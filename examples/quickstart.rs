//! Quickstart: load one AOT-compiled S5 layer, run it from Rust, and
//! cross-check against the pure-Rust reference implementation.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! This demonstrates the full three-layer contract on the smallest
//! possible artifact: the Pallas scan kernel (L1) and the JAX layer
//! math (L2) are baked into `artifacts/quickstart_fwd.hlo.txt`; Rust (L3)
//! loads it through PJRT, feeds a random sequence, and verifies the output
//! against an independent implementation of the same layer.

use s5::num::C64;
use s5::rng::Rng;
use s5::runtime::params::{assemble_inputs, literal_f32, to_vec_f32, ParamStore};
use s5::runtime::{Artifact, Client};
use s5::ssm::s5::S5Layer;
use std::collections::BTreeMap;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(s5::ARTIFACTS_DIR);
    anyhow::ensure!(
        dir.join("quickstart_fwd.hlo.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // 1. Load + compile the AOT artifact on the PJRT CPU client.
    let client = Client::cpu()?;
    let art = Artifact::load(dir, "quickstart_fwd", &client)?;
    let (l, h, p2) = (128usize, 8usize, 4usize);
    println!(
        "loaded {}: kind={} ({} inputs, {} outputs)",
        art.name,
        art.manifest.kind,
        art.manifest.inputs.len(),
        art.manifest.outputs.len()
    );

    // 2. Load the initial parameters the Python build exported.
    let store = ParamStore::load_npz(&Artifact::init_npz_path(dir, "quickstart"))?;
    println!("parameters: {} tensors, {} scalars", store.len(), store.total_elems());

    // 3. Run the compiled layer on a random sequence.
    let mut rng = Rng::new(42);
    let u = rng.normal_vec_f32(l * h);
    let mut extra = BTreeMap::new();
    extra.insert("u".to_string(), literal_f32(&u, &[l, h])?);
    let inputs = assemble_inputs(&art.manifest, &store, &mut extra)?;
    let t = s5::util::Timer::start();
    let y_hlo = to_vec_f32(&art.run(&inputs)?[0])?;
    println!("PJRT execution: {:.2}ms for (L={l}, H={h})", t.millis());

    // 4. Same layer, pure Rust (the parity oracle).
    let f = |name: &str| to_vec_f32(store.get(name).unwrap()).unwrap();
    let (lr, li) = (f("params.lambda_re"), f("params.lambda_im"));
    let (br, bi) = (f("params.b_re"), f("params.b_im"));
    let (cr, ci) = (f("params.c_re"), f("params.c_im"));
    let layer = S5Layer {
        lambda: (0..p2).map(|i| C64::new(lr[i] as f64, li[i] as f64)).collect(),
        b_tilde: (0..p2 * h).map(|i| C64::new(br[i] as f64, bi[i] as f64)).collect(),
        c_tilde: vec![(0..h * p2).map(|i| C64::new(cr[i] as f64, ci[i] as f64)).collect()],
        d: f("params.d"),
        log_dt: f("params.log_dt"),
        gate_w: f("params.gate_w"),
        norm_scale: f("params.norm_scale"),
        norm_bias: f("params.norm_bias"),
        h,
        p2,
    };
    let y_rust = layer.apply(&u, l, 1.0, None, 1);

    // 5. Compare.
    let max_err = y_hlo
        .iter()
        .zip(&y_rust)
        .map(|(a, b)| (a - b).abs() / (1.0 + a.abs().max(b.abs())))
        .fold(0.0f32, f32::max);
    println!("max relative error HLO vs Rust oracle: {max_err:.2e}");
    anyhow::ensure!(max_err < 2e-3, "parity violated");
    println!("first output row: {:?}", &y_hlo[..h.min(6)]);
    println!("quickstart OK — all three layers agree ✓");
    Ok(())
}
