//! Quickstart: the unified `SequenceModel` inference API, end to end —
//! typed batched prefill, bit-for-bit streaming, and native npz
//! checkpoint round-tripping. Runs hermetically (no PJRT, no artifacts):
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! (The PJRT parity checks against the compiled HLO live in
//! `tests/parity.rs` and the pjrt-gated examples.)

use s5::rng::Rng;
use s5::runtime::NpzStore;
use s5::ssm::api::{Batch, ForwardOptions, SequenceModel, Session};
use s5::ssm::engine::EngineWorkspace;
use s5::ssm::rnn::GruCell;
use s5::ssm::s5::{S5Config, S5Model};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let (d_in, classes, depth, l) = (3usize, 10usize, 2usize, 128usize);
    let cfg = S5Config { h: 32, p: 32, j: 1, ..Default::default() };
    let model = S5Model::init(d_in, classes, depth, &cfg, &mut Rng::new(42));
    println!("S5 model: {} params, spec {:?}", model.param_count(), model.spec());

    // 1. Typed batched prefill: a packed (B, L, d_in) buffer under one
    //    ForwardOptions, one output row per sequence.
    let batch = 4usize;
    let mut rng = Rng::new(7);
    let u = rng.normal_vec_f32(batch * l * d_in);
    let opts = ForwardOptions::new().with_threads(0); // 0 = auto-detect
    let mut ws = EngineWorkspace::new();
    let logits = model.prefill(Batch::new(&u, batch, l, d_in), &opts, &mut ws);
    println!("prefill: {batch} sequences → {} logit rows", logits.len() / classes);

    // 2. Native checkpoint export before the model moves behind a trait
    //    object (save → load → identical logits is checked below).
    let store = model.to_param_store();

    // 3. Streaming: a Session steps one observation at a time and, on the
    //    sequential scan path, reproduces the batched forward bit-for-bit.
    let seq_opts = ForwardOptions::new(); // sequential scan (deterministic)
    let one = &u[..l * d_in];
    let offline = model.prefill(Batch::single(one, l, d_in), &seq_opts, &mut ws);
    let shared: Arc<dyn SequenceModel> = Arc::new(model);
    let mut session = Session::new(shared.clone(), seq_opts.clone());
    let streamed = session.prefill(one, l);
    anyhow::ensure!(offline == streamed, "streaming must equal batched exactly");
    println!("session: {} steps, streaming ≡ batched bit-for-bit ✓", session.steps());

    // 4. The same API drives a completely different model family.
    let gru: Arc<dyn SequenceModel> = Arc::new(GruCell::init(d_in, 16, &mut Rng::new(1)));
    let hidden = gru.prefill(Batch::single(one, l, d_in), &opts, &mut ws);
    println!("gru prefill through the same trait: {} hidden units", hidden.len());

    // 5. Checkpoint round trip through the pure-Rust npz store.
    let path = std::env::temp_dir().join(format!("s5_quickstart_{}.npz", std::process::id()));
    store.save(&path)?;
    let reloaded = S5Model::from_param_store(&NpzStore::load(&path)?)?;
    let re_logits = reloaded.prefill(Batch::single(one, l, d_in), &seq_opts, &mut ws);
    let baseline = shared.prefill(Batch::single(one, l, d_in), &seq_opts, &mut ws);
    let max_err = re_logits
        .iter()
        .zip(&baseline)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("checkpoint round trip: max |Δlogit| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-4, "checkpoint round trip drifted");
    std::fs::remove_file(&path).ok();
    println!("quickstart OK ✓");
    Ok(())
}
