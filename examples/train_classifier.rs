//! End-to-end training driver (the repo's E2E validation, DESIGN.md §3).
//!
//! Trains the sMNIST pixel-level classifier (paper §6.4 / Table 10's
//! setting, on the synthetic digit generator) for a few hundred steps
//! through the full stack: Rust data pipeline → fused AdamW train-step HLO
//! (containing the Pallas scan kernel) on PJRT → metrics → checkpoint →
//! held-out evaluation. Logs the loss curve and writes
//! `train_classifier_metrics.csv` + `train_classifier_ckpt.npz`.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_classifier -- --steps 300
//! ```

use s5::coordinator::{TrainConfig, Trainer};
use s5::runtime::Client;
use s5::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = TrainConfig::for_preset(&args.get_or("preset", "smnist"));
    cfg.steps = args.get_usize("steps", 300);
    cfg.train_pool = args.get_usize("train-pool", 512);
    cfg.eval_pool = args.get_usize("eval-pool", 128);
    cfg.eval_every = args.get_usize("eval-every", 50);
    cfg.base_lr = args.get_f64("lr", cfg.base_lr);
    cfg.checkpoint = Some("train_classifier_ckpt.npz".to_string());
    cfg.metrics_csv = Some("train_classifier_metrics.csv".to_string());

    println!(
        "=== E2E training driver: preset={} steps={} lr={} ===",
        cfg.preset, cfg.steps, cfg.base_lr
    );
    let client = Client::cpu()?;
    let mut trainer = Trainer::new(&client, cfg)?;
    let t0 = s5::util::Timer::start();
    trainer.run()?;
    let wall = t0.secs();

    let (eval_loss, eval_acc) = trainer.evaluate()?;
    let tput = trainer.log.throughput(50);

    // loss curve summary (printed so EXPERIMENTS.md can quote it directly)
    println!("\n--- loss curve (EMA) ---");
    let ema = trainer.log.ema_loss(0.1);
    let n = ema.len();
    for frac in [0.0, 0.1, 0.25, 0.5, 0.75, 1.0] {
        let idx = ((n - 1) as f64 * frac) as usize;
        println!(
            "  step {:>5}: loss {:.4}",
            trainer.log.records[idx].step, ema[idx]
        );
    }
    println!("  curve: [{}]", trainer.log.sparkline(40));
    println!("\n--- results ---");
    println!("train wall time     : {wall:.1}s ({tput:.2} steps/s)");
    println!("final train loss    : {:.4}", ema[n - 1]);
    println!("held-out loss       : {eval_loss:.4}");
    println!("held-out accuracy   : {:.2}%", eval_acc * 100.0);
    println!("checkpoint          : train_classifier_ckpt.npz");
    println!("metrics csv         : train_classifier_metrics.csv");

    anyhow::ensure!(
        ema[n - 1] < ema[0],
        "loss did not decrease over training"
    );
    println!("\nE2E driver OK — all layers compose ✓");
    Ok(())
}
