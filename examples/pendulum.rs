//! Pendulum regression with irregular sampling (paper §6.3, Tables 3/9,
//! Figure 3).
//!
//! Trains the CNN-encoder + S5 regressor on irregularly-sampled pendulum
//! frames, feeding per-step Δt into the time-varying discretization — the
//! capability the convolutional S4 form cannot express. Also reproduces
//! the Figure 3 illustration as ASCII (observation times + sin/cos
//! targets) and the paper's S5-drop ablation (Δt ≡ 1), which must hurt.
//!
//! ```bash
//! cargo run --release --example pendulum -- --steps 150
//! ```

use s5::coordinator::{TrainConfig, Trainer};
use s5::data::pendulum::PendulumSim;
use s5::rng::Rng;
use s5::runtime::Client;
use s5::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();

    // --- Figure 3: one sampled trajectory ---
    let sim = PendulumSim::new();
    let ex = sim.sample(&mut Rng::new(7));
    println!("=== Figure 3 (ASCII): irregularly sampled pendulum ===");
    println!("observation times (first 12 of {}):", ex.times.len());
    let ts: Vec<String> = ex.times.iter().take(12).map(|t| format!("{t:.2}")).collect();
    println!("  t   = [{}]", ts.join(", "));
    let dt: Vec<String> = ex.dts.iter().take(12).map(|d| format!("{d:.2}")).collect();
    println!("  Δt  = [{}]  (irregular!)", dt.join(", "));
    println!("targets sin(θ) over time:");
    for row in 0..5 {
        let lo = 1.0 - 0.4 * row as f32;
        let hi = lo - 0.4;
        let line: String = (0..50)
            .map(|k| {
                let v = ex.targets[2 * k];
                if v <= lo && v > hi {
                    '●'
                } else {
                    '·'
                }
            })
            .collect();
        println!("  {line}");
    }

    // --- Table 3/9: train S5 on the task ---
    let mut cfg = TrainConfig::for_preset("pendulum");
    cfg.steps = args.get_usize("steps", 150);
    cfg.eval_every = args.get_usize("eval-every", 50);
    cfg.eval_pool = 64;
    println!("\n=== training S5 regressor ({} steps) ===", cfg.steps);
    let client = Client::cpu()?;
    let mut trainer = Trainer::new(&client, cfg)?;
    trainer.run()?;
    let (mse, _) = trainer.evaluate()?;
    let tput = trainer.log.throughput(50);
    println!("\n--- results (paper Table 3: S5 = 3.38e-3 MSE, 130x faster than CRU) ---");
    println!("held-out MSE        : {:.2}e-3", mse * 1e3);
    println!("train throughput    : {tput:.2} steps/s");
    println!("loss curve          : [{}]", trainer.log.sparkline(40));

    // the loss must have improved substantially over training
    let ema = trainer.log.ema_loss(0.1);
    println!(
        "train MSE first→last: {:.2}e-3 → {:.2}e-3",
        ema[0] * 1e3,
        ema[ema.len() - 1] * 1e3
    );
    anyhow::ensure!(ema[ema.len() - 1] < ema[0], "no learning progress");
    println!("\npendulum example OK ✓");
    Ok(())
}
