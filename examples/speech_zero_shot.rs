//! Zero-shot sampling-rate transfer (paper §6.2, Tables 2/8).
//!
//! The headline property of continuous-time parameterization: a model
//! trained at the base rate ("16 kHz", L=2048) classifies decimated audio
//! ("8 kHz", L=1024) **without retraining**, purely by doubling the Δ
//! timescale input. The 8 kHz path runs through a *separate* fwd artifact
//! compiled at L=1024 — parameters are length-independent, so the trained
//! 16 kHz checkpoint is loaded straight into it.
//!
//! ```bash
//! cargo run --release --example speech_zero_shot -- --steps 200
//! ```

use s5::coordinator::{TrainConfig, Trainer};
use s5::data::speech::SpeechCommands;
use s5::data::TaskGen;
use s5::rng::Rng;
use s5::runtime::params::{literal_f32, to_vec_f32, ParamStore};
use s5::runtime::{Artifact, Client};
use s5::util::Args;
use std::path::Path;
use xla::Literal;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dir = Path::new(s5::ARTIFACTS_DIR);
    let ckpt = std::env::temp_dir().join("s5_speech_zero_shot.npz");

    // 1. Train at 16 kHz (L=2048).
    let mut cfg = TrainConfig::for_preset("speech");
    cfg.steps = args.get_usize("steps", 200);
    cfg.train_pool = args.get_usize("train-pool", 256);
    cfg.eval_pool = args.get_usize("eval-pool", 70);
    cfg.eval_every = 0;
    cfg.checkpoint = Some(ckpt.to_string_lossy().to_string());
    println!("=== training 35-way keyword model at 16 kHz ({} steps) ===", cfg.steps);
    let client = Client::cpu()?;
    let mut trainer = Trainer::new(&client, cfg)?;
    trainer.run()?;
    let (_, acc16) = trainer.evaluate()?;
    println!("16 kHz held-out accuracy: {:.1}%", acc16 * 100.0);

    // 2. Zero-shot at 8 kHz: same parameters, half-length artifact, ρ=2.
    println!("\n=== zero-shot transfer to 8 kHz (decimated, timescale=2) ===");
    let art8k = Artifact::load(dir, "speech8k_fwd", &client)?;
    let store = ParamStore::load_npz(&ckpt)?;
    let idx = art8k.manifest.input_group("params");
    let specs: Vec<_> = idx.iter().map(|&i| &art8k.manifest.inputs[i]).collect();
    let params = store.gather(&specs)?;

    let gen16 = SpeechCommands::new(2048);
    let batch = art8k.manifest.meta_usize("batch")?;
    let classes = art8k.manifest.meta_usize("classes")?;
    let x_spec = &art8k.manifest.inputs[art8k.manifest.input_index("x")?];

    let eval_8k = |timescale: f32| -> anyhow::Result<f64> {
        let mut rng = Rng::new(0x8000);
        let (mut correct, mut total) = (0usize, 0usize);
        for _ in 0..8 {
            let mut x = Vec::with_capacity(batch * 1024);
            let mut labels = Vec::with_capacity(batch);
            for _ in 0..batch {
                // sample a 16 kHz waveform, then naively decimate x2 (§6.2)
                let ex = gen16.sample(&mut rng);
                x.extend(SpeechCommands::decimate(&ex.x, 2));
                labels.push(ex.label);
            }
            let ts = literal_f32(&[timescale], &[])?;
            let xl = literal_f32(&x, &x_spec.dims)?;
            let mut refs: Vec<&Literal> = params.iter().collect();
            refs.push(&ts);
            refs.push(&xl);
            let logits = to_vec_f32(&art8k.run(&refs)?[0])?;
            for (i, &label) in labels.iter().enumerate() {
                let row = &logits[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == label as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    };

    let acc_rescaled = eval_8k(2.0)?; // Δ doubled: the S5 recipe
    let acc_naive = eval_8k(1.0)?; // no rescale: the CNN-baseline failure mode
    println!("8 kHz, timescale=2 (S5 recipe) : {:.1}%", acc_rescaled * 100.0);
    println!("8 kHz, timescale=1 (no rescale): {:.1}%", acc_naive * 100.0);

    println!("\n--- Table 2 shape check ---");
    println!("paper: S5 96.5% @16k → 94.5% @8k (small drop); CNNs collapse to ~7%");
    println!(
        "ours : {:.1}% @16k → {:.1}% @8k rescaled vs {:.1}% unrescaled",
        acc16 * 100.0,
        acc_rescaled * 100.0,
        acc_naive * 100.0
    );
    anyhow::ensure!(
        acc_rescaled >= acc_naive,
        "Δ-rescaling should not hurt zero-shot transfer"
    );
    std::fs::remove_file(&ckpt).ok();
    println!("\nspeech_zero_shot OK ✓");
    Ok(())
}
