//! Serving demo: one model-generic dynamic-batching server.
//!
//! Starts the native inference server twice — once over an S5 stack, once
//! over the GRU baseline — through the same `Arc<dyn SequenceModel>`
//! handle API, fires concurrent clients at each, and reports throughput,
//! latency percentiles and batch fill. Also opens a pooled streaming
//! session against the S5 server. Runs hermetically (no PJRT):
//!
//! ```bash
//! cargo run --release --example serve -- --requests 96 --clients 16
//! ```

use s5::coordinator::server::{NativeInferenceServer, ServerConfig};
use s5::rng::Rng;
use s5::ssm::api::SequenceModel;
use s5::ssm::rnn::GruCell;
use s5::ssm::s5::{S5Config, S5Model};
use s5::util::{Args, Stats};
use std::sync::Arc;
use std::time::Duration;

fn drive(
    server: &NativeInferenceServer,
    l: usize,
    n_requests: usize,
    clients: usize,
) -> (f64, Stats) {
    let handle = server.handle();
    let d_in = handle.row / l;
    let t0 = std::time::Instant::now();
    let lat: Vec<f64> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..clients)
            .map(|c| {
                let h = handle.clone();
                let per_client = n_requests / clients;
                s.spawn(move || {
                    let mut rng = Rng::new(c as u64);
                    let mut lats = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let x = rng.normal_vec_f32(l * d_in);
                        let resp = h.infer(x).expect("infer");
                        lats.push(resp.total_secs);
                    }
                    lats
                })
            })
            .collect();
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    (lat.len() as f64 / wall, Stats::from(&lat))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 96);
    let clients = args.get_usize("clients", 16);
    let (l, d_in) = (128usize, 4usize);
    let cfg = ServerConfig { max_wait: Duration::from_millis(10), ..Default::default() };

    // The two models share nothing but the trait — one server loop each.
    let s5_model: Arc<dyn SequenceModel> = Arc::new(S5Model::init(
        d_in,
        10,
        4,
        &S5Config { h: 32, p: 32, j: 1, ..Default::default() },
        &mut Rng::new(3),
    ));
    let gru_model: Arc<dyn SequenceModel> = Arc::new(GruCell::init(d_in, 32, &mut Rng::new(4)));

    for model in [s5_model.clone(), gru_model] {
        let spec = model.spec();
        println!("=== serving {} (d_out {}) with dynamic batching ===", spec.name, spec.d_output);
        let server = NativeInferenceServer::start_model(model, l, cfg);
        let (tput, lat) = drive(&server, l, n_requests, clients);
        println!(
            "  {tput:.1} req/s | p50 {:.1}ms p95 {:.1}ms | mean batch fill {:.2}",
            lat.p50 * 1e3,
            lat.p95 * 1e3,
            server.stats.mean_batch_fill()
        );
    }

    // Streaming: check a pooled session out of a running server and feed
    // it one observation at a time (same shared model, no extra copy).
    let server = NativeInferenceServer::start_model(s5_model, l, cfg);
    let mut session = server.open_session();
    let mut rng = Rng::new(9);
    let mut logits = Vec::new();
    for _ in 0..l {
        logits = session.step(&rng.normal_vec_f32(d_in));
    }
    println!(
        "streamed {} steps through a pooled session → {} logits",
        session.steps(),
        logits.len()
    );
    server.close_session(session);

    println!("serve example OK ✓");
    Ok(())
}
