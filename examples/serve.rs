//! Serving demo: dynamic batching under concurrent load.
//!
//! Starts the inference server on the sMNIST classifier artifact and fires
//! concurrent clients at it, reporting throughput, latency percentiles and
//! batch-fill — then repeats with batching disabled to show the win.
//!
//! ```bash
//! cargo run --release --example serve -- --requests 96 --clients 16
//! ```

use s5::coordinator::server::{InferenceServer, ServerConfig};
use s5::data::make_task;
use s5::rng::Rng;
use s5::util::{Args, Stats};
use std::path::Path;
use std::time::Duration;

fn drive(server: &InferenceServer, n_requests: usize, clients: usize) -> (f64, Stats) {
    let handle = server.handle();
    let task = make_task("smnist").unwrap();
    let t0 = std::time::Instant::now();
    let lat: Vec<f64> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..clients)
            .map(|c| {
                let h = handle.clone();
                let task = &task;
                let per_client = n_requests / clients;
                s.spawn(move || {
                    let mut rng = Rng::new(c as u64);
                    let mut lats = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let ex = task.sample(&mut rng);
                        let resp = h.infer(ex.x).expect("infer");
                        lats.push(resp.total_secs);
                    }
                    lats
                })
            })
            .collect();
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    (lat.len() as f64 / wall, Stats::from(&lat))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 96);
    let clients = args.get_usize("clients", 16);
    let dir = Path::new(s5::ARTIFACTS_DIR);

    println!("=== dynamic batching ON (max_wait = 10ms) ===");
    let batched = InferenceServer::start(
        dir,
        "smnist",
        None,
        ServerConfig { max_wait: Duration::from_millis(10), ..Default::default() },
    )?;
    let (tput_b, lat_b) = drive(&batched, n_requests, clients);
    println!(
        "  {tput_b:.1} req/s | p50 {:.1}ms p95 {:.1}ms | mean batch fill {:.2}",
        lat_b.p50 * 1e3,
        lat_b.p95 * 1e3,
        batched.stats.mean_batch_fill()
    );
    drop(batched);

    println!("=== dynamic batching OFF (max_wait = 0) ===");
    let unbatched = InferenceServer::start(
        dir,
        "smnist",
        None,
        ServerConfig { max_wait: Duration::from_millis(0), ..Default::default() },
    )?;
    let (tput_u, lat_u) = drive(&unbatched, n_requests, clients);
    println!(
        "  {tput_u:.1} req/s | p50 {:.1}ms p95 {:.1}ms | mean batch fill {:.2}",
        lat_u.p50 * 1e3,
        lat_u.p95 * 1e3,
        unbatched.stats.mean_batch_fill()
    );

    println!("\nbatching speedup: {:.2}x throughput", tput_b / tput_u);
    println!("serve example OK ✓");
    Ok(())
}
