//! Self-tests for the invariant checker: every lint L1–L6 must trip on a
//! seeded violation and stay quiet on its clean twin, suppressions must
//! work (and demand a reason), and — the real teeth — the repo at HEAD
//! must come back clean with `UNSAFE.md` in sync.

use std::fs;
use std::path::{Path, PathBuf};

/// Write a throwaway fixture tree under the OS temp dir and return its
/// root. Re-created from scratch on every call (`cargo test` may rerun).
fn fixture(name: &str, files: &[(&str, &str)]) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("xtask-selftest-{}-{name}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    for (rel, text) in files {
        let p = dir.join(rel);
        fs::create_dir_all(p.parent().unwrap()).unwrap();
        fs::write(&p, text).unwrap();
    }
    dir
}

fn check(dir: &Path) -> xtask::CheckResult {
    xtask::run_check(dir, "fixture", &[])
}

fn lints_hit(res: &xtask::CheckResult) -> Vec<&'static str> {
    res.findings.iter().map(|f| f.lint).collect()
}

// ---- L1: pool-only threading ----

#[test]
fn l1_thread_spawn_outside_pool_trips() {
    let dir = fixture(
        "l1-bad",
        &[(
            "worker.rs",
            "pub fn go() {\n    std::thread::spawn(|| {}).join().unwrap();\n}\n",
        )],
    );
    let res = check(&dir);
    assert_eq!(lints_hit(&res), ["pool-threading"], "{:#?}", res.findings);
}

#[test]
fn l1_pool_rs_itself_is_exempt() {
    let dir = fixture(
        "l1-pool",
        &[(
            "runtime/pool.rs",
            "pub fn go() {\n    std::thread::spawn(|| {}).join().unwrap();\n}\n",
        )],
    );
    let res = check(&dir);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

#[test]
fn l1_mentions_in_comments_and_strings_do_not_trip() {
    let dir = fixture(
        "l1-comment",
        &[(
            "doc.rs",
            "//! Replaces `thread::spawn` everywhere.\n/* thread::scope too */\npub const HELP: &str = \"thread::spawn is banned\";\n",
        )],
    );
    let res = check(&dir);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

#[test]
fn suppression_with_reason_silences_and_without_reason_trips() {
    let ok = fixture(
        "sup-ok",
        &[(
            "worker.rs",
            "pub fn go() {\n    // s5:allow(pool-threading) fixture exercises a raw spawn\n    std::thread::spawn(|| {}).join().unwrap();\n}\n",
        )],
    );
    let res = check(&ok);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);

    let bad = fixture(
        "sup-bad",
        &[(
            "worker.rs",
            "pub fn go() {\n    // s5:allow(pool-threading)\n    std::thread::spawn(|| {}).join().unwrap();\n}\n",
        )],
    );
    let res = check(&bad);
    // The reason-less allow is itself a finding, and it does not suppress.
    let hit = lints_hit(&res);
    assert!(hit.contains(&"suppression"), "{:#?}", res.findings);
    assert!(hit.contains(&"pool-threading"), "{:#?}", res.findings);
}

// ---- L2: env reads + registry ----

#[test]
fn l2_env_var_outside_envcfg_trips() {
    let dir = fixture(
        "l2-bad",
        &[(
            "knobs.rs",
            "pub fn debug() -> bool {\n    std::env::var(\"DEBUG\").is_ok()\n}\n",
        )],
    );
    let res = check(&dir);
    assert_eq!(lints_hit(&res), ["env-registry"], "{:#?}", res.findings);
}

#[test]
fn l2_registry_cross_check_flags_unregistered_and_stale() {
    let envcfg = "\
// s5:env-registry-begin
pub const ENV_REGISTRY: &[(&str, &str)] = &[
    (\"S5_GOOD\", \"a registered knob\"),
    (\"S5_UNUSED\", \"a stale entry\"),
];
// s5:env-registry-end
pub fn read(name: &str) -> Option<String> {
    std::env::var(name).ok()
}
";
    let dir = fixture(
        "l2-registry",
        &[
            ("runtime/envcfg.rs", envcfg),
            (
                "user.rs",
                "pub const A: &str = \"S5_GOOD\";\npub const B: &str = \"S5_BOGUS\";\n",
            ),
        ],
    );
    let res = check(&dir);
    let msgs: Vec<&str> = res.findings.iter().map(|f| f.msg.as_str()).collect();
    assert_eq!(res.findings.len(), 2, "{:#?}", res.findings);
    assert!(msgs.iter().any(|m| m.contains("S5_BOGUS")), "{msgs:#?}");
    assert!(msgs.iter().any(|m| m.contains("S5_UNUSED")), "{msgs:#?}");
}

// ---- L3: hot fences ----

#[test]
fn l3_alloc_inside_fence_trips() {
    let dir = fixture(
        "l3-bad",
        &[(
            "kern.rs",
            "pub fn hot(xs: &mut Vec<f32>) {\n    // s5:hot-begin\n    xs.push(1.0);\n    // s5:hot-end\n}\n",
        )],
    );
    let res = check(&dir);
    assert_eq!(lints_hit(&res), ["hot-alloc"], "{:#?}", res.findings);
}

#[test]
fn l3_clean_fence_and_alloc_outside_fence_pass() {
    let dir = fixture(
        "l3-ok",
        &[(
            "kern.rs",
            "pub fn hot(xs: &mut [f32], ys: &mut Vec<f32>) {\n    ys.push(0.0);\n    // s5:hot-begin\n    xs[0] = 1.0;\n    // s5:hot-end\n    ys.push(2.0);\n}\n",
        )],
    );
    let res = check(&dir);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

#[test]
fn l3_unbalanced_fence_is_an_error() {
    let dir = fixture(
        "l3-fence",
        &[("kern.rs", "// s5:hot-begin\npub fn f() {}\n")],
    );
    let res = check(&dir);
    assert_eq!(lints_hit(&res), ["fence"], "{:#?}", res.findings);
}

// ---- L4: unsafe hygiene ----

#[test]
fn l4_undocumented_unsafe_trips_and_documented_passes() {
    let bad = fixture(
        "l4-bad",
        &[(
            "raw.rs",
            "pub fn f(p: *const i32) -> i32 {\n    unsafe { *p }\n}\n",
        )],
    );
    let res = check(&bad);
    assert_eq!(lints_hit(&res), ["unsafe-safety"], "{:#?}", res.findings);
    assert_eq!(res.unsafe_sites.len(), 1);

    let ok = fixture(
        "l4-ok",
        &[(
            "raw.rs",
            "pub fn f(p: *const i32) -> i32 {\n    // SAFETY: caller guarantees p is valid and aligned.\n    unsafe { *p }\n}\n",
        )],
    );
    let res = check(&ok);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
    assert_eq!(res.unsafe_sites.len(), 1);
}

#[test]
fn l4_inventory_renders_deterministically() {
    let dir = fixture(
        "l4-md",
        &[(
            "raw.rs",
            "pub fn f(p: *const i32) -> i32 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n",
        )],
    );
    let res = check(&dir);
    let md = xtask::render_unsafe_md(&res.unsafe_sites);
    assert!(md.contains("## fixture/raw.rs"), "{md}");
    assert!(md.contains("- `unsafe { *p }`"), "{md}");
    assert!(md.contains("Total: 1 occurrences across 1 files."), "{md}");
}

// ---- L5: simd gate symmetry ----

#[test]
fn l5_attribute_gate_without_scalar_twin_trips() {
    let dir = fixture(
        "l5-attr",
        &[(
            "lanes.rs",
            "#[cfg(feature = \"simd\")]\npub fn lanes() {}\n",
        )],
    );
    let res = check(&dir);
    assert_eq!(lints_hit(&res), ["simd-symmetry"], "{:#?}", res.findings);

    let ok = fixture(
        "l5-attr-ok",
        &[(
            "lanes.rs",
            "#[cfg(feature = \"simd\")]\npub fn lanes() {}\n#[cfg(not(feature = \"simd\"))]\npub fn lanes() {}\n",
        )],
    );
    let res = check(&ok);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

#[test]
fn l5_cfg_macro_outside_if_dispatch_trips() {
    let dir = fixture(
        "l5-expr",
        &[(
            "gate.rs",
            "pub fn wide() -> bool {\n    cfg!(feature = \"simd\")\n}\n",
        )],
    );
    let res = check(&dir);
    assert_eq!(lints_hit(&res), ["simd-symmetry"], "{:#?}", res.findings);
}

#[test]
fn l5_dispatch_without_scalar_fallthrough_trips() {
    let dir = fixture(
        "l5-fall",
        &[(
            "gate.rs",
            "pub fn kernel(x: &mut [f32]) {\n    if cfg!(feature = \"simd\") {\n        x[0] = 1.0;\n    }\n}\n",
        )],
    );
    let res = check(&dir);
    assert_eq!(lints_hit(&res), ["simd-symmetry"], "{:#?}", res.findings);
}

#[test]
fn l5_dispatch_with_fallthrough_or_else_passes() {
    let dir = fixture(
        "l5-ok",
        &[(
            "gate.rs",
            "pub fn kernel(x: &mut [f32]) {\n    if cfg!(feature = \"simd\") {\n        x[0] = 1.0;\n        return;\n    }\n    x[0] = 2.0;\n}\npub fn kernel2(x: &mut [f32]) {\n    if cfg!(feature = \"simd\") {\n        x[0] = 1.0;\n    } else {\n        x[0] = 2.0;\n    }\n}\n",
        )],
    );
    let res = check(&dir);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

// ---- L6: no unwrap/expect on the serving path ----

#[test]
fn l6_unwrap_in_coordinator_trips() {
    let dir = fixture(
        "l6-bad",
        &[(
            "coordinator/server.rs",
            "pub fn go(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n",
        )],
    );
    let res = check(&dir);
    assert_eq!(lints_hit(&res), ["serve-unwrap"], "{:#?}", res.findings);
}

#[test]
fn l6_expect_in_ssm_api_trips() {
    let dir = fixture(
        "l6-expect",
        &[(
            "ssm/api.rs",
            "pub fn go(v: Option<u32>) -> u32 {\n    v.expect(\"present\")\n}\n",
        )],
    );
    let res = check(&dir);
    assert_eq!(lints_hit(&res), ["serve-unwrap"], "{:#?}", res.findings);
}

#[test]
fn l6_unwrap_off_the_serving_path_is_fine() {
    let dir = fixture(
        "l6-elsewhere",
        &[(
            "ssm/scan.rs",
            "pub fn go(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n",
        )],
    );
    let res = check(&dir);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

#[test]
fn l6_cfg_test_code_is_exempt() {
    let dir = fixture(
        "l6-test-mod",
        &[(
            "coordinator/server.rs",
            "pub fn go(v: Option<u32>) -> Option<u32> {\n    v\n}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        assert_eq!(super::go(Some(1)).unwrap(), 1);\n        Some(2u32).expect(\"two\");\n    }\n}\n",
        )],
    );
    let res = check(&dir);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

#[test]
fn l6_poison_recovery_idiom_is_not_matched() {
    let dir = fixture(
        "l6-poison",
        &[(
            "ssm/api.rs",
            "use std::sync::Mutex;\npub fn go(m: &Mutex<u32>) -> u32 {\n    *m.lock().unwrap_or_else(|p| p.into_inner())\n}\n",
        )],
    );
    let res = check(&dir);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

#[test]
fn l6_suppression_with_reason_silences() {
    let dir = fixture(
        "l6-sup",
        &[(
            "coordinator/server.rs",
            "pub fn go(v: Option<u32>) -> u32 {\n    // s5:allow(serve-unwrap) fixture: invariant established one line up\n    v.unwrap()\n}\n",
        )],
    );
    let res = check(&dir);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

#[test]
fn l6_mentions_in_comments_and_strings_do_not_trip() {
    let dir = fixture(
        "l6-comment",
        &[(
            "coordinator/server.rs",
            "//! Never call `.unwrap()` here.\npub const HELP: &str = \".expect( is banned\";\n",
        )],
    );
    let res = check(&dir);
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
}

// ---- the repo itself ----

/// The teeth: `rust/src` at HEAD is lint-clean and the committed
/// `UNSAFE.md` matches the regenerated inventory byte-for-byte.
#[test]
fn repo_head_is_clean_and_unsafe_md_in_sync() {
    let (res, repo) = xtask::check_repo(Path::new(env!("CARGO_MANIFEST_DIR")));
    assert!(res.files_scanned > 10, "src scan found too few files");
    assert!(res.findings.is_empty(), "{:#?}", res.findings);
    let md = xtask::render_unsafe_md(&res.unsafe_sites);
    let committed = fs::read_to_string(repo.join("UNSAFE.md"))
        .expect("UNSAFE.md missing — run `cargo run -p xtask -- write-unsafe`");
    assert_eq!(
        committed, md,
        "UNSAFE.md is stale — run `cargo run -p xtask -- write-unsafe`"
    );
}
