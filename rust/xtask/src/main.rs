//! CLI for the invariant checker.
//!
//! * `cargo run -p xtask -- check` — run lints L1–L6 over `rust/src`,
//!   verify `UNSAFE.md` is in sync; non-zero exit on any finding.
//! * `cargo run -p xtask -- write-unsafe` — regenerate `UNSAFE.md`.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("check");
    let (res, repo) = xtask::check_repo(Path::new(env!("CARGO_MANIFEST_DIR")));
    let unsafe_md = xtask::render_unsafe_md(&res.unsafe_sites);
    let unsafe_path = repo.join("UNSAFE.md");

    match cmd {
        "check" => {
            let mut failed = false;
            for f in &res.findings {
                eprintln!("{f}");
                failed = true;
            }
            match std::fs::read_to_string(&unsafe_path) {
                Ok(cur) if cur == unsafe_md => {}
                _ => {
                    eprintln!(
                        "unsafe-safety: {}: stale or missing — regenerate with \
                         `cargo run -p xtask -- write-unsafe`",
                        unsafe_path.display()
                    );
                    failed = true;
                }
            }
            if failed {
                eprintln!("xtask check: FAILED");
                ExitCode::FAILURE
            } else {
                println!(
                    "xtask check: OK ({} files, {} unsafe sites, 0 findings)",
                    res.files_scanned,
                    res.unsafe_sites.len()
                );
                ExitCode::SUCCESS
            }
        }
        "write-unsafe" => {
            if let Err(e) = std::fs::write(&unsafe_path, unsafe_md) {
                eprintln!("failed to write {}: {e}", unsafe_path.display());
                return ExitCode::FAILURE;
            }
            println!("wrote {} ({} sites)", unsafe_path.display(), res.unsafe_sites.len());
            ExitCode::SUCCESS
        }
        other => {
            eprintln!("unknown command {other:?}; usage: xtask [check|write-unsafe]");
            ExitCode::FAILURE
        }
    }
}
