//! Invariant checker for the s5 repo: `cargo run -p xtask -- check`.
//!
//! The engine's performance story rests on a handful of repo-wide
//! invariants that ordinary tests cannot see (they are properties of the
//! *source*, not of any one execution). This crate machine-checks them
//! with a hand-rolled line lexer — no `syn`, no dependencies; the build
//! container is hermetic — that strips comments, string literals and char
//! literals from every line of `rust/src`, then pattern-matches the
//! remaining code text. Six named lints:
//!
//! * **`pool-threading` (L1)** — `thread::spawn` / `thread::scope` /
//!   `thread::Builder` appear only inside `runtime/pool.rs`. Everything
//!   else must go through [`spawn_worker`]/`Executor` so the persistent
//!   worker pool stays the single source of parallelism.
//! * **`env-registry` (L2)** — `std::env::var*` reads live only in
//!   `runtime/envcfg.rs`, and every `S5_*` knob string found anywhere in
//!   the sources is listed in the committed registry table between the
//!   `s5:env-registry-begin` / `-end` markers (and vice versa: no stale
//!   registry entries).
//! * **`hot-alloc` (L3)** — no allocating calls (`Vec::new`, `vec!`,
//!   `.push(`, `.collect`, `.clone(`, `Box::new`, `format!`, …) between
//!   `// s5:hot-begin` and `// s5:hot-end` fence comments. The fences
//!   wrap the scan/SIMD/engine tile kernels; the runtime twin of this
//!   lint is the counting-allocator harness (`s5::testing::alloc_guard`).
//! * **`unsafe-safety` (L4)** — every `unsafe` token is directly preceded
//!   by a `// SAFETY:` comment, and the full inventory is mirrored in the
//!   committed `UNSAFE.md` (regenerate with `cargo run -p xtask --
//!   write-unsafe`).
//! * **`simd-symmetry` (L5)** — the scalar build stays a complete oracle:
//!   per file, `#[cfg(feature = "simd")]` and `#[cfg(not(feature =
//!   "simd"))]` counts match, and every `cfg!(feature = "simd")` is an
//!   `if` dispatch whose block is followed by scalar fallthrough code
//!   (or an `else`).
//! * **`serve-unwrap` (L6)** — no `.unwrap()` / `.expect(` on the serving
//!   path (`coordinator/` and `ssm/api.rs`) outside `#[cfg(test)]` code.
//!   The server's fault-containment story is that every failure becomes a
//!   typed `ServeError` answered to the caller; a stray unwrap would turn
//!   a recoverable condition into a worker-killing panic. Poison-tolerant
//!   lock recovery spells `.unwrap_or_else(|p| p.into_inner())`, which the
//!   lint deliberately does not match.
//!
//! Any line can be exempted with `// s5:allow(<lint>) <reason>` on the
//! offending line or the line directly above; the reason is mandatory.
//!
//! [`spawn_worker`]: ../s5/runtime/pool/fn.spawn_worker.html

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// The lint catalogue: `(name, what it enforces)`.
pub const LINTS: &[(&str, &str)] = &[
    ("pool-threading", "L1: thread spawn primitives only inside runtime/pool.rs"),
    ("env-registry", "L2: env reads only in runtime/envcfg.rs; S5_* knobs match the registry"),
    ("hot-alloc", "L3: no allocating calls inside // s5:hot-begin / // s5:hot-end fences"),
    ("unsafe-safety", "L4: every `unsafe` has a // SAFETY: comment; UNSAFE.md is in sync"),
    ("simd-symmetry", "L5: every simd feature gate has a scalar twin"),
    ("serve-unwrap", "L6: no .unwrap()/.expect( on the serving path outside #[cfg(test)]"),
];

/// One lint violation (or checker-internal error such as an unbalanced
/// fence) at a source location. Line numbers are 1-based.
#[derive(Debug, Clone)]
pub struct Finding {
    pub lint: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}:{}: {}", self.lint, self.file, self.line, self.msg)
    }
}

/// One `unsafe` occurrence, for the `UNSAFE.md` inventory.
#[derive(Debug, Clone)]
pub struct UnsafeSite {
    pub file: String,
    pub line: usize,
    /// The trimmed raw source line containing the `unsafe` token.
    pub text: String,
}

/// Everything one `run_check` pass produces.
#[derive(Debug, Default)]
pub struct CheckResult {
    pub findings: Vec<Finding>,
    pub unsafe_sites: Vec<UnsafeSite>,
    pub files_scanned: usize,
}

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

/// One lexed source line. `code` has the same char length as the raw line
/// with comments, string-literal contents and char-literal contents
/// blanked to spaces (string quotes are kept, so `format!("…")` still
/// shows `format!` and `("")` in code). `comment` is the concatenated
/// comment text on the line (used for fence / suppression / SAFETY
/// detection); `strings` holds the contents of string literals that start
/// or continue on the line (used for the `S5_*` registry scan and for
/// recognising the `"simd"` feature string).
#[derive(Debug, Default, Clone)]
struct Line {
    raw: String,
    code: String,
    comment: String,
    strings: Vec<String>,
}

#[derive(Clone, Copy)]
enum Mode {
    Code,
    /// Block comment, with nesting depth (Rust block comments nest).
    Block(u32),
    /// Inside a `"…"` (or `b"…"`) string literal.
    Str,
    /// Inside a raw string literal with this many `#`s.
    RawStr(usize),
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Is `chars[i]` the `r`/`b` opening a raw string literal (`r"`, `r#"`,
/// `br"`, …)? Requires a non-identifier char before it, so `for r in …`
/// and identifiers ending in `r` never match.
fn is_raw_string_start(chars: &[char], i: usize) -> bool {
    if i > 0 && is_ident_char(chars[i - 1]) {
        return false;
    }
    let mut j = i + 1;
    if chars[i] == 'b' {
        if chars.get(j) != Some(&'r') {
            return false;
        }
        j += 1;
    } else if chars[i] != 'r' {
        return false;
    }
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

/// For a raw-string opener at `i`, return `(hash_count, index past the
/// opening quote)`.
fn raw_string_open(chars: &[char], i: usize) -> (usize, usize) {
    let mut j = i + 1;
    if chars[i] == 'b' {
        j += 1;
    }
    let mut h = 0;
    while chars.get(j) == Some(&'#') {
        j += 1;
        h += 1;
    }
    (h, j + 1)
}

/// Lex a whole file into per-line `code` / `comment` / `strings` views.
/// Block comments, plain strings and raw strings may span lines; the
/// lexer state carries across.
fn lex(text: &str) -> Vec<Line> {
    let mut mode = Mode::Code;
    let mut out = Vec::new();
    for raw in text.lines() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = vec![' '; chars.len()];
        let mut comment = String::new();
        let mut strings = Vec::new();
        let mut cur = String::new();
        let mut i = 0;
        while i < chars.len() {
            mode = match mode {
                Mode::Code => {
                    let c = chars[i];
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        for &cc in &chars[i + 2..] {
                            comment.push(cc);
                        }
                        i = chars.len();
                        Mode::Code
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        i += 2;
                        Mode::Block(1)
                    } else if c == '"' {
                        code[i] = '"';
                        i += 1;
                        Mode::Str
                    } else if (c == 'r' || c == 'b') && is_raw_string_start(&chars, i) {
                        let (h, after) = raw_string_open(&chars, i);
                        code[i] = c;
                        i = after;
                        Mode::RawStr(h)
                    } else if c == 'b' && chars.get(i + 1) == Some(&'"') {
                        code[i] = 'b';
                        code[i + 1] = '"';
                        i += 2;
                        Mode::Str
                    } else if c == '\'' {
                        // Char literal vs lifetime: `'x'` / `'\n'` are
                        // literals (contents blanked); `'env` is a
                        // lifetime (left in code).
                        if chars.get(i + 1) == Some(&'\\') {
                            code[i] = '\'';
                            let mut j = i + 2;
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            if j < chars.len() {
                                code[j] = '\'';
                            }
                            i = j + 1;
                        } else if chars.get(i + 2) == Some(&'\'') {
                            code[i] = '\'';
                            code[i + 2] = '\'';
                            i += 3;
                        } else {
                            code[i] = '\'';
                            i += 1;
                        }
                        Mode::Code
                    } else {
                        code[i] = c;
                        i += 1;
                        Mode::Code
                    }
                }
                Mode::Block(depth) => {
                    if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        i += 2;
                        if depth == 1 {
                            Mode::Code
                        } else {
                            Mode::Block(depth - 1)
                        }
                    } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        i += 2;
                        Mode::Block(depth + 1)
                    } else {
                        comment.push(chars[i]);
                        i += 1;
                        Mode::Block(depth)
                    }
                }
                Mode::Str => {
                    if chars[i] == '\\' {
                        if let Some(&e) = chars.get(i + 1) {
                            cur.push(e);
                        }
                        i += 2;
                        Mode::Str
                    } else if chars[i] == '"' {
                        code[i] = '"';
                        i += 1;
                        strings.push(std::mem::take(&mut cur));
                        Mode::Code
                    } else {
                        cur.push(chars[i]);
                        i += 1;
                        Mode::Str
                    }
                }
                Mode::RawStr(h) => {
                    let closes = chars[i] == '"'
                        && chars[i + 1..].iter().take(h).filter(|&&c| c == '#').count() == h;
                    if closes {
                        code[i] = '"';
                        i += 1 + h;
                        strings.push(std::mem::take(&mut cur));
                        Mode::Code
                    } else {
                        cur.push(chars[i]);
                        i += 1;
                        Mode::RawStr(h)
                    }
                }
            };
        }
        // A literal spanning lines contributes its partial content to
        // each line it touches.
        if !cur.is_empty() {
            strings.push(std::mem::take(&mut cur));
        }
        out.push(Line {
            raw: raw.to_string(),
            code: code.into_iter().collect(),
            comment,
            strings,
        });
    }
    out
}

/// Does `code` contain `word` with non-identifier chars (or edges) on both
/// sides? (`unsafe_code` does not contain the word `unsafe`.)
fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let i = start + pos;
        let before_ok = i == 0 || {
            let c = bytes[i - 1] as char;
            !is_ident_char(c)
        };
        let j = i + word.len();
        let after_ok = j >= bytes.len() || {
            let c = bytes[j] as char;
            !is_ident_char(c)
        };
        if before_ok && after_ok {
            return true;
        }
        start = i + 1;
    }
    false
}

// ---------------------------------------------------------------------------
// Per-file annotations: suppressions and hot fences
// ---------------------------------------------------------------------------

/// `// s5:allow(<lint>) <reason>` markers; each covers its own line and
/// the next one (0-based line indices).
struct Suppressions(BTreeSet<(usize, String)>);

impl Suppressions {
    fn collect(rel: &str, lines: &[Line], findings: &mut Vec<Finding>) -> Suppressions {
        let mut set = BTreeSet::new();
        for (n, line) in lines.iter().enumerate() {
            let mut rest = line.comment.as_str();
            while let Some(pos) = rest.find("s5:allow(") {
                let after = &rest[pos + "s5:allow(".len()..];
                let Some(close) = after.find(')') else {
                    findings.push(Finding {
                        lint: "suppression",
                        file: rel.to_string(),
                        line: n + 1,
                        msg: "malformed s5:allow — missing `)`".to_string(),
                    });
                    break;
                };
                let name = after[..close].trim().to_string();
                let reason = after[close + 1..].trim();
                if name.is_empty() || reason.is_empty() {
                    findings.push(Finding {
                        lint: "suppression",
                        file: rel.to_string(),
                        line: n + 1,
                        msg: "s5:allow(<lint>) needs a lint name and a non-empty reason"
                            .to_string(),
                    });
                } else {
                    set.insert((n, name.clone()));
                    set.insert((n + 1, name));
                }
                rest = &after[close + 1..];
            }
        }
        Suppressions(set)
    }

    fn allows(&self, n: usize, lint: &str) -> bool {
        self.0.contains(&(n, lint.to_string()))
    }
}

/// `// s5:hot-begin` / `// s5:hot-end` fence ranges (0-based, inclusive of
/// the marker lines — the markers themselves carry no code).
fn fence_ranges(rel: &str, lines: &[Line], findings: &mut Vec<Finding>) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut open: Option<usize> = None;
    for (n, line) in lines.iter().enumerate() {
        if line.comment.contains("s5:hot-begin") {
            if open.is_some() {
                findings.push(Finding {
                    lint: "fence",
                    file: rel.to_string(),
                    line: n + 1,
                    msg: "nested s5:hot-begin (fences do not nest)".to_string(),
                });
            } else {
                open = Some(n);
            }
        }
        if line.comment.contains("s5:hot-end") {
            match open.take() {
                Some(s) => ranges.push((s, n)),
                None => findings.push(Finding {
                    lint: "fence",
                    file: rel.to_string(),
                    line: n + 1,
                    msg: "s5:hot-end without a matching s5:hot-begin".to_string(),
                }),
            }
        }
    }
    if let Some(s) = open {
        findings.push(Finding {
            lint: "fence",
            file: rel.to_string(),
            line: s + 1,
            msg: "unclosed s5:hot-begin".to_string(),
        });
    }
    ranges
}

// ---------------------------------------------------------------------------
// Lints
// ---------------------------------------------------------------------------

const THREAD_PATS: &[&str] = &["thread::spawn", "thread::scope", "thread::Builder"];

fn lint_threads(rel: &str, lines: &[Line], sup: &Suppressions, findings: &mut Vec<Finding>) {
    if rel.ends_with("runtime/pool.rs") {
        return;
    }
    for (n, line) in lines.iter().enumerate() {
        for pat in THREAD_PATS {
            if line.code.contains(pat) && !sup.allows(n, "pool-threading") {
                findings.push(Finding {
                    lint: "pool-threading",
                    file: rel.to_string(),
                    line: n + 1,
                    msg: format!(
                        "`{pat}` outside runtime/pool.rs — use runtime::pool \
                         (spawn_worker / Executor)"
                    ),
                });
            }
        }
    }
}

const ENV_PATS: &[&str] = &["env::var", "env::set_var", "env::remove_var"];

fn lint_env_reads(rel: &str, lines: &[Line], sup: &Suppressions, findings: &mut Vec<Finding>) {
    if rel.ends_with("runtime/envcfg.rs") {
        return;
    }
    for (n, line) in lines.iter().enumerate() {
        for pat in ENV_PATS {
            if line.code.contains(pat) && !sup.allows(n, "env-registry") {
                findings.push(Finding {
                    lint: "env-registry",
                    file: rel.to_string(),
                    line: n + 1,
                    msg: format!(
                        "`{pat}` outside runtime/envcfg.rs — use the envcfg accessors \
                         (env_usize_once / env_flag_once / is_set)"
                    ),
                });
            }
        }
    }
}

/// Allocating calls banned inside hot fences. Substring matches against
/// lexed code, so comments and strings never trip it; `.clone(` does not
/// match `.cloned(`.
const HOT_BANNED: &[&str] = &[
    "Vec::new",
    "vec!",
    "Box::new",
    "Arc::new",
    "Rc::new",
    "String::new",
    "format!",
    ".push(",
    ".collect",
    ".to_vec",
    ".clone(",
    ".to_string",
    ".to_owned",
    ".reserve(",
    ".resize(",
    ".extend(",
    "with_capacity",
];

fn lint_hot_alloc(
    rel: &str,
    lines: &[Line],
    fences: &[(usize, usize)],
    sup: &Suppressions,
    findings: &mut Vec<Finding>,
) {
    for &(s, e) in fences {
        for (n, line) in lines.iter().enumerate().take(e + 1).skip(s) {
            for pat in HOT_BANNED {
                if line.code.contains(pat) && !sup.allows(n, "hot-alloc") {
                    findings.push(Finding {
                        lint: "hot-alloc",
                        file: rel.to_string(),
                        line: n + 1,
                        msg: format!("allocating call `{pat}` inside an s5:hot fence"),
                    });
                }
            }
        }
    }
}

fn lint_unsafe(
    rel: &str,
    lines: &[Line],
    sup: &Suppressions,
    findings: &mut Vec<Finding>,
    sites: &mut Vec<UnsafeSite>,
) {
    for (n, line) in lines.iter().enumerate() {
        if !has_word(&line.code, "unsafe") {
            continue;
        }
        sites.push(UnsafeSite {
            file: rel.to_string(),
            line: n + 1,
            text: line.raw.trim().to_string(),
        });
        // The contiguous comment block directly above (no blank lines in
        // between) — or a trailing comment on the line itself — must say
        // SAFETY:.
        let mut ok = line.comment.contains("SAFETY:");
        let mut k = n;
        while !ok && k > 0 {
            k -= 1;
            let above = &lines[k];
            if !above.code.trim().is_empty() || above.raw.trim().is_empty() {
                break;
            }
            ok = above.comment.contains("SAFETY:");
        }
        if !ok && !sup.allows(n, "unsafe-safety") {
            findings.push(Finding {
                lint: "unsafe-safety",
                file: rel.to_string(),
                line: n + 1,
                msg: "`unsafe` without a `// SAFETY:` comment directly above".to_string(),
            });
        }
    }
}

/// Find the line index of the `}` closing the block whose `{` is the
/// first open brace at/after char `from` on line `n`, plus what (if
/// anything) follows that `}` on its own line.
fn block_close(lines: &[Line], n: usize, from: usize) -> Option<(usize, String)> {
    let mut depth = 0usize;
    let mut seen_open = false;
    for (k, line) in lines.iter().enumerate().skip(n) {
        let code = &line.code;
        let start = if k == n { from.min(code.len()) } else { 0 };
        for (ci, c) in code.char_indices() {
            if ci < start {
                continue;
            }
            if c == '{' {
                depth += 1;
                seen_open = true;
            } else if c == '}' {
                depth = depth.saturating_sub(1);
                if seen_open && depth == 0 {
                    let rest: String = code[ci + c.len_utf8()..].trim().to_string();
                    return Some((k, rest));
                }
            }
        }
    }
    None
}

fn lint_simd_symmetry(rel: &str, lines: &[Line], sup: &Suppressions, findings: &mut Vec<Finding>) {
    let simd_str = |l: &Line| l.strings.iter().any(|s| s == "simd");
    // (a) attribute gates: every #[cfg(feature = "simd")] item needs a
    // #[cfg(not(feature = "simd"))] scalar twin in the same file.
    let pos = lines
        .iter()
        .filter(|l| l.code.contains("#[cfg(feature =") && simd_str(l))
        .count();
    let neg = lines
        .iter()
        .filter(|l| l.code.contains("#[cfg(not(feature =") && simd_str(l))
        .count();
    if pos != neg {
        findings.push(Finding {
            lint: "simd-symmetry",
            file: rel.to_string(),
            line: 1,
            msg: format!(
                "feature-gate asymmetry: {pos} #[cfg(feature = \"simd\")] vs {neg} \
                 #[cfg(not(feature = \"simd\"))] — every gated item needs a scalar twin"
            ),
        });
    }
    // (b) expression gates: cfg!(feature = "simd") must be an `if`
    // dispatch whose block is followed by the scalar path (code or an
    // `else` branch) — a bare block at the end of a function means the
    // scalar build silently does nothing.
    for (n, line) in lines.iter().enumerate() {
        let Some(idx) = line.code.find("cfg!(feature =") else {
            continue;
        };
        if !line.strings.iter().any(|s| s == "simd") || sup.allows(n, "simd-symmetry") {
            continue;
        }
        let before = line.code[..idx].trim_end();
        if !(before.ends_with("if") || before.ends_with("if !")) {
            findings.push(Finding {
                lint: "simd-symmetry",
                file: rel.to_string(),
                line: n + 1,
                msg: "cfg!(feature = \"simd\") must be an `if` dispatch with a scalar twin"
                    .to_string(),
            });
            continue;
        }
        let Some((close, rest)) = block_close(lines, n, idx) else {
            findings.push(Finding {
                lint: "simd-symmetry",
                file: rel.to_string(),
                line: n + 1,
                msg: "unclosed cfg!(feature = \"simd\") dispatch block".to_string(),
            });
            continue;
        };
        // `} else {` (or trailing code) on the closing line counts as the
        // scalar continuation; otherwise the next code line must exist
        // and not immediately close the enclosing item.
        let mut scalar_follows = !rest.is_empty();
        let mut k = close + 1;
        while !scalar_follows && k < lines.len() {
            let t = lines[k].code.trim();
            if !t.is_empty() {
                scalar_follows = t != "}";
                break;
            }
            k += 1;
        }
        if !scalar_follows {
            findings.push(Finding {
                lint: "simd-symmetry",
                file: rel.to_string(),
                line: n + 1,
                msg: "simd dispatch block has no scalar fallthrough after it".to_string(),
            });
        }
    }
}

/// Panicking shortcut calls banned on the serving path. `.expect(` also
/// catches `.expect_err(` — both panic, both are banned there. The
/// poison-recovery idiom `.unwrap_or_else(|p| p.into_inner())` matches
/// neither pattern, by design.
const SERVE_UNWRAP_PATS: &[&str] = &[".unwrap()", ".expect("];

/// Files subject to L6: the request path from admission to model call.
fn serving_path(rel: &str) -> bool {
    rel.contains("/coordinator/") || rel.ends_with("ssm/api.rs")
}

/// 0-based inclusive line ranges gated behind `#[cfg(test)]`: the
/// attribute line through the closing brace of the first block that
/// follows it (the `mod tests { … }` body in practice). An attribute with
/// no following block (e.g. on a lone `use`) conservatively extends to
/// end of file — serving sources keep all test code in a trailing module,
/// so that approximation never hides production lines in this repo.
fn cfg_test_ranges(lines: &[Line]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for (n, line) in lines.iter().enumerate() {
        let Some(idx) = line.code.find("#[cfg(test)]") else {
            continue;
        };
        let end = match block_close(lines, n, idx) {
            Some((close, _)) => close,
            None => lines.len().saturating_sub(1),
        };
        out.push((n, end));
    }
    out
}

fn lint_serve_unwrap(rel: &str, lines: &[Line], sup: &Suppressions, findings: &mut Vec<Finding>) {
    if !serving_path(rel) {
        return;
    }
    let test_ranges = cfg_test_ranges(lines);
    for (n, line) in lines.iter().enumerate() {
        if test_ranges.iter().any(|&(b, e)| n >= b && n <= e) {
            continue;
        }
        for pat in SERVE_UNWRAP_PATS {
            if line.code.contains(pat) && !sup.allows(n, "serve-unwrap") {
                findings.push(Finding {
                    lint: "serve-unwrap",
                    file: rel.to_string(),
                    line: n + 1,
                    msg: format!(
                        "`{pat}` on the serving path — answer a typed ServeError (or recover \
                         explicitly) instead of panicking in the worker"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// S5_* knob registry cross-check
// ---------------------------------------------------------------------------

/// Extract `S5_<NAME>` knob names from a string-literal body.
fn knob_names(s: &str, out: &mut BTreeSet<String>) {
    let bytes = s.as_bytes();
    let mut start = 0;
    while let Some(pos) = s[start..].find("S5_") {
        let i = start + pos;
        let mut j = i + 3;
        while j < bytes.len() && (bytes[j].is_ascii_uppercase() || bytes[j].is_ascii_digit() || bytes[j] == b'_') {
            j += 1;
        }
        if j > i + 3 {
            out.insert(s[i..j].to_string());
        }
        start = j.max(i + 1);
    }
}

/// The registry table range in envcfg.rs (0-based, inclusive), if the
/// markers are present.
fn registry_range(lines: &[Line]) -> Option<(usize, usize)> {
    let begin = lines.iter().position(|l| l.comment.contains("s5:env-registry-begin"))?;
    let end = lines.iter().position(|l| l.comment.contains("s5:env-registry-end"))?;
    (begin < end).then_some((begin, end))
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

fn rs_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let Ok(rd) = fs::read_dir(&dir) else { continue };
        for entry in rd.flatten() {
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

fn rel_name(root: &Path, prefix: &str, path: &Path) -> String {
    let tail = path.strip_prefix(root).unwrap_or(path);
    let tail = tail.to_string_lossy().replace('\\', "/");
    if prefix.is_empty() {
        tail
    } else {
        format!("{prefix}/{tail}")
    }
}

/// Run every lint over the `.rs` files under `src_dir` (displayed with
/// `src_prefix`, e.g. `rust/src`). `usage_dirs` are scanned only for
/// `S5_*` knob strings (benches and tests read registered knobs without
/// being subject to the source lints).
pub fn run_check(src_dir: &Path, src_prefix: &str, usage_dirs: &[&Path]) -> CheckResult {
    let mut res = CheckResult::default();
    // knob name -> first place it appears in a string literal
    let mut used: BTreeMap<String, (String, usize)> = BTreeMap::new();
    // registered knob name -> registry line (in envcfg.rs)
    let mut registered: BTreeMap<String, usize> = BTreeMap::new();
    let mut envcfg_rel = String::new();

    for path in rs_files(src_dir) {
        let rel = rel_name(src_dir, src_prefix, &path);
        let Ok(text) = fs::read_to_string(&path) else { continue };
        let lines = lex(&text);
        res.files_scanned += 1;

        let sup = Suppressions::collect(&rel, &lines, &mut res.findings);
        let fences = fence_ranges(&rel, &lines, &mut res.findings);
        lint_threads(&rel, &lines, &sup, &mut res.findings);
        lint_env_reads(&rel, &lines, &sup, &mut res.findings);
        lint_hot_alloc(&rel, &lines, &fences, &sup, &mut res.findings);
        lint_unsafe(&rel, &lines, &sup, &mut res.findings, &mut res.unsafe_sites);
        lint_simd_symmetry(&rel, &lines, &sup, &mut res.findings);
        lint_serve_unwrap(&rel, &lines, &sup, &mut res.findings);

        // Registry table + knob usage. The registry lines themselves are
        // excluded from the usage scan (they would trivially satisfy it).
        let reg = if rel.ends_with("runtime/envcfg.rs") {
            envcfg_rel = rel.clone();
            registry_range(&lines)
        } else {
            None
        };
        if let Some((b, e)) = reg {
            for (n, line) in lines.iter().enumerate().take(e + 1).skip(b) {
                let mut names = BTreeSet::new();
                for s in &line.strings {
                    knob_names(s, &mut names);
                }
                for name in names {
                    registered.entry(name).or_insert(n + 1);
                }
            }
        }
        for (n, line) in lines.iter().enumerate() {
            if let Some((b, e)) = reg {
                if n >= b && n <= e {
                    continue;
                }
            }
            let mut names = BTreeSet::new();
            for s in &line.strings {
                knob_names(s, &mut names);
            }
            for name in names {
                used.entry(name).or_insert_with(|| (rel.clone(), n + 1));
            }
        }
    }

    for dir in usage_dirs {
        let prefix = dir
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        for path in rs_files(dir) {
            let rel = rel_name(dir, &prefix, &path);
            let Ok(text) = fs::read_to_string(&path) else { continue };
            for (n, line) in lex(&text).iter().enumerate() {
                let mut names = BTreeSet::new();
                for s in &line.strings {
                    knob_names(s, &mut names);
                }
                for name in names {
                    used.entry(name).or_insert_with(|| (rel.clone(), n + 1));
                }
            }
        }
    }

    // Cross-check (only when a registry table exists — fixture trees
    // without an envcfg.rs still get the location lint above).
    if !registered.is_empty() {
        for (name, (file, line)) in &used {
            if !registered.contains_key(name) {
                res.findings.push(Finding {
                    lint: "env-registry",
                    file: file.clone(),
                    line: *line,
                    msg: format!(
                        "knob `{name}` is not listed in the envcfg registry table \
                         (// s5:env-registry-begin)"
                    ),
                });
            }
        }
        for (name, line) in &registered {
            if !used.contains_key(name) {
                res.findings.push(Finding {
                    lint: "env-registry",
                    file: envcfg_rel.clone(),
                    line: *line,
                    msg: format!("registry entry `{name}` is referenced nowhere — stale?"),
                });
            }
        }
    }

    res
}

/// Render the committed `UNSAFE.md` inventory. Deliberately line-number
/// free so unrelated edits above an `unsafe` site do not churn the file.
pub fn render_unsafe_md(sites: &[UnsafeSite]) -> String {
    let mut out = String::new();
    out.push_str("# Unsafe inventory\n\n");
    out.push_str(
        "Generated by `cargo run -p xtask -- write-unsafe`; checked for staleness\n\
         by `cargo run -p xtask -- check` (lint L4, `unsafe-safety`). Every\n\
         `unsafe` in `rust/src` must be directly preceded by a `// SAFETY:`\n\
         comment explaining why the invariants hold.\n\n",
    );
    if sites.is_empty() {
        out.push_str("No `unsafe` code.\n");
        return out;
    }
    let mut files: Vec<&str> = Vec::new();
    for s in sites {
        if files.last() != Some(&s.file.as_str()) {
            files.push(&s.file);
        }
    }
    out.push_str(&format!(
        "Total: {} occurrences across {} files.\n",
        sites.len(),
        files.len()
    ));
    for f in files {
        out.push_str(&format!("\n## {f}\n\n"));
        for s in sites.iter().filter(|s| s.file == f) {
            out.push_str(&format!("- `{}`\n", s.text));
        }
    }
    out
}

/// The real-repo invocation shared by the `xtask` binary and the
/// self-test: lints `rust/src`, scans `rust/benches`, `rust/tests` and
/// `examples/` for knob usage. Returns the result and the repo root
/// (where `UNSAFE.md` lives).
pub fn check_repo(xtask_manifest_dir: &Path) -> (CheckResult, PathBuf) {
    let rust_dir = xtask_manifest_dir.parent().expect("xtask sits in rust/");
    let repo = rust_dir.parent().expect("rust/ sits in the repo root").to_path_buf();
    let src = rust_dir.join("src");
    let benches = rust_dir.join("benches");
    let tests = rust_dir.join("tests");
    let examples = repo.join("examples");
    let usage = [benches.as_path(), tests.as_path(), examples.as_path()];
    let res = run_check(&src, "rust/src", &usage);
    (res, repo)
}
