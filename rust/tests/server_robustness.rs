//! Fault-containment acceptance tests: the serving stack under injected
//! faults, driven by the deterministic `s5::testing::fault` harness.
//!
//! The headline proof: with a [`FaultPlan`] that panics at exactly batch
//! #k, under many concurrent clients, *exactly* the requests in that
//! batch are answered [`ServeError::ModelPanic`]; every other response is
//! **bit-for-bit** identical to a no-fault serial replay of the inner
//! model; and the worker survives in place (same pool, no respawn,
//! service continues). The server shape keeps L = 7 with threads = 4, so
//! the scan is sequential in every sharding branch and numerics cannot
//! depend on batch composition (see `tests/pool_stress.rs`).
//!
//! The rest of the file pins the other containment surfaces: bounded
//! admission (load-shedding in bounded time), request deadlines (both
//! dequeue-side drop-before-execute and the client-side clock), graceful
//! drain on shutdown/drop, session-pool reuse after a mid-stream panic
//! (f32 and bf16), idle-TTL eviction, and admission-time input
//! validation.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use s5::coordinator::server::{NativeInferenceServer, ServeError, ServerConfig};
use s5::rng::Rng;
use s5::runtime::pool::global_pool;
use s5::ssm::api::{Batch, ForwardOptions, SequenceModel, Session, SessionPool};
use s5::ssm::dtype::Dtype;
use s5::ssm::engine::EngineWorkspace;
use s5::ssm::s5::{S5Config, S5Model};
use s5::testing::fault::{FaultPlan, FaultyModel};

/// L = 7 with threads = 4 keeps every scan sequential (7 < 4·(T/B) for
/// all batch shardings), so responses are replayable as batch-of-1
/// serial prefills, bit-for-bit.
const L: usize = 7;
const D_IN: usize = 2;

fn model(seed: u64, depth: usize) -> S5Model {
    let cfg = S5Config { h: 16, p: 16, j: 1, ..Default::default() };
    S5Model::init(D_IN, 5, depth, &cfg, &mut Rng::new(seed))
}

fn assert_bits_equal(want: &[f32], got: &[f32], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length mismatch");
    for (i, (x, y)) in want.iter().zip(got.iter()).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

fn serve_cfg(max_batch: usize, max_wait: Duration) -> ServerConfig {
    ServerConfig { max_wait, max_batch, threads: 4, ..ServerConfig::default() }
}

/// The acceptance proof: a model that panics at exactly batch #5, under 8
/// concurrent clients × 4 requests. With `max_batch = 1` every request is
/// its own batch, so exactly one request must be answered `ModelPanic`;
/// all 31 others must match a no-fault serial replay bit-for-bit; the
/// worker survives (no pool respawn) and keeps serving.
#[test]
fn injected_panic_poisons_exactly_its_own_batch() {
    let inner: Arc<dyn SequenceModel> = Arc::new(model(42, 2));
    let faulty = Arc::new(FaultyModel::new(inner, FaultPlan::panic_at_prefill(5)));
    let server = NativeInferenceServer::start_model(
        faulty.clone() as Arc<dyn SequenceModel>,
        L,
        serve_cfg(1, Duration::ZERO),
    );
    let handle = server.handle();
    let pool_workers = global_pool().live_workers();

    let mut records: Vec<(Vec<f32>, Result<Vec<f32>, ServeError>)> = Vec::new();
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..8u64)
            .map(|tid| {
                let h = handle.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(4200 + tid);
                    let mut out = Vec::new();
                    for _ in 0..4 {
                        let x = rng.normal_vec_f32(L * D_IN);
                        let r = h.infer(x.clone()).map(|resp| resp.logits);
                        out.push((x, r));
                    }
                    out
                })
            })
            .collect();
        for j in joins {
            records.extend(j.join().expect("client thread"));
        }
    });
    assert_eq!(records.len(), 32);

    // exactly the poisoned batch's requests fail, with the injected
    // panic's message carried through to the caller
    let errs: Vec<&ServeError> = records.iter().filter_map(|(_, r)| r.as_ref().err()).collect();
    assert_eq!(errs.len(), 1, "exactly one request rides batch #5: {errs:?}");
    match errs[0] {
        ServeError::ModelPanic(msg) => {
            assert!(msg.contains("injected fault: prefill #5"), "{msg}")
        }
        other => panic!("expected ModelPanic, got {other:?}"),
    }
    assert_eq!(server.stats.panicked.load(Ordering::Relaxed), 1);
    assert_eq!(server.stats.requests.load(Ordering::Relaxed), 32);
    assert_eq!(server.stats.batches.load(Ordering::Relaxed), 32);
    assert_eq!(server.stats.shed.load(Ordering::Relaxed), 0);

    // every surviving response is bit-for-bit the no-fault serial replay
    // of the inner model — batches before AND after the poisoned one
    let m = model(42, 2);
    let opts = ForwardOptions::new().with_threads(4);
    let mut ws = EngineWorkspace::new();
    let mut survivors = 0;
    for (i, (x, r)) in records.iter().enumerate() {
        if let Ok(got) = r {
            let want = m.prefill(Batch::single(x, L, D_IN), &opts, &mut ws);
            assert_bits_equal(&want, got, &format!("record {i}"));
            survivors += 1;
        }
    }
    assert_eq!(survivors, 31);

    // the worker survived in place: the process-wide pool lost nobody,
    // and the same server keeps serving correct answers
    assert_eq!(global_pool().live_workers(), pool_workers, "a pool worker died");
    let x = Rng::new(7).normal_vec_f32(L * D_IN);
    let resp = handle.infer(x.clone()).expect("server must serve after the panic");
    let want = m.prefill(Batch::single(&x, L, D_IN), &opts, &mut ws);
    assert_bits_equal(&want, &resp.logits, "post-panic request");
    assert_eq!(faulty.prefills(), 33, "32 storm batches + 1 follow-up");
}

/// With coalescing enabled, a poisoned batch can hold several requests:
/// every member gets `ModelPanic` (the `panicked` counter equals the
/// error count observed by clients), and requests that missed the batch
/// still replay bit-exact.
#[test]
fn a_poisoned_multi_request_batch_answers_every_member() {
    let inner: Arc<dyn SequenceModel> = Arc::new(model(11, 2));
    let faulty = Arc::new(FaultyModel::new(inner, FaultPlan::panic_at_prefill(0)));
    let server = NativeInferenceServer::start_model(
        faulty as Arc<dyn SequenceModel>,
        L,
        serve_cfg(8, Duration::from_millis(200)),
    );
    let handle = server.handle();

    let mut records: Vec<(Vec<f32>, Result<Vec<f32>, ServeError>)> = Vec::new();
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..6u64)
            .map(|tid| {
                let h = handle.clone();
                s.spawn(move || {
                    let x = Rng::new(1100 + tid).normal_vec_f32(L * D_IN);
                    let r = h.infer(x.clone()).map(|resp| resp.logits);
                    (x, r)
                })
            })
            .collect();
        for j in joins {
            records.push(j.join().expect("client thread"));
        }
    });

    let errs: Vec<&ServeError> = records.iter().filter_map(|(_, r)| r.as_ref().err()).collect();
    assert!(!errs.is_empty(), "batch #0 held at least its first request");
    assert!(
        errs.iter().all(|e| matches!(e, ServeError::ModelPanic(m) if m.contains("prefill #0"))),
        "{errs:?}"
    );
    // no member of the poisoned batch is silently dropped: the panicked
    // counter is exactly the ModelPanic count clients observed
    assert_eq!(server.stats.panicked.load(Ordering::Relaxed), errs.len() as u64);

    let m = model(11, 2);
    let opts = ForwardOptions::new().with_threads(4);
    let mut ws = EngineWorkspace::new();
    for (i, (x, r)) in records.iter().enumerate() {
        if let Ok(got) = r {
            let want = m.prefill(Batch::single(x, L, D_IN), &opts, &mut ws);
            assert_bits_equal(&want, got, &format!("survivor {i}"));
        }
    }
}

/// A full admission queue sheds immediately with a typed `QueueFull` —
/// the caller is told in bounded time (well under the in-flight batch's
/// execution time), not made to wait.
#[test]
fn a_full_queue_sheds_immediately_with_a_typed_error() {
    let inner: Arc<dyn SequenceModel> = Arc::new(model(5, 1));
    let slow = Arc::new(FaultyModel::new(
        inner,
        FaultPlan::none().with_prefill_delay(Duration::from_millis(300)),
    ));
    let cfg = ServerConfig {
        max_wait: Duration::ZERO,
        max_batch: 1,
        threads: 2,
        queue_cap: 1,
        ..ServerConfig::default()
    };
    let server = NativeInferenceServer::start_model(slow as Arc<dyn SequenceModel>, L, cfg);
    let handle = server.handle();

    std::thread::scope(|s| {
        let ha = handle.clone();
        let a = s.spawn(move || ha.infer(vec![0.5; L * D_IN]));
        // let the worker dequeue A (it then sleeps 300ms inside prefill)
        std::thread::sleep(Duration::from_millis(60));
        let hb = handle.clone();
        let b = s.spawn(move || hb.infer(vec![0.25; L * D_IN]));
        // B now occupies the single queue slot
        std::thread::sleep(Duration::from_millis(60));
        let t0 = Instant::now();
        let c = handle.infer(vec![0.75; L * D_IN]);
        let waited = t0.elapsed();
        assert!(matches!(c, Err(ServeError::QueueFull { cap: 1 })), "{c:?}");
        assert!(waited < Duration::from_millis(200), "shed took {waited:?}");
        assert!(a.join().expect("client A").is_ok());
        assert!(b.join().expect("client B").is_ok());
    });
    assert_eq!(server.stats.shed.load(Ordering::Relaxed), 1);
    assert_eq!(server.stats.requests.load(Ordering::Relaxed), 2, "shed request never executed");
}

/// A request whose server-default deadline passed while queued is dropped
/// at dequeue — the model never sees it (drop-before-execute).
#[test]
fn queued_requests_past_the_default_deadline_expire_without_executing() {
    let inner: Arc<dyn SequenceModel> = Arc::new(model(6, 1));
    let slow = Arc::new(FaultyModel::new(
        inner,
        FaultPlan::none().with_prefill_delay(Duration::from_millis(250)),
    ));
    let cfg = ServerConfig {
        max_wait: Duration::ZERO,
        max_batch: 1,
        threads: 2,
        deadline: Some(Duration::from_millis(50)),
        ..ServerConfig::default()
    };
    let server =
        NativeInferenceServer::start_model(slow.clone() as Arc<dyn SequenceModel>, L, cfg);
    let handle = server.handle();

    std::thread::scope(|s| {
        let ha = handle.clone();
        let a = s.spawn(move || ha.infer(vec![0.1; L * D_IN]));
        // A is dequeued fresh (within budget) and executes for 250ms
        std::thread::sleep(Duration::from_millis(40));
        let hb = handle.clone();
        let b = s.spawn(move || hb.infer(vec![0.2; L * D_IN]));
        let a = a.join().expect("client A");
        let b = b.join().expect("client B");
        assert!(a.is_ok(), "{a:?}");
        assert!(
            matches!(b, Err(ServeError::DeadlineExceeded { budget })
                if budget == Duration::from_millis(50)),
            "{b:?}"
        );
    });
    assert_eq!(server.stats.expired.load(Ordering::Relaxed), 1);
    assert_eq!(slow.prefills(), 1, "the expired request never reached the model");
}

/// An explicit per-request deadline bounds the *caller's* wait on its own
/// clock, even while the worker is wedged inside a slow forward.
#[test]
fn an_explicit_deadline_bounds_the_client_wait_against_a_wedged_worker() {
    let inner: Arc<dyn SequenceModel> = Arc::new(model(9, 1));
    let slow = Arc::new(FaultyModel::new(
        inner,
        FaultPlan::none().with_prefill_delay(Duration::from_millis(400)),
    ));
    let server = NativeInferenceServer::start_model(
        slow as Arc<dyn SequenceModel>,
        L,
        serve_cfg(1, Duration::ZERO),
    );
    let handle = server.handle();

    let t0 = Instant::now();
    let r = handle.infer_deadline(vec![0.3; L * D_IN], 1.0, Duration::from_millis(50));
    let waited = t0.elapsed();
    assert!(
        matches!(r, Err(ServeError::DeadlineExceeded { budget })
            if budget == Duration::from_millis(50)),
        "{r:?}"
    );
    assert!(waited >= Duration::from_millis(50), "gave up before the budget: {waited:?}");
    assert!(waited < Duration::from_millis(300), "client hung past its deadline: {waited:?}");
    // dropping the server now joins a worker that is mid-forward: the
    // drain must still complete (bounded by one batch execution)
}

/// `shutdown()` drains: the in-flight batch finishes normally, queued
/// requests are answered `ShuttingDown` (never executed), and admission
/// stays closed afterwards. A second call is a no-op.
#[test]
fn shutdown_finishes_in_flight_work_and_answers_the_queue() {
    let inner: Arc<dyn SequenceModel> = Arc::new(model(13, 1));
    let slow = Arc::new(FaultyModel::new(
        inner,
        FaultPlan::none().with_prefill_delay(Duration::from_millis(200)),
    ));
    let cfg = ServerConfig {
        max_wait: Duration::ZERO,
        max_batch: 1,
        threads: 2,
        queue_cap: 8,
        ..ServerConfig::default()
    };
    let mut server =
        NativeInferenceServer::start_model(slow.clone() as Arc<dyn SequenceModel>, L, cfg);
    let handle = server.handle();

    std::thread::scope(|s| {
        let ha = handle.clone();
        let a = s.spawn(move || ha.infer(vec![0.1; L * D_IN]));
        std::thread::sleep(Duration::from_millis(50)); // A is executing
        let hb = handle.clone();
        let b = s.spawn(move || hb.infer(vec![0.2; L * D_IN]));
        let hc = handle.clone();
        let c = s.spawn(move || hc.infer(vec![0.3; L * D_IN]));
        std::thread::sleep(Duration::from_millis(50)); // B and C are queued
        server.shutdown();
        assert!(a.join().expect("client A").is_ok(), "in-flight batch finishes");
        assert!(matches!(b.join().expect("client B"), Err(ServeError::ShuttingDown)));
        assert!(matches!(c.join().expect("client C"), Err(ServeError::ShuttingDown)));
    });
    assert!(matches!(handle.infer(vec![0.4; L * D_IN]), Err(ServeError::ShuttingDown)));
    assert_eq!(server.stats.queue_depth(), 0, "drain left the depth gauge dirty");
    assert_eq!(slow.prefills(), 1, "queued requests were never executed");
    server.shutdown(); // idempotent
}

/// Dropping a server under sustained load from 8 client threads routes
/// through the same drain: every client ends on a typed `ShuttingDown`
/// (never a hang, never a channel panic), and the queue gauge is empty.
#[test]
fn dropping_a_loaded_server_drains_cleanly() {
    let inner: Arc<dyn SequenceModel> = Arc::new(model(21, 1));
    let slow = Arc::new(FaultyModel::new(
        inner,
        FaultPlan::none().with_prefill_delay(Duration::from_millis(5)),
    ));
    let cfg = ServerConfig {
        max_wait: Duration::ZERO,
        max_batch: 4,
        threads: 2,
        queue_cap: 4,
        ..ServerConfig::default()
    };
    let server = NativeInferenceServer::start_model(slow as Arc<dyn SequenceModel>, L, cfg);
    let handle = server.handle();
    let stats = server.stats.clone();

    std::thread::scope(|s| {
        let joins: Vec<_> = (0..8u64)
            .map(|tid| {
                let h = handle.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(900 + tid);
                    let mut served = 0u64;
                    loop {
                        match h.infer(rng.normal_vec_f32(L * D_IN)) {
                            Ok(_) => served += 1,
                            Err(ServeError::QueueFull { .. }) => {} // expected under load
                            Err(ServeError::ShuttingDown) => return served,
                            Err(e) => panic!("unexpected error under load: {e:?}"),
                        }
                    }
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(80));
        drop(server);
        for j in joins {
            let _served = j.join().expect("client thread ended on ShuttingDown");
        }
    });
    assert_eq!(stats.queue_depth(), 0, "drain left requests in the gauge");
    assert!(stats.requests.load(Ordering::Relaxed) > 0, "no work happened before the drop");
}

/// A pooled session whose stream panicked mid-step (with the state dirty
/// *beyond* the last observed output) is recycled clean: the next
/// `acquire` streams bit-for-bit like a fresh session over the bare
/// inner model. Covered at both storage dtypes.
fn session_reuse_after_step_panic(dtype: Option<Dtype>) {
    let inner: Arc<dyn SequenceModel> = Arc::new(model(33, 2));
    let faulty = Arc::new(FaultyModel::new(inner.clone(), FaultPlan::panic_at_step(3)));
    let mut opts = ForwardOptions::new().with_threads(1);
    if let Some(d) = dtype {
        opts = opts.with_dtype(d);
    }
    let pool = SessionPool::new(faulty as Arc<dyn SequenceModel>, opts.clone());

    let mut rng = Rng::new(5150);
    let mut sess = pool.acquire();
    for _ in 0..3 {
        let u = rng.normal_vec_f32(D_IN);
        let _ = sess.step(&u); // steps #0..#2 are clean
    }
    let u = rng.normal_vec_f32(D_IN);
    // step #3 panics *after* the inner state update — the adversarial
    // dirty-state case
    let blown = catch_unwind(AssertUnwindSafe(|| sess.step(&u)));
    assert!(blown.is_err(), "step #3 must panic");
    pool.release(sess);
    assert_eq!(pool.idle(), 1);

    let mut recycled = pool.acquire();
    assert_eq!(pool.idle(), 0, "acquire reuses the pooled state");
    let mut fresh = Session::new(inner, opts);
    for i in 0..5 {
        let u = rng.normal_vec_f32(D_IN);
        let want = fresh.step(&u);
        let got = recycled.step(&u);
        assert_bits_equal(&want, &got, &format!("recycled step {i} (dtype {dtype:?})"));
    }
    pool.release(recycled);
}

#[test]
fn a_recycled_session_never_leaks_state_after_a_panic_f32() {
    session_reuse_after_step_panic(None);
}

#[test]
fn a_recycled_session_never_leaks_state_after_a_panic_bf16() {
    session_reuse_after_step_panic(Some(Dtype::Bf16));
}

/// Idle-TTL eviction: states returned and not reclaimed within the TTL
/// are dropped; a pool without a TTL never evicts; the server-owned pool
/// (5-minute TTL) keeps fresh returns.
#[test]
fn idle_sessions_are_evicted_after_the_ttl() {
    let inner: Arc<dyn SequenceModel> = Arc::new(model(3, 1));
    let opts = ForwardOptions::new().with_threads(1);
    let pool = SessionPool::with_ttl(inner.clone(), opts.clone(), Duration::from_millis(30));
    let (a, b) = (pool.acquire(), pool.acquire());
    pool.release(a);
    pool.release(b);
    assert_eq!(pool.idle(), 2);
    std::thread::sleep(Duration::from_millis(60));
    assert_eq!(pool.evict_idle(), 2);
    assert_eq!(pool.idle(), 0);

    let forever = SessionPool::new(inner, opts);
    forever.release(forever.acquire());
    std::thread::sleep(Duration::from_millis(60));
    assert_eq!(forever.evict_idle(), 0, "a TTL-less pool never evicts");
    assert_eq!(forever.idle(), 1);

    let server =
        NativeInferenceServer::start_model(Arc::new(model(3, 1)), L, ServerConfig::default());
    let s = server.open_session();
    server.close_session(s);
    assert_eq!(server.evict_idle_sessions(), 0, "5-minute TTL keeps fresh returns");
}

/// Malformed payloads and timescales are rejected on the caller's thread
/// with `InvalidInput`, before the queue — the worker never sees them.
#[test]
fn malformed_requests_are_rejected_before_the_queue() {
    let server =
        NativeInferenceServer::start_model(Arc::new(model(1, 1)), L, ServerConfig::default());
    let handle = server.handle();
    let ok_row = vec![0.5f32; L * D_IN];

    let wrong_width = handle.infer(vec![0.5; L * D_IN + 1]);
    assert!(
        matches!(&wrong_width, Err(ServeError::InvalidInput(m)) if m.contains("width")),
        "{wrong_width:?}"
    );
    let mut nan_row = ok_row.clone();
    nan_row[3] = f32::NAN;
    assert!(
        matches!(handle.infer(nan_row), Err(ServeError::InvalidInput(m)) if m.contains("index 3"))
    );
    let mut inf_row = ok_row.clone();
    inf_row[0] = f32::INFINITY;
    assert!(matches!(handle.infer(inf_row), Err(ServeError::InvalidInput(_))));
    for ts in [0.0, -1.0, f64::NAN, f64::INFINITY] {
        let r = handle.infer_with_timescale(ok_row.clone(), ts);
        assert!(matches!(r, Err(ServeError::InvalidInput(_))), "timescale {ts}: {r:?}");
    }

    assert_eq!(server.stats.requests.load(Ordering::Relaxed), 0, "nothing reached the worker");
    assert_eq!(server.stats.queue_depth(), 0);
    assert!(handle.infer(ok_row).is_ok(), "a well-formed request still succeeds");
}

/// A mismatched-timescale arrival during an open batch window executes as
/// its own singleton batch and is counted in `stats.stragglers`; both
/// requests stay bit-exact at their own timescale.
#[test]
fn mismatched_timescales_run_alone_and_are_counted_as_stragglers() {
    let m = model(55, 2);
    let server = NativeInferenceServer::start(
        m.clone(),
        L,
        ServerConfig {
            max_wait: Duration::from_millis(250),
            max_batch: 8,
            threads: 4,
            ..ServerConfig::default()
        },
    );
    let handle = server.handle();
    let mut rng = Rng::new(808);
    let xa = rng.normal_vec_f32(L * D_IN);
    let xb = rng.normal_vec_f32(L * D_IN);

    let (ra, rb) = std::thread::scope(|s| {
        let ha = handle.clone();
        let xa2 = xa.clone();
        let a = s.spawn(move || ha.infer_with_timescale(xa2, 1.0));
        // land B inside A's 250ms batch window
        std::thread::sleep(Duration::from_millis(60));
        let hb = handle.clone();
        let xb2 = xb.clone();
        let b = s.spawn(move || hb.infer_with_timescale(xb2, 2.0));
        (a.join().expect("client A"), b.join().expect("client B"))
    });
    let ra = ra.expect("ts=1.0 request");
    let rb = rb.expect("ts=2.0 request");

    assert_eq!(server.stats.stragglers.load(Ordering::Relaxed), 1);
    assert_eq!(server.stats.requests.load(Ordering::Relaxed), 2);
    assert_eq!(server.stats.batches.load(Ordering::Relaxed), 2);
    assert_eq!(ra.batched_with, 1);
    assert_eq!(rb.batched_with, 1);

    let mut ws = EngineWorkspace::new();
    for (x, ts, got) in [(&xa, 1.0, &ra.logits), (&xb, 2.0, &rb.logits)] {
        let opts = ForwardOptions::new().with_threads(4).with_timescale(ts);
        let want = m.prefill(Batch::single(x, L, D_IN), &opts, &mut ws);
        assert_bits_equal(&want, got, &format!("ts {ts}"));
    }
}
