//! Golden parity: the pure-Rust engine pinned to the Python reference
//! through small **committed** npz fixtures (`tests/fixtures/`).
//!
//! `python/tests/gen_fixtures.py` runs the `python/compile` reference
//! (hippo init, ZOH discretization, the scan oracle, `s5_ssm_apply`,
//! `s5_layer_apply`, the classifier) on fixed-seed cases and commits
//! inputs plus expected outputs; this suite loads them through the
//! no-dependency `runtime/npz.rs` reader and checks every module
//! boundary of the Rust engine against them, sweeping the execution
//! surface (fused/staged tiling, planar/interleaved layout, f32/f64-state
//! /bf16 storage, pooled/scoped/inline dispatch, thread budgets, wide
//! mode). Unlike `tests/parity.rs` this needs no Python and no PJRT at
//! test time — the fixtures are the contract — and it **cannot silently
//! skip**: a missing or unreadable fixture is a test failure, and the
//! `MANIFEST.txt` checksums prove the committed bytes are the generated
//! ones before any numeric claim is made.
//!
//! Tolerances (`|got − want| ≤ ATOL + RTOL·|want|`, per f32 component),
//! kept in sync with `python/tests/test_fixture_parity.py::TOL` which
//! measures the actual gap of a numpy mirror of the Rust op order:
//!
//! | module                   | ATOL | RTOL | why                                      |
//! |--------------------------|------|------|------------------------------------------|
//! | hippo eigenvalues        | 1e-5 | 1e-6 | Jacobi vs LAPACK eigenvalue agreement    |
//! | ZOH discretization       | 1e-6 | 1e-5 | both sides f64; dt round-trips f32       |
//! | scan (TI/TV)             | 1e-5 | 1e-4 | f32 recurrence vs complex128 reference;  |
//! |                          |      |      | covers the parallel chunk-combine too    |
//! | ssm / layer / logits     | 5e-4 | 5e-4 | f32 engine vs mixed-precision JAX ref    |
//! | any module, bf16 storage | 5e-2 | 5e-2 | the PR-8 bf16 drift budget (0.05)        |
//!
//! Measured headroom: the module-level gap of the numpy mirror is
//! ≈ 5e-7 absolute on these shapes, three orders under the gate.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use s5::num::{C32, C64};
use s5::runtime::npz::{crc32, NpzStore, NpzTensor};
use s5::runtime::pool::WorkerPool;
use s5::ssm::api::ForwardOptions;
use s5::ssm::discretize::{discretize_one, Method};
use s5::ssm::dtype::Dtype;
use s5::ssm::engine::{EngineWorkspace, Tiling};
use s5::ssm::hippo::block_diag_hippo_init;
use s5::ssm::s5::{S5Layer, S5Model};
use s5::ssm::scan::{
    backend_for_exec, ScanBackend, ScanExec, ScanLayout, ScanScratch, SequentialBackend,
};

// -- tolerances (see the module docs table) ---------------------------------

const TOL_HIPPO: (f32, f32) = (1e-5, 1e-6);
const TOL_DISC: (f32, f32) = (1e-6, 1e-5);
const TOL_SCAN: (f32, f32) = (1e-5, 1e-4);
const TOL_MODULE: (f32, f32) = (5e-4, 5e-4);
const TOL_BF16: (f32, f32) = (5e-2, 5e-2);

/// The seven committed fixture files; the manifest test proves the set on
/// disk is exactly this.
const FIXTURE_FILES: &[&str] = &[
    "fx_hippo.npz",
    "fx_discretize.npz",
    "fx_scan_ti.npz",
    "fx_scan_tv.npz",
    "fx_ssm.npz",
    "fx_layer.npz",
    "fx_model.npz",
];

// -- loading helpers (every failure panics — no silent skips) ---------------

fn fixtures_dir() -> PathBuf {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures");
    assert!(
        dir.join("MANIFEST.txt").is_file(),
        "golden fixtures missing at {dir:?} — they are committed files; \
         regenerate with `python tests/gen_fixtures.py` from python/ if lost"
    );
    dir
}

fn load(name: &str) -> NpzStore {
    let path = fixtures_dir().join(name);
    NpzStore::load(&path).unwrap_or_else(|e| panic!("loading fixture {path:?}: {e:#}"))
}

fn tensor<'a>(store: &'a NpzStore, file: &str, name: &str) -> &'a NpzTensor {
    store.get(name).unwrap_or_else(|| panic!("fixture {file}: tensor {name:?} missing"))
}

fn f32s<'a>(store: &'a NpzStore, file: &str, name: &str) -> &'a [f32] {
    tensor(store, file, name)
        .f32s()
        .unwrap_or_else(|| panic!("fixture {file}: tensor {name:?} is not f32"))
}

fn to_c64(re: &[f32], im: &[f32]) -> Vec<C64> {
    assert_eq!(re.len(), im.len());
    re.iter().zip(im).map(|(&r, &i)| C64::new(r as f64, i as f64)).collect()
}

fn to_c32(re: &[f32], im: &[f32]) -> Vec<C32> {
    assert_eq!(re.len(), im.len());
    re.iter().zip(im).map(|(&r, &i)| C32::new(r, i)).collect()
}

/// `|got − want| ≤ atol + rtol·|want|` per f32 component.
fn assert_close(want: &[f32], got: &[f32], (atol, rtol): (f32, f32), tag: &str) {
    assert_eq!(want.len(), got.len(), "{tag}: length {} vs {}", want.len(), got.len());
    for (i, (&w, &g)) in want.iter().zip(got).enumerate() {
        let err = (w - g).abs();
        let gate = atol + rtol * w.abs();
        assert!(
            err <= gate,
            "{tag}: index {i}: want {w}, got {g} (|err| {err} > {gate} = \
             {atol} + {rtol}·|want|)"
        );
    }
}

/// Build an [`S5Layer`] from a fixture's `<prefix>.*` tensors (the
/// `init_s5_layer` param dict flattened by gen_fixtures.py).
fn layer_from_fixture(store: &NpzStore, file: &str, prefix: &str) -> S5Layer {
    let g = |suffix: &str| f32s(store, file, &format!("{prefix}.{suffix}"));
    let d = g("d").to_vec();
    let lam_re = g("lambda_re");
    let (h, p2) = (d.len(), lam_re.len());
    let c_re = g("c_re");
    let n_dir = c_re.len() / (h * p2);
    assert!(n_dir == 1 || n_dir == 2, "{file}:{prefix}: bad C shape");
    let c_all = to_c64(c_re, g("c_im"));
    S5Layer {
        lambda: to_c64(lam_re, g("lambda_im")),
        b_tilde: to_c64(g("b_re"), g("b_im")),
        c_tilde: c_all.chunks(h * p2).map(|c| c.to_vec()).collect(),
        d,
        log_dt: g("log_dt").to_vec(),
        gate_w: g("gate_w").to_vec(),
        norm_scale: g("norm_scale").to_vec(),
        norm_bias: g("norm_bias").to_vec(),
        h,
        p2,
    }
}

/// The engine-configuration sweep the module-level fixtures run under:
/// every (tiling × layout × dispatch × state-precision) combination the
/// engine exposes, plus the bf16 storage dtype with its own tolerance.
/// Returns `(label, options, tolerance)`.
fn engine_sweep() -> Vec<(&'static str, ForwardOptions, (f32, f32))> {
    let pool = Arc::new(WorkerPool::new(3));
    vec![
        ("fused-auto-seq", ForwardOptions::new(), TOL_MODULE),
        (
            "fused-tile1-scoped3",
            ForwardOptions::new().with_exec(3, ScanExec::Scoped).with_tile(1),
            TOL_MODULE,
        ),
        (
            "fused-tile7-pooled3",
            ForwardOptions::new().with_exec(3, ScanExec::Pool(pool)).with_tile(7),
            TOL_MODULE,
        ),
        ("fused-inline3", ForwardOptions::new().with_exec(3, ScanExec::Inline), TOL_MODULE),
        ("staged-planar-seq", ForwardOptions::new().with_tiling(Tiling::Staged), TOL_MODULE),
        (
            "staged-planar-scoped8",
            ForwardOptions::new().with_tiling(Tiling::Staged).with_exec(8, ScanExec::Scoped),
            TOL_MODULE,
        ),
        (
            "interleaved-seq",
            ForwardOptions::new().with_scan(1, ScanLayout::Interleaved),
            TOL_MODULE,
        ),
        (
            "interleaved-t3",
            ForwardOptions::new().with_scan(3, ScanLayout::Interleaved),
            TOL_MODULE,
        ),
        ("f64-state", ForwardOptions::new().with_f64_state(), TOL_MODULE),
        (
            "wide-scoped4",
            ForwardOptions::new().with_wide().with_exec(4, ScanExec::Scoped),
            TOL_MODULE,
        ),
        ("bf16-fused-auto", ForwardOptions::new().with_dtype(Dtype::Bf16), TOL_BF16),
        (
            "bf16-tile5-scoped3",
            ForwardOptions::new()
                .with_dtype(Dtype::Bf16)
                .with_exec(3, ScanExec::Scoped)
                .with_tile(5),
            TOL_BF16,
        ),
    ]
}

// -- 0. the manifest: committed bytes are the generated bytes ---------------

#[test]
fn manifest_matches_committed_fixtures() {
    let dir = fixtures_dir();
    let manifest = std::fs::read_to_string(dir.join("MANIFEST.txt")).unwrap();
    let mut files_seen = BTreeSet::new();
    let mut tensors_listed: Vec<(String, String, Vec<usize>)> = Vec::new();
    for line in manifest.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        match parts.as_slice() {
            ["file", name, crc_hex, size] => {
                let raw = std::fs::read(dir.join(name))
                    .unwrap_or_else(|e| panic!("fixture {name} listed but unreadable: {e}"));
                assert_eq!(
                    raw.len(),
                    size.parse::<usize>().unwrap(),
                    "{name}: size drifted from the manifest — regenerate fixtures \
                     and manifest together (python tests/gen_fixtures.py)"
                );
                let crc = u32::from_str_radix(crc_hex, 16).unwrap();
                assert_eq!(
                    crc32(&raw),
                    crc,
                    "{name}: crc32 drifted from the manifest — the committed npz \
                     is not the file the generator wrote"
                );
                files_seen.insert(name.to_string());
            }
            ["tensor", spec, shape] => {
                let (file, tname) = spec.split_once(':').unwrap();
                let dims: Vec<usize> = shape.split('x').map(|d| d.parse().unwrap()).collect();
                tensors_listed.push((file.to_string(), tname.to_string(), dims));
            }
            _ => panic!("unrecognized manifest line: {line:?}"),
        }
    }
    // the file set is closed: exactly the seven fixtures, each listed
    let want: BTreeSet<String> = FIXTURE_FILES.iter().map(|s| s.to_string()).collect();
    assert_eq!(files_seen, want, "manifest file set != expected fixture set");
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.unwrap().file_name().into_string().unwrap();
        if name.ends_with(".npz") {
            assert!(files_seen.contains(&name), "untracked fixture on disk: {name}");
        }
    }
    // every listed tensor parses with the listed shape, and every tensor
    // in every store is listed (no unmanifested payload)
    assert!(!tensors_listed.is_empty(), "manifest lists no tensors");
    for file in FIXTURE_FILES {
        let store = load(file);
        let listed: Vec<&(String, String, Vec<usize>)> =
            tensors_listed.iter().filter(|(f, _, _)| f == file).collect();
        assert_eq!(
            listed.len(),
            store.len(),
            "{file}: manifest lists {} tensors, store holds {}",
            listed.len(),
            store.len()
        );
        for (_, tname, dims) in listed {
            let t = tensor(&store, file, tname);
            // the generator writes "1" for both () and (1,) — normalize
            let mut got = t.dims.clone();
            if got.is_empty() {
                got.push(1);
            }
            assert_eq!(&got, dims, "{file}:{tname}: shape mismatch");
        }
    }
}

// -- 1. HiPPO block-diagonal init ------------------------------------------

#[test]
fn hippo_eigenvalues_match_reference() {
    let file = "fx_hippo.npz";
    let store = load(file);
    for case in 0..3 {
        let meta = f32s(&store, file, &format!("case{case}.meta"));
        let (p, j, conj) = (meta[0] as usize, meta[1] as usize, meta[2] != 0.0);
        let (lam, _v, _vinv) = block_diag_hippo_init(p, j, conj);
        let want_re = f32s(&store, file, &format!("case{case}.lambda_re"));
        let want_im = f32s(&store, file, &format!("case{case}.lambda_im"));
        assert_eq!(lam.len(), want_re.len(), "case{case}: P2 mismatch");
        let got_re: Vec<f32> = lam.iter().map(|z| z.re as f32).collect();
        let got_im: Vec<f32> = lam.iter().map(|z| z.im as f32).collect();
        let tag = format!("hippo case{case} (p={p} j={j} conj={conj})");
        assert_close(want_re, &got_re, TOL_HIPPO, &format!("{tag} re"));
        assert_close(want_im, &got_im, TOL_HIPPO, &format!("{tag} im"));
    }
}

// -- 2. ZOH discretization --------------------------------------------------

#[test]
fn zoh_discretization_matches_reference() {
    let file = "fx_discretize.npz";
    let store = load(file);
    let lam = to_c64(f32s(&store, file, "lambda_re"), f32s(&store, file, "lambda_im"));
    for prefix in ["vec", "scalar"] {
        let dt = f32s(&store, file, &format!("{prefix}.dt"));
        let want_lb_re = f32s(&store, file, &format!("{prefix}.lam_bar_re"));
        let want_lb_im = f32s(&store, file, &format!("{prefix}.lam_bar_im"));
        let want_sc_re = f32s(&store, file, &format!("{prefix}.scale_re"));
        let want_sc_im = f32s(&store, file, &format!("{prefix}.scale_im"));
        let (mut lb_re, mut lb_im) = (Vec::new(), Vec::new());
        let (mut sc_re, mut sc_im) = (Vec::new(), Vec::new());
        for (r, &l) in lam.iter().enumerate() {
            let dt_r = dt[if dt.len() == 1 { 0 } else { r }] as f64;
            let (lb, sc) = discretize_one(l, dt_r, Method::Zoh);
            lb_re.push(lb.re as f32);
            lb_im.push(lb.im as f32);
            sc_re.push(sc.re as f32);
            sc_im.push(sc.im as f32);
        }
        assert_close(want_lb_re, &lb_re, TOL_DISC, &format!("zoh {prefix} lam_bar re"));
        assert_close(want_lb_im, &lb_im, TOL_DISC, &format!("zoh {prefix} lam_bar im"));
        assert_close(want_sc_re, &sc_re, TOL_DISC, &format!("zoh {prefix} scale re"));
        assert_close(want_sc_im, &sc_im, TOL_DISC, &format!("zoh {prefix} scale im"));
    }
}

// -- 3. the scan substrate (TI and TV, every backend) -----------------------

/// The scan-backend sweep: sequential, and the parallel strategy across
/// thread budgets and dispatch modes (whose chunk-combine is the one
/// tolerance-bearing reassociation — covered by TOL_SCAN).
fn scan_backends() -> Vec<(String, Box<dyn ScanBackend>)> {
    let pool = Arc::new(WorkerPool::new(3));
    let mut v: Vec<(String, Box<dyn ScanBackend>)> =
        vec![("sequential".into(), Box::new(SequentialBackend))];
    for &t in &[1usize, 3, 8] {
        for (ename, exec) in [
            ("scoped", ScanExec::Scoped),
            ("pooled", ScanExec::Pool(pool.clone())),
            ("inline", ScanExec::Inline),
        ] {
            for layout in [ScanLayout::Planar, ScanLayout::Interleaved] {
                v.push((
                    format!("{layout:?}-t{t}-{ename}"),
                    backend_for_exec(t, layout, exec.clone()),
                ));
            }
        }
    }
    v
}

fn check_scan_fixture(file: &str, time_varying: bool) {
    let store = load(file);
    let a = to_c32(f32s(&store, file, "a_re"), f32s(&store, file, "a_im"));
    let drive = to_c32(f32s(&store, file, "drive_re"), f32s(&store, file, "drive_im"));
    let dims = &tensor(&store, file, "drive_re").dims;
    let (l, p) = (dims[0], dims[1]);
    let want_re = f32s(&store, file, "x_re");
    let want_im = f32s(&store, file, "x_im");
    for (name, be) in scan_backends() {
        let tag = format!("{file} {name}");
        // interleaved entry point
        let mut scratch = ScanScratch::new();
        let mut buf = drive.clone();
        if time_varying {
            be.scan_tv(&a, &mut buf, l, p, &mut scratch);
        } else {
            be.scan_ti(&a, &mut buf, l, p, &mut scratch);
        }
        let got_re: Vec<f32> = buf.iter().map(|z| z.re).collect();
        let got_im: Vec<f32> = buf.iter().map(|z| z.im).collect();
        assert_close(want_re, &got_re, TOL_SCAN, &format!("{tag} interleaved re"));
        assert_close(want_im, &got_im, TOL_SCAN, &format!("{tag} interleaved im"));
        // planar twin
        let (ar, ai): (Vec<f32>, Vec<f32>) =
            (a.iter().map(|z| z.re).collect(), a.iter().map(|z| z.im).collect());
        let mut xr: Vec<f32> = drive.iter().map(|z| z.re).collect();
        let mut xi: Vec<f32> = drive.iter().map(|z| z.im).collect();
        if time_varying {
            be.scan_tv_planar(&ar, &ai, &mut xr, &mut xi, l, p, &mut scratch);
        } else {
            be.scan_ti_planar(&ar, &ai, &mut xr, &mut xi, l, p, &mut scratch);
        }
        assert_close(want_re, &xr, TOL_SCAN, &format!("{tag} planar re"));
        assert_close(want_im, &xi, TOL_SCAN, &format!("{tag} planar im"));
    }
}

#[test]
fn scan_ti_matches_reference() {
    check_scan_fixture("fx_scan_ti.npz", false);
}

#[test]
fn scan_tv_matches_reference() {
    check_scan_fixture("fx_scan_tv.npz", true);
}

// -- 4. s5_ssm_apply (conj-sym projection, ZOH, bidir, TV) ------------------

#[test]
fn ssm_apply_matches_reference_across_engine_configs() {
    let file = "fx_ssm.npz";
    let store = load(file);
    let uni = layer_from_fixture(&store, file, "uni");
    let bi = layer_from_fixture(&store, file, "bi");
    let u = f32s(&store, file, "input.u");
    let dts = f32s(&store, file, "input.dts");
    let dims = &tensor(&store, file, "input.u").dims;
    let (batch, l) = (dims[0], dims[1]);
    let ts = f32s(&store, file, "input.timescale"); // [1.0, 0.5]
    // (case label, layer, dts?, timescale, expected) — `bi_tv` is the
    // regression pin for the bidirectional irregular-sampling fix: the
    // backward scan must reverse the Δt multipliers *with* the drive.
    let cases: [(&str, &S5Layer, Option<&[f32]>, f64, &str); 5] = [
        ("uni_ti", &uni, None, ts[0] as f64, "expect.uni_ti"),
        ("uni_ts", &uni, None, ts[1] as f64, "expect.uni_ts"),
        ("uni_tv", &uni, Some(dts), ts[0] as f64, "expect.uni_tv"),
        ("bi_ti", &bi, None, ts[0] as f64, "expect.bi_ti"),
        ("bi_tv", &bi, Some(dts), ts[0] as f64, "expect.bi_tv"),
    ];
    for (label, layer, case_dts, timescale, expect_key) in cases {
        let want = f32s(&store, file, expect_key);
        for (cfg, opts, tol) in engine_sweep() {
            let opts = opts.with_timescale(timescale);
            let mut ws = EngineWorkspace::new();
            let got = layer.apply_ssm_batch_opts(u, batch, l, case_dts, &opts, &mut ws);
            assert_close(want, &got, tol, &format!("ssm {label} [{cfg}]"));
        }
    }
}

// -- 5. the full layer (pre-norm → SSM → GELU → gate → residual) ------------

#[test]
fn layer_apply_matches_reference_across_engine_configs() {
    let file = "fx_layer.npz";
    let store = load(file);
    let uni = layer_from_fixture(&store, file, "uni");
    let bi = layer_from_fixture(&store, file, "bi");
    let u = f32s(&store, file, "input.u");
    let dts = f32s(&store, file, "input.dts");
    let dims = &tensor(&store, file, "input.u").dims;
    let (batch, l) = (dims[0], dims[1]);
    let cases: [(&str, &S5Layer, Option<&[f32]>, &str); 3] = [
        ("uni_y", &uni, None, "expect.uni_y"),
        ("uni_tv_y", &uni, Some(dts), "expect.uni_tv_y"),
        ("bi_y", &bi, None, "expect.bi_y"),
    ];
    for (label, layer, case_dts, expect_key) in cases {
        let want = f32s(&store, file, expect_key);
        for (cfg, opts, tol) in engine_sweep() {
            let mut ws = EngineWorkspace::new();
            let got = layer.apply_batch_opts(u, batch, l, case_dts, &opts, &mut ws);
            assert_close(want, &got, tol, &format!("layer {label} [{cfg}]"));
        }
    }
}

// -- 6. the classifier end-to-end (fixture doubles as a checkpoint) ---------

#[test]
fn classifier_logits_match_reference_across_engine_configs() {
    let file = "fx_model.npz";
    let store = load(file);
    // the fixture's params.* tensors are a Rust-native checkpoint — this
    // also pins `from_param_store` against the Python-side naming
    let model = S5Model::from_param_store(&store)
        .unwrap_or_else(|e| panic!("{file}: from_param_store failed: {e:#}"));
    let u = f32s(&store, file, "input.u");
    let dims = &tensor(&store, file, "input.u").dims;
    let (batch, l) = (dims[0], dims[1]);
    let classes = tensor(&store, file, "expect.logits").dims[1];
    let ts = f32s(&store, file, "input.timescale"); // [1.0, 0.5]
    let runs = [(ts[0] as f64, "expect.logits"), (ts[1] as f64, "expect.logits_ts")];
    for (timescale, expect_key) in runs {
        let want = f32s(&store, file, expect_key);
        for (cfg, opts, tol) in engine_sweep() {
            let opts = opts.with_timescale(timescale);
            let mut ws = EngineWorkspace::new();
            let mut got = vec![0.0f32; batch * classes];
            model.forward_batch_opts_into(u, batch, l, &opts, &mut ws, &mut got);
            assert_close(want, &got, tol, &format!("logits ts={timescale} [{cfg}]"));
        }
    }
}
