//! Integration: the unified `SequenceModel` API — streaming ≡ batched
//! equivalence, legacy-wrapper ≡ new-API equivalence, the model-generic
//! native server, and native npz checkpoint round trips. No compiled
//! artifacts required.

use s5::coordinator::server::{NativeInferenceServer, ServerConfig};
use s5::rng::Rng;
use s5::runtime::NpzStore;
use s5::ssm::api::{Batch, ForwardOptions, SequenceModel, Session};
use s5::ssm::engine::{EngineWorkspace, Tiling};
use s5::ssm::rnn::{CruLike, GruCell};
use s5::ssm::s5::{S5Config, S5Model};
use s5::ssm::scan::ScanLayout;
use s5::testing::prop;
use std::sync::Arc;
use std::time::Duration;

fn s5_model(seed: u64, depth: usize) -> S5Model {
    let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
    S5Model::init(2, 5, depth, &cfg, &mut Rng::new(seed))
}

// ---------------------------------------------------------------------------
// streaming ≡ batched
// ---------------------------------------------------------------------------

/// Property: driving `Session::step` for L tokens reproduces the batched
/// `prefill` output **bit-for-bit** on the sequential scan path, for both
/// S5 and the GRU baseline (the online/offline shared-kernel guarantee).
#[test]
fn prop_session_steps_reproduce_prefill_bit_for_bit() {
    prop::check("session ≡ prefill (exact)", 8, |g| {
        let l = 4 + g.below(80);
        let models: Vec<Arc<dyn SequenceModel>> = vec![
            Arc::new(s5_model(1 + g.below(1000) as u64, 2)),
            Arc::new(GruCell::init(2, 6, &mut Rng::new(g.below(1000) as u64))),
        ];
        for model in models {
            let spec = model.spec();
            let d = spec.d_input;
            let u: Vec<f32> = (0..l * d).map(|_| g.normal() as f32).collect();
            let opts = ForwardOptions::new(); // sequential scan
            let mut ws = EngineWorkspace::new();
            let offline = model.prefill(Batch::single(&u, l, d), &opts, &mut ws);
            let mut session = Session::new(model.clone(), opts);
            let streamed = session.prefill(&u, l);
            if offline != streamed {
                return Err(format!(
                    "{}: streaming diverged from batched at L={l}: {offline:?} vs {streamed:?}",
                    spec.name
                ));
            }
        }
        Ok(())
    });
}

/// With a parallel scan strategy the chunked combine is only close, not
/// identical — streaming must still agree within the documented tolerance.
#[test]
fn session_matches_parallel_prefill_within_tolerance() {
    let model: Arc<dyn SequenceModel> = Arc::new(s5_model(11, 3));
    let l = 96;
    let mut rng = Rng::new(5);
    let u = rng.normal_vec_f32(l * 2);
    let mut ws = EngineWorkspace::new();
    let par = model.prefill(
        Batch::single(&u, l, 2),
        &ForwardOptions::new().with_threads(4),
        &mut ws,
    );
    let mut session = Session::new(model, ForwardOptions::new());
    let streamed = session.prefill(&u, l);
    prop::close_slice_f32(&par, &streamed, 1e-4).unwrap();
}

/// Session reset restarts the stream exactly; irregular Δt steps flow
/// through for the models that honor them.
#[test]
fn session_reset_and_dt_paths() {
    let cru: Arc<dyn SequenceModel> = Arc::new(CruLike::init(2, 4, &mut Rng::new(3)));
    let mut session = Session::new(cru, ForwardOptions::new());
    let mut rng = Rng::new(8);
    let x = rng.normal_vec_f32(2);
    let y1 = session.step_dt(&x, 1.7);
    let _ = session.step(&x);
    session.reset();
    assert_eq!(session.steps(), 0);
    let y3 = session.step_dt(&x, 1.7);
    assert_eq!(y1, y3, "reset must restart the stream exactly");
    // Δt must be load-bearing for the CRU-like baseline
    session.reset();
    let yfast = session.step_dt(&x, 3.0);
    assert_ne!(y1, yfast, "Δt must influence the CRU-like output");
}

// ---------------------------------------------------------------------------
// planar (default) ≡ interleaved oracle
// ---------------------------------------------------------------------------

/// The planar pipelines reproduce the interleaved `C32` oracle
/// **bit-for-bit** through the full `SequenceModel` surface: the staged
/// planar pipeline against the interleaved oracle at the *same* strategy
/// (sequential and parallel), and the default fused tiled pipeline
/// against the interleaved *sequential* oracle (fused in-tile scans are
/// sequential whatever the thread budget).
#[test]
fn prop_planar_prefill_matches_interleaved_oracle() {
    prop::check("planar ≡ interleaved (API)", 6, |g| {
        let model = s5_model(31 + g.below(100) as u64, 2);
        let batch = 1 + g.below(5);
        // lengths straddling the T=3 parallel backend's 4·T fallback and
        // its chunk remainders, plus a random longer one
        let l = [11usize, 12, 13, 24 + g.below(40)][g.below(4)];
        let u: Vec<f32> = (0..batch * l * 2).map(|_| g.normal() as f32).collect();
        let seq_oracle = ForwardOptions::new().with_scan(1, ScanLayout::Interleaved);
        for threads in [1usize, 3] {
            let staged = ForwardOptions::new().with_threads(threads).with_tiling(Tiling::Staged);
            let fused = ForwardOptions::new().with_threads(threads);
            let oracle = ForwardOptions::new().with_scan(threads, ScanLayout::Interleaved);
            assert_eq!(staged.scan_layout(), ScanLayout::Planar);
            assert_eq!(oracle.scan_layout(), ScanLayout::Interleaved);
            let mut wp = EngineWorkspace::new();
            let mut wf = EngineWorkspace::new();
            let mut wi = EngineWorkspace::new();
            let mut ws = EngineWorkspace::new();
            let got = model.prefill(Batch::new(&u, batch, l, 2), &staged, &mut wp);
            let want = model.prefill(Batch::new(&u, batch, l, 2), &oracle, &mut wi);
            if got != want {
                return Err(format!("staged B={batch} L={l} t={threads}: {got:?} vs {want:?}"));
            }
            let got = model.prefill(Batch::new(&u, batch, l, 2), &fused, &mut wf);
            let want = model.prefill(Batch::new(&u, batch, l, 2), &seq_oracle, &mut ws);
            if got != want {
                return Err(format!("fused B={batch} L={l} t={threads}: {got:?} vs {want:?}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// tile-boundary streaming equivalence (the fused-forward PR's contract)
// ---------------------------------------------------------------------------

/// `Session::step`-by-step replay ≡ tiled prefill on the same tokens,
/// bit-for-bit, across tile sizes that don't divide L, T = 1, T ≥ L and
/// the auto tile — both for the batched prefill output and for the
/// chunked `Session::prefill` fast path (which runs the fused pipeline
/// on the live stream state).
#[test]
fn tiled_prefill_equals_step_replay_bit_for_bit() {
    let model: Arc<dyn SequenceModel> = Arc::new(s5_model(61, 3));
    for l in [1usize, 2, 19, 64] {
        let mut rng = Rng::new(100 + l as u64);
        let u = rng.normal_vec_f32(l * 2);
        // pure per-token replay (the streaming ground truth)
        let mut stepper = Session::new(model.clone(), ForwardOptions::new());
        let mut stepped = Vec::new();
        for k in 0..l {
            stepped = stepper.step(&u[k * 2..(k + 1) * 2]);
        }
        let tiles = [1usize, 3, 5, l, l + 9, 0 /* 0 = auto via default */];
        for &tile in &tiles {
            let opts = if tile == 0 {
                ForwardOptions::new()
            } else {
                ForwardOptions::new().with_tile(tile)
            };
            // batched tiled prefill
            let mut ws = EngineWorkspace::new();
            let offline = model.prefill(Batch::single(&u, l, 2), &opts, &mut ws);
            assert_eq!(
                offline, stepped,
                "tiled prefill (tile={tile}) diverged from step replay at L={l}"
            );
            // chunked Session::prefill (advance_batch fast path)
            let mut session = Session::new(model.clone(), opts);
            let streamed = session.prefill(&u, l);
            assert_eq!(
                streamed, stepped,
                "chunked Session::prefill (tile={tile}) diverged from step replay at L={l}"
            );
            assert_eq!(session.steps(), l);
            // and the session state is live: one more step matches a
            // stepper that consumed the same prefix token-by-token
            let extra = rng.normal_vec_f32(2);
            assert_eq!(
                session.step(&extra),
                stepper.step(&extra),
                "post-prefill step diverged (tile={tile}, L={l})"
            );
            stepper.reset();
            for k in 0..l {
                stepper.step(&u[k * 2..(k + 1) * 2]);
            }
        }
    }
}

/// The bf16 storage dtype keeps the tile-boundary streaming contract
/// **within the dtype**: per-token step replay ≡ tiled/chunked prefill
/// bit-for-bit, because the per-step path round-trips its drive and its
/// projection read through bf16 at exactly the points where a fused bf16
/// tile narrow-stores (see `ssm::online`).
#[test]
fn bf16_tiled_prefill_equals_step_replay_bit_for_bit() {
    use s5::ssm::dtype::Dtype;
    let model: Arc<dyn SequenceModel> = Arc::new(s5_model(62, 3));
    for l in [1usize, 2, 19, 64] {
        let mut rng = Rng::new(200 + l as u64);
        let u = rng.normal_vec_f32(l * 2);
        let bf = ForwardOptions::new().with_dtype(Dtype::Bf16);
        // pure per-token replay under bf16 (the streaming ground truth)
        let mut stepper = Session::new(model.clone(), bf.clone());
        let mut stepped = Vec::new();
        for k in 0..l {
            stepped = stepper.step(&u[k * 2..(k + 1) * 2]);
        }
        // sanity: the bf16 stream is a *different* stream than f32
        if l >= 19 {
            let f32_opts = ForwardOptions::new().with_dtype(Dtype::F32);
            let mut f32_stepper = Session::new(model.clone(), f32_opts);
            let mut f32_stepped = Vec::new();
            for k in 0..l {
                f32_stepped = f32_stepper.step(&u[k * 2..(k + 1) * 2]);
            }
            assert_ne!(stepped, f32_stepped, "bf16 stream silently ran f32 at L={l}");
        }
        for tile in [1usize, 3, 5, l, l + 9] {
            let opts = ForwardOptions::new().with_dtype(Dtype::Bf16).with_tile(tile);
            // batched tiled prefill under bf16
            let mut ws = EngineWorkspace::new();
            let offline = model.prefill(Batch::single(&u, l, 2), &opts, &mut ws);
            assert_eq!(
                offline, stepped,
                "bf16 tiled prefill (tile={tile}) diverged from step replay at L={l}"
            );
            // chunked Session::prefill (advance_batch fast path)
            let mut session = Session::new(model.clone(), opts);
            let streamed = session.prefill(&u, l);
            assert_eq!(
                streamed, stepped,
                "bf16 chunked Session::prefill (tile={tile}) diverged at L={l}"
            );
            // the session state is live: one more step matches replay
            let extra = rng.normal_vec_f32(2);
            assert_eq!(
                session.step(&extra),
                stepper.step(&extra),
                "bf16 post-prefill step diverged (tile={tile}, L={l})"
            );
            stepper.reset();
            for k in 0..l {
                stepper.step(&u[k * 2..(k + 1) * 2]);
            }
        }
        // a staged policy runs as one fused tile under bf16 — same stream
        let staged = ForwardOptions::new().with_dtype(Dtype::Bf16).with_tiling(Tiling::Staged);
        let mut ws = EngineWorkspace::new();
        let offline = model.prefill(Batch::single(&u, l, 2), &staged, &mut ws);
        assert_eq!(offline, stepped, "bf16 staged prefill diverged from step replay at L={l}");
    }
}

/// Bidirectional stacks cannot stream, but their tiled prefill must
/// equal the staged reference bit-for-bit across tile shapes — including
/// tiles that don't divide L, T = 1 and T ≥ L.
#[test]
fn bidirectional_tiled_prefill_matches_staged() {
    let cfg = S5Config { h: 6, p: 8, j: 1, bidir: true, ..Default::default() };
    let model = S5Model::init(2, 4, 2, &cfg, &mut Rng::new(71));
    let (batch, l) = (2usize, 45usize);
    let u = Rng::new(72).normal_vec_f32(batch * l * 2);
    let view = Batch::new(&u, batch, l, 2);
    let mut ws = EngineWorkspace::new();
    let want = model.prefill(view, &ForwardOptions::new().with_tiling(Tiling::Staged), &mut ws);
    for tile in [1usize, 4, 7, l, l + 3] {
        for threads in [1usize, 3] {
            let opts = ForwardOptions::new().with_threads(threads).with_tile(tile);
            let mut wsf = EngineWorkspace::new();
            let got = model.prefill(view, &opts, &mut wsf);
            assert_eq!(want, got, "bidir tiled prefill diverged (tile={tile}, t={threads})");
        }
    }
}

/// The f64 scan-state option flows through the API surface: finite,
/// tile-invariant, close to the f32 path — and streaming sessions keep
/// their f32 semantics regardless.
#[test]
fn f64_state_flows_through_prefill() {
    let model: Arc<dyn SequenceModel> = Arc::new(s5_model(81, 2));
    let l = 120;
    let u = Rng::new(82).normal_vec_f32(l * 2);
    let mut ws_a = EngineWorkspace::new();
    let mut ws_b = EngineWorkspace::new();
    let mut ws_c = EngineWorkspace::new();
    let a = model.prefill(
        Batch::single(&u, l, 2),
        &ForwardOptions::new().with_f64_state().with_tile(9),
        &mut ws_a,
    );
    let b = model.prefill(
        Batch::single(&u, l, 2),
        &ForwardOptions::new().with_f64_state().with_tile(50),
        &mut ws_b,
    );
    assert_eq!(a, b, "f64 state must be tile-invariant");
    let f32_res = model.prefill(Batch::single(&u, l, 2), &ForwardOptions::new(), &mut ws_c);
    prop::close_slice_f32(&f32_res, &a, 1e-3).unwrap();
    // a session under f64 options still streams (f32 state) and matches
    // its own replay
    let mut s1 = Session::new(model.clone(), ForwardOptions::new().with_f64_state());
    let mut s2 = Session::new(model, ForwardOptions::new());
    let prefilled = s1.prefill(&u, l);
    let mut stepped = Vec::new();
    for k in 0..l {
        stepped = s2.step(&u[k * 2..(k + 1) * 2]);
    }
    assert_eq!(prefilled, stepped, "streaming is f32 regardless of the offline option");
}

/// A streaming session (planar per-step kernel) reproduces the
/// *interleaved* sequential prefill bit-for-bit too: the layout changes
/// nothing, anywhere in the stack.
#[test]
fn session_steps_match_interleaved_prefill_bit_for_bit() {
    let model: Arc<dyn SequenceModel> = Arc::new(s5_model(7, 2));
    let l = 40;
    let mut rng = Rng::new(9);
    let u = rng.normal_vec_f32(l * 2);
    let mut ws = EngineWorkspace::new();
    let oracle = model.prefill(
        Batch::single(&u, l, 2),
        &ForwardOptions::new().with_scan(1, ScanLayout::Interleaved),
        &mut ws,
    );
    let mut session = Session::new(model, ForwardOptions::new());
    let streamed = session.prefill(&u, l);
    assert_eq!(oracle, streamed);
}

// ---------------------------------------------------------------------------
// legacy wrappers ≡ new API
// ---------------------------------------------------------------------------

/// The deprecated positional signatures are thin wrappers over the same
/// cores the new API drives: outputs must match exactly.
#[test]
#[allow(deprecated)]
fn prop_legacy_wrappers_equal_new_api() {
    prop::check("legacy ≡ new API", 8, |g| {
        let l = 4 + g.below(60);
        let model = s5_model(21, 2);
        let u: Vec<f32> = (0..l * 2).map(|_| g.normal() as f32).collect();
        for threads in [1usize, 3] {
            let old = model.forward(&u, l, 1.5, threads);
            let mut ws = EngineWorkspace::new();
            let new = model.prefill(
                Batch::single(&u, l, 2),
                &ForwardOptions::new().with_threads(threads).with_timescale(1.5),
                &mut ws,
            );
            if old != new {
                return Err(format!("S5 t={threads}: {old:?} vs {new:?}"));
            }
        }
        let gru = GruCell::init(3, 5, &mut Rng::new(2));
        let batch = 1 + g.below(4);
        let xs: Vec<f32> = (0..batch * l * 3).map(|_| g.normal() as f32).collect();
        let old = gru.run_batch(&xs, batch, l, 2);
        let mut ws = EngineWorkspace::new();
        let new = gru.prefill(
            Batch::new(&xs, batch, l, 3),
            &ForwardOptions::new().with_threads(2),
            &mut ws,
        );
        for bi in 0..batch {
            let want = &old[(bi * l + l - 1) * 5..(bi * l + l) * 5];
            let got = &new[bi * 5..(bi + 1) * 5];
            if want != got {
                return Err(format!("GRU seq {bi}: {want:?} vs {got:?}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// the model-generic server (acceptance criterion)
// ---------------------------------------------------------------------------

/// One server implementation, two model families, the same handle API:
/// responses must equal direct prefills of the same model.
#[test]
fn server_is_generic_over_sequence_models() {
    let l = 24;
    let cfg = ServerConfig {
        max_wait: Duration::from_millis(5),
        max_batch: 8,
        threads: 2,
        ..ServerConfig::default()
    };
    let models: Vec<Arc<dyn SequenceModel>> = vec![
        Arc::new(s5_model(77, 2)),
        Arc::new(GruCell::init(2, 7, &mut Rng::new(78))),
    ];
    for model in models {
        let spec = model.spec();
        let server = NativeInferenceServer::start_model(model.clone(), l, cfg);
        let handle = server.handle();
        assert_eq!(handle.row, l * spec.d_input);
        assert_eq!(handle.d_output, spec.d_output);
        let results: Vec<(Vec<f32>, Vec<f32>)> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..6u64)
                .map(|i| {
                    let h = handle.clone();
                    let d = spec.d_input;
                    s.spawn(move || {
                        let mut rng = Rng::new(i);
                        let x = rng.normal_vec_f32(l * d);
                        let resp = h.infer(x.clone()).unwrap();
                        (x, resp.logits)
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        let mut ws = EngineWorkspace::new();
        for (x, logits) in &results {
            assert_eq!(logits.len(), spec.d_output, "{} row width", spec.name);
            let want = model.prefill(
                Batch::single(x, l, spec.d_input),
                &ForwardOptions::new().with_threads(2),
                &mut ws,
            );
            prop::close_slice_f32(&want, logits, 1e-4)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        }
    }
}

/// Streaming sessions pooled by the server: check out, stream, return,
/// and the reused session starts clean.
#[test]
fn server_pools_streaming_sessions() {
    let l = 16;
    let model: Arc<dyn SequenceModel> = Arc::new(s5_model(91, 2));
    let server = NativeInferenceServer::start_model(
        model,
        l,
        ServerConfig {
            max_wait: Duration::from_millis(1),
            max_batch: 4,
            threads: 1,
            ..ServerConfig::default()
        },
    );
    let mut rng = Rng::new(14);
    let x = rng.normal_vec_f32(2);
    let mut s1 = server.open_session();
    let y1 = s1.step(&x);
    server.close_session(s1);
    let mut s2 = server.open_session();
    assert_eq!(s2.steps(), 0);
    let y2 = s2.step(&x);
    assert_eq!(y1, y2, "pooled session must restart clean");
    server.close_session(s2);
}

/// Nearby-but-distinct f64 timescales must never share a batch (they
/// would have aliased through the old f32 request field).
#[test]
fn f64_timescales_do_not_alias() {
    let l = 16;
    let model = s5_model(31, 2);
    let direct = model.clone();
    let server = NativeInferenceServer::start(
        model,
        l,
        ServerConfig {
            max_wait: Duration::from_millis(30),
            max_batch: 8,
            threads: 1,
            ..ServerConfig::default()
        },
    );
    let handle = server.handle();
    // 1 + 2^-30 is exactly representable in f64 but rounds to 1.0f32
    let ts_a = 1.0f64;
    let ts_b = 1.0f64 + 2f64.powi(-30);
    assert_ne!(ts_a, ts_b);
    assert_eq!(ts_a as f32, ts_b as f32);
    let mut rng = Rng::new(2);
    let x = rng.normal_vec_f32(l * 2);
    let (ra, rb) = std::thread::scope(|s| {
        let (h1, h2) = (handle.clone(), handle.clone());
        let (xa, xb) = (x.clone(), x.clone());
        let a = s.spawn(move || h1.infer_with_timescale(xa, ts_a).unwrap());
        let b = s.spawn(move || h2.infer_with_timescale(xb, ts_b).unwrap());
        (a.join().unwrap(), b.join().unwrap())
    });
    // under f64 coalescing keys the two requests can never share a batch
    // (under the old f32 key they could have been grouped)
    assert_eq!(ra.batched_with, 1, "distinct f64 timescales must not batch");
    assert_eq!(rb.batched_with, 1, "distinct f64 timescales must not batch");
    let mut ws = EngineWorkspace::new();
    for (resp, ts) in [(&ra, ts_a), (&rb, ts_b)] {
        let want = direct.prefill(
            Batch::single(&x, l, 2),
            &ForwardOptions::new().with_timescale(ts),
            &mut ws,
        );
        prop::close_slice_f32(&want, &resp.logits, 1e-4).unwrap();
    }
}

// ---------------------------------------------------------------------------
// native checkpoint round trip (acceptance criterion)
// ---------------------------------------------------------------------------

/// save → load → identical logits: the parameters surviving one f32 disk
/// round trip already, a second save/load must be exact; and the first
/// import must agree with the source model to f32-rounding tolerance.
#[test]
fn checkpoint_roundtrip_identical_logits() {
    let dir = std::env::temp_dir().join(format!("s5_seq_api_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path_a = dir.join("ckpt_a.npz");
    let path_b = dir.join("ckpt_b.npz");

    let original = s5_model(123, 2);
    original.to_param_store().save(&path_a).unwrap();
    let loaded = S5Model::from_param_store(&NpzStore::load(&path_a).unwrap()).unwrap();
    loaded.to_param_store().save(&path_b).unwrap();
    let reloaded = S5Model::from_param_store(&NpzStore::load(&path_b).unwrap()).unwrap();

    let l = 40;
    let mut rng = Rng::new(7);
    let u = rng.normal_vec_f32(l * 2);
    let opts = ForwardOptions::new();
    let mut ws = EngineWorkspace::new();
    let y_orig = original.prefill(Batch::single(&u, l, 2), &opts, &mut ws);
    let y_loaded = loaded.prefill(Batch::single(&u, l, 2), &opts, &mut ws);
    let y_reloaded = reloaded.prefill(Batch::single(&u, l, 2), &opts, &mut ws);

    // once on disk, logits are pinned exactly
    assert_eq!(y_loaded, y_reloaded, "save → load must be lossless");
    // and the first export only rounds f64-initialized params to f32
    prop::close_slice_f32(&y_orig, &y_loaded, 1e-4).unwrap();

    // the model shape round-trips too
    assert_eq!(loaded.spec(), original.spec());
    assert_eq!(loaded.param_count(), original.param_count());
    std::fs::remove_dir_all(&dir).ok();
}

/// A bidirectional model round-trips its second C matrix.
#[test]
fn checkpoint_roundtrip_bidirectional() {
    let dir = std::env::temp_dir().join(format!("s5_seq_api_bidir_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bidir.npz");
    let cfg = S5Config { h: 6, p: 8, j: 1, bidir: true, ..Default::default() };
    let original = S5Model::init(3, 4, 2, &cfg, &mut Rng::new(9));
    original.to_param_store().save(&path).unwrap();
    let loaded = S5Model::from_param_store(&NpzStore::load(&path).unwrap()).unwrap();
    assert!(!loaded.streamable());
    let l = 20;
    let mut rng = Rng::new(10);
    let u = rng.normal_vec_f32(l * 3);
    let opts = ForwardOptions::new();
    let mut ws = EngineWorkspace::new();
    let y0 = original.prefill(Batch::single(&u, l, 3), &opts, &mut ws);
    let y1 = loaded.prefill(Batch::single(&u, l, 3), &opts, &mut ws);
    prop::close_slice_f32(&y0, &y1, 1e-4).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The checkpoint loader rejects malformed stores with pointed errors.
#[test]
fn checkpoint_import_rejects_bad_stores() {
    let empty = NpzStore::new();
    let err = S5Model::from_param_store(&empty).unwrap_err();
    assert!(format!("{err:#}").contains("encoder"), "{err:#}");

    let mut truncated = s5_model(5, 1).to_param_store();
    // corrupt one tensor's shape
    truncated.insert_f32("params.layers.0.d", &[3], vec![0.0; 3]);
    let err = S5Model::from_param_store(&truncated).unwrap_err();
    assert!(format!("{err:#}").contains("layers.0"), "{err:#}");
}
