//! Concurrent-server and worker-pool stress tests: the serving stack on
//! top of the persistent pool, under contention.
//!
//! * Mixed load (batched inference + streaming sessions) from many
//!   client threads against one server must produce responses that are
//!   **bit-exact** against a serial replay. The server shape is chosen
//!   so the scan falls back to the sequential kernel in every batch
//!   sharding branch (L < 4·(T/B) for all B), making the numerics
//!   batch-composition-invariant — any coalescing the dynamic batcher
//!   happens to pick must then reproduce the serial replay exactly,
//!   while the dense engine stages still fan out across the shared
//!   pool for every batch.
//! * Concurrent chunked prefills (big L, so the Blelloch chunking *is*
//!   active) racing on one dedicated pool must each match their
//!   scoped-executor reference bit-for-bit.
//! * Pooled forwards never spawn steady-state threads (the lifecycle
//!   acceptance criterion), and the server drains cleanly on shutdown.

use std::sync::Arc;
use std::time::Duration;

use s5::coordinator::server::{NativeInferenceServer, ServerConfig};
use s5::rng::Rng;
use s5::runtime::pool::{global_pool, WorkerPool};
use s5::ssm::api::{Batch, ForwardOptions, SequenceModel};
use s5::ssm::engine::{EngineWorkspace, Tiling};
use s5::ssm::s5::{S5Config, S5Model};
use s5::ssm::scan::{backend_for_threads, ParallelBackend, ScanExec};

fn model(seed: u64, depth: usize) -> S5Model {
    let cfg = S5Config { h: 16, p: 16, j: 1, ..Default::default() };
    S5Model::init(2, 5, depth, &cfg, &mut Rng::new(seed))
}

fn assert_bits_equal(want: &[f32], got: &[f32], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length mismatch");
    for (i, (x, y)) in want.iter().zip(got.iter()).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

/// N client threads drive a mix of batched inference (several f64
/// timescales) and pooled streaming sessions against one server; every
/// response must equal a serial batch-of-1 replay bit-for-bit.
#[test]
fn mixed_concurrent_load_is_bit_exact_vs_serial_replay() {
    // L = 7 with T = 4: 7 < 4·(T/B) for every sharding (B=1 → 16,
    // B=2 → 8), so the scan is sequential in all branches and numerics
    // cannot depend on how requests were coalesced.
    let l = 7usize;
    let m = model(77, 2);
    let server = NativeInferenceServer::start(
        m.clone(),
        l,
        ServerConfig {
            max_wait: Duration::from_millis(5),
            max_batch: 8,
            threads: 4,
            ..ServerConfig::default()
        },
    );
    let handle = server.handle();
    // sessions are opened up front (the server handle is the only part
    // of the server that crosses threads) and moved into the workers
    let n_threads = 6u64;
    let sessions: Vec<_> = (0..n_threads / 2).map(|_| server.open_session()).collect();

    let mut records: Vec<(Vec<f32>, f64, Vec<f32>)> = Vec::new();
    let mut returned = Vec::new();
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        let mut sess_joins = Vec::new();
        let mut sessions = sessions;
        for tid in 0..n_threads {
            if tid % 2 == 0 {
                let h = handle.clone();
                joins.push(s.spawn(move || {
                    let mut rng = Rng::new(1000 + tid);
                    let mut out = Vec::new();
                    for it in 0..6 {
                        let x = rng.normal_vec_f32(l * 2);
                        let ts = if it % 3 == 2 { 2.0 } else { 1.0 };
                        let resp = h.infer_with_timescale(x.clone(), ts).unwrap();
                        out.push((x, ts, resp.logits));
                    }
                    out
                }));
            } else {
                let mut sess = sessions.pop().unwrap();
                sess_joins.push(s.spawn(move || {
                    let mut rng = Rng::new(2000 + tid);
                    let mut out = Vec::new();
                    for _ in 0..4 {
                        let x = rng.normal_vec_f32(l * 2);
                        let y = sess.prefill(&x, l);
                        out.push((x, 1.0f64, y));
                        sess.reset();
                    }
                    (out, sess)
                }));
            }
        }
        for j in joins {
            records.extend(j.join().unwrap());
        }
        for j in sess_joins {
            let (out, sess) = j.join().unwrap();
            records.extend(out);
            returned.push(sess);
        }
    });
    for sess in returned {
        server.close_session(sess);
    }

    // serial replay: batch-of-1 prefills with the server's own thread
    // budget must reproduce every concurrent response exactly
    assert_eq!(records.len(), 3 * 6 + 3 * 4);
    let mut ws = EngineWorkspace::new();
    for (i, (x, ts, got)) in records.iter().enumerate() {
        let opts = ForwardOptions::new().with_threads(4).with_timescale(*ts);
        let want = m.prefill(Batch::single(x, l, 2), &opts, &mut ws);
        assert_bits_equal(&want, got, &format!("record {i} (ts={ts})"));
    }
    // every batched request is accounted for (sessions bypass the queue)
    assert_eq!(
        server.stats.requests.load(std::sync::atomic::Ordering::Relaxed),
        18,
        "batched request count"
    );
}

/// Concurrent *chunked* prefills (L large enough that the Blelloch
/// three-phase scan actually engages) racing on one shared dedicated
/// pool must match their scoped-executor references bit-for-bit.
#[test]
fn concurrent_pooled_chunked_prefills_match_scoped_reference() {
    let pool = Arc::new(WorkerPool::new(3));
    let m = model(91, 2);
    // (threads, batch, l): chunked single-sequence scans and the B < T
    // branch with ⌊T/B⌋ ≥ 2 chunk-workers per sequence. The staged
    // pipeline is pinned explicitly: the fused (default) forward scans
    // tiles sequentially, and this test exists to race the Blelloch
    // chunk-combine on a shared pool.
    let configs = [(3usize, 1usize, 200usize), (8, 3, 64)];
    for &(t, batch, l) in &configs {
        let n_inputs = 6u64;
        // references computed serially with the scoped executor
        let refs: Vec<(Vec<f32>, Vec<f32>)> = (0..n_inputs)
            .map(|i| {
                let u = Rng::new(3000 + i).normal_vec_f32(batch * l * 2);
                let opts = ForwardOptions::new()
                    .with_exec(t, ScanExec::Scoped)
                    .with_tiling(Tiling::Staged);
                let mut ws = EngineWorkspace::new();
                let want = m.prefill(Batch::new(&u, batch, l, 2), &opts, &mut ws);
                (u, want)
            })
            .collect();
        std::thread::scope(|s| {
            for (u, want) in &refs {
                let pool = pool.clone();
                let m = &m;
                s.spawn(move || {
                    let opts = ForwardOptions::new()
                        .with_exec(t, ScanExec::Pool(pool))
                        .with_tiling(Tiling::Staged);
                    let mut ws = EngineWorkspace::new();
                    for round in 0..4 {
                        let got = m.prefill(Batch::new(u, batch, l, 2), &opts, &mut ws);
                        assert_bits_equal(
                            want,
                            &got,
                            &format!("t={t} B={batch} L={l} round {round}"),
                        );
                    }
                });
            }
        });
    }
    assert_eq!(pool.live_workers(), pool.workers(), "a pool worker died under load");
}

/// The lifecycle acceptance criterion: pooled execution performs zero
/// steady-state thread spawns. The pool's thread count is fixed at
/// construction and stays fixed across warmup and differently-shaped
/// batches; the default resolvers dispatch on a pool (never the scoped
/// spawn-per-call path); and the process-global pool is one shared
/// fixed-size instance.
#[test]
fn pooled_engine_spawns_no_steady_state_threads() {
    let pool = Arc::new(WorkerPool::new(3));
    let be = ParallelBackend::with_exec(4, ScanExec::Pool(pool.clone()));
    assert!(be.executor().is_pool(), "dedicated-pool backend must dispatch on the pool");
    let m = model(55, 2);
    let mut ws = EngineWorkspace::new();
    // warmup at the largest shape, then sweep smaller/larger L and B
    let mut rng = Rng::new(56);
    let u = rng.normal_vec_f32(5 * 100 * 2);
    let _ = m.forward_batch(&u[..5 * 100 * 2], 5, 100, 1.0, &be, &mut ws);
    assert_eq!(pool.workers(), 3);
    assert_eq!(pool.live_workers(), 3);
    for &(b, l) in &[(1usize, 64usize), (3, 40), (5, 12), (2, 100), (4, 7)] {
        let u = rng.normal_vec_f32(b * l * 2);
        let _ = m.forward_batch(&u, b, l, 1.0, &be, &mut ws);
        assert_eq!(pool.workers(), 3, "pool spawned at (B={b}, L={l})");
        assert_eq!(pool.live_workers(), 3, "pool lost a worker at (B={b}, L={l})");
    }
    // the default resolver is pooled (process-global pool), and the
    // global pool is one fixed-size shared instance
    assert!(backend_for_threads(4).executor().is_pool());
    let g = global_pool();
    let workers_before = g.workers();
    let u = rng.normal_vec_f32(3 * 50 * 2);
    let gbe = backend_for_threads(4);
    let _ = m.forward_batch(&u, 3, 50, 1.0, gbe.as_ref(), &mut ws);
    assert_eq!(global_pool().workers(), workers_before, "global pool grew");
    assert_eq!(global_pool().live_workers(), workers_before);
}

/// Shutdown drains cleanly: every issued request is answered, and
/// dropping the handle then the server joins the worker without hanging
/// (the drop order every caller of `handle()` observes).
#[test]
fn server_drains_cleanly_on_shutdown() {
    let l = 12usize;
    let m = model(13, 1);
    let server = NativeInferenceServer::start(
        m,
        l,
        ServerConfig {
            max_wait: Duration::from_millis(1),
            max_batch: 4,
            threads: 2,
            ..ServerConfig::default()
        },
    );
    let stats = server.stats.clone();
    let handle = server.handle();
    let mut rng = Rng::new(3);
    for _ in 0..10 {
        let x = rng.normal_vec_f32(l * 2);
        let resp = handle.infer(x).unwrap();
        assert_eq!(resp.logits.len(), 5);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    drop(handle);
    drop(server); // joins the worker — completing (not hanging) is the assertion
    assert_eq!(stats.requests.load(std::sync::atomic::Ordering::Relaxed), 10);
    assert!(stats.batches.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}
