//! Integration: the full train → eval → checkpoint → re-serve cycle
//! against real compiled artifacts (skipped when artifacts/ is absent).

use s5::coordinator::{TrainConfig, Trainer};
use s5::runtime::{Client, ParamStore};
use std::path::Path;

fn have(name: &str) -> bool {
    Path::new("artifacts").join(format!("{name}.hlo.txt")).exists()
}

fn quick_cfg(preset: &str) -> TrainConfig {
    let mut cfg = TrainConfig::for_preset(preset);
    cfg.steps = 6;
    cfg.train_pool = 24;
    cfg.eval_pool = 8;
    cfg.eval_every = 0;
    cfg.warmup_steps = 2;
    cfg
}

#[test]
fn classifier_train_step_decreases_loss_over_steps() {
    if !have("smnist_train") {
        return;
    }
    let client = Client::cpu().unwrap();
    let mut cfg = quick_cfg("smnist");
    cfg.steps = 20;
    let mut t = Trainer::new(&client, cfg).unwrap();
    let mut losses = Vec::new();
    for _ in 0..20 {
        let (loss, _) = t.train_step().unwrap();
        assert!(loss.is_finite());
        losses.push(loss);
    }
    let head: f64 = losses[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = losses[15..].iter().sum::<f64>() / 5.0;
    assert!(
        tail < head,
        "loss did not trend down: head {head:.4} tail {tail:.4} ({losses:?})"
    );
}

#[test]
fn evaluate_returns_sane_accuracy() {
    if !have("smnist_fwd") {
        return;
    }
    let client = Client::cpu().unwrap();
    let mut t = Trainer::new(&client, quick_cfg("smnist")).unwrap();
    let (loss, acc) = t.evaluate().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn checkpoint_roundtrip_preserves_params() {
    if !have("smnist_train") {
        return;
    }
    let client = Client::cpu().unwrap();
    let mut t = Trainer::new(&client, quick_cfg("smnist")).unwrap();
    t.train_step().unwrap();
    let dir = std::env::temp_dir().join(format!("s5_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.npz");
    t.save_checkpoint(&path).unwrap();
    let store = ParamStore::load_npz(&path).unwrap();
    assert_eq!(store.len(), t.params().len());
    assert!(store.names().all(|n| n.starts_with("params.")));
    // a trained parameter differs from the init npz
    let init =
        ParamStore::load_npz(Path::new("artifacts/smnist_init.npz")).unwrap();
    let name = "params.decoder.w";
    let a = s5::runtime::params::to_vec_f32(store.get(name).unwrap()).unwrap();
    let b = s5::runtime::params::to_vec_f32(init.get(name).unwrap()).unwrap();
    assert_ne!(a, b, "training must move the decoder weights");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pendulum_trainer_runs_and_regresses() {
    if !have("pendulum_train") {
        return;
    }
    let client = Client::cpu().unwrap();
    let mut cfg = quick_cfg("pendulum");
    cfg.eval_pool = 16;
    let mut t = Trainer::new(&client, cfg).unwrap();
    for _ in 0..4 {
        let (loss, mse) = t.train_step().unwrap();
        assert!(loss.is_finite() && mse >= 0.0);
    }
    let (mse, _) = t.evaluate().unwrap();
    // sin/cos targets are in [-1,1]: an untrained-but-sane model sits below
    // trivial variance bounds
    assert!(mse < 5.0, "pendulum eval MSE insane: {mse}");
}

#[test]
fn retrieval_trainer_runs() {
    if !have("retrieval_train") {
        return;
    }
    let client = Client::cpu().unwrap();
    let mut cfg = quick_cfg("retrieval");
    cfg.eval_pool = 8;
    let mut t = Trainer::new(&client, cfg).unwrap();
    let (loss, acc) = t.train_step().unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn timescale_changes_eval_output() {
    if !have("smnist_fwd") {
        return;
    }
    let client = Client::cpu().unwrap();
    let mut t = Trainer::new(&client, quick_cfg("smnist")).unwrap();
    let (l1, _) = t.evaluate_with_timescale(1.0).unwrap();
    let (l2, _) = t.evaluate_with_timescale(4.0).unwrap();
    assert!((l1 - l2).abs() > 1e-9, "timescale input had no effect");
}
