//! Cross-backend / cross-executor equivalence matrix (the pin that lets
//! scheduling changes land without numeric drift).
//!
//! For every kernel variant — sequential/parallel strategy × TI/TV
//! multipliers × single/batched/step entry points × planar/interleaved
//! layout — and a shape sweep that includes every degenerate case the
//! chunking can produce (L = 0, P = 0, B = 0, L < threads, remainder
//! chunks), the matrix asserts that the **executor never changes a
//! bit**: pooled (dedicated pool and the process-global pool), scoped
//! spawn-per-call threads, and single-threaded inline execution of the
//! same chunked decomposition all produce identical results. The pool is
//! deliberately sized differently from every thread budget under test so
//! oversubscription and under-subscription are both exercised.
//!
//! A second layer of tests pins the same invariance end-to-end through
//! the engine: full S5 forwards (planar + interleaved, TI + irregular-Δt,
//! uni- and bidirectional) and the generic `SequenceModel::prefill`
//! surface are bit-for-bit executor-invariant, and a `ParallelBackend`
//! clamped to one thread equals the `SequentialBackend` exactly.

use std::sync::Arc;

use s5::num::C32;
use s5::rng::Rng;
use s5::runtime::pool::WorkerPool;
use s5::ssm::api::{Batch, ForwardOptions, SequenceModel};
use s5::ssm::dtype::Dtype;
use s5::ssm::engine::EngineWorkspace;
use s5::ssm::s5::{S5Config, S5Model};
use s5::ssm::scan::{
    backend_for_exec, backend_for_threads, ParallelBackend, ScanBackend, ScanExec, ScanLayout,
    ScanScratch, SequentialBackend,
};

/// (batch, l, p) shapes: degenerate, boundary and regular. With thread
/// budgets {2, 3, 8} these hit L = 0, P = 0, B = 0, L < threads,
/// single-row chunks and non-divisible remainder chunks.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 0, 3),  // empty sequence
    (1, 5, 0),  // empty state
    (0, 4, 3),  // empty batch
    (1, 1, 4),  // single step
    (3, 2, 3),  // L < every thread budget
    (1, 9, 3),  // non-divisible remainder
    (2, 7, 2),  // remainder chunk shorter than the rest
    (1, 64, 5), // chunked single sequence
    (5, 33, 4), // B > some budgets, < others
    (3, 40, 6), // B < budgets with chunked per-sequence scans
];

const THREADS: &[usize] = &[1, 2, 3, 8];

fn rand_c32(g: &mut Rng, n: usize, scale: f32) -> Vec<C32> {
    (0..n)
        .map(|_| C32::new(g.normal() as f32 * scale, g.normal() as f32 * scale))
        .collect()
}

fn planes(z: &[C32]) -> (Vec<f32>, Vec<f32>) {
    (z.iter().map(|v| v.re).collect(), z.iter().map(|v| v.im).collect())
}

/// One deterministic input set for a (batch, l, p) shape.
struct Case {
    /// TI multipliers (p)
    a_ti: Vec<C32>,
    /// single-sequence TV multipliers (l·p)
    a_tv1: Vec<C32>,
    /// single-sequence drive (l·p)
    b1: Vec<C32>,
    /// batched TV multipliers (batch·l·p)
    a_tv: Vec<C32>,
    /// batched drive (batch·l·p)
    b: Vec<C32>,
}

impl Case {
    fn generate(seed: u64, batch: usize, l: usize, p: usize) -> Case {
        let mut g = Rng::new(seed);
        Case {
            a_ti: rand_c32(&mut g, p, 0.6),
            a_tv1: rand_c32(&mut g, l * p, 0.6),
            b1: rand_c32(&mut g, l * p, 1.0),
            a_tv: rand_c32(&mut g, batch * l * p, 0.6),
            b: rand_c32(&mut g, batch * l * p, 1.0),
        }
    }
}

/// The executor axis for a fixed thread budget: scoped is the reference,
/// the rest must match it bit-for-bit. The dedicated pool has 3 workers —
/// none of the budgets under test — so shard counts and worker counts
/// disagree in both directions.
fn backends(t: usize, pool: &Arc<WorkerPool>) -> Vec<(&'static str, ParallelBackend)> {
    vec![
        ("scoped", ParallelBackend::with_exec(t, ScanExec::Scoped)),
        ("pooled", ParallelBackend::with_exec(t, ScanExec::Pool(pool.clone()))),
        ("inline", ParallelBackend::with_exec(t, ScanExec::Inline)),
        ("global", ParallelBackend::new(t)),
    ]
}

/// A kernel runner: execute one entry-point variant under a backend and
/// return a canonical f32 flattening of the states.
type Runner = fn(&dyn ScanBackend, &Case, usize, usize, usize) -> Vec<f32>;

fn flat(z: &[C32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(2 * z.len());
    for v in z {
        out.push(v.re);
        out.push(v.im);
    }
    out
}

fn run_ti_single(be: &dyn ScanBackend, c: &Case, _b: usize, l: usize, p: usize) -> Vec<f32> {
    let mut scratch = ScanScratch::new();
    let mut buf = c.b1.clone();
    be.scan_ti(&c.a_ti, &mut buf, l, p, &mut scratch);
    flat(&buf)
}

fn run_tv_single(be: &dyn ScanBackend, c: &Case, _b: usize, l: usize, p: usize) -> Vec<f32> {
    let mut scratch = ScanScratch::new();
    let mut buf = c.b1.clone();
    be.scan_tv(&c.a_tv1, &mut buf, l, p, &mut scratch);
    flat(&buf)
}

fn run_ti_batch(be: &dyn ScanBackend, c: &Case, b: usize, l: usize, p: usize) -> Vec<f32> {
    let mut scratch = ScanScratch::new();
    let mut buf = c.b.clone();
    be.scan_batch_ti(&c.a_ti, &mut buf, b, l, p, &mut scratch);
    flat(&buf)
}

fn run_tv_batch(be: &dyn ScanBackend, c: &Case, b: usize, l: usize, p: usize) -> Vec<f32> {
    let mut scratch = ScanScratch::new();
    let mut buf = c.b.clone();
    be.scan_batch_tv(&c.a_tv, &mut buf, b, l, p, &mut scratch);
    flat(&buf)
}

fn run_ti_single_planar(be: &dyn ScanBackend, c: &Case, _b: usize, l: usize, p: usize) -> Vec<f32> {
    let mut scratch = ScanScratch::new();
    let (ar, ai) = planes(&c.a_ti);
    let (mut xr, mut xi) = planes(&c.b1);
    be.scan_ti_planar(&ar, &ai, &mut xr, &mut xi, l, p, &mut scratch);
    xr.extend_from_slice(&xi);
    xr
}

fn run_tv_single_planar(be: &dyn ScanBackend, c: &Case, _b: usize, l: usize, p: usize) -> Vec<f32> {
    let mut scratch = ScanScratch::new();
    let (ar, ai) = planes(&c.a_tv1);
    let (mut xr, mut xi) = planes(&c.b1);
    be.scan_tv_planar(&ar, &ai, &mut xr, &mut xi, l, p, &mut scratch);
    xr.extend_from_slice(&xi);
    xr
}

fn run_ti_batch_planar(be: &dyn ScanBackend, c: &Case, b: usize, l: usize, p: usize) -> Vec<f32> {
    let mut scratch = ScanScratch::new();
    let (ar, ai) = planes(&c.a_ti);
    let (mut xr, mut xi) = planes(&c.b);
    be.scan_batch_ti_planar(&ar, &ai, &mut xr, &mut xi, b, l, p, &mut scratch);
    xr.extend_from_slice(&xi);
    xr
}

fn run_tv_batch_planar(be: &dyn ScanBackend, c: &Case, b: usize, l: usize, p: usize) -> Vec<f32> {
    let mut scratch = ScanScratch::new();
    let (ar, ai) = planes(&c.a_tv);
    let (mut xr, mut xi) = planes(&c.b);
    be.scan_batch_tv_planar(&ar, &ai, &mut xr, &mut xi, b, l, p, &mut scratch);
    xr.extend_from_slice(&xi);
    xr
}

/// Streaming-step replay over the single sequence (interleaved step).
fn run_step(be: &dyn ScanBackend, c: &Case, _b: usize, l: usize, p: usize) -> Vec<f32> {
    let mut state = vec![C32::ZERO; p];
    let mut out = Vec::with_capacity(2 * l * p);
    for k in 0..l {
        be.scan_step(&c.a_ti, &mut state, &c.b1[k * p..(k + 1) * p]);
        out.extend(flat(&state));
    }
    out
}

/// Streaming-step replay over the single sequence (planar step).
fn run_step_planar(be: &dyn ScanBackend, c: &Case, _b: usize, l: usize, p: usize) -> Vec<f32> {
    let (ar, ai) = planes(&c.a_ti);
    let (br, bi) = planes(&c.b1);
    let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
    let mut out = Vec::with_capacity(2 * l * p);
    for k in 0..l {
        let row = k * p;
        be.scan_step_planar(&ar, &ai, &mut sr, &mut si, &br[row..row + p], &bi[row..row + p]);
        out.extend_from_slice(&sr);
        out.extend_from_slice(&si);
    }
    out
}

fn bits_equal(a: &[f32], b: &[f32]) -> Option<usize> {
    if a.len() != b.len() {
        return Some(usize::MAX);
    }
    a.iter().zip(b.iter()).position(|(x, y)| x.to_bits() != y.to_bits())
}

/// Run one kernel variant across the full (threads × executors × shapes)
/// grid, asserting bit-equality against the scoped reference — and, at a
/// thread budget of 1, against the `SequentialBackend` too.
fn check_matrix(run: Runner, name: &str) {
    let pool = Arc::new(WorkerPool::new(3));
    for (si, &(batch, l, p)) in SHAPES.iter().enumerate() {
        let case = Case::generate(0xC0FFEE + si as u64, batch, l, p);
        for &t in THREADS {
            let mut reference: Option<Vec<f32>> = None;
            for (ename, be) in backends(t, &pool) {
                let got = run(&be, &case, batch, l, p);
                match &reference {
                    None => reference = Some(got),
                    Some(want) => {
                        if let Some(i) = bits_equal(want, &got) {
                            panic!(
                                "{name}: executor {ename} diverged from scoped at \
                                 t={t} shape=(B={batch}, L={l}, P={p}) index {i}"
                            );
                        }
                    }
                }
            }
            if t == 1 {
                // a one-thread parallel strategy must equal the
                // sequential backend exactly, whatever the executor
                let want = reference.unwrap();
                let got = run(&SequentialBackend, &case, batch, l, p);
                if let Some(i) = bits_equal(&want, &got) {
                    panic!(
                        "{name}: ParallelBackend(1) != SequentialBackend at \
                         shape=(B={batch}, L={l}, P={p}) index {i}"
                    );
                }
            }
        }
    }
}

macro_rules! matrix {
    ($($test:ident => $runner:ident),+ $(,)?) => {
        $(
            #[test]
            fn $test() {
                check_matrix($runner, stringify!($runner));
            }
        )+
    };
}

matrix! {
    ti_single_interleaved_is_executor_invariant => run_ti_single,
    tv_single_interleaved_is_executor_invariant => run_tv_single,
    ti_batch_interleaved_is_executor_invariant => run_ti_batch,
    tv_batch_interleaved_is_executor_invariant => run_tv_batch,
    ti_single_planar_is_executor_invariant => run_ti_single_planar,
    tv_single_planar_is_executor_invariant => run_tv_single_planar,
    ti_batch_planar_is_executor_invariant => run_ti_batch_planar,
    tv_batch_planar_is_executor_invariant => run_tv_batch_planar,
    step_interleaved_is_executor_invariant => run_step,
    step_planar_is_executor_invariant => run_step_planar,
}

// ---------------------------------------------------------------------------
// End-to-end: the engine hot path is executor-invariant too
// ---------------------------------------------------------------------------

/// Full S5 forwards — uni/bidirectional, TI and irregular-Δt, planar and
/// interleaved — are bit-for-bit identical across executors.
#[test]
fn model_forward_is_executor_invariant() {
    let pool = Arc::new(WorkerPool::new(3));
    let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
    let model = S5Model::init(2, 5, 2, &cfg, &mut Rng::new(7));
    let (batch, l) = (3usize, 40usize);
    let mut g = Rng::new(8);
    let u = g.normal_vec_f32(batch * l * 2);
    for &t in &[2usize, 3] {
        for layout in [ScanLayout::Planar, ScanLayout::Interleaved] {
            let execs: Vec<(&'static str, Box<dyn ScanBackend>)> = vec![
                ("scoped", backend_for_exec(t, layout, ScanExec::Scoped)),
                ("pooled", backend_for_exec(t, layout, ScanExec::Pool(pool.clone()))),
                ("inline", backend_for_exec(t, layout, ScanExec::Inline)),
                ("global", backend_for_exec(t, layout, ScanExec::Pooled)),
            ];
            let mut reference: Option<Vec<f32>> = None;
            for (ename, be) in &execs {
                let mut ws = EngineWorkspace::new();
                let got = model.forward_batch(&u, batch, l, 1.0, be.as_ref(), &mut ws);
                match &reference {
                    None => reference = Some(got),
                    Some(want) => {
                        if let Some(i) = bits_equal(want, &got) {
                            panic!("model: {ename} diverged (t={t}, {layout:?}) at {i}");
                        }
                    }
                }
            }
        }
    }
}

/// The irregular-Δt (TV) layer path and a bidirectional layer are
/// executor-invariant as well.
#[test]
fn layer_tv_and_bidir_are_executor_invariant() {
    use s5::ssm::s5::S5Layer;
    let pool = Arc::new(WorkerPool::new(3));
    let mut g = Rng::new(21);
    let (batch, l) = (3usize, 36usize);
    let uni =
        S5Layer::init(&S5Config { h: 4, p: 8, j: 1, ..Default::default() }, &mut Rng::new(1));
    let bidir = S5Layer::init(
        &S5Config { h: 4, p: 8, j: 1, bidir: true, ..Default::default() },
        &mut Rng::new(2),
    );
    let u = g.normal_vec_f32(batch * l * 4);
    let dts: Vec<f32> = (0..batch * l).map(|_| g.uniform_in(0.3, 2.5) as f32).collect();
    for &t in &[2usize, 3] {
        let execs: Vec<(&'static str, Box<dyn ScanBackend>)> = vec![
            ("scoped", backend_for_exec(t, ScanLayout::Planar, ScanExec::Scoped)),
            ("pooled", backend_for_exec(t, ScanLayout::Planar, ScanExec::Pool(pool.clone()))),
            ("inline", backend_for_exec(t, ScanLayout::Planar, ScanExec::Inline)),
        ];
        let mut want_tv: Option<Vec<f32>> = None;
        let mut want_bi: Option<Vec<f32>> = None;
        for (ename, be) in &execs {
            let mut ws = EngineWorkspace::new();
            let tv = uni.apply_ssm_batch(&u, batch, l, 1.0, Some(&dts), be.as_ref(), &mut ws);
            let bi = bidir.apply_batch(&u, batch, l, 1.0, None, be.as_ref(), &mut ws);
            match &want_tv {
                None => want_tv = Some(tv),
                Some(want) => {
                    if let Some(i) = bits_equal(want, &tv) {
                        panic!("TV layer: {ename} diverged (t={t}) at {i}");
                    }
                }
            }
            match &want_bi {
                None => want_bi = Some(bi),
                Some(want) => {
                    if let Some(i) = bits_equal(want, &bi) {
                        panic!("bidir layer: {ename} diverged (t={t}) at {i}");
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fused cache-blocked (tiled) forward ≡ staged reference
// ---------------------------------------------------------------------------

/// The fused tile pipeline scans each tile sequentially (parallelism
/// comes from sharding sequence × direction pipelines), so whatever the
/// tile size, thread budget or executor, its output must equal the
/// staged planar pipeline over the **sequential** scan strategy exactly —
/// layer level, uni- and bidirectional, TI and irregular-Δt, batched.
/// This is the pin that lets tile-size heuristics change freely (and
/// what the CI `S5_TILE_L` sweep drives through `Tiling::Auto`).
#[test]
fn fused_tiled_matches_staged_sequential_bit_for_bit() {
    use s5::ssm::engine::Tiling;
    use s5::ssm::s5::{S5Config, S5Layer};
    let pool = Arc::new(WorkerPool::new(3));
    let mut g = Rng::new(0xF05E);
    for &bidir in &[false, true] {
        let layer = S5Layer::init(
            &S5Config { h: 6, p: 8, j: 1, bidir, ..Default::default() },
            &mut Rng::new(3),
        );
        for &(batch, l) in &[(1usize, 1usize), (1, 7), (2, 33), (3, 40)] {
            let u: Vec<f32> = (0..batch * l * 6).map(|_| g.normal() as f32).collect();
            let dts: Vec<f32> =
                (0..batch * l).map(|_| g.uniform_in(0.3, 2.5) as f32).collect();
            let staged = ForwardOptions::new().with_tiling(Tiling::Staged);
            let mut ws = EngineWorkspace::new();
            let want = layer.apply_batch_opts(&u, batch, l, None, &staged, &mut ws);
            // TV covered in both directions: the backward scan reverses
            // the Δt multipliers with the drive (fixture-pinned semantics)
            // and stays bit-exact across tilings.
            let want_tv =
                Some(layer.apply_ssm_batch_opts(&u, batch, l, Some(&dts), &staged, &mut ws));
            for &tile in &[1usize, 3, 8, l, l + 7, 4096] {
                for &t in &[1usize, 3, 8] {
                    for exec in
                        [ScanExec::Scoped, ScanExec::Pool(pool.clone()), ScanExec::Inline]
                    {
                        let ename = format!("{exec:?}");
                        let fused = ForwardOptions::new()
                            .with_exec(t, exec)
                            .with_tile(tile);
                        let mut wsf = EngineWorkspace::new();
                        let got = layer.apply_batch_opts(&u, batch, l, None, &fused, &mut wsf);
                        if let Some(i) = bits_equal(&want, &got) {
                            panic!(
                                "fused layer bidir={bidir} B={batch} L={l} tile={tile} \
                                 t={t} exec={ename}: diverged from staged sequential at {i}"
                            );
                        }
                        if let Some(want_tv) = &want_tv {
                            let got = layer.apply_ssm_batch_opts(
                                &u,
                                batch,
                                l,
                                Some(&dts),
                                &fused,
                                &mut wsf,
                            );
                            if let Some(i) = bits_equal(want_tv, &got) {
                                panic!(
                                    "fused TV B={batch} L={l} tile={tile} t={t} \
                                     exec={ename}: diverged at {i}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Model level, through the typed prefill surface: the default (Auto)
/// fused pipeline — whatever tile `S5_TILE_L` injects — equals the
/// staged sequential reference bit-for-bit, and the staged parallel
/// strategy stays within the documented chunk-combine tolerance.
#[test]
fn fused_auto_prefill_matches_staged_reference() {
    use s5::ssm::engine::Tiling;
    let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
    let model = S5Model::init(2, 5, 2, &cfg, &mut Rng::new(41));
    let (batch, l) = (3usize, 52usize);
    let u = Rng::new(42).normal_vec_f32(batch * l * 2);
    let view = Batch::new(&u, batch, l, 2);
    let mut ws_a = EngineWorkspace::new();
    let mut ws_b = EngineWorkspace::new();
    let mut ws_c = EngineWorkspace::new();
    let want = model.prefill(view, &ForwardOptions::new().with_tiling(Tiling::Staged), &mut ws_a);
    for t in [1usize, 4] {
        let got = model.prefill(view, &ForwardOptions::new().with_threads(t), &mut ws_b);
        if let Some(i) = bits_equal(&want, &got) {
            panic!("fused auto prefill (t={t}) diverged from staged sequential at {i}");
        }
    }
    // staged parallel: equal within the documented 1e-4 combine tolerance
    let par = model.prefill(
        view,
        &ForwardOptions::new().with_threads(4).with_tiling(Tiling::Staged),
        &mut ws_c,
    );
    for (i, (a, b)) in want.iter().zip(par.iter()).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4 * (1.0 + a.abs().max(b.abs())),
            "staged parallel drifted past tolerance at {i}: {a} vs {b}"
        );
    }
}

// ---------------------------------------------------------------------------
// In-tile wide path (single-stream): tolerance-pinned vs the staged oracle
// ---------------------------------------------------------------------------

/// Max relative divergence gate for the wide path: the seeded
/// chunked-parallel tile scan reassociates the carry, so wide results are
/// tolerance-equal to the sequential reference, never bit-equal.
fn assert_rel_close(want: &[f32], got: &[f32], tol: f32, what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length mismatch");
    for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
        let denom = w.abs().max(g.abs()).max(1.0);
        assert!(
            (w - g).abs() <= tol * denom,
            "{what}: drifted past tol={tol:e} at {i}: want {w} got {g}"
        );
    }
}

/// The opt-in wide fused path ([`ForwardOptions::with_wide`]) on a
/// single stream (B = 1, fewer pipelines than workers): within the
/// documented 1e-4 relative tolerance of the staged **sequential**
/// oracle for every tile × thread budget, bit-for-bit identical across
/// executors at a fixed budget (the in-tile chunking is fixed by the
/// budget, never the executor), and exactly equal to the sequential
/// fused path when the budget leaves no leftover workers (t = 1, or
/// bidirectional t = 2).
#[test]
fn fused_wide_single_stream_tracks_staged_sequential() {
    use s5::ssm::engine::Tiling;
    use s5::ssm::s5::{S5Config, S5Layer};
    let pool = Arc::new(WorkerPool::new(3));
    let mut g = Rng::new(0x51DE);
    for &bidir in &[false, true] {
        let layer = S5Layer::init(
            &S5Config { h: 6, p: 8, j: 1, bidir, ..Default::default() },
            &mut Rng::new(9),
        );
        for &l in &[33usize, 129] {
            let u: Vec<f32> = (0..l * 6).map(|_| g.normal() as f32).collect();
            let dts: Vec<f32> = (0..l).map(|_| g.uniform_in(0.3, 2.5) as f32).collect();
            // pinned f32: the wide path's 1e-4 gate is the f32 carry
            // reassociation story (bf16 wide is budget-gated separately)
            let staged =
                ForwardOptions::new().with_dtype(Dtype::F32).with_tiling(Tiling::Staged);
            let mut ws = EngineWorkspace::new();
            let want = layer.apply_batch_opts(&u, 1, l, None, &staged, &mut ws);
            // bidirectional TV included: the backward scan reverses the Δt
            // multipliers with the drive, so the wide gates apply there too
            let want_tv =
                Some(layer.apply_ssm_batch_opts(&u, 1, l, Some(&dts), &staged, &mut ws));
            for &tile in &[1usize, 5, 64, l + 7] {
                for &t in &[1usize, 2, 8] {
                    let mut reference: Option<(Vec<f32>, Option<Vec<f32>>)> = None;
                    for exec in
                        [ScanExec::Scoped, ScanExec::Pool(pool.clone()), ScanExec::Inline]
                    {
                        let ename = format!("{exec:?}");
                        let tag = format!(
                            "wide bidir={bidir} L={l} tile={tile} t={t} exec={ename}"
                        );
                        let wide = ForwardOptions::new()
                            .with_dtype(Dtype::F32)
                            .with_wide()
                            .with_exec(t, exec)
                            .with_tile(tile);
                        let mut wsf = EngineWorkspace::new();
                        let got = layer.apply_batch_opts(&u, 1, l, None, &wide, &mut wsf);
                        assert_rel_close(&want, &got, 1e-4, &tag);
                        let got_tv = want_tv.as_ref().map(|want_tv| {
                            let got_tv = layer.apply_ssm_batch_opts(
                                &u,
                                1,
                                l,
                                Some(&dts),
                                &wide,
                                &mut wsf,
                            );
                            assert_rel_close(want_tv, &got_tv, 1e-4, &format!("{tag} TV"));
                            got_tv
                        });
                        // inactive split (no leftover workers) = exactly
                        // the sequential fused path = the staged oracle
                        let n_units = if bidir { 2 } else { 1 };
                        if t <= n_units {
                            if let Some(i) = bits_equal(&want, &got) {
                                panic!("{tag}: inactive wide split must be bitwise at {i}");
                            }
                        }
                        // executor invariance at a fixed budget is bitwise
                        match &reference {
                            None => reference = Some((got, got_tv)),
                            Some((w, w_tv)) => {
                                if let Some(i) = bits_equal(w, &got) {
                                    panic!("{tag}: executor changed wide bits at {i}");
                                }
                                if let (Some(w_tv), Some(got_tv)) = (w_tv, &got_tv) {
                                    if let Some(i) = bits_equal(w_tv, got_tv) {
                                        panic!("{tag}: executor changed TV wide bits at {i}");
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Long-L (64k) drift gate for the wide path, against the f64-carry
/// reference (the PR-5 drift harness): going wide may not add more than
/// a small multiple of the drift the sequential f32 path already
/// accumulates, and must stay within 1e-3 of that sequential f32 path
/// outright. Runs identically under `--features simd` and
/// `--no-default-features`, so it doubles as the lane-kernel tolerance
/// suite at depth (bit-exactness of simd-vs-scalar is pinned separately
/// in the `ssm::simd` unit tests).
#[test]
fn fused_wide_long_l_stays_within_drift_tolerance() {
    use s5::ssm::s5::{S5Config, S5Layer};
    let layer =
        S5Layer::init(&S5Config { h: 2, p: 4, j: 1, ..Default::default() }, &mut Rng::new(11));
    let l = 65536usize;
    let u = Rng::new(12).normal_vec_f32(l * 2);
    let mut ws = EngineWorkspace::new();
    let want64 = layer.apply_batch_opts(
        &u,
        1,
        l,
        None,
        &ForwardOptions::new().with_f64_state(),
        &mut ws,
    );
    // pinned f32 (this gate is the f32 story; bf16 has its own budget)
    let seq32 = layer.apply_batch_opts(
        &u,
        1,
        l,
        None,
        &ForwardOptions::new().with_dtype(Dtype::F32),
        &mut ws,
    );
    let wide32 = layer.apply_batch_opts(
        &u,
        1,
        l,
        None,
        &ForwardOptions::new().with_dtype(Dtype::F32).with_wide().with_exec(8, ScanExec::Scoped),
        &mut ws,
    );
    assert_rel_close(&seq32, &wide32, 1e-3, "wide vs sequential f32 at L=64k");
    let rel_err = |a: &[f32], b: &[f32]| {
        a.iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs() / x.abs().max(y.abs()).max(1.0))
            .fold(0.0f32, f32::max)
    };
    let err_seq = rel_err(&want64, &seq32);
    let err_wide = rel_err(&want64, &wide32);
    assert!(
        err_wide <= 4.0 * err_seq + 1e-4,
        "wide drift {err_wide:e} not comparable to sequential f32 drift {err_seq:e}"
    );
    // wide is documented as ignored under the f64 carry: bit-for-bit
    let w64 = layer.apply_batch_opts(
        &u,
        1,
        l,
        None,
        &ForwardOptions::new().with_f64_state().with_wide().with_exec(8, ScanExec::Scoped),
        &mut ws,
    );
    // f64 carries are thread-invariant, so only the executor-side shard
    // count differs — results must match the 1-thread f64 run exactly
    if let Some(i) = bits_equal(&want64, &w64) {
        panic!("wide + f64_state must leave the f64 result untouched (diverged at {i})");
    }
}

// ---------------------------------------------------------------------------
// bf16 storage: per-dtype invariance and the long-L drift budget
// ---------------------------------------------------------------------------

/// bf16 drive-plane storage keeps the fused pipeline's invariance story
/// *within the dtype*: the scan carry stays f32 across tiles and every
/// bf16 value is exactly one narrow-store/widen-load pair at fixed
/// pipeline points, so the result is identical for every tile size,
/// thread budget and executor — including `Tiling::Staged`, which bf16
/// runs as a single fused tile.
#[test]
fn fused_bf16_is_tile_thread_and_executor_invariant() {
    use s5::ssm::engine::Tiling;
    use s5::ssm::s5::S5Layer;
    let pool = Arc::new(WorkerPool::new(3));
    let mut g = Rng::new(0xBF16);
    for &bidir in &[false, true] {
        let layer = S5Layer::init(
            &S5Config { h: 6, p: 8, j: 1, bidir, ..Default::default() },
            &mut Rng::new(5),
        );
        for &(batch, l) in &[(1usize, 7usize), (2, 33), (3, 40)] {
            let u: Vec<f32> = (0..batch * l * 6).map(|_| g.normal() as f32).collect();
            let dts: Vec<f32> =
                (0..batch * l).map(|_| g.uniform_in(0.3, 2.5) as f32).collect();
            let staged =
                ForwardOptions::new().with_dtype(Dtype::Bf16).with_tiling(Tiling::Staged);
            let mut ws = EngineWorkspace::new();
            let want = layer.apply_batch_opts(&u, batch, l, None, &staged, &mut ws);
            // sanity: the narrowed planes really took effect — the bf16
            // output differs bitwise from the f32 pipeline at these shapes
            let f32_out = layer.apply_batch_opts(
                &u,
                batch,
                l,
                None,
                &ForwardOptions::new().with_dtype(Dtype::F32),
                &mut ws,
            );
            assert!(
                bits_equal(&want, &f32_out).is_some(),
                "bf16 silently ran f32 (bidir={bidir} B={batch} L={l})"
            );
            // bidirectional TV included (reversed-Δt backward multipliers)
            let want_tv =
                Some(layer.apply_ssm_batch_opts(&u, batch, l, Some(&dts), &staged, &mut ws));
            for &tile in &[1usize, 3, 8, l + 7] {
                for &t in &[1usize, 3] {
                    for exec in
                        [ScanExec::Scoped, ScanExec::Pool(pool.clone()), ScanExec::Inline]
                    {
                        let ename = format!("{exec:?}");
                        let fused = ForwardOptions::new()
                            .with_dtype(Dtype::Bf16)
                            .with_exec(t, exec)
                            .with_tile(tile);
                        let mut wsf = EngineWorkspace::new();
                        let got = layer.apply_batch_opts(&u, batch, l, None, &fused, &mut wsf);
                        if let Some(i) = bits_equal(&want, &got) {
                            panic!(
                                "bf16 fused bidir={bidir} B={batch} L={l} tile={tile} \
                                 t={t} exec={ename}: diverged from staged bf16 at {i}"
                            );
                        }
                        if let Some(want_tv) = &want_tv {
                            let got = layer.apply_ssm_batch_opts(
                                &u,
                                batch,
                                l,
                                Some(&dts),
                                &fused,
                                &mut wsf,
                            );
                            if let Some(i) = bits_equal(want_tv, &got) {
                                panic!(
                                    "bf16 fused TV B={batch} L={l} tile={tile} t={t} \
                                     exec={ename}: diverged at {i}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

/// The bf16 drift budget at depth (the acceptance gate): a bf16 fused
/// forward at L = 64k stays within 0.05 relative of the f64-carry
/// oracle, on the batched path, on the opt-in wide path, and through a
/// streaming session's chunked prefill (the bf16 storage rounding enters
/// at fixed narrow-store points while all accumulation stays f32, so the
/// error does not compound with depth).
#[test]
fn fused_bf16_long_l_drift_within_budget() {
    use s5::ssm::s5::S5Layer;
    let layer =
        S5Layer::init(&S5Config { h: 2, p: 4, j: 1, ..Default::default() }, &mut Rng::new(11));
    let l = 65536usize;
    let u = Rng::new(12).normal_vec_f32(l * 2);
    let mut ws = EngineWorkspace::new();
    let want64 = layer.apply_batch_opts(
        &u,
        1,
        l,
        None,
        &ForwardOptions::new().with_f64_state(),
        &mut ws,
    );
    let bf = layer.apply_batch_opts(
        &u,
        1,
        l,
        None,
        &ForwardOptions::new().with_dtype(Dtype::Bf16),
        &mut ws,
    );
    assert_rel_close(&want64, &bf, 0.05, "bf16 fused vs f64 oracle at L=64k");
    // wide bf16: the seeded chunked tile scan reassociates the carry on
    // top of the storage rounding — still within the same budget
    let bf_wide = layer.apply_batch_opts(
        &u,
        1,
        l,
        None,
        &ForwardOptions::new()
            .with_dtype(Dtype::Bf16)
            .with_wide()
            .with_exec(8, ScanExec::Scoped),
        &mut ws,
    );
    assert_rel_close(&want64, &bf_wide, 0.05, "wide bf16 vs f64 oracle at L=64k");
    // streaming at depth: a bf16 session prefill (the chunked push path)
    // tracks the f64-state batched oracle within the same budget
    let cfg = S5Config { h: 4, p: 4, j: 1, ..Default::default() };
    let model = S5Model::init(2, 3, 1, &cfg, &mut Rng::new(21));
    let toks = Rng::new(22).normal_vec_f32(l * 2);
    let mut ws2 = EngineWorkspace::new();
    let want = model.prefill(
        Batch::single(&toks, l, 2),
        &ForwardOptions::new().with_f64_state(),
        &mut ws2,
    );
    let model: Arc<dyn SequenceModel> = Arc::new(model);
    let mut sess =
        s5::ssm::api::Session::new(model, ForwardOptions::new().with_dtype(Dtype::Bf16));
    let got = sess.prefill(&toks, l);
    assert_rel_close(&want, &got, 0.05, "bf16 streaming prefill vs f64 oracle at L=64k");
}

/// The typed `SequenceModel::prefill` surface with pooled options equals
/// the scoped-option run bit-for-bit (what the server actually calls).
#[test]
fn prefill_api_is_executor_invariant() {
    let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
    let model = S5Model::init(2, 5, 2, &cfg, &mut Rng::new(31));
    let (batch, l) = (4usize, 48usize);
    let u = Rng::new(32).normal_vec_f32(batch * l * 2);
    let view = Batch::new(&u, batch, l, 2);
    let mut ws_a = EngineWorkspace::new();
    let mut ws_b = EngineWorkspace::new();
    let pooled = model.prefill(view, &ForwardOptions::new().with_threads(3), &mut ws_a);
    let scoped = model.prefill(
        view,
        &ForwardOptions::new().with_exec(3, ScanExec::Scoped),
        &mut ws_b,
    );
    if let Some(i) = bits_equal(&pooled, &scoped) {
        panic!("prefill: pooled != scoped at {i}");
    }
    // and the default resolver really is pooled
    assert!(backend_for_threads(3).executor().is_pool());
}
