//! Integration: the inference server against a real compiled artifact —
//! batching, concurrency, error propagation.

use s5::coordinator::server::{InferenceServer, ServerConfig};
use s5::data::make_task;
use s5::rng::Rng;
use std::path::Path;
use std::time::Duration;

fn have(name: &str) -> bool {
    Path::new("artifacts").join(format!("{name}.hlo.txt")).exists()
}

fn start(preset: &str, max_wait_ms: u64) -> InferenceServer {
    InferenceServer::start(
        Path::new("artifacts"),
        preset,
        None,
        ServerConfig { max_wait: Duration::from_millis(max_wait_ms), ..Default::default() },
    )
    .unwrap()
}

#[test]
fn single_request_roundtrip() {
    if !have("smnist_fwd") {
        return;
    }
    let server = start("smnist", 1);
    let task = make_task("smnist").unwrap();
    let ex = task.sample(&mut Rng::new(0));
    let resp = server.handle().infer(ex.x).unwrap();
    assert_eq!(resp.logits.len(), 10);
    assert!(resp.logits.iter().all(|v| v.is_finite()));
    assert!(resp.batched_with >= 1);
}

#[test]
fn concurrent_requests_are_batched() {
    if !have("smnist_fwd") {
        return;
    }
    let server = start("smnist", 50);
    let handle = server.handle();
    let task = make_task("smnist").unwrap();
    let fills: Vec<usize> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..16)
            .map(|i| {
                let h = handle.clone();
                let task = &task;
                s.spawn(move || {
                    let ex = task.sample(&mut Rng::new(i));
                    h.infer(ex.x).unwrap().batched_with
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    // with a 50ms window and 16 concurrent clients, at least one executed
    // batch must have coalesced multiple requests
    assert!(
        fills.iter().any(|&f| f > 1),
        "no batching observed: fills {fills:?}"
    );
    assert!(server.stats.mean_batch_fill() > 1.0);
}

#[test]
fn wrong_width_rejected_immediately() {
    if !have("smnist_fwd") {
        return;
    }
    let server = start("smnist", 1);
    let err = server.handle().infer(vec![0.0; 3]).unwrap_err();
    assert!(format!("{err}").contains("width"), "{err}");
}

#[test]
fn different_timescales_do_not_share_a_batch() {
    if !have("smnist_fwd") {
        return;
    }
    let server = start("smnist", 30);
    let handle = server.handle();
    let task = make_task("smnist").unwrap();
    std::thread::scope(|s| {
        let h1 = handle.clone();
        let h2 = handle.clone();
        let t1 = &task;
        let t2 = &task;
        let a = s.spawn(move || {
            let ex = t1.sample(&mut Rng::new(1));
            h1.infer_with_timescale(ex.x, 1.0).unwrap()
        });
        let b = s.spawn(move || {
            let ex = t2.sample(&mut Rng::new(2));
            h2.infer_with_timescale(ex.x, 2.0).unwrap()
        });
        let (ra, rb) = (a.join().unwrap(), b.join().unwrap());
        // both served; a mixed batch would have corrupted one of them
        assert_eq!(ra.logits.len(), 10);
        assert_eq!(rb.logits.len(), 10);
    });
}

#[test]
fn throughput_improves_with_batching_window() {
    if !have("smnist_fwd") {
        return;
    }
    let task = make_task("smnist").unwrap();
    let run = |server: &InferenceServer, n: usize| -> f64 {
        let handle = server.handle();
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            let joins: Vec<_> = (0..n)
                .map(|i| {
                    let h = handle.clone();
                    let task = &task;
                    s.spawn(move || {
                        let ex = task.sample(&mut Rng::new(i as u64));
                        h.infer(ex.x).unwrap();
                    })
                })
                .collect();
            for j in joins {
                j.join().unwrap();
            }
        });
        n as f64 / t0.elapsed().as_secs_f64()
    };
    let batched = start("smnist", 20);
    let tput_batched = run(&batched, 32);
    drop(batched);
    let unbatched = start("smnist", 0);
    let tput_unbatched = run(&unbatched, 32);
    eprintln!("throughput batched={tput_batched:.1}/s unbatched={tput_unbatched:.1}/s");
    // batching should never be catastrophically worse; usually much better
    assert!(tput_batched > tput_unbatched * 0.5);
}
