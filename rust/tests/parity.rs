//! Three-way parity: compiled HLO (L1 Pallas kernel + L2 JAX model) vs the
//! pure-Rust S5 oracle, on identical parameters.
//!
//! This is the test that pins the whole stack together: the quickstart
//! artifact's npz parameters are loaded into BOTH the PJRT executable and
//! the Rust [`s5::ssm::s5::S5Layer`]; outputs must agree to f32 tolerances.
//! A failure here means the L2 math and the reference implementation have
//! diverged (or the manifest/param plumbing reordered something).
//!
//! These tests need `artifacts/` (built by `make artifacts`, which needs
//! the Python toolchain + a PJRT plugin), so they are `#[ignore]`d in the
//! default run and **panic** — never silently pass — when invoked
//! explicitly (`cargo test --test parity -- --ignored`) without the
//! artifacts present. The default `cargo test` output therefore shows
//! them as `ignored`, which is the honest state; the previous
//! eprintln-and-return-Ok shape reported a green "parity" result on
//! machines that had never run the compiled model at all. Offline golden
//! parity (no PJRT needed) lives in `tests/parity_fixtures.rs`.

#![allow(deprecated)] // legacy positional wrappers are the subjects/oracles here

use s5::num::C64;
use s5::rng::Rng;
use s5::runtime::params::{assemble_inputs, literal_f32, to_vec_f32, ParamStore};
use s5::runtime::{Artifact, Client};
use s5::ssm::s5::S5Layer;
use std::collections::BTreeMap;
use std::path::Path;

fn artifacts_dir() -> &'static Path {
    let p = Path::new("artifacts");
    assert!(
        p.join("quickstart_fwd.hlo.txt").exists(),
        "artifacts/ not built — this test was invoked explicitly but has nothing \
         to check. Run `make artifacts` first (Python + PJRT required); the \
         offline golden-fixture parity suite is `cargo test --test parity_fixtures`."
    );
    p
}

/// Build an S5Layer from the quickstart npz (the same tensors the HLO gets).
fn layer_from_store(store: &ParamStore, h: usize, p2: usize) -> S5Layer {
    let f = |name: &str| -> Vec<f32> {
        to_vec_f32(store.get(name).unwrap_or_else(|| panic!("missing {name}"))).unwrap()
    };
    let lam_re = f("params.lambda_re");
    let lam_im = f("params.lambda_im");
    let b_re = f("params.b_re");
    let b_im = f("params.b_im");
    let c_re = f("params.c_re");
    let c_im = f("params.c_im");
    let n_dir = c_re.len() / (h * p2);
    S5Layer {
        lambda: (0..p2)
            .map(|i| C64::new(lam_re[i] as f64, lam_im[i] as f64))
            .collect(),
        b_tilde: (0..p2 * h)
            .map(|i| C64::new(b_re[i] as f64, b_im[i] as f64))
            .collect(),
        c_tilde: (0..n_dir)
            .map(|d| {
                (0..h * p2)
                    .map(|i| {
                        C64::new(c_re[d * h * p2 + i] as f64, c_im[d * h * p2 + i] as f64)
                    })
                    .collect()
            })
            .collect(),
        d: f("params.d"),
        log_dt: f("params.log_dt"),
        gate_w: f("params.gate_w"),
        norm_scale: f("params.norm_scale"),
        norm_bias: f("params.norm_bias"),
        h,
        p2,
    }
}

#[test]
#[ignore = "needs artifacts/ (run `make artifacts`, requires Python + PJRT)"]
fn quickstart_layer_hlo_matches_rust_oracle() {
    let dir = artifacts_dir();
    let client = Client::cpu().unwrap();
    let art = Artifact::load(dir, "quickstart_fwd", &client).unwrap();
    let store = ParamStore::load_npz(&Artifact::init_npz_path(dir, "quickstart")).unwrap();

    let (l, h, p2) = (128usize, 8usize, 4usize);
    let mut rng = Rng::new(0xFEED);
    let u: Vec<f32> = rng.normal_vec_f32(l * h);

    // HLO path
    let mut extra = BTreeMap::new();
    extra.insert("u".to_string(), literal_f32(&u, &[l, h]).unwrap());
    let inputs = assemble_inputs(&art.manifest, &store, &mut extra).unwrap();
    let outs = art.run(&inputs).unwrap();
    let y_hlo = to_vec_f32(&outs[0]).unwrap();

    // Rust oracle path
    let layer = layer_from_store(&store, h, p2);
    let y_rust = layer.apply(&u, l, 1.0, None, 1);

    assert_eq!(y_hlo.len(), y_rust.len());
    let mut max_err = 0.0f32;
    for (a, b) in y_hlo.iter().zip(y_rust.iter()) {
        let scale = 1.0 + a.abs().max(b.abs());
        max_err = max_err.max((a - b).abs() / scale);
    }
    assert!(max_err < 2e-3, "HLO vs Rust oracle diverged: max rel err {max_err}");
}

#[test]
#[ignore = "needs artifacts/ (run `make artifacts`, requires Python + PJRT)"]
fn quickstart_parity_across_magnitudes() {
    let dir = artifacts_dir();
    let client = Client::cpu().unwrap();
    let art = Artifact::load(dir, "quickstart_fwd", &client).unwrap();
    let store = ParamStore::load_npz(&Artifact::init_npz_path(dir, "quickstart")).unwrap();
    let (l, h, p2) = (128usize, 8usize, 4usize);
    let layer = layer_from_store(&store, h, p2);

    for (seed, scale) in [(1u64, 0.01f32), (2, 1.0), (3, 10.0)] {
        let mut rng = Rng::new(seed);
        let u: Vec<f32> = rng.normal_vec_f32(l * h).iter().map(|v| v * scale).collect();
        let mut extra = BTreeMap::new();
        extra.insert("u".to_string(), literal_f32(&u, &[l, h]).unwrap());
        let inputs = assemble_inputs(&art.manifest, &store, &mut extra).unwrap();
        let y_hlo = to_vec_f32(&art.run(&inputs).unwrap()[0]).unwrap();
        let y_rust = layer.apply(&u, l, 1.0, None, 1);
        for (i, (a, b)) in y_hlo.iter().zip(y_rust.iter()).enumerate() {
            let s = 1.0 + a.abs().max(b.abs());
            assert!(
                (a - b).abs() / s < 5e-3,
                "scale {scale}, idx {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
#[ignore = "needs artifacts/ (run `make artifacts`, requires Python + PJRT)"]
fn oracle_parallel_scan_agrees_inside_parity_setup() {
    // layered sanity: the oracle's threaded path equals its sequential path
    // on the real quickstart parameters (ties the scan substrate into the
    // parity chain).
    let dir = artifacts_dir();
    let store = ParamStore::load_npz(&Artifact::init_npz_path(dir, "quickstart")).unwrap();
    let layer = layer_from_store(&store, 8, 4);
    let mut rng = Rng::new(7);
    let u = rng.normal_vec_f32(128 * 8);
    let y1 = layer.apply(&u, 128, 1.0, None, 1);
    let y4 = layer.apply(&u, 128, 1.0, None, 4);
    for (a, b) in y1.iter().zip(y4.iter()) {
        assert!((a - b).abs() < 1e-4);
    }
}
