//! Integration: the native inference server over the batched engine —
//! no compiled artifacts required. Covers the dynamic batcher (coalescing,
//! fan-out), correctness of batched serving against direct forwards, and
//! error propagation. (The model-generic server and streaming-session
//! coverage lives in `tests/sequence_api.rs`.)

#![allow(deprecated)] // `S5Model::forward` is the per-sequence oracle here

use s5::coordinator::server::{NativeInferenceServer, ServeError, ServerConfig};
use s5::rng::Rng;
use s5::ssm::s5::{S5Config, S5Model};
use std::time::Duration;

fn model(d_in: usize, classes: usize) -> S5Model {
    let cfg = S5Config { h: 16, p: 16, j: 1, ..Default::default() };
    S5Model::init(d_in, classes, 2, &cfg, &mut Rng::new(77))
}

fn start(l: usize, max_wait_ms: u64, max_batch: usize) -> (NativeInferenceServer, S5Model) {
    let m = model(2, 5);
    let server = NativeInferenceServer::start(
        m.clone(),
        l,
        ServerConfig {
            max_wait: Duration::from_millis(max_wait_ms),
            max_batch,
            threads: 2,
            ..ServerConfig::default()
        },
    );
    (server, m)
}

#[test]
fn single_request_roundtrip_matches_direct_forward() {
    let l = 32;
    let (server, m) = start(l, 1, 8);
    let mut rng = Rng::new(0);
    let x = rng.normal_vec_f32(l * 2);
    let resp = server.handle().infer(x.clone()).unwrap();
    assert_eq!(resp.logits.len(), 5);
    assert!(resp.logits.iter().all(|v| v.is_finite()));
    assert!(resp.batched_with >= 1);
    // served logits equal a direct single-sequence forward
    let want = m.forward(&x, l, 1.0, 1);
    for (a, b) in want.iter().zip(resp.logits.iter()) {
        assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
    }
}

#[test]
fn concurrent_requests_are_batched_and_correct() {
    let l = 24;
    let (server, m) = start(l, 50, 16);
    let handle = server.handle();
    let results: Vec<(Vec<f32>, Vec<f32>, usize)> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..12u64)
            .map(|i| {
                let h = handle.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(i);
                    let x = rng.normal_vec_f32(l * 2);
                    let resp = h.infer(x.clone()).unwrap();
                    (x, resp.logits, resp.batched_with)
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    // with a 50ms window and 12 concurrent clients, at least one executed
    // batch must have coalesced multiple requests
    assert!(
        results.iter().any(|(_, _, fill)| *fill > 1),
        "no batching observed"
    );
    assert!(server.stats.mean_batch_fill() > 1.0);
    // every response equals its own direct forward, whatever batch it
    // landed in — the batched-engine equivalence, end to end
    for (x, logits, _) in &results {
        let want = m.forward(x, l, 1.0, 1);
        for (a, b) in want.iter().zip(logits.iter()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }
}

#[test]
fn wrong_width_rejected_immediately() {
    let (server, _) = start(16, 1, 8);
    let err = server.handle().infer(vec![0.0; 3]).unwrap_err();
    // typed, so callers can distinguish bad input from load-shedding
    assert!(matches!(&err, ServeError::InvalidInput(m) if m.contains("width")), "{err}");
}

#[test]
fn different_timescales_do_not_share_a_batch() {
    let l = 16;
    let (server, m) = start(l, 30, 8);
    let handle = server.handle();
    std::thread::scope(|s| {
        let h1 = handle.clone();
        let h2 = handle.clone();
        let a = s.spawn(move || {
            let mut rng = Rng::new(1);
            let x = rng.normal_vec_f32(l * 2);
            (x.clone(), h1.infer_with_timescale(x, 1.0).unwrap())
        });
        let b = s.spawn(move || {
            let mut rng = Rng::new(2);
            let x = rng.normal_vec_f32(l * 2);
            (x.clone(), h2.infer_with_timescale(x, 2.0).unwrap())
        });
        let (xa, ra) = a.join().unwrap();
        let (xb, rb) = b.join().unwrap();
        // each must be served at its own timescale
        let wa = m.forward(&xa, l, 1.0, 1);
        let wb = m.forward(&xb, l, 2.0, 1);
        for (w, g) in wa.iter().zip(ra.logits.iter()) {
            assert!((w - g).abs() < 1e-4 * (1.0 + w.abs()));
        }
        for (w, g) in wb.iter().zip(rb.logits.iter()) {
            assert!((w - g).abs() < 1e-4 * (1.0 + w.abs()));
        }
    });
}

#[test]
fn max_batch_caps_fill() {
    let l = 16;
    let (server, _) = start(l, 80, 3);
    let handle = server.handle();
    let fills: Vec<usize> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..9u64)
            .map(|i| {
                let h = handle.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(i);
                    h.infer(rng.normal_vec_f32(l * 2)).unwrap().batched_with
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    assert!(fills.iter().all(|&f| f <= 3), "max_batch exceeded: {fills:?}");
}
