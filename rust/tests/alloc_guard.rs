//! Counting-allocator harness: pins the zero-allocation invariants of the
//! steady-state hot paths — the fused batched forward after workspace
//! warmup, and `Session::step_into` streaming. Lives in its own test
//! binary because it installs a `#[global_allocator]`; the other test
//! binaries keep the untouched system allocator.
//!
//! Everything runs on the sequential (threads = 1) reference
//! configuration: allocation counting is per-thread, so a meaningful
//! zero-allocation window needs the measured work to stay on the
//! measuring thread (shards ≤ 1 runs inline, no pool dispatch).

use s5::rng::Rng;
use s5::ssm::api::{Batch, ForwardOptions, SequenceModel, Session};
use s5::ssm::dtype::Dtype;
use s5::ssm::engine::EngineWorkspace;
use s5::ssm::s5::{S5Config, S5Model};
use s5::testing::alloc_guard::{assert_no_alloc, measure, CountingAlloc};
use std::sync::Arc;

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

fn model(seed: u64) -> S5Model {
    let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
    S5Model::init(3, 4, 2, &cfg, &mut Rng::new(seed))
}

/// The guard itself works: it observes a deliberate allocation, and
/// `assert_no_alloc` trips on one (the lint-harness self-test).
#[test]
fn guard_counts_and_trips() {
    let (n, v) = measure(|| vec![1u8; 4096]);
    assert!(n >= 1, "allocating a Vec must be observed, got {n}");
    drop(v);
    let trip = std::panic::catch_unwind(|| {
        assert_no_alloc("deliberate allocation", || {
            std::hint::black_box(vec![2u8; 64]);
        })
    });
    assert!(trip.is_err(), "assert_no_alloc must panic on a deliberate allocation");
}

/// The fused batched forward allocates only on warmup: once the engine
/// workspace is grown for a shape, repeat forwards of that shape are
/// heap-silent — and still produce identical output.
#[test]
fn fused_forward_steady_state_is_alloc_free() {
    let m = model(7);
    let opts = ForwardOptions::new(); // sequential scan, fused auto-tiled
    let (b, l, d) = (2usize, 48usize, 3usize);
    let mut rng = Rng::new(11);
    let u = rng.normal_vec_f32(b * l * d);
    let mut ws = EngineWorkspace::new();
    let mut out = vec![0.0f32; b * 4];
    for _ in 0..2 {
        m.prefill_into(Batch::new(&u, b, l, d), &opts, &mut ws, &mut out);
    }
    let warm = out.clone();
    assert_no_alloc("steady-state fused forward", || {
        m.prefill_into(Batch::new(&u, b, l, d), &opts, &mut ws, &mut out);
    });
    assert_eq!(out, warm, "steady-state forward must reproduce the warmup output");
}

/// The bf16 twin: with bf16 drive planes the fused forward reuses the
/// workspace's narrow plane family the same way — warmup grows it once,
/// then repeat forwards of the shape are heap-silent.
#[test]
fn fused_forward_bf16_steady_state_is_alloc_free() {
    let m = model(7);
    let opts = ForwardOptions::new().with_dtype(Dtype::Bf16);
    let (b, l, d) = (2usize, 48usize, 3usize);
    let mut rng = Rng::new(11);
    let u = rng.normal_vec_f32(b * l * d);
    let mut ws = EngineWorkspace::new();
    let mut out = vec![0.0f32; b * 4];
    for _ in 0..2 {
        m.prefill_into(Batch::new(&u, b, l, d), &opts, &mut ws, &mut out);
    }
    let warm = out.clone();
    assert_no_alloc("steady-state bf16 fused forward", || {
        m.prefill_into(Batch::new(&u, b, l, d), &opts, &mut ws, &mut out);
    });
    assert_eq!(out, warm, "steady-state bf16 forward must reproduce the warmup output");
}

/// A warmed-up streaming session steps without touching the heap, and the
/// `step_into` path is bit-identical to the allocating `step`.
#[test]
fn session_step_steady_state_is_alloc_free() {
    let m: Arc<dyn SequenceModel> = Arc::new(model(13));
    let mut fast = Session::new(m.clone(), ForwardOptions::new());
    let mut oracle = Session::new(m, ForwardOptions::new());
    let mut rng = Rng::new(17);
    let mut out = vec![0.0f32; 4];
    // warmup: grows the stream state's workspace rows
    for _ in 0..3 {
        let u = rng.normal_vec_f32(3);
        fast.step_into(&u, &mut out);
        assert_eq!(out, oracle.step(&u), "step_into must equal the allocating step");
    }
    let u = rng.normal_vec_f32(3);
    assert_no_alloc("steady-state Session::step_into", || {
        for _ in 0..8 {
            fast.step_into(&u, &mut out);
        }
    });
    let mut want = Vec::new();
    for _ in 0..8 {
        want = oracle.step(&u);
    }
    assert_eq!(out, want, "steady-state steps must match the oracle replay");
}

/// The bf16 twin for streaming: a bf16 session (whose chunked prefill
/// borrows the workspace's bf16 plane family) still steps heap-silently
/// through `step_into` once warmed up.
#[test]
fn session_bf16_steady_state_is_alloc_free() {
    let m: Arc<dyn SequenceModel> = Arc::new(model(13));
    let opts = ForwardOptions::new().with_dtype(Dtype::Bf16);
    let mut sess = Session::new(m, opts);
    let mut rng = Rng::new(19);
    let mut out = vec![0.0f32; 4];
    let chunk = rng.normal_vec_f32(16 * 3);
    // warmup: grows the stream state's rows and the bf16 prefill planes
    for _ in 0..2 {
        sess.prefill(&chunk, 16);
        let u = rng.normal_vec_f32(3);
        sess.step_into(&u, &mut out);
    }
    let u = rng.normal_vec_f32(3);
    assert_no_alloc("steady-state bf16 Session::step_into", || {
        for _ in 0..8 {
            sess.step_into(&u, &mut out);
        }
    });
}
