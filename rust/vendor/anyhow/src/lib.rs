//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this path dependency
//! provides the subset of `anyhow` the crate actually uses: [`Error`],
//! [`Result`], the [`Context`] extension trait (on both `Result` and
//! `Option`), and the `anyhow!` / `bail!` / `ensure!` macros. Error chains
//! are captured eagerly as strings: `{}` shows the outermost message,
//! `{:#}` (and `Debug`) the full `outer: ...: root` chain, matching how the
//! CLI and tests format errors.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chained error: `chain[0]` is the outermost context message,
/// the last element the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Build from a standard error, capturing its `source()` chain.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root-cause message (innermost of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// The same blanket conversion real anyhow ships: any std error (but not
// `Error` itself, which deliberately does not implement `StdError`).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::new(io_err()).context("loading config");
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e:#}").contains("outer"));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
    }

    #[test]
    fn context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("root {}", 42)
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root 42");
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "bad request width {}", x);
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{}", f(12).unwrap_err()).contains("width 12"));
        assert!(format!("{}", f(7).unwrap_err()).contains("unlucky"));
        let msg = "boom";
        let e = anyhow!("{msg}");
        assert_eq!(format!("{e}"), "boom");
    }
}
