//! Mini property-testing harness (the offline build has no `proptest`)
//! plus the counting-allocator harness behind the repo's zero-allocation
//! invariants.
//!
//! [`prop::check`] runs a closure against many deterministically-seeded RNG
//! streams; a failure reports the seed so the case replays exactly. This is
//! intentionally shrink-free: generators here draw structured inputs whose
//! failing seeds are already small enough to debug directly.
//!
//! [`alloc_guard`] provides a forwarding `#[global_allocator]` that counts
//! per-thread heap traffic; `tests/alloc_guard.rs` installs it and asserts
//! the steady-state fused forward and `Session::step` paths allocate
//! nothing after warmup.
//!
//! [`fault`] is the deterministic fault-injection harness behind the
//! serving-robustness suite: a [`FaultPlan`](fault::FaultPlan) schedules
//! panics/latency at exact batch or step indices (seeded, no wall-clock
//! randomness) and [`FaultyModel`](fault::FaultyModel) wraps any
//! `SequenceModel` to execute that schedule — `tests/server_robustness.rs`
//! uses it to prove panic isolation, load-shedding, deadline and drain
//! semantics.

/// Counting-allocator harness for the zero-allocation invariants.
///
/// The steady-state hot paths (the fused batched forward after workspace
/// warmup, and `Session::step` via the `step_into` chain) are documented
/// as allocation-free. This module makes that a *tested* property rather
/// than a code-review one: a dedicated test binary installs
/// [`CountingAlloc`](alloc_guard::CountingAlloc) as its global allocator
/// and wraps the hot path in [`assert_no_alloc`](alloc_guard::assert_no_alloc).
///
/// Counting is per-thread by design: the pool workers' warmup-era buffers
/// are owned by the pool, and what the harness pins is the *caller's*
/// steady-state path. Work handed to the pool is counted on the worker
/// threads, not the measuring thread — size assertions under test configs
/// keep those paths single-threaded so the count is meaningful.
pub mod alloc_guard {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        /// Heap allocations observed on this thread since it started.
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    /// A `#[global_allocator]` that forwards to [`System`] and counts
    /// every allocation on the current thread. Frees are not counted:
    /// the invariant under test is "no new heap traffic", and dropping a
    /// warmup-era buffer inside a measured window is benign.
    ///
    /// Install it in a dedicated test binary — the test harness itself
    /// allocates freely; only [`measure`]d windows are asserted:
    ///
    /// ```ignore
    /// #[global_allocator]
    /// static COUNTING: CountingAlloc = CountingAlloc;
    /// ```
    ///
    /// [`measure`]: alloc_guard::measure
    pub struct CountingAlloc;

    /// Bump this thread's allocation counter.
    fn count() {
        // try_with, not with: the allocator can be re-entered during TLS
        // teardown, where `with` would panic inside alloc — skip those.
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
    }

    // SAFETY: every method forwards verbatim to `System`, which upholds
    // the `GlobalAlloc` contract; the only extra work is a thread-local
    // counter bump, which never allocates and never unwinds.
    unsafe impl GlobalAlloc for CountingAlloc {
        // SAFETY: caller obligations on `layout` pass straight through
        // to `System::alloc`.
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            count();
            System.alloc(layout)
        }

        // SAFETY: caller obligations on `layout` pass straight through
        // to `System::alloc_zeroed`.
        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            count();
            System.alloc_zeroed(layout)
        }

        // SAFETY: caller obligations on `ptr`/`layout`/`new_size` pass
        // straight through to `System::realloc`.
        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            count();
            System.realloc(ptr, layout, new_size)
        }

        // SAFETY: caller obligations on `ptr`/`layout` pass straight
        // through to `System::dealloc` (frees are deliberately uncounted).
        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }

    /// Allocations made on this thread while running `f`, plus `f`'s
    /// result. Reads zero unless [`CountingAlloc`] is the process's
    /// global allocator.
    pub fn measure<R>(f: impl FnOnce() -> R) -> (u64, R) {
        let before = ALLOCS.with(|c| c.get());
        let out = f();
        let n = ALLOCS.with(|c| c.get()) - before;
        (n, out)
    }

    /// Run `f`, panicking (with `label`) if it allocated on this thread.
    pub fn assert_no_alloc<R>(label: &str, f: impl FnOnce() -> R) -> R {
        let (n, out) = measure(f);
        assert!(
            n == 0,
            "{label}: expected zero heap allocations in the measured window, observed {n}"
        );
        out
    }
}

pub mod prop {
    use crate::rng::Rng;

    /// Outcome of a single property evaluation.
    pub type PropResult = Result<(), String>;

    /// Assert a boolean inside a property.
    pub fn ensure(ok: bool) -> PropResult {
        if ok {
            Ok(())
        } else {
            Err("property violated".to_string())
        }
    }

    /// Assert with a message.
    pub fn ensure_msg(ok: bool, msg: impl Into<String>) -> PropResult {
        if ok {
            Ok(())
        } else {
            Err(msg.into())
        }
    }

    /// Assert two f64 values are close (absolute + relative tolerance).
    pub fn close_f64(a: f64, b: f64, tol: f64) -> PropResult {
        let scale = 1.0 + a.abs().max(b.abs());
        ensure_msg(
            (a - b).abs() <= tol * scale,
            format!("{a} !~ {b} (tol {tol})"),
        )
    }

    /// Assert two f32 slices are elementwise close.
    pub fn close_slice_f32(a: &[f32], b: &[f32], tol: f32) -> PropResult {
        ensure_msg(a.len() == b.len(), format!("len {} != {}", a.len(), b.len()))?;
        for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
            let scale = 1.0 + x.abs().max(y.abs());
            if (x - y).abs() > tol * scale {
                return Err(format!("idx {i}: {x} !~ {y} (tol {tol})"));
            }
        }
        Ok(())
    }

    /// Run `cases` evaluations of `f`, each with a fresh deterministic RNG.
    ///
    /// Panics (failing the enclosing `#[test]`) on the first violated case,
    /// printing the replay seed.
    pub fn check<F>(name: &str, cases: u64, mut f: F)
    where
        F: FnMut(&mut Rng) -> PropResult,
    {
        for case in 0..cases {
            let seed = 0x5EED_0000_0000 ^ case.wrapping_mul(0x9E37_79B9);
            let mut rng = Rng::new(seed);
            if let Err(msg) = f(&mut rng) {
                panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
            }
        }
    }
}

/// Deterministic fault injection for serving-robustness tests.
///
/// The plan is explicit — "panic at prefill #k", "sleep this long before
/// every prefill", "panic at step #n" — or derived from a seed through the
/// repo's own [`Rng`](crate::rng::Rng), never from wall-clock randomness,
/// so a failing schedule replays exactly.
pub mod fault {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    use crate::ssm::api::{Batch, ForwardOptions, ModelSpec, SequenceModel, SessionState};
    use crate::ssm::engine::EngineWorkspace;

    /// A deterministic fault schedule for a [`FaultyModel`].
    ///
    /// Counters are global across the wrapper (prefills count batches in
    /// arrival order on the server's single worker, steps count
    /// materializing `step`/`step_into` calls), so "batch #k" means the
    /// k-th executed batch, 0-based.
    #[derive(Clone, Debug, Default)]
    pub struct FaultPlan {
        /// 0-based prefill (batch) indices that panic. The panic fires
        /// *before* the inner forward runs — the model blows up on entry,
        /// leaving any shared workspace exactly as adversarial as a real
        /// mid-batch unwind the server must contain.
        pub panic_on_prefills: Vec<u64>,
        /// 0-based step indices that panic. The panic fires *after* the
        /// inner step updated the state — the adversarial case for
        /// session reuse: the state is dirty beyond the caller's last
        /// observed output.
        pub panic_on_steps: Vec<u64>,
        /// Injected latency before every prefill (models a slow shard;
        /// lets tests fill the admission queue deterministically).
        pub prefill_delay: Duration,
    }

    impl FaultPlan {
        /// No faults: the wrapper is a transparent pass-through.
        pub fn none() -> FaultPlan {
            FaultPlan::default()
        }

        /// Panic at exactly prefill (batch) #k, 0-based.
        pub fn panic_at_prefill(k: u64) -> FaultPlan {
            FaultPlan { panic_on_prefills: vec![k], ..FaultPlan::default() }
        }

        /// Panic at exactly step #n, 0-based.
        pub fn panic_at_step(n: u64) -> FaultPlan {
            FaultPlan { panic_on_steps: vec![n], ..FaultPlan::default() }
        }

        /// Sleep `delay` before every prefill.
        pub fn with_prefill_delay(mut self, delay: Duration) -> FaultPlan {
            self.prefill_delay = delay;
            self
        }

        /// A seeded schedule: panic at one prefill index in
        /// `[0, horizon)`, derived from the repo RNG — deterministic per
        /// seed, no wall-clock randomness.
        pub fn seeded_panic(seed: u64, horizon: u64) -> FaultPlan {
            assert!(horizon > 0, "empty horizon");
            let mut rng = crate::rng::Rng::new(seed);
            let k = ((rng.uniform() * horizon as f64) as u64).min(horizon - 1);
            FaultPlan::panic_at_prefill(k)
        }
    }

    /// A [`SequenceModel`] wrapper that executes a [`FaultPlan`] around an
    /// inner model. Between scheduled faults it delegates verbatim, so
    /// un-faulted outputs are bit-for-bit the inner model's.
    pub struct FaultyModel {
        inner: Arc<dyn SequenceModel>,
        plan: FaultPlan,
        prefills: AtomicU64,
        steps: AtomicU64,
    }

    impl FaultyModel {
        pub fn new(inner: Arc<dyn SequenceModel>, plan: FaultPlan) -> FaultyModel {
            FaultyModel { inner, plan, prefills: AtomicU64::new(0), steps: AtomicU64::new(0) }
        }

        /// Prefill (batch) calls observed so far.
        pub fn prefills(&self) -> u64 {
            self.prefills.load(Ordering::SeqCst)
        }

        /// Materializing step calls observed so far.
        pub fn steps(&self) -> u64 {
            self.steps.load(Ordering::SeqCst)
        }

        fn count_step(&self) -> u64 {
            self.steps.fetch_add(1, Ordering::SeqCst)
        }
    }

    impl SequenceModel for FaultyModel {
        fn spec(&self) -> ModelSpec {
            self.inner.spec()
        }

        fn prefill_into(
            &self,
            batch: Batch<'_>,
            opts: &ForwardOptions,
            ws: &mut EngineWorkspace,
            out: &mut [f32],
        ) {
            let k = self.prefills.fetch_add(1, Ordering::SeqCst);
            if !self.plan.prefill_delay.is_zero() {
                std::thread::sleep(self.plan.prefill_delay);
            }
            if self.plan.panic_on_prefills.contains(&k) {
                panic!("injected fault: prefill #{k}");
            }
            self.inner.prefill_into(batch, opts, ws, out);
        }

        fn make_state(&self, opts: &ForwardOptions) -> SessionState {
            self.inner.make_state(opts)
        }

        fn reset_state(&self, state: &mut SessionState) {
            self.inner.reset_state(state);
        }

        fn step(
            &self,
            state: &mut SessionState,
            u: &[f32],
            dt: Option<f32>,
            opts: &ForwardOptions,
        ) -> Vec<f32> {
            let n = self.count_step();
            let out = self.inner.step(state, u, dt, opts);
            if self.plan.panic_on_steps.contains(&n) {
                panic!("injected fault: step #{n}");
            }
            out
        }

        fn step_into(
            &self,
            state: &mut SessionState,
            u: &[f32],
            dt: Option<f32>,
            opts: &ForwardOptions,
            out: &mut [f32],
        ) {
            let n = self.count_step();
            self.inner.step_into(state, u, dt, opts, out);
            if self.plan.panic_on_steps.contains(&n) {
                panic!("injected fault: step #{n}");
            }
        }

        // the swallowed-prefix fast paths delegate uncounted: only
        // materializing steps advance the step schedule, keeping "step
        // #n" independent of how a prefix was chunked
        fn advance(
            &self,
            state: &mut SessionState,
            u: &[f32],
            dt: Option<f32>,
            opts: &ForwardOptions,
        ) {
            self.inner.advance(state, u, dt, opts);
        }

        fn advance_batch(
            &self,
            state: &mut SessionState,
            tokens: &[f32],
            l: usize,
            opts: &ForwardOptions,
        ) {
            self.inner.advance_batch(state, tokens, l, opts);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn passing_property_passes() {
        prop::check("tautology", 50, |g| {
            let x = g.uniform();
            prop::ensure((0.0..1.0).contains(&x))
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_panics_with_seed() {
        prop::check("must fail", 10, |g| prop::ensure(g.uniform() < -1.0));
    }

    #[test]
    fn close_slice_reports_index() {
        let e = prop::close_slice_f32(&[1.0, 2.0], &[1.0, 3.0], 1e-3).unwrap_err();
        assert!(e.contains("idx 1"), "{e}");
    }

    #[test]
    fn fault_plan_seeded_schedule_is_deterministic_and_in_range() {
        use super::fault::FaultPlan;
        let a = FaultPlan::seeded_panic(42, 10);
        let b = FaultPlan::seeded_panic(42, 10);
        assert_eq!(a.panic_on_prefills, b.panic_on_prefills, "same seed, same schedule");
        assert!(a.panic_on_prefills[0] < 10);
        // different seeds explore the horizon (not a constant schedule)
        let hits: std::collections::BTreeSet<u64> = (0..64)
            .map(|seed| FaultPlan::seeded_panic(seed, 1000).panic_on_prefills[0])
            .collect();
        assert!(hits.len() > 1, "seeds all mapped to one index");
        assert!(hits.iter().all(|&k| k < 1000));
    }
}
