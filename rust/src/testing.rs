//! Mini property-testing harness (the offline build has no `proptest`).
//!
//! [`prop::check`] runs a closure against many deterministically-seeded RNG
//! streams; a failure reports the seed so the case replays exactly. This is
//! intentionally shrink-free: generators here draw structured inputs whose
//! failing seeds are already small enough to debug directly.

pub mod prop {
    use crate::rng::Rng;

    /// Outcome of a single property evaluation.
    pub type PropResult = Result<(), String>;

    /// Assert a boolean inside a property.
    pub fn ensure(ok: bool) -> PropResult {
        if ok {
            Ok(())
        } else {
            Err("property violated".to_string())
        }
    }

    /// Assert with a message.
    pub fn ensure_msg(ok: bool, msg: impl Into<String>) -> PropResult {
        if ok {
            Ok(())
        } else {
            Err(msg.into())
        }
    }

    /// Assert two f64 values are close (absolute + relative tolerance).
    pub fn close_f64(a: f64, b: f64, tol: f64) -> PropResult {
        let scale = 1.0 + a.abs().max(b.abs());
        ensure_msg(
            (a - b).abs() <= tol * scale,
            format!("{a} !~ {b} (tol {tol})"),
        )
    }

    /// Assert two f32 slices are elementwise close.
    pub fn close_slice_f32(a: &[f32], b: &[f32], tol: f32) -> PropResult {
        ensure_msg(a.len() == b.len(), format!("len {} != {}", a.len(), b.len()))?;
        for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
            let scale = 1.0 + x.abs().max(y.abs());
            if (x - y).abs() > tol * scale {
                return Err(format!("idx {i}: {x} !~ {y} (tol {tol})"));
            }
        }
        Ok(())
    }

    /// Run `cases` evaluations of `f`, each with a fresh deterministic RNG.
    ///
    /// Panics (failing the enclosing `#[test]`) on the first violated case,
    /// printing the replay seed.
    pub fn check<F>(name: &str, cases: u64, mut f: F)
    where
        F: FnMut(&mut Rng) -> PropResult,
    {
        for case in 0..cases {
            let seed = 0x5EED_0000_0000 ^ case.wrapping_mul(0x9E37_79B9);
            let mut rng = Rng::new(seed);
            if let Err(msg) = f(&mut rng) {
                panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn passing_property_passes() {
        prop::check("tautology", 50, |g| {
            let x = g.uniform();
            prop::ensure((0.0..1.0).contains(&x))
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_panics_with_seed() {
        prop::check("must fail", 10, |g| prop::ensure(g.uniform() < -1.0));
    }

    #[test]
    fn close_slice_reports_index() {
        let e = prop::close_slice_f32(&[1.0, 2.0], &[1.0, 3.0], 1e-3).unwrap_err();
        assert!(e.contains("idx 1"), "{e}");
    }
}
