//! `s5` — the Layer-3 coordinator CLI.
//!
//! ```text
//! s5 train --preset smnist --steps 300 [--lr 4e-3] [--checkpoint out.npz]
//! s5 eval  --preset smnist --checkpoint out.npz [--timescale 2.0]
//! s5 serve --preset smnist [--engine native|pjrt] [--model s5|gru]
//!          [--checkpoint ckpt.npz] [--requests 64]
//!          [--threads N] [--max-batch N] [--max-wait-ms N]
//! s5 data  --task listops [--n 3]        # inspect generator output
//! s5 info  [--artifacts artifacts]       # list compiled artifacts
//! ```
//!
//! Thread knobs default to `0` = auto-detect
//! (`std::thread::available_parallelism`). Builds without the `pjrt`
//! feature keep the full native path (`serve --engine native`, `data`,
//! `info`); `train`/`eval`/`sweep` and `serve --engine pjrt` need the
//! compiled-artifact runtime.

use anyhow::bail;
use s5::coordinator::server::{NativeInferenceServer, RunningServer, ServerConfig};
use s5::data::{make_task, TaskGen};
use s5::rng::Rng;
use s5::runtime::{Manifest, NpzStore};
use s5::ssm::api::SequenceModel;
use s5::ssm::engine::auto_threads;
use s5::ssm::rnn::GruCell;
use s5::ssm::s5::{S5Config, S5Model};
use s5::util::{Args, Table};
use s5::{info, ARTIFACTS_DIR};
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    if args.has_flag("verbose") {
        s5::util::set_verbose(true);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "data" => cmd_data(&args),
        "info" => cmd_info(&args),
        "sweep" => cmd_sweep(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "s5 — Simplified State Space Layers (S5) coordinator\n\n\
         USAGE: s5 <train|eval|serve|data|info> [--key value]...\n\n\
         train  --preset <p> --steps N [--lr F --wd F --seed N --checkpoint F --metrics F]\n\
         eval   --preset <p> [--checkpoint F --timescale F]\n\
         serve  --preset <p> [--engine native|pjrt --model s5|gru (native)\n\
                --checkpoint F.npz --requests N --threads N --max-batch N\n\
                --max-wait-ms N]  (threads 0 = auto)\n\
         data   --task <t> [--n N] [--dump DIR]\n\
         sweep  --preset <p> --lrs 1e-3,3e-3 [--wds ...] [--seeds ...] [--steps N]\n\
         info   [--artifacts DIR]\n\n\
         Presets: quickstart smnist listops text retrieval image pathfinder\n\
         pathx speech pendulum abl5_* abl6_*  (see python/compile/aot.py)"
    );
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> anyhow::Result<()> {
    use s5::coordinator::{TrainConfig, Trainer};
    use s5::runtime::Client;
    let mut cfg = TrainConfig::for_preset(&args.get_or("preset", "smnist"));
    if let Some(f) = args.get("config") {
        cfg.apply_file(Path::new(f))?;
    }
    cfg.apply_args(args);
    let client = Client::cpu()?;
    let mut trainer = Trainer::new(&client, cfg)?;
    trainer.run()?;
    let (eloss, emetric) = trainer.evaluate()?;
    info!("final eval: loss={eloss:.4} metric={emetric:.4}");
    println!("final_eval_loss {eloss:.6}");
    println!("final_eval_metric {emetric:.6}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> anyhow::Result<()> {
    bail!("this build has no PJRT runtime (rebuild with --features pjrt); \
           the native engine is available via `s5 serve --engine native`")
}

#[cfg(feature = "pjrt")]
fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    use s5::coordinator::{TrainConfig, Trainer};
    use s5::runtime::Client;
    let mut cfg = TrainConfig::for_preset(&args.get_or("preset", "smnist"));
    cfg.apply_args(args);
    cfg.steps = 0;
    let client = Client::cpu()?;
    let mut trainer = Trainer::new(&client, cfg)?;
    let ts = args.get_f64("timescale", 1.0) as f32;
    let (loss, metric) = trainer.evaluate_with_timescale(ts)?;
    println!("eval_loss {loss:.6}\neval_metric {metric:.6}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_eval(_args: &Args) -> anyhow::Result<()> {
    bail!("eval needs the PJRT runtime (rebuild with --features pjrt)")
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let preset = args.get_or("preset", "smnist");
    let n_requests = args.get_usize("requests", 64);
    // --queue-cap 0 and --deadline-ms 0 mean auto: the S5_QUEUE_CAP /
    // S5_REQ_DEADLINE_MS knobs if set, else the built-in defaults.
    let deadline_ms = args.get_usize("deadline-ms", 0);
    let cfg = ServerConfig {
        max_wait: std::time::Duration::from_millis(args.get_usize("max-wait-ms", 2) as u64),
        max_batch: args.get_usize("max-batch", 16),
        threads: args.get_usize("threads", 0),
        queue_cap: args.get_usize("queue-cap", 0),
        deadline: (deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(deadline_ms as u64)),
    };
    let default_engine = if cfg!(feature = "pjrt") { "pjrt" } else { "native" };
    let engine = args.get_or("engine", default_engine);

    let task = make_task(&preset)
        .ok_or_else(|| anyhow::anyhow!("no generator for preset {preset:?}"))?;
    // Shared across the client threads below (the generators are stateless
    // per-sample; `TaskGen: Send + Sync`).
    let task: Arc<dyn TaskGen> = Arc::from(task);
    let server = match engine.as_str() {
        "native" => {
            // Serve the pure-Rust batched engine through the unified
            // SequenceModel API: one dynamic-batching loop for S5 and the
            // RNN baselines, with native checkpoint import (npz) so
            // trained weights are served without PJRT.
            let mut rng = Rng::new(args.get_usize("seed", 0) as u64);
            let model: Arc<dyn SequenceModel> = match args.get_or("model", "s5").as_str() {
                "s5" => {
                    let model = if let Some(ck) = args.get("checkpoint") {
                        let store = NpzStore::load(Path::new(ck))?;
                        let m = S5Model::from_param_store(&store)?;
                        anyhow::ensure!(
                            m.d_in == task.d_input() && m.classes == task.classes(),
                            "checkpoint {ck:?} is (d_in={}, classes={}) but preset \
                             {preset:?} needs (d_in={}, classes={})",
                            m.d_in,
                            m.classes,
                            task.d_input(),
                            task.classes()
                        );
                        info!("loaded checkpoint {ck} ({} params)", m.param_count());
                        m
                    } else {
                        let cfg_model = S5Config { h: 32, p: 32, j: 1, ..Default::default() };
                        S5Model::init(task.d_input(), task.classes(), 4, &cfg_model, &mut rng)
                    };
                    Arc::new(model)
                }
                "gru" => {
                    anyhow::ensure!(
                        args.get("checkpoint").is_none(),
                        "--checkpoint applies to the s5 model only"
                    );
                    Arc::new(GruCell::init(task.d_input(), 32, &mut rng))
                }
                other => bail!("unknown native model {other:?} (expected s5 or gru)"),
            };
            let spec = model.spec();
            info!(
                "native engine: model {} (d_in {}, d_out {}), {} threads, max_batch {}",
                spec.name,
                spec.d_input,
                spec.d_output,
                auto_threads(cfg.threads),
                cfg.max_batch
            );
            RunningServer::Native(NativeInferenceServer::start_model(
                model,
                task.seq_len(),
                cfg,
            ))
        }
        "pjrt" => start_pjrt_server(args, &preset, cfg)?,
        other => bail!("unknown engine {other:?} (expected native or pjrt)"),
    };
    let handle = server.handle();
    info!("server up ({engine}); firing {n_requests} concurrent requests");

    let t0 = std::time::Instant::now();
    // Named worker threads via runtime::pool (lint L1: no raw
    // thread::spawn/scope outside the pool module); latencies come back
    // over a channel since the clients outlive this stack frame's borrows.
    let (lat_tx, lat_rx) = std::sync::mpsc::channel();
    let mut joins = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let h = handle.clone();
        let task = Arc::clone(&task);
        let lat_tx = lat_tx.clone();
        joins.push(s5::runtime::pool::spawn_worker(&format!("serve-client-{i}"), move || {
            let mut rng = Rng::new(i as u64);
            let ex = task.sample(&mut rng);
            let resp = h.infer(ex.x).expect("infer");
            let _ = lat_tx.send(resp.total_secs);
        }));
    }
    drop(lat_tx);
    let lat: Vec<f64> = lat_rx.iter().collect();
    for j in joins {
        j.join().expect("serve client thread panicked");
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = s5::util::Stats::from(&lat);
    let st = server.stats();
    println!(
        "served {n_requests} requests in {wall:.3}s  ({:.1} req/s)\n\
         latency p50={:.1}ms p95={:.1}ms  mean batch fill={:.2}\n\
         shed={} expired={} panicked={}",
        n_requests as f64 / wall,
        stats.p50 * 1e3,
        stats.p95 * 1e3,
        st.mean_batch_fill(),
        st.shed.load(std::sync::atomic::Ordering::Relaxed),
        st.expired.load(std::sync::atomic::Ordering::Relaxed),
        st.panicked.load(std::sync::atomic::Ordering::Relaxed)
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn start_pjrt_server(args: &Args, preset: &str, cfg: ServerConfig) -> anyhow::Result<RunningServer> {
    use s5::coordinator::server::InferenceServer;
    let artifacts = args.get_or("artifacts", ARTIFACTS_DIR);
    let checkpoint = args.get("checkpoint").map(Path::new);
    Ok(RunningServer::Pjrt(InferenceServer::start(
        Path::new(&artifacts),
        preset,
        checkpoint,
        cfg,
    )?))
}

#[cfg(not(feature = "pjrt"))]
fn start_pjrt_server(
    _args: &Args,
    _preset: &str,
    _cfg: ServerConfig,
) -> anyhow::Result<RunningServer> {
    bail!("the pjrt engine needs the PJRT runtime (rebuild with --features pjrt); \
           use --engine native")
}

fn cmd_data(args: &Args) -> anyhow::Result<()> {
    let name = args.get_or("task", "listops");
    let n = args.get_usize("n", 3);
    let task = make_task(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown task {name:?}"))?;
    let mut rng = Rng::new(args.get_usize("seed", 0) as u64);
    println!(
        "task={} L={} d_input={} classes={}",
        task.name(),
        task.seq_len(),
        task.d_input(),
        task.classes()
    );
    let dump = args.get("dump").map(std::path::PathBuf::from);
    if let Some(d) = &dump {
        std::fs::create_dir_all(d)?;
    }
    for i in 0..n {
        let ex = task.sample(&mut rng);
        let mean: f32 = ex.x.iter().sum::<f32>() / ex.x.len() as f32;
        let nz = ex.x.iter().filter(|&&v| v != 0.0).count();
        println!(
            "  sample {i}: label={} mean={mean:.4} nonzero={nz}/{}",
            ex.label,
            ex.x.len()
        );
        if let Some(d) = &dump {
            // image-shaped tasks dump as PGM for visual inspection
            let side = (task.seq_len() as f64).sqrt() as usize;
            if side * side == task.seq_len() && task.d_input() == 1 {
                let path = d.join(format!("{}_{i}_label{}.pgm", task.name(), ex.label));
                s5::util::pgm::write_pgm(&path, &ex.x, side, side)?;
                println!("    wrote {}", path.display());
            }
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    use s5::coordinator::sweep::{Axis, Grid, SweepResults};
    use s5::coordinator::{TrainConfig, Trainer};
    use s5::runtime::Client;
    let mut base = TrainConfig::for_preset(&args.get_or("preset", "smnist"));
    base.steps = args.get_usize("steps", 30);
    base.train_pool = args.get_usize("train-pool", 128);
    base.eval_pool = args.get_usize("eval-pool", 48);
    base.eval_every = 0;
    let parse_f64s = |key: &str| -> Option<Vec<f64>> {
        args.get(key)
            .map(|v| v.split(',').map(|x| x.parse().expect(key)).collect())
    };
    let mut grid = Grid::new(base);
    if let Some(lrs) = parse_f64s("lrs") {
        grid = grid.axis(Axis::Lr(lrs));
    }
    if let Some(wds) = parse_f64s("wds") {
        grid = grid.axis(Axis::WeightDecay(wds));
    }
    if let Some(seeds) = args.get("seeds") {
        grid = grid.axis(Axis::Seed(
            seeds.split(',').map(|x| x.parse().expect("seeds")).collect(),
        ));
    }
    if grid.axes.is_empty() {
        grid = grid.axis(Axis::Lr(vec![1e-3, 3e-3, 6e-3]));
    }
    let runs = grid.expand();
    info!("sweep: {} runs of {} steps each", runs.len(), grid.base.steps);
    let client = Client::cpu()?;
    let mut results = SweepResults::default();
    for (label, cfg) in runs {
        let steps = cfg.steps;
        let mut trainer = Trainer::new(&client, cfg)?;
        for _ in 0..steps {
            trainer.train_step()?;
        }
        let (loss, metric) = trainer.evaluate()?;
        info!("  {label}: loss={loss:.4} metric={metric:.4}");
        results.push(label, loss, metric);
    }
    print!("{}", results.render());
    if let Some((label, loss, metric)) = results.best_by_metric() {
        println!("best: {label} (loss={loss:.4}, metric={metric:.4})");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_sweep(_args: &Args) -> anyhow::Result<()> {
    bail!("sweep needs the PJRT runtime (rebuild with --features pjrt)")
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", ARTIFACTS_DIR);
    let dir = Path::new(&dir);
    if !dir.exists() {
        bail!("artifacts directory {dir:?} missing — run `make artifacts`");
    }
    let mut t = Table::new(&["artifact", "kind", "inputs", "outputs", "hlo bytes"]);
    let mut names: Vec<String> = std::fs::read_dir(dir)?
        .filter_map(|e| {
            let p = e.ok()?.path();
            let s = p.file_name()?.to_string_lossy().to_string();
            s.strip_suffix(".manifest.txt").map(|x| x.to_string())
        })
        .collect();
    names.sort();
    for name in names {
        let m = Manifest::load(&dir.join(format!("{name}.manifest.txt")))?;
        let hlo = std::fs::metadata(dir.join(format!("{name}.hlo.txt")))
            .map(|md| md.len())
            .unwrap_or(0);
        t.row(&[
            name,
            m.kind.clone(),
            m.inputs.len().to_string(),
            m.outputs.len().to_string(),
            hlo.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
