//! Dense complex linear algebra from scratch.
//!
//! Provides the [`CMat`] dense complex matrix, matrix/vector products, and a
//! cyclic **Jacobi eigensolver for Hermitian matrices**. The eigensolver is
//! the substrate that lets the pure-Rust reference stack diagonalize HiPPO-N
//! exactly the way the Python build path does (via the Hermitian matrix
//! i·S — see `ssm::hippo`): HiPPO-N itself is *normal*, so its skew part has
//! an orthonormal eigenbasis and Jacobi converges quadratically.

use crate::num::C64;

/// Dense row-major complex matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<C64>,
}

impl CMat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        CMat { rows, cols, data: vec![C64::ZERO; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = CMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = C64::ONE;
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> C64) -> Self {
        let mut m = CMat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Build a real matrix (imaginary parts zero).
    pub fn from_real(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols);
        CMat {
            rows,
            cols,
            data: data.iter().map(|&x| C64::from_re(x)).collect(),
        }
    }

    /// Conjugate transpose Aᴴ.
    pub fn hermitian_t(&self) -> CMat {
        CMat::from_fn(self.cols, self.rows, |i, j| self[(j, i)].conj())
    }

    /// Matrix product A·B.
    pub fn matmul(&self, other: &CMat) -> CMat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = CMat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == C64::ZERO {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Matrix–vector product A·x.
    pub fn matvec(&self, x: &[C64]) -> Vec<C64> {
        assert_eq!(self.cols, x.len());
        let mut out = vec![C64::ZERO; self.rows];
        for i in 0..self.rows {
            let mut acc = C64::ZERO;
            for j in 0..self.cols {
                acc += self[(i, j)] * x[j];
            }
            out[i] = acc;
        }
        out
    }

    /// Scale all entries.
    pub fn scale(&self, s: C64) -> CMat {
        CMat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&z| z * s).collect(),
        }
    }

    /// Elementwise sum.
    pub fn add(&self, other: &CMat) -> CMat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        CMat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| a + b)
                .collect(),
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|z| z.norm_sq()).sum::<f64>().sqrt()
    }

    /// Largest |A - Aᴴ| entry — hermitian defect.
    pub fn hermitian_defect(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        let mut d = 0.0f64;
        for i in 0..self.rows {
            for j in 0..self.cols {
                d = d.max((self[(i, j)] - self[(j, i)].conj()).abs());
            }
        }
        d
    }

    /// Extract a column.
    pub fn col(&self, j: usize) -> Vec<C64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }
}

impl std::ops::Index<(usize, usize)> for CMat {
    type Output = C64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &C64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for CMat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut C64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Eigendecomposition result of a Hermitian matrix: `a = V · diag(w) · Vᴴ`
/// with real eigenvalues `w` (ascending) and unitary `V` (columns are
/// eigenvectors).
#[derive(Clone, Debug)]
pub struct HermitianEig {
    pub eigenvalues: Vec<f64>,
    pub vectors: CMat,
}

/// Cyclic Jacobi eigensolver for Hermitian matrices.
///
/// Repeatedly annihilates the largest-magnitude off-diagonal entry with a
/// complex Givens rotation until the off-diagonal Frobenius mass is below
/// `tol · ‖A‖`. Quadratically convergent; O(n³) per sweep, fine for the
/// state sizes used in SSM initialization (P ≤ a few hundred).
pub fn eigh(a: &CMat, tol: f64) -> HermitianEig {
    assert_eq!(a.rows, a.cols, "eigh needs a square matrix");
    let n = a.rows;
    assert!(
        a.hermitian_defect() < 1e-9 * (1.0 + a.fro_norm()),
        "matrix is not Hermitian"
    );
    let mut m = a.clone();
    let mut v = CMat::eye(n);
    let norm = a.fro_norm().max(1e-300);

    let off = |m: &CMat| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    s += m[(i, j)].norm_sq();
                }
            }
        }
        s.sqrt()
    };

    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        if off(&m) <= tol * norm {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() <= tol * norm / (n as f64) {
                    continue;
                }
                // Unitary 2x2 rotation zeroing entry (p,q) of the Hermitian
                // submatrix [[α, β],[β̄, γ]] with β = |β|e^{iφ}:
                // phase-factor β out (T = diag(1, e^{-iφ}) makes it real),
                // then a real Jacobi rotation with tan 2θ = 2|β|/(γ−α).
                // Combined U = T·R has columns
                //   U[:,p] = [c, −s·e^{−iφ}]ᵀ,  U[:,q] = [s, c·e^{−iφ}]ᵀ.
                let alpha = m[(p, p)].re;
                let gamma = m[(q, q)].re;
                let abs_b = apq.abs();
                let phase = apq.scale(1.0 / abs_b); // e^{iφ}
                let theta = 0.5 * (2.0 * abs_b).atan2(gamma - alpha);
                let (c, s) = (theta.cos(), theta.sin());
                let se_m = phase.conj().scale(s); // s·e^{−iφ}
                let ce_m = phase.conj().scale(c); // c·e^{−iφ}
                let se_p = phase.scale(s); // s·e^{+iφ}
                let ce_p = phase.scale(c); // c·e^{+iφ}
                // rows (U^H M): row_p' = c·row_p − s·e^{iφ}·row_q,
                //               row_q' = s·row_p + c·e^{iφ}·row_q
                for j in 0..n {
                    let mpj = m[(p, j)];
                    let mqj = m[(q, j)];
                    m[(p, j)] = mpj.scale(c) - se_p * mqj;
                    m[(q, j)] = mpj.scale(s) + ce_p * mqj;
                }
                // cols (M U): col_p' = c·col_p − s·e^{−iφ}·col_q,
                //             col_q' = s·col_p + c·e^{−iφ}·col_q
                for i in 0..n {
                    let mip = m[(i, p)];
                    let miq = m[(i, q)];
                    m[(i, p)] = mip.scale(c) - se_m * miq;
                    m[(i, q)] = mip.scale(s) + ce_m * miq;
                }
                // accumulate eigenvectors: V ← V·U (columns like cols of M)
                for i in 0..n {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = vip.scale(c) - se_m * viq;
                    v[(i, q)] = vip.scale(s) + ce_m * viq;
                }
            }
        }
    }

    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)].re, i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let eigenvalues: Vec<f64> = pairs.iter().map(|&(w, _)| w).collect();
    let vectors = CMat::from_fn(n, n, |i, j| v[(i, pairs[j].1)]);
    HermitianEig { eigenvalues, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::prop;

    fn rand_hermitian(g: &mut Rng, n: usize) -> CMat {
        let mut a = CMat::zeros(n, n);
        for i in 0..n {
            a[(i, i)] = C64::from_re(g.normal());
            for j in (i + 1)..n {
                let z = C64::new(g.normal(), g.normal());
                a[(i, j)] = z;
                a[(j, i)] = z.conj();
            }
        }
        a
    }

    #[test]
    fn matmul_identity() {
        let mut g = Rng::new(0);
        let a = CMat::from_fn(4, 4, |_, _| C64::new(g.normal(), g.normal()));
        let i = CMat::eye(4);
        let prod = a.matmul(&i);
        assert!((prod.fro_norm() - a.fro_norm()).abs() < 1e-12);
        for k in 0..16 {
            assert!((prod.data[k] - a.data[k]).abs() < 1e-12);
        }
    }

    #[test]
    fn hermitian_t_involution() {
        let mut g = Rng::new(1);
        let a = CMat::from_fn(3, 5, |_, _| C64::new(g.normal(), g.normal()));
        let b = a.hermitian_t().hermitian_t();
        assert_eq!(a, b);
    }

    #[test]
    fn eigh_diagonal_matrix() {
        let mut a = CMat::zeros(3, 3);
        a[(0, 0)] = C64::from_re(3.0);
        a[(1, 1)] = C64::from_re(-1.0);
        a[(2, 2)] = C64::from_re(2.0);
        let e = eigh(&a, 1e-12);
        assert_eq!(e.eigenvalues.len(), 3);
        assert!((e.eigenvalues[0] + 1.0).abs() < 1e-10);
        assert!((e.eigenvalues[2] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn prop_eigh_reconstructs() {
        prop::check("eigh reconstruction", 25, |g| {
            let n = 2 + g.below(8);
            let a = rand_hermitian(g, n);
            let e = eigh(&a, 1e-12);
            // V diag(w) V^H == A
            let mut vd = e.vectors.clone();
            for i in 0..n {
                for j in 0..n {
                    vd[(i, j)] = vd[(i, j)].scale(e.eigenvalues[j]);
                }
            }
            let rec = vd.matmul(&e.vectors.hermitian_t());
            let err = rec.add(&a.scale(-C64::ONE)).fro_norm() / (1.0 + a.fro_norm());
            prop::ensure_msg(err < 1e-8, format!("reconstruction err {err}"))
        });
    }

    #[test]
    fn prop_eigh_vectors_unitary() {
        prop::check("eigh unitarity", 25, |g| {
            let n = 2 + g.below(8);
            let a = rand_hermitian(g, n);
            let e = eigh(&a, 1e-12);
            let gram = e.vectors.hermitian_t().matmul(&e.vectors);
            let err = gram.add(&CMat::eye(n).scale(-C64::ONE)).fro_norm();
            prop::ensure_msg(err < 1e-8, format!("unitarity err {err}"))
        });
    }

    #[test]
    fn prop_eigenvalues_match_trace() {
        prop::check("eig trace", 25, |g| {
            let n = 2 + g.below(8);
            let a = rand_hermitian(g, n);
            let e = eigh(&a, 1e-12);
            let tr: f64 = (0..n).map(|i| a[(i, i)].re).sum();
            let sum: f64 = e.eigenvalues.iter().sum();
            prop::close_f64(tr, sum, 1e-8)
        });
    }

    #[test]
    #[should_panic(expected = "not Hermitian")]
    fn eigh_rejects_non_hermitian() {
        let mut a = CMat::zeros(2, 2);
        a[(0, 1)] = C64::ONE;
        eigh(&a, 1e-10);
    }
}
