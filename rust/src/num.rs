//! Complex arithmetic from scratch (the offline build has no `num-complex`).
//!
//! [`C64`] (f64 parts) is used by the initialization/linear-algebra path and
//! the reference SSM implementations; [`C32`] (f32 parts) mirrors the planar
//! layout the L1 Pallas kernel uses and is the element type of the
//! performance-critical scan loops.

use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub};

/// Complex number with `f64` components.
#[derive(Clone, Copy, PartialEq, Default)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

/// Complex number with `f32` components (planar-kernel element type).
#[derive(Clone, Copy, PartialEq, Default)]
pub struct C32 {
    pub re: f32,
    pub im: f32,
}

macro_rules! impl_complex {
    ($name:ident, $t:ty) => {
        impl $name {
            pub const ZERO: $name = $name { re: 0.0, im: 0.0 };
            pub const ONE: $name = $name { re: 1.0, im: 0.0 };
            pub const I: $name = $name { re: 0.0, im: 1.0 };

            #[inline]
            pub fn new(re: $t, im: $t) -> Self {
                Self { re, im }
            }

            #[inline]
            pub fn from_re(re: $t) -> Self {
                Self { re, im: 0.0 }
            }

            /// Complex conjugate.
            #[inline]
            pub fn conj(self) -> Self {
                Self { re: self.re, im: -self.im }
            }

            /// Squared magnitude |z|².
            #[inline]
            pub fn norm_sq(self) -> $t {
                self.re * self.re + self.im * self.im
            }

            /// Magnitude |z|.
            #[inline]
            pub fn abs(self) -> $t {
                self.norm_sq().sqrt()
            }

            /// Argument in (-π, π].
            #[inline]
            pub fn arg(self) -> $t {
                self.im.atan2(self.re)
            }

            /// Complex exponential e^z.
            #[inline]
            pub fn exp(self) -> Self {
                let r = self.re.exp();
                Self { re: r * self.im.cos(), im: r * self.im.sin() }
            }

            /// Multiplicative inverse 1/z.
            #[inline]
            pub fn inv(self) -> Self {
                let d = self.norm_sq();
                Self { re: self.re / d, im: -self.im / d }
            }

            /// Scale by a real factor.
            #[inline]
            pub fn scale(self, s: $t) -> Self {
                Self { re: self.re * s, im: self.im * s }
            }

            /// e^{iθ} on the unit circle.
            #[inline]
            pub fn cis(theta: $t) -> Self {
                Self { re: theta.cos(), im: theta.sin() }
            }

            /// Integer power by repeated squaring.
            pub fn powi(self, mut n: u32) -> Self {
                let mut base = self;
                let mut acc = Self::ONE;
                while n > 0 {
                    if n & 1 == 1 {
                        acc = acc * base;
                    }
                    base = base * base;
                    n >>= 1;
                }
                acc
            }

            /// True if both components are finite.
            #[inline]
            pub fn is_finite(self) -> bool {
                self.re.is_finite() && self.im.is_finite()
            }
        }

        impl Add for $name {
            type Output = $name;
            #[inline]
            fn add(self, o: $name) -> $name {
                $name { re: self.re + o.re, im: self.im + o.im }
            }
        }

        impl Sub for $name {
            type Output = $name;
            #[inline]
            fn sub(self, o: $name) -> $name {
                $name { re: self.re - o.re, im: self.im - o.im }
            }
        }

        impl Mul for $name {
            type Output = $name;
            #[inline]
            fn mul(self, o: $name) -> $name {
                $name {
                    re: self.re * o.re - self.im * o.im,
                    im: self.re * o.im + self.im * o.re,
                }
            }
        }

        impl Div for $name {
            type Output = $name;
            #[inline]
            fn div(self, o: $name) -> $name {
                self * o.inv()
            }
        }

        impl Neg for $name {
            type Output = $name;
            #[inline]
            fn neg(self) -> $name {
                $name { re: -self.re, im: -self.im }
            }
        }

        impl AddAssign for $name {
            #[inline]
            fn add_assign(&mut self, o: $name) {
                self.re += o.re;
                self.im += o.im;
            }
        }

        impl MulAssign for $name {
            #[inline]
            fn mul_assign(&mut self, o: $name) {
                *self = *self * o;
            }
        }

        impl fmt::Debug for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                if self.im >= 0.0 {
                    write!(f, "{:.6}+{:.6}i", self.re, self.im)
                } else {
                    write!(f, "{:.6}-{:.6}i", self.re, -self.im)
                }
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                fmt::Debug::fmt(self, f)
            }
        }
    };
}

impl_complex!(C64, f64);
impl_complex!(C32, f32);

impl C64 {
    /// Downcast to f32 components.
    #[inline]
    pub fn to_c32(self) -> C32 {
        C32 { re: self.re as f32, im: self.im as f32 }
    }
}

impl C32 {
    /// Upcast to f64 components.
    #[inline]
    pub fn to_c64(self) -> C64 {
        C64 { re: self.re as f64, im: self.im as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn close(a: C64, b: C64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn basics() {
        let a = C64::new(3.0, 4.0);
        assert_eq!(a.abs(), 5.0);
        assert_eq!(a.conj().im, -4.0);
        assert!(close(a * a.inv(), C64::ONE, 1e-12));
        assert!(close(C64::I * C64::I, -C64::ONE, 1e-15));
    }

    #[test]
    fn exp_of_zero_and_i_pi() {
        assert!(close(C64::ZERO.exp(), C64::ONE, 1e-15));
        let e = C64::new(0.0, std::f64::consts::PI).exp();
        assert!(close(e, -C64::ONE, 1e-12));
    }

    #[test]
    fn prop_mul_commutes_and_associates() {
        prop::check("c64 mul", 200, |g| {
            let a = C64::new(g.normal(), g.normal());
            let b = C64::new(g.normal(), g.normal());
            let c = C64::new(g.normal(), g.normal());
            prop::ensure(close(a * b, b * a, 1e-12))?;
            prop::ensure(close((a * b) * c, a * (b * c), 1e-10))
        });
    }

    #[test]
    fn prop_exp_homomorphism() {
        prop::check("exp(a+b)=exp(a)exp(b)", 200, |g| {
            let a = C64::new(g.uniform_in(-2.0, 2.0), g.uniform_in(-3.0, 3.0));
            let b = C64::new(g.uniform_in(-2.0, 2.0), g.uniform_in(-3.0, 3.0));
            prop::ensure(close((a + b).exp(), a.exp() * b.exp(), 1e-10))
        });
    }

    #[test]
    fn prop_powi_matches_repeated_mul() {
        prop::check("powi", 100, |g| {
            let a = C64::cis(g.uniform_in(0.0, 6.28)).scale(0.9);
            let n = g.below(12) as u32;
            let mut want = C64::ONE;
            for _ in 0..n {
                want = want * a;
            }
            prop::ensure(close(a.powi(n), want, 1e-10))
        });
    }

    #[test]
    fn conversions_roundtrip() {
        let a = C64::new(1.25, -0.5); // exactly representable in f32
        assert_eq!(a.to_c32().to_c64(), a);
    }
}
