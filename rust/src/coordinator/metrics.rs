//! Training/serving metrics: step records, moving averages, CSV export,
//! throughput accounting.

use std::fmt::Write as _;
use std::path::Path;

/// One training step record.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub metric: f64, // accuracy for classifiers, MSE for regressors
    pub lr: f64,
    pub wall_secs: f64,
}

/// Accumulating metrics log.
#[derive(Default)]
pub struct MetricsLog {
    pub records: Vec<StepRecord>,
    pub eval_records: Vec<(usize, f64, f64)>, // (step, loss, metric)
}

impl MetricsLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn push_eval(&mut self, step: usize, loss: f64, metric: f64) {
        self.eval_records.push((step, loss, metric));
    }

    /// Exponential moving average of the loss (smoothing for loss curves).
    pub fn ema_loss(&self, alpha: f64) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.records.len());
        let mut ema = None;
        for r in &self.records {
            let e = match ema {
                None => r.loss,
                Some(prev) => alpha * r.loss + (1.0 - alpha) * prev,
            };
            ema = Some(e);
            out.push(e);
        }
        out
    }

    /// Mean steps/sec over the last `window` records.
    pub fn throughput(&self, window: usize) -> f64 {
        let tail: Vec<&StepRecord> =
            self.records.iter().rev().take(window.max(1)).collect();
        if tail.is_empty() {
            return 0.0;
        }
        let total: f64 = tail.iter().map(|r| r.wall_secs).sum();
        tail.len() as f64 / total.max(1e-9)
    }

    /// Last eval metric, if any.
    pub fn last_eval(&self) -> Option<(usize, f64, f64)> {
        self.eval_records.last().copied()
    }

    /// Render a CSV of the step records.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss,metric,lr,wall_secs\n");
        for r in &self.records {
            let _ = writeln!(
                s,
                "{},{:.6},{:.6},{:.6e},{:.6}",
                r.step, r.loss, r.metric, r.lr, r.wall_secs
            );
        }
        s
    }

    pub fn save_csv(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Loss-curve sparkline for terminal logging.
    pub fn sparkline(&self, buckets: usize) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        if self.records.is_empty() {
            return String::new();
        }
        let ema = self.ema_loss(0.2);
        let chunk = (ema.len() as f64 / buckets as f64).max(1.0);
        let vals: Vec<f64> = (0..buckets.min(ema.len()))
            .map(|i| {
                let lo = (i as f64 * chunk) as usize;
                let hi = (((i + 1) as f64 * chunk) as usize).min(ema.len());
                ema[lo..hi.max(lo + 1)].iter().sum::<f64>() / (hi.max(lo + 1) - lo) as f64
            })
            .collect();
        let (mn, mx) = vals
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &v| (a.min(v), b.max(v)));
        vals.iter()
            .map(|&v| {
                let t = if mx > mn { (v - mn) / (mx - mn) } else { 0.0 };
                BARS[((t * 7.0).round() as usize).min(7)]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f64) -> StepRecord {
        StepRecord { step, loss, metric: 0.5, lr: 1e-3, wall_secs: 0.1 }
    }

    #[test]
    fn ema_smooths() {
        let mut m = MetricsLog::new();
        for i in 0..10 {
            m.push(rec(i, if i % 2 == 0 { 1.0 } else { 0.0 }));
        }
        let ema = m.ema_loss(0.3);
        let var_raw: f64 = m.records.windows(2).map(|w| (w[1].loss - w[0].loss).abs()).sum();
        let var_ema: f64 = ema.windows(2).map(|w| (w[1] - w[0]).abs()).sum();
        assert!(var_ema < var_raw);
    }

    #[test]
    fn throughput_counts_steps_per_sec() {
        let mut m = MetricsLog::new();
        for i in 0..5 {
            m.push(rec(i, 1.0));
        }
        assert!((m.throughput(5) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn csv_format() {
        let mut m = MetricsLog::new();
        m.push(rec(1, 0.5));
        let csv = m.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn sparkline_renders() {
        let mut m = MetricsLog::new();
        for i in 0..50 {
            m.push(rec(i, 1.0 / (1.0 + i as f64)));
        }
        let s = m.sparkline(10);
        assert_eq!(s.chars().count(), 10);
    }

    #[test]
    fn eval_records_tracked() {
        let mut m = MetricsLog::new();
        m.push_eval(10, 0.7, 0.8);
        m.push_eval(20, 0.5, 0.9);
        assert_eq!(m.last_eval().unwrap(), (20, 0.5, 0.9));
    }
}
