//! Hyperparameter sweeps: grid expansion + best-by-metric selection.
//!
//! The paper's Table 11 configurations came from sweeps over LR, weight
//! decay and SSM-LR ratio (§G.2). This module provides the L3 machinery:
//! declare a [`Grid`] over [`TrainConfig`] fields, expand it to runs, and
//! fold results with [`SweepResults`]. The execution itself goes through
//! the normal `crate::coordinator::Trainer` (`pjrt` feature); see `s5 sweep`.

use crate::coordinator::config::TrainConfig;

/// One axis of a grid sweep.
#[derive(Clone, Debug)]
pub enum Axis {
    Lr(Vec<f64>),
    WeightDecay(Vec<f64>),
    Seed(Vec<u64>),
    WarmupSteps(Vec<usize>),
}

impl Axis {
    fn len(&self) -> usize {
        match self {
            Axis::Lr(v) => v.len(),
            Axis::WeightDecay(v) => v.len(),
            Axis::Seed(v) => v.len(),
            Axis::WarmupSteps(v) => v.len(),
        }
    }

    fn apply(&self, idx: usize, cfg: &mut TrainConfig) {
        match self {
            Axis::Lr(v) => cfg.base_lr = v[idx],
            Axis::WeightDecay(v) => cfg.weight_decay = v[idx],
            Axis::Seed(v) => cfg.seed = v[idx],
            Axis::WarmupSteps(v) => cfg.warmup_steps = v[idx],
        }
    }

    fn label(&self, idx: usize) -> String {
        match self {
            Axis::Lr(v) => format!("lr={}", v[idx]),
            Axis::WeightDecay(v) => format!("wd={}", v[idx]),
            Axis::Seed(v) => format!("seed={}", v[idx]),
            Axis::WarmupSteps(v) => format!("warmup={}", v[idx]),
        }
    }
}

/// A full factorial grid over a base configuration.
pub struct Grid {
    pub base: TrainConfig,
    pub axes: Vec<Axis>,
}

impl Grid {
    pub fn new(base: TrainConfig) -> Grid {
        Grid { base, axes: Vec::new() }
    }

    pub fn axis(mut self, axis: Axis) -> Grid {
        assert!(axis.len() > 0, "empty sweep axis");
        self.axes.push(axis);
        self
    }

    /// Total number of runs.
    pub fn len(&self) -> usize {
        self.axes.iter().map(Axis::len).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand to (label, config) pairs in row-major axis order.
    pub fn expand(&self) -> Vec<(String, TrainConfig)> {
        let n = self.len();
        let mut out = Vec::with_capacity(n);
        for flat in 0..n {
            let mut cfg = self.base.clone();
            let mut rem = flat;
            let mut labels = Vec::with_capacity(self.axes.len());
            for axis in self.axes.iter().rev() {
                let idx = rem % axis.len();
                rem /= axis.len();
                axis.apply(idx, &mut cfg);
                labels.push(axis.label(idx));
            }
            labels.reverse();
            out.push((labels.join(" "), cfg));
        }
        out
    }
}

/// Collected sweep outcomes.
#[derive(Default)]
pub struct SweepResults {
    pub rows: Vec<(String, f64, f64)>, // (label, loss, metric)
}

impl SweepResults {
    pub fn push(&mut self, label: String, loss: f64, metric: f64) {
        self.rows.push((label, loss, metric));
    }

    /// Best run by highest metric (accuracy) — ties broken by lower loss.
    /// `total_cmp` (not `partial_cmp().unwrap()`): a NaN loss from a
    /// diverged run must not panic the whole sweep report.
    pub fn best_by_metric(&self) -> Option<&(String, f64, f64)> {
        self.rows.iter().max_by(|a, b| a.2.total_cmp(&b.2).then(b.1.total_cmp(&a.1)))
    }

    /// Best run by lowest loss (regression tasks).
    pub fn best_by_loss(&self) -> Option<&(String, f64, f64)> {
        self.rows.iter().min_by(|a, b| a.1.total_cmp(&b.1))
    }

    pub fn render(&self) -> String {
        let mut t = crate::util::Table::new(&["run", "loss", "metric"]);
        for (label, loss, metric) in &self.rows {
            t.row(&[label.clone(), format!("{loss:.4}"), format!("{metric:.4}")]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_expands_factorially() {
        let g = Grid::new(TrainConfig::default())
            .axis(Axis::Lr(vec![1e-3, 3e-3]))
            .axis(Axis::Seed(vec![0, 1, 2]));
        assert_eq!(g.len(), 6);
        let runs = g.expand();
        assert_eq!(runs.len(), 6);
        // every combination appears exactly once
        let mut seen = std::collections::BTreeSet::new();
        for (label, cfg) in &runs {
            assert!(seen.insert((format!("{:.0e}", cfg.base_lr), cfg.seed)), "{label}");
        }
    }

    #[test]
    fn labels_carry_values() {
        let g = Grid::new(TrainConfig::default()).axis(Axis::WeightDecay(vec![0.05]));
        let runs = g.expand();
        assert!(runs[0].0.contains("wd=0.05"), "{}", runs[0].0);
    }

    #[test]
    fn best_selection() {
        let mut r = SweepResults::default();
        r.push("a".into(), 0.9, 0.5);
        r.push("b".into(), 0.7, 0.8);
        r.push("c".into(), 0.6, 0.8);
        assert_eq!(r.best_by_metric().unwrap().0, "c"); // tie on metric → lower loss
        assert_eq!(r.best_by_loss().unwrap().0, "c");
    }

    #[test]
    #[should_panic(expected = "empty sweep axis")]
    fn rejects_empty_axis() {
        let _ = Grid::new(TrainConfig::default()).axis(Axis::Lr(vec![]));
    }
}
