//! Preset → data-generator routing.
//!
//! Every AOT preset (see `python/compile/aot.py::PRESETS`) maps to one of
//! the synthetic generators at the dimensions recorded in its manifest
//! meta. Ablation presets reuse the base task of the experiment they
//! ablate (Table 5 → sMNIST, Table 6 → ListOps).

use anyhow::bail;

use crate::data::{self, TaskGen};
use crate::runtime::Manifest;

/// Build the generator for a classifier preset from its manifest.
pub fn task_for_preset(preset: &str, manifest: &Manifest) -> anyhow::Result<Box<dyn TaskGen>> {
    let length = manifest.meta_usize("length")?;
    let task: Box<dyn TaskGen> = if preset.starts_with("abl5") || preset == "smnist" {
        if length != 784 {
            bail!("smnist-family preset with L={length}");
        }
        Box::new(data::mnist::SeqMnist::new(false))
    } else if preset.starts_with("abl6") || preset == "listops" {
        Box::new(data::listops::ListOps::new(length))
    } else if preset == "text" {
        Box::new(data::text::Sentiment::new(length))
    } else if preset == "image" {
        Box::new(data::image::TextureImage::new(int_sqrt(length)?))
    } else if preset == "pathfinder" {
        Box::new(data::pathfinder::Pathfinder::new(int_sqrt(length)?))
    } else if preset == "pathx" {
        Box::new(data::pathfinder::Pathfinder::new_pathx(int_sqrt(length)?))
    } else if preset == "speech" {
        Box::new(data::speech::SpeechCommands::new(length))
    } else {
        bail!("no task generator for preset {preset:?}");
    };
    // cross-check the generator agrees with the artifact's shape contract
    let d_input = manifest.meta_usize("d_input").unwrap_or(task.d_input());
    let classes = manifest.meta_usize("classes").unwrap_or(task.classes());
    if task.seq_len() != length || task.d_input() != d_input || task.classes() != classes {
        bail!(
            "task/manifest mismatch for {preset}: task (L={}, d={}, c={}) vs manifest (L={length}, d={d_input}, c={classes})",
            task.seq_len(),
            task.d_input(),
            task.classes()
        );
    }
    Ok(task)
}

fn int_sqrt(n: usize) -> anyhow::Result<usize> {
    let s = (n as f64).sqrt().round() as usize;
    if s * s != n {
        bail!("sequence length {n} is not a perfect square");
    }
    Ok(s)
}

/// Retrieval generator for the two-tower preset.
pub fn retrieval_for_preset(manifest: &Manifest) -> anyhow::Result<data::retrieval::Retrieval> {
    let length = manifest.meta_usize("length")?;
    let gen = data::retrieval::Retrieval::new(length);
    if gen.d_input() != manifest.meta_usize("d_input")? {
        bail!("retrieval vocab mismatch");
    }
    Ok(gen)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest(preset: &str, length: usize, d: usize, c: usize) -> Manifest {
        Manifest::parse(&format!(
            "artifact {preset}_train\nkind classifier\nmeta length {length}\nmeta d_input {d}\nmeta classes {c}\ninput 0 x f32 1\n"
        ))
        .unwrap()
    }

    #[test]
    fn routes_core_presets() {
        let cases = [
            ("smnist", 784, 1, 10),
            ("listops", 512, 18, 10),
            ("text", 1024, 32, 2),
            ("image", 1024, 1, 10),
            ("pathfinder", 1024, 1, 2),
            ("pathx", 4096, 1, 2),
            ("speech", 2048, 1, 35),
            ("abl5_pn_scalar", 784, 1, 10),
            ("abl6_continuous_hippo", 256, 18, 10),
        ];
        for (preset, l, d, c) in cases {
            let m = manifest(preset, l, d, c);
            let t = task_for_preset(preset, &m).unwrap_or_else(|e| panic!("{preset}: {e}"));
            assert_eq!(t.seq_len(), l, "{preset}");
        }
    }

    #[test]
    fn rejects_shape_mismatch() {
        let m = manifest("listops", 512, 5, 10); // wrong vocab
        assert!(task_for_preset("listops", &m).is_err());
    }

    #[test]
    fn rejects_unknown_preset() {
        let m = manifest("nope", 16, 1, 2);
        assert!(task_for_preset("nope", &m).is_err());
    }

    #[test]
    fn rejects_non_square_image() {
        let m = manifest("image", 1000, 1, 10);
        assert!(task_for_preset("image", &m).is_err());
    }
}
