//! The training orchestrator.
//!
//! Owns the loop the L2 graphs cannot see: data generation/shuffling, the
//! cosine LR schedule, step counting, periodic held-out evaluation,
//! metrics, and checkpointing. Each step executes the fused
//! loss+grad+AdamW artifact (`<preset>_train`) through PJRT; evaluation
//! executes `<preset>_fwd`.
//!
//! Supports the three graph kinds the AOT pipeline emits:
//! `classifier` (LRA suite, speech, sMNIST, ablations), `retrieval`
//! (two-tower) and `pendulum` (irregular-Δt regression).
//!
//! Compiled only with the `pjrt` feature (the fused train step is an AOT
//! artifact); the native batched engine (`ssm::engine`) covers the
//! inference side in hermetic builds.

use anyhow::{bail, Context};
use std::path::Path;
use xla::Literal;

use crate::coordinator::config::TrainConfig;
use crate::coordinator::metrics::{MetricsLog, StepRecord};
use crate::coordinator::schedule::CosineSchedule;
use crate::coordinator::tasks;
use crate::data::batcher::BatchStream;
use crate::data::pendulum::PendulumSim;
use crate::data::retrieval::Retrieval;
use crate::info;
use crate::rng::Rng;
use crate::runtime::params::{literal_f32, literal_i32, literal_zeros, to_vec_f32, ParamStore};
use crate::runtime::{Artifact, Client};
use crate::util::Timer;

/// Kind-specific data plumbing.
enum TaskData {
    Classifier { train: BatchStream, eval: BatchStream },
    Retrieval { gen: Retrieval, eval_seed: u64 },
    Pendulum { sim: PendulumSim },
}

/// A live training session.
pub struct Trainer {
    pub cfg: TrainConfig,
    train_art: Artifact,
    fwd_art: Artifact,
    /// parameter literals, in the train manifest's params.* order
    params: Vec<Literal>,
    m: Vec<Literal>,
    v: Vec<Literal>,
    n_params: usize,
    schedule: CosineSchedule,
    data: TaskData,
    pub log: MetricsLog,
    rng: Rng,
    pub step: usize,
}

impl Trainer {
    /// Load artifacts + init params and wire the data stream.
    pub fn new(client: &Client, cfg: TrainConfig) -> anyhow::Result<Trainer> {
        let dir = Path::new(&cfg.artifacts_dir);
        let train_art = Artifact::load(dir, &format!("{}_train", cfg.preset), client)?;
        let fwd_art = Artifact::load(dir, &format!("{}_fwd", cfg.preset), client)?;
        let store = ParamStore::load_npz(&Artifact::init_npz_path(dir, &cfg.preset))?;

        // params in manifest order
        let param_idx = train_art.manifest.input_group("params");
        let specs: Vec<_> = param_idx
            .iter()
            .map(|&i| &train_art.manifest.inputs[i])
            .collect();
        let params = store.gather(&specs)?;
        let m: Vec<Literal> = specs.iter().map(|s| literal_zeros(s)).collect::<Result<_, _>>()?;
        let v: Vec<Literal> = specs.iter().map(|s| literal_zeros(s)).collect::<Result<_, _>>()?;
        let n_params = params.len();

        let kind = train_art.manifest.kind.clone();
        let data = match kind.as_str() {
            "classifier" => {
                let task = tasks::task_for_preset(&cfg.preset, &train_art.manifest)?;
                let batch = train_art.manifest.meta_usize("batch")?;
                TaskData::Classifier {
                    train: BatchStream::new(task.as_ref(), cfg.train_pool, batch, cfg.seed),
                    eval: BatchStream::new(
                        task.as_ref(),
                        cfg.eval_pool,
                        batch,
                        cfg.seed ^ 0xE7A1,
                    ),
                }
            }
            "retrieval" => TaskData::Retrieval {
                gen: tasks::retrieval_for_preset(&train_art.manifest)?,
                eval_seed: cfg.seed ^ 0xE7A1,
            },
            "pendulum" => TaskData::Pendulum { sim: PendulumSim::new() },
            other => bail!("unsupported artifact kind {other:?}"),
        };

        let schedule = CosineSchedule::new(cfg.base_lr, cfg.warmup_steps, cfg.steps);
        info!(
            "trainer ready: preset={} kind={} params={} tensors",
            cfg.preset, kind, n_params
        );
        Ok(Trainer {
            cfg,
            train_art,
            fwd_art,
            params,
            m,
            v,
            n_params,
            schedule,
            data,
            log: MetricsLog::new(),
            rng: Rng::new(0xD1CE),
            step: 0,
        })
    }

    fn scalars(&self, lr: f64, wd: f64, step: usize) -> anyhow::Result<[Literal; 3]> {
        Ok([
            literal_f32(&[lr as f32], &[])?,
            literal_f32(&[wd as f32], &[])?,
            literal_f32(&[step as f32], &[])?,
        ])
    }

    /// One optimizer step on a prepared batch (kind-specific tail inputs).
    fn step_with_batch(&mut self, batch_inputs: Vec<Literal>) -> anyhow::Result<(f64, f64)> {
        self.step += 1;
        let lr = self.schedule.lr(self.step);
        let scalars = self.scalars(lr, self.cfg.weight_decay, self.step)?;
        let n = self.n_params;

        let mut refs: Vec<&Literal> = Vec::with_capacity(3 * n + 3 + batch_inputs.len());
        refs.extend(self.params.iter());
        refs.extend(self.m.iter());
        refs.extend(self.v.iter());
        refs.extend(scalars.iter());
        refs.extend(batch_inputs.iter());
        if refs.len() != self.train_art.manifest.inputs.len() {
            bail!(
                "input arity mismatch: built {}, manifest wants {}",
                refs.len(),
                self.train_art.manifest.inputs.len()
            );
        }

        let timer = Timer::start();
        let mut outs = self.train_art.run(&refs)?;
        // outputs: params' (n), m' (n), v' (n), loss, metric
        let metric = outs.pop().context("missing metric output")?;
        let loss = outs.pop().context("missing loss output")?;
        let mut outs = outs.into_iter();
        self.params = outs.by_ref().take(n).collect();
        self.m = outs.by_ref().take(n).collect();
        self.v = outs.by_ref().take(n).collect();
        let loss = to_vec_f32(&loss)?[0] as f64;
        let metric = to_vec_f32(&metric)?[0] as f64;
        self.log.push(StepRecord {
            step: self.step,
            loss,
            metric,
            lr,
            wall_secs: timer.secs(),
        });
        Ok((loss, metric))
    }

    /// Build the batch-input literals for the next training batch.
    fn next_batch_inputs(&mut self) -> anyhow::Result<Vec<Literal>> {
        let man = &self.train_art.manifest;
        match &mut self.data {
            TaskData::Classifier { train, .. } => {
                let b = train.next_batch();
                let x_spec = &man.inputs[man.input_index("x")?];
                Ok(vec![
                    literal_f32(&b.x, &x_spec.dims)?,
                    literal_i32(&b.labels, &[b.batch_size])?,
                ])
            }
            TaskData::Retrieval { gen, .. } => {
                let batch = man.meta_usize("batch")?;
                let x_spec = &man.inputs[man.input_index("x1")?];
                let mut x1 = Vec::new();
                let mut x2 = Vec::new();
                let mut y = Vec::new();
                for _ in 0..batch {
                    let p = gen.sample_pair(&mut self.rng);
                    x1.extend_from_slice(&p.x1);
                    x2.extend_from_slice(&p.x2);
                    y.push(p.label);
                }
                Ok(vec![
                    literal_f32(&x1, &x_spec.dims)?,
                    literal_f32(&x2, &x_spec.dims)?,
                    literal_i32(&y, &[batch])?,
                ])
            }
            TaskData::Pendulum { sim } => {
                let batch = man.meta_usize("batch")?;
                let img_spec = &man.inputs[man.input_index("imgs")?];
                let mut imgs = Vec::new();
                let mut dts = Vec::new();
                let mut tgt = Vec::new();
                for _ in 0..batch {
                    let ex = sim.sample(&mut self.rng);
                    imgs.extend_from_slice(&ex.images);
                    dts.extend_from_slice(&ex.dts);
                    tgt.extend_from_slice(&ex.targets);
                }
                Ok(vec![
                    literal_f32(&imgs, &img_spec.dims)?,
                    literal_f32(&dts, &[batch, sim.obs_len])?,
                    literal_f32(&tgt, &[batch, sim.obs_len, 2])?,
                ])
            }
        }
    }

    /// One training step (generates its own batch).
    pub fn train_step(&mut self) -> anyhow::Result<(f64, f64)> {
        let batch = self.next_batch_inputs()?;
        self.step_with_batch(batch)
    }

    /// Run the configured number of steps with periodic eval + logging.
    pub fn run(&mut self) -> anyhow::Result<()> {
        let steps = self.cfg.steps;
        for _ in 0..steps {
            let (loss, metric) = self.train_step()?;
            if self.step % 10 == 0 || self.step == 1 {
                info!(
                    "step {:>5}/{steps} loss={loss:.4} metric={metric:.4} lr={:.2e} [{}]",
                    self.step,
                    self.schedule.lr(self.step),
                    self.log.sparkline(24),
                );
            }
            if self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0 {
                if let Ok((eloss, emetric)) = self.evaluate() {
                    self.log.push_eval(self.step, eloss, emetric);
                    info!("eval @ {}: loss={eloss:.4} metric={emetric:.4}", self.step);
                }
            }
        }
        if let Some(path) = self.cfg.checkpoint.clone() {
            self.save_checkpoint(Path::new(&path))?;
            info!("checkpoint saved to {path}");
        }
        if let Some(path) = self.cfg.metrics_csv.clone() {
            self.log.save_csv(Path::new(&path))?;
        }
        Ok(())
    }

    /// Held-out evaluation through the fwd artifact.
    pub fn evaluate(&mut self) -> anyhow::Result<(f64, f64)> {
        self.evaluate_with_timescale(1.0)
    }

    /// Evaluation with a Δ-rescaling factor (zero-shot resampling, §6.2).
    pub fn evaluate_with_timescale(&mut self, timescale: f32) -> anyhow::Result<(f64, f64)> {
        match &mut self.data {
            TaskData::Classifier { eval, .. } => {
                let batches = eval.eval_batches();
                let man = &self.fwd_art.manifest;
                let x_spec = &man.inputs[man.input_index("x")?];
                let classes = man.meta_usize("classes")?;
                let (mut correct, mut total, mut loss_sum) = (0usize, 0usize, 0.0f64);
                for b in &batches {
                    let x = literal_f32(&b.x, &x_spec.dims)?;
                    let ts = literal_f32(&[timescale], &[])?;
                    let mut refs: Vec<&Literal> = self.params.iter().collect();
                    refs.push(&ts);
                    refs.push(&x);
                    let outs = self.fwd_art.run(&refs)?;
                    let logits = to_vec_f32(&outs[0])?;
                    for (i, &label) in b.labels.iter().enumerate() {
                        let row = &logits[i * classes..(i + 1) * classes];
                        let (pred, _) = argmax(row);
                        if pred == label as usize {
                            correct += 1;
                        }
                        loss_sum += xent(row, label as usize);
                        total += 1;
                    }
                }
                Ok((loss_sum / total as f64, correct as f64 / total as f64))
            }
            TaskData::Retrieval { gen, eval_seed } => {
                let man = &self.fwd_art.manifest;
                let batch = man.meta_usize("batch")?;
                let x_spec = &man.inputs[man.input_index("x1")?];
                let classes = man.meta_usize("classes")?;
                let mut rng = Rng::new(*eval_seed);
                let (mut correct, mut total, mut loss_sum) = (0usize, 0usize, 0.0f64);
                for _ in 0..(self.cfg.eval_pool / batch).max(1) {
                    let mut x1 = Vec::new();
                    let mut x2 = Vec::new();
                    let mut y = Vec::new();
                    for _ in 0..batch {
                        let p = gen.sample_pair(&mut rng);
                        x1.extend_from_slice(&p.x1);
                        x2.extend_from_slice(&p.x2);
                        y.push(p.label);
                    }
                    let ts = literal_f32(&[timescale], &[])?;
                    let x1l = literal_f32(&x1, &x_spec.dims)?;
                    let x2l = literal_f32(&x2, &x_spec.dims)?;
                    let mut refs: Vec<&Literal> = self.params.iter().collect();
                    refs.push(&ts);
                    refs.push(&x1l);
                    refs.push(&x2l);
                    let outs = self.fwd_art.run(&refs)?;
                    let logits = to_vec_f32(&outs[0])?;
                    for (i, &label) in y.iter().enumerate() {
                        let row = &logits[i * classes..(i + 1) * classes];
                        if argmax(row).0 == label as usize {
                            correct += 1;
                        }
                        loss_sum += xent(row, label as usize);
                        total += 1;
                    }
                }
                Ok((loss_sum / total as f64, correct as f64 / total as f64))
            }
            TaskData::Pendulum { sim } => {
                let man = &self.fwd_art.manifest;
                let batch = man.meta_usize("batch")?;
                let img_spec = &man.inputs[man.input_index("imgs")?];
                let mut rng = Rng::new(0xEE11);
                let (mut mse_sum, mut total) = (0.0f64, 0usize);
                for _ in 0..(self.cfg.eval_pool / batch).max(1) {
                    let mut imgs = Vec::new();
                    let mut dts = Vec::new();
                    let mut tgt = Vec::new();
                    for _ in 0..batch {
                        let ex = sim.sample(&mut rng);
                        imgs.extend_from_slice(&ex.images);
                        dts.extend_from_slice(&ex.dts);
                        tgt.extend_from_slice(&ex.targets);
                    }
                    let il = literal_f32(&imgs, &img_spec.dims)?;
                    let dl = literal_f32(&dts, &[batch, sim.obs_len])?;
                    let mut refs: Vec<&Literal> = self.params.iter().collect();
                    refs.push(&il);
                    refs.push(&dl);
                    let outs = self.fwd_art.run(&refs)?;
                    let pred = to_vec_f32(&outs[0])?;
                    for (p, t) in pred.iter().zip(tgt.iter()) {
                        mse_sum += ((p - t) * (p - t)) as f64;
                        total += 1;
                    }
                }
                let mse = mse_sum / total as f64;
                Ok((mse, mse))
            }
        }
    }

    /// Export current parameters as an npz checkpoint.
    pub fn save_checkpoint(&self, path: &Path) -> anyhow::Result<()> {
        let mut store = ParamStore::new();
        let idx = self.train_art.manifest.input_group("params");
        for (lit, &i) in self.params.iter().zip(idx.iter()) {
            store.insert(
                &self.train_art.manifest.inputs[i].name,
                crate::runtime::params::clone_literal(lit)?,
            );
        }
        store.save_npz(path)
    }

    /// Borrow the current parameter literals (manifest order).
    pub fn params(&self) -> &[Literal] {
        &self.params
    }
}

fn argmax(row: &[f32]) -> (usize, f32) {
    let mut best = (0usize, f32::NEG_INFINITY);
    for (i, &v) in row.iter().enumerate() {
        if v > best.1 {
            best = (i, v);
        }
    }
    best
}

fn xent(row: &[f32], label: usize) -> f64 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = (row.iter().map(|&v| ((v - mx) as f64).exp()).sum::<f64>()).ln() + mx as f64;
    lse - row[label] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_xent() {
        let row = [0.1f32, 2.0, -1.0];
        assert_eq!(argmax(&row).0, 1);
        let l = xent(&row, 1);
        assert!(l > 0.0 && l < 1.0, "{l}");
        // xent of the true argmax is smaller than of other labels
        assert!(xent(&row, 1) < xent(&row, 0));
    }
}
