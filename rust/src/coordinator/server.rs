//! Inference server: request queue → dynamic batcher → worker.
//!
//! The serving half of the coordinator (vLLM-router-shaped, scaled to this
//! system): callers submit single sequences; a worker thread owns the
//! model, coalesces outstanding requests into batches (waiting at most
//! `max_wait` for stragglers), executes once per batch, and fans the logit
//! rows back out. The offline build has no tokio, so the event loop is
//! built on std::sync::mpsc — which also keeps the hot path free of
//! async-runtime overhead.
//!
//! Two execution backends share the queue/batcher/fan-out machinery:
//!
//! * **Native** ([`NativeInferenceServer`], always available) — generic
//!   over `dyn` [`SequenceModel`]: up to `max_batch` queued sequences are
//!   packed into one typed [`Batch`] (via `data/batcher::pack_rows`) and
//!   pushed through [`SequenceModel::prefill_into`] with a reused
//!   [`EngineWorkspace`] — one dynamic-batching loop serves the S5 stack
//!   and the RNN baselines alike. The server also owns a
//!   [`SessionPool`], handing out prefill-then-step streaming
//!   [`Session`]s per connection over the same shared model.
//! * **PJRT** (`InferenceServer`, behind the `pjrt` feature) — executes a
//!   pre-compiled fixed-batch artifact, padding to the artifact's batch
//!   dimension.
//!
//! Timescales are `f64` end to end (request → coalescing key → model), so
//! server-side timescale grouping can never alias two nearby values
//! through an f32 round trip.
//!
//! Both backends spawn their one long-lived worker through the shared
//! [`spawn_worker`] path; per-batch parallelism inside the native engine
//! dispatches on the process-wide persistent worker pool
//! ([`crate::runtime::pool`]) instead of spawning per request.

use anyhow::Context;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::data::batcher::pack_rows_into;
use crate::runtime::pool::spawn_worker;
use crate::ssm::api::{Batch, ForwardOptions, SequenceModel, Session, SessionPool};
use crate::ssm::engine::{auto_threads, EngineWorkspace};
use crate::ssm::s5::S5Model;

/// One inference request: a single (L × d_input) sequence.
struct Request {
    x: Vec<f32>,
    timescale: f64,
    submitted: Instant,
    resp: Sender<anyhow::Result<Response>>,
}

/// The reply: logits plus telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    /// how many real requests shared the executed batch
    pub batched_with: usize,
    pub queue_secs: f64,
    pub total_secs: f64,
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// max time the batcher waits to fill a batch
    pub max_wait: Duration,
    /// max requests coalesced into one executed batch (native mode; the
    /// PJRT mode is pinned to the artifact's compiled batch dimension)
    pub max_batch: usize,
    /// worker/scan threads for the native engine; 0 = auto-detect via
    /// `std::thread::available_parallelism`
    pub threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_wait: Duration::from_millis(2), max_batch: 16, threads: 0 }
    }
}

/// Aggregate serving statistics.
#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
}

impl ServerStats {
    /// Mean requests per executed batch.
    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// Handle for submitting requests; clone freely across client threads.
#[derive(Clone)]
pub struct ServeHandle {
    tx: Sender<Request>,
    /// Flat request width: L × d_input.
    pub row: usize,
    /// Output row width per sequence (classifier logits, hidden state, …).
    pub d_output: usize,
}

impl ServeHandle {
    /// Blocking single inference (row-major L×d sequence → logits).
    pub fn infer(&self, x: Vec<f32>) -> anyhow::Result<Response> {
        self.infer_with_timescale(x, 1.0)
    }

    /// Inference with a Δ-rescale factor (zero-shot resampling path).
    /// `timescale` is `f64` all the way into the model, matching the
    /// forward signatures (no lossy f32 hop).
    pub fn infer_with_timescale(&self, x: Vec<f32>, timescale: f64) -> anyhow::Result<Response> {
        anyhow::ensure!(x.len() == self.row, "bad request width {}", x.len());
        let (rtx, rrx) = channel();
        self.tx
            .send(Request { x, timescale, submitted: Instant::now(), resp: rtx })
            .ok()
            .context("server stopped")?;
        rrx.recv().context("server dropped request")?
    }
}

/// Drain the channel into a batch of ≤ `max_batch` same-timescale
/// requests, waiting at most `max_wait` past the first request.
/// Mismatched-timescale stragglers are executed alone via `run_one`.
/// The coalescing key is the exact `f64` timescale, so two nearby-but-
/// different values are never batched (and thus never served) as one.
fn coalesce(
    rx: &Receiver<Request>,
    first: Request,
    max_batch: usize,
    max_wait: Duration,
    mut run_one: impl FnMut(Vec<Request>),
) -> Vec<Request> {
    let mut pending = vec![first];
    let deadline = Instant::now() + max_wait;
    while pending.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) if r.timescale == pending[0].timescale => pending.push(r),
            Ok(r) => {
                // different timescale: run it in its own batch
                run_one(vec![r]);
                continue;
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    pending
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// A running native inference server over the batched pure-Rust engine,
/// generic over `dyn` [`SequenceModel`]. Dropping it stops the worker.
pub struct NativeInferenceServer {
    handle: ServeHandle,
    pub stats: Arc<ServerStats>,
    sessions: SessionPool,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl NativeInferenceServer {
    /// Start serving an [`S5Model`] (convenience wrapper around
    /// [`NativeInferenceServer::start_model`]).
    pub fn start(model: S5Model, l: usize, cfg: ServerConfig) -> NativeInferenceServer {
        NativeInferenceServer::start_model(Arc::new(model), l, cfg)
    }

    /// Start serving any [`SequenceModel`] for fixed-length (L × d_input)
    /// sequences — the same dynamic-batching loop serves the S5 stack and
    /// the RNN baselines.
    ///
    /// The worker shares the model `Arc`, owns one [`EngineWorkspace`]
    /// (reused across batches: zero steady-state allocation on the big
    /// buffers) and a scan backend sized to `cfg.threads` (0 =
    /// auto-detect). The backend dispatches on the **process-wide
    /// persistent worker pool** (see [`crate::runtime::pool`]): the
    /// batch worker, every streaming [`Session`] handed out by
    /// [`NativeInferenceServer::open_session`], and any co-resident
    /// server share one pool, so high-rate serving performs zero
    /// steady-state thread spawns after warmup.
    pub fn start_model(
        model: Arc<dyn SequenceModel>,
        l: usize,
        cfg: ServerConfig,
    ) -> NativeInferenceServer {
        let spec = model.spec();
        let row = l * spec.d_input;
        let d_output = spec.d_output;
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(ServerStats::default());
        let wstats = stats.clone();
        let opts = ForwardOptions::new().with_threads(auto_threads(cfg.threads));
        let sessions = SessionPool::new(model.clone(), opts.clone());
        let worker = spawn_worker("s5-native-server", move || {
            native_worker_loop(model, rx, cfg, opts, l, row, d_output, wstats);
        });
        NativeInferenceServer {
            handle: ServeHandle { tx, row, d_output },
            stats,
            sessions,
            worker: Some(worker),
        }
    }

    pub fn handle(&self) -> ServeHandle {
        self.handle.clone()
    }

    /// Check out a streaming [`Session`] over the served model (pooled:
    /// closed sessions' states are reused across connections). Streaming
    /// steps run on the caller's thread — they are latency-bound, not
    /// batch-bound — while sharing the worker's model.
    pub fn open_session(&self) -> Session {
        self.sessions.acquire()
    }

    /// Return a session to the pool for the next connection.
    pub fn close_session(&self, session: Session) {
        self.sessions.release(session);
    }
}

impl Drop for NativeInferenceServer {
    fn drop(&mut self) {
        // closing the channel stops the worker
        let (tx, _) = channel();
        self.handle.tx = tx;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn native_worker_loop(
    model: Arc<dyn SequenceModel>,
    rx: Receiver<Request>,
    cfg: ServerConfig,
    opts: ForwardOptions,
    l: usize,
    row: usize,
    d_output: usize,
    stats: Arc<ServerStats>,
) {
    let d_input = row / l;
    let mut ws = EngineWorkspace::new();
    let mut xbuf = Vec::new();
    let mut logits = Vec::new();
    let max_batch = cfg.max_batch.max(1);
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let execute = |pending: Vec<Request>,
                       ws: &mut EngineWorkspace,
                       xbuf: &mut Vec<f32>,
                       logits: &mut Vec<f32>| {
            let n = pending.len();
            stats.requests.fetch_add(n as u64, Ordering::Relaxed);
            stats.batches.fetch_add(1, Ordering::Relaxed);
            let t0 = Instant::now();
            let rows: Vec<&[f32]> = pending.iter().map(|r| r.x.as_slice()).collect();
            pack_rows_into(&rows, n, row, xbuf);
            logits.resize(n * d_output, 0.0);
            let batch_opts = opts.clone().with_timescale(pending[0].timescale);
            model.prefill_into(
                Batch::new(&xbuf[..n * row], n, l, d_input),
                &batch_opts,
                ws,
                &mut logits[..n * d_output],
            );
            for (i, r) in pending.into_iter().enumerate() {
                let resp = Response {
                    logits: logits[i * d_output..(i + 1) * d_output].to_vec(),
                    batched_with: n,
                    queue_secs: (t0 - r.submitted).as_secs_f64(),
                    total_secs: r.submitted.elapsed().as_secs_f64(),
                };
                let _ = r.resp.send(Ok(resp));
            }
        };
        let pending = coalesce(&rx, first, max_batch, cfg.max_wait, |one| {
            execute(one, &mut ws, &mut xbuf, &mut logits)
        });
        execute(pending, &mut ws, &mut xbuf, &mut logits);
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (feature-gated: needs the xla runtime)
// ---------------------------------------------------------------------------

/// A running PJRT inference server. Dropping it stops the worker.
#[cfg(feature = "pjrt")]
pub struct InferenceServer {
    handle: ServeHandle,
    pub stats: Arc<ServerStats>,
    worker: Option<std::thread::JoinHandle<()>>,
}

#[cfg(feature = "pjrt")]
impl InferenceServer {
    /// Load `<preset>_fwd` + params (npz checkpoint or `<preset>_init.npz`)
    /// and start the worker.
    ///
    /// PJRT handles are not `Send` (the xla crate wraps raw pointers and an
    /// `Rc` refcount), so the worker thread creates its *own* client and
    /// compiles the artifact locally; only plain data crosses the channel.
    pub fn start(
        artifacts_dir: &std::path::Path,
        preset: &str,
        checkpoint: Option<&std::path::Path>,
        cfg: ServerConfig,
    ) -> anyhow::Result<InferenceServer> {
        use crate::runtime::params::ParamStore;
        use crate::runtime::{Artifact, Client};
        use xla::Literal;

        // manifest is plain data: parse on the caller thread for the handle
        let manifest = crate::runtime::Manifest::load(
            &artifacts_dir.join(format!("{preset}_fwd.manifest.txt")),
        )?;
        let x_spec = &manifest.inputs[manifest.input_index("x")?];
        let batch = x_spec.dims[0];
        let row: usize = x_spec.dims[1..].iter().product();
        let classes = manifest.meta_usize("classes")?;
        let x_dims = x_spec.dims.clone();

        let params_path = checkpoint
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| Artifact::init_npz_path(artifacts_dir, preset));
        let dir = artifacts_dir.to_path_buf();
        let fwd_name = format!("{preset}_fwd");

        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(ServerStats::default());
        let wstats = stats.clone();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let worker = spawn_worker("s5-pjrt-server", move || {
            let setup = (|| -> anyhow::Result<(Artifact, Vec<Literal>)> {
                let client = Client::cpu()?;
                let art = Artifact::load(&dir, &fwd_name, &client)?;
                let store = ParamStore::load_npz(&params_path)?;
                let idx = art.manifest.input_group("params");
                let specs: Vec<_> = idx.iter().map(|&i| &art.manifest.inputs[i]).collect();
                let params = store.gather(&specs)?;
                Ok((art, params))
            })();
            match setup {
                Ok((art, params)) => {
                    let _ = ready_tx.send(Ok(()));
                    pjrt::worker_loop(art, params, rx, cfg, batch, row, classes, x_dims, wstats);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        });
        ready_rx
            .recv()
            .context("server worker died during startup")??;

        Ok(InferenceServer {
            handle: ServeHandle { tx, row, d_output: classes },
            stats,
            worker: Some(worker),
        })
    }

    pub fn handle(&self) -> ServeHandle {
        self.handle.clone()
    }
}

#[cfg(feature = "pjrt")]
impl Drop for InferenceServer {
    fn drop(&mut self) {
        // closing the channel stops the worker
        let (tx, _) = channel();
        self.handle.tx = tx;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use crate::runtime::params::{literal_f32, to_vec_f32};
    use crate::runtime::Artifact;
    use xla::Literal;

    #[allow(clippy::too_many_arguments)]
    pub(super) fn worker_loop(
        art: Artifact,
        params: Vec<Literal>,
        rx: Receiver<Request>,
        cfg: ServerConfig,
        batch: usize,
        row: usize,
        classes: usize,
        x_dims: Vec<usize>,
        stats: Arc<ServerStats>,
    ) {
        loop {
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => return,
            };
            let pending = coalesce(&rx, first, batch, cfg.max_wait, |one| {
                execute_batch(&art, &params, one, batch, row, classes, &x_dims, &stats)
            });
            execute_batch(&art, &params, pending, batch, row, classes, &x_dims, &stats);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_batch(
        art: &Artifact,
        params: &[Literal],
        pending: Vec<Request>,
        batch: usize,
        row: usize,
        classes: usize,
        x_dims: &[usize],
        stats: &Arc<ServerStats>,
    ) {
        let n_real = pending.len();
        stats.requests.fetch_add(n_real as u64, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();

        // pad to the artifact's fixed batch dimension
        let mut x = vec![0.0f32; batch * row];
        for (i, r) in pending.iter().enumerate() {
            x[i * row..(i + 1) * row].copy_from_slice(&r.x);
        }
        let result = (|| -> anyhow::Result<Vec<f32>> {
            // the compiled artifact takes an f32 timescale scalar; the f64
            // request value is only narrowed at this final hop
            let ts = literal_f32(&[pending[0].timescale as f32], &[])?;
            let xl = literal_f32(&x, x_dims)?;
            let mut refs: Vec<&Literal> = params.iter().collect();
            refs.push(&ts);
            refs.push(&xl);
            let outs = art.run(&refs)?;
            to_vec_f32(&outs[0])
        })();

        match result {
            Ok(logits) => {
                for (i, r) in pending.into_iter().enumerate() {
                    let resp = Response {
                        logits: logits[i * classes..(i + 1) * classes].to_vec(),
                        batched_with: n_real,
                        queue_secs: (t0 - r.submitted).as_secs_f64(),
                        total_secs: r.submitted.elapsed().as_secs_f64(),
                    };
                    let _ = r.resp.send(Ok(resp));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in pending {
                    let _ = r.resp.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

/// A started server of either backend — lets the CLI and benches hold one
/// value regardless of execution mode.
pub enum RunningServer {
    Native(NativeInferenceServer),
    #[cfg(feature = "pjrt")]
    Pjrt(InferenceServer),
}

impl RunningServer {
    pub fn handle(&self) -> ServeHandle {
        match self {
            RunningServer::Native(s) => s.handle(),
            #[cfg(feature = "pjrt")]
            RunningServer::Pjrt(s) => s.handle(),
        }
    }

    pub fn stats(&self) -> &ServerStats {
        match self {
            RunningServer::Native(s) => &s.stats,
            #[cfg(feature = "pjrt")]
            RunningServer::Pjrt(s) => &s.stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_config_default_sane() {
        let c = ServerConfig::default();
        assert!(c.max_wait >= Duration::from_micros(100));
        assert!(c.max_batch >= 1);
        // threads = 0 means auto-detect, which must resolve to ≥ 1 worker
        assert_eq!(c.threads, 0);
        assert!(auto_threads(c.threads) >= 1);
    }

    #[test]
    fn stats_fill_math() {
        let s = ServerStats::default();
        s.requests.store(10, Ordering::Relaxed);
        s.batches.store(4, Ordering::Relaxed);
        assert!((s.mean_batch_fill() - 2.5).abs() < 1e-12);
        let empty = ServerStats::default();
        assert_eq!(empty.mean_batch_fill(), 0.0);
    }
}
