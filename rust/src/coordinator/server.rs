//! Inference server: request queue → dynamic batcher → worker.
//!
//! The serving half of the coordinator (vLLM-router-shaped, scaled to this
//! system): callers submit single sequences; a worker thread owns the
//! model, coalesces outstanding requests into batches (waiting at most
//! `max_wait` for stragglers), executes once per batch, and fans the logit
//! rows back out. The offline build has no tokio, so the event loop is
//! built on std::sync::mpsc — which also keeps the hot path free of
//! async-runtime overhead.
//!
//! Two execution backends share the queue/batcher/fan-out machinery:
//!
//! * **Native** ([`NativeInferenceServer`], always available) — generic
//!   over `dyn` [`SequenceModel`]: up to `max_batch` queued sequences are
//!   packed into one typed [`Batch`] (via `data/batcher::pack_rows`) and
//!   pushed through [`SequenceModel::prefill_into`] with a reused
//!   [`EngineWorkspace`] — one dynamic-batching loop serves the S5 stack
//!   and the RNN baselines alike. The server also owns a
//!   [`SessionPool`], handing out prefill-then-step streaming
//!   [`Session`]s per connection over the same shared model.
//! * **PJRT** (`InferenceServer`, behind the `pjrt` feature) — executes a
//!   pre-compiled fixed-batch artifact, padding to the artifact's batch
//!   dimension.
//!
//! Timescales are `f64` end to end (request → coalescing key → model), so
//! server-side timescale grouping can never alias two nearby values
//! through an f32 round trip.
//!
//! # Fault containment
//!
//! Every way a request can fail is a typed [`ServeError`], decided at one
//! of three points:
//!
//! * **Admission** (caller's thread): malformed payloads are rejected as
//!   [`ServeError::InvalidInput`] before touching the queue; the queue is
//!   capacity-bounded (`queue_cap` / `S5_QUEUE_CAP`), and a full queue
//!   sheds the request as [`ServeError::QueueFull`] immediately instead
//!   of growing without bound.
//! * **Dequeue** (worker thread): a request whose deadline (its own, or
//!   the server default / `S5_REQ_DEADLINE_MS`) has already passed is
//!   answered [`ServeError::DeadlineExceeded`] without executing —
//!   drop-before-execute, so an overloaded server never burns a batch on
//!   work nobody is waiting for. Callers with an explicit deadline also
//!   stop waiting on their own clock, so they can never hang forever.
//! * **Execution** (worker thread): the batch forward runs under
//!   `catch_unwind`, riding the worker pool's per-task panic isolation
//!   ([`crate::runtime::pool`]). A panicking model answers exactly the
//!   requests in *its own* batch with [`ServeError::ModelPanic`]; the
//!   worker survives (same thread, not respawned), discards the possibly
//!   half-written workspace, and subsequent batches are bit-for-bit
//!   unaffected — pinned by `tests/server_robustness.rs`.
//!
//! [`NativeInferenceServer::shutdown`] (also run on drop) drains rather
//! than abandons: admission stops ([`ServeError::ShuttingDown`]), the
//! in-flight batch finishes, and every still-queued request is answered
//! `ShuttingDown` — no sender is ever left blocked on a dead channel.
//!
//! Both backends spawn their one long-lived worker through the shared
//! [`spawn_worker`] path; per-batch parallelism inside the native engine
//! dispatches on the process-wide persistent worker pool
//! ([`crate::runtime::pool`]) instead of spawning per request.

#[cfg(feature = "pjrt")]
use anyhow::Context;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError,
};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use crate::data::batcher::pack_rows_into;
use crate::runtime::envcfg::env_usize_once;
use crate::runtime::pool::{panic_message, spawn_worker};
use crate::ssm::api::{Batch, ForwardOptions, SequenceModel, Session, SessionPool};
use crate::ssm::engine::{auto_threads, EngineWorkspace};
use crate::ssm::s5::S5Model;

/// How a request failed. Every serving failure is one of these — the
/// stringly `anyhow` surface is gone from the request path, so callers
/// can match on the cause (shed vs expired vs poisoned batch) instead of
/// grepping messages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Load-shed at admission: the bounded queue already holds `cap`
    /// requests. Retry later or scale out; nothing was enqueued.
    QueueFull { cap: usize },
    /// The request's deadline budget elapsed before a result was
    /// produced — either caught at dequeue (drop-before-execute) or by
    /// the caller's own clock while waiting.
    DeadlineExceeded { budget: Duration },
    /// Rejected at admission before touching the queue: wrong row width,
    /// non-finite payload values, or a non-positive/non-finite timescale.
    InvalidInput(String),
    /// The model panicked while executing the batch this request was in.
    /// Only that batch is poisoned; the worker survives and later
    /// requests are unaffected.
    ModelPanic(String),
    /// The server is draining (or already gone): admission is closed and
    /// queued requests are being answered with this error.
    ShuttingDown,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::QueueFull { cap } => {
                write!(f, "request shed: admission queue full ({cap} queued)")
            }
            ServeError::DeadlineExceeded { budget } => {
                write!(f, "deadline exceeded (budget {budget:?})")
            }
            ServeError::InvalidInput(msg) => write!(f, "invalid input: {msg}"),
            ServeError::ModelPanic(msg) => {
                write!(f, "model panicked while serving this batch: {msg}")
            }
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One inference request: a single (L × d_input) sequence.
struct Request {
    x: Vec<f32>,
    timescale: f64,
    submitted: Instant,
    /// Client-supplied deadline budget; `None` defers to the server
    /// default (see [`ServerConfig::deadline`]).
    deadline: Option<Duration>,
    resp: Sender<Result<Response, ServeError>>,
}

/// What travels over the bounded admission queue: requests, plus a
/// shutdown sentinel so a drain can wake a worker parked in `recv()`.
enum Msg {
    Infer(Request),
    Shutdown,
}

/// The reply: logits plus telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    /// how many real requests shared the executed batch
    pub batched_with: usize,
    pub queue_secs: f64,
    pub total_secs: f64,
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// max time the batcher waits to fill a batch
    pub max_wait: Duration,
    /// max requests coalesced into one executed batch (native mode; the
    /// PJRT mode is pinned to the artifact's compiled batch dimension)
    pub max_batch: usize,
    /// worker/scan threads for the native engine; 0 = auto-detect via
    /// `std::thread::available_parallelism`
    pub threads: usize,
    /// admission-queue capacity in requests; a full queue sheds new
    /// requests as [`ServeError::QueueFull`]. 0 = auto: the
    /// `S5_QUEUE_CAP` override if set (and ≥ 1), else
    /// [`DEFAULT_QUEUE_CAP`].
    pub queue_cap: usize,
    /// default per-request deadline, enforced at dequeue
    /// (drop-before-execute); `None` = auto: `S5_REQ_DEADLINE_MS` if set
    /// and non-zero, else no deadline. A client-supplied deadline
    /// ([`ServeHandle::infer_deadline`]) always takes precedence.
    pub deadline: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(2),
            max_batch: 16,
            threads: 0,
            queue_cap: 0,
            deadline: None,
        }
    }
}

/// Built-in admission-queue capacity when neither
/// [`ServerConfig::queue_cap`] nor `S5_QUEUE_CAP` is set.
pub const DEFAULT_QUEUE_CAP: usize = 1024;

/// Extra slack on the *client-side* wait beyond an explicit request
/// deadline: the dequeue-side verdict for an expired request (or a
/// just-in-time success) needs a moment to travel back before the caller
/// gives up on its own clock.
const CLIENT_DEADLINE_GRACE: Duration = Duration::from_millis(50);

fn resolved_queue_cap(cfg: &ServerConfig) -> usize {
    if cfg.queue_cap > 0 {
        return cfg.queue_cap;
    }
    static CAP: OnceLock<Option<usize>> = OnceLock::new();
    match env_usize_once(&CAP, "S5_QUEUE_CAP", "an admission-queue capacity in requests (>= 1)") {
        Some(n) if n > 0 => n,
        _ => DEFAULT_QUEUE_CAP,
    }
}

fn resolved_default_deadline(cfg: &ServerConfig) -> Option<Duration> {
    if let Some(d) = cfg.deadline {
        return Some(d);
    }
    static MS: OnceLock<Option<usize>> = OnceLock::new();
    match env_usize_once(
        &MS,
        "S5_REQ_DEADLINE_MS",
        "a default request deadline in milliseconds (0 disables)",
    ) {
        Some(ms) if ms > 0 => Some(Duration::from_millis(ms as u64)),
        _ => None,
    }
}

/// Aggregate serving statistics.
#[derive(Default)]
pub struct ServerStats {
    /// requests that reached execution accounting (includes stragglers)
    pub requests: AtomicU64,
    /// executed batches (includes singleton straggler batches)
    pub batches: AtomicU64,
    /// requests shed at admission because the bounded queue was full
    pub shed: AtomicU64,
    /// requests dropped at dequeue because their deadline had passed
    pub expired: AtomicU64,
    /// requests answered [`ServeError::ModelPanic`] because their batch's
    /// forward panicked
    pub panicked: AtomicU64,
    /// mismatched-timescale requests executed as singleton straggler
    /// batches (they dilute [`ServerStats::mean_batch_fill`]; this
    /// counter makes that visible)
    pub stragglers: AtomicU64,
    queue_depth: AtomicU64,
}

impl ServerStats {
    /// Mean requests per executed batch.
    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Gauge: requests admitted but not yet dequeued by the worker.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed) as usize
    }

    fn depth_inc(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    fn depth_dec(&self) {
        // Every dec pairs with an admission-side inc, but a relaxed gauge
        // must never wrap even if a future refactor breaks that pairing.
        let _ = self
            .queue_depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| Some(d.saturating_sub(1)));
    }
}

/// State shared between client handles and the worker: the drain flag
/// that closes admission.
#[derive(Default)]
struct ServeShared {
    shutting_down: AtomicBool,
}

/// Handle for submitting requests; clone freely across client threads.
#[derive(Clone)]
pub struct ServeHandle {
    tx: SyncSender<Msg>,
    shared: Arc<ServeShared>,
    stats: Arc<ServerStats>,
    queue_cap: usize,
    /// Flat request width: L × d_input.
    pub row: usize,
    /// Output row width per sequence (classifier logits, hidden state, …).
    pub d_output: usize,
}

impl ServeHandle {
    /// Blocking single inference (row-major L×d sequence → logits).
    pub fn infer(&self, x: Vec<f32>) -> Result<Response, ServeError> {
        self.submit(x, 1.0, None)
    }

    /// Inference with a Δ-rescale factor (zero-shot resampling path).
    /// `timescale` is `f64` all the way into the model, matching the
    /// forward signatures (no lossy f32 hop).
    pub fn infer_with_timescale(&self, x: Vec<f32>, timescale: f64) -> Result<Response, ServeError> {
        self.submit(x, timescale, None)
    }

    /// Inference with a hard per-request deadline. The worker drops the
    /// request unexecuted if the budget elapses while it is queued, and
    /// the caller stops waiting shortly after the budget on its own
    /// clock — so this call can never hang forever, even against a
    /// wedged worker.
    pub fn infer_deadline(
        &self,
        x: Vec<f32>,
        timescale: f64,
        deadline: Duration,
    ) -> Result<Response, ServeError> {
        self.submit(x, timescale, Some(deadline))
    }

    /// Validate → admit (bounded, shedding) → wait. All input checking
    /// happens here on the caller's thread, before the queue.
    fn submit(
        &self,
        x: Vec<f32>,
        timescale: f64,
        deadline: Option<Duration>,
    ) -> Result<Response, ServeError> {
        self.validate(&x, timescale)?;
        if self.shared.shutting_down.load(Ordering::Acquire) {
            return Err(ServeError::ShuttingDown);
        }
        let (rtx, rrx) = channel();
        let req = Request { x, timescale, submitted: Instant::now(), deadline, resp: rtx };
        match self.tx.try_send(Msg::Infer(req)) {
            Ok(()) => self.stats.depth_inc(),
            Err(TrySendError::Full(_)) => {
                self.stats.shed.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::QueueFull { cap: self.queue_cap });
            }
            Err(TrySendError::Disconnected(_)) => return Err(ServeError::ShuttingDown),
        }
        match deadline {
            Some(d) => match rrx.recv_timeout(d + CLIENT_DEADLINE_GRACE) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => Err(ServeError::DeadlineExceeded { budget: d }),
                Err(RecvTimeoutError::Disconnected) => Err(ServeError::ShuttingDown),
            },
            // a dropped response sender means the worker is gone: drain
            None => rrx.recv().unwrap_or(Err(ServeError::ShuttingDown)),
        }
    }

    fn validate(&self, x: &[f32], timescale: f64) -> Result<(), ServeError> {
        if x.len() != self.row {
            return Err(ServeError::InvalidInput(format!(
                "bad request width {} (expected {})",
                x.len(),
                self.row
            )));
        }
        if let Some(i) = x.iter().position(|v| !v.is_finite()) {
            return Err(ServeError::InvalidInput(format!("non-finite payload value at index {i}")));
        }
        if !(timescale.is_finite() && timescale > 0.0) {
            return Err(ServeError::InvalidInput(format!(
                "timescale {timescale} must be positive and finite"
            )));
        }
        Ok(())
    }
}

/// Dequeue-side triage: answer drain/expired requests without executing
/// them. Returns the request back when it should still run.
fn triage(
    r: Request,
    shared: &ServeShared,
    default_deadline: Option<Duration>,
    stats: &ServerStats,
) -> Option<Request> {
    if shared.shutting_down.load(Ordering::Acquire) {
        let _ = r.resp.send(Err(ServeError::ShuttingDown));
        return None;
    }
    if let Some(b) = r.deadline.or(default_deadline) {
        if r.submitted.elapsed() >= b {
            stats.expired.fetch_add(1, Ordering::Relaxed);
            let _ = r.resp.send(Err(ServeError::DeadlineExceeded { budget: b }));
            return None;
        }
    }
    Some(r)
}

/// Answer every still-queued request with `ShuttingDown`. Called by the
/// worker once it observes a shutdown sentinel (or is about to exit).
fn drain_queue(rx: &Receiver<Msg>, stats: &ServerStats) {
    while let Ok(msg) = rx.try_recv() {
        if let Msg::Infer(r) = msg {
            stats.depth_dec();
            let _ = r.resp.send(Err(ServeError::ShuttingDown));
        }
    }
}

/// Drain the channel into a batch of ≤ `max_batch` same-timescale
/// requests, waiting at most `max_wait` past the first request.
/// Each candidate passes through `triage` first (deadline/drain checks);
/// mismatched-timescale survivors are executed alone via `run_one`.
/// The coalescing key is the exact `f64` timescale, so two nearby-but-
/// different values are never batched (and thus never served) as one.
/// Returns the batch plus whether a shutdown sentinel was observed —
/// requests *behind* the sentinel stay queued for the caller's drain.
fn coalesce(
    rx: &Receiver<Msg>,
    first: Request,
    max_batch: usize,
    max_wait: Duration,
    mut triage: impl FnMut(Request) -> Option<Request>,
    mut run_one: impl FnMut(Vec<Request>),
) -> (Vec<Request>, bool) {
    let mut pending = vec![first];
    let deadline = Instant::now() + max_wait;
    while pending.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(Msg::Shutdown) => return (pending, true),
            Ok(Msg::Infer(r)) => {
                let Some(r) = triage(r) else { continue };
                if r.timescale == pending[0].timescale {
                    pending.push(r);
                } else {
                    // different timescale: run it in its own batch
                    run_one(vec![r]);
                }
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    (pending, false)
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// A running native inference server over the batched pure-Rust engine,
/// generic over `dyn` [`SequenceModel`]. Dropping it drains and stops the
/// worker (see [`NativeInferenceServer::shutdown`]).
pub struct NativeInferenceServer {
    handle: ServeHandle,
    pub stats: Arc<ServerStats>,
    sessions: SessionPool,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl NativeInferenceServer {
    /// Start serving an [`S5Model`] (convenience wrapper around
    /// [`NativeInferenceServer::start_model`]).
    pub fn start(model: S5Model, l: usize, cfg: ServerConfig) -> NativeInferenceServer {
        NativeInferenceServer::start_model(Arc::new(model), l, cfg)
    }

    /// Start serving any [`SequenceModel`] for fixed-length (L × d_input)
    /// sequences — the same dynamic-batching loop serves the S5 stack and
    /// the RNN baselines.
    ///
    /// The worker shares the model `Arc`, owns one [`EngineWorkspace`]
    /// (reused across batches: zero steady-state allocation on the big
    /// buffers) and a scan backend sized to `cfg.threads` (0 =
    /// auto-detect). The backend dispatches on the **process-wide
    /// persistent worker pool** (see [`crate::runtime::pool`]): the
    /// batch worker, every streaming [`Session`] handed out by
    /// [`NativeInferenceServer::open_session`], and any co-resident
    /// server share one pool, so high-rate serving performs zero
    /// steady-state thread spawns after warmup.
    pub fn start_model(
        model: Arc<dyn SequenceModel>,
        l: usize,
        cfg: ServerConfig,
    ) -> NativeInferenceServer {
        let spec = model.spec();
        let row = l * spec.d_input;
        let d_output = spec.d_output;
        let queue_cap = resolved_queue_cap(&cfg);
        let deadline = resolved_default_deadline(&cfg);
        let (tx, rx) = sync_channel::<Msg>(queue_cap);
        let stats = Arc::new(ServerStats::default());
        let shared = Arc::new(ServeShared::default());
        let opts = ForwardOptions::new().with_threads(auto_threads(cfg.threads));
        let sessions = SessionPool::with_ttl(model.clone(), opts.clone(), DEFAULT_SESSION_TTL);
        let ctx = WorkerCtx {
            model,
            cfg,
            opts,
            l,
            row,
            d_output,
            deadline,
            stats: stats.clone(),
            shared: shared.clone(),
        };
        let worker = spawn_worker("s5-native-server", move || {
            native_worker_loop(ctx, rx);
        });
        NativeInferenceServer {
            handle: ServeHandle { tx, shared, stats: stats.clone(), queue_cap, row, d_output },
            stats,
            sessions,
            worker: Some(worker),
        }
    }

    pub fn handle(&self) -> ServeHandle {
        self.handle.clone()
    }

    /// Check out a streaming [`Session`] over the served model (pooled:
    /// closed sessions' states are reused across connections). Streaming
    /// steps run on the caller's thread — they are latency-bound, not
    /// batch-bound — while sharing the worker's model.
    pub fn open_session(&self) -> Session {
        self.sessions.acquire()
    }

    /// Return a session to the pool for the next connection.
    pub fn close_session(&self, session: Session) {
        self.sessions.release(session);
    }

    /// Reclaim pooled session states that have sat idle past the pool's
    /// TTL (sessions opened and never returned are unaffected — the pool
    /// only owns returned states). Returns how many were evicted.
    pub fn evict_idle_sessions(&self) -> usize {
        self.sessions.evict_idle()
    }

    /// Graceful drain: close admission (new submissions get
    /// [`ServeError::ShuttingDown`]), let the in-flight batch finish,
    /// answer every still-queued request with `ShuttingDown`, then join
    /// the worker. Bounded: at most one batch execution plus the queue
    /// drain. Idempotent — a second call is a no-op.
    pub fn shutdown(&mut self) {
        self.handle.shared.shutting_down.store(true, Ordering::Release);
        if let Some(w) = self.worker.take() {
            // Wake the worker if it is parked in recv() on an empty
            // queue. A full queue cannot block this send forever: the
            // draining worker is popping entries; if the worker is
            // already gone the send fails, which is fine.
            let _ = self.handle.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

impl Drop for NativeInferenceServer {
    fn drop(&mut self) {
        // Route through the drain path: queued senders get a typed answer
        // instead of a dropped channel, and the join is bounded.
        self.shutdown();
    }
}

/// Idle-TTL for the server-owned [`SessionPool`]: returned states that no
/// connection reclaims within this window are dropped (their buffers
/// freed) on the next pool operation or explicit
/// [`NativeInferenceServer::evict_idle_sessions`] call.
const DEFAULT_SESSION_TTL: Duration = Duration::from_secs(300);

/// Everything the native worker thread owns, bundled so the loop and its
/// closures share one immutable context.
struct WorkerCtx {
    model: Arc<dyn SequenceModel>,
    cfg: ServerConfig,
    opts: ForwardOptions,
    l: usize,
    row: usize,
    d_output: usize,
    deadline: Option<Duration>,
    stats: Arc<ServerStats>,
    shared: Arc<ServeShared>,
}

fn native_worker_loop(ctx: WorkerCtx, rx: Receiver<Msg>) {
    let d_input = ctx.row / ctx.l;
    let mut ws = EngineWorkspace::new();
    let mut xbuf = Vec::new();
    let mut logits = Vec::new();
    let max_batch = ctx.cfg.max_batch.max(1);
    loop {
        let first = match rx.recv() {
            Ok(Msg::Infer(r)) => {
                ctx.stats.depth_dec();
                match triage(r, &ctx.shared, ctx.deadline, &ctx.stats) {
                    Some(r) => r,
                    None => continue,
                }
            }
            Ok(Msg::Shutdown) => {
                drain_queue(&rx, &ctx.stats);
                return;
            }
            Err(_) => return, // all senders dropped
        };
        let execute = |pending: Vec<Request>,
                       ws: &mut EngineWorkspace,
                       xbuf: &mut Vec<f32>,
                       logits: &mut Vec<f32>,
                       straggler: bool| {
            let n = pending.len();
            ctx.stats.requests.fetch_add(n as u64, Ordering::Relaxed);
            ctx.stats.batches.fetch_add(1, Ordering::Relaxed);
            if straggler {
                ctx.stats.stragglers.fetch_add(n as u64, Ordering::Relaxed);
            }
            let t0 = Instant::now();
            let rows: Vec<&[f32]> = pending.iter().map(|r| r.x.as_slice()).collect();
            pack_rows_into(&rows, n, ctx.row, xbuf);
            logits.resize(n * ctx.d_output, 0.0);
            let batch_opts = ctx.opts.clone().with_timescale(pending[0].timescale);
            // Panic containment: only this batch's forward is inside the
            // unwind boundary; `pending` stays owned out here so the
            // poisoned batch can still answer its own requests.
            let run = catch_unwind(AssertUnwindSafe(|| {
                ctx.model.prefill_into(
                    Batch::new(&xbuf[..n * ctx.row], n, ctx.l, d_input),
                    &batch_opts,
                    ws,
                    &mut logits[..n * ctx.d_output],
                );
            }));
            match run {
                Ok(()) => {
                    for (i, r) in pending.into_iter().enumerate() {
                        let resp = Response {
                            logits: logits[i * ctx.d_output..(i + 1) * ctx.d_output].to_vec(),
                            batched_with: n,
                            queue_secs: (t0 - r.submitted).as_secs_f64(),
                            total_secs: r.submitted.elapsed().as_secs_f64(),
                        };
                        let _ = r.resp.send(Ok(resp));
                    }
                }
                Err(payload) => {
                    let msg = panic_message(payload);
                    ctx.stats.panicked.fetch_add(n as u64, Ordering::Relaxed);
                    for r in pending {
                        let _ = r.resp.send(Err(ServeError::ModelPanic(msg.clone())));
                    }
                    // The unwound forward may have left the workspace
                    // mid-update; discard the scratch rather than trust
                    // it — the next batch rebuilds from clean buffers.
                    *ws = EngineWorkspace::new();
                    logits.clear();
                }
            }
        };
        let (pending, saw_shutdown) = coalesce(
            &rx,
            first,
            max_batch,
            ctx.cfg.max_wait,
            |r| {
                ctx.stats.depth_dec();
                triage(r, &ctx.shared, ctx.deadline, &ctx.stats)
            },
            |one| execute(one, &mut ws, &mut xbuf, &mut logits, true),
        );
        execute(pending, &mut ws, &mut xbuf, &mut logits, false);
        if saw_shutdown {
            drain_queue(&rx, &ctx.stats);
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (feature-gated: needs the xla runtime)
// ---------------------------------------------------------------------------

/// A running PJRT inference server. Dropping it drains and stops the
/// worker.
#[cfg(feature = "pjrt")]
pub struct InferenceServer {
    handle: ServeHandle,
    pub stats: Arc<ServerStats>,
    worker: Option<std::thread::JoinHandle<()>>,
}

#[cfg(feature = "pjrt")]
impl InferenceServer {
    /// Load `<preset>_fwd` + params (npz checkpoint or `<preset>_init.npz`)
    /// and start the worker.
    ///
    /// PJRT handles are not `Send` (the xla crate wraps raw pointers and an
    /// `Rc` refcount), so the worker thread creates its *own* client and
    /// compiles the artifact locally; only plain data crosses the channel.
    pub fn start(
        artifacts_dir: &std::path::Path,
        preset: &str,
        checkpoint: Option<&std::path::Path>,
        cfg: ServerConfig,
    ) -> anyhow::Result<InferenceServer> {
        use crate::runtime::params::ParamStore;
        use crate::runtime::{Artifact, Client};
        use xla::Literal;

        // manifest is plain data: parse on the caller thread for the handle
        let manifest = crate::runtime::Manifest::load(
            &artifacts_dir.join(format!("{preset}_fwd.manifest.txt")),
        )?;
        let x_spec = &manifest.inputs[manifest.input_index("x")?];
        let batch = x_spec.dims[0];
        let row: usize = x_spec.dims[1..].iter().product();
        let classes = manifest.meta_usize("classes")?;
        let x_dims = x_spec.dims.clone();

        let params_path = checkpoint
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| Artifact::init_npz_path(artifacts_dir, preset));
        let dir = artifacts_dir.to_path_buf();
        let fwd_name = format!("{preset}_fwd");

        let queue_cap = resolved_queue_cap(&cfg);
        let deadline = resolved_default_deadline(&cfg);
        let (tx, rx) = sync_channel::<Msg>(queue_cap);
        let stats = Arc::new(ServerStats::default());
        let shared = Arc::new(ServeShared::default());
        let wstats = stats.clone();
        let wshared = shared.clone();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let worker = spawn_worker("s5-pjrt-server", move || {
            let setup = (|| -> anyhow::Result<(Artifact, Vec<Literal>)> {
                let client = Client::cpu()?;
                let art = Artifact::load(&dir, &fwd_name, &client)?;
                let store = ParamStore::load_npz(&params_path)?;
                let idx = art.manifest.input_group("params");
                let specs: Vec<_> = idx.iter().map(|&i| &art.manifest.inputs[i]).collect();
                let params = store.gather(&specs)?;
                Ok((art, params))
            })();
            match setup {
                Ok((art, params)) => {
                    let _ = ready_tx.send(Ok(()));
                    pjrt::worker_loop(
                        art, params, rx, cfg, batch, row, classes, x_dims, wstats, wshared,
                        deadline,
                    );
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        });
        ready_rx
            .recv()
            .context("server worker died during startup")??;

        Ok(InferenceServer {
            handle: ServeHandle {
                tx,
                shared,
                stats: stats.clone(),
                queue_cap,
                row,
                d_output: classes,
            },
            stats,
            worker: Some(worker),
        })
    }

    pub fn handle(&self) -> ServeHandle {
        self.handle.clone()
    }

    /// Graceful drain, mirroring [`NativeInferenceServer::shutdown`].
    pub fn shutdown(&mut self) {
        self.handle.shared.shutting_down.store(true, Ordering::Release);
        if let Some(w) = self.worker.take() {
            let _ = self.handle.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

#[cfg(feature = "pjrt")]
impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use crate::runtime::params::{literal_f32, to_vec_f32};
    use crate::runtime::Artifact;
    use xla::Literal;

    #[allow(clippy::too_many_arguments)]
    pub(super) fn worker_loop(
        art: Artifact,
        params: Vec<Literal>,
        rx: Receiver<Msg>,
        cfg: ServerConfig,
        batch: usize,
        row: usize,
        classes: usize,
        x_dims: Vec<usize>,
        stats: Arc<ServerStats>,
        shared: Arc<ServeShared>,
        deadline: Option<Duration>,
    ) {
        loop {
            let first = match rx.recv() {
                Ok(Msg::Infer(r)) => {
                    stats.depth_dec();
                    match triage(r, &shared, deadline, &stats) {
                        Some(r) => r,
                        None => continue,
                    }
                }
                Ok(Msg::Shutdown) => {
                    drain_queue(&rx, &stats);
                    return;
                }
                Err(_) => return,
            };
            let (pending, saw_shutdown) = coalesce(
                &rx,
                first,
                batch,
                cfg.max_wait,
                |r| {
                    stats.depth_dec();
                    triage(r, &shared, deadline, &stats)
                },
                |one| execute_batch(&art, &params, one, batch, row, classes, &x_dims, &stats, true),
            );
            execute_batch(&art, &params, pending, batch, row, classes, &x_dims, &stats, false);
            if saw_shutdown {
                drain_queue(&rx, &stats);
                return;
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_batch(
        art: &Artifact,
        params: &[Literal],
        pending: Vec<Request>,
        batch: usize,
        row: usize,
        classes: usize,
        x_dims: &[usize],
        stats: &Arc<ServerStats>,
        straggler: bool,
    ) {
        let n_real = pending.len();
        stats.requests.fetch_add(n_real as u64, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        if straggler {
            stats.stragglers.fetch_add(n_real as u64, Ordering::Relaxed);
        }
        let t0 = Instant::now();

        // pad to the artifact's fixed batch dimension
        let mut x = vec![0.0f32; batch * row];
        for (i, r) in pending.iter().enumerate() {
            x[i * row..(i + 1) * row].copy_from_slice(&r.x);
        }
        let result = (|| -> anyhow::Result<Vec<f32>> {
            // the compiled artifact takes an f32 timescale scalar; the f64
            // request value is only narrowed at this final hop
            let ts = literal_f32(&[pending[0].timescale as f32], &[])?;
            let xl = literal_f32(&x, x_dims)?;
            let mut refs: Vec<&Literal> = params.iter().collect();
            refs.push(&ts);
            refs.push(&xl);
            let outs = art.run(&refs)?;
            to_vec_f32(&outs[0])
        })();

        match result {
            Ok(logits) => {
                for (i, r) in pending.into_iter().enumerate() {
                    let resp = Response {
                        logits: logits[i * classes..(i + 1) * classes].to_vec(),
                        batched_with: n_real,
                        queue_secs: (t0 - r.submitted).as_secs_f64(),
                        total_secs: r.submitted.elapsed().as_secs_f64(),
                    };
                    let _ = r.resp.send(Ok(resp));
                }
            }
            Err(e) => {
                // The xla runtime reports execution failure as an error
                // rather than unwinding; it poisons this batch the same
                // way a native panic would, so it maps to the same
                // variant and counter.
                let msg = format!("pjrt run failed: {e:#}");
                stats.panicked.fetch_add(n_real as u64, Ordering::Relaxed);
                for r in pending {
                    let _ = r.resp.send(Err(ServeError::ModelPanic(msg.clone())));
                }
            }
        }
    }
}

/// A started server of either backend — lets the CLI and benches hold one
/// value regardless of execution mode.
pub enum RunningServer {
    Native(NativeInferenceServer),
    #[cfg(feature = "pjrt")]
    Pjrt(InferenceServer),
}

impl RunningServer {
    pub fn handle(&self) -> ServeHandle {
        match self {
            RunningServer::Native(s) => s.handle(),
            #[cfg(feature = "pjrt")]
            RunningServer::Pjrt(s) => s.handle(),
        }
    }

    pub fn stats(&self) -> &ServerStats {
        match self {
            RunningServer::Native(s) => &s.stats,
            #[cfg(feature = "pjrt")]
            RunningServer::Pjrt(s) => &s.stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_req(ts: f64) -> (Request, Receiver<Result<Response, ServeError>>) {
        let (rtx, rrx) = channel();
        let req = Request {
            x: Vec::new(),
            timescale: ts,
            submitted: Instant::now(),
            deadline: None,
            resp: rtx,
        };
        (req, rrx)
    }

    #[test]
    fn server_config_default_sane() {
        let c = ServerConfig::default();
        assert!(c.max_wait >= Duration::from_micros(100));
        assert!(c.max_batch >= 1);
        // threads = 0 means auto-detect, which must resolve to ≥ 1 worker
        assert_eq!(c.threads, 0);
        assert!(auto_threads(c.threads) >= 1);
        // queue_cap = 0 / deadline = None mean auto (env, then built-in)
        assert_eq!(c.queue_cap, 0);
        assert_eq!(c.deadline, None);
        assert!(resolved_queue_cap(&c) >= 1);
        // an explicit value always wins without consulting the env
        let explicit = ServerConfig { queue_cap: 7, ..ServerConfig::default() };
        assert_eq!(resolved_queue_cap(&explicit), 7);
        let with_deadline = ServerConfig {
            deadline: Some(Duration::from_millis(9)),
            ..ServerConfig::default()
        };
        assert_eq!(resolved_default_deadline(&with_deadline), Some(Duration::from_millis(9)));
    }

    #[test]
    fn stats_fill_math() {
        let s = ServerStats::default();
        s.requests.store(10, Ordering::Relaxed);
        s.batches.store(4, Ordering::Relaxed);
        assert!((s.mean_batch_fill() - 2.5).abs() < 1e-12);
        let empty = ServerStats::default();
        assert_eq!(empty.mean_batch_fill(), 0.0);
        // the depth gauge never wraps below zero
        empty.depth_dec();
        assert_eq!(empty.queue_depth(), 0);
        empty.depth_inc();
        assert_eq!(empty.queue_depth(), 1);
        empty.depth_dec();
        assert_eq!(empty.queue_depth(), 0);
    }

    #[test]
    fn serve_error_display_names_the_cause() {
        assert!(format!("{}", ServeError::QueueFull { cap: 4 }).contains("queue full"));
        let e = ServeError::DeadlineExceeded { budget: Duration::from_millis(5) };
        assert!(format!("{e}").contains("deadline"));
        assert!(format!("{}", ServeError::InvalidInput("bad request width 3".into()))
            .contains("width"));
        assert!(format!("{}", ServeError::ModelPanic("boom".into())).contains("boom"));
        assert!(format!("{}", ServeError::ShuttingDown).contains("shutting down"));
    }

    #[test]
    fn coalesce_groups_on_the_exact_f64_key_and_runs_stragglers_alone() {
        let (tx, rx) = sync_channel::<Msg>(16);
        // a key one ulp-ish away must NOT coalesce with 1.0
        let near = 1.0 + 2f64.powi(-30);
        let (first, _k0) = test_req(1.0);
        let (r1, _k1) = test_req(1.0);
        let (r2, _k2) = test_req(near);
        let (r3, _k3) = test_req(1.0);
        for r in [r1, r2, r3] {
            tx.send(Msg::Infer(r)).expect("queue send");
        }
        let mut singles = Vec::new();
        let (batch, saw_shutdown) =
            coalesce(&rx, first, 8, Duration::from_millis(200), Some, |one| {
                singles.push(one[0].timescale);
            });
        assert!(!saw_shutdown);
        assert_eq!(batch.len(), 3, "the three exact-1.0 requests coalesce");
        assert!(batch.iter().all(|r| r.timescale == 1.0));
        assert_eq!(singles, vec![near], "the near-miss ran as its own batch");
    }

    #[test]
    fn coalesce_stops_filling_at_a_shutdown_sentinel() {
        let (tx, rx) = sync_channel::<Msg>(16);
        let (first, _k0) = test_req(1.0);
        let (r1, _k1) = test_req(1.0);
        let (r2, _k2) = test_req(1.0);
        tx.send(Msg::Infer(r1)).expect("queue send");
        tx.send(Msg::Shutdown).expect("queue send");
        tx.send(Msg::Infer(r2)).expect("queue send");
        let (batch, saw_shutdown) =
            coalesce(&rx, first, 8, Duration::from_millis(200), Some, |_| {
                panic!("no stragglers expected")
            });
        assert!(saw_shutdown);
        // the request behind the sentinel stays queued for the drain
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn triage_answers_expired_and_draining_requests_without_executing() {
        let stats = ServerStats::default();
        let shared = ServeShared::default();
        // a zero budget is always already expired
        let (r, rrx) = test_req(1.0);
        assert!(triage(r, &shared, Some(Duration::ZERO), &stats).is_none());
        assert!(matches!(rrx.try_recv(), Ok(Err(ServeError::DeadlineExceeded { .. }))));
        assert_eq!(stats.expired.load(Ordering::Relaxed), 1);
        // no deadline: passes through untouched
        let (r, _keep) = test_req(1.0);
        assert!(triage(r, &shared, None, &stats).is_some());
        // draining: answered ShuttingDown
        shared.shutting_down.store(true, Ordering::Release);
        let (r, rrx) = test_req(1.0);
        assert!(triage(r, &shared, None, &stats).is_none());
        assert!(matches!(rrx.try_recv(), Ok(Err(ServeError::ShuttingDown))));
    }
}
