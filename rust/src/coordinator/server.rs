//! Inference server: request queue → dynamic batcher → PJRT worker.
//!
//! The serving half of the coordinator (vLLM-router-shaped, scaled to this
//! system): callers submit single sequences; a worker thread owns the
//! compiled fwd executable and the parameters, coalesces outstanding
//! requests into padded batches of the artifact's fixed batch size (waiting
//! at most `max_wait` for stragglers), executes once per batch, and fans
//! the logit rows back out. The offline build has no tokio, so the event
//! loop is built on std::sync::mpsc — which also keeps the hot path free
//! of async-runtime overhead.

use anyhow::Context;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};
use xla::Literal;

use crate::runtime::params::{literal_f32, to_vec_f32, ParamStore};
use crate::runtime::{Artifact, Client};

/// One inference request: a single (L × d_input) sequence.
struct Request {
    x: Vec<f32>,
    timescale: f32,
    submitted: Instant,
    resp: Sender<anyhow::Result<Response>>,
}

/// The reply: logits plus telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    /// how many real requests shared the executed batch
    pub batched_with: usize,
    pub queue_secs: f64,
    pub total_secs: f64,
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// max time the batcher waits to fill a batch
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_wait: Duration::from_millis(2) }
    }
}

/// Aggregate serving statistics.
#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
}

impl ServerStats {
    /// Mean requests per executed batch.
    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// Handle for submitting requests; clone freely across client threads.
#[derive(Clone)]
pub struct ServeHandle {
    tx: Sender<Request>,
    pub row: usize,
    pub classes: usize,
}

impl ServeHandle {
    /// Blocking single inference (row-major L×d sequence → logits).
    pub fn infer(&self, x: Vec<f32>) -> anyhow::Result<Response> {
        self.infer_with_timescale(x, 1.0)
    }

    /// Inference with a Δ-rescale factor (zero-shot resampling path).
    pub fn infer_with_timescale(&self, x: Vec<f32>, timescale: f32) -> anyhow::Result<Response> {
        anyhow::ensure!(x.len() == self.row, "bad request width {}", x.len());
        let (rtx, rrx) = channel();
        self.tx
            .send(Request { x, timescale, submitted: Instant::now(), resp: rtx })
            .ok()
            .context("server stopped")?;
        rrx.recv().context("server dropped request")?
    }
}

/// A running inference server. Dropping it stops the worker.
pub struct InferenceServer {
    handle: ServeHandle,
    pub stats: Arc<ServerStats>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl InferenceServer {
    /// Load `<preset>_fwd` + params (npz checkpoint or `<preset>_init.npz`)
    /// and start the worker.
    ///
    /// PJRT handles are not `Send` (the xla crate wraps raw pointers and an
    /// `Rc` refcount), so the worker thread creates its *own* client and
    /// compiles the artifact locally; only plain data crosses the channel.
    pub fn start(
        artifacts_dir: &Path,
        preset: &str,
        checkpoint: Option<&Path>,
        cfg: ServerConfig,
    ) -> anyhow::Result<InferenceServer> {
        // manifest is plain data: parse on the caller thread for the handle
        let manifest = crate::runtime::Manifest::load(
            &artifacts_dir.join(format!("{preset}_fwd.manifest.txt")),
        )?;
        let x_spec = &manifest.inputs[manifest.input_index("x")?];
        let batch = x_spec.dims[0];
        let row: usize = x_spec.dims[1..].iter().product();
        let classes = manifest.meta_usize("classes")?;
        let x_dims = x_spec.dims.clone();

        let params_path = checkpoint
            .map(|p| p.to_path_buf())
            .unwrap_or_else(|| Artifact::init_npz_path(artifacts_dir, preset));
        let dir = artifacts_dir.to_path_buf();
        let fwd_name = format!("{preset}_fwd");

        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(ServerStats::default());
        let wstats = stats.clone();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let worker = std::thread::spawn(move || {
            let setup = (|| -> anyhow::Result<(Artifact, Vec<Literal>)> {
                let client = Client::cpu()?;
                let art = Artifact::load(&dir, &fwd_name, &client)?;
                let store = ParamStore::load_npz(&params_path)?;
                let idx = art.manifest.input_group("params");
                let specs: Vec<_> = idx.iter().map(|&i| &art.manifest.inputs[i]).collect();
                let params = store.gather(&specs)?;
                Ok((art, params))
            })();
            match setup {
                Ok((art, params)) => {
                    let _ = ready_tx.send(Ok(()));
                    worker_loop(art, params, rx, cfg, batch, row, classes, x_dims, wstats);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        });
        ready_rx
            .recv()
            .context("server worker died during startup")??;

        Ok(InferenceServer {
            handle: ServeHandle { tx, row, classes },
            stats,
            worker: Some(worker),
        })
    }

    pub fn handle(&self) -> ServeHandle {
        self.handle.clone()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // closing the channel stops the worker
        let (tx, _) = channel();
        self.handle.tx = tx;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    art: Artifact,
    params: Vec<Literal>,
    rx: Receiver<Request>,
    cfg: ServerConfig,
    batch: usize,
    row: usize,
    classes: usize,
    x_dims: Vec<usize>,
    stats: Arc<ServerStats>,
) {
    loop {
        // block for the first request of the next batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        // coalesce: same-timescale requests batch together
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) if r.timescale == pending[0].timescale => pending.push(r),
                Ok(r) => {
                    // different timescale: run it in the next batch
                    execute_batch(&art, &params, vec![r], batch, row, classes, &x_dims, &stats);
                    continue;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        execute_batch(&art, &params, pending, batch, row, classes, &x_dims, &stats);
    }
}

#[allow(clippy::too_many_arguments)]
fn execute_batch(
    art: &Artifact,
    params: &[Literal],
    pending: Vec<Request>,
    batch: usize,
    row: usize,
    classes: usize,
    x_dims: &[usize],
    stats: &Arc<ServerStats>,
) {
    let n_real = pending.len();
    stats.requests.fetch_add(n_real as u64, Ordering::Relaxed);
    stats.batches.fetch_add(1, Ordering::Relaxed);
    let t0 = Instant::now();

    // pad to the artifact's fixed batch dimension
    let mut x = vec![0.0f32; batch * row];
    for (i, r) in pending.iter().enumerate() {
        x[i * row..(i + 1) * row].copy_from_slice(&r.x);
    }
    let result = (|| -> anyhow::Result<Vec<f32>> {
        let ts = literal_f32(&[pending[0].timescale], &[])?;
        let xl = literal_f32(&x, x_dims)?;
        let mut refs: Vec<&Literal> = params.iter().collect();
        refs.push(&ts);
        refs.push(&xl);
        let outs = art.run(&refs)?;
        to_vec_f32(&outs[0])
    })();

    match result {
        Ok(logits) => {
            for (i, r) in pending.into_iter().enumerate() {
                let resp = Response {
                    logits: logits[i * classes..(i + 1) * classes].to_vec(),
                    batched_with: n_real,
                    queue_secs: (t0 - r.submitted).as_secs_f64(),
                    total_secs: r.submitted.elapsed().as_secs_f64(),
                };
                let _ = r.resp.send(Ok(resp));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for r in pending {
                let _ = r.resp.send(Err(anyhow::anyhow!("{msg}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_config_default_sane() {
        let c = ServerConfig::default();
        assert!(c.max_wait >= Duration::from_micros(100));
    }

    #[test]
    fn stats_fill_math() {
        let s = ServerStats::default();
        s.requests.store(10, Ordering::Relaxed);
        s.batches.store(4, Ordering::Relaxed);
        assert!((s.mean_batch_fill() - 2.5).abs() < 1e-12);
        let empty = ServerStats::default();
        assert_eq!(empty.mean_batch_fill(), 0.0);
    }
}
