//! The Layer-3 coordinator: configuration, training orchestration, and the
//! inference server. Everything after `make artifacts` runs through here —
//! Python is never on this path.

pub mod config;
pub mod metrics;
pub mod schedule;
pub mod server;
pub mod sweep;
pub mod tasks;
pub mod trainer;

pub use config::TrainConfig;
pub use trainer::Trainer;
