//! The Layer-3 coordinator: configuration, training orchestration, and the
//! inference server. Everything after `make artifacts` runs through here —
//! Python is never on this path.

//!
//! Training executes compiled artifacts and therefore needs the `pjrt`
//! feature; serving has both a PJRT mode (`pjrt`) and an always-available
//! native mode backed by the batched engine in [`crate::ssm::engine`].

pub mod config;
pub mod metrics;
pub mod schedule;
pub mod server;
pub mod sweep;
pub mod tasks;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use config::TrainConfig;
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;
