//! Learning-rate schedules (paper §G.2.1: AdamW + cosine annealing, with
//! the warmup used by the S4 training recipes). The schedule lives in Rust
//! — the AOT train step takes `lr` as a runtime scalar — so artifacts are
//! schedule-agnostic.

/// Cosine decay with linear warmup.
#[derive(Clone, Copy, Debug)]
pub struct CosineSchedule {
    pub base_lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub min_lr: f64,
}

impl CosineSchedule {
    pub fn new(base_lr: f64, warmup_steps: usize, total_steps: usize) -> Self {
        CosineSchedule { base_lr, warmup_steps, total_steps, min_lr: 1e-7 }
    }

    /// LR at 1-based step `step`.
    pub fn lr(&self, step: usize) -> f64 {
        if self.warmup_steps > 0 && step <= self.warmup_steps {
            return self.base_lr * step as f64 / self.warmup_steps as f64;
        }
        let done = (step - self.warmup_steps) as f64;
        let span = (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let frac = (done / span).clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * frac).cos());
        self.min_lr + (self.base_lr - self.min_lr) * cos
    }
}

/// Constant schedule (ablation/debug).
#[derive(Clone, Copy, Debug)]
pub struct ConstantSchedule(pub f64);

impl ConstantSchedule {
    pub fn lr(&self, _step: usize) -> f64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = CosineSchedule::new(1.0, 10, 100);
        assert!((s.lr(1) - 0.1).abs() < 1e-12);
        assert!((s.lr(5) - 0.5).abs() < 1e-12);
        assert!((s.lr(10) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = CosineSchedule::new(1.0, 0, 100);
        assert!(s.lr(1) > 0.99);
        assert!(s.lr(50) < 0.6);
        assert!(s.lr(100) < 1e-3);
        assert!(s.lr(100) >= s.min_lr);
    }

    #[test]
    fn monotone_after_warmup() {
        let s = CosineSchedule::new(3e-3, 20, 200);
        let mut prev = f64::INFINITY;
        for step in 21..=200 {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-12, "step {step}");
            prev = lr;
        }
    }

    #[test]
    fn never_negative_or_nan() {
        let s = CosineSchedule::new(1e-2, 5, 50);
        for step in 1..=80 {
            let lr = s.lr(step);
            assert!(lr.is_finite() && lr >= 0.0);
        }
    }
}
