//! Run configuration: defaults per preset, overridable from key=value
//! config files (a TOML-subset parser — the offline build has no `serde`/
//! `toml`) and from CLI flags.

use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

use crate::util::Args;

/// Everything the trainer needs for one run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// AOT preset name (picks the artifact pair + init npz).
    pub preset: String,
    pub steps: usize,
    pub base_lr: f64,
    pub warmup_steps: usize,
    pub weight_decay: f64,
    /// training pool size (synthetic examples materialized per run)
    pub train_pool: usize,
    /// held-out pool size
    pub eval_pool: usize,
    pub eval_every: usize,
    pub seed: u64,
    /// directory holding the AOT artifacts
    pub artifacts_dir: String,
    /// optional checkpoint output path (npz)
    pub checkpoint: Option<String>,
    /// optional metrics CSV output path
    pub metrics_csv: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "smnist".to_string(),
            steps: 200,
            base_lr: 4e-3,
            warmup_steps: 20,
            weight_decay: 0.01,
            train_pool: 512,
            eval_pool: 128,
            eval_every: 50,
            seed: 0,
            artifacts_dir: crate::ARTIFACTS_DIR.to_string(),
            checkpoint: None,
            metrics_csv: None,
        }
    }
}

impl TrainConfig {
    /// Paper-informed defaults per preset (Table 11 scaled to CPU budget).
    pub fn for_preset(preset: &str) -> TrainConfig {
        let mut c = TrainConfig { preset: preset.to_string(), ..Default::default() };
        match preset {
            "listops" | "abl6_continuous_hippo" | "abl6_continuous_gaussian"
            | "abl6_continuous_antisymmetric" | "abl6_discrete_hippo"
            | "abl6_discrete_gaussian" | "abl6_discrete_antisymmetric" => {
                c.base_lr = 3e-3;
                c.weight_decay = 0.04;
            }
            "text" => {
                c.base_lr = 4e-3;
                c.weight_decay = 0.05;
            }
            "pathfinder" | "pathx" => {
                c.base_lr = 4e-3;
                c.weight_decay = 0.03;
            }
            "speech" => {
                c.base_lr = 6e-3;
                c.weight_decay = 0.04;
            }
            "pendulum" => {
                c.base_lr = 8e-3;
                c.weight_decay = 0.0;
                c.train_pool = 256;
                c.eval_pool = 64;
            }
            _ => {}
        }
        c
    }

    /// Apply `--key value` CLI overrides.
    pub fn apply_args(&mut self, args: &Args) {
        if let Some(p) = args.get("preset") {
            *self = TrainConfig::for_preset(p);
        }
        self.steps = args.get_usize("steps", self.steps);
        self.base_lr = args.get_f64("lr", self.base_lr);
        self.warmup_steps = args.get_usize("warmup", self.warmup_steps);
        self.weight_decay = args.get_f64("wd", self.weight_decay);
        self.train_pool = args.get_usize("train-pool", self.train_pool);
        self.eval_pool = args.get_usize("eval-pool", self.eval_pool);
        self.eval_every = args.get_usize("eval-every", self.eval_every);
        self.seed = args.get_usize("seed", self.seed as usize) as u64;
        if let Some(d) = args.get("artifacts") {
            self.artifacts_dir = d.to_string();
        }
        self.checkpoint = args.get("checkpoint").map(|s| s.to_string()).or(self.checkpoint.take());
        self.metrics_csv = args.get("metrics").map(|s| s.to_string()).or(self.metrics_csv.take());
    }

    /// Load overrides from a `key = value` config file (TOML subset:
    /// comments with '#', no sections-nesting, bare scalars and strings).
    pub fn apply_file(&mut self, path: &Path) -> anyhow::Result<()> {
        let kv = parse_kv_file(path)?;
        for (k, v) in kv {
            match k.as_str() {
                "preset" => self.preset = v,
                "steps" => self.steps = v.parse().context("steps")?,
                "lr" => self.base_lr = v.parse().context("lr")?,
                "warmup" => self.warmup_steps = v.parse().context("warmup")?,
                "wd" => self.weight_decay = v.parse().context("wd")?,
                "train_pool" => self.train_pool = v.parse().context("train_pool")?,
                "eval_pool" => self.eval_pool = v.parse().context("eval_pool")?,
                "eval_every" => self.eval_every = v.parse().context("eval_every")?,
                "seed" => self.seed = v.parse().context("seed")?,
                "artifacts_dir" => self.artifacts_dir = v,
                "checkpoint" => self.checkpoint = Some(v),
                "metrics_csv" => self.metrics_csv = Some(v),
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }
}

/// Parse a flat `key = value` file: '#' comments, optional quotes.
pub fn parse_kv_file(path: &Path) -> anyhow::Result<BTreeMap<String, String>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading config {path:?}"))?;
    parse_kv(&text)
}

pub fn parse_kv(text: &str) -> anyhow::Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("config line {}: expected key = value, got {raw:?}", ln + 1);
        };
        let v = v.trim().trim_matches('"').trim_matches('\'');
        out.insert(k.trim().to_string(), v.to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_defaults_differ() {
        let a = TrainConfig::for_preset("smnist");
        let b = TrainConfig::for_preset("pendulum");
        assert_ne!(a.base_lr, b.base_lr);
        assert_eq!(b.weight_decay, 0.0);
    }

    #[test]
    fn args_override() {
        let mut c = TrainConfig::default();
        let args = Args::parse(
            ["--steps", "42", "--lr", "0.001", "--seed", "9"].iter().map(|s| s.to_string()),
        );
        c.apply_args(&args);
        assert_eq!(c.steps, 42);
        assert_eq!(c.base_lr, 0.001);
        assert_eq!(c.seed, 9);
    }

    #[test]
    fn kv_parser() {
        let kv = parse_kv("steps = 10 # comment\nlr = \"0.01\"\n\n# full comment\n").unwrap();
        assert_eq!(kv["steps"], "10");
        assert_eq!(kv["lr"], "0.01");
        assert_eq!(kv.len(), 2);
    }

    #[test]
    fn kv_parser_rejects_garbage() {
        assert!(parse_kv("not a kv line").is_err());
    }

    #[test]
    fn file_overrides() {
        let dir = std::env::temp_dir().join(format!("s5_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.conf");
        std::fs::write(&p, "steps = 7\nwd = 0.5\ncheckpoint = out.npz\n").unwrap();
        let mut c = TrainConfig::default();
        c.apply_file(&p).unwrap();
        assert_eq!(c.steps, 7);
        assert_eq!(c.weight_decay, 0.5);
        assert_eq!(c.checkpoint.as_deref(), Some("out.npz"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_rejects_unknown_key() {
        let dir = std::env::temp_dir().join(format!("s5_cfg2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.conf");
        std::fs::write(&p, "bogus = 1\n").unwrap();
        let mut c = TrainConfig::default();
        assert!(c.apply_file(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
