//! # S5: Simplified State Space Layers for Sequence Modeling
//!
//! A production-grade reproduction of Smith, Warrington & Linderman
//! (ICLR 2023). The crate is the **Layer-3 coordinator** of a three-layer
//! stack (see `DESIGN.md`):
//!
//! * **L1** — a Pallas kernel implementing the diagonal-SSM parallel scan
//!   (built at compile time, `python/compile/kernels/scan.py`);
//! * **L2** — the JAX model (S5 layers, classifiers, regressors, fused
//!   AdamW train steps) lowered once to HLO text (`python/compile/aot.py`);
//! * **L3** — this crate: loads the AOT artifacts through the PJRT C API
//!   (via the `xla` crate), and owns the data pipeline, training loop,
//!   inference server, benchmarks and the paper's experiment harness.
//!
//! Python never runs on the request path: after `make artifacts` the `s5`
//! binary is self-contained.
//!
//! The crate also carries a **pure-Rust S5/S4/S4D reference stack**
//! ([`ssm`]) used four ways: as the parity oracle against the compiled HLO,
//! as the subject of the runtime benchmarks (paper Table 4), as the
//! substrate for the parallel-scan scaling studies (paper §2.2, Appendix H)
//! — and, via the **batched native inference engine** ([`ssm::engine`] +
//! [`ssm::scan::ScanBackend`]), as the execution backend of the native
//! serving mode: packed (B, L, H) forwards with workspace reuse and
//! pluggable sequential/parallel scan strategies.
//!
//! ## The unified inference API ([`ssm::api`])
//!
//! Every sequence model in the crate — the S5 stack and the GRU/CRU
//! baselines — implements one typed, object-safe trait,
//! [`ssm::api::SequenceModel`]:
//!
//! * **`prefill`** consumes a typed [`ssm::api::Batch`] view of a packed
//!   (B, L, d) buffer under [`ssm::api::ForwardOptions`] (timescale as
//!   `f64` everywhere, explicit scan strategy) and emits one output row
//!   per sequence;
//! * **`make_state` / `step`** run incremental decoding; the
//!   [`ssm::api::Session`] wrapper (pooled per connection by the server
//!   via [`ssm::api::SessionPool`]) gives prefill-then-step streaming
//!   that reproduces the batched forward **bit-for-bit** on the
//!   sequential scan path;
//! * the native server
//!   ([`coordinator::server::NativeInferenceServer`]) is generic over
//!   `dyn SequenceModel`, so one dynamic-batching loop serves every
//!   model, and [`runtime::npz::NpzStore`] +
//!   [`ssm::s5::S5Model::from_param_store`] load `<preset>_init.npz` /
//!   trained checkpoints natively (`serve --engine native --checkpoint`).
//!
//! The pre-redesign entry points remain as thin deprecated wrappers:
//!
//! | old (deprecated) | new |
//! |---|---|
//! | `S5Model::forward(u, l, ts, threads)` | `model.prefill(Batch::single(u, l, d_in), &opts, &mut ws)` |
//! | `S5Layer::apply(u, l, ts, dts, threads)` | `layer.apply_batch(u, 1, l, ts, dts, opts.scan_backend(), &mut ws)` |
//! | `S5Layer::apply_ssm(u, l, ts, dts, threads)` | `layer.apply_ssm_batch(u, 1, l, ts, dts, opts.scan_backend(), &mut ws)` |
//! | `GruCell::run_batch(xs, b, l, threads)` | `cell.prefill(Batch::new(xs, b, l, d_in), &opts, &mut ws)` |
//! | `CruLike::run_batch(xs, dts, b, l, threads)` | `cru.prefill(...)` (regular Δt) / `Session::step_dt` (irregular) |
//! | `OnlineModel::new(&model, ts)` + `push`/`logits` | `Session::new(model, opts)` + `step`/`prefill` |
//! | `ServeHandle::infer_with_timescale(x, f32)` | same name, `timescale: f64` |
//!
//! where `opts = ForwardOptions::new().with_threads(n).with_timescale(ts)`
//! replaces every positional `(timescale, threads)` tail.
//!
//! ## Scan strategy selection
//!
//! The inner scan — the hot loop of every native request — runs in one of
//! two memory layouts (see [`ssm::scan::ScanLayout`]):
//!
//! * **Planar (the default).** The complex drive/state lives as separate
//!   re/im `f32` planes (struct-of-arrays, matching the L1 Pallas
//!   kernel). With the real↔imag data dependence split across planes,
//!   LLVM autovectorizes the P-lane recurrence into SIMD mul/fma — this
//!   is the layout every resolver hands out
//!   ([`ssm::scan::backend_for_threads`],
//!   [`ssm::api::ForwardOptions::with_threads`], the server's `--threads`
//!   knob).
//! * **Interleaved (the reference oracle).** The original `[C32]` path,
//!   selected via [`ssm::scan::backend_for`] /
//!   [`ssm::api::ForwardOptions::with_scan`] with
//!   [`ssm::scan::ScanLayout::Interleaved`]. Kept for A/B validation:
//!   both layouts execute identical floating-point operations in
//!   identical order, so planar ≡ interleaved **bit-for-bit** (property
//!   tests pin this for sequential/parallel × TI/TV, batched forwards and
//!   streaming steps).
//!
//! Orthogonally, the *strategy* is sequential (≤ 1 thread; deterministic
//! reference, streaming ≡ batched exactly) or chunked-parallel (Blelloch
//! three-phase within a sequence, sequence-sharding across a batch, with
//! pooled chunk summaries in [`ssm::scan::ScanScratch`] so steady-state
//! serving allocates nothing on the scan buffers).
//!
//! ## Memory model & tiling
//!
//! At serving shapes (L = 16k, P = 256) the native forward is bound by
//! memory traffic, not FLOPs: materializing full (B, L, P2) drive planes
//! and re-streaming them through scale, scan and projection round-trips
//! DRAM once per stage. The default forward is therefore the **fused
//! cache-blocked** pipeline ([`ssm::engine::Tiling::Auto`]): every
//! (sequence × direction) processes its L in tiles, fusing drive → Δt
//! scale → tile-resumable scan ([`ssm::scan::ScanBackend::scan_ti_planar_resume`])
//! → projection (+ feedthrough) per tile, carrying the scan state across
//! tile boundaries. Consequences:
//!
//! * **Workspace**: the scan-facing buffers
//!   ([`ssm::engine::EngineWorkspace::ssm_capacity_bytes`]) hold
//!   O(B·T·P2) — independent of L, growing only with the tile length
//!   (capacity tests pin this; `bench_scan_scaling` reports the measured
//!   bytes/token).
//! * **Tile auto-sizing**: T is chosen so one pipeline's tile working
//!   set (drive planes + TV multiplier planes + touched input/output
//!   rows) fits a **measured** cache budget
//!   ([`ssm::engine::tile_target_bytes`]), clamped to [64, 8192] rows.
//!   The budget is calibrated once per process, before the worker pool
//!   spawns: a pointer-chase probe walks a shuffled cycle over working
//!   sets from 64 KiB to 8 MiB and takes half the largest size that
//!   still runs near cache latency (falling back to the historical
//!   256 KiB guess if the timings are degenerate). Override the
//!   measurement with `S5_CACHE_KB` (effective cache size in KiB), or
//!   pin the tile directly per forward with
//!   [`ssm::api::ForwardOptions::with_tile`] / `with_tiling`, or
//!   process-wide with `S5_TILE_L` (0 = staged; CI sweeps {1, 64, 4096}).
//! * **Equivalence**: in-tile scans are sequential by default (tiles of
//!   one sequence are data-dependent; parallelism shards the B ×
//!   direction pipelines across the worker pool), so the fused result
//!   equals the staged pipeline over the sequential strategy
//!   **bit-for-bit** — for any tile size, thread budget and executor.
//!   The untiled staged pipeline ([`ssm::engine::Tiling::Staged`]) is
//!   retained as the reference oracle (and is what the interleaved
//!   layout always runs); use it when you need the chunked-parallel
//!   in-sequence scan of a single long sequence.
//! * **Single-stream width**: [`ssm::api::ForwardOptions::with_wide`]
//!   ([`ssm::engine::ScanPolicy::wide`]) lets the fused pipeline go wide
//!   *inside* the tile when there are fewer (sequence × direction)
//!   pipelines than workers: drive/Δt-scale and projection row-split
//!   (bit-exact), the tile scan runs seeded chunked-parallel resume
//!   kernels ([`ssm::scan::ScanBackend::scan_ti_planar_resume_par`]),
//!   and the tile widens to one cache budget per chunk worker. The
//!   carry reassociation makes wide results tolerance-equal (≤ 1e-4
//!   relative) to the sequential reference — deterministic for a fixed
//!   thread budget and executor-invariant, but not bit-for-bit, which
//!   is why it is opt-in and the default stays exactly reproducible.
//! * **Chunked prefill**: `Session::prefill` swallows its prefix through
//!   the same tile pipeline resuming from the live stream state
//!   ([`ssm::api::SequenceModel::advance_batch`]), bit-for-bit equal to
//!   per-token stepping at batch-kernel throughput.
//! * **f64 state**: [`ssm::api::ForwardOptions::with_f64_state`] carries
//!   the scan state in f64 (long-L drift studies) through the fused
//!   pipeline; results are tile-invariant since the carry never
//!   round-trips through f32.
//!
//! ## Precision model
//!
//! The engine splits **storage precision** from **compute precision**
//! ([`ssm::dtype`]). Compute — the scan recurrence, chunk summaries of
//! the parallel scan, tile carries and the f64 projection accumulate —
//! always runs in f32 (or f64 with `with_f64_state`); what the storage
//! dtype selects is the element type of the *drive planes*, the (T, P2)
//! buffers that dominate the fused forward's memory traffic:
//!
//! * **f32 (the default).** [`ssm::dtype::Dtype::F32`] is bit-for-bit
//!   the pre-dtype pipeline: the generic kernels instantiate to the
//!   identical floating-point operations (pinned by the equivalence
//!   matrix in `tests/scan_matrix.rs`).
//! * **bf16 storage.** [`ssm::dtype::Dtype::Bf16`] — selected per
//!   forward with [`ssm::api::ForwardOptions::with_dtype`] or
//!   process-wide with `S5_DTYPE` — narrow-stores the drive planes as
//!   software bfloat16 ([`ssm::dtype::Bf16`]: round-to-nearest-even
//!   f32→bf16, exact widen back; no hardware or crate dependency),
//!   halving drive-plane bytes/token. Every bf16 value is produced by
//!   one narrow-store and consumed by one widen-load; arithmetic never
//!   runs in bf16. Accuracy is pinned by a long-L drift harness
//!   (≤ 0.05 relative vs. the f64-state oracle at L = 64k), and results
//!   stay tile- and executor-invariant per dtype.
//! * **Streaming composes.** A bf16 session round-trips its per-step
//!   drive and projection read through bf16 at exactly the points the
//!   fused tile narrow-stores, so chunked prefill ≡ step replay remains
//!   **bit-for-bit** within the dtype (`tests/sequence_api.rs`).
//! * **Precedence.** An explicit `with_dtype` beats `S5_DTYPE`;
//!   `with_f64_state` forces f32 storage (its tile-invariance contract
//!   is the precision story); the interleaved oracle layout is f32-only.
//!   On-disk checkpoints are unaffected: npz import widens `<f2`/`<f8`
//!   members to f32 ([`runtime::npz`]), and bf16 exists only in the
//!   runtime workspace, never in checkpoints.
//!
//! ## Threading model
//!
//! Parallel work — the chunked scans and the dense per-sequence engine
//! stages — dispatches on an [`runtime::pool::Executor`] instead of
//! spawning threads:
//!
//! * **Pool ownership.** By default every multi-threaded backend
//!   ([`ssm::scan::backend_for_threads`],
//!   [`ssm::api::ForwardOptions::with_threads`], the native server's
//!   `--threads` knob) dispatches onto the **process-wide persistent
//!   worker pool** ([`runtime::pool::global_pool`]): spawned lazily
//!   once, sized to `available_parallelism − 1` workers (the calling
//!   thread participates in every run; override with
//!   `S5_POOL_WORKERS`), parked when idle, joined on drop. The batch
//!   worker of [`coordinator::server::NativeInferenceServer`], its
//!   pooled streaming [`ssm::api::Session`]s and any co-resident server
//!   share this one pool, so high-rate serving performs **zero
//!   steady-state thread spawns** (dispatch itself costs O(shards)
//!   small boxed closures per parallel stage; the big data buffers stay
//!   allocation-free in the workspace). The pool initializer also runs
//!   the one-shot cache calibration (see *Memory model & tiling*) so
//!   the timing probe never races worker startup. A dedicated
//!   [`runtime::pool::WorkerPool`] can be pinned per backend via
//!   [`ssm::scan::ScanExec::Pool`].
//! * **Work splitting.** Parallelism prefers the coarsest independent
//!   axis: batched forwards shard (sequence × direction) pipelines;
//!   only when those can't fill the budget does work split *within* a
//!   sequence — the staged pipeline's chunked scan, or (opt-in) the
//!   fused pipeline's in-tile wide path, which gives each leftover
//!   worker a row-chunk of every tile. Env overrides (`S5_POOL_WORKERS`,
//!   `S5_TILE_L`, `S5_CACHE_KB`) parse strictly via
//!   [`runtime::envcfg`]: a malformed value warns once on stderr and
//!   falls back to the default instead of silently misconfiguring a
//!   sweep.
//! * **Opting out.** [`ssm::api::ForwardOptions::with_exec`] (or
//!   [`ssm::scan::backend_for_exec`]) selects
//!   [`ssm::scan::ScanExec::Scoped`] — the pre-pool spawn-per-call
//!   scoped threads — or [`ssm::scan::ScanExec::Inline`], which runs
//!   the same chunked decomposition single-threaded on the caller.
//! * **Invariance.** The executor never changes the shard
//!   decomposition (that is fixed by the backend's thread budget), so
//!   pooled ≡ scoped ≡ inline **bit-for-bit** — pinned across every
//!   kernel × layout × shape combination by the `tests/scan_matrix.rs`
//!   equivalence matrix, which is what lets future scheduling changes
//!   land without numeric drift.
//! * **Streaming.** A session step is latency-bound O(P·H) and always
//!   runs inline on the caller's thread; only prefills fan out.
//!
//! ## Oracles & parity
//!
//! "Correct" is defined by three oracle tiers, ordered by strictness:
//!
//! 1. **The in-process bit-for-bit oracle.** The interleaved-`[C32]`
//!    layout over the untiled staged pipeline
//!    ([`ssm::scan::ScanLayout::Interleaved`] +
//!    [`ssm::engine::Tiling::Staged`]) is the reference every optimized
//!    path must reproduce **exactly**: fused tiling (any tile length),
//!    planar layout, explicit-lane SIMD kernels, every executor and
//!    thread budget, and each storage dtype against itself. The
//!    equivalence matrix in `tests/scan_matrix.rs` pins this, and CI
//!    re-runs it across tile/dtype/pool sweeps.
//! 2. **The f64-state drift oracle.** For contracts that are
//!    tolerance-bound rather than bitwise — the opt-in wide path's carry
//!    reassociation, bf16 storage drift at long L —
//!    [`ssm::api::ForwardOptions::with_f64_state`] provides the
//!    higher-precision reference the bounds are measured against.
//! 3. **The cross-language golden fixtures.** `tests/fixtures/*.npz` are
//!    small committed input/expected pairs generated by the Python
//!    reference implementation (`python/tests/gen_fixtures.py`, pure
//!    NumPy, offline and deterministic) for every module boundary:
//!    HiPPO-LegS init ([`ssm::hippo::block_diag_hippo_init`]), ZOH
//!    discretization ([`ssm::discretize::discretize_one`]), the TI/TV
//!    scans, `s5_ssm_apply` (incl. bidirectional), the full layer, and
//!    classifier logits ([`ssm::s5::S5Model::from_param_store`]).
//!    `tests/parity_fixtures.rs` loads them through
//!    [`runtime::npz::NpzStore`], first verifying the committed bytes
//!    against `tests/fixtures/MANIFEST.txt` (size + CRC-32 via
//!    [`runtime::npz::crc32`] + per-tensor shapes), then pins the engine
//!    across a 12-config sweep (fused/staged × planar/interleaved ×
//!    executors × f64-state/wide/bf16). Tolerances are per tier:
//!    tight f32 bounds for init/discretize/scan primitives, 5e-4 for
//!    module-level outputs (one f32 run vs. another), 5e-2 for bf16
//!    storage. Unlike the PJRT-based `tests/parity.rs` (which needs
//!    `make artifacts` and is `#[ignore]`d without it), the fixture
//!    suite runs everywhere and **cannot silently skip** — missing or
//!    mismatched fixtures are a panic, not an ignore.
//!
//! One convention the fixtures pin deliberately: in a **bidirectional**
//! layer under **time-varying** Δt, the backward scan reverses the Δt
//! multipliers *together with* the drive — step `k` of the backward scan
//! uses Λ̄ and B̃u discretized at source row `l−1−k`. Both the Python
//! reference and all three Rust paths implement this; the `bi_tv` fixture
//! case is the regression pin.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | logging, timing, stats, CLI parsing, table formatting |
//! | [`rng`] | deterministic SplitMix64/PCG RNG + samplers (offline: no `rand`) |
//! | [`num`] | complex arithmetic |
//! | [`linalg`] | dense complex matrices, Hermitian Jacobi eigensolver |
//! | [`fft`] | radix-2 FFT (substrate for the S4 convolution baseline) |
//! | [`ssm`] | HiPPO init, discretization, scans, batched engine, unified API, S5/S4/S4D |
//! | [`data`] | the nine synthetic workload generators + batching |
//! | [`runtime`] | manifests + native npz store; persistent worker pool; PJRT artifact loading (`pjrt` feature) |
//! | [`coordinator`] | configs, trainer (`pjrt`), LR schedules, metrics, server |
//! | [`testing`] | mini property-testing harness (offline: no `proptest`) + counting-allocator guard + deterministic fault injection ([`testing::fault`]) |
//! | [`bench`] | shared harness for the paper-table benchmark binaries |
//!
//! ## Features
//!
//! `pjrt` (off by default) enables the compiled-HLO execution path: the
//! `xla` FFI runtime, the npz parameter store, the trainer, and the PJRT
//! serving backend. The default build is fully hermetic (no crates.io,
//! no prebuilt xla_extension) and still provides the entire native stack
//! including the batched inference server.
//!
//! `simd` (**on** by default) routes the four hottest planar loops —
//! Δt-scale, scan recurrence, chunk combine, projection accumulate —
//! through the explicit-lane kernels in [`ssm::simd`]. The lane kernels
//! perform the identical floating-point operations in the identical
//! per-element order as the scalar loops, so enabling the feature
//! changes **no bit of any result** (pinned by the `ssm::simd` unit
//! tests and the full equivalence matrix, which CI runs both with and
//! without the feature); `--no-default-features` pins the plain scalar
//! oracle build.
//!
//! ## Failure model
//!
//! Serving is fault-contained: every way a request can fail is a typed
//! [`coordinator::server::ServeError`], and a failure never out-lives
//! the request (or batch) it belongs to.
//!
//! * **Panic ≠ crash.** A model panic during a served batch is caught
//!   (`catch_unwind`, riding the worker pool's per-task isolation);
//!   exactly that batch's requests are answered
//!   [`coordinator::server::ServeError::ModelPanic`], the worker thread
//!   survives in place, and later batches are **bit-for-bit** unaffected
//!   (the possibly half-written workspace is discarded). Pooled
//!   streaming sessions have the same property at the
//!   [`ssm::api::SessionPool`] layer: states are reset before re-pooling
//!   and the free-list mutex recovers from poisoning, so a panicking
//!   stream can never leak state into the next connection.
//! * **Error ≠ panic.** Malformed input is rejected at admission
//!   ([`coordinator::server::ServeError::InvalidInput`]) on the caller's
//!   thread; on the worker, recoverable conditions return errors. Lint
//!   L6 (below) statically bans `.unwrap()` / `.expect(` on the serving
//!   path so a recoverable condition cannot be promoted to a panic by
//!   accident.
//! * **Shed, don't queue without bound.** The admission queue is
//!   capacity-bounded (`queue_cap` / `S5_QUEUE_CAP`); a full queue sheds
//!   immediately with [`coordinator::server::ServeError::QueueFull`].
//!   Requests carry deadlines (client-supplied, or the server default /
//!   `S5_REQ_DEADLINE_MS`) enforced at dequeue — drop-before-execute —
//!   and on the caller's own clock, so callers never hang on a wedged
//!   worker. [`coordinator::server::ServerStats`] counts every shed,
//!   expired and panicked request and gauges the live queue depth.
//! * **Drain, don't abandon.** Shutdown (explicit or on drop) closes
//!   admission, finishes the in-flight batch, and answers every queued
//!   request with [`coordinator::server::ServeError::ShuttingDown`].
//!
//! All of it is pinned deterministically by the fault-injection harness
//! in [`testing::fault`] ([`testing::fault::FaultPlan`] schedules exact
//! panic batch/step indices and injected latency;
//! [`testing::fault::FaultyModel`] wraps any model) driven by
//! `tests/server_robustness.rs` on both the simd and scalar builds.
//!
//! ## Checked invariants
//!
//! Six repo-wide source invariants are machine-enforced by the `xtask`
//! workspace crate — run `cargo run -p xtask -- check` from `rust/`
//! (CI runs it on every push, next to `cargo clippy --all-targets -- -D
//! warnings`). They are properties of the *source*, so ordinary tests
//! cannot pin them:
//!
//! * **L1 `pool-threading`** — the thread-spawn primitives
//!   (`thread::spawn` / `thread::scope` / `thread::Builder`) appear only
//!   inside `runtime/pool.rs`. Everything else goes through
//!   [`runtime::pool::spawn_worker`] or the pool's `Executor`, keeping
//!   the persistent worker pool the single source of parallelism.
//! * **L2 `env-registry`** — `std::env::var*` reads live only in
//!   `runtime/envcfg.rs` (use its strict warn-once accessors), and every
//!   `S5_*` knob string in the sources, benches, tests and examples is
//!   listed in [`runtime::envcfg::ENV_REGISTRY`] — and vice versa, no
//!   stale registry rows.
//! * **L3 `hot-alloc`** — no allocating calls (`Vec::new`, `vec!`,
//!   `.push(`, `.collect`, `.clone(`, `format!`, …) between
//!   `// s5:hot-begin` and `// s5:hot-end` fence comments. The fences
//!   wrap the per-tile kernels in `ssm::scan`, `ssm::simd`,
//!   `ssm::engine` and `ssm::s5`; the *runtime* twin of this static rule
//!   is the counting-allocator harness [`testing::alloc_guard`], which
//!   `tests/alloc_guard.rs` uses to assert the steady-state fused
//!   forward and `Session::step_into` perform zero heap allocations.
//! * **L4 `unsafe-safety`** — every `unsafe` token is directly preceded
//!   by a `// SAFETY:` comment, and the full inventory is mirrored in
//!   the committed `UNSAFE.md` (regenerate with `cargo run -p xtask --
//!   write-unsafe`).
//! * **L5 `simd-symmetry`** — the scalar build stays a complete oracle:
//!   per file, `#[cfg(feature = "simd")]` and `#[cfg(not(feature =
//!   "simd"))]` counts match, and every `cfg!(feature = "simd")` is an
//!   `if` dispatch whose block is followed by scalar fallthrough code
//!   (or an `else` branch).
//! * **L6 `serve-unwrap`** — no `.unwrap()` / `.expect(` on the serving
//!   path (`coordinator/` and `ssm/api.rs`) outside `#[cfg(test)]` code:
//!   every serving failure must become a typed
//!   [`coordinator::server::ServeError`] instead of a worker-killing
//!   panic (see *Failure model*). The poison-recovery idiom
//!   `.unwrap_or_else(|p| p.into_inner())` is deliberately not matched.
//!
//! Any line can be exempted with `// s5:allow(<lint>) <reason>` on the
//! offending line or the line directly above; the reason is mandatory.
//! CI additionally runs the pool lifecycle and scan kernels under Miri,
//! and the pool stress test under ThreadSanitizer (nightly jobs whose
//! logs upload as artifacts).

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod fft;
pub mod linalg;
pub mod num;
pub mod rng;
pub mod runtime;
pub mod ssm;
pub mod testing;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default location of the AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";
