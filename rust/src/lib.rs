//! # S5: Simplified State Space Layers for Sequence Modeling
//!
//! A production-grade reproduction of Smith, Warrington & Linderman
//! (ICLR 2023). The crate is the **Layer-3 coordinator** of a three-layer
//! stack (see `DESIGN.md`):
//!
//! * **L1** — a Pallas kernel implementing the diagonal-SSM parallel scan
//!   (built at compile time, `python/compile/kernels/scan.py`);
//! * **L2** — the JAX model (S5 layers, classifiers, regressors, fused
//!   AdamW train steps) lowered once to HLO text (`python/compile/aot.py`);
//! * **L3** — this crate: loads the AOT artifacts through the PJRT C API
//!   (via the `xla` crate), and owns the data pipeline, training loop,
//!   inference server, benchmarks and the paper's experiment harness.
//!
//! Python never runs on the request path: after `make artifacts` the `s5`
//! binary is self-contained.
//!
//! The crate also carries a **pure-Rust S5/S4/S4D reference stack**
//! ([`ssm`]) used four ways: as the parity oracle against the compiled HLO,
//! as the subject of the runtime benchmarks (paper Table 4), as the
//! substrate for the parallel-scan scaling studies (paper §2.2, Appendix H)
//! — and, via the **batched native inference engine** ([`ssm::engine`] +
//! [`ssm::scan::ScanBackend`]), as the execution backend of the native
//! serving mode: packed (B, L, H) forwards with workspace reuse and
//! pluggable sequential/parallel scan strategies.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | logging, timing, stats, CLI parsing, table formatting |
//! | [`rng`] | deterministic SplitMix64/PCG RNG + samplers (offline: no `rand`) |
//! | [`num`] | complex arithmetic |
//! | [`linalg`] | dense complex matrices, Hermitian Jacobi eigensolver |
//! | [`fft`] | radix-2 FFT (substrate for the S4 convolution baseline) |
//! | [`ssm`] | HiPPO init, discretization, scans, batched engine, S5/S4/S4D |
//! | [`data`] | the nine synthetic workload generators + batching |
//! | [`runtime`] | manifests; PJRT artifact loading + params (`pjrt` feature) |
//! | [`coordinator`] | configs, trainer (`pjrt`), LR schedules, metrics, server |
//! | [`testing`] | mini property-testing harness (offline: no `proptest`) |
//! | [`bench`] | shared harness for the paper-table benchmark binaries |
//!
//! ## Features
//!
//! `pjrt` (off by default) enables the compiled-HLO execution path: the
//! `xla` FFI runtime, the npz parameter store, the trainer, and the PJRT
//! serving backend. The default build is fully hermetic (no crates.io,
//! no prebuilt xla_extension) and still provides the entire native stack
//! including the batched inference server.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod fft;
pub mod linalg;
pub mod num;
pub mod rng;
pub mod runtime;
pub mod ssm;
pub mod testing;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default location of the AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";
