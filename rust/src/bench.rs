//! Shared harness for the paper-table benchmark binaries (`rust/benches/`).
//!
//! The offline build has no criterion, so this provides the measurement
//! loop (warmup + timed iterations + summary stats), relative-to-baseline
//! reporting in the same "× of S4D" style the paper's Table 4 uses, and
//! helpers to append results to `bench_output` sections.

use crate::util::{time_fn, Stats, Table};

/// One measured subject.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub stats: Stats,
    /// optional auxiliary metric (bytes, accuracy, MSE…)
    pub aux: Option<f64>,
}

/// A group of measurements sharing a baseline (paper style: "1.0×" row).
pub struct RelativeReport {
    pub title: String,
    pub baseline: String,
    pub rows: Vec<Measurement>,
}

impl RelativeReport {
    pub fn new(title: &str, baseline: &str) -> Self {
        RelativeReport { title: title.to_string(), baseline: baseline.to_string(), rows: vec![] }
    }

    pub fn add(&mut self, name: &str, stats: Stats) {
        self.rows.push(Measurement { name: name.to_string(), stats, aux: None });
    }

    pub fn add_with_aux(&mut self, name: &str, stats: Stats, aux: f64) {
        self.rows.push(Measurement { name: name.to_string(), stats, aux: Some(aux) });
    }

    /// Render with speed multipliers relative to the baseline row
    /// (>1× = faster than baseline, as in paper Table 4).
    pub fn render(&self) -> String {
        let base = self
            .rows
            .iter()
            .find(|m| m.name == self.baseline)
            .map(|m| m.stats.mean)
            .unwrap_or(f64::NAN);
        let mut t = Table::new(&["subject", "mean", "p50", "p95", "speed vs baseline"]);
        for m in &self.rows {
            t.row(&[
                m.name.clone(),
                fmt_secs(m.stats.mean),
                fmt_secs(m.stats.p50),
                fmt_secs(m.stats.p95),
                format!("{:.2}x", base / m.stats.mean),
            ]);
        }
        format!("## {}\n{}", self.title, t.render())
    }
}

/// Human-scale seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Standard measurement loop for bench binaries. Iteration counts adapt to
/// `quick` mode (`S5_BENCH_QUICK=1`, used by `cargo test`-adjacent smoke).
pub fn measure<F: FnMut()>(name: &str, f: F) -> Stats {
    let quick = quick_mode();
    let (warmup, iters) = if quick { (1, 3) } else { (3, 12) };
    let stats = time_fn(warmup, iters, f);
    eprintln!("  measured {name}: mean={} p95={}", fmt_secs(stats.mean), fmt_secs(stats.p95));
    stats
}

/// True when benches should run tiny workloads (`S5_BENCH_QUICK=1`).
/// Routed through [`crate::runtime::envcfg`] like every other knob:
/// strict 0/1 parse, one warning on anything else, read once per process.
pub fn quick_mode() -> bool {
    static CELL: std::sync::OnceLock<Option<bool>> = std::sync::OnceLock::new();
    crate::runtime::envcfg::env_flag_once(&CELL, "S5_BENCH_QUICK").unwrap_or(false)
}

/// Paper-vs-measured comparison row for EXPERIMENTS.md-style output.
pub fn paper_row(exp: &str, paper: &str, measured: &str, holds: bool) -> String {
    format!(
        "| {exp} | {paper} | {measured} | {} |",
        if holds { "✓" } else { "✗" }
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
        assert!(fmt_secs(2.5e-5).ends_with("us"));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }

    #[test]
    fn relative_report_math() {
        let mut r = RelativeReport::new("t", "base");
        r.add("base", Stats { n: 1, mean: 2.0, ..Default::default() });
        r.add("fast", Stats { n: 1, mean: 1.0, ..Default::default() });
        let s = r.render();
        assert!(s.contains("2.00x"), "{s}");
        assert!(s.contains("1.00x"), "{s}");
    }

    #[test]
    fn paper_row_renders() {
        let row = paper_row("Table 4 / Path-X", "4.7x", "3.9x", true);
        assert!(row.contains('✓'));
    }
}
