//! Iterative radix-2 FFT, from scratch.
//!
//! Substrate for the S4 **convolution mode** baseline (paper §2.3 and
//! Figure 4a): the SISO SSM output is `y = k * u`, computed by padding to
//! 2L, transforming, multiplying pointwise, and inverse-transforming —
//! exactly the O(L log L) path whose cost Proposition 1 compares against the
//! S5 scan.

use crate::num::C64;

/// In-place iterative Cooley–Tukey FFT. `xs.len()` must be a power of two.
/// `inverse` applies the conjugate transform *without* the 1/N scale
/// (callers that need a true inverse use [`ifft`]).
pub fn fft_in_place(xs: &mut [C64], inverse: bool) {
    let n = xs.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two, got {n}");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            xs.swap(i, j);
        }
    }
    // butterflies
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = C64::cis(ang);
        let mut i = 0;
        while i < n {
            let mut w = C64::ONE;
            for k in 0..len / 2 {
                let u = xs[i + k];
                let v = xs[i + k + len / 2] * w;
                xs[i + k] = u + v;
                xs[i + k + len / 2] = u - v;
                w = w * wlen;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Forward FFT (allocating).
pub fn fft(xs: &[C64]) -> Vec<C64> {
    let mut out = xs.to_vec();
    fft_in_place(&mut out, false);
    out
}

/// Inverse FFT with 1/N normalization (allocating).
pub fn ifft(xs: &[C64]) -> Vec<C64> {
    let mut out = xs.to_vec();
    fft_in_place(&mut out, true);
    let scale = 1.0 / out.len() as f64;
    for z in &mut out {
        *z = z.scale(scale);
    }
    out
}

/// Next power of two ≥ n.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Linear (causal) convolution of two real sequences truncated to
/// `out_len`, via zero-padded FFT. This is the S4 conv-mode primitive:
/// `y[k] = Σ_j kernel[j] · signal[k-j]`.
pub fn conv_real(kernel: &[f64], signal: &[f64], out_len: usize) -> Vec<f64> {
    let n = next_pow2(kernel.len() + signal.len());
    let mut ka = vec![C64::ZERO; n];
    let mut sa = vec![C64::ZERO; n];
    for (i, &k) in kernel.iter().enumerate() {
        ka[i] = C64::from_re(k);
    }
    for (i, &s) in signal.iter().enumerate() {
        sa[i] = C64::from_re(s);
    }
    fft_in_place(&mut ka, false);
    fft_in_place(&mut sa, false);
    for i in 0..n {
        ka[i] = ka[i] * sa[i];
    }
    fft_in_place(&mut ka, true);
    let scale = 1.0 / n as f64;
    (0..out_len).map(|i| ka[i].re * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut xs = vec![C64::ZERO; 8];
        xs[0] = C64::ONE;
        fft_in_place(&mut xs, false);
        for z in xs {
            assert!((z - C64::ONE).abs() < 1e-12);
        }
    }

    #[test]
    fn fft_known_dft_of_ones() {
        let xs = vec![C64::ONE; 4];
        let f = fft(&xs);
        assert!((f[0] - C64::from_re(4.0)).abs() < 1e-12);
        for k in 1..4 {
            assert!(f[k].abs() < 1e-12);
        }
    }

    #[test]
    fn prop_ifft_inverts_fft() {
        prop::check("ifft∘fft = id", 40, |g| {
            let n = 1 << (1 + g.below(9)); // 2..=512
            let xs: Vec<C64> = (0..n).map(|_| C64::new(g.normal(), g.normal())).collect();
            let back = ifft(&fft(&xs));
            for (a, b) in xs.iter().zip(&back) {
                prop::close_f64(a.re, b.re, 1e-9)?;
                prop::close_f64(a.im, b.im, 1e-9)?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_parseval() {
        prop::check("parseval", 30, |g| {
            let n = 1 << (2 + g.below(7));
            let xs: Vec<C64> = (0..n).map(|_| C64::new(g.normal(), g.normal())).collect();
            let f = fft(&xs);
            let e_time: f64 = xs.iter().map(|z| z.norm_sq()).sum();
            let e_freq: f64 = f.iter().map(|z| z.norm_sq()).sum::<f64>() / n as f64;
            prop::close_f64(e_time, e_freq, 1e-9)
        });
    }

    #[test]
    fn prop_conv_matches_naive() {
        prop::check("fft conv ≡ naive conv", 30, |g| {
            let lk = 1 + g.below(20);
            let ls = 1 + g.below(40);
            let kernel: Vec<f64> = (0..lk).map(|_| g.normal()).collect();
            let signal: Vec<f64> = (0..ls).map(|_| g.normal()).collect();
            let out_len = ls;
            let fast = conv_real(&kernel, &signal, out_len);
            let mut naive = vec![0.0; out_len];
            for k in 0..out_len {
                for j in 0..=k.min(lk - 1) {
                    if k - j < ls {
                        naive[k] += kernel[j] * signal[k - j];
                    }
                }
            }
            for (a, b) in fast.iter().zip(&naive) {
                prop::close_f64(*a, *b, 1e-8)?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fft_rejects_non_pow2() {
        let mut xs = vec![C64::ZERO; 6];
        fft_in_place(&mut xs, false);
    }
}
