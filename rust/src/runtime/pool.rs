//! Persistent worker pool: how the native engine dispatches parallelism.
//!
//! Before this module, every multi-threaded stage in the native stack —
//! the chunked Blelloch scans of [`crate::ssm::scan`], the dense per-
//! sequence stages of [`crate::ssm::engine`] (`par_zip*`), the batch
//! sharding of the `ScanBackend`s — paid a `std::thread::scope`
//! spawn/join per call (~20 spawn sites). At serving request rates that
//! per-batch spawn overhead is pure waste: the paper's pitch is that the
//! scan "leverages efficient and widely implemented parallel scans"
//! (Smith et al. 2023, §2.2), and on CPU an efficient parallel scan means
//! fanning chunks onto *already-running* workers.
//!
//! Three pieces:
//!
//! * [`WorkerPool`] — N persistent, parked worker threads (one-time
//!   spawn, condvar wakeup, joined on drop). [`WorkerPool::run`] /
//!   [`WorkerPool::run_tasks`] are *scoped*: the shard closures may
//!   borrow stack data exactly like `std::thread::scope` closures do,
//!   because the call blocks until every shard has executed. The calling
//!   thread participates in the work (it claims shards alongside the
//!   workers), so a run always completes even when every worker is busy
//!   — which also makes nested runs (batch sharding → in-sequence
//!   chunking) deadlock-free by induction: a waiting caller has no
//!   unclaimed shards left, and every claimed shard is being executed by
//!   a thread that never blocks on the pool. A panicking shard poisons
//!   only that task: the worker survives, the pool stays usable, the
//!   remaining shards still run, and the first panic **payload** is
//!   re-raised on the calling thread after the run completes. (The other
//!   executors differ in detail: `thread::scope` re-raises with its own
//!   "scoped thread panicked" payload, and inline execution propagates
//!   immediately, skipping the remaining shards — panic behavior is a
//!   best-effort debugging surface, not part of the bit-for-bit
//!   equivalence contract, which covers successful runs only.)
//! * [`Executor`] — the dispatch strategy handle the kernels and engine
//!   stages take instead of spawning: [`Inline`](Executor::Inline) (run
//!   shards on the caller, no threads), [`Scoped`](Executor::Scoped)
//!   (the pre-pool spawn-per-call fallback) or
//!   [`Pool`](Executor::Pool). All three run the identical shard
//!   closures over the identical data decomposition, so results agree
//!   **bit-for-bit** across executors — pinned by the
//!   `tests/scan_matrix.rs` equivalence matrix, which is what lets
//!   future scheduling changes land without numeric drift.
//! * [`global_pool`] — the lazily-spawned process-wide pool every
//!   [`backend_for_threads`](crate::ssm::scan::backend_for_threads)
//!   strategy and the native server share, sized to
//!   `available_parallelism − 1` workers (the caller is the +1) and
//!   overridable with `S5_POOL_WORKERS` (CI oversubscribes it to shake
//!   out scheduling bugs).
//!
//! Shard *decomposition* (how many chunks, which rows) is decided by the
//! backends' `threads()` budget, never by the executor — the pool can be
//! bigger or smaller than any budget without changing a single result.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A type-erased shard closure. The `'static` bound is a lie told once,
/// inside [`WorkerPool::run_tasks`], where the completion barrier makes
/// it true in practice (no task outlives the borrowed environment).
type Task = Box<dyn FnOnce() + Send + 'static>;

struct GroupState {
    /// shards executed so far (a run is complete when `done == n`)
    done: usize,
    /// first panic payload raised by a shard, re-raised on the caller
    panic: Option<Box<dyn Any + Send>>,
}

/// One scoped run: the remaining shard closures plus the completion
/// latch. Workers and the calling thread claim tasks until none remain;
/// the caller then blocks on `done == n`.
struct Group {
    tasks: Mutex<Vec<Task>>,
    n: usize,
    state: Mutex<GroupState>,
    cv: Condvar,
}

impl Group {
    /// Claim one shard and execute it. Returns false when no shards
    /// remain to claim. Panics are captured into the group state; the
    /// claim is always counted, so the completion latch cannot hang.
    fn claim_and_run(&self) -> bool {
        let task = self.tasks.lock().unwrap().pop();
        let task = match task {
            Some(t) => t,
            None => return false,
        };
        let result = catch_unwind(AssertUnwindSafe(task));
        let mut st = self.state.lock().unwrap();
        if let Err(payload) = result {
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        st.done += 1;
        if st.done == self.n {
            self.cv.notify_all();
        }
        true
    }
}

struct Shared {
    /// pending work: one entry per outstanding shard (stale entries for
    /// fully-claimed groups are popped and discarded cheaply)
    queue: Mutex<VecDeque<Arc<Group>>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// workers currently running their loop (drops to 0 after shutdown)
    live: AtomicUsize,
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let group = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(g) = q.pop_front() {
                    break Some(g);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        match group {
            Some(g) => {
                g.claim_and_run();
            }
            None => break,
        }
    }
    shared.live.fetch_sub(1, Ordering::SeqCst);
}

/// A fixed-size pool of persistent, parked worker threads with a scoped
/// fork-join `run` primitive. See the module docs for the execution and
/// panic model. Dropping the pool joins every worker.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `workers` persistent threads (clamped to ≥ 1).
    ///
    /// Sizing rule of thumb: a run on a pool of W workers executes on up
    /// to W + 1 threads (the caller participates), so a pool intended to
    /// saturate T cores wants W = T − 1 workers.
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            live: AtomicUsize::new(workers),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("s5-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("failed to spawn pool worker thread")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Number of worker threads this pool spawned at construction. The
    /// pool never spawns again — `workers()` is also the total thread
    /// count it will ever create (the no-steady-state-spawn contract the
    /// lifecycle tests pin).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Worker threads currently running their loop (equals [`workers`]
    /// while the pool is alive; reaches 0 only during drop).
    ///
    /// [`workers`]: WorkerPool::workers
    pub fn live_workers(&self) -> usize {
        self.shared.live.load(Ordering::SeqCst)
    }

    /// Shards currently queued but not yet claimed (telemetry; includes
    /// stale entries of already-completed runs until workers drain them).
    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Run `n_shards` invocations of `f(shard)` across the pool and the
    /// calling thread, returning when all have completed. `f` may borrow
    /// stack data (the call is a completion barrier, exactly like
    /// `std::thread::scope`). Re-raises the first shard panic.
    pub fn run<F>(&self, n_shards: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let f = &f;
        self.run_tasks((0..n_shards).map(move |i| move || f(i)));
    }

    /// Run one closure per shard (each may own disjoint `&mut` borrows,
    /// the way `thread::scope` spawn bodies do) across the pool and the
    /// calling thread; returns when every closure has executed.
    ///
    /// Dispatch cost is O(shards) small heap objects (boxed closures +
    /// one latch) — negligible against the OS-thread spawn/join this
    /// replaces, and amortized by any non-trivial shard body. A future
    /// zero-alloc fast path could pool the task buffers if profiles ever
    /// show it.
    pub fn run_tasks<'env, I, F>(&self, tasks: I)
    where
        I: IntoIterator<Item = F>,
        F: FnOnce() + Send + 'env,
    {
        let mut boxed: Vec<Task> = tasks
            .into_iter()
            .map(|t| {
                let t: Box<dyn FnOnce() + Send + 'env> = Box::new(t);
                // SAFETY: every task is executed (and dropped) before
                // this call returns — the caller claims until the task
                // list is empty, then blocks on the `done == n` latch —
                // so no closure ever outlives the `'env` borrows it
                // captures. Only the lifetime is transmuted.
                unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(t) }
            })
            .collect();
        let n = boxed.len();
        if n == 0 {
            return;
        }
        if n == 1 {
            // single shard: run inline, no synchronization traffic
            return (boxed.pop().unwrap())();
        }
        let group = Arc::new(Group {
            tasks: Mutex::new(boxed),
            n,
            state: Mutex::new(GroupState { done: 0, panic: None }),
            cv: Condvar::new(),
        });
        {
            // one wakeup ticket per shard the workers could take (the
            // caller is about to claim at least one itself)
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..n - 1 {
                q.push_back(group.clone());
            }
        }
        // wake at most one parked worker per ticket — notify_all would
        // thundering-herd a large pool on a small run. A notification
        // landing while every worker is busy is not lost: workers always
        // re-check the queue before parking.
        for _ in 0..n - 1 {
            self.shared.cv.notify_one();
        }
        // the calling thread participates until no shard is left to claim
        while group.claim_and_run() {}
        // ...then waits for shards claimed by workers to finish
        let mut st = group.state.lock().unwrap();
        while st.done < n {
            st = group.cv.wait(st).unwrap();
        }
        let panicked = st.panic.take();
        drop(st);
        if let Some(payload) = panicked {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers())
            .field("live", &self.live_workers())
            .finish()
    }
}

/// How a parallel stage dispatches its shard closures. Cheap to copy;
/// kernels and engine stages take one of these instead of spawning.
///
/// All three variants execute the identical closures over the identical
/// decomposition — results are bit-for-bit executor-invariant (pinned by
/// `tests/scan_matrix.rs`).
#[derive(Clone, Copy)]
pub enum Executor<'a> {
    /// Run every shard on the calling thread, in order. Single-threaded
    /// execution of the same chunked decomposition — the deterministic
    /// debugging mode, and what sequential backends report.
    Inline,
    /// Spawn one scoped thread per shard (`std::thread::scope`) — the
    /// pre-pool behavior, kept as the fallback and as the bench baseline
    /// the pooled path is A/B'd against.
    Scoped,
    /// Dispatch onto a persistent [`WorkerPool`] (the calling thread
    /// participates). The default for every pooled scan backend.
    Pool(&'a WorkerPool),
}

impl<'a> Executor<'a> {
    /// Execute one closure per shard to completion (a fork-join barrier
    /// in every variant).
    pub fn run_tasks<I, F>(&self, tasks: I)
    where
        I: IntoIterator<Item = F>,
        F: FnOnce() + Send,
    {
        match self {
            Executor::Inline => {
                for t in tasks {
                    t();
                }
            }
            Executor::Scoped => {
                std::thread::scope(|s| {
                    for t in tasks {
                        s.spawn(t);
                    }
                });
            }
            Executor::Pool(pool) => pool.run_tasks(tasks),
        }
    }

    /// Execute `f(shard)` for `n_shards` shards to completion.
    pub fn run<F>(&self, n_shards: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let f = &f;
        self.run_tasks((0..n_shards).map(move |i| move || f(i)));
    }

    /// Short strategy name (telemetry, bench labels).
    pub fn kind(&self) -> &'static str {
        match self {
            Executor::Inline => "inline",
            Executor::Scoped => "scoped",
            Executor::Pool(_) => "pool",
        }
    }

    /// True when this executor dispatches onto a persistent pool.
    pub fn is_pool(&self) -> bool {
        matches!(self, Executor::Pool(_))
    }
}

impl std::fmt::Debug for Executor<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.kind())
    }
}

static GLOBAL_POOL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide worker pool shared by every pooled scan backend, the
/// native inference server and its streaming sessions. Spawned lazily on
/// first use and never dropped (workers park when idle).
///
/// Sized to `available_parallelism − 1` workers — the calling thread is
/// the +1 — and overridable with the `S5_POOL_WORKERS` environment
/// variable (read once, parsed strictly via [`crate::runtime::envcfg`];
/// CI oversubscribes it to stress scheduling).
///
/// First use also runs the one-shot cache calibration
/// ([`crate::ssm::engine::tile_target_bytes`]) *before* the workers spin
/// up, so the timing probe measures a quiet process and every fused
/// forward dispatched onto this pool finds the budget already resolved.
pub fn global_pool() -> &'static WorkerPool {
    GLOBAL_POOL.get_or_init(|| {
        let _ = crate::ssm::engine::tile_target_bytes();
        WorkerPool::new(default_global_workers())
    })
}

fn default_global_workers() -> usize {
    static WORKERS: OnceLock<Option<usize>> = OnceLock::new();
    if let Some(n) =
        crate::runtime::envcfg::env_usize_once(&WORKERS, "S5_POOL_WORKERS", "a worker count")
    {
        return n.max(1);
    }
    std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(2)
        .saturating_sub(1)
        .max(1)
}

/// Render a caught panic payload as its message. `panic!` with a format
/// string produces a `String` payload and `panic!("literal")` a
/// `&'static str`; anything else (custom `panic_any` values) gets a
/// placeholder. Used by the serving layer to surface a contained model
/// panic as a typed error without re-raising it.
pub fn panic_message(payload: Box<dyn Any + Send>) -> String {
    match payload.downcast::<String>() {
        Ok(s) => *s,
        Err(p) => match p.downcast::<&'static str>() {
            Ok(s) => (*s).to_string(),
            Err(_) => "<non-string panic payload>".to_string(),
        },
    }
}

/// Spawn a named long-lived service thread (server workers). The one
/// `std::thread` spawn path outside the pool itself — the coordinator's
/// native and PJRT serving loops both go through here instead of each
/// hand-rolling a `std::thread::spawn` block.
pub fn spawn_worker<F>(name: &str, f: F) -> std::thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .unwrap_or_else(|e| panic!("failed to spawn worker thread {name:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Every shard runs exactly once, with stack-borrowed data, and the
    /// caller sees all writes after the barrier.
    #[test]
    fn run_executes_every_shard_with_borrowed_data() {
        let pool = WorkerPool::new(3);
        for &n in &[0usize, 1, 2, 3, 7, 64] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            let base = 10u64;
            pool.run(n, |i| {
                hits[i].fetch_add(base + i as u64, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), base + i as u64, "n={n} shard {i}");
            }
        }
    }

    /// run_tasks closures may own disjoint `&mut` chunks, like
    /// `thread::scope` spawn bodies.
    #[test]
    fn run_tasks_supports_disjoint_mut_chunks() {
        let pool = WorkerPool::new(2);
        let mut data = vec![0u64; 24];
        pool.run_tasks(data.chunks_mut(5).enumerate().map(|(c, chunk)| {
            move || {
                for v in chunk.iter_mut() {
                    *v = c as u64 + 1;
                }
            }
        }));
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, (i / 5) as u64 + 1, "idx {i}");
        }
    }

    /// Oversubscription: many more shards than workers completes, and
    /// nested runs (a shard that itself runs shards) cannot deadlock
    /// because the waiting caller participates.
    #[test]
    fn oversubscription_and_nesting_complete() {
        let pool = WorkerPool::new(2);
        let outer = 5usize;
        let inner = 7usize;
        let count = AtomicU64::new(0);
        pool.run(outer, |_| {
            pool.run(inner, |_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(count.load(Ordering::SeqCst), (outer * inner) as u64);
        // plain oversubscription, one level
        let count = AtomicU64::new(0);
        pool.run(64, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 64);
    }

    /// A panicking shard is re-raised on the caller (scope semantics) but
    /// poisons only that task: the workers survive and the pool keeps
    /// serving runs.
    #[test]
    fn panicking_shard_leaves_pool_usable() {
        let pool = WorkerPool::new(2);
        let before = pool.live_workers();
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(6, |i| {
                if i == 3 {
                    panic!("shard 3 exploded");
                }
            });
        }));
        let payload = result.expect_err("shard panic must propagate to the caller");
        let msg = panic_message(payload);
        assert!(msg.contains("exploded"), "unexpected payload {msg:?}");
        // the helper also renders formatted (String) payloads and shrugs
        // at non-string ones instead of panicking itself
        let shard = 3;
        let formatted = catch_unwind(|| panic!("shard {shard} exploded")).expect_err("must panic");
        assert_eq!(panic_message(formatted), "shard 3 exploded");
        let opaque = catch_unwind(|| std::panic::panic_any(42u32)).expect_err("must panic");
        assert_eq!(panic_message(opaque), "<non-string panic payload>");
        assert_eq!(pool.live_workers(), before, "a worker died with the task");
        // the pool still works
        let count = AtomicU64::new(0);
        pool.run(8, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }

    /// Reuse across differently-sized runs never spawns new threads:
    /// `workers()` (total ever spawned) and `live_workers()` are stable
    /// from construction to drop.
    #[test]
    fn varied_size_reuse_never_leaks_threads() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 3);
        assert_eq!(pool.live_workers(), 3);
        for &n in &[1usize, 16, 2, 64, 5, 128, 3] {
            let count = AtomicU64::new(0);
            pool.run(n, |_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(count.load(Ordering::SeqCst), n as u64);
            assert_eq!(pool.workers(), 3, "pool grew at n={n}");
            assert_eq!(pool.live_workers(), 3, "a worker exited at n={n}");
        }
    }

    /// Drop joins all workers: the live counter reaches 0 and the worker
    /// threads are gone (join returned).
    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(4);
        let shared = pool.shared.clone();
        pool.run(10, |_| {});
        assert_eq!(shared.live.load(Ordering::SeqCst), 4);
        drop(pool); // joins — must not hang
        assert_eq!(shared.live.load(Ordering::SeqCst), 0, "a worker outlived the pool");
        assert_eq!(shared.queue.lock().unwrap().len(), 0, "work left behind after drop");
    }

    /// The executor variants run the same tasks to the same effect; the
    /// clamped-to-one-worker pool still completes (caller participation).
    #[test]
    fn executor_variants_agree() {
        let pool = WorkerPool::new(1);
        for exec in [Executor::Inline, Executor::Scoped, Executor::Pool(&pool)] {
            let mut data = vec![0u32; 12];
            exec.run_tasks(data.chunks_mut(4).enumerate().map(|(c, chunk)| {
                move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (c * 4 + j) as u32;
                    }
                }
            }));
            let want: Vec<u32> = (0..12).collect();
            assert_eq!(data, want, "executor {}", exec.kind());
        }
        assert!(Executor::Pool(&pool).is_pool());
        assert!(!Executor::Scoped.is_pool());
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let p1 = global_pool();
        let p2 = global_pool();
        assert!(std::ptr::eq(p1, p2));
        assert!(p1.workers() >= 1);
        assert_eq!(p1.live_workers(), p1.workers());
    }
}
