//! Compiled artifact: HLO text → PJRT executable, plus execution helpers.

use anyhow::{bail, Context};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::manifest::Manifest;

/// Shared PJRT client handle.
#[derive(Clone)]
pub struct Client {
    inner: Arc<PjRtClient>,
}

impl Client {
    /// Create the CPU PJRT client (the testbed backend, see DESIGN.md
    /// §Hardware-Adaptation).
    pub fn cpu() -> anyhow::Result<Client> {
        let c = PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::debug!(
            "PJRT client: platform={} devices={}",
            c.platform_name(),
            c.device_count()
        );
        Ok(Client { inner: Arc::new(c) })
    }

    pub fn raw(&self) -> &PjRtClient {
        &self.inner
    }
}

/// A loaded, compiled AOT artifact (one lowered jit function).
pub struct Artifact {
    pub manifest: Manifest,
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl Artifact {
    /// Load `<dir>/<name>.hlo.txt` + manifest and compile on `client`.
    ///
    /// HLO **text** is required (not a serialized proto): jax ≥ 0.5 emits
    /// 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
    /// parser reassigns ids.
    pub fn load(dir: &Path, name: &str, client: &Client) -> anyhow::Result<Artifact> {
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let man_path = dir.join(format!("{name}.manifest.txt"));
        let manifest = Manifest::load(&man_path)?;
        let t = crate::util::Timer::start();
        let proto = HloModuleProto::from_text_file(&hlo_path)
            .with_context(|| format!("parsing HLO text {hlo_path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client
            .raw()
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        crate::debug!("compiled {name} in {:.2}s", t.secs());
        Ok(Artifact { manifest, exe, name: name.to_string() })
    }

    /// Path of the npz of initial parameters for a preset.
    pub fn init_npz_path(dir: &Path, preset: &str) -> PathBuf {
        dir.join(format!("{preset}_init.npz"))
    }

    /// Execute with ordered inputs; returns the flattened output tuple.
    /// Accepts owned literals or references (the trainer passes refs to its
    /// long-lived parameter literals to avoid host copies).
    pub fn run<L: std::borrow::Borrow<Literal>>(
        &self,
        inputs: &[L],
    ) -> anyhow::Result<Vec<Literal>> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest wants {}",
                self.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        let bufs = self.exe.execute::<L>(inputs)?;
        let result = bufs[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != self.manifest.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest declares {}",
                self.name,
                outs.len(),
                self.manifest.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Number of parameters (by manifest group).
    pub fn n_param_inputs(&self) -> usize {
        self.manifest.input_group("params").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::params::{literal_f32, to_vec_f32, ParamStore};
    use std::collections::BTreeMap;

    fn artifacts_dir() -> Option<&'static Path> {
        let p = Path::new(crate::ARTIFACTS_DIR);
        if p.join("quickstart_fwd.hlo.txt").exists() {
            Some(p)
        } else {
            None
        }
    }

    #[test]
    fn quickstart_layer_executes() {
        let Some(dir) = artifacts_dir() else { return };
        let client = Client::cpu().unwrap();
        let art = Artifact::load(dir, "quickstart_fwd", &client).unwrap();
        assert_eq!(art.manifest.kind, "layer");
        let store = ParamStore::load_npz(&Artifact::init_npz_path(dir, "quickstart")).unwrap();
        let (l, h) = (128usize, 8usize);
        let mut extra = BTreeMap::new();
        extra.insert(
            "u".to_string(),
            literal_f32(&vec![0.1; l * h], &[l, h]).unwrap(),
        );
        let inputs =
            crate::runtime::params::assemble_inputs(&art.manifest, &store, &mut extra).unwrap();
        let outs = art.run(&inputs).unwrap();
        assert_eq!(outs.len(), 1);
        let y = to_vec_f32(&outs[0]).unwrap();
        assert_eq!(y.len(), l * h);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn run_rejects_wrong_arity() {
        let Some(dir) = artifacts_dir() else { return };
        let client = Client::cpu().unwrap();
        let art = Artifact::load(dir, "quickstart_fwd", &client).unwrap();
        assert!(art.run::<Literal>(&[]).is_err());
    }
}
