//! Named parameter store: the host-side view of model state.
//!
//! Parameters are named exactly as in the manifests (`params.layers.0.b_re`
//! …) and serialized as npz: numpy writes the initial store at AOT time,
//! [`ParamStore::load_npz`] reads it, and checkpoints round-trip through
//! `Literal::write_npz` so a trained model can be re-served without Python.

use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;
use xla::{FromRawBytes, Literal};

use crate::runtime::manifest::{Dtype, Manifest, TensorSpec};

/// Ordered name → tensor map.
pub struct ParamStore {
    entries: BTreeMap<String, Literal>,
}

impl ParamStore {
    pub fn new() -> Self {
        ParamStore { entries: BTreeMap::new() }
    }

    /// Load every tensor from an npz file.
    pub fn load_npz(path: &Path) -> anyhow::Result<ParamStore> {
        let pairs = Literal::read_npz(path, &())
            .with_context(|| format!("reading npz {path:?}"))?;
        let mut entries = BTreeMap::new();
        for (name, lit) in pairs {
            entries.insert(name, lit);
        }
        Ok(ParamStore { entries })
    }

    /// Save every tensor to an npz file (checkpointing).
    ///
    /// Hand-rolled npy/npz writer: the xla crate's `Literal::write_npz`
    /// copies through an untyped `u8` buffer, which its own `copy_raw_to`
    /// rejects with an element-type mismatch — so we serialize the npy
    /// format ourselves (v1.0 header + little-endian payload, STORED zip
    /// entries, matching what `numpy.savez` produces).
    pub fn save_npz(&self, path: &Path) -> anyhow::Result<()> {
        use std::io::Write as _;
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating npz {path:?}"))?;
        let mut zip = zip::ZipWriter::new(file);
        let opts = zip::write::FileOptions::default()
            .compression_method(zip::CompressionMethod::Stored);
        for (name, lit) in &self.entries {
            zip.start_file(format!("{name}.npy"), opts)?;
            let bytes = npy_bytes(lit)?;
            zip.write_all(&bytes)?;
        }
        zip.finish()?;
        Ok(())
    }

    pub fn insert(&mut self, name: &str, lit: Literal) {
        self.entries.insert(name.to_string(), lit);
    }

    pub fn get(&self, name: &str) -> Option<&Literal> {
        self.entries.get(name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    /// Materialize tensors in the order demanded by `specs`, checking
    /// shapes. `specs` names must all exist in the store.
    pub fn gather(&self, specs: &[&TensorSpec]) -> anyhow::Result<Vec<Literal>> {
        let mut out = Vec::with_capacity(specs.len());
        for spec in specs {
            let lit = self
                .entries
                .get(&spec.name)
                .with_context(|| format!("param {:?} missing from store", spec.name))?;
            let got = lit.element_count();
            if got != spec.elem_count() {
                bail!(
                    "param {:?}: store has {got} elements, manifest wants {:?}",
                    spec.name,
                    spec.dims
                );
            }
            out.push(clone_literal(lit)?);
        }
        Ok(out)
    }

    /// Total f32-equivalent parameter count.
    pub fn total_elems(&self) -> usize {
        self.entries.values().map(|l| l.element_count()).sum()
    }
}

impl Default for ParamStore {
    fn default() -> Self {
        Self::new()
    }
}

/// Serialize one literal as npy v1.0 bytes (little-endian, C order).
/// The header comes from the shared pure-Rust serializer
/// ([`crate::runtime::npz::npy_header`]), so the pjrt checkpoint writer
/// and the native [`crate::runtime::npz::NpzStore`] emit identical files.
fn npy_bytes(lit: &Literal) -> anyhow::Result<Vec<u8>> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let (descr, payload): (&str, Vec<u8>) = match shape.ty() {
        xla::ElementType::F32 => {
            let mut host = vec![0f32; lit.element_count()];
            lit.copy_raw_to(&mut host)?;
            ("<f4", host.iter().flat_map(|v| v.to_le_bytes()).collect())
        }
        xla::ElementType::S32 => {
            let mut host = vec![0i32; lit.element_count()];
            lit.copy_raw_to(&mut host)?;
            ("<i4", host.iter().flat_map(|v| v.to_le_bytes()).collect())
        }
        other => anyhow::bail!("npy_bytes: unsupported element type {other:?}"),
    };
    let mut out = crate::runtime::npz::npy_header(descr, &dims);
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Deep-copy a literal (the xla crate exposes no Clone for Literal).
pub fn clone_literal(lit: &Literal) -> anyhow::Result<Literal> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    // copy_raw_to type-checks the element type, so read through the real
    // dtype and reinterpret as bytes for the untyped constructor.
    let bytes: Vec<u8> = match shape.ty() {
        xla::ElementType::F32 => {
            let mut host = vec![0f32; lit.element_count()];
            lit.copy_raw_to(&mut host)?;
            host.iter().flat_map(|v| v.to_le_bytes()).collect()
        }
        xla::ElementType::S32 => {
            let mut host = vec![0i32; lit.element_count()];
            lit.copy_raw_to(&mut host)?;
            host.iter().flat_map(|v| v.to_le_bytes()).collect()
        }
        other => anyhow::bail!("clone_literal: unsupported element type {other:?}"),
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        shape.ty(),
        &dims,
        &bytes,
    )?)
}

/// Build an f32 literal with the given dims (dims=[] ⇒ scalar).
pub fn literal_f32(data: &[f32], dims: &[usize]) -> anyhow::Result<Literal> {
    let expected: usize = dims.iter().product::<usize>().max(1);
    if data.len() != expected {
        bail!("literal_f32: {} values for dims {dims:?}", data.len());
    }
    if dims.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let lit = Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims_i64)?)
}

/// Build an i32 literal.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> anyhow::Result<Literal> {
    let expected: usize = dims.iter().product::<usize>().max(1);
    if data.len() != expected {
        bail!("literal_i32: {} values for dims {dims:?}", data.len());
    }
    if dims.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let lit = Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims_i64)?)
}

/// Zero-filled literal matching a spec (Adam state bootstrap).
pub fn literal_zeros(spec: &TensorSpec) -> anyhow::Result<Literal> {
    match spec.dtype {
        Dtype::F32 => literal_f32(&vec![0.0; spec.elem_count()], &spec.dims),
        Dtype::I32 => literal_i32(&vec![0; spec.elem_count()], &spec.dims),
    }
}

/// Read back an f32 literal as a host vector.
pub fn to_vec_f32(lit: &Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Build the full ordered input vector for a manifest by combining the
/// param store (for `params.*` slots) with caller-provided tensors for the
/// rest. `extra` maps input-name → Literal.
pub fn assemble_inputs(
    manifest: &Manifest,
    params: &ParamStore,
    extra: &mut BTreeMap<String, Literal>,
) -> anyhow::Result<Vec<Literal>> {
    let mut out = Vec::with_capacity(manifest.inputs.len());
    for spec in &manifest.inputs {
        if let Some(lit) = extra.remove(&spec.name) {
            if lit.element_count() != spec.elem_count() {
                bail!(
                    "input {:?}: got {} elements, want {:?}",
                    spec.name,
                    lit.element_count(),
                    spec.dims
                );
            }
            out.push(lit);
        } else if let Some(lit) = params.get(&spec.name) {
            out.push(clone_literal(lit)?);
        } else {
            bail!("no source for input {:?}", spec.name);
        }
    }
    if !extra.is_empty() {
        let stray: Vec<&String> = extra.keys().collect();
        bail!("extra inputs not consumed: {stray:?}");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_vec_f32(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn scalar_literals() {
        let s = literal_f32(&[7.5], &[]).unwrap();
        assert_eq!(s.element_count(), 1);
        let i = literal_i32(&[3], &[]).unwrap();
        assert_eq!(i.element_count(), 1);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn store_save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("s5_params_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.npz");
        let mut store = ParamStore::new();
        store.insert("params.a", literal_f32(&[1.5, -2.5], &[2]).unwrap());
        store.insert("params.b", literal_f32(&[0.0; 6], &[2, 3]).unwrap());
        store.save_npz(&path).unwrap();
        let loaded = ParamStore::load_npz(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(
            to_vec_f32(loaded.get("params.a").unwrap()).unwrap(),
            vec![1.5, -2.5]
        );
        assert_eq!(loaded.total_elems(), 8);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn assemble_respects_manifest_order() {
        let m = Manifest::parse(
            "artifact t\nkind k\ninput 0 params.w f32 2\ninput 1 lr f32 -\ninput 2 x f32 2\n",
        )
        .unwrap();
        let mut store = ParamStore::new();
        store.insert("params.w", literal_f32(&[1.0, 2.0], &[2]).unwrap());
        let mut extra = BTreeMap::new();
        extra.insert("lr".to_string(), literal_f32(&[0.1], &[]).unwrap());
        extra.insert("x".to_string(), literal_f32(&[9.0, 8.0], &[2]).unwrap());
        let inputs = assemble_inputs(&m, &store, &mut extra).unwrap();
        assert_eq!(inputs.len(), 3);
        assert_eq!(to_vec_f32(&inputs[0]).unwrap(), vec![1.0, 2.0]);
        assert_eq!(to_vec_f32(&inputs[2]).unwrap(), vec![9.0, 8.0]);
    }

    #[test]
    fn assemble_rejects_missing_and_stray() {
        let m = Manifest::parse("artifact t\nkind k\ninput 0 x f32 1\n").unwrap();
        let store = ParamStore::new();
        let mut extra = BTreeMap::new();
        assert!(assemble_inputs(&m, &store, &mut extra).is_err());
        let mut extra = BTreeMap::new();
        extra.insert("x".to_string(), literal_f32(&[1.0], &[1]).unwrap());
        extra.insert("stray".to_string(), literal_f32(&[1.0], &[1]).unwrap());
        assert!(assemble_inputs(&m, &store, &mut extra).is_err());
    }
}
