//! Strict, warn-once parsing for the `S5_*` environment overrides.
//!
//! The runtime knobs (`S5_TILE_L`, `S5_POOL_WORKERS`, `S5_CACHE_KB`,
//! benchmark toggles) are read from the environment exactly once per
//! process and cached in a caller-owned `OnceLock` — `std::env::var`
//! takes the env lock and allocates, which has no place on a hot path,
//! and a knob that changed mid-process would make runs irreproducible
//! anyway.
//!
//! Parsing is **strict**: the value must be a plain non-negative decimal
//! integer (surrounding whitespace tolerated). Anything else — empty,
//! signs, floats, hex, unit suffixes, non-UTF-8 — is *rejected with a
//! one-time warning on stderr* and the built-in default is used, rather
//! than silently misconfiguring a sweep (`S5_POOL_WORKERS=max` used to be
//! quietly ignored; a CI matrix that tested nothing is worse than a
//! failure). The pure parser is separated from the env read so the
//! accept/reject behavior is unit-testable without mutating the process
//! environment (which would race parallel tests).

use std::sync::OnceLock;

// s5:env-registry-begin
/// Every `S5_*` environment knob the repo reads, with what it controls.
/// This table is the registry lint L2 (`env-registry`, see `xtask`)
/// cross-checks: a knob string used anywhere in the sources must appear
/// here, and every entry here must be used somewhere — so the table can
/// neither lag behind a new knob nor accumulate stale ones.
pub const ENV_REGISTRY: &[(&str, &str)] = &[
    ("S5_TILE_L", "fused-forward L-tile length override (engine auto-tiling)"),
    ("S5_CACHE_KB", "per-core cache budget in KiB (skips the pointer-chase probe)"),
    ("S5_POOL_WORKERS", "global worker-pool size override"),
    ("S5_BENCH_QUICK", "benches: 0/1 — tiny sizes for CI smoke runs"),
    ("S5_BENCH_JSON", "benches: output path for the scan perf snapshot"),
    ("S5_BENCH_STEPS", "benches: step-count override for the table benches"),
    ("S5_DTYPE", "storage dtype of the planar drive planes: f32 (default) or bf16"),
    ("S5_QUEUE_CAP", "server admission-queue capacity in requests (full queue sheds)"),
    ("S5_REQ_DEADLINE_MS", "server default per-request deadline in ms (0/unset = none)"),
    ("S5_ENVCFG_TEST_NEVER_SET", "(tests only) a name no environment ever sets"),
];
// s5:env-registry-end

/// Strictly parse one override value: a non-negative decimal integer,
/// with surrounding ASCII whitespace tolerated. Returns a human-readable
/// rejection reason otherwise.
pub fn parse_usize_strict(raw: &str) -> Result<usize, &'static str> {
    let t = raw.trim();
    if t.is_empty() {
        return Err("empty value");
    }
    if !t.bytes().all(|b| b.is_ascii_digit()) {
        return Err("not a plain non-negative decimal integer");
    }
    t.parse::<usize>().map_err(|_| "out of range for usize")
}

/// Read + strictly parse an environment override, once per process.
///
/// `cell` is the caller-owned cache (one per variable); `expect`
/// describes the expected value for the one-time warning, e.g.
/// `"a worker count"`. Returns `None` when the variable is unset **or**
/// invalid — the caller applies its default either way.
pub fn env_usize_once(
    cell: &OnceLock<Option<usize>>,
    name: &str,
    expect: &str,
) -> Option<usize> {
    *cell.get_or_init(|| {
        let raw = match std::env::var(name) {
            Ok(v) => v,
            Err(std::env::VarError::NotPresent) => return None,
            Err(std::env::VarError::NotUnicode(_)) => {
                eprintln!("{name} is not valid UTF-8; expected {expect} — using the default");
                return None;
            }
        };
        match parse_usize_strict(&raw) {
            Ok(n) => Some(n),
            Err(why) => {
                eprintln!("{name}={raw:?} ignored ({why}); expected {expect} — using the default");
                None
            }
        }
    })
}

/// Read a boolean toggle, once per process. Accepts exactly `0` / `1`
/// (surrounding whitespace tolerated) — same strictness contract as
/// [`env_usize_once`]: anything else warns once on stderr and returns
/// `None` so the caller's default applies (`S5_BENCH_QUICK=yes` silently
/// running the full bench matrix would be the quiet-misconfiguration bug
/// all over again).
pub fn env_flag_once(cell: &OnceLock<Option<bool>>, name: &str) -> Option<bool> {
    *cell.get_or_init(|| {
        let raw = match std::env::var(name) {
            Ok(v) => v,
            Err(std::env::VarError::NotPresent) => return None,
            Err(std::env::VarError::NotUnicode(_)) => {
                eprintln!("{name} is not valid UTF-8; expected 0 or 1 — using the default");
                return None;
            }
        };
        match raw.trim() {
            "0" => Some(false),
            "1" => Some(true),
            _ => {
                eprintln!("{name}={raw:?} ignored; expected 0 or 1 — using the default");
                None
            }
        }
    })
}

/// Strictly parse one choice-valued override: the trimmed value must
/// equal one of `choices` exactly (case-sensitive — the accepted spellings
/// are part of the contract, like the 0/1 flags). Returns the index into
/// `choices`, or a human-readable rejection reason.
pub fn parse_choice_strict(raw: &str, choices: &[&str]) -> Result<usize, &'static str> {
    let t = raw.trim();
    if t.is_empty() {
        return Err("empty value");
    }
    choices.iter().position(|c| *c == t).ok_or("not one of the accepted values")
}

/// Read + strictly parse a choice-valued override, once per process.
/// Same contract as [`env_usize_once`]: `None` when unset **or** invalid
/// (after a one-time stderr warning naming the accepted set), so the
/// caller's default applies — `S5_DTYPE=fp16` silently serving f32 would
/// be the quiet-misconfiguration bug all over again. `Some(i)` indexes
/// into `choices`.
pub fn env_choice_once(
    cell: &OnceLock<Option<usize>>,
    name: &str,
    choices: &[&str],
) -> Option<usize> {
    *cell.get_or_init(|| {
        let raw = match std::env::var(name) {
            Ok(v) => v,
            Err(std::env::VarError::NotPresent) => return None,
            Err(std::env::VarError::NotUnicode(_)) => {
                eprintln!(
                    "{name} is not valid UTF-8; expected one of {choices:?} — using the default"
                );
                return None;
            }
        };
        match parse_choice_strict(&raw, choices) {
            Ok(i) => Some(i),
            Err(why) => {
                eprintln!(
                    "{name}={raw:?} ignored ({why}); expected one of {choices:?} — using the default"
                );
                None
            }
        }
    })
}

/// Is the variable present in the environment at all (any value)?
/// For tests and diagnostics that only need to know whether an override
/// is active — keeps raw `std::env::var` probes out of the rest of the
/// crate (lint L2).
pub fn is_set(name: &str) -> bool {
    std::env::var_os(name).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_plain_decimals() {
        assert_eq!(parse_usize_strict("0"), Ok(0));
        assert_eq!(parse_usize_strict("7"), Ok(7));
        assert_eq!(parse_usize_strict("4096"), Ok(4096));
        assert_eq!(parse_usize_strict("  12 "), Ok(12));
        assert_eq!(parse_usize_strict("\t3\n"), Ok(3));
    }

    #[test]
    fn rejects_everything_else() {
        for bad in [
            "", "  ", "-1", "+1", "1.5", "0x10", "1e3", "12k", "two", "1 2", "∞",
        ] {
            assert!(
                parse_usize_strict(bad).is_err(),
                "{bad:?} should be rejected"
            );
        }
        // out of range for usize (u64::MAX * 10)
        assert_eq!(
            parse_usize_strict("184467440737095516150"),
            Err("out of range for usize")
        );
    }

    #[test]
    fn unset_variable_falls_back_without_poisoning_the_cache() {
        // A variable that is never set in any test environment: the read
        // caches None and later reads stay None.
        static CELL: OnceLock<Option<usize>> = OnceLock::new();
        assert_eq!(
            env_usize_once(&CELL, "S5_ENVCFG_TEST_NEVER_SET", "a number"),
            None
        );
        assert_eq!(
            env_usize_once(&CELL, "S5_ENVCFG_TEST_NEVER_SET", "a number"),
            None
        );
    }

    #[test]
    fn choice_parser_accepts_exact_spellings_only() {
        const DTYPES: &[&str] = &["f32", "bf16"];
        assert_eq!(parse_choice_strict("f32", DTYPES), Ok(0));
        assert_eq!(parse_choice_strict("bf16", DTYPES), Ok(1));
        assert_eq!(parse_choice_strict("  bf16 ", DTYPES), Ok(1), "whitespace tolerated");
        assert_eq!(parse_choice_strict("", DTYPES), Err("empty value"));
        for bad in ["BF16", "f16", "fp32", "bf 16", "bf16,f32", "2"] {
            assert_eq!(
                parse_choice_strict(bad, DTYPES),
                Err("not one of the accepted values"),
                "{bad:?} should be rejected"
            );
        }
    }

    #[test]
    fn choice_read_on_an_unset_variable_falls_back() {
        // The invalid-*set*-value path is pinned through the pure parser
        // above (mutating the process environment would race parallel
        // tests); the unset path caches None like the usize reader.
        static CELL: OnceLock<Option<usize>> = OnceLock::new();
        let choices = ["f32", "bf16"];
        assert_eq!(env_choice_once(&CELL, "S5_ENVCFG_TEST_NEVER_SET", &choices), None);
        assert_eq!(env_choice_once(&CELL, "S5_ENVCFG_TEST_NEVER_SET", &choices), None);
    }

    #[test]
    fn flag_and_presence_probes_on_an_unset_variable() {
        static CELL: OnceLock<Option<bool>> = OnceLock::new();
        assert_eq!(env_flag_once(&CELL, "S5_ENVCFG_TEST_NEVER_SET"), None);
        assert_eq!(env_flag_once(&CELL, "S5_ENVCFG_TEST_NEVER_SET"), None);
        assert!(!is_set("S5_ENVCFG_TEST_NEVER_SET"));
        // The registry lists every knob exactly once.
        let mut names: Vec<&str> = ENV_REGISTRY.iter().map(|(n, _)| *n).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate registry entries");
    }
}
