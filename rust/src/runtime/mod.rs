//! PJRT runtime: load and execute the AOT artifacts.
//!
//! The compile path (`make artifacts`) leaves, per preset:
//!   `<p>_{fwd,train}.hlo.txt`, `<p>_{fwd,train}.manifest.txt`,
//!   `<p>_init.npz`.
//!
//! [`manifest`] parses the argument-order manifests, `artifact` compiles
//! the HLO text on the PJRT CPU client and runs it, `params` manages the
//! named parameter store (npz in, npz out for checkpoints). HLO **text** is
//! the interchange format — see DESIGN.md and /opt/xla-example/README.md.

//!
//! The PJRT execution half (`artifact`, `params`) needs the `xla` FFI
//! crate and is fenced behind the `pjrt` feature; the manifest parser and
//! the pure-Rust npz store ([`npz`]) are plain data and always available —
//! the native engine uses them for `s5 info` and for serving
//! `<preset>_init.npz` / trained checkpoints without PJRT.

//!
//! [`pool`] is runtime in the other sense: the process-wide persistent
//! worker pool and the [`pool::Executor`] dispatch handle every parallel
//! stage of the native engine runs on (no PJRT involved; always
//! available). [`envcfg`] centralizes the strict, warn-once parsing of
//! the `S5_*` environment overrides the runtime knobs read.

#[cfg(feature = "pjrt")]
pub mod artifact;
pub mod envcfg;
pub mod manifest;
pub mod npz;
#[cfg(feature = "pjrt")]
pub mod params;
pub mod pool;

#[cfg(feature = "pjrt")]
pub use artifact::{Artifact, Client};
pub use manifest::{Dtype, Manifest, TensorSpec};
pub use npz::NpzStore;
#[cfg(feature = "pjrt")]
pub use params::ParamStore;
pub use pool::{Executor, WorkerPool};
