//! PJRT runtime: load and execute the AOT artifacts.
//!
//! The compile path (`make artifacts`) leaves, per preset:
//!   `<p>_{fwd,train}.hlo.txt`, `<p>_{fwd,train}.manifest.txt`,
//!   `<p>_init.npz`.
//!
//! [`manifest`] parses the argument-order manifests, [`artifact`] compiles
//! the HLO text on the PJRT CPU client and runs it, [`params`] manages the
//! named parameter store (npz in, npz out for checkpoints). HLO **text** is
//! the interchange format — see DESIGN.md and /opt/xla-example/README.md.

pub mod artifact;
pub mod manifest;
pub mod params;

pub use artifact::{Artifact, Client};
pub use manifest::{Dtype, Manifest, TensorSpec};
pub use params::ParamStore;
