//! Artifact manifests: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! A manifest pins the *flattened argument order* of the lowered jit
//! function (HLO parameter i ↔ `input i <name> <dtype> <dims>`), the output
//! tuple layout, and the model hyperparameters (`meta` lines). The runtime
//! refuses to execute with mismatched shapes, which turns silent
//! misalignment into loud errors.

use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

/// Element type of a tensor crossing the boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> anyhow::Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype tag {other:?}"),
        })
    }
}

/// One input or output tensor slot.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub index: usize,
    pub name: String,
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elem_count(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// Parsed manifest for one artifact graph.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifact: String,
    pub kind: String,
    pub meta: BTreeMap<String, String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn parse_dims(s: &str) -> anyhow::Result<Vec<usize>> {
    if s == "-" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect()
}

impl Manifest {
    /// Parse `<name>.manifest.txt`.
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let mut artifact = String::new();
        let mut kind = String::new();
        let mut meta = BTreeMap::new();
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.is_empty() {
                continue;
            }
            match parts[0] {
                "artifact" => artifact = parts[1].to_string(),
                "kind" => kind = parts[1].to_string(),
                "meta" => {
                    if parts.len() >= 3 {
                        meta.insert(parts[1].to_string(), parts[2..].join(" "));
                    }
                }
                "input" | "output" => {
                    if parts.len() != 5 {
                        bail!("line {}: malformed tensor line: {line:?}", ln + 1);
                    }
                    let spec = TensorSpec {
                        index: parts[1].parse()?,
                        name: parts[2].to_string(),
                        dtype: Dtype::parse(parts[3])?,
                        dims: parse_dims(parts[4])?,
                    };
                    if parts[0] == "input" {
                        inputs.push(spec);
                    } else {
                        outputs.push(spec);
                    }
                }
                other => bail!("line {}: unknown directive {other:?}", ln + 1),
            }
        }
        if artifact.is_empty() {
            bail!("manifest missing 'artifact' line");
        }
        // argument order must be dense and sorted
        for (i, spec) in inputs.iter().enumerate() {
            if spec.index != i {
                bail!("input order corrupt at {i}: got index {}", spec.index);
            }
        }
        Ok(Manifest { artifact, kind, meta, inputs, outputs })
    }

    /// Integer meta lookup.
    pub fn meta_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.meta
            .get(key)
            .with_context(|| format!("meta key {key:?} missing"))?
            .parse()
            .with_context(|| format!("meta key {key:?} not an integer"))
    }

    /// Input index by exact name.
    pub fn input_index(&self, name: &str) -> anyhow::Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("no input named {name:?} in {}", self.artifact))
    }

    /// Indices of inputs whose name starts with `prefix.` (e.g. "params").
    pub fn input_group(&self, prefix: &str) -> Vec<usize> {
        let pat = format!("{prefix}.");
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.name.starts_with(&pat))
            .map(|(i, _)| i)
            .collect()
    }

    /// Output index by exact name.
    pub fn output_index(&self, name: &str) -> anyhow::Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("no output named {name:?} in {}", self.artifact))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "artifact tiny_train\nkind classifier\nmeta classes 3\nmeta h 8\ninput 0 params.encoder.bias f32 8\ninput 1 lr f32 -\ninput 2 y i32 2\noutput 0 out.0 f32 8\noutput 1 out.3 f32 -\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifact, "tiny_train");
        assert_eq!(m.kind, "classifier");
        assert_eq!(m.meta_usize("classes").unwrap(), 3);
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.inputs[1].dims, Vec::<usize>::new());
        assert_eq!(m.inputs[2].dtype, Dtype::I32);
        assert_eq!(m.input_index("lr").unwrap(), 1);
        assert_eq!(m.input_group("params"), vec![0]);
        assert_eq!(m.output_index("out.3").unwrap(), 1);
    }

    #[test]
    fn scalar_dims_elem_count() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.inputs[1].elem_count(), 1);
        assert_eq!(m.inputs[0].elem_count(), 8);
    }

    #[test]
    fn rejects_out_of_order_inputs() {
        let bad = "artifact a\nkind k\ninput 1 x f32 2\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn rejects_unknown_directive() {
        assert!(Manifest::parse("artifact a\nbogus z\n").is_err());
    }

    #[test]
    fn rejects_missing_artifact() {
        assert!(Manifest::parse("kind k\n").is_err());
    }

    #[test]
    fn real_artifact_manifests_parse() {
        // integration with the actual build output when present
        let dir = std::path::Path::new(crate::ARTIFACTS_DIR);
        if !dir.exists() {
            return;
        }
        for entry in std::fs::read_dir(dir).unwrap() {
            let p = entry.unwrap().path();
            if p.to_string_lossy().ends_with(".manifest.txt") {
                let m = Manifest::load(&p).unwrap_or_else(|e| panic!("{p:?}: {e}"));
                assert!(!m.inputs.is_empty(), "{p:?}");
            }
        }
    }
}
