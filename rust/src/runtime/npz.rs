//! Pure-Rust npz (zip-of-npy) reading and writing — no PJRT, no crates.
//!
//! The compile path exports `<preset>_init.npz` and the trainer writes
//! checkpoints in the same format; historically only the `pjrt`-gated
//! `ParamStore` (backed by the `xla` and `zip` crates) could read them, so
//! `serve --engine native` had no access to trained weights. This module
//! lifts npz I/O out of the feature gate:
//!
//! * [`NpzStore`] — an ordered name → tensor map with
//!   [`NpzStore::load`]/[`NpzStore::save`] round-tripping through the
//!   exact on-disk format `numpy.savez` produces (STORED zip entries, npy
//!   v1.0 little-endian C-order payloads).
//! * `npy_header` — the shared npy header serializer (also used by the
//!   pjrt checkpoint writer, so both writers emit identical files).
//!
//! Only STORED (uncompressed) zip members are supported — which is what
//! `numpy.savez` and both of our writers emit; `savez_compressed` archives
//! are rejected with a pointed error. Loading converts member dtypes to
//! the native stack's compute precision: `<f8` downcasts and `<f2` (IEEE
//! binary16, from mixed-precision trainers) widens to f32; writers emit
//! `<f4`/`<i4` only.

use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::Path;

/// One named tensor: dims + typed payload.
#[derive(Clone, Debug, PartialEq)]
pub struct NpzTensor {
    /// Shape; empty = scalar.
    pub dims: Vec<usize>,
    pub data: NpzData,
}

/// Typed tensor payload (the two dtypes the manifests use).
#[derive(Clone, Debug, PartialEq)]
pub enum NpzData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl NpzTensor {
    pub fn f32(dims: &[usize], data: Vec<f32>) -> NpzTensor {
        assert_eq!(dims.iter().product::<usize>().max(1), data.len());
        NpzTensor { dims: dims.to_vec(), data: NpzData::F32(data) }
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> NpzTensor {
        assert_eq!(dims.iter().product::<usize>().max(1), data.len());
        NpzTensor { dims: dims.to_vec(), data: NpzData::I32(data) }
    }

    /// The f32 payload, if this tensor is f32.
    pub fn f32s(&self) -> Option<&[f32]> {
        match &self.data {
            NpzData::F32(v) => Some(v),
            NpzData::I32(_) => None,
        }
    }

    pub fn elem_count(&self) -> usize {
        match &self.data {
            NpzData::F32(v) => v.len(),
            NpzData::I32(v) => v.len(),
        }
    }
}

/// Ordered name → tensor map backed by npz files; the native-stack
/// counterpart of the pjrt `ParamStore`.
#[derive(Default)]
pub struct NpzStore {
    entries: BTreeMap<String, NpzTensor>,
}

impl NpzStore {
    pub fn new() -> NpzStore {
        NpzStore::default()
    }

    /// Load every tensor from an npz file.
    pub fn load(path: &Path) -> anyhow::Result<NpzStore> {
        let bytes = std::fs::read(path).with_context(|| format!("reading npz {path:?}"))?;
        let mut entries = BTreeMap::new();
        for (name, npy) in zip_entries(&bytes).with_context(|| format!("parsing {path:?}"))? {
            let tensor =
                parse_npy(npy).with_context(|| format!("parsing member {name:?} of {path:?}"))?;
            let name = name.strip_suffix(".npy").unwrap_or(&name).to_string();
            entries.insert(name, tensor);
        }
        Ok(NpzStore { entries })
    }

    /// Save every tensor to an npz file (STORED zip of npy members,
    /// matching `numpy.savez`).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        // plain zip32: no zip64 records, so sizes/offsets must fit u32 and
        // the member count u16 — fail loudly instead of wrapping silently
        anyhow::ensure!(
            self.entries.len() <= u16::MAX as usize,
            "npz member count {} exceeds the zip32 limit",
            self.entries.len()
        );
        let mut zip = Vec::new();
        let mut central = Vec::new();
        let mut count = 0u16;
        for (name, tensor) in &self.entries {
            let member = format!("{name}.npy");
            let payload = npy_bytes(tensor);
            anyhow::ensure!(
                payload.len() <= u32::MAX as usize && zip.len() <= u32::MAX as usize,
                "npz member {member:?} exceeds the zip32 4 GiB limit"
            );
            let crc = crc32(&payload);
            let offset = zip.len() as u32;
            write_local_header(&mut zip, &member, crc, payload.len() as u32);
            zip.extend_from_slice(&payload);
            write_central_header(&mut central, &member, crc, payload.len() as u32, offset);
            count += 1;
        }
        anyhow::ensure!(
            zip.len() + central.len() <= u32::MAX as usize,
            "npz archive exceeds the zip32 4 GiB limit"
        );
        let cd_offset = zip.len() as u32;
        let cd_size = central.len() as u32;
        zip.extend_from_slice(&central);
        // end of central directory
        zip.extend_from_slice(&0x06054b50u32.to_le_bytes());
        zip.extend_from_slice(&[0u8; 4]); // disk numbers
        zip.extend_from_slice(&count.to_le_bytes());
        zip.extend_from_slice(&count.to_le_bytes());
        zip.extend_from_slice(&cd_size.to_le_bytes());
        zip.extend_from_slice(&cd_offset.to_le_bytes());
        zip.extend_from_slice(&[0u8; 2]); // comment length
        std::fs::write(path, zip).with_context(|| format!("writing npz {path:?}"))
    }

    pub fn insert(&mut self, name: &str, tensor: NpzTensor) {
        self.entries.insert(name.to_string(), tensor);
    }

    pub fn insert_f32(&mut self, name: &str, dims: &[usize], data: Vec<f32>) {
        self.insert(name, NpzTensor::f32(dims, data));
    }

    pub fn get(&self, name: &str) -> Option<&NpzTensor> {
        self.entries.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total element count across all tensors.
    pub fn total_elems(&self) -> usize {
        self.entries.values().map(|t| t.elem_count()).sum()
    }
}

// ---------------------------------------------------------------------------
// npy serialization (shared with the pjrt checkpoint writer)
// ---------------------------------------------------------------------------

/// Serialize the npy v1.0 preamble (magic + version + padded header dict)
/// for a C-order little-endian array of `descr` (`"<f4"` / `"<i4"`) and
/// shape `dims`. The payload follows immediately after these bytes.
pub(crate) fn npy_header(descr: &str, dims: &[usize]) -> Vec<u8> {
    let shape_str = match dims.len() {
        0 => "()".to_string(),
        1 => format!("({},)", dims[0]),
        _ => format!(
            "({})",
            dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header =
        format!("{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_str}, }}");
    // total preamble (magic 6 + ver 2 + len 2 + header) must be 64-aligned
    let base = 6 + 2 + 2;
    let pad = (64 - (base + header.len() + 1) % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut out = Vec::with_capacity(base + header.len());
    out.extend_from_slice(b"\x93NUMPY");
    out.push(1);
    out.push(0);
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out
}

/// One tensor as complete npy bytes (header + little-endian payload).
fn npy_bytes(tensor: &NpzTensor) -> Vec<u8> {
    let (descr, payload): (&str, Vec<u8>) = match &tensor.data {
        NpzData::F32(v) => ("<f4", v.iter().flat_map(|x| x.to_le_bytes()).collect()),
        NpzData::I32(v) => ("<i4", v.iter().flat_map(|x| x.to_le_bytes()).collect()),
    };
    let mut out = npy_header(descr, &tensor.dims);
    out.extend_from_slice(&payload);
    out
}

/// Parse one npy member into a tensor.
fn parse_npy(bytes: &[u8]) -> anyhow::Result<NpzTensor> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("not an npy file");
    }
    let (hlen, start) = match bytes[6] {
        1 => (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10),
        2 | 3 => {
            if bytes.len() < 12 {
                bail!("truncated npy v2 header");
            }
            (
                u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
                12,
            )
        }
        v => bail!("unsupported npy major version {v}"),
    };
    if bytes.len() < start + hlen {
        bail!("truncated npy header");
    }
    let header = std::str::from_utf8(&bytes[start..start + hlen])
        .context("npy header is not utf-8")?;
    let descr = dict_str_value(header, "descr").context("npy header missing descr")?;
    let fortran = dict_raw_value(header, "fortran_order")
        .context("npy header missing fortran_order")?;
    if fortran.starts_with("True") {
        bail!("fortran-order npy arrays are not supported");
    }
    let shape_src = dict_raw_value(header, "shape").context("npy header missing shape")?;
    let dims = parse_shape(&shape_src)?;
    let n: usize = dims.iter().product::<usize>().max(1);
    let payload = &bytes[start + hlen..];

    let data = match descr.as_str() {
        "<f4" | "=f4" => {
            if payload.len() < n * 4 {
                bail!("npy payload too short for {n} f32 values");
            }
            NpzData::F32(
                payload[..n * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )
        }
        "<f2" | "=f2" => {
            // IEEE binary16 checkpoints (mixed-precision trainers) widen
            // to f32 on load — exact, since every f16 value is
            // representable in f32. The native stack's own low-precision
            // format is bf16 and lives only in the runtime drive planes
            // (see the crate-level "Precision model" docs), never on disk.
            if payload.len() < n * 2 {
                bail!("npy payload too short for {n} f16 values");
            }
            NpzData::F32(
                payload[..n * 2]
                    .chunks_exact(2)
                    .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
                    .collect(),
            )
        }
        "<f8" => {
            // f64 checkpoints downcast (the native stack computes in f32)
            if payload.len() < n * 8 {
                bail!("npy payload too short for {n} f64 values");
            }
            NpzData::F32(
                payload[..n * 8]
                    .chunks_exact(8)
                    .map(|c| {
                        f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
                    })
                    .collect(),
            )
        }
        "<i4" | "=i4" => {
            if payload.len() < n * 4 {
                bail!("npy payload too short for {n} i32 values");
            }
            NpzData::I32(
                payload[..n * 4]
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )
        }
        other => bail!("unsupported npy dtype {other:?} (want <f2/<f4/<f8/<i4)"),
    };
    Ok(NpzTensor { dims, data })
}

/// Widen one IEEE binary16 bit pattern to f32 — exact for every input.
/// Subnormals scale the raw mantissa by 2⁻²⁴, infinities and NaNs map to
/// their f32 counterparts (NaN payload preserved in the top mantissa
/// bits), normals rebias the exponent 15 → 127.
fn f16_bits_to_f32(bits: u16) -> f32 {
    let sign = ((bits >> 15) as u32) << 31;
    let exp = (bits >> 10) & 0x1f;
    let mant = (bits & 0x3ff) as u32;
    match exp {
        // ±zero and subnormals: magnitude = mant · 2⁻²⁴ (exact in f32)
        0 => f32::from_bits(sign | (mant as f32 * 2.0f32.powi(-24)).to_bits()),
        0x1f => f32::from_bits(sign | 0x7f80_0000 | (mant << 13)),
        _ => f32::from_bits(sign | ((exp as u32 + 112) << 23) | (mant << 13)),
    }
}

/// Pull the quoted string value of `key` out of an npy header dict.
fn dict_str_value(header: &str, key: &str) -> Option<String> {
    let raw = dict_raw_value(header, key)?;
    let raw = raw.trim_start();
    let quote = raw.chars().next()?;
    if quote != '\'' && quote != '"' {
        return None;
    }
    let rest = &raw[1..];
    rest.find(quote).map(|end| rest[..end].to_string())
}

/// Pull the raw (up to the next top-level `,` or `}`) value of `key`.
fn dict_raw_value(header: &str, key: &str) -> Option<String> {
    let pat = format!("'{key}':");
    let at = header.find(&pat)?;
    let rest = header[at + pat.len()..].trim_start();
    let mut depth = 0usize;
    let mut out = String::new();
    for ch in rest.chars() {
        match ch {
            '(' | '[' => depth += 1,
            ')' | ']' => {
                if depth == 0 {
                    break;
                }
                depth -= 1;
                out.push(ch);
                continue;
            }
            ',' | '}' if depth == 0 => break,
            _ => {}
        }
        out.push(ch);
    }
    Some(out.trim().to_string())
}

/// Parse a python shape tuple like `(3, 4)` / `(5,)` / `()`.
fn parse_shape(src: &str) -> anyhow::Result<Vec<usize>> {
    let inner = src
        .trim()
        .strip_prefix('(')
        .and_then(|s| s.strip_suffix(')'))
        .with_context(|| format!("bad npy shape {src:?}"))?;
    let mut dims = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        dims.push(part.parse::<usize>().with_context(|| format!("bad npy dim {part:?}"))?);
    }
    Ok(dims)
}

// ---------------------------------------------------------------------------
// Minimal zip container (STORED members only)
// ---------------------------------------------------------------------------

/// Iterate `(member_name, member_bytes)` of a zip archive via its central
/// directory (so data-descriptor local headers are handled too).
fn zip_entries(bytes: &[u8]) -> anyhow::Result<Vec<(String, &[u8])>> {
    // find the end-of-central-directory record from the back
    let eocd_sig = 0x06054b50u32.to_le_bytes();
    let scan_from = bytes.len().saturating_sub(22 + 65536);
    let eocd = (scan_from..bytes.len().saturating_sub(21))
        .rev()
        .find(|&i| bytes[i..i + 4] == eocd_sig)
        .context("no zip end-of-central-directory record (not a zip file?)")?;
    let count = read_u16(bytes, eocd + 10)? as usize;
    let mut at = read_u32(bytes, eocd + 16)? as usize;

    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        if read_u32(bytes, at)? != 0x02014b50 {
            bail!("bad central directory entry at {at}");
        }
        let method = read_u16(bytes, at + 10)?;
        let comp_size = read_u32(bytes, at + 20)? as usize;
        let name_len = read_u16(bytes, at + 28)? as usize;
        let extra_len = read_u16(bytes, at + 30)? as usize;
        let comment_len = read_u16(bytes, at + 32)? as usize;
        let local_at = read_u32(bytes, at + 42)? as usize;
        let name = std::str::from_utf8(
            bytes.get(at + 46..at + 46 + name_len).context("truncated entry name")?,
        )
        .context("non-utf8 member name")?
        .to_string();
        if method != 0 {
            bail!(
                "zip member {name:?} uses compression method {method}; only STORED \
                 archives are supported (was this written by numpy.savez_compressed?)"
            );
        }
        // the local header carries its own (possibly different) extra field
        if read_u32(bytes, local_at)? != 0x04034b50 {
            bail!("bad local header for member {name:?}");
        }
        let lname = read_u16(bytes, local_at + 26)? as usize;
        let lextra = read_u16(bytes, local_at + 28)? as usize;
        let data_at = local_at + 30 + lname + lextra;
        let data = bytes
            .get(data_at..data_at + comp_size)
            .with_context(|| format!("truncated data for member {name:?}"))?;
        out.push((name, data));
        at += 46 + name_len + extra_len + comment_len;
    }
    Ok(out)
}

fn read_u16(bytes: &[u8], at: usize) -> anyhow::Result<u16> {
    let b = bytes.get(at..at + 2).context("truncated zip record")?;
    Ok(u16::from_le_bytes([b[0], b[1]]))
}

fn read_u32(bytes: &[u8], at: usize) -> anyhow::Result<u32> {
    let b = bytes.get(at..at + 4).context("truncated zip record")?;
    Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

fn write_local_header(out: &mut Vec<u8>, name: &str, crc: u32, size: u32) {
    out.extend_from_slice(&0x04034b50u32.to_le_bytes());
    out.extend_from_slice(&20u16.to_le_bytes()); // version needed
    out.extend_from_slice(&[0u8; 2]); // flags
    out.extend_from_slice(&[0u8; 2]); // method: STORED
    out.extend_from_slice(&[0u8; 4]); // mod time/date
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(&size.to_le_bytes()); // compressed
    out.extend_from_slice(&size.to_le_bytes()); // uncompressed
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(&[0u8; 2]); // extra length
    out.extend_from_slice(name.as_bytes());
}

fn write_central_header(out: &mut Vec<u8>, name: &str, crc: u32, size: u32, offset: u32) {
    out.extend_from_slice(&0x02014b50u32.to_le_bytes());
    out.extend_from_slice(&20u16.to_le_bytes()); // version made by
    out.extend_from_slice(&20u16.to_le_bytes()); // version needed
    out.extend_from_slice(&[0u8; 2]); // flags
    out.extend_from_slice(&[0u8; 2]); // method: STORED
    out.extend_from_slice(&[0u8; 4]); // mod time/date
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(&size.to_le_bytes());
    out.extend_from_slice(&size.to_le_bytes());
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(&[0u8; 2]); // extra
    out.extend_from_slice(&[0u8; 2]); // comment
    out.extend_from_slice(&[0u8; 2]); // disk number
    out.extend_from_slice(&[0u8; 2]); // internal attrs
    out.extend_from_slice(&[0u8; 4]); // external attrs
    out.extend_from_slice(&offset.to_le_bytes());
    out.extend_from_slice(name.as_bytes());
}

/// CRC-32 (IEEE, reflected) — required by the zip format. Public so the
/// golden-fixture harness (`tests/parity_fixtures.rs`) can verify the
/// committed fixture files against their MANIFEST checksums with the
/// same polynomial Python's `zlib.crc32` uses.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &byte in data {
        crc ^= byte as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB88320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("s5_npz_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn npy_header_is_64_aligned() {
        for dims in [vec![], vec![5], vec![2, 3], vec![4, 1, 7]] {
            let h = npy_header("<f4", &dims);
            assert_eq!(h.len() % 64, 0, "dims {dims:?}");
            assert_eq!(&h[..6], b"\x93NUMPY");
        }
    }

    #[test]
    fn store_roundtrip_preserves_tensors() {
        let mut store = NpzStore::new();
        store.insert_f32("params.a", &[2, 3], vec![1.0, -2.5, 3.25, 0.0, 5.0, -6.125]);
        store.insert_f32("params.b", &[4], vec![0.5; 4]);
        store.insert("steps", NpzTensor::i32(&[], vec![42]));
        let path = tmp("roundtrip.npz");
        store.save(&path).unwrap();
        let loaded = NpzStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded.get("params.a"), store.get("params.a"));
        assert_eq!(loaded.get("params.b"), store.get("params.b"));
        assert_eq!(loaded.get("steps"), store.get("steps"));
        assert_eq!(loaded.total_elems(), 11);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parse_npy_rejects_garbage_and_fortran() {
        assert!(parse_npy(b"not an npy").is_err());
        // hand-build a fortran-order header
        let mut h = npy_header("<f4", &[2]);
        let pos = h.windows(5).position(|w| w == b"False").unwrap();
        h[pos..pos + 5].copy_from_slice(b"True,");
        h.extend_from_slice(&[0u8; 8]);
        assert!(parse_npy(&h).is_err());
    }

    #[test]
    fn f16_widening_matches_reference_bit_patterns() {
        let patterns: [(u16, f32); 8] = [
            (0x3C00, 1.0),
            (0xC000, -2.0),
            (0x3555, 0.25 * (1.0 + 341.0 / 1024.0)), // ≈ 1/3, exact widen
            (0x7BFF, 65504.0),                       // largest finite f16
            (0x0001, 1.0 / 16_777_216.0),            // smallest subnormal
            (0x03FF, 1023.0 / 16_777_216.0),         // largest subnormal
            (0x8000, -0.0),
            (0x7C00, f32::INFINITY),
        ];
        for (bits, want) in patterns {
            assert_eq!(
                f16_bits_to_f32(bits).to_bits(),
                want.to_bits(),
                "pattern {bits:#06x}"
            );
        }
        // NaN stays NaN, payload shifted into the top f32 mantissa bits
        assert!(f16_bits_to_f32(0x7E01).is_nan());
        assert!(f16_bits_to_f32(0xFE00).is_nan());
    }

    #[test]
    fn f16_members_load_widened_and_roundtrip_as_f32() {
        // hand-build a one-member STORED archive with an `<f2` payload
        // (our writers never emit f16 — reading is import-compat only)
        let mut payload = npy_header("<f2", &[3]);
        for bits in [0x3C00u16, 0xC000, 0x7BFF] {
            payload.extend_from_slice(&bits.to_le_bytes());
        }
        let crc = crc32(&payload);
        let mut zip = Vec::new();
        let mut central = Vec::new();
        write_local_header(&mut zip, "w.npy", crc, payload.len() as u32);
        zip.extend_from_slice(&payload);
        write_central_header(&mut central, "w.npy", crc, payload.len() as u32, 0);
        let cd_offset = zip.len() as u32;
        let cd_size = central.len() as u32;
        zip.extend_from_slice(&central);
        zip.extend_from_slice(&0x06054b50u32.to_le_bytes());
        zip.extend_from_slice(&[0u8; 4]);
        zip.extend_from_slice(&1u16.to_le_bytes());
        zip.extend_from_slice(&1u16.to_le_bytes());
        zip.extend_from_slice(&cd_size.to_le_bytes());
        zip.extend_from_slice(&cd_offset.to_le_bytes());
        zip.extend_from_slice(&[0u8; 2]);
        let path = tmp("f16.npz");
        std::fs::write(&path, zip).unwrap();
        let store = NpzStore::load(&path).unwrap();
        assert_eq!(store.get("w").unwrap().f32s().unwrap(), &[1.0, -2.0, 65504.0]);
        // widened members save back as plain <f4 and reload unchanged
        let path2 = tmp("f16_as_f32.npz");
        store.save(&path2).unwrap();
        let reloaded = NpzStore::load(&path2).unwrap();
        assert_eq!(reloaded.get("w"), store.get("w"));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn shape_parser_handles_tuples() {
        assert_eq!(parse_shape("()").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_shape("(5,)").unwrap(), vec![5]);
        assert_eq!(parse_shape("(2, 3, 4)").unwrap(), vec![2, 3, 4]);
        assert!(parse_shape("5").is_err());
    }

    #[test]
    fn load_rejects_non_zip() {
        let path = tmp("not_a.npz");
        std::fs::write(&path, b"hello world, definitely not a zip").unwrap();
        assert!(NpzStore::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
