//! Synthetic grayscale image classification (LRA "Image" / sCIFAR stand-in).
//!
//! Ten texture classes, each a parametric 2-D pattern (oriented gratings,
//! checkerboards, radial rings, blobs) with per-sample phase/frequency
//! jitter and additive noise, rasterized row-major into a 1-D sequence —
//! so class evidence is spread across the whole raster exactly like
//! pixel-level CIFAR.

use crate::data::{SeqExample, TaskGen};
use crate::rng::Rng;

pub struct TextureImage {
    side: usize,
}

impl TextureImage {
    pub fn new(side: usize) -> Self {
        TextureImage { side }
    }

    fn render(&self, class: usize, rng: &mut Rng) -> Vec<f32> {
        let n = self.side;
        let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
        let freq = rng.uniform_in(0.8, 1.2);
        let mut img = vec![0.0f32; n * n];
        for r in 0..n {
            for c in 0..n {
                let x = c as f64 / n as f64 - 0.5;
                let y = r as f64 / n as f64 - 0.5;
                let v = match class {
                    // oriented gratings at four angles
                    0..=3 => {
                        let ang = class as f64 * std::f64::consts::FRAC_PI_4;
                        let t = x * ang.cos() + y * ang.sin();
                        (freq * 8.0 * std::f64::consts::TAU * t / 2.0 + phase).sin()
                    }
                    // checkerboards, two scales
                    4 | 5 => {
                        let s = if class == 4 { 4.0 } else { 8.0 };
                        let cx = (x * s * freq + phase / 6.0).floor() as i64;
                        let cy = (y * s * freq).floor() as i64;
                        if (cx + cy) % 2 == 0 {
                            1.0
                        } else {
                            -1.0
                        }
                    }
                    // radial rings, two frequencies
                    6 | 7 => {
                        let rr = (x * x + y * y).sqrt();
                        let s = if class == 6 { 12.0 } else { 24.0 };
                        (s * freq * std::f64::consts::TAU * rr + phase).cos()
                    }
                    // diagonal sawtooth
                    8 => ((x + y) * freq * 6.0 + phase / 6.0).fract() * 2.0 - 1.0,
                    // gaussian blob grid
                    _ => {
                        let gx = (x * 4.0 * freq).fract() - 0.5;
                        let gy = (y * 4.0 * freq).fract() - 0.5;
                        (-(gx * gx + gy * gy) * 30.0).exp() * 2.0 - 1.0
                    }
                };
                img[r * n + c] = v as f32 + (rng.normal() as f32) * 0.25;
            }
        }
        img
    }
}

impl TaskGen for TextureImage {
    fn seq_len(&self) -> usize {
        self.side * self.side
    }

    fn d_input(&self) -> usize {
        1
    }

    fn classes(&self) -> usize {
        10
    }

    fn name(&self) -> &'static str {
        "image"
    }

    fn sample(&self, rng: &mut Rng) -> SeqExample {
        let label = rng.below(10) as i32;
        SeqExample { x: self.render(label as usize, rng), label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_range() {
        let t = TextureImage::new(32);
        let mut rng = Rng::new(0);
        let ex = t.sample(&mut rng);
        assert_eq!(ex.x.len(), 1024);
        assert!(ex.x.iter().all(|v| v.is_finite() && v.abs() < 5.0));
    }

    #[test]
    fn classes_are_distinguishable_in_pixel_space() {
        // mean intra-class distance < mean inter-class distance
        let t = TextureImage::new(16);
        let mut rng = Rng::new(1);
        let per_class = 6;
        let mut samples: Vec<(usize, Vec<f32>)> = Vec::new();
        for class in 0..10 {
            for _ in 0..per_class {
                samples.push((class, t.render(class, &mut rng)));
            }
        }
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| ((x - y) * (x - y)) as f64).sum::<f64>()
        };
        let (mut intra, mut ni) = (0.0, 0);
        let (mut inter, mut nj) = (0.0, 0);
        for i in 0..samples.len() {
            for j in (i + 1)..samples.len() {
                let d = dist(&samples[i].1, &samples[j].1);
                if samples[i].0 == samples[j].0 {
                    intra += d;
                    ni += 1;
                } else {
                    inter += d;
                    nj += 1;
                }
            }
        }
        let (intra, inter) = (intra / ni as f64, inter / nj as f64);
        assert!(intra < inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn per_sample_jitter_changes_pixels() {
        let t = TextureImage::new(16);
        let mut rng = Rng::new(2);
        let a = t.render(0, &mut rng);
        let b = t.render(0, &mut rng);
        assert_ne!(a, b);
    }
}
