//! Synthetic keyword waveforms (Speech Commands stand-in, paper §6.2).
//!
//! Each of the 35 "words" is a distinct harmonic signature: a fundamental
//! frequency plus 2 formant-like partials with a word-specific envelope,
//! embedded in noise with random amplitude/onset jitter. The headline
//! property under test is the paper's zero-shot resampling claim: a model
//! trained at the base rate transfers to **decimated** audio purely by
//! rescaling the Δ timescale input — so the generator exposes
//! [`SpeechCommands::decimate`].

use crate::data::{SeqExample, TaskGen};
use crate::rng::Rng;

pub const N_WORDS: usize = 35;

pub struct SpeechCommands {
    seq_len: usize,
}

impl SpeechCommands {
    pub fn new(seq_len: usize) -> Self {
        SpeechCommands { seq_len }
    }

    /// Word-specific spectral recipe.
    fn recipe(word: usize) -> (f64, f64, f64) {
        // fundamentals spread over [40, 180] cycles per window, two partial
        // ratios per word so neighbours stay separable
        let f0 = 40.0 + 4.0 * word as f64;
        let r1 = 1.5 + 0.1 * ((word * 7) % 10) as f64;
        let r2 = 2.5 + 0.15 * ((word * 3) % 10) as f64;
        (f0, r1, r2)
    }

    fn render(&self, word: usize, rng: &mut Rng) -> Vec<f32> {
        let l = self.seq_len;
        let (f0, r1, r2) = Self::recipe(word);
        let amp = rng.uniform_in(0.7, 1.3);
        let onset = rng.uniform_in(0.0, 0.15);
        let dur = rng.uniform_in(0.6, 0.85);
        let phase = rng.uniform_in(0.0, std::f64::consts::TAU);
        let mut x = vec![0.0f32; l];
        for (k, item) in x.iter_mut().enumerate() {
            let t = k as f64 / l as f64;
            let env = if t < onset || t > onset + dur {
                0.0
            } else {
                let u = (t - onset) / dur;
                (std::f64::consts::PI * u).sin().powi(2)
            };
            let w = std::f64::consts::TAU * f0 * t;
            let s = (w + phase).sin()
                + 0.6 * (w * r1 + 1.3 * phase).sin()
                + 0.35 * (w * r2 + 2.1 * phase).sin();
            *item = (amp * env * s + rng.normal() * 0.08) as f32;
        }
        x
    }

    /// Naive decimation by `factor` (paper Table 2's 8 kHz column).
    pub fn decimate(x: &[f32], factor: usize) -> Vec<f32> {
        x.iter().step_by(factor).copied().collect()
    }
}

impl TaskGen for SpeechCommands {
    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn d_input(&self) -> usize {
        1
    }

    fn classes(&self) -> usize {
        N_WORDS
    }

    fn name(&self) -> &'static str {
        "speech"
    }

    fn sample(&self, rng: &mut Rng) -> SeqExample {
        let label = rng.below(N_WORDS) as i32;
        SeqExample { x: self.render(label as usize, rng), label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waveform_shape_and_energy() {
        let t = SpeechCommands::new(2048);
        let ex = t.sample(&mut Rng::new(0));
        assert_eq!(ex.x.len(), 2048);
        let energy: f32 = ex.x.iter().map(|v| v * v).sum();
        assert!(energy > 10.0, "waveform should carry signal, got {energy}");
    }

    #[test]
    fn decimation_halves_length() {
        let t = SpeechCommands::new(2048);
        let ex = t.sample(&mut Rng::new(1));
        let half = SpeechCommands::decimate(&ex.x, 2);
        assert_eq!(half.len(), 1024);
        assert_eq!(half[1], ex.x[2]);
    }

    #[test]
    fn words_have_distinct_spectra() {
        // dominant FFT bin should differ between far-apart words
        use crate::fft;
        use crate::num::C64;
        let t = SpeechCommands::new(1024);
        let mut rng = Rng::new(2);
        let peak_bin = |word: usize, rng: &mut Rng| -> usize {
            let x = t.render(word, rng);
            let z: Vec<C64> = x.iter().map(|&v| C64::from_re(v as f64)).collect();
            let f = fft::fft(&z);
            (1..512)
                .max_by(|&a, &b| f[a].abs().partial_cmp(&f[b].abs()).unwrap())
                .unwrap()
        };
        let b0 = peak_bin(0, &mut rng);
        let b30 = peak_bin(30, &mut rng);
        assert!(
            (b0 as i64 - b30 as i64).unsigned_abs() > 20,
            "bins {b0} vs {b30}"
        );
    }

    #[test]
    fn all_labels_reachable() {
        let t = SpeechCommands::new(256);
        let mut rng = Rng::new(3);
        let mut seen = vec![false; N_WORDS];
        for _ in 0..600 {
            seen[t.sample(&mut rng).label as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 30);
    }
}
