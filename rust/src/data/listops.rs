//! ListOps: nested prefix-notation expressions (Nangia & Bowman 2018; LRA
//! task 1). This is a *real* generator+evaluator, not a canned corpus: it
//! samples bracketed expressions over MIN/MAX/MED/SUM-MOD with integer
//! operands, evaluates them for the label, and one-hot tokenizes.
//!
//! The long-range structure is intrinsic: the value of the outermost
//! operator depends on operands separated by the whole expression.

use crate::data::{one_hot, SeqExample, TaskGen};
use crate::rng::Rng;

/// Token vocabulary: 0..=9 digits, 10..=13 operators, 14 '[', 15 ']',
/// 16 PAD, 17 EOS — 18 tokens, matching the `listops` AOT preset d_input.
pub const VOCAB: usize = 18;
const OP_MIN: usize = 10;
const OP_MAX: usize = 11;
const OP_MED: usize = 12;
const OP_SM: usize = 13;
const LBRACK: usize = 14;
const RBRACK: usize = 15;
const PAD: usize = 16;
const EOS: usize = 17;

/// Expression tree.
enum Expr {
    Leaf(u8),
    Node(usize, Vec<Expr>), // (operator token, children)
}

impl Expr {
    fn eval(&self) -> u8 {
        match self {
            Expr::Leaf(v) => *v,
            Expr::Node(op, kids) => {
                let mut vals: Vec<u8> = kids.iter().map(|k| k.eval()).collect();
                match *op {
                    OP_MIN => *vals.iter().min().unwrap(),
                    OP_MAX => *vals.iter().max().unwrap(),
                    OP_MED => {
                        vals.sort_unstable();
                        vals[vals.len() / 2]
                    }
                    OP_SM => (vals.iter().map(|&v| v as u32).sum::<u32>() % 10) as u8,
                    _ => unreachable!(),
                }
            }
        }
    }

    fn tokens(&self, out: &mut Vec<usize>) {
        match self {
            Expr::Leaf(v) => out.push(*v as usize),
            Expr::Node(op, kids) => {
                out.push(LBRACK);
                out.push(*op);
                for k in kids {
                    k.tokens(out);
                }
                out.push(RBRACK);
            }
        }
    }
}

/// The ListOps task generator.
pub struct ListOps {
    seq_len: usize,
    max_depth: usize,
    max_args: usize,
}

impl ListOps {
    pub fn new(seq_len: usize) -> Self {
        ListOps { seq_len, max_depth: 6, max_args: 4 }
    }

    fn gen_expr(&self, rng: &mut Rng, depth: usize, budget: &mut usize) -> Expr {
        // every node consumes tokens; stop when the budget or depth runs out
        if depth >= self.max_depth || *budget < 6 || rng.coin(0.35) {
            *budget = budget.saturating_sub(1);
            return Expr::Leaf(rng.below(10) as u8);
        }
        let op = OP_MIN + rng.below(4);
        let n_args = 2 + rng.below(self.max_args - 1);
        *budget = budget.saturating_sub(3); // [ op ]
        let kids = (0..n_args)
            .map(|_| self.gen_expr(rng, depth + 1, budget))
            .collect();
        Expr::Node(op, kids)
    }
}

impl TaskGen for ListOps {
    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn d_input(&self) -> usize {
        VOCAB
    }

    fn classes(&self) -> usize {
        10
    }

    fn name(&self) -> &'static str {
        "listops"
    }

    fn sample(&self, rng: &mut Rng) -> SeqExample {
        // sample until the tokenized expression fits (leaving room for EOS)
        loop {
            let mut budget = self.seq_len - 1;
            let expr = self.gen_expr(rng, 0, &mut budget);
            let mut toks = Vec::new();
            expr.tokens(&mut toks);
            if toks.len() + 1 > self.seq_len {
                continue;
            }
            let label = expr.eval() as i32;
            toks.push(EOS);
            while toks.len() < self.seq_len {
                toks.push(PAD);
            }
            let mut x = vec![0.0f32; self.seq_len * VOCAB];
            for (k, &t) in toks.iter().enumerate() {
                one_hot(t, VOCAB, &mut x[k * VOCAB..(k + 1) * VOCAB]);
            }
            return SeqExample { x, label };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn eval_known_expression() {
        // [MAX 2 9 [MIN 4 7] 0] = 9
        let e = Expr::Node(
            OP_MAX,
            vec![
                Expr::Leaf(2),
                Expr::Leaf(9),
                Expr::Node(OP_MIN, vec![Expr::Leaf(4), Expr::Leaf(7)]),
                Expr::Leaf(0),
            ],
        );
        assert_eq!(e.eval(), 9);
    }

    #[test]
    fn eval_sum_mod() {
        let e = Expr::Node(OP_SM, vec![Expr::Leaf(7), Expr::Leaf(8)]);
        assert_eq!(e.eval(), 5);
    }

    #[test]
    fn eval_median() {
        let e = Expr::Node(
            OP_MED,
            vec![Expr::Leaf(9), Expr::Leaf(1), Expr::Leaf(5)],
        );
        assert_eq!(e.eval(), 5);
    }

    #[test]
    fn prop_samples_wellformed() {
        let task = ListOps::new(256);
        prop::check("listops wellformed", 50, |g| {
            let ex = task.sample(g);
            prop::ensure(ex.x.len() == 256 * VOCAB)?;
            prop::ensure((0..10).contains(&ex.label))?;
            // each row is exactly one-hot
            for k in 0..256 {
                let row = &ex.x[k * VOCAB..(k + 1) * VOCAB];
                let s: f32 = row.iter().sum();
                prop::ensure_msg((s - 1.0).abs() < 1e-6, format!("row {k} sum {s}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_brackets_balanced() {
        let task = ListOps::new(256);
        prop::check("listops brackets", 50, |g| {
            let ex = task.sample(g);
            let mut depth: i64 = 0;
            for k in 0..256 {
                let row = &ex.x[k * VOCAB..(k + 1) * VOCAB];
                let tok = row.iter().position(|&v| v == 1.0).unwrap();
                match tok {
                    LBRACK => depth += 1,
                    RBRACK => depth -= 1,
                    _ => {}
                }
                prop::ensure(depth >= 0)?;
            }
            prop::ensure_msg(depth == 0, format!("unbalanced: {depth}"))
        });
    }

    #[test]
    fn labels_cover_many_classes() {
        let task = ListOps::new(512);
        let mut rng = Rng::new(42);
        let mut seen = [false; 10];
        for _ in 0..200 {
            seen[task.sample(&mut rng).label as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 6, "{seen:?}");
    }
}
