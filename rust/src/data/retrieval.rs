//! Synthetic citation matching (LRA "Retrieval" / AAN stand-in).
//!
//! Two token sequences must be classified as *equivalent* (they cite the
//! same underlying work) or not. Equivalent pairs share a sparse
//! "signature" — a set of rare identifier tokens scattered independently
//! through both documents with different filler; non-equivalent pairs carry
//! different signatures. As in the AAN task, each document must be encoded
//! independently (two-tower model, §G.3.3) so the signature has to survive
//! compression into a single vector.

use crate::data::{one_hot, SeqExample, TaskGen};
use crate::rng::Rng;

pub const VOCAB: usize = 32;
const SIG_TOKENS: usize = 12; // tokens 1..=12 form signatures
const FILLER_START: usize = 13;
const SIG_SIZE: usize = 3;

/// A pair example: both sequences plus the equivalence label.
#[derive(Clone, Debug)]
pub struct PairExample {
    pub x1: Vec<f32>,
    pub x2: Vec<f32>,
    pub label: i32,
}

pub struct Retrieval {
    seq_len: usize,
}

impl Retrieval {
    pub fn new(seq_len: usize) -> Self {
        Retrieval { seq_len }
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn d_input(&self) -> usize {
        VOCAB
    }

    pub fn classes(&self) -> usize {
        2
    }

    fn signature(rng: &mut Rng) -> Vec<usize> {
        let mut sig = rng.choose_sorted(SIG_TOKENS, SIG_SIZE);
        for s in sig.iter_mut() {
            *s += 1; // tokens 1..=SIG_TOKENS
        }
        sig
    }

    fn doc(&self, rng: &mut Rng, sig: &[usize]) -> Vec<f32> {
        let mut toks: Vec<usize> = (0..self.seq_len)
            .map(|_| FILLER_START + rng.below(VOCAB - FILLER_START))
            .collect();
        // plant each signature token 2-3 times at random positions
        for &s in sig {
            let reps = 2 + rng.below(2);
            for _ in 0..reps {
                toks[rng.below(self.seq_len)] = s;
            }
        }
        let mut x = vec![0.0f32; self.seq_len * VOCAB];
        for (k, &t) in toks.iter().enumerate() {
            one_hot(t, VOCAB, &mut x[k * VOCAB..(k + 1) * VOCAB]);
        }
        x
    }

    /// Sample a document pair.
    pub fn sample_pair(&self, rng: &mut Rng) -> PairExample {
        let label = rng.below(2) as i32;
        let sig1 = Self::signature(rng);
        let sig2 = if label == 1 {
            sig1.clone()
        } else {
            // resample until the signature differs
            loop {
                let s = Self::signature(rng);
                if s != sig1 {
                    break s;
                }
            }
        };
        PairExample {
            x1: self.doc(rng, &sig1),
            x2: self.doc(rng, &sig2),
            label,
        }
    }
}

impl TaskGen for Retrieval {
    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn d_input(&self) -> usize {
        VOCAB
    }

    fn classes(&self) -> usize {
        2
    }

    fn name(&self) -> &'static str {
        "retrieval"
    }

    /// Single-sequence view: concatenation is NOT used by the two-tower
    /// model; this exists so generic tooling can smoke-test the generator.
    fn sample(&self, rng: &mut Rng) -> SeqExample {
        let p = self.sample_pair(rng);
        SeqExample { x: p.x1, label: p.label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn sig_of(x: &[f32], seq_len: usize) -> Vec<usize> {
        let mut present = vec![false; SIG_TOKENS + 1];
        for k in 0..seq_len {
            let row = &x[k * VOCAB..(k + 1) * VOCAB];
            let tok = row.iter().position(|&v| v == 1.0).unwrap();
            if (1..=SIG_TOKENS).contains(&tok) {
                present[tok] = true;
            }
        }
        (1..=SIG_TOKENS).filter(|&t| present[t]).collect()
    }

    #[test]
    fn prop_equivalent_pairs_share_signature() {
        let task = Retrieval::new(128);
        prop::check("retrieval signatures", 40, |g| {
            let p = task.sample_pair(g);
            let s1 = sig_of(&p.x1, 128);
            let s2 = sig_of(&p.x2, 128);
            if p.label == 1 {
                prop::ensure_msg(s1 == s2, format!("{s1:?} vs {s2:?}"))
            } else {
                prop::ensure_msg(s1 != s2, "negative pair shares signature".to_string())
            }
        });
    }

    #[test]
    fn docs_differ_even_when_equivalent() {
        let task = Retrieval::new(128);
        let mut rng = Rng::new(3);
        let p = loop {
            let p = task.sample_pair(&mut rng);
            if p.label == 1 {
                break p;
            }
        };
        assert_ne!(p.x1, p.x2, "equivalent docs must not be identical");
    }

    #[test]
    fn pair_shapes() {
        let task = Retrieval::new(64);
        let p = task.sample_pair(&mut Rng::new(4));
        assert_eq!(p.x1.len(), 64 * VOCAB);
        assert_eq!(p.x2.len(), 64 * VOCAB);
    }
}
