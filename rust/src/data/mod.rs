//! Synthetic workload generators — the data substrate.
//!
//! The paper evaluates on LRA (ListOps, Text/IMDB, Retrieval/AAN, Image/
//! CIFAR, Pathfinder, Path-X), Speech Commands, pixel-level MNIST/CIFAR and
//! a pendulum-image regression. None of those corpora are available in this
//! offline environment, so each generator here builds a from-scratch
//! synthetic task exercising the **same code path and difficulty axis**
//! (long sequences, sparse long-range dependencies, 2-D structure flattened
//! to 1-D, continuous-time sampling). See DESIGN.md §Substitutions.
//!
//! All generators are deterministic given a seed and implement [`TaskGen`],
//! so the trainer, server and bench harness are generic over tasks.

pub mod batcher;
pub mod image;
pub mod listops;
pub mod mnist;
pub mod pathfinder;
pub mod pendulum;
pub mod retrieval;
pub mod speech;
pub mod text;

use crate::rng::Rng;

/// One labelled sequence example: `x` is row-major (L × d_input).
#[derive(Clone, Debug)]
pub struct SeqExample {
    pub x: Vec<f32>,
    pub label: i32,
}

/// A classification task that can sample labelled sequences.
pub trait TaskGen: Send + Sync {
    /// Sequence length L (fixed; generators pad internally).
    fn seq_len(&self) -> usize;
    /// Input feature width per step.
    fn d_input(&self) -> usize;
    /// Number of classes.
    fn classes(&self) -> usize;
    /// Sample one example.
    fn sample(&self, rng: &mut Rng) -> SeqExample;
    /// Short task name (matches the AOT preset name).
    fn name(&self) -> &'static str;
}

/// Build a named task at its preset dimensions.
pub fn make_task(name: &str) -> Option<Box<dyn TaskGen>> {
    Some(match name {
        "listops" => Box::new(listops::ListOps::new(512)),
        "text" => Box::new(text::Sentiment::new(1024)),
        "image" => Box::new(image::TextureImage::new(32)),
        "pathfinder" => Box::new(pathfinder::Pathfinder::new(32)),
        "pathx" => Box::new(pathfinder::Pathfinder::new_pathx(64)),
        "speech" => Box::new(speech::SpeechCommands::new(2048)),
        "smnist" => Box::new(mnist::SeqMnist::new(false)),
        "psmnist" => Box::new(mnist::SeqMnist::new(true)),
        _ => return None,
    })
}

/// One-hot encode a token id into `out` (a row of width `vocab`).
pub fn one_hot(token: usize, vocab: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), vocab);
    out.iter_mut().for_each(|v| *v = 0.0);
    out[token] = 1.0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn make_task_known_names() {
        for name in ["listops", "text", "image", "pathfinder", "pathx", "speech", "smnist"] {
            let t = make_task(name).unwrap_or_else(|| panic!("{name}"));
            assert!(t.seq_len() > 0);
            assert!(t.classes() >= 2);
        }
        assert!(make_task("nope").is_none());
    }

    #[test]
    fn all_tasks_sample_consistent_shapes_and_labels() {
        let mut rng = Rng::new(0);
        for name in ["listops", "text", "image", "pathfinder", "speech", "smnist"] {
            let t = make_task(name).unwrap();
            for _ in 0..5 {
                let ex = t.sample(&mut rng);
                assert_eq!(ex.x.len(), t.seq_len() * t.d_input(), "{name}");
                assert!((ex.label as usize) < t.classes(), "{name}");
                assert!(ex.x.iter().all(|v| v.is_finite()), "{name}");
            }
        }
    }

    #[test]
    fn tasks_are_seed_deterministic() {
        let t = make_task("listops").unwrap();
        let a = t.sample(&mut Rng::new(7));
        let b = t.sample(&mut Rng::new(7));
        assert_eq!(a.x, b.x);
        assert_eq!(a.label, b.label);
    }
}
