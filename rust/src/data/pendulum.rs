//! Pendulum image regression (Becker et al. 2019 / Schirmer et al. 2022;
//! paper §6.3, Tables 3/9, Figure 3) — simulated from scratch.
//!
//! A damped pendulum driven by a random torque process is integrated with
//! RK4 on a fine grid of `total_steps`; `obs_len` frames are sampled
//! *irregularly without replacement*; each frame is a 24×24 rendering of
//! the bob corrupted by a temporally-correlated noise process. Targets are
//! (sin θ, cos θ) per observation; the inter-observation intervals Δt feed
//! the S5 layer's time-varying discretization.

use crate::rng::Rng;

pub const IMG_SIDE: usize = 24;

/// One irregularly-sampled pendulum trajectory.
#[derive(Clone, Debug)]
pub struct PendulumExample {
    /// (L × 24 × 24) noisy frames.
    pub images: Vec<f32>,
    /// (L) inter-observation intervals (Δt between consecutive samples).
    pub dts: Vec<f32>,
    /// (L × 2) regression targets (sin θ, cos θ).
    pub targets: Vec<f32>,
    /// (L) absolute observation times (for plotting / Figure 3).
    pub times: Vec<f32>,
}

pub struct PendulumSim {
    pub obs_len: usize,
    pub total_steps: usize,
    pub duration: f64,
    /// correlated-noise mixing coefficient
    noise_rho: f32,
    noise_amp: f32,
}

impl PendulumSim {
    /// Paper setting: T=100 fine steps' duration, L=50 observations.
    pub fn new() -> Self {
        PendulumSim {
            obs_len: 50,
            total_steps: 100,
            duration: 10.0,
            noise_rho: 0.8,
            noise_amp: 0.35,
        }
    }

    /// Integrate θ'' = −(g/ℓ)·sin θ − γθ' + τ(t) with RK4.
    fn simulate(&self, rng: &mut Rng) -> Vec<(f64, f64)> {
        let g_over_l = 9.81 / 1.0;
        let gamma = 0.25;
        let dt = self.duration / self.total_steps as f64;
        let mut theta = rng.uniform_in(-std::f64::consts::PI, std::f64::consts::PI);
        let mut omega = rng.uniform_in(-1.0, 1.0);
        // Ornstein–Uhlenbeck-ish torque process
        let mut tau = 0.0f64;
        let mut states = Vec::with_capacity(self.total_steps);
        for _ in 0..self.total_steps {
            tau = 0.9 * tau + 0.6 * rng.normal();
            let f = |th: f64, om: f64| -> (f64, f64) {
                (om, -g_over_l * th.sin() - gamma * om + tau)
            };
            let (k1t, k1o) = f(theta, omega);
            let (k2t, k2o) = f(theta + 0.5 * dt * k1t, omega + 0.5 * dt * k1o);
            let (k3t, k3o) = f(theta + 0.5 * dt * k2t, omega + 0.5 * dt * k2o);
            let (k4t, k4o) = f(theta + dt * k3t, omega + dt * k3o);
            theta += dt / 6.0 * (k1t + 2.0 * k2t + 2.0 * k3t + k4t);
            omega += dt / 6.0 * (k1o + 2.0 * k2o + 2.0 * k3o + k4o);
            states.push((theta, omega));
        }
        states
    }

    /// Render the bob at angle θ into a 24×24 frame.
    pub fn render(theta: f64) -> Vec<f32> {
        let n = IMG_SIDE as f64;
        let cx = n / 2.0;
        let cy = n / 2.0;
        let r = n * 0.36;
        let bx = cx + r * theta.sin();
        let by = cy + r * theta.cos();
        let mut img = vec![0.0f32; IMG_SIDE * IMG_SIDE];
        for row in 0..IMG_SIDE {
            for col in 0..IMG_SIDE {
                let dx = col as f64 - bx;
                let dy = row as f64 - by;
                img[row * IMG_SIDE + col] = (-(dx * dx + dy * dy) / 4.5).exp() as f32;
            }
        }
        img
    }

    /// Draw one irregularly-sampled example.
    pub fn sample(&self, rng: &mut Rng) -> PendulumExample {
        let states = self.simulate(rng);
        let idx = rng.choose_sorted(self.total_steps, self.obs_len);
        let fine_dt = self.duration / self.total_steps as f64;

        let mut images = Vec::with_capacity(self.obs_len * IMG_SIDE * IMG_SIDE);
        let mut dts = Vec::with_capacity(self.obs_len);
        let mut targets = Vec::with_capacity(self.obs_len * 2);
        let mut times = Vec::with_capacity(self.obs_len);
        // correlated noise field evolving across observations
        let mut noise = vec![0.0f32; IMG_SIDE * IMG_SIDE];
        let mut prev_t = 0usize;
        for (i, &t) in idx.iter().enumerate() {
            let gap = if i == 0 { t + 1 } else { t - prev_t };
            prev_t = t;
            dts.push(gap as f32 * fine_dt as f32);
            times.push((t as f64 * fine_dt) as f32);
            let (theta, _) = states[t];
            targets.push(theta.sin() as f32);
            targets.push(theta.cos() as f32);
            let mut frame = Self::render(theta);
            for (p, nz) in frame.iter_mut().zip(noise.iter_mut()) {
                *nz = self.noise_rho * *nz
                    + (1.0 - self.noise_rho) * (rng.normal() as f32) * 2.0;
                *p = (*p + self.noise_amp * *nz).clamp(-1.0, 2.0);
            }
            images.extend_from_slice(&frame);
        }
        PendulumExample { images, dts, targets, times }
    }
}

impl Default for PendulumSim {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let sim = PendulumSim::new();
        let ex = sim.sample(&mut Rng::new(0));
        assert_eq!(ex.images.len(), 50 * 24 * 24);
        assert_eq!(ex.dts.len(), 50);
        assert_eq!(ex.targets.len(), 100);
        assert_eq!(ex.times.len(), 50);
    }

    #[test]
    fn targets_on_unit_circle() {
        let sim = PendulumSim::new();
        let ex = sim.sample(&mut Rng::new(1));
        for k in 0..50 {
            let s = ex.targets[2 * k];
            let c = ex.targets[2 * k + 1];
            assert!((s * s + c * c - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn intervals_positive_and_irregular() {
        let sim = PendulumSim::new();
        let ex = sim.sample(&mut Rng::new(2));
        assert!(ex.dts.iter().all(|&d| d > 0.0));
        // irregular: not all gaps equal
        let first = ex.dts[1];
        assert!(ex.dts[1..].iter().any(|&d| (d - first).abs() > 1e-6));
        // times strictly increasing
        for w in ex.times.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn bob_follows_angle() {
        // bright pixel of the clean render moves with θ
        let up = PendulumSim::render(0.0);
        let down = PendulumSim::render(std::f64::consts::PI);
        let argmax = |img: &[f32]| -> usize {
            img.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        let up_row = argmax(&up) / IMG_SIDE;
        let down_row = argmax(&down) / IMG_SIDE;
        assert!(up_row > down_row, "θ=0 hangs low (row {up_row}), θ=π points up (row {down_row})");
    }

    #[test]
    fn dynamics_stay_bounded() {
        let sim = PendulumSim::new();
        for seed in 0..5 {
            let ex = sim.sample(&mut Rng::new(seed));
            assert!(ex.images.iter().all(|v| v.is_finite()));
        }
    }
}
