//! Synthetic byte-level sentiment classification (LRA "Text" / IMDB stand-in).
//!
//! Documents are streams over a 32-token vocabulary: filler tokens plus a
//! small set of *positive* and *negative* cue tokens planted sparsely
//! through the document. The label is the sign of the cue majority. Because
//! cues are rare (a handful in ~1k tokens) and can appear anywhere, the
//! classifier must integrate evidence across the whole sequence — the same
//! difficulty axis as character-level IMDB.

use crate::data::{one_hot, SeqExample, TaskGen};
use crate::rng::Rng;

pub const VOCAB: usize = 32;
const POS_CUES: std::ops::Range<usize> = 1..5;
const NEG_CUES: std::ops::Range<usize> = 5..9;
const FILLER_START: usize = 9;

pub struct Sentiment {
    seq_len: usize,
    /// expected number of cue tokens per document
    n_cues: usize,
}

impl Sentiment {
    pub fn new(seq_len: usize) -> Self {
        Sentiment { seq_len, n_cues: 9 }
    }
}

impl TaskGen for Sentiment {
    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn d_input(&self) -> usize {
        VOCAB
    }

    fn classes(&self) -> usize {
        2
    }

    fn name(&self) -> &'static str {
        "text"
    }

    fn sample(&self, rng: &mut Rng) -> SeqExample {
        let label = rng.below(2) as i32;
        // majority cue count for the labelled polarity
        let n_major = self.n_cues / 2 + 1 + rng.below(self.n_cues / 2);
        let n_minor = rng.below(n_major); // strictly fewer
        let mut toks: Vec<usize> = (0..self.seq_len)
            .map(|_| FILLER_START + rng.below(VOCAB - FILLER_START))
            .collect();
        let positions = rng.choose_sorted(self.seq_len, n_major + n_minor);
        for (i, &pos) in positions.iter().enumerate() {
            let is_major = i < n_major;
            let positive = (label == 1) == is_major;
            let cue = if positive {
                POS_CUES.start + rng.below(POS_CUES.len())
            } else {
                NEG_CUES.start + rng.below(NEG_CUES.len())
            };
            toks[pos] = cue;
        }
        let mut x = vec![0.0f32; self.seq_len * VOCAB];
        for (k, &t) in toks.iter().enumerate() {
            one_hot(t, VOCAB, &mut x[k * VOCAB..(k + 1) * VOCAB]);
        }
        SeqExample { x, label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn cue_counts(ex: &SeqExample, seq_len: usize) -> (usize, usize) {
        let (mut pos, mut neg) = (0, 0);
        for k in 0..seq_len {
            let row = &ex.x[k * VOCAB..(k + 1) * VOCAB];
            let tok = row.iter().position(|&v| v == 1.0).unwrap();
            if POS_CUES.contains(&tok) {
                pos += 1;
            } else if NEG_CUES.contains(&tok) {
                neg += 1;
            }
        }
        (pos, neg)
    }

    #[test]
    fn prop_label_matches_cue_majority() {
        let task = Sentiment::new(256);
        prop::check("sentiment majority", 60, |g| {
            let ex = task.sample(g);
            let (pos, neg) = cue_counts(&ex, 256);
            prop::ensure(pos + neg >= 1)?;
            if ex.label == 1 {
                prop::ensure_msg(pos > neg, format!("pos={pos} neg={neg}"))
            } else {
                prop::ensure_msg(neg > pos, format!("pos={pos} neg={neg}"))
            }
        });
    }

    #[test]
    fn cues_are_sparse() {
        let task = Sentiment::new(1024);
        let mut rng = Rng::new(1);
        let ex = task.sample(&mut rng);
        let (pos, neg) = cue_counts(&ex, 1024);
        assert!(pos + neg < 40, "cues should be rare, got {}", pos + neg);
    }

    #[test]
    fn labels_balanced() {
        let task = Sentiment::new(128);
        let mut rng = Rng::new(2);
        let ones: usize = (0..400)
            .map(|_| task.sample(&mut rng).label as usize)
            .sum();
        assert!((120..280).contains(&ones), "{ones}");
    }
}
