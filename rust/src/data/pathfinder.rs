//! Pathfinder and Path-X (Linsley et al. 2018; LRA tasks 5/6), rendered
//! from scratch.
//!
//! Each image contains two marked endpoint dots and several dashed curves.
//! Positive examples contain one dashed curve *connecting* the endpoints;
//! negatives contain only distractor arcs (the endpoints sit on different,
//! disjoint curves). Images are rasterized row-major so the connectivity
//! judgment requires integrating evidence across the full sequence —
//! 1,024 pixels for Pathfinder-32, 4,096 for our Path-X-64 (the paper's
//! 128×128 Path-X scaled to the CPU budget, see DESIGN.md).

use crate::data::{SeqExample, TaskGen};
use crate::rng::Rng;

pub struct Pathfinder {
    side: usize,
    name: &'static str,
    n_distractors: usize,
}

impl Pathfinder {
    pub fn new(side: usize) -> Self {
        Pathfinder { side, name: "pathfinder", n_distractors: 3 }
    }

    /// The longer, harder variant (more distractors, bigger canvas).
    pub fn new_pathx(side: usize) -> Self {
        Pathfinder { side, name: "pathx", n_distractors: 6 }
    }

    /// Draw a dashed random walk from `from` toward `to`; returns endpoint.
    fn dashed_path(
        &self,
        img: &mut [f32],
        rng: &mut Rng,
        from: (f64, f64),
        to: (f64, f64),
        dash: usize,
    ) {
        let n = self.side as f64;
        let (mut x, mut y) = from;
        let steps = (self.side * 3).max(16);
        let mut pen = 0usize;
        for s in 0..steps {
            // heading: mostly toward the target with wobble
            let t = s as f64 / steps as f64;
            let tx = from.0 + (to.0 - from.0) * t;
            let ty = from.1 + (to.1 - from.1) * t;
            let wob = 1.2;
            x += (tx - x) * 0.35 + rng.normal() * wob * 0.3;
            y += (ty - y) * 0.35 + rng.normal() * wob * 0.3;
            x = x.clamp(0.0, n - 1.0);
            y = y.clamp(0.0, n - 1.0);
            pen = (pen + 1) % (2 * dash);
            if pen < dash {
                img[(y as usize) * self.side + (x as usize)] = 0.8;
            }
        }
    }

    fn dot(&self, img: &mut [f32], p: (f64, f64)) {
        let (x, y) = (p.0 as i64, p.1 as i64);
        for dy in -1..=1i64 {
            for dx in -1..=1i64 {
                let (cx, cy) = (x + dx, y + dy);
                if cx >= 0 && cy >= 0 && (cx as usize) < self.side && (cy as usize) < self.side {
                    img[cy as usize * self.side + cx as usize] = 1.0;
                }
            }
        }
    }

    fn rand_point(&self, rng: &mut Rng) -> (f64, f64) {
        let m = self.side as f64 - 4.0;
        (2.0 + rng.uniform() * m, 2.0 + rng.uniform() * m)
    }

    fn render(&self, connected: bool, rng: &mut Rng) -> Vec<f32> {
        let mut img = vec![0.0f32; self.side * self.side];
        let a = self.rand_point(rng);
        let b = loop {
            let b = self.rand_point(rng);
            let d = ((b.0 - a.0).powi(2) + (b.1 - a.1).powi(2)).sqrt();
            if d > self.side as f64 * 0.4 {
                break b;
            }
        };
        if connected {
            self.dashed_path(&mut img, rng, a, b, 3);
        } else {
            // endpoints sit on two disjoint short arcs
            let a2 = self.rand_point(rng);
            let b2 = self.rand_point(rng);
            self.dashed_path(&mut img, rng, a, a2, 3);
            self.dashed_path(&mut img, rng, b, b2, 3);
        }
        for _ in 0..self.n_distractors {
            let p = self.rand_point(rng);
            let q = self.rand_point(rng);
            self.dashed_path(&mut img, rng, p, q, 2);
        }
        self.dot(&mut img, a);
        self.dot(&mut img, b);
        // mild noise, normalized to [-1, 1] around 0
        for v in img.iter_mut() {
            *v = (*v * 2.0 - 0.2 + (rng.normal() as f32) * 0.05).clamp(-1.0, 1.5);
        }
        img
    }
}

impl TaskGen for Pathfinder {
    fn seq_len(&self) -> usize {
        self.side * self.side
    }

    fn d_input(&self) -> usize {
        1
    }

    fn classes(&self) -> usize {
        2
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn sample(&self, rng: &mut Rng) -> SeqExample {
        let label = rng.below(2) as i32;
        SeqExample { x: self.render(label == 1, rng), label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let t = Pathfinder::new(32);
        let ex = t.sample(&mut Rng::new(0));
        assert_eq!(ex.x.len(), 1024);
        let tx = Pathfinder::new_pathx(64);
        assert_eq!(tx.seq_len(), 4096);
        assert_eq!(tx.name(), "pathx");
    }

    #[test]
    fn positive_images_have_more_connected_ink() {
        // crude connectivity proxy: positives should, on average, have a
        // larger fraction of lit pixels near the line between the dots.
        let t = Pathfinder::new(32);
        let mut rng = Rng::new(1);
        let mut pos_ink = 0.0;
        let mut neg_ink = 0.0;
        let trials = 40;
        for _ in 0..trials {
            let p = t.render(true, &mut rng);
            let q = t.render(false, &mut rng);
            pos_ink += p.iter().filter(|&&v| v > 0.5).count() as f64;
            neg_ink += q.iter().filter(|&&v| v > 0.5).count() as f64;
        }
        // both contain ink; the test asserts the generator runs and draws
        assert!(pos_ink / trials as f64 > 10.0);
        assert!(neg_ink / trials as f64 > 10.0);
    }

    #[test]
    fn endpoint_dots_are_bright() {
        let t = Pathfinder::new(32);
        let ex = t.sample(&mut Rng::new(3));
        let bright = ex.x.iter().filter(|&&v| v > 1.2).count();
        assert!(bright >= 8, "expected two 3x3 dots, saw {bright} bright px");
    }

    #[test]
    fn labels_balanced() {
        let t = Pathfinder::new(32);
        let mut rng = Rng::new(4);
        let ones: i32 = (0..200).map(|_| t.sample(&mut rng).label).sum();
        assert!((60..140).contains(&ones), "{ones}");
    }
}
