//! Batching: packs [`SeqExample`]s into the flat row-major buffers the PJRT
//! executables expect, with epoch shuffling and deterministic streams.

use crate::data::{SeqExample, TaskGen};
use crate::rng::Rng;

/// A packed batch: `x` is (B × L × d_input) row-major, `labels` is (B).
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch_size: usize,
}

/// Pack `examples` (must all share L×d) into one flat batch, padding the
/// tail by repeating earlier examples if fewer than `batch_size` remain.
pub fn pack(examples: &[SeqExample], batch_size: usize, row: usize) -> Batch {
    let rows: Vec<&[f32]> = examples.iter().map(|e| e.x.as_slice()).collect();
    let labels: Vec<i32> = examples.iter().map(|e| e.label).collect();
    pack_rows(&rows, &labels, batch_size, row)
}

/// Pack bare float rows (one per sequence) into one flat batch, padding
/// the tail by cycling earlier rows if fewer than `batch_size` remain.
/// `labels` cycles in lockstep with `rows`.
pub fn pack_rows(rows: &[&[f32]], labels: &[i32], batch_size: usize, row: usize) -> Batch {
    assert_eq!(rows.len(), labels.len());
    let mut x = Vec::with_capacity(batch_size * row);
    pack_rows_into(rows, batch_size, row, &mut x);
    let labels = (0..batch_size).map(|i| labels[i % labels.len()]).collect();
    Batch { x, labels, batch_size }
}

/// The packing core shared by the trainer path ([`pack`]/[`pack_rows`])
/// and the native inference server's dynamic batcher: fill `out` with
/// `batch_size` rows cycled from `rows`, reusing `out`'s capacity so a
/// hot loop packs with zero steady-state allocation.
pub fn pack_rows_into(rows: &[&[f32]], batch_size: usize, row: usize, out: &mut Vec<f32>) {
    assert!(!rows.is_empty());
    out.clear();
    out.reserve(batch_size * row);
    for i in 0..batch_size {
        let r = rows[i % rows.len()];
        assert_eq!(r.len(), row, "inconsistent example width");
        out.extend_from_slice(r);
    }
}

/// Streaming batch source over a generator task: materializes a finite
/// epoch pool (so train/eval splits are meaningful), shuffles each epoch.
pub struct BatchStream {
    pool: Vec<SeqExample>,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub batch_size: usize,
    row: usize,
    pub epoch: usize,
}

impl BatchStream {
    /// Generate `pool_size` examples up front from `task` with `seed`.
    pub fn new(task: &dyn TaskGen, pool_size: usize, batch_size: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let pool: Vec<SeqExample> = (0..pool_size).map(|_| task.sample(&mut rng)).collect();
        let mut order: Vec<usize> = (0..pool_size).collect();
        rng.shuffle(&mut order);
        BatchStream {
            pool,
            order,
            cursor: 0,
            rng,
            batch_size,
            row: task.seq_len() * task.d_input(),
            epoch: 0,
        }
    }

    /// Next shuffled batch; reshuffles at epoch boundaries.
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch_size > self.pool.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch_size];
        self.cursor += self.batch_size;
        let examples: Vec<SeqExample> = idx.iter().map(|&i| self.pool[i].clone()).collect();
        pack(&examples, self.batch_size, self.row)
    }

    /// Iterate the whole pool once in fixed order (evaluation).
    pub fn eval_batches(&self) -> Vec<Batch> {
        self.pool
            .chunks(self.batch_size)
            .map(|chunk| pack(chunk, self.batch_size, self.row))
            .collect()
    }

    pub fn pool_len(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::make_task;

    #[test]
    fn pack_shapes_and_padding() {
        let ex = SeqExample { x: vec![1.0, 2.0], label: 3 };
        let b = pack(&[ex], 4, 2);
        assert_eq!(b.x.len(), 8);
        assert_eq!(b.labels, vec![3, 3, 3, 3]);
    }

    #[test]
    fn stream_covers_pool_each_epoch() {
        let task = make_task("smnist").unwrap();
        let mut s = BatchStream::new(task.as_ref(), 16, 4, 9);
        let mut n = 0;
        let e0 = s.epoch;
        while s.epoch == e0 {
            let b = s.next_batch();
            assert_eq!(b.x.len(), 4 * 784);
            n += 1;
            if n > 10 {
                break;
            }
        }
        assert_eq!(n, 5, "4 batches per epoch then reshuffle on the 5th");
    }

    #[test]
    fn eval_batches_cover_pool() {
        let task = make_task("smnist").unwrap();
        let s = BatchStream::new(task.as_ref(), 10, 4, 10);
        let evs = s.eval_batches();
        assert_eq!(evs.len(), 3); // 4 + 4 + 2(padded)
        assert!(evs.iter().all(|b| b.labels.len() == 4));
    }

    #[test]
    fn pack_rows_cycles_and_matches_pack() {
        let a = SeqExample { x: vec![1.0, 2.0], label: 7 };
        let b = SeqExample { x: vec![3.0, 4.0], label: 8 };
        let via_pack = pack(&[a.clone(), b.clone()], 5, 2);
        let rows: Vec<&[f32]> = vec![&a.x, &b.x];
        let via_rows = pack_rows(&rows, &[7, 8], 5, 2);
        assert_eq!(via_pack.x, via_rows.x);
        assert_eq!(via_pack.labels, via_rows.labels);
        assert_eq!(via_rows.labels, vec![7, 8, 7, 8, 7]);
    }

    #[test]
    #[should_panic(expected = "inconsistent")]
    fn pack_rejects_ragged() {
        let a = SeqExample { x: vec![1.0, 2.0], label: 0 };
        let b = SeqExample { x: vec![1.0], label: 0 };
        pack(&[a, b], 2, 2);
    }
}
