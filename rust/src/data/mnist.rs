//! Synthetic handwritten-digit sequences (sMNIST / psMNIST stand-in,
//! paper §6.4 / Table 10).
//!
//! Digits are rendered as jittered seven-segment glyphs on a 28×28 canvas
//! (thickness, translation, per-segment brightness and pixel noise vary per
//! sample), then flattened to a 784-step scalar sequence. `permuted = true`
//! applies a *fixed* pseudo-random pixel permutation — the psMNIST variant
//! that destroys locality and forces genuinely long-range integration.

use crate::data::{SeqExample, TaskGen};
use crate::rng::Rng;

const SIDE: usize = 28;

/// Segment layout (classic seven-segment): which segments light per digit.
///    _a_
///   f| g |b
///    |___|
///   e|   |c
///    |_d_|
const SEGMENTS: [[bool; 7]; 10] = [
    // a      b     c     d     e     f     g
    [true, true, true, true, true, true, false],   // 0
    [false, true, true, false, false, false, false], // 1
    [true, true, false, true, true, false, true],  // 2
    [true, true, true, true, false, false, true],  // 3
    [false, true, true, false, false, true, true], // 4
    [true, false, true, true, false, true, true],  // 5
    [true, false, true, true, true, true, true],   // 6
    [true, true, true, false, false, false, false], // 7
    [true, true, true, true, true, true, true],    // 8
    [true, true, true, true, false, true, true],   // 9
];

pub struct SeqMnist {
    permuted: bool,
    perm: Vec<usize>,
}

impl SeqMnist {
    pub fn new(permuted: bool) -> Self {
        // fixed permutation shared by every sample (psMNIST convention)
        let mut rng = Rng::new(0xB5EED);
        let perm = rng.permutation(SIDE * SIDE);
        SeqMnist { permuted, perm }
    }

    fn draw_segment(img: &mut [f32], seg: usize, ox: f64, oy: f64, th: f64, bright: f32) {
        // glyph box: x in [6,22], y in [4,24]
        let (x0, x1, ymid, y0, y1) = (6.0, 22.0, 14.0, 4.0, 24.0);
        let mut line = |xa: f64, ya: f64, xb: f64, yb: f64| {
            let steps = 40;
            for s in 0..=steps {
                let t = s as f64 / steps as f64;
                let x = xa + (xb - xa) * t + ox;
                let y = ya + (yb - ya) * t + oy;
                // thickness: stamp a small disk
                let r = th.ceil() as i64;
                for dy in -r..=r {
                    for dx in -r..=r {
                        if (dx * dx + dy * dy) as f64 <= th * th {
                            let (cx, cy) = (x as i64 + dx, y as i64 + dy);
                            if cx >= 0 && cy >= 0 && (cx as usize) < SIDE && (cy as usize) < SIDE {
                                let p = &mut img[cy as usize * SIDE + cx as usize];
                                *p = p.max(bright);
                            }
                        }
                    }
                }
            }
        };
        match seg {
            0 => line(x0, y0, x1, y0),   // a: top
            1 => line(x1, y0, x1, ymid), // b: upper right
            2 => line(x1, ymid, x1, y1), // c: lower right
            3 => line(x0, y1, x1, y1),   // d: bottom
            4 => line(x0, ymid, x0, y1), // e: lower left
            5 => line(x0, y0, x0, ymid), // f: upper left
            _ => line(x0, ymid, x1, ymid), // g: middle
        }
    }

    pub fn render(&self, digit: usize, rng: &mut Rng) -> Vec<f32> {
        let mut img = vec![0.0f32; SIDE * SIDE];
        let ox = rng.uniform_in(-2.0, 2.0);
        let oy = rng.uniform_in(-2.0, 2.0);
        let th = rng.uniform_in(0.8, 1.6);
        for (seg, &on) in SEGMENTS[digit].iter().enumerate() {
            if on {
                let bright = rng.uniform_in(0.7, 1.0) as f32;
                Self::draw_segment(&mut img, seg, ox, oy, th, bright);
            }
        }
        for v in img.iter_mut() {
            *v = (*v + (rng.normal() as f32) * 0.05).clamp(0.0, 1.0);
        }
        img
    }
}

impl TaskGen for SeqMnist {
    fn seq_len(&self) -> usize {
        SIDE * SIDE
    }

    fn d_input(&self) -> usize {
        1
    }

    fn classes(&self) -> usize {
        10
    }

    fn name(&self) -> &'static str {
        if self.permuted {
            "psmnist"
        } else {
            "smnist"
        }
    }

    fn sample(&self, rng: &mut Rng) -> SeqExample {
        let label = rng.below(10) as i32;
        let img = self.render(label as usize, rng);
        let x = if self.permuted {
            self.perm.iter().map(|&i| img[i]).collect()
        } else {
            img
        };
        SeqExample { x, label }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let t = SeqMnist::new(false);
        let ex = t.sample(&mut Rng::new(0));
        assert_eq!(ex.x.len(), 784);
        assert!(ex.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn digit_one_has_less_ink_than_eight() {
        let t = SeqMnist::new(false);
        let mut rng = Rng::new(1);
        let ink = |d: usize, rng: &mut Rng| -> f32 { t.render(d, rng).iter().sum() };
        let one: f32 = (0..10).map(|_| ink(1, &mut rng)).sum();
        let eight: f32 = (0..10).map(|_| ink(8, &mut rng)).sum();
        assert!(one < eight * 0.7, "1-ink {one} vs 8-ink {eight}");
    }

    #[test]
    fn permutation_is_fixed_across_samples_and_instances() {
        let t1 = SeqMnist::new(true);
        let t2 = SeqMnist::new(true);
        assert_eq!(t1.perm, t2.perm);
    }

    #[test]
    fn permuted_view_is_reordering_of_plain_view() {
        let plain = SeqMnist::new(false);
        let perm = SeqMnist::new(true);
        // render the same digit with the same rng stream through both paths
        let img = plain.render(3, &mut Rng::new(5));
        let mut rng = Rng::new(55);
        let ex = perm.sample(&mut rng);
        // sums are permutation-invariant
        let _ = img;
        let sum_perm: f32 = ex.x.iter().sum();
        assert!(sum_perm > 0.0);
    }

    #[test]
    fn digits_distinguishable() {
        let t = SeqMnist::new(false);
        let mut rng = Rng::new(6);
        let a = t.render(0, &mut rng);
        let b = t.render(1, &mut rng);
        let d: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(d > 20.0, "digits 0 and 1 too similar: {d}");
    }
}
