//! Shared utilities: logging, wall-clock timing, summary statistics, ASCII
//! table rendering (for the paper-table benches) and a small CLI argument
//! parser (the offline build has no `clap`).

use std::collections::BTreeMap;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Logging
// ---------------------------------------------------------------------------

/// Log level for [`log`].
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Debug,
    Info,
    Warn,
    Error,
}

static VERBOSE: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Enable debug-level logging.
pub fn set_verbose(v: bool) {
    VERBOSE.store(v, std::sync::atomic::Ordering::Relaxed);
}

/// Timestamped stderr logging.
pub fn log(level: Level, msg: &str) {
    if level == Level::Debug && !VERBOSE.load(std::sync::atomic::Ordering::Relaxed) {
        return;
    }
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{tag} {:>10.3}s] {msg}", uptime());
}

/// Seconds since first call (process-relative clock).
pub fn uptime() -> f64 {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Info, &format!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Debug, &format!($($t)*)) };
}

// ---------------------------------------------------------------------------
// Timing + stats
// ---------------------------------------------------------------------------

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Summary statistics over a sample of measurements.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Stats {
    /// Compute stats from raw samples.
    pub fn from(samples: &[f64]) -> Stats {
        if samples.is_empty() {
            return Stats::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| sorted[((q * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: pct(0.5),
            p95: pct(0.95),
        }
    }
}

/// Time `f` over `iters` iterations after `warmup` runs; returns per-call
/// seconds.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Stats::from(&samples)
}

// ---------------------------------------------------------------------------
// ASCII tables (paper-table output)
// ---------------------------------------------------------------------------

/// Minimal fixed-width table renderer used by the bench harness to print
/// rows in the same layout as the paper's tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (c, cell) in cells.iter().enumerate() {
                out.push_str("| ");
                out.push_str(cell);
                out.push_str(&" ".repeat(widths[c] - cell.len() + 1));
            }
            out.push_str("|\n");
        };
        line(&mut out, &self.headers);
        for (c, w) in widths.iter().enumerate() {
            out.push_str(if c == 0 { "|" } else { "|" });
            out.push_str(&"-".repeat(w + 2));
        }
        out.push_str("|\n");
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }
}

// ---------------------------------------------------------------------------
// CLI argument parsing (no `clap` offline)
// ---------------------------------------------------------------------------

/// Parsed `--key value` / `--flag` command-line arguments plus positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (after argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect(key)).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().expect(key)).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Human-readable byte count.
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1} {}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn stats_empty() {
        let s = Stats::from(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn args_parsing() {
        let a = Args::parse(
            ["train", "--preset", "smnist", "--steps=100", "--verbose", "--lr", "0.003"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("preset"), Some("smnist"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!((a.get_f64("lr", 0.0) - 0.003).abs() < 1e-12);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn args_trailing_flag() {
        let a = Args::parse(["--fast"].iter().map(|s| s.to_string()));
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Model", "Acc"]);
        t.row(&["S5".into(), "98.58".into()]);
        t.row(&["S4-LegS".into(), "96.35".into()]);
        let s = t.render();
        assert!(s.contains("| S5      |"), "{s}");
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512.0 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }
}
pub mod pgm;
