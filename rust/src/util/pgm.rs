//! PGM image export: dump generator samples (Pathfinder renders, pendulum
//! frames, digit glyphs) for visual inspection — `s5 data --dump DIR`.
//!
//! Plain binary PGM (P5): universally viewable, zero dependencies.

use std::io::Write;
use std::path::Path;

/// Write a grayscale image (row-major, any real range — min/max normalized)
/// as binary PGM.
pub fn write_pgm(path: &Path, pixels: &[f32], width: usize, height: usize) -> anyhow::Result<()> {
    anyhow::ensure!(pixels.len() == width * height, "pixel count mismatch");
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &p in pixels {
        lo = lo.min(p);
        hi = hi.max(p);
    }
    let span = if hi > lo { hi - lo } else { 1.0 };
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{width} {height}\n255\n")?;
    let bytes: Vec<u8> = pixels
        .iter()
        .map(|&p| (((p - lo) / span) * 255.0).round().clamp(0.0, 255.0) as u8)
        .collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Parse a PGM file back (for round-trip tests).
pub fn read_pgm(path: &Path) -> anyhow::Result<(Vec<u8>, usize, usize)> {
    let data = std::fs::read(path)?;
    let text_end = data
        .windows(1)
        .enumerate()
        .filter(|(_, w)| w[0] == b'\n')
        .map(|(i, _)| i)
        .nth(2)
        .ok_or_else(|| anyhow::anyhow!("bad pgm header"))?;
    let header = std::str::from_utf8(&data[..text_end])?;
    let mut it = header.split_whitespace();
    anyhow::ensure!(it.next() == Some("P5"), "not a P5 pgm");
    let width: usize = it.next().unwrap_or("0").parse()?;
    let height: usize = it.next().unwrap_or("0").parse()?;
    let pixels = data[text_end + 1..].to_vec();
    anyhow::ensure!(pixels.len() == width * height, "truncated pgm");
    Ok((pixels, width, height))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("s5_pgm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let path = tmp("rt.pgm");
        let img: Vec<f32> = (0..64).map(|i| i as f32 / 63.0).collect();
        write_pgm(&path, &img, 8, 8).unwrap();
        let (px, w, h) = read_pgm(&path).unwrap();
        assert_eq!((w, h), (8, 8));
        assert_eq!(px[0], 0);
        assert_eq!(px[63], 255);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn constant_image_does_not_divide_by_zero() {
        let path = tmp("flat.pgm");
        write_pgm(&path, &[0.5; 16], 4, 4).unwrap();
        let (px, _, _) = read_pgm(&path).unwrap();
        assert!(px.iter().all(|&p| p == 0));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_dims() {
        let path = tmp("bad.pgm");
        assert!(write_pgm(&path, &[0.0; 5], 2, 3).is_err());
    }

    #[test]
    fn dump_real_generators() {
        use crate::data::TaskGen;
        let dir = std::env::temp_dir().join(format!("s5_dumps_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = crate::rng::Rng::new(0);
        let pf = crate::data::pathfinder::Pathfinder::new(32);
        let ex = pf.sample(&mut rng);
        write_pgm(&dir.join("pathfinder.pgm"), &ex.x, 32, 32).unwrap();
        let frame = crate::data::pendulum::PendulumSim::render(1.0);
        write_pgm(&dir.join("pendulum.pgm"), &frame, 24, 24).unwrap();
        assert!(read_pgm(&dir.join("pathfinder.pgm")).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
