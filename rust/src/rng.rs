//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so this module provides the RNG
//! substrate used by every data generator, initializer and property test:
//! a SplitMix64-seeded xoshiro256++ core with normal/uniform samplers and
//! Fisher–Yates shuffling. All experiment code takes explicit seeds so runs
//! are exactly reproducible.

/// xoshiro256++ PRNG seeded via SplitMix64.
///
/// Passes BigCrush in its published form; here we need speed, determinism
/// and independence across streams (`split`) rather than cryptographic
/// strength.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (used to give each worker its own RNG).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    /// Next raw 64-bit output (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        // avoid log(0)
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u1 = if u1 <= 1e-300 { 1e-300 } else { u1 };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals (f32).
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Vector of uniforms in [lo, hi) (f32).
    pub fn uniform_vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n)
            .map(|_| self.uniform_in(lo as f64, hi as f64) as f32)
            .collect()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample k distinct indices from 0..n (k ≤ n), sorted ascending.
    pub fn choose_sorted(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx = self.permutation(n);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(7);
        let mut b = a.split();
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(4);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn choose_sorted_distinct() {
        let mut r = Rng::new(5);
        let k = r.choose_sorted(50, 20);
        assert_eq!(k.len(), 20);
        for w in k.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
