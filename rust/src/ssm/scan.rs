//! Scans for first-order linear recurrences (paper §2.2, Appendix H).
//!
//! The recurrence x_k = ā_k ∘ x_{k−1} + b_k over ℂ^P is provided at three
//! altitudes:
//!
//! 1. **In-place kernels** — [`scan_sequential_ti_inplace`] /
//!    [`scan_sequential_tv_inplace`] overwrite the drive buffer with the
//!    states using the previous output row as the carried state (no scratch
//!    at all); [`scan_parallel_ti_inplace`] / [`scan_parallel_tv_inplace`]
//!    are the multi-threaded chunked form (local scan → chunk-summary
//!    combine → fixup, the CPU analogue of the work-efficient Blelloch scan
//!    the paper leans on). The parallel kernels honor the requested chunking
//!    exactly — heuristics live in the backends — so tests can pin
//!    chunk-boundary behavior.
//! 2. **The [`ScanBackend`] trait** — the object-safe strategy interface the
//!    batched engine ([`crate::ssm::engine`]) threads through the S5 stack.
//!    It unifies sequential and parallel, time-invariant (TI) and
//!    time-varying (TV) scans, adds batched entry points over (B, L, P)
//!    row-major buffers (parallelized across B × chunks), and exposes the
//!    single-step recurrence ([`ScanBackend::scan_step`]) that online
//!    generation (§3.3) shares with the offline path.
//! 3. **Allocating wrappers** — [`scan_sequential`], [`scan_sequential_ti`],
//!    [`scan_parallel_ti`], [`scan_parallel_tv`] keep the original
//!    copy-out signatures for benches and exploratory code.
//!
//! [`scan_dense_sequential`] is the O(L·P²)/O(L·P³) *dense*-A strawman of
//! §2.2, kept as a baseline to demonstrate why diagonalization is load-
//! bearing for S5.
//!
//! ## Memory layout: planar (SoA) vs interleaved
//!
//! Every kernel and every [`ScanBackend`] entry point exists in **two
//! layouts**. The interleaved form works on `[C32]` (re/im adjacent per
//! element); its inner loop carries a real↔imag data dependence that blocks
//! autovectorization. The planar form works on separate re/im `f32` planes
//! (struct-of-arrays, the same layout the L1 Pallas kernel uses), which
//! lets LLVM emit SIMD mul/fma over the P lanes. Both layouts execute the
//! *identical* floating-point operations in the identical order, so their
//! results agree bit-for-bit — the interleaved kernels are kept as the
//! reference oracle (see [`ScanLayout`] and the `Interleaved` wrapper),
//! while [`backend_for_threads`] hands out planar-driving backends by
//! default.
//!
//! Parallel kernels need O(chunks·P) chunk summaries; the pooled form
//! ([`ScanScratch`], owned by the engine workspace) reuses them so
//! steady-state inference allocates nothing (ROADMAP item).
//!
//! The planar hot loops additionally dispatch onto the explicit
//! lane-blocked kernels of [`crate::ssm::simd`] when the `simd` cargo
//! feature is on (the default). Those kernels execute the identical FP
//! ops per element, so the dispatch is invisible to every bit-for-bit
//! pin; `--no-default-features` builds keep the scalar loops as the
//! oracle.
//!
//! ## Tile-resumable kernels and the in-tile wide path
//!
//! The fused cache-blocked forward scans one tile at a time, carrying the
//! state across tiles ([`scan_resume_ti_planar_inplace`] and friends —
//! bit-for-bit equal to the staged sequential scan under any tiling).
//! When a single stream must saturate the machine (B × direction units <
//! workers), [`scan_resume_ti_planar_par_inplace`] /
//! [`scan_resume_tv_planar_par_inplace`] run the chunked three-phase scan
//! *within* the tile, seeding the chunk-summary combine from the carried
//! state and fixing up chunk 0 as well. Seeded chunking reassociates the
//! carry propagation, so this path is tolerance-pinned (not bitwise)
//! against the sequential oracle and is opt-in via `ScanPolicy::wide`.
//!
//! ## Dispatch: the worker pool
//!
//! The multi-threaded kernels no longer spawn. Every parallel phase takes
//! an [`Executor`] (see [`crate::runtime::pool`]) and every backend
//! reports one via [`ScanBackend::executor`]: [`ParallelBackend`]
//! dispatches onto the process-wide persistent [`WorkerPool`] by default
//! ([`ScanExec::Pooled`]), with spawn-per-call scoped threads
//! ([`ScanExec::Scoped`]) and inline execution ([`ScanExec::Inline`])
//! retained as fallbacks/oracles. The executor never changes the shard
//! decomposition — that is fixed by the backend's thread budget — so
//! results are bit-for-bit identical across executors (pinned by
//! `tests/scan_matrix.rs`).

use crate::num::{C32, C64};
use crate::runtime::pool::{global_pool, Executor, WorkerPool};
use crate::ssm::dtype::{bf16_to_f32, f32_to_bf16, Bf16, ScanElem};
use crate::ssm::simd;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// In-place kernels
// ---------------------------------------------------------------------------
// s5:hot-begin — the sequential / tile-resumable scan kernels are the
// innermost loops of both the fused forward and streaming decode; all
// scratch is caller-owned (lint L3, plus the alloc_guard runtime tests).

/// One streaming recurrence step: `state ← a ∘ state + b` (elementwise).
///
/// This is the shared inner step of the sequential kernels and of online
/// generation ([`crate::ssm::online`]), so the two modes cannot drift.
#[inline]
pub fn scan_step_inplace(a: &[C32], state: &mut [C32], b: &[C32]) {
    debug_assert_eq!(a.len(), state.len());
    debug_assert_eq!(b.len(), state.len());
    for j in 0..state.len() {
        state[j] = a[j] * state[j] + b[j];
    }
}

/// Sequential time-invariant scan, in place: on entry `bu` holds the drive
/// b (row-major (L, P)); on exit it holds the states x. `a` has length P.
///
/// Uses the previous output row as the carried state — zero scratch.
pub fn scan_sequential_ti_inplace(a: &[C32], bu: &mut [C32], l: usize, p: usize) {
    assert_eq!(a.len(), p);
    assert_eq!(bu.len(), l * p);
    for k in 1..l {
        let (prev, cur) = bu.split_at_mut(k * p);
        let prev = &prev[(k - 1) * p..];
        for j in 0..p {
            cur[j] = a[j] * prev[j] + cur[j];
        }
    }
}

/// Sequential time-varying scan, in place: `a` and `bu` are (L, P).
pub fn scan_sequential_tv_inplace(a: &[C32], bu: &mut [C32], l: usize, p: usize) {
    assert_eq!(a.len(), l * p);
    assert_eq!(bu.len(), l * p);
    for k in 1..l {
        let row = k * p;
        let (prev, cur) = bu.split_at_mut(row);
        let prev = &prev[(k - 1) * p..];
        for j in 0..p {
            cur[j] = a[row + j] * prev[j] + cur[j];
        }
    }
}

/// One streaming recurrence step in planar layout:
/// `state ← a ∘ state + b` over separate re/im planes.
///
/// Same FP ops in the same order as [`scan_step_inplace`], so the two
/// layouts agree bit-for-bit; this is the kernel the planar online path
/// ([`crate::ssm::online`]) shares with the offline planar scans.
#[inline]
pub fn scan_step_planar_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    br: &[f32],
    bi: &[f32],
) {
    let p = sr.len();
    debug_assert_eq!(ar.len(), p);
    debug_assert_eq!(ai.len(), p);
    debug_assert_eq!(si.len(), p);
    debug_assert_eq!(br.len(), p);
    debug_assert_eq!(bi.len(), p);
    for j in 0..p {
        let nr = ar[j] * sr[j] - ai[j] * si[j] + br[j];
        let ni = ar[j] * si[j] + ai[j] * sr[j] + bi[j];
        sr[j] = nr;
        si[j] = ni;
    }
}

/// Sequential time-invariant scan in planar layout, in place: `ar`/`ai`
/// have length P; `bur`/`bui` are (L, P) planes holding the drive on entry
/// and the states on exit. Mirrors [`scan_sequential_ti_inplace`]
/// operation-for-operation.
pub fn scan_sequential_ti_planar_inplace(
    ar: &[f32],
    ai: &[f32],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
) {
    assert_eq!(ar.len(), p);
    assert_eq!(ai.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    for k in 1..l {
        let row = k * p;
        let (pr_all, cur_r) = bur.split_at_mut(row);
        let (pi_all, cur_i) = bui.split_at_mut(row);
        let pr = &pr_all[row - p..];
        let pi = &pi_all[row - p..];
        if cfg!(feature = "simd") {
            simd::scan_row_step(ar, ai, pr, pi, &mut cur_r[..p], &mut cur_i[..p]);
        } else {
            for j in 0..p {
                let nr = ar[j] * pr[j] - ai[j] * pi[j] + cur_r[j];
                let ni = ar[j] * pi[j] + ai[j] * pr[j] + cur_i[j];
                cur_r[j] = nr;
                cur_i[j] = ni;
            }
        }
    }
}

/// Sequential time-varying scan in planar layout, in place: all four
/// planes are (L, P). Mirrors [`scan_sequential_tv_inplace`].
pub fn scan_sequential_tv_planar_inplace(
    ar: &[f32],
    ai: &[f32],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
) {
    assert_eq!(ar.len(), l * p);
    assert_eq!(ai.len(), l * p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    for k in 1..l {
        let row = k * p;
        let (pr_all, cur_r) = bur.split_at_mut(row);
        let (pi_all, cur_i) = bui.split_at_mut(row);
        let pr = &pr_all[row - p..];
        let pi = &pi_all[row - p..];
        if cfg!(feature = "simd") {
            simd::scan_row_step(
                &ar[row..row + p],
                &ai[row..row + p],
                pr,
                pi,
                &mut cur_r[..p],
                &mut cur_i[..p],
            );
        } else {
            for j in 0..p {
                let nr = ar[row + j] * pr[j] - ai[row + j] * pi[j] + cur_r[j];
                let ni = ar[row + j] * pi[j] + ai[row + j] * pr[j] + cur_i[j];
                cur_r[j] = nr;
                cur_i[j] = ni;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tile-resumable kernels (the fused cache-blocked forward path)
// ---------------------------------------------------------------------------

/// Sequential TI scan of an (L, P) tile resumed from a carried state:
/// `state` holds the state entering the tile (the previous tile's final
/// state row, or zeros) and holds the post-tile state on exit; `bu` holds
/// the tile's drive on entry and its states on exit. Row k executes the
/// exact per-element op of [`scan_step_inplace`] (and of row k ≥ 1 of
/// [`scan_sequential_ti_inplace`], with the carried state playing the
/// previous row), so an arbitrary tile decomposition reproduces the
/// whole-sequence sequential scan bit-for-bit.
pub fn scan_resume_ti_inplace(a: &[C32], state: &mut [C32], bu: &mut [C32], l: usize, p: usize) {
    assert_eq!(a.len(), p);
    assert_eq!(state.len(), p);
    assert_eq!(bu.len(), l * p);
    for k in 0..l {
        let row = k * p;
        for j in 0..p {
            state[j] = a[j] * state[j] + bu[row + j];
            bu[row + j] = state[j];
        }
    }
}

/// Tile-resumable TV scan (interleaved): `a` and `bu` are (L, P) tile
/// rows; see [`scan_resume_ti_inplace`] for the state contract.
pub fn scan_resume_tv_inplace(a: &[C32], state: &mut [C32], bu: &mut [C32], l: usize, p: usize) {
    assert_eq!(a.len(), l * p);
    assert_eq!(state.len(), p);
    assert_eq!(bu.len(), l * p);
    for k in 0..l {
        let row = k * p;
        for j in 0..p {
            state[j] = a[row + j] * state[j] + bu[row + j];
            bu[row + j] = state[j];
        }
    }
}

/// Planar tile-resumable TI scan: `sr`/`si` carry the state in/out,
/// `bur`/`bui` are (L, P) drive-in/states-out planes. Identical FP ops in
/// identical order to [`scan_step_planar_inplace`] per row (and to rows
/// k ≥ 1 of [`scan_sequential_ti_planar_inplace`]), so tiled ≡ staged ≡
/// streaming, bit-for-bit, on the sequential op order.
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_ti_planar_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
) {
    assert_eq!(ar.len(), p);
    assert_eq!(ai.len(), p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    for k in 0..l {
        let row = k * p;
        if cfg!(feature = "simd") {
            simd::scan_row_resume(
                ar,
                ai,
                sr,
                si,
                &mut bur[row..row + p],
                &mut bui[row..row + p],
            );
        } else {
            for j in 0..p {
                let nr = ar[j] * sr[j] - ai[j] * si[j] + bur[row + j];
                let ni = ar[j] * si[j] + ai[j] * sr[j] + bui[row + j];
                sr[j] = nr;
                si[j] = ni;
                bur[row + j] = nr;
                bui[row + j] = ni;
            }
        }
    }
}

/// Planar tile-resumable TV scan: all four data planes are (L, P) tile
/// rows; see [`scan_resume_ti_planar_inplace`] for the state contract.
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_tv_planar_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
) {
    assert_eq!(ar.len(), l * p);
    assert_eq!(ai.len(), l * p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    for k in 0..l {
        let row = k * p;
        if cfg!(feature = "simd") {
            simd::scan_row_resume(
                &ar[row..row + p],
                &ai[row..row + p],
                sr,
                si,
                &mut bur[row..row + p],
                &mut bui[row..row + p],
            );
        } else {
            for j in 0..p {
                let nr = ar[row + j] * sr[j] - ai[row + j] * si[j] + bur[row + j];
                let ni = ar[row + j] * si[j] + ai[row + j] * sr[j] + bui[row + j];
                sr[j] = nr;
                si[j] = ni;
                bur[row + j] = nr;
                bui[row + j] = ni;
            }
        }
    }
}

/// Planar tile-resumable TI scan with an **f64 carry state** (the
/// `ForwardOptions::with_f64_state` long-L drift option): the recurrence
/// accumulates in f64 end-to-end — the state never round-trips through
/// f32 — while the emitted state rows are rounded to f32 per row. Because
/// the carry is continuous, the result is independent of the tile
/// decomposition bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_ti_planar_f64_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f64],
    si: &mut [f64],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
) {
    assert_eq!(ar.len(), p);
    assert_eq!(ai.len(), p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    for k in 0..l {
        let row = k * p;
        for j in 0..p {
            let nr = ar[j] as f64 * sr[j] - ai[j] as f64 * si[j] + bur[row + j] as f64;
            let ni = ar[j] as f64 * si[j] + ai[j] as f64 * sr[j] + bui[row + j] as f64;
            sr[j] = nr;
            si[j] = ni;
            bur[row + j] = nr as f32;
            bui[row + j] = ni as f32;
        }
    }
}

/// Planar tile-resumable TV scan with an f64 carry state (irregular-Δt
/// twin of [`scan_resume_ti_planar_f64_inplace`]; the multipliers stay
/// f32 — only the carried state is widened).
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_tv_planar_f64_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f64],
    si: &mut [f64],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
) {
    assert_eq!(ar.len(), l * p);
    assert_eq!(ai.len(), l * p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    for k in 0..l {
        let row = k * p;
        for j in 0..p {
            let nr = ar[row + j] as f64 * sr[j] - ai[row + j] as f64 * si[j] + bur[row + j] as f64;
            let ni = ar[row + j] as f64 * si[j] + ai[row + j] as f64 * sr[j] + bui[row + j] as f64;
            sr[j] = nr;
            si[j] = ni;
            bur[row + j] = nr as f32;
            bui[row + j] = ni as f32;
        }
    }
}

/// Planar tile-resumable TI scan over **bf16 storage planes**: the carry
/// `sr`/`si` stays f32 across rows and tiles (the compute dtype) while the
/// (L, P) drive/state planes hold bfloat16. Each row load-widens the
/// stored drive (exact), runs the f32 recurrence of
/// [`scan_resume_ti_planar_inplace`], and narrow-stores the emitted state
/// row (round-to-nearest-even). Because the carried state never
/// round-trips through bf16, the result is tile-decomposition invariant
/// bit-for-bit, and replaying the rows through
/// [`scan_step_planar_inplace`] with
/// [`crate::ssm::dtype::bf16_round_trip`]-rounded drive/state reproduces
/// it exactly (streaming ≡ prefill; `tests/sequence_api.rs`).
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_ti_planar_bf16_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    bur: &mut [Bf16],
    bui: &mut [Bf16],
    l: usize,
    p: usize,
) {
    assert_eq!(ar.len(), p);
    assert_eq!(ai.len(), p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    for k in 0..l {
        let row = k * p;
        if cfg!(feature = "simd") {
            simd::scan_row_resume_bf16(
                ar,
                ai,
                sr,
                si,
                &mut bur[row..row + p],
                &mut bui[row..row + p],
            );
        } else {
            for j in 0..p {
                let nr = ar[j] * sr[j] - ai[j] * si[j] + bf16_to_f32(bur[row + j]);
                let ni = ar[j] * si[j] + ai[j] * sr[j] + bf16_to_f32(bui[row + j]);
                sr[j] = nr;
                si[j] = ni;
                bur[row + j] = f32_to_bf16(nr);
                bui[row + j] = f32_to_bf16(ni);
            }
        }
    }
}

/// TV twin of [`scan_resume_ti_planar_bf16_inplace`]: per-row f32
/// multiplier planes (only the drive/state storage narrows — the
/// Δt-scaled multipliers stay full precision).
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_tv_planar_bf16_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    bur: &mut [Bf16],
    bui: &mut [Bf16],
    l: usize,
    p: usize,
) {
    assert_eq!(ar.len(), l * p);
    assert_eq!(ai.len(), l * p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    for k in 0..l {
        let row = k * p;
        if cfg!(feature = "simd") {
            simd::scan_row_resume_bf16(
                &ar[row..row + p],
                &ai[row..row + p],
                sr,
                si,
                &mut bur[row..row + p],
                &mut bui[row..row + p],
            );
        } else {
            for j in 0..p {
                let nr = ar[row + j] * sr[j] - ai[row + j] * si[j] + bf16_to_f32(bur[row + j]);
                let ni = ar[row + j] * si[j] + ai[row + j] * sr[j] + bf16_to_f32(bui[row + j]);
                sr[j] = nr;
                si[j] = ni;
                bur[row + j] = f32_to_bf16(nr);
                bui[row + j] = f32_to_bf16(ni);
            }
        }
    }
}

/// Scratch elements a parallel interleaved scan needs for a given state
/// size and chunk-worker budget: 3 chunk-summary rows per chunk (ā-power,
/// local-final, enter) plus the combine state.
pub fn chunk_scratch_len(p: usize, threads: usize) -> usize {
    3 * threads.max(1) * p + p
}

/// Scratch elements a parallel planar scan needs (re+im planes for each of
/// the three summary rows, plus the two combine-state planes).
pub fn planar_scratch_len(p: usize, threads: usize) -> usize {
    6 * threads.max(1) * p + 2 * p
}

// s5:hot-end — the spawn-per-call convenience wrappers below allocate
// their own chunk summaries by design; the pooled forms stay fenced above.

/// Parallel chunked TI scan, in place, over exactly `threads` chunks
/// (clamped to L). Three phases (classic two-pass prefix scan, Blelloch
/// §1.4 at CPU chunk granularity):
///
///  1. each worker scans its chunk locally from x=0 in place and records
///     the chunk's composition (ā^len, local final state);
///  2. chunk summaries combine sequentially (T ≪ L elements);
///  3. each worker adds `ā^{k−start+1} ∘ x_enter` to its local states.
///
/// No small-L fallback: callers get the chunking they ask for (the
/// [`ParallelBackend`] applies the "sequential is faster below 4·T rows"
/// heuristic). Transient allocation is O(T·P) for the summaries; the
/// pooled form ([`scan_parallel_ti_inplace_pooled`]) allocates nothing.
/// Dispatches on scoped spawn-per-call threads — the backends route the
/// persistent worker pool through the pooled form's [`Executor`].
pub fn scan_parallel_ti_inplace(a: &[C32], bu: &mut [C32], l: usize, p: usize, threads: usize) {
    let mut scratch = vec![C32::ZERO; chunk_scratch_len(p, threads.min(l.max(1)))];
    scan_parallel_ti_inplace_pooled(a, bu, l, p, threads, &mut scratch, Executor::Scoped);
}

/// [`scan_parallel_ti_inplace`] with caller-owned chunk summaries and an
/// explicit shard dispatcher: `scratch` must hold at least
/// [`chunk_scratch_len`]`(p, threads)` elements (its contents are ignored
/// on entry and clobbered), and the parallel phases run on `exec` (pool,
/// scoped threads or inline — bit-identical results either way). The
/// engine routes its pooled [`ScanScratch`] buffers and the backend's
/// executor here so steady-state scans neither allocate nor spawn.
#[allow(clippy::too_many_arguments)]
pub fn scan_parallel_ti_inplace_pooled(
    a: &[C32],
    bu: &mut [C32],
    l: usize,
    p: usize,
    threads: usize,
    scratch: &mut [C32],
    exec: Executor<'_>,
) {
    assert_eq!(a.len(), p);
    assert_eq!(bu.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_sequential_ti_inplace(a, bu, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);
    let n = n_chunks * p;
    assert!(
        scratch.len() >= 3 * n + p,
        "parallel scan scratch too small: {} < {}",
        scratch.len(),
        3 * n + p
    );
    let (a_pow, rest) = scratch.split_at_mut(n);
    let (last, rest) = rest.split_at_mut(n);
    let (enter, rest) = rest.split_at_mut(n);
    let state = &mut rest[..p];

    // Phase 1: local in-place scans (parallel).
    exec.run_tasks(
        bu.chunks_mut(chunk * p)
            .zip(a_pow.chunks_mut(p))
            .zip(last.chunks_mut(p))
            .enumerate()
            .map(|(c, ((xc, ac), lc))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 1..len {
                        let (prev, cur) = xc.split_at_mut(k * p);
                        let prev = &prev[(k - 1) * p..];
                        for j in 0..p {
                            cur[j] = a[j] * prev[j] + cur[j];
                        }
                    }
                    for j in 0..p {
                        ac[j] = a[j].powi(len as u32);
                        lc[j] = xc[(len - 1) * p + j];
                    }
                }
            }),
    );

    // Phase 2: combine chunk summaries sequentially → state entering chunk c.
    {
        state.fill(C32::ZERO);
        for c in 0..n_chunks {
            enter[c * p..(c + 1) * p].copy_from_slice(state);
            for j in 0..p {
                state[j] = a_pow[c * p + j] * state[j] + last[c * p + j];
            }
        }
    }

    // Phase 3: fixup (parallel): x_k += ā^{k−start+1} ∘ x_enter. The enter
    // rows double as the carry accumulators. Chunk 0 enters at zero:
    // nothing to add, so it is skipped.
    exec.run_tasks(
        bu.chunks_mut(chunk * p)
            .zip(enter.chunks_mut(p))
            .enumerate()
            .skip(1)
            .map(|(c, (xc, carry))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 0..len {
                        let row = k * p;
                        for j in 0..p {
                            carry[j] = carry[j] * a[j];
                            xc[row + j] += carry[j];
                        }
                    }
                }
            }),
    );
}

/// Parallel chunked TV scan, in place (irregular sampling): `a`, `bu` are
/// (L, P). Same three phases as [`scan_parallel_ti_inplace`] with per-step
/// multiplier products as the chunk summaries.
pub fn scan_parallel_tv_inplace(a: &[C32], bu: &mut [C32], l: usize, p: usize, threads: usize) {
    let mut scratch = vec![C32::ZERO; chunk_scratch_len(p, threads.min(l.max(1)))];
    scan_parallel_tv_inplace_pooled(a, bu, l, p, threads, &mut scratch, Executor::Scoped);
}

/// [`scan_parallel_tv_inplace`] with caller-owned chunk summaries and an
/// explicit shard dispatcher (see [`scan_parallel_ti_inplace_pooled`] for
/// the scratch and executor contract).
#[allow(clippy::too_many_arguments)]
pub fn scan_parallel_tv_inplace_pooled(
    a: &[C32],
    bu: &mut [C32],
    l: usize,
    p: usize,
    threads: usize,
    scratch: &mut [C32],
    exec: Executor<'_>,
) {
    assert_eq!(a.len(), l * p);
    assert_eq!(bu.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_sequential_tv_inplace(a, bu, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);
    let n = n_chunks * p;
    assert!(
        scratch.len() >= 3 * n + p,
        "parallel scan scratch too small: {} < {}",
        scratch.len(),
        3 * n + p
    );
    let (a_prod, rest) = scratch.split_at_mut(n);
    let (last, rest) = rest.split_at_mut(n);
    let (enter, rest) = rest.split_at_mut(n);
    let state = &mut rest[..p];

    exec.run_tasks(
        bu.chunks_mut(chunk * p)
            .zip(a_prod.chunks_mut(p))
            .zip(last.chunks_mut(p))
            .enumerate()
            .map(|(c, ((xc, ac), lc))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    ac.fill(C32::ONE);
                    for k in 0..len {
                        let g = (start + k) * p;
                        if k > 0 {
                            let (prev, cur) = xc.split_at_mut(k * p);
                            let prev = &prev[(k - 1) * p..];
                            for j in 0..p {
                                cur[j] = a[g + j] * prev[j] + cur[j];
                            }
                        }
                        for j in 0..p {
                            ac[j] = a[g + j] * ac[j];
                        }
                    }
                    lc.copy_from_slice(&xc[(len - 1) * p..len * p]);
                }
            }),
    );

    {
        state.fill(C32::ZERO);
        for c in 0..n_chunks {
            enter[c * p..(c + 1) * p].copy_from_slice(state);
            for j in 0..p {
                state[j] = a_prod[c * p + j] * state[j] + last[c * p + j];
            }
        }
    }

    exec.run_tasks(
        bu.chunks_mut(chunk * p)
            .zip(enter.chunks_mut(p))
            .enumerate()
            .skip(1) // chunk 0 enters at zero: nothing to add
            .map(|(c, (xc, carry))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 0..len {
                        let g = (start + k) * p;
                        let row = k * p;
                        for j in 0..p {
                            carry[j] = a[g + j] * carry[j];
                            xc[row + j] += carry[j];
                        }
                    }
                }
            }),
    );
}

/// Parallel chunked TI scan in planar layout, in place: `ar`/`ai` length
/// P, `bur`/`bui` (L, P) planes. Identical phases, chunking and FP op
/// order to [`scan_parallel_ti_inplace_pooled`], so the two layouts agree
/// bit-for-bit. `scratch` must hold at least
/// [`planar_scratch_len`]`(p, threads)` elements; the parallel phases
/// dispatch on `exec` (results are executor-invariant).
#[allow(clippy::too_many_arguments)]
pub fn scan_parallel_ti_planar_inplace(
    ar: &[f32],
    ai: &[f32],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
    threads: usize,
    scratch: &mut [f32],
    exec: Executor<'_>,
) {
    assert_eq!(ar.len(), p);
    assert_eq!(ai.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_sequential_ti_planar_inplace(ar, ai, bur, bui, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);
    let n = n_chunks * p;
    assert!(
        scratch.len() >= 6 * n + 2 * p,
        "planar scan scratch too small: {} < {}",
        scratch.len(),
        6 * n + 2 * p
    );
    let (apw_r, rest) = scratch.split_at_mut(n);
    let (apw_i, rest) = rest.split_at_mut(n);
    let (last_r, rest) = rest.split_at_mut(n);
    let (last_i, rest) = rest.split_at_mut(n);
    let (ent_r, rest) = rest.split_at_mut(n);
    let (ent_i, rest) = rest.split_at_mut(n);
    let (st_r, rest) = rest.split_at_mut(p);
    let st_i = &mut rest[..p];

    // Phase 1: local in-place scans + chunk summaries (ā^len, local final).
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(apw_r.chunks_mut(p))
            .zip(apw_i.chunks_mut(p))
            .zip(last_r.chunks_mut(p))
            .zip(last_i.chunks_mut(p))
            .enumerate()
            .map(|(c, (((((xrc, xic), arc), aic), lrc), lic))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 1..len {
                        let row = k * p;
                        let (pr_all, cur_r) = xrc.split_at_mut(row);
                        let (pi_all, cur_i) = xic.split_at_mut(row);
                        let pr = &pr_all[row - p..];
                        let pi = &pi_all[row - p..];
                        if cfg!(feature = "simd") {
                            simd::scan_row_step(ar, ai, pr, pi, &mut cur_r[..p], &mut cur_i[..p]);
                        } else {
                            for j in 0..p {
                                let nr = ar[j] * pr[j] - ai[j] * pi[j] + cur_r[j];
                                let ni = ar[j] * pi[j] + ai[j] * pr[j] + cur_i[j];
                                cur_r[j] = nr;
                                cur_i[j] = ni;
                            }
                        }
                    }
                    for j in 0..p {
                        let apw = C32::new(ar[j], ai[j]).powi(len as u32);
                        arc[j] = apw.re;
                        aic[j] = apw.im;
                        lrc[j] = xrc[(len - 1) * p + j];
                        lic[j] = xic[(len - 1) * p + j];
                    }
                }
            }),
    );

    // Phase 2: combine chunk summaries sequentially → state entering chunk c.
    st_r.fill(0.0);
    st_i.fill(0.0);
    for c in 0..n_chunks {
        let row = c * p;
        ent_r[row..row + p].copy_from_slice(st_r);
        ent_i[row..row + p].copy_from_slice(st_i);
        if cfg!(feature = "simd") {
            simd::combine_row(
                &apw_r[row..row + p],
                &apw_i[row..row + p],
                &last_r[row..row + p],
                &last_i[row..row + p],
                st_r,
                st_i,
            );
        } else {
            for j in 0..p {
                let nr = apw_r[row + j] * st_r[j] - apw_i[row + j] * st_i[j] + last_r[row + j];
                let ni = apw_r[row + j] * st_i[j] + apw_i[row + j] * st_r[j] + last_i[row + j];
                st_r[j] = nr;
                st_i[j] = ni;
            }
        }
    }

    // Phase 3: fixup (parallel): x_k += ā^{k−start+1} ∘ x_enter. Chunk 0
    // enters at zero: nothing to add, so it is skipped.
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(ent_r.chunks_mut(p))
            .zip(ent_i.chunks_mut(p))
            .enumerate()
            .skip(1)
            .map(|(c, (((xrc, xic), crr), cri))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 0..len {
                        let row = k * p;
                        if cfg!(feature = "simd") {
                            let (xr_row, xi_row) =
                                (&mut xrc[row..row + p], &mut xic[row..row + p]);
                            simd::fixup_row(ar, ai, crr, cri, xr_row, xi_row);
                        } else {
                            for j in 0..p {
                                let nr = crr[j] * ar[j] - cri[j] * ai[j];
                                let ni = crr[j] * ai[j] + cri[j] * ar[j];
                                crr[j] = nr;
                                cri[j] = ni;
                                xrc[row + j] += nr;
                                xic[row + j] += ni;
                            }
                        }
                    }
                }
            }),
    );
}

/// Parallel chunked TV scan in planar layout, in place: all planes (L, P).
/// Mirrors [`scan_parallel_tv_inplace_pooled`] operation-for-operation.
#[allow(clippy::too_many_arguments)]
pub fn scan_parallel_tv_planar_inplace(
    ar: &[f32],
    ai: &[f32],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
    threads: usize,
    scratch: &mut [f32],
    exec: Executor<'_>,
) {
    assert_eq!(ar.len(), l * p);
    assert_eq!(ai.len(), l * p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_sequential_tv_planar_inplace(ar, ai, bur, bui, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);
    let n = n_chunks * p;
    assert!(
        scratch.len() >= 6 * n + 2 * p,
        "planar scan scratch too small: {} < {}",
        scratch.len(),
        6 * n + 2 * p
    );
    let (apd_r, rest) = scratch.split_at_mut(n);
    let (apd_i, rest) = rest.split_at_mut(n);
    let (last_r, rest) = rest.split_at_mut(n);
    let (last_i, rest) = rest.split_at_mut(n);
    let (ent_r, rest) = rest.split_at_mut(n);
    let (ent_i, rest) = rest.split_at_mut(n);
    let (st_r, rest) = rest.split_at_mut(p);
    let st_i = &mut rest[..p];

    // Phase 1: local scans + per-chunk multiplier products.
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(apd_r.chunks_mut(p))
            .zip(apd_i.chunks_mut(p))
            .zip(last_r.chunks_mut(p))
            .zip(last_i.chunks_mut(p))
            .enumerate()
            .map(|(c, (((((xrc, xic), arc), aic), lrc), lic))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    arc.fill(1.0);
                    aic.fill(0.0);
                    for k in 0..len {
                        let g = (start + k) * p;
                        if k > 0 {
                            let row = k * p;
                            let (pr_all, cur_r) = xrc.split_at_mut(row);
                            let (pi_all, cur_i) = xic.split_at_mut(row);
                            let pr = &pr_all[row - p..];
                            let pi = &pi_all[row - p..];
                            if cfg!(feature = "simd") {
                                simd::scan_row_step(
                                    &ar[g..g + p],
                                    &ai[g..g + p],
                                    pr,
                                    pi,
                                    &mut cur_r[..p],
                                    &mut cur_i[..p],
                                );
                            } else {
                                for j in 0..p {
                                    let nr = ar[g + j] * pr[j] - ai[g + j] * pi[j] + cur_r[j];
                                    let ni = ar[g + j] * pi[j] + ai[g + j] * pr[j] + cur_i[j];
                                    cur_r[j] = nr;
                                    cur_i[j] = ni;
                                }
                            }
                        }
                        if cfg!(feature = "simd") {
                            simd::cmul_row(&ar[g..g + p], &ai[g..g + p], arc, aic);
                        } else {
                            for j in 0..p {
                                let nr = ar[g + j] * arc[j] - ai[g + j] * aic[j];
                                let ni = ar[g + j] * aic[j] + ai[g + j] * arc[j];
                                arc[j] = nr;
                                aic[j] = ni;
                            }
                        }
                    }
                    lrc.copy_from_slice(&xrc[(len - 1) * p..len * p]);
                    lic.copy_from_slice(&xic[(len - 1) * p..len * p]);
                }
            }),
    );

    // Phase 2: combine chunk summaries sequentially.
    st_r.fill(0.0);
    st_i.fill(0.0);
    for c in 0..n_chunks {
        let row = c * p;
        ent_r[row..row + p].copy_from_slice(st_r);
        ent_i[row..row + p].copy_from_slice(st_i);
        if cfg!(feature = "simd") {
            simd::combine_row(
                &apd_r[row..row + p],
                &apd_i[row..row + p],
                &last_r[row..row + p],
                &last_i[row..row + p],
                st_r,
                st_i,
            );
        } else {
            for j in 0..p {
                let nr = apd_r[row + j] * st_r[j] - apd_i[row + j] * st_i[j] + last_r[row + j];
                let ni = apd_r[row + j] * st_i[j] + apd_i[row + j] * st_r[j] + last_i[row + j];
                st_r[j] = nr;
                st_i[j] = ni;
            }
        }
    }

    // Phase 3: fixup with per-step multipliers (chunk 0 skipped: it
    // enters at zero).
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(ent_r.chunks_mut(p))
            .zip(ent_i.chunks_mut(p))
            .enumerate()
            .skip(1)
            .map(|(c, (((xrc, xic), crr), cri))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 0..len {
                        let g = (start + k) * p;
                        let row = k * p;
                        if cfg!(feature = "simd") {
                            let (xr_row, xi_row) =
                                (&mut xrc[row..row + p], &mut xic[row..row + p]);
                            simd::fixup_row(&ar[g..g + p], &ai[g..g + p], crr, cri, xr_row, xi_row);
                        } else {
                            for j in 0..p {
                                let nr = ar[g + j] * crr[j] - ai[g + j] * cri[j];
                                let ni = ar[g + j] * cri[j] + ai[g + j] * crr[j];
                                crr[j] = nr;
                                cri[j] = ni;
                                xrc[row + j] += nr;
                                xic[row + j] += ni;
                            }
                        }
                    }
                }
            }),
    );
}

/// Chunked-parallel planar tile-resumable TI scan: the **in-tile wide
/// path** of the fused forward (`ScanPolicy::wide`). Splits the (L, P)
/// tile into `threads` chunks on `exec` and runs the same three phases as
/// [`scan_parallel_ti_planar_inplace`], except that the phase-2 combine is
/// *seeded* from the incoming carry `sr`/`si` instead of zero, so chunk 0
/// is fixed up too (its entering state is the live carry). On exit
/// `sr`/`si` hold the emitted final state row — the same carry contract as
/// [`scan_resume_ti_planar_inplace`].
///
/// Numerics: the chunk decomposition reassociates the carry propagation,
/// so the result is **not** bit-for-bit equal to the sequential resume
/// kernel — it is executor-invariant and chunking-deterministic (same
/// `threads` ⇒ same bits), and agrees with the sequential op order to
/// O(ε·L) rounding (tolerance-pinned in `tests/scan_matrix.rs`).
/// `threads == 1` falls back to the sequential resume kernel exactly.
///
/// `scratch` must hold [`planar_scratch_len`]`(p, threads)` elements.
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_ti_planar_par_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
    threads: usize,
    scratch: &mut [f32],
    exec: Executor<'_>,
) {
    assert_eq!(ar.len(), p);
    assert_eq!(ai.len(), p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_resume_ti_planar_inplace(ar, ai, sr, si, bur, bui, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);
    let n = n_chunks * p;
    assert!(
        scratch.len() >= 6 * n + 2 * p,
        "planar scan scratch too small: {} < {}",
        scratch.len(),
        6 * n + 2 * p
    );
    let (apw_r, rest) = scratch.split_at_mut(n);
    let (apw_i, rest) = rest.split_at_mut(n);
    let (last_r, rest) = rest.split_at_mut(n);
    let (last_i, rest) = rest.split_at_mut(n);
    let (ent_r, rest) = rest.split_at_mut(n);
    let (ent_i, rest) = rest.split_at_mut(n);
    let (st_r, rest) = rest.split_at_mut(p);
    let st_i = &mut rest[..p];

    // Phase 1: local in-place scans from zero + chunk summaries — identical
    // to the from-zero parallel kernel.
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(apw_r.chunks_mut(p))
            .zip(apw_i.chunks_mut(p))
            .zip(last_r.chunks_mut(p))
            .zip(last_i.chunks_mut(p))
            .enumerate()
            .map(|(c, (((((xrc, xic), arc), aic), lrc), lic))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 1..len {
                        let row = k * p;
                        let (pr_all, cur_r) = xrc.split_at_mut(row);
                        let (pi_all, cur_i) = xic.split_at_mut(row);
                        let pr = &pr_all[row - p..];
                        let pi = &pi_all[row - p..];
                        if cfg!(feature = "simd") {
                            simd::scan_row_step(ar, ai, pr, pi, &mut cur_r[..p], &mut cur_i[..p]);
                        } else {
                            for j in 0..p {
                                let nr = ar[j] * pr[j] - ai[j] * pi[j] + cur_r[j];
                                let ni = ar[j] * pi[j] + ai[j] * pr[j] + cur_i[j];
                                cur_r[j] = nr;
                                cur_i[j] = ni;
                            }
                        }
                    }
                    for j in 0..p {
                        let apw = C32::new(ar[j], ai[j]).powi(len as u32);
                        arc[j] = apw.re;
                        aic[j] = apw.im;
                        lrc[j] = xrc[(len - 1) * p + j];
                        lic[j] = xic[(len - 1) * p + j];
                    }
                }
            }),
    );

    // Phase 2: combine seeded from the incoming carry (the one line that
    // distinguishes this kernel from the from-zero parallel scan).
    st_r.copy_from_slice(sr);
    st_i.copy_from_slice(si);
    for c in 0..n_chunks {
        let row = c * p;
        ent_r[row..row + p].copy_from_slice(st_r);
        ent_i[row..row + p].copy_from_slice(st_i);
        if cfg!(feature = "simd") {
            simd::combine_row(
                &apw_r[row..row + p],
                &apw_i[row..row + p],
                &last_r[row..row + p],
                &last_i[row..row + p],
                st_r,
                st_i,
            );
        } else {
            for j in 0..p {
                let nr = apw_r[row + j] * st_r[j] - apw_i[row + j] * st_i[j] + last_r[row + j];
                let ni = apw_r[row + j] * st_i[j] + apw_i[row + j] * st_r[j] + last_i[row + j];
                st_r[j] = nr;
                st_i[j] = ni;
            }
        }
    }

    // Phase 3: fixup — every chunk participates (chunk 0's entering state
    // is the live carry, not zero).
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(ent_r.chunks_mut(p))
            .zip(ent_i.chunks_mut(p))
            .map(|(((xrc, xic), crr), cri)| {
                move || {
                    let len = xrc.len() / p;
                    for k in 0..len {
                        let row = k * p;
                        if cfg!(feature = "simd") {
                            let (xr_row, xi_row) =
                                (&mut xrc[row..row + p], &mut xic[row..row + p]);
                            simd::fixup_row(ar, ai, crr, cri, xr_row, xi_row);
                        } else {
                            for j in 0..p {
                                let nr = crr[j] * ar[j] - cri[j] * ai[j];
                                let ni = crr[j] * ai[j] + cri[j] * ar[j];
                                crr[j] = nr;
                                cri[j] = ni;
                                xrc[row + j] += nr;
                                xic[row + j] += ni;
                            }
                        }
                    }
                }
            }),
    );

    // Carry out: the state leaving the tile is the emitted final row (the
    // sequential resume contract — state ≡ last row, bit-for-bit).
    sr.copy_from_slice(&bur[(l - 1) * p..]);
    si.copy_from_slice(&bui[(l - 1) * p..]);
}

/// Chunked-parallel planar tile-resumable TV scan: irregular-Δt twin of
/// [`scan_resume_ti_planar_par_inplace`] (per-row multipliers, per-chunk
/// multiplier products instead of ā-powers). Same seeded-combine carry
/// contract and the same numerics caveat.
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_tv_planar_par_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    bur: &mut [f32],
    bui: &mut [f32],
    l: usize,
    p: usize,
    threads: usize,
    scratch: &mut [f32],
    exec: Executor<'_>,
) {
    assert_eq!(ar.len(), l * p);
    assert_eq!(ai.len(), l * p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_resume_tv_planar_inplace(ar, ai, sr, si, bur, bui, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);
    let n = n_chunks * p;
    assert!(
        scratch.len() >= 6 * n + 2 * p,
        "planar scan scratch too small: {} < {}",
        scratch.len(),
        6 * n + 2 * p
    );
    let (apd_r, rest) = scratch.split_at_mut(n);
    let (apd_i, rest) = rest.split_at_mut(n);
    let (last_r, rest) = rest.split_at_mut(n);
    let (last_i, rest) = rest.split_at_mut(n);
    let (ent_r, rest) = rest.split_at_mut(n);
    let (ent_i, rest) = rest.split_at_mut(n);
    let (st_r, rest) = rest.split_at_mut(p);
    let st_i = &mut rest[..p];

    // Phase 1: local scans + per-chunk multiplier products.
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(apd_r.chunks_mut(p))
            .zip(apd_i.chunks_mut(p))
            .zip(last_r.chunks_mut(p))
            .zip(last_i.chunks_mut(p))
            .enumerate()
            .map(|(c, (((((xrc, xic), arc), aic), lrc), lic))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    arc.fill(1.0);
                    aic.fill(0.0);
                    for k in 0..len {
                        let g = (start + k) * p;
                        if k > 0 {
                            let row = k * p;
                            let (pr_all, cur_r) = xrc.split_at_mut(row);
                            let (pi_all, cur_i) = xic.split_at_mut(row);
                            let pr = &pr_all[row - p..];
                            let pi = &pi_all[row - p..];
                            if cfg!(feature = "simd") {
                                simd::scan_row_step(
                                    &ar[g..g + p],
                                    &ai[g..g + p],
                                    pr,
                                    pi,
                                    &mut cur_r[..p],
                                    &mut cur_i[..p],
                                );
                            } else {
                                for j in 0..p {
                                    let nr = ar[g + j] * pr[j] - ai[g + j] * pi[j] + cur_r[j];
                                    let ni = ar[g + j] * pi[j] + ai[g + j] * pr[j] + cur_i[j];
                                    cur_r[j] = nr;
                                    cur_i[j] = ni;
                                }
                            }
                        }
                        if cfg!(feature = "simd") {
                            simd::cmul_row(&ar[g..g + p], &ai[g..g + p], arc, aic);
                        } else {
                            for j in 0..p {
                                let nr = ar[g + j] * arc[j] - ai[g + j] * aic[j];
                                let ni = ar[g + j] * aic[j] + ai[g + j] * arc[j];
                                arc[j] = nr;
                                aic[j] = ni;
                            }
                        }
                    }
                    lrc.copy_from_slice(&xrc[(len - 1) * p..len * p]);
                    lic.copy_from_slice(&xic[(len - 1) * p..len * p]);
                }
            }),
    );

    // Phase 2: combine seeded from the incoming carry.
    st_r.copy_from_slice(sr);
    st_i.copy_from_slice(si);
    for c in 0..n_chunks {
        let row = c * p;
        ent_r[row..row + p].copy_from_slice(st_r);
        ent_i[row..row + p].copy_from_slice(st_i);
        if cfg!(feature = "simd") {
            simd::combine_row(
                &apd_r[row..row + p],
                &apd_i[row..row + p],
                &last_r[row..row + p],
                &last_i[row..row + p],
                st_r,
                st_i,
            );
        } else {
            for j in 0..p {
                let nr = apd_r[row + j] * st_r[j] - apd_i[row + j] * st_i[j] + last_r[row + j];
                let ni = apd_r[row + j] * st_i[j] + apd_i[row + j] * st_r[j] + last_i[row + j];
                st_r[j] = nr;
                st_i[j] = ni;
            }
        }
    }

    // Phase 3: fixup with per-step multipliers — every chunk participates.
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(ent_r.chunks_mut(p))
            .zip(ent_i.chunks_mut(p))
            .enumerate()
            .map(|(c, (((xrc, xic), crr), cri))| {
                move || {
                    let start = c * chunk;
                    let len = xrc.len() / p;
                    for k in 0..len {
                        let g = (start + k) * p;
                        let row = k * p;
                        if cfg!(feature = "simd") {
                            let (xr_row, xi_row) =
                                (&mut xrc[row..row + p], &mut xic[row..row + p]);
                            simd::fixup_row(&ar[g..g + p], &ai[g..g + p], crr, cri, xr_row, xi_row);
                        } else {
                            for j in 0..p {
                                let nr = ar[g + j] * crr[j] - ai[g + j] * cri[j];
                                let ni = ar[g + j] * cri[j] + ai[g + j] * crr[j];
                                crr[j] = nr;
                                cri[j] = ni;
                                xrc[row + j] += nr;
                                xic[row + j] += ni;
                            }
                        }
                    }
                }
            }),
    );

    // Carry out: state ≡ emitted final row.
    sr.copy_from_slice(&bur[(l - 1) * p..]);
    si.copy_from_slice(&bui[(l - 1) * p..]);
}

/// Chunked-parallel bf16-storage tile-resumable TI scan: the in-tile wide
/// path over bfloat16 planes. Same three-phase structure as
/// [`scan_resume_ti_planar_par_inplace`], with two storage-driven
/// differences: phase 1 runs each chunk in *resume form* from a zeroed
/// **f32** local carry held in the chunk-summary scratch rows — never by
/// re-reading the narrowed previous plane row, which would compound the
/// 2⁻⁸ storage rounding across the chunk — and the carry-out is the f32
/// combine state rather than a widened final row, so the state leaving
/// the tile carries no storage rounding. Consequently `sr`/`si` on exit
/// are *not* bitwise the widened last row (unlike the f32 kernel's carry
/// ≡ row contract); tests pin tolerance agreement with
/// [`scan_resume_ti_planar_bf16_inplace`], executor invariance, and the
/// exact `threads == 1` fallback to the sequential bf16 kernel.
///
/// `scratch` must hold [`planar_scratch_len`]`(p, threads)` f32 elements.
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_ti_planar_par_bf16_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    bur: &mut [Bf16],
    bui: &mut [Bf16],
    l: usize,
    p: usize,
    threads: usize,
    scratch: &mut [f32],
    exec: Executor<'_>,
) {
    assert_eq!(ar.len(), p);
    assert_eq!(ai.len(), p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_resume_ti_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);
    let n = n_chunks * p;
    assert!(
        scratch.len() >= 6 * n + 2 * p,
        "planar scan scratch too small: {} < {}",
        scratch.len(),
        6 * n + 2 * p
    );
    let (apw_r, rest) = scratch.split_at_mut(n);
    let (apw_i, rest) = rest.split_at_mut(n);
    let (last_r, rest) = rest.split_at_mut(n);
    let (last_i, rest) = rest.split_at_mut(n);
    let (ent_r, rest) = rest.split_at_mut(n);
    let (ent_i, rest) = rest.split_at_mut(n);
    let (st_r, rest) = rest.split_at_mut(p);
    let st_i = &mut rest[..p];

    // Phase 1: local resume-form scans from a zeroed f32 carry. The
    // last_r/last_i summary rows double as the live carry, so the local
    // final state is exact f32 even though every emitted row narrows.
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(apw_r.chunks_mut(p))
            .zip(apw_i.chunks_mut(p))
            .zip(last_r.chunks_mut(p))
            .zip(last_i.chunks_mut(p))
            .enumerate()
            .map(|(c, (((((xrc, xic), arc), aic), lrc), lic))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    lrc.fill(0.0);
                    lic.fill(0.0);
                    for k in 0..len {
                        let row = k * p;
                        if cfg!(feature = "simd") {
                            simd::scan_row_resume_bf16(
                                ar,
                                ai,
                                lrc,
                                lic,
                                &mut xrc[row..row + p],
                                &mut xic[row..row + p],
                            );
                        } else {
                            for j in 0..p {
                                let nr = ar[j] * lrc[j] - ai[j] * lic[j]
                                    + bf16_to_f32(xrc[row + j]);
                                let ni = ar[j] * lic[j] + ai[j] * lrc[j]
                                    + bf16_to_f32(xic[row + j]);
                                lrc[j] = nr;
                                lic[j] = ni;
                                xrc[row + j] = f32_to_bf16(nr);
                                xic[row + j] = f32_to_bf16(ni);
                            }
                        }
                    }
                    for j in 0..p {
                        let apw = C32::new(ar[j], ai[j]).powi(len as u32);
                        arc[j] = apw.re;
                        aic[j] = apw.im;
                    }
                }
            }),
    );

    // Phase 2: combine seeded from the incoming carry — pure f32, the
    // identical per-row op of the f32 kernel.
    st_r.copy_from_slice(sr);
    st_i.copy_from_slice(si);
    for c in 0..n_chunks {
        let row = c * p;
        ent_r[row..row + p].copy_from_slice(st_r);
        ent_i[row..row + p].copy_from_slice(st_i);
        if cfg!(feature = "simd") {
            simd::combine_row(
                &apw_r[row..row + p],
                &apw_i[row..row + p],
                &last_r[row..row + p],
                &last_i[row..row + p],
                st_r,
                st_i,
            );
        } else {
            for j in 0..p {
                let nr = apw_r[row + j] * st_r[j] - apw_i[row + j] * st_i[j] + last_r[row + j];
                let ni = apw_r[row + j] * st_i[j] + apw_i[row + j] * st_r[j] + last_i[row + j];
                st_r[j] = nr;
                st_i[j] = ni;
            }
        }
    }

    // Phase 3: fixup — every chunk participates; the correction advances
    // in f32 and each touched row widens, adds, and re-narrows once.
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(ent_r.chunks_mut(p))
            .zip(ent_i.chunks_mut(p))
            .map(|(((xrc, xic), crr), cri)| {
                move || {
                    let len = xrc.len() / p;
                    for k in 0..len {
                        let row = k * p;
                        if cfg!(feature = "simd") {
                            let (xr_row, xi_row) =
                                (&mut xrc[row..row + p], &mut xic[row..row + p]);
                            simd::fixup_row_bf16(ar, ai, crr, cri, xr_row, xi_row);
                        } else {
                            for j in 0..p {
                                let nr = crr[j] * ar[j] - cri[j] * ai[j];
                                let ni = crr[j] * ai[j] + cri[j] * ar[j];
                                crr[j] = nr;
                                cri[j] = ni;
                                let xr = bf16_to_f32(xrc[row + j]) + nr;
                                let xi = bf16_to_f32(xic[row + j]) + ni;
                                xrc[row + j] = f32_to_bf16(xr);
                                xic[row + j] = f32_to_bf16(xi);
                            }
                        }
                    }
                }
            }),
    );

    // Carry out: the f32 combine state — storage-rounding-free, unlike
    // the widened final row (see the kernel docs).
    sr.copy_from_slice(st_r);
    si.copy_from_slice(st_i);
}

/// Chunked-parallel bf16-storage tile-resumable TV scan: irregular-Δt
/// twin of [`scan_resume_ti_planar_par_bf16_inplace`] (per-row f32
/// multipliers, per-chunk multiplier products instead of ā-powers). Same
/// f32-carry phase structure and the same carry-out contract.
#[allow(clippy::too_many_arguments)]
pub fn scan_resume_tv_planar_par_bf16_inplace(
    ar: &[f32],
    ai: &[f32],
    sr: &mut [f32],
    si: &mut [f32],
    bur: &mut [Bf16],
    bui: &mut [Bf16],
    l: usize,
    p: usize,
    threads: usize,
    scratch: &mut [f32],
    exec: Executor<'_>,
) {
    assert_eq!(ar.len(), l * p);
    assert_eq!(ai.len(), l * p);
    assert_eq!(sr.len(), p);
    assert_eq!(si.len(), p);
    assert_eq!(bur.len(), l * p);
    assert_eq!(bui.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_resume_tv_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);
    let n = n_chunks * p;
    assert!(
        scratch.len() >= 6 * n + 2 * p,
        "planar scan scratch too small: {} < {}",
        scratch.len(),
        6 * n + 2 * p
    );
    let (apd_r, rest) = scratch.split_at_mut(n);
    let (apd_i, rest) = rest.split_at_mut(n);
    let (last_r, rest) = rest.split_at_mut(n);
    let (last_i, rest) = rest.split_at_mut(n);
    let (ent_r, rest) = rest.split_at_mut(n);
    let (ent_i, rest) = rest.split_at_mut(n);
    let (st_r, rest) = rest.split_at_mut(p);
    let st_i = &mut rest[..p];

    // Phase 1: local resume-form scans from a zeroed f32 carry, plus the
    // per-chunk multiplier products.
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(apd_r.chunks_mut(p))
            .zip(apd_i.chunks_mut(p))
            .zip(last_r.chunks_mut(p))
            .zip(last_i.chunks_mut(p))
            .enumerate()
            .map(|(c, (((((xrc, xic), arc), aic), lrc), lic))| {
                move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    lrc.fill(0.0);
                    lic.fill(0.0);
                    arc.fill(1.0);
                    aic.fill(0.0);
                    for k in 0..len {
                        let g = (start + k) * p;
                        let row = k * p;
                        if cfg!(feature = "simd") {
                            simd::scan_row_resume_bf16(
                                &ar[g..g + p],
                                &ai[g..g + p],
                                lrc,
                                lic,
                                &mut xrc[row..row + p],
                                &mut xic[row..row + p],
                            );
                        } else {
                            for j in 0..p {
                                let nr = ar[g + j] * lrc[j] - ai[g + j] * lic[j]
                                    + bf16_to_f32(xrc[row + j]);
                                let ni = ar[g + j] * lic[j] + ai[g + j] * lrc[j]
                                    + bf16_to_f32(xic[row + j]);
                                lrc[j] = nr;
                                lic[j] = ni;
                                xrc[row + j] = f32_to_bf16(nr);
                                xic[row + j] = f32_to_bf16(ni);
                            }
                        }
                        if cfg!(feature = "simd") {
                            simd::cmul_row(&ar[g..g + p], &ai[g..g + p], arc, aic);
                        } else {
                            for j in 0..p {
                                let nr = ar[g + j] * arc[j] - ai[g + j] * aic[j];
                                let ni = ar[g + j] * aic[j] + ai[g + j] * arc[j];
                                arc[j] = nr;
                                aic[j] = ni;
                            }
                        }
                    }
                }
            }),
    );

    // Phase 2: combine seeded from the incoming carry.
    st_r.copy_from_slice(sr);
    st_i.copy_from_slice(si);
    for c in 0..n_chunks {
        let row = c * p;
        ent_r[row..row + p].copy_from_slice(st_r);
        ent_i[row..row + p].copy_from_slice(st_i);
        if cfg!(feature = "simd") {
            simd::combine_row(
                &apd_r[row..row + p],
                &apd_i[row..row + p],
                &last_r[row..row + p],
                &last_i[row..row + p],
                st_r,
                st_i,
            );
        } else {
            for j in 0..p {
                let nr = apd_r[row + j] * st_r[j] - apd_i[row + j] * st_i[j] + last_r[row + j];
                let ni = apd_r[row + j] * st_i[j] + apd_i[row + j] * st_r[j] + last_i[row + j];
                st_r[j] = nr;
                st_i[j] = ni;
            }
        }
    }

    // Phase 3: fixup with per-step multipliers — every chunk participates.
    exec.run_tasks(
        bur.chunks_mut(chunk * p)
            .zip(bui.chunks_mut(chunk * p))
            .zip(ent_r.chunks_mut(p))
            .zip(ent_i.chunks_mut(p))
            .enumerate()
            .map(|(c, (((xrc, xic), crr), cri))| {
                move || {
                    let start = c * chunk;
                    let len = xrc.len() / p;
                    for k in 0..len {
                        let g = (start + k) * p;
                        let row = k * p;
                        if cfg!(feature = "simd") {
                            let (xr_row, xi_row) =
                                (&mut xrc[row..row + p], &mut xic[row..row + p]);
                            simd::fixup_row_bf16(
                                &ar[g..g + p],
                                &ai[g..g + p],
                                crr,
                                cri,
                                xr_row,
                                xi_row,
                            );
                        } else {
                            for j in 0..p {
                                let nr = ar[g + j] * crr[j] - ai[g + j] * cri[j];
                                let ni = ar[g + j] * cri[j] + ai[g + j] * crr[j];
                                crr[j] = nr;
                                cri[j] = ni;
                                let xr = bf16_to_f32(xrc[row + j]) + nr;
                                let xi = bf16_to_f32(xic[row + j]) + ni;
                                xrc[row + j] = f32_to_bf16(xr);
                                xic[row + j] = f32_to_bf16(xi);
                            }
                        }
                    }
                }
            }),
    );

    // Carry out: the f32 combine state (see the TI kernel docs).
    sr.copy_from_slice(st_r);
    si.copy_from_slice(st_i);
}

// ---------------------------------------------------------------------------
// Pooled scratch for the parallel kernels' chunk summaries
// ---------------------------------------------------------------------------

/// Reusable chunk-summary buffers for the parallel scan kernels, pooled so
/// steady-state inference performs zero heap allocation (ROADMAP item: the
/// O(threads·P) summaries used to be allocated fresh per call).
///
/// One `ScanScratch` belongs to one driving thread (it lives inside
/// [`crate::ssm::engine::EngineWorkspace`]); the per-worker inner buffers
/// exist because a batched scan with B < threads runs up to B chunked
/// scans *concurrently*, each needing its own summaries. The `reserve_*`
/// methods grow every worker to the worst case any (B, L) sharding of the
/// backend's thread budget can need — worker `i` only ever runs with a
/// sub-budget of `threads / (i + 1)` chunk-workers — so capacity is stable
/// after the first call regardless of which branch later calls take.
#[derive(Default)]
pub struct ScanScratch {
    /// per concurrent chunked scan: interleaved summaries
    c: Vec<Vec<C32>>,
    /// per concurrent chunked scan: planar summaries
    f: Vec<Vec<f32>>,
}

impl ScanScratch {
    pub fn new() -> ScanScratch {
        ScanScratch::default()
    }

    fn c_workers(&mut self, n: usize) -> &mut [Vec<C32>] {
        if self.c.len() < n {
            self.c.resize_with(n, Vec::new);
        }
        &mut self.c[..n]
    }

    pub(crate) fn f_workers(&mut self, n: usize) -> &mut [Vec<f32>] {
        if self.f.len() < n {
            self.f.resize_with(n, Vec::new);
        }
        &mut self.f[..n]
    }

    fn reserve_interleaved(&mut self, p: usize, threads: usize) {
        let t = threads.max(1);
        for (i, w) in self.c_workers(t).iter_mut().enumerate() {
            let need = chunk_scratch_len(p, t / (i + 1));
            if w.len() < need {
                w.resize(need, C32::ZERO);
            }
        }
    }

    pub(crate) fn reserve_planar(&mut self, p: usize, threads: usize) {
        let t = threads.max(1);
        for (i, w) in self.f_workers(t).iter_mut().enumerate() {
            let need = planar_scratch_len(p, t / (i + 1));
            if w.len() < need {
                w.resize(need, 0.0);
            }
        }
    }

    /// Heap bytes currently held (capacity, not length).
    pub fn capacity_bytes(&self) -> usize {
        self.c.capacity() * std::mem::size_of::<Vec<C32>>()
            + self.f.capacity() * std::mem::size_of::<Vec<f32>>()
            + self.c.iter().map(|w| w.capacity() * 8).sum::<usize>()
            + self.f.iter().map(|w| w.capacity() * 4).sum::<usize>()
    }
}

/// Which buffer layout the engine should drive a backend with.
///
/// Both families of entry points exist on every [`ScanBackend`]; this is
/// the backend's *preference*, consulted by the S5 forward path when it
/// decides whether to materialize planar or interleaved drive buffers.
/// [`Planar`](ScanLayout::Planar) is the default everywhere (SIMD-friendly
/// separate re/im planes); [`Interleaved`](ScanLayout::Interleaved) keeps
/// the original `[C32]` path alive as the reference oracle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanLayout {
    Planar,
    Interleaved,
}

// ---------------------------------------------------------------------------
// ScanBackend: the pluggable strategy the engine threads through the stack
// ---------------------------------------------------------------------------

/// Object-safe scan strategy.
///
/// One backend object serves every scan shape in the native stack, in both
/// memory layouts:
///
/// * `scan_ti` / `scan_tv` (+ `_planar`) — one sequence, in place over the
///   drive buffer;
/// * `scan_batch_ti` / `scan_batch_tv` (+ `_planar`) — a packed (B, L, P)
///   row-major batch, each sequence scanned independently (backends
///   parallelize across B sequences × in-sequence chunks);
/// * `scan_step` / `scan_step_planar` — the single-step recurrence online
///   generation uses, so streaming and offline scans share one inner
///   kernel.
///
/// The `_planar` family takes separate re/im `f32` planes (SIMD-friendly
/// struct-of-arrays); the engine consults [`ScanBackend::layout`] to decide
/// which family to drive. All entry points overwrite the drive with the
/// states; parallel strategies take their O(threads·P) chunk summaries from
/// the caller's pooled [`ScanScratch`], so steady-state scans allocate
/// nothing.
#[allow(clippy::too_many_arguments)]
pub trait ScanBackend: Send + Sync {
    /// Short human-readable strategy name (for benches/telemetry).
    fn name(&self) -> &'static str;

    /// Worker-thread budget this backend schedules onto (1 = sequential).
    fn threads(&self) -> usize;

    /// Buffer layout the engine should drive this backend with.
    fn layout(&self) -> ScanLayout {
        ScanLayout::Planar
    }

    /// How this backend (and every engine stage driven by it) dispatches
    /// shard closures. The default is the pre-pool spawn-per-call scoped
    /// fallback; [`SequentialBackend`] runs inline and
    /// [`ParallelBackend`] dispatches onto the persistent worker pool
    /// unless configured otherwise (see [`ScanExec`]). The executor never
    /// affects the shard decomposition, so results are bit-for-bit
    /// executor-invariant.
    fn executor(&self) -> Executor<'_> {
        Executor::Scoped
    }

    /// Time-invariant scan of one sequence: `a` (P), `bu` (L, P) in/out.
    fn scan_ti(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize, scratch: &mut ScanScratch);

    /// Time-varying scan of one sequence: `a`, `bu` (L, P) in/out.
    fn scan_tv(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize, scratch: &mut ScanScratch);

    /// Batched TI scan: `a` (P) shared, `bu` (B, L, P) in/out.
    #[allow(clippy::too_many_arguments)]
    fn scan_batch_ti(
        &self,
        a: &[C32],
        bu: &mut [C32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        assert_eq!(bu.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        for seq in bu.chunks_mut(l * p) {
            self.scan_ti(a, seq, l, p, scratch);
        }
    }

    /// Batched TV scan: `a`, `bu` both (B, L, P), `bu` in/out.
    #[allow(clippy::too_many_arguments)]
    fn scan_batch_tv(
        &self,
        a: &[C32],
        bu: &mut [C32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        assert_eq!(a.len(), batch * l * p);
        assert_eq!(bu.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        for (aseq, seq) in a.chunks(l * p).zip(bu.chunks_mut(l * p)) {
            self.scan_tv(aseq, seq, l, p, scratch);
        }
    }

    /// One streaming step `state ← a ∘ state + b` (online generation §3.3).
    fn scan_step(&self, a: &[C32], state: &mut [C32], b: &[C32]) {
        scan_step_inplace(a, state, b);
    }

    /// Planar TI scan of one sequence: `ar`/`ai` (P), `bur`/`bui` (L, P)
    /// planes, in/out.
    #[allow(clippy::too_many_arguments)]
    fn scan_ti_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    );

    /// Planar TV scan of one sequence: all planes (L, P), drive in/out.
    #[allow(clippy::too_many_arguments)]
    fn scan_tv_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    );

    /// Batched planar TI scan: `ar`/`ai` (P) shared, `bur`/`bui` (B, L, P)
    /// planes in/out.
    #[allow(clippy::too_many_arguments)]
    fn scan_batch_ti_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        assert_eq!(bur.len(), batch * l * p);
        assert_eq!(bui.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        for (sr, si) in bur.chunks_mut(l * p).zip(bui.chunks_mut(l * p)) {
            self.scan_ti_planar(ar, ai, sr, si, l, p, scratch);
        }
    }

    /// Batched planar TV scan: `ar`/`ai` and `bur`/`bui` all (B, L, P)
    /// planes, drive in/out.
    #[allow(clippy::too_many_arguments)]
    fn scan_batch_tv_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        assert_eq!(ar.len(), batch * l * p);
        assert_eq!(ai.len(), batch * l * p);
        assert_eq!(bur.len(), batch * l * p);
        assert_eq!(bui.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        let rows = l * p;
        for (((arseq, aiseq), sr), si) in ar
            .chunks(rows)
            .zip(ai.chunks(rows))
            .zip(bur.chunks_mut(rows))
            .zip(bui.chunks_mut(rows))
        {
            self.scan_tv_planar(arseq, aiseq, sr, si, l, p, scratch);
        }
    }

    /// One planar streaming step over separate re/im planes.
    fn scan_step_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        br: &[f32],
        bi: &[f32],
    ) {
        scan_step_planar_inplace(ar, ai, sr, si, br, bi);
    }

    /// Tile-resumable TI scan (interleaved): scan an (L, P) tile from a
    /// carried `state`, leaving the post-tile state in `state` — the
    /// multi-row generalization of [`ScanBackend::scan_step`] the fused
    /// cache-blocked forward carries state across tile boundaries with.
    ///
    /// The default in-tile scan is sequential (the rows of one tile are
    /// data-dependent) and fused-path parallelism comes from sharding
    /// (sequence × direction) tile pipelines across the executor. When
    /// those units can't cover the worker budget, the fused path can
    /// instead go wide *inside* the tile via
    /// [`ScanBackend::scan_ti_planar_resume_par`] — a chunked parallel
    /// scan seeded from the carry (opt-in through `ScanPolicy::wide`,
    /// because the chunked combine reassociates the carry propagation and
    /// therefore trades the bit-for-bit fused ≡ staged pin for a
    /// tolerance pin).
    fn scan_ti_resume(&self, a: &[C32], state: &mut [C32], bu: &mut [C32], l: usize, p: usize) {
        scan_resume_ti_inplace(a, state, bu, l, p);
    }

    /// Tile-resumable TV scan (interleaved): `a`, `bu` are (L, P) tile
    /// rows; see [`ScanBackend::scan_ti_resume`].
    fn scan_tv_resume(&self, a: &[C32], state: &mut [C32], bu: &mut [C32], l: usize, p: usize) {
        scan_resume_tv_inplace(a, state, bu, l, p);
    }

    /// Tile-resumable planar TI scan: `sr`/`si` carry the state in/out
    /// (see [`ScanBackend::scan_ti_resume`]). This is the entry point the
    /// fused forward and the chunked-prefill streaming path drive; its
    /// per-row op is exactly [`ScanBackend::scan_step_planar`], so tiled
    /// prefill ≡ step replay bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    fn scan_ti_planar_resume(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
    ) {
        scan_resume_ti_planar_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    /// Tile-resumable planar TV scan: all planes are (L, P) tile rows.
    #[allow(clippy::too_many_arguments)]
    fn scan_tv_planar_resume(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
    ) {
        scan_resume_tv_planar_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    /// Tile-resumable planar TI scan that may split the tile into
    /// `threads` chunks scanned in parallel and stitched through the
    /// seeded combine ([`scan_resume_ti_planar_par_inplace`]) — the
    /// single-stream saturation path. `threads` is the per-tile worker
    /// budget *granted by the caller* (the fused path hands each unit its
    /// share of the backend budget), not the backend's own thread count;
    /// `scratch` is a caller-owned buffer grown as needed (pooled by the
    /// engine workspace, so steady state allocates nothing).
    ///
    /// The default ignores the budget and stays sequential — bitwise
    /// identical to [`ScanBackend::scan_ti_planar_resume`]. Backends that
    /// override it (the parallel planar strategies) return chunked
    /// results: executor-invariant and deterministic for a fixed budget,
    /// tolerance-pinned against the sequential op order.
    #[allow(clippy::too_many_arguments)]
    fn scan_ti_planar_resume_par(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        let _ = (threads, &scratch);
        scan_resume_ti_planar_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    /// TV twin of [`ScanBackend::scan_ti_planar_resume_par`].
    #[allow(clippy::too_many_arguments)]
    fn scan_tv_planar_resume_par(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        let _ = (threads, &scratch);
        scan_resume_tv_planar_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    /// Tile-resumable planar TI scan over **bf16 storage planes**: f32
    /// carry in `sr`/`si`, bfloat16 (L, P) drive/state rows. Every
    /// backend runs the sequential load-widen/compute/narrow-store kernel
    /// ([`scan_resume_ti_planar_bf16_inplace`]) — the op order is the
    /// same everywhere, so this entry point is backend-invariant
    /// bit-for-bit (in-tile parallelism goes through
    /// [`ScanBackend::scan_ti_planar_resume_par_bf16`] instead).
    #[allow(clippy::too_many_arguments)]
    fn scan_ti_planar_resume_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
    ) {
        scan_resume_ti_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    /// TV twin of [`ScanBackend::scan_ti_planar_resume_bf16`].
    #[allow(clippy::too_many_arguments)]
    fn scan_tv_planar_resume_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
    ) {
        scan_resume_tv_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    /// bf16-storage twin of [`ScanBackend::scan_ti_planar_resume_par`]:
    /// the default ignores the budget and stays sequential (bitwise
    /// identical to [`ScanBackend::scan_ti_planar_resume_bf16`]); the
    /// parallel planar backend overrides it with the chunked bf16 kernel
    /// ([`scan_resume_ti_planar_par_bf16_inplace`]), whose carry-out is
    /// the f32 combine state — tolerance-pinned, executor-invariant.
    #[allow(clippy::too_many_arguments)]
    fn scan_ti_planar_resume_par_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        let _ = (threads, &scratch);
        scan_resume_ti_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    /// TV twin of [`ScanBackend::scan_ti_planar_resume_par_bf16`].
    #[allow(clippy::too_many_arguments)]
    fn scan_tv_planar_resume_par_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        let _ = (threads, &scratch);
        scan_resume_tv_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
    }
}

/// The literal O(L·P) loop (ground truth; also the online-generation mode
/// of §3.3 at L = 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct SequentialBackend;

#[allow(clippy::too_many_arguments)]
impl ScanBackend for SequentialBackend {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn threads(&self) -> usize {
        1
    }

    fn executor(&self) -> Executor<'_> {
        Executor::Inline
    }

    fn scan_ti(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize, _scratch: &mut ScanScratch) {
        scan_sequential_ti_inplace(a, bu, l, p);
    }

    fn scan_tv(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize, _scratch: &mut ScanScratch) {
        scan_sequential_tv_inplace(a, bu, l, p);
    }

    fn scan_ti_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        _scratch: &mut ScanScratch,
    ) {
        scan_sequential_ti_planar_inplace(ar, ai, bur, bui, l, p);
    }

    fn scan_tv_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        _scratch: &mut ScanScratch,
    ) {
        scan_sequential_tv_planar_inplace(ar, ai, bur, bui, l, p);
    }
}

/// How a [`ParallelBackend`] dispatches its shard closures — the knob
/// behind "pooled by default, scoped/inline on request".
///
/// Every mode runs the identical shard closures over the identical
/// decomposition (fixed by the backend's thread budget), so the results
/// are bit-for-bit mode-invariant; `tests/scan_matrix.rs` pins this.
#[derive(Clone, Default)]
pub enum ScanExec {
    /// The process-wide persistent worker pool
    /// ([`crate::runtime::pool::global_pool`]) — the default everywhere
    /// ([`backend_for_threads`], the native server).
    #[default]
    Pooled,
    /// A dedicated pool instance (tests, isolated serving pools).
    Pool(Arc<WorkerPool>),
    /// Spawn scoped threads per call — the pre-pool dispatch, kept as
    /// the opt-out and as the bench baseline.
    Scoped,
    /// Run every shard inline on the caller thread (deterministic
    /// single-threaded execution of the same chunked decomposition).
    Inline,
}

impl std::fmt::Debug for ScanExec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ScanExec::Pooled => "pooled",
            ScanExec::Pool(_) => "pool",
            ScanExec::Scoped => "scoped",
            ScanExec::Inline => "inline",
        })
    }
}

/// Multi-threaded backend: chunked Blelloch scan within a sequence,
/// sequence-sharding across a batch.
///
/// Heuristics: a single sequence falls back to the sequential kernel below
/// 4·T rows (chunk bookkeeping would dominate); a batch with B ≥ T shards
/// whole sequences across workers (embarrassingly parallel, no fixup
/// phase); a batch with B < T gives each sequence ⌊T/B⌋ chunk-workers.
///
/// Shards dispatch on the configured [`ScanExec`] — the persistent
/// worker pool by default, so steady-state serving never spawns a
/// thread.
#[derive(Clone, Debug)]
pub struct ParallelBackend {
    threads: usize,
    exec: ScanExec,
}

impl ParallelBackend {
    /// `threads = 0` auto-detects via `std::thread::available_parallelism`.
    /// Dispatches on the process-wide persistent pool ([`ScanExec::Pooled`]).
    pub fn new(threads: usize) -> ParallelBackend {
        ParallelBackend::with_exec(threads, ScanExec::Pooled)
    }

    /// A backend with an explicit dispatch mode (`threads = 0`
    /// auto-detects).
    pub fn with_exec(threads: usize, exec: ScanExec) -> ParallelBackend {
        ParallelBackend { threads: crate::ssm::engine::auto_threads(threads), exec }
    }
}

#[allow(clippy::too_many_arguments)]
impl ScanBackend for ParallelBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn executor(&self) -> Executor<'_> {
        match &self.exec {
            ScanExec::Pooled => Executor::Pool(global_pool()),
            ScanExec::Pool(pool) => Executor::Pool(pool.as_ref()),
            ScanExec::Scoped => Executor::Scoped,
            ScanExec::Inline => Executor::Inline,
        }
    }

    fn scan_ti(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize, scratch: &mut ScanScratch) {
        scratch.reserve_interleaved(p, self.threads);
        if self.threads <= 1 || l < 4 * self.threads {
            scan_sequential_ti_inplace(a, bu, l, p);
        } else {
            let ex = self.executor();
            scan_parallel_ti_inplace_pooled(a, bu, l, p, self.threads, &mut scratch.c[0], ex);
        }
    }

    fn scan_tv(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize, scratch: &mut ScanScratch) {
        scratch.reserve_interleaved(p, self.threads);
        if self.threads <= 1 || l < 4 * self.threads {
            scan_sequential_tv_inplace(a, bu, l, p);
        } else {
            let ex = self.executor();
            scan_parallel_tv_inplace_pooled(a, bu, l, p, self.threads, &mut scratch.c[0], ex);
        }
    }

    fn scan_batch_ti(
        &self,
        a: &[C32],
        bu: &mut [C32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        assert_eq!(bu.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        scratch.reserve_interleaved(p, self.threads);
        let rows = l * p;
        let t = self.threads.max(1);
        if batch == 1 {
            return self.scan_ti(a, bu, l, p, scratch);
        }
        if t <= 1 {
            for seq in bu.chunks_mut(rows) {
                scan_sequential_ti_inplace(a, seq, l, p);
            }
        } else if batch >= t {
            let per = batch.div_ceil(t);
            self.executor().run_tasks(bu.chunks_mut(per * rows).map(|shard| {
                move || {
                    for seq in shard.chunks_mut(rows) {
                        scan_sequential_ti_inplace(a, seq, l, p);
                    }
                }
            }));
        } else {
            let per_seq = t / batch;
            let ex = self.executor();
            let workers = scratch.c_workers(batch);
            ex.run_tasks(bu.chunks_mut(rows).zip(workers.iter_mut()).map(|(seq, w)| {
                move || {
                    if per_seq <= 1 || l < 4 * per_seq {
                        scan_sequential_ti_inplace(a, seq, l, p);
                    } else {
                        scan_parallel_ti_inplace_pooled(a, seq, l, p, per_seq, w, ex);
                    }
                }
            }));
        }
    }

    fn scan_batch_tv(
        &self,
        a: &[C32],
        bu: &mut [C32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        assert_eq!(a.len(), batch * l * p);
        assert_eq!(bu.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        scratch.reserve_interleaved(p, self.threads);
        let rows = l * p;
        let t = self.threads.max(1);
        if batch == 1 {
            return self.scan_tv(a, bu, l, p, scratch);
        }
        if t <= 1 {
            for (aseq, seq) in a.chunks(rows).zip(bu.chunks_mut(rows)) {
                scan_sequential_tv_inplace(aseq, seq, l, p);
            }
        } else if batch >= t {
            let per = batch.div_ceil(t);
            self.executor().run_tasks(
                a.chunks(per * rows)
                    .zip(bu.chunks_mut(per * rows))
                    .map(|(ashard, shard)| {
                        move || {
                            for (aseq, seq) in ashard.chunks(rows).zip(shard.chunks_mut(rows)) {
                                scan_sequential_tv_inplace(aseq, seq, l, p);
                            }
                        }
                    }),
            );
        } else {
            let per_seq = t / batch;
            let ex = self.executor();
            let workers = scratch.c_workers(batch);
            ex.run_tasks(
                a.chunks(rows)
                    .zip(bu.chunks_mut(rows))
                    .zip(workers.iter_mut())
                    .map(|((aseq, seq), w)| {
                        move || {
                            if per_seq <= 1 || l < 4 * per_seq {
                                scan_sequential_tv_inplace(aseq, seq, l, p);
                            } else {
                                scan_parallel_tv_inplace_pooled(aseq, seq, l, p, per_seq, w, ex);
                            }
                        }
                    }),
            );
        }
    }

    fn scan_ti_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        scratch.reserve_planar(p, self.threads);
        if self.threads <= 1 || l < 4 * self.threads {
            scan_sequential_ti_planar_inplace(ar, ai, bur, bui, l, p);
        } else {
            let ex = self.executor();
            let w = &mut scratch.f[0];
            scan_parallel_ti_planar_inplace(ar, ai, bur, bui, l, p, self.threads, w, ex);
        }
    }

    fn scan_tv_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        scratch.reserve_planar(p, self.threads);
        if self.threads <= 1 || l < 4 * self.threads {
            scan_sequential_tv_planar_inplace(ar, ai, bur, bui, l, p);
        } else {
            let ex = self.executor();
            let w = &mut scratch.f[0];
            scan_parallel_tv_planar_inplace(ar, ai, bur, bui, l, p, self.threads, w, ex);
        }
    }

    fn scan_ti_planar_resume_par(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        // Same too-short-to-split heuristic as the from-zero parallel
        // entry points; the caller's grant is additionally clamped to the
        // backend budget so a misconfigured caller can't oversubscribe.
        let t = threads.max(1).min(self.threads.max(1));
        if t <= 1 || l < 4 * t {
            return scan_resume_ti_planar_inplace(ar, ai, sr, si, bur, bui, l, p);
        }
        let need = planar_scratch_len(p, t);
        if scratch.len() < need {
            scratch.resize(need, 0.0);
        }
        scan_resume_ti_planar_par_inplace(
            ar,
            ai,
            sr,
            si,
            bur,
            bui,
            l,
            p,
            t,
            &mut scratch[..need],
            self.executor(),
        );
    }

    fn scan_tv_planar_resume_par(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        let t = threads.max(1).min(self.threads.max(1));
        if t <= 1 || l < 4 * t {
            return scan_resume_tv_planar_inplace(ar, ai, sr, si, bur, bui, l, p);
        }
        let need = planar_scratch_len(p, t);
        if scratch.len() < need {
            scratch.resize(need, 0.0);
        }
        scan_resume_tv_planar_par_inplace(
            ar,
            ai,
            sr,
            si,
            bur,
            bui,
            l,
            p,
            t,
            &mut scratch[..need],
            self.executor(),
        );
    }

    fn scan_ti_planar_resume_par_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        // Same clamp + too-short-to-split heuristic as the f32 override.
        let t = threads.max(1).min(self.threads.max(1));
        if t <= 1 || l < 4 * t {
            return scan_resume_ti_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
        }
        let need = planar_scratch_len(p, t);
        if scratch.len() < need {
            scratch.resize(need, 0.0);
        }
        scan_resume_ti_planar_par_bf16_inplace(
            ar,
            ai,
            sr,
            si,
            bur,
            bui,
            l,
            p,
            t,
            &mut scratch[..need],
            self.executor(),
        );
    }

    fn scan_tv_planar_resume_par_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        let t = threads.max(1).min(self.threads.max(1));
        if t <= 1 || l < 4 * t {
            return scan_resume_tv_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
        }
        let need = planar_scratch_len(p, t);
        if scratch.len() < need {
            scratch.resize(need, 0.0);
        }
        scan_resume_tv_planar_par_bf16_inplace(
            ar,
            ai,
            sr,
            si,
            bur,
            bui,
            l,
            p,
            t,
            &mut scratch[..need],
            self.executor(),
        );
    }

    fn scan_batch_ti_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        assert_eq!(ar.len(), p);
        assert_eq!(ai.len(), p);
        assert_eq!(bur.len(), batch * l * p);
        assert_eq!(bui.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        scratch.reserve_planar(p, self.threads);
        let rows = l * p;
        let t = self.threads.max(1);
        if batch == 1 {
            return self.scan_ti_planar(ar, ai, bur, bui, l, p, scratch);
        }
        if t <= 1 {
            for (sr, si) in bur.chunks_mut(rows).zip(bui.chunks_mut(rows)) {
                scan_sequential_ti_planar_inplace(ar, ai, sr, si, l, p);
            }
        } else if batch >= t {
            let per = batch.div_ceil(t);
            self.executor().run_tasks(
                bur.chunks_mut(per * rows)
                    .zip(bui.chunks_mut(per * rows))
                    .map(|(shr, shi)| {
                        move || {
                            for (sr, si) in shr.chunks_mut(rows).zip(shi.chunks_mut(rows)) {
                                scan_sequential_ti_planar_inplace(ar, ai, sr, si, l, p);
                            }
                        }
                    }),
            );
        } else {
            let per_seq = t / batch;
            let ex = self.executor();
            let workers = scratch.f_workers(batch);
            ex.run_tasks(
                bur.chunks_mut(rows)
                    .zip(bui.chunks_mut(rows))
                    .zip(workers.iter_mut())
                    .map(|((sr, si), w)| {
                        move || {
                            if per_seq <= 1 || l < 4 * per_seq {
                                scan_sequential_ti_planar_inplace(ar, ai, sr, si, l, p);
                            } else {
                                scan_parallel_ti_planar_inplace(
                                    ar, ai, sr, si, l, p, per_seq, w, ex,
                                );
                            }
                        }
                    }),
            );
        }
    }

    fn scan_batch_tv_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        assert_eq!(ar.len(), batch * l * p);
        assert_eq!(ai.len(), batch * l * p);
        assert_eq!(bur.len(), batch * l * p);
        assert_eq!(bui.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        scratch.reserve_planar(p, self.threads);
        let rows = l * p;
        let t = self.threads.max(1);
        if batch == 1 {
            return self.scan_tv_planar(ar, ai, bur, bui, l, p, scratch);
        }
        if t <= 1 {
            for (((arseq, aiseq), sr), si) in ar
                .chunks(rows)
                .zip(ai.chunks(rows))
                .zip(bur.chunks_mut(rows))
                .zip(bui.chunks_mut(rows))
            {
                scan_sequential_tv_planar_inplace(arseq, aiseq, sr, si, l, p);
            }
        } else if batch >= t {
            let per = batch.div_ceil(t);
            self.executor().run_tasks(
                ar.chunks(per * rows)
                    .zip(ai.chunks(per * rows))
                    .zip(bur.chunks_mut(per * rows))
                    .zip(bui.chunks_mut(per * rows))
                    .map(|(((arsh, aish), shr), shi)| {
                        move || {
                            for (((arseq, aiseq), sr), si) in arsh
                                .chunks(rows)
                                .zip(aish.chunks(rows))
                                .zip(shr.chunks_mut(rows))
                                .zip(shi.chunks_mut(rows))
                            {
                                scan_sequential_tv_planar_inplace(arseq, aiseq, sr, si, l, p);
                            }
                        }
                    }),
            );
        } else {
            let per_seq = t / batch;
            let ex = self.executor();
            let workers = scratch.f_workers(batch);
            ex.run_tasks(
                ar.chunks(rows)
                    .zip(ai.chunks(rows))
                    .zip(bur.chunks_mut(rows))
                    .zip(bui.chunks_mut(rows))
                    .zip(workers.iter_mut())
                    .map(|((((arseq, aiseq), sr), si), w)| {
                        move || {
                            if per_seq <= 1 || l < 4 * per_seq {
                                scan_sequential_tv_planar_inplace(arseq, aiseq, sr, si, l, p);
                            } else {
                                scan_parallel_tv_planar_inplace(
                                    arseq, aiseq, sr, si, l, p, per_seq, w, ex,
                                );
                            }
                        }
                    }),
            );
        }
    }
}

/// Layout-override wrapper: delegates every scan to the inner backend but
/// reports [`ScanLayout::Interleaved`], directing the engine to drive the
/// original `[C32]` path. This keeps the interleaved kernels alive as the
/// reference oracle the planar default is validated against (property
/// tests, `--scan-layout interleaved`-style A/B runs).
#[derive(Clone, Copy, Debug, Default)]
pub struct Interleaved<B: ScanBackend>(pub B);

#[allow(clippy::too_many_arguments)]
impl<B: ScanBackend> ScanBackend for Interleaved<B> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn threads(&self) -> usize {
        self.0.threads()
    }

    fn layout(&self) -> ScanLayout {
        ScanLayout::Interleaved
    }

    fn executor(&self) -> Executor<'_> {
        self.0.executor()
    }

    fn scan_ti(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize, scratch: &mut ScanScratch) {
        self.0.scan_ti(a, bu, l, p, scratch);
    }

    fn scan_tv(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize, scratch: &mut ScanScratch) {
        self.0.scan_tv(a, bu, l, p, scratch);
    }

    fn scan_batch_ti(
        &self,
        a: &[C32],
        bu: &mut [C32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        self.0.scan_batch_ti(a, bu, batch, l, p, scratch);
    }

    fn scan_batch_tv(
        &self,
        a: &[C32],
        bu: &mut [C32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        self.0.scan_batch_tv(a, bu, batch, l, p, scratch);
    }

    fn scan_step(&self, a: &[C32], state: &mut [C32], b: &[C32]) {
        self.0.scan_step(a, state, b);
    }

    fn scan_ti_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        self.0.scan_ti_planar(ar, ai, bur, bui, l, p, scratch);
    }

    fn scan_tv_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        self.0.scan_tv_planar(ar, ai, bur, bui, l, p, scratch);
    }

    fn scan_batch_ti_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        self.0.scan_batch_ti_planar(ar, ai, bur, bui, batch, l, p, scratch);
    }

    fn scan_batch_tv_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        bur: &mut [f32],
        bui: &mut [f32],
        batch: usize,
        l: usize,
        p: usize,
        scratch: &mut ScanScratch,
    ) {
        self.0.scan_batch_tv_planar(ar, ai, bur, bui, batch, l, p, scratch);
    }

    fn scan_step_planar(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        br: &[f32],
        bi: &[f32],
    ) {
        self.0.scan_step_planar(ar, ai, sr, si, br, bi);
    }

    fn scan_ti_planar_resume_par(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        self.0.scan_ti_planar_resume_par(ar, ai, sr, si, bur, bui, l, p, threads, scratch);
    }

    fn scan_tv_planar_resume_par(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        self.0.scan_tv_planar_resume_par(ar, ai, sr, si, bur, bui, l, p, threads, scratch);
    }

    fn scan_ti_planar_resume_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
    ) {
        self.0.scan_ti_planar_resume_bf16(ar, ai, sr, si, bur, bui, l, p);
    }

    fn scan_tv_planar_resume_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
    ) {
        self.0.scan_tv_planar_resume_bf16(ar, ai, sr, si, bur, bui, l, p);
    }

    fn scan_ti_planar_resume_par_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        self.0.scan_ti_planar_resume_par_bf16(ar, ai, sr, si, bur, bui, l, p, threads, scratch);
    }

    fn scan_tv_planar_resume_par_bf16(
        &self,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        self.0.scan_tv_planar_resume_par_bf16(ar, ai, sr, si, bur, bui, l, p, threads, scratch);
    }
}

// ---------------------------------------------------------------------------
// PlanarElem: static dtype routing for the generic fused forward
// ---------------------------------------------------------------------------

/// Compile-time routing from a storage dtype to its kernel set.
///
/// The fused forward (`S5Layer::fused_unit`) is generic over the storage
/// element of its drive planes, but the scan strategy arrives as a
/// `&dyn ScanBackend` — a trait object cannot carry generic methods, so
/// the *element type* routes instead: each implementation forwards to
/// its backend entry points and lane kernels. The supertrait is sealed
/// ([`ScanElem`]), so the set of storage types stays closed and every
/// routing decision is monomorphized away.
///
/// The `f32` implementation reproduces the pre-dtype code paths exactly —
/// identity widen/narrow, the same backend methods, and the first-tile
/// fast path seeded by the zero-scratch sequential kernel — so
/// f32-instantiated callers stay **bit-for-bit** with the pre-refactor
/// engine (pinned by `tests/scan_matrix.rs`).
#[allow(clippy::too_many_arguments)]
pub trait PlanarElem: ScanElem {
    /// Select this dtype's drive-plane pair out of the workspace's two
    /// plane families (both pairs always exist on the buffer struct; only
    /// the selected pair is grown and written).
    fn pick_drive<'a>(
        f32_planes: (&'a mut Vec<f32>, &'a mut Vec<f32>),
        bf16_planes: (&'a mut Vec<Bf16>, &'a mut Vec<Bf16>),
    ) -> (&'a mut Vec<Self>, &'a mut Vec<Self>);

    /// Lane-blocked Δt-scale of `rows` (rows, p) drive rows in storage
    /// (the `simd`-feature fast path; scalar loops stay in the caller).
    fn scale_rows_simd(
        bur: &mut [Self],
        bui: &mut [Self],
        fr: &[f32],
        fi: &[f32],
        rows: usize,
        p: usize,
    );

    /// Lane-blocked projection of one stored state row into `y`.
    fn project_row_simd(ct: &[C64], xr: &[Self], xi: &[Self], y: &mut [f32], h: usize, p2: usize);

    /// First-tile TI scan of the fused forward. `f32` seeds with the
    /// zero-scratch sequential kernel and copies the final row out as the
    /// carry (the pre-dtype fast path, bit-for-bit — including the
    /// sign-of-zero behavior of leaving row 0 untouched). [`Bf16`] always
    /// runs the resume kernel from the caller's pre-zeroed f32 carry:
    /// streaming has no "first tile" (every chunk resumes), so resuming
    /// from zero is what makes bf16 prefill ≡ step replay bit-for-bit.
    fn scan_ti_first(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Self],
        bui: &mut [Self],
        l: usize,
        p: usize,
    );

    /// TV twin of [`PlanarElem::scan_ti_first`].
    fn scan_tv_first(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Self],
        bui: &mut [Self],
        l: usize,
        p: usize,
    );

    /// Tile-resumable TI scan through the backend.
    fn scan_ti_resume(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Self],
        bui: &mut [Self],
        l: usize,
        p: usize,
    );

    /// Tile-resumable TV scan through the backend.
    fn scan_tv_resume(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Self],
        bui: &mut [Self],
        l: usize,
        p: usize,
    );

    /// In-tile wide TI scan through the backend (`ScanPolicy::wide`).
    fn scan_ti_resume_par(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Self],
        bui: &mut [Self],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    );

    /// In-tile wide TV scan through the backend.
    fn scan_tv_resume_par(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Self],
        bui: &mut [Self],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    );

    /// f64-carry TI scan (`ForwardOptions::with_f64_state`). The policy
    /// layer forces f32 storage under the f64-state option, so the
    /// [`Bf16`] implementation is unreachable by construction.
    fn scan_ti_f64(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f64],
        si: &mut [f64],
        bur: &mut [Self],
        bui: &mut [Self],
        l: usize,
        p: usize,
    );

    /// f64-carry TV scan; see [`PlanarElem::scan_ti_f64`].
    fn scan_tv_f64(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f64],
        si: &mut [f64],
        bur: &mut [Self],
        bui: &mut [Self],
        l: usize,
        p: usize,
    );
}

#[allow(clippy::too_many_arguments)]
impl PlanarElem for f32 {
    fn pick_drive<'a>(
        f32_planes: (&'a mut Vec<f32>, &'a mut Vec<f32>),
        _bf16_planes: (&'a mut Vec<Bf16>, &'a mut Vec<Bf16>),
    ) -> (&'a mut Vec<f32>, &'a mut Vec<f32>) {
        f32_planes
    }

    fn scale_rows_simd(
        bur: &mut [f32],
        bui: &mut [f32],
        fr: &[f32],
        fi: &[f32],
        rows: usize,
        p: usize,
    ) {
        simd::scale_rows(bur, bui, fr, fi, rows, p);
    }

    fn project_row_simd(ct: &[C64], xr: &[f32], xi: &[f32], y: &mut [f32], h: usize, p2: usize) {
        simd::project_row(ct, xr, xi, y, h, p2);
    }

    fn scan_ti_first(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
    ) {
        scan_sequential_ti_planar_inplace(ar, ai, bur, bui, l, p);
        sr.copy_from_slice(&bur[(l - 1) * p..]);
        si.copy_from_slice(&bui[(l - 1) * p..]);
    }

    fn scan_tv_first(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
    ) {
        scan_sequential_tv_planar_inplace(ar, ai, bur, bui, l, p);
        sr.copy_from_slice(&bur[(l - 1) * p..]);
        si.copy_from_slice(&bui[(l - 1) * p..]);
    }

    fn scan_ti_resume(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
    ) {
        be.scan_ti_planar_resume(ar, ai, sr, si, bur, bui, l, p);
    }

    fn scan_tv_resume(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
    ) {
        be.scan_tv_planar_resume(ar, ai, sr, si, bur, bui, l, p);
    }

    fn scan_ti_resume_par(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        be.scan_ti_planar_resume_par(ar, ai, sr, si, bur, bui, l, p, threads, scratch);
    }

    fn scan_tv_resume_par(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        be.scan_tv_planar_resume_par(ar, ai, sr, si, bur, bui, l, p, threads, scratch);
    }

    fn scan_ti_f64(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f64],
        si: &mut [f64],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
    ) {
        scan_resume_ti_planar_f64_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    fn scan_tv_f64(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f64],
        si: &mut [f64],
        bur: &mut [f32],
        bui: &mut [f32],
        l: usize,
        p: usize,
    ) {
        scan_resume_tv_planar_f64_inplace(ar, ai, sr, si, bur, bui, l, p);
    }
}

#[allow(clippy::too_many_arguments)]
impl PlanarElem for Bf16 {
    fn pick_drive<'a>(
        _f32_planes: (&'a mut Vec<f32>, &'a mut Vec<f32>),
        bf16_planes: (&'a mut Vec<Bf16>, &'a mut Vec<Bf16>),
    ) -> (&'a mut Vec<Bf16>, &'a mut Vec<Bf16>) {
        bf16_planes
    }

    fn scale_rows_simd(
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        fr: &[f32],
        fi: &[f32],
        rows: usize,
        p: usize,
    ) {
        simd::scale_rows_bf16(bur, bui, fr, fi, rows, p);
    }

    fn project_row_simd(ct: &[C64], xr: &[Bf16], xi: &[Bf16], y: &mut [f32], h: usize, p2: usize) {
        simd::project_row_bf16(ct, xr, xi, y, h, p2);
    }

    fn scan_ti_first(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
    ) {
        scan_resume_ti_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    fn scan_tv_first(
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
    ) {
        scan_resume_tv_planar_bf16_inplace(ar, ai, sr, si, bur, bui, l, p);
    }

    fn scan_ti_resume(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
    ) {
        be.scan_ti_planar_resume_bf16(ar, ai, sr, si, bur, bui, l, p);
    }

    fn scan_tv_resume(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
    ) {
        be.scan_tv_planar_resume_bf16(ar, ai, sr, si, bur, bui, l, p);
    }

    fn scan_ti_resume_par(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        be.scan_ti_planar_resume_par_bf16(ar, ai, sr, si, bur, bui, l, p, threads, scratch);
    }

    fn scan_tv_resume_par(
        be: &dyn ScanBackend,
        ar: &[f32],
        ai: &[f32],
        sr: &mut [f32],
        si: &mut [f32],
        bur: &mut [Bf16],
        bui: &mut [Bf16],
        l: usize,
        p: usize,
        threads: usize,
        scratch: &mut Vec<f32>,
    ) {
        be.scan_tv_planar_resume_par_bf16(ar, ai, sr, si, bur, bui, l, p, threads, scratch);
    }

    fn scan_ti_f64(
        _ar: &[f32],
        _ai: &[f32],
        _sr: &mut [f64],
        _si: &mut [f64],
        _bur: &mut [Bf16],
        _bui: &mut [Bf16],
        _l: usize,
        _p: usize,
    ) {
        unreachable!("f64-state forces f32 storage (ScanPolicy::storage_dtype)");
    }

    fn scan_tv_f64(
        _ar: &[f32],
        _ai: &[f32],
        _sr: &mut [f64],
        _si: &mut [f64],
        _bur: &mut [Bf16],
        _bui: &mut [Bf16],
        _l: usize,
        _p: usize,
    ) {
        unreachable!("f64-state forces f32 storage (ScanPolicy::storage_dtype)");
    }
}

/// Pick a backend for a thread budget: ≤ 1 worker → [`SequentialBackend`],
/// otherwise [`ParallelBackend`]; `threads = 0` auto-detects. The returned
/// backend prefers the **planar** layout (the default strategy) and
/// dispatches shards on the process-wide persistent worker pool
/// ([`ScanExec::Pooled`]) — one pool shared across every batch, request
/// and session, so steady-state serving never spawns a thread.
///
/// This is the resolver behind the `threads` knob everywhere — the CLI,
/// the native server, and
/// [`ForwardOptions::with_threads`](crate::ssm::api::ForwardOptions::with_threads)
/// in the unified inference API all funnel through it.
pub fn backend_for_threads(threads: usize) -> Box<dyn ScanBackend> {
    backend_for(threads, ScanLayout::Planar)
}

/// [`backend_for_threads`] with an explicit layout: `Interleaved` wraps
/// the same strategy in the layout-override oracle wrapper.
pub fn backend_for(threads: usize, layout: ScanLayout) -> Box<dyn ScanBackend> {
    backend_for_exec(threads, layout, ScanExec::Pooled)
}

/// [`backend_for`] with an explicit dispatch mode — the opt-out knob for
/// the persistent pool (e.g. [`ScanExec::Scoped`] restores the
/// spawn-per-call behavior, [`ScanExec::Inline`] pins single-threaded
/// execution of the same chunked decomposition). Results are bit-for-bit
/// identical across modes.
pub fn backend_for_exec(
    threads: usize,
    layout: ScanLayout,
    exec: ScanExec,
) -> Box<dyn ScanBackend> {
    let t = crate::ssm::engine::auto_threads(threads);
    match (t <= 1, layout) {
        (true, ScanLayout::Planar) => Box::new(SequentialBackend),
        (false, ScanLayout::Planar) => Box::new(ParallelBackend::with_exec(t, exec)),
        (true, ScanLayout::Interleaved) => Box::new(Interleaved(SequentialBackend)),
        (false, ScanLayout::Interleaved) => {
            Box::new(Interleaved(ParallelBackend::with_exec(t, exec)))
        }
    }
}

// ---------------------------------------------------------------------------
// Allocating wrappers (original signatures)
// ---------------------------------------------------------------------------

/// Sequential scan, time-varying multipliers.
///
/// `a`, `b`: row-major (L, P). Returns states (L, P).
pub fn scan_sequential(a: &[C32], b: &[C32], l: usize, p: usize) -> Vec<C32> {
    assert_eq!(a.len(), l * p);
    assert_eq!(b.len(), l * p);
    let mut xs = b.to_vec();
    scan_sequential_tv_inplace(a, &mut xs, l, p);
    xs
}

/// Sequential scan with a *time-invariant* diagonal (the common S5 case):
/// `a` has length P.
pub fn scan_sequential_ti(a: &[C32], b: &[C32], l: usize, p: usize) -> Vec<C32> {
    assert_eq!(a.len(), p);
    assert_eq!(b.len(), l * p);
    let mut xs = b.to_vec();
    scan_sequential_ti_inplace(a, &mut xs, l, p);
    xs
}

/// Parallel chunked scan over `threads` workers (time-invariant diagonal).
/// Falls back to the sequential kernel when the chunk bookkeeping would
/// dominate (L < 4·threads).
pub fn scan_parallel_ti(a: &[C32], b: &[C32], l: usize, p: usize, threads: usize) -> Vec<C32> {
    assert_eq!(a.len(), p);
    assert_eq!(b.len(), l * p);
    let threads = threads.max(1).min(l.max(1));
    let mut xs = b.to_vec();
    if threads == 1 || l < 4 * threads {
        scan_sequential_ti_inplace(a, &mut xs, l, p);
    } else {
        scan_parallel_ti_inplace(a, &mut xs, l, p, threads);
    }
    xs
}

/// Parallel chunked scan with time-varying multipliers (irregular sampling).
pub fn scan_parallel_tv(a: &[C32], b: &[C32], l: usize, p: usize, threads: usize) -> Vec<C32> {
    assert_eq!(a.len(), l * p);
    assert_eq!(b.len(), l * p);
    let threads = threads.max(1).min(l.max(1));
    let mut xs = b.to_vec();
    if threads == 1 || l < 4 * threads {
        scan_sequential_tv_inplace(a, &mut xs, l, p);
    } else {
        scan_parallel_tv_inplace(a, &mut xs, l, p, threads);
    }
    xs
}

/// Planar (struct-of-arrays) sequential scan: separate re/im f32 streams,
/// matching the L1 kernel's memory layout.
///
/// §Perf experiment (EXPERIMENTS.md): the interleaved `C32` loop carries a
/// real↔imag data dependence per element that blocks autovectorization;
/// planar streams let LLVM emit SIMD mul/fma over the P lanes. Same math,
/// same O(L·P) work.
pub fn scan_sequential_ti_planar(
    ar: &[f32],
    ai: &[f32],
    br: &[f32],
    bi: &[f32],
    l: usize,
    p: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(ar.len(), p);
    assert_eq!(br.len(), l * p);
    let mut xr = vec![0.0f32; l * p];
    let mut xi = vec![0.0f32; l * p];
    let mut sr = vec![0.0f32; p];
    let mut si = vec![0.0f32; p];
    for k in 0..l {
        let row = k * p;
        let (brk, bik) = (&br[row..row + p], &bi[row..row + p]);
        let (xrk, xik) = (&mut xr[row..row + p], &mut xi[row..row + p]);
        for j in 0..p {
            let nr = ar[j] * sr[j] - ai[j] * si[j] + brk[j];
            let ni = ar[j] * si[j] + ai[j] * sr[j] + bik[j];
            sr[j] = nr;
            si[j] = ni;
            xrk[j] = nr;
            xik[j] = ni;
        }
    }
    (xr, xi)
}

/// Dense-state-matrix sequential recurrence x_k = Ā x_{k−1} + b_k — the
/// O(L·P²) strawman of §2.2 (its *parallel* form would need O(P³) matrix
/// products per combine, which is the cost the diagonalization removes).
///
/// `a_dense`: row-major (P, P) in C64 for accuracy; `b`: (L, P).
pub fn scan_dense_sequential(a_dense: &[C64], b: &[C64], l: usize, p: usize) -> Vec<C64> {
    assert_eq!(a_dense.len(), p * p);
    assert_eq!(b.len(), l * p);
    let mut xs = vec![C64::ZERO; l * p];
    let mut state = vec![C64::ZERO; p];
    let mut next = vec![C64::ZERO; p];
    for k in 0..l {
        for i in 0..p {
            let mut acc = b[k * p + i];
            for j in 0..p {
                acc += a_dense[i * p + j] * state[j];
            }
            next[i] = acc;
        }
        std::mem::swap(&mut state, &mut next);
        xs[k * p..(k + 1) * p].copy_from_slice(&state);
    }
    xs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::prop;

    fn rand_c32(g: &mut Rng, n: usize, scale: f32) -> Vec<C32> {
        (0..n)
            .map(|_| C32::new(g.normal() as f32 * scale, g.normal() as f32 * scale))
            .collect()
    }

    fn close(a: &[C32], b: &[C32], tol: f32) -> prop::PropResult {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            let d = (*x - *y).abs();
            let s = 1.0 + x.abs().max(y.abs());
            if d > tol * s {
                return Err(format!("idx {i}: {x:?} !~ {y:?}"));
            }
        }
        Ok(())
    }

    #[test]
    fn sequential_ti_matches_tv() {
        let mut g = Rng::new(0);
        let (l, p) = (50, 4);
        let a = rand_c32(&mut g, p, 0.5);
        let b = rand_c32(&mut g, l * p, 1.0);
        let mut a_full = Vec::with_capacity(l * p);
        for _ in 0..l {
            a_full.extend_from_slice(&a);
        }
        let x1 = scan_sequential_ti(&a, &b, l, p);
        let x2 = scan_sequential(&a_full, &b, l, p);
        close(&x1, &x2, 1e-6).unwrap();
    }

    #[test]
    fn prop_parallel_ti_matches_sequential() {
        prop::check("parallel TI scan ≡ sequential", 40, |g| {
            let l = 1 + g.below(500);
            let p = 1 + g.below(12);
            let threads = 1 + g.below(8);
            let a = rand_c32(g, p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let seq = scan_sequential_ti(&a, &b, l, p);
            let par = scan_parallel_ti(&a, &b, l, p, threads);
            close(&seq, &par, 1e-4)
        });
    }

    #[test]
    fn prop_parallel_tv_matches_sequential() {
        prop::check("parallel TV scan ≡ sequential", 40, |g| {
            let l = 1 + g.below(400);
            let p = 1 + g.below(10);
            let threads = 1 + g.below(8);
            let a = rand_c32(g, l * p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let seq = scan_sequential(&a, &b, l, p);
            let par = scan_parallel_tv(&a, &b, l, p, threads);
            close(&seq, &par, 1e-4)
        });
    }

    /// Chunk-boundary sweep: the in-place parallel kernels (no fallback)
    /// must match the sequential kernels at L = 1, chunk−1, chunk, chunk+1
    /// and non-divisible L, for several thread counts.
    #[test]
    fn parallel_inplace_chunk_boundaries() {
        let mut g = Rng::new(11);
        for &t in &[2usize, 3, 5, 8] {
            // with threads = t, chunk = ceil(l / t): exercise the lengths
            // around every boundary the sharding can produce
            for &l in &[1usize, 2, t - 1, t, t + 1, 4 * t - 1, 4 * t, 4 * t + 1, 10 * t + 3] {
                let l = l.max(1);
                let p = 3;
                let a = rand_c32(&mut g, p, 0.6);
                let b = rand_c32(&mut g, l * p, 1.0);
                let want = scan_sequential_ti(&a, &b, l, p);
                let mut got = b.clone();
                scan_parallel_ti_inplace(&a, &mut got, l, p, t);
                close(&want, &got, 1e-4)
                    .unwrap_or_else(|e| panic!("TI t={t} l={l}: {e}"));

                let a_tv = rand_c32(&mut g, l * p, 0.6);
                let want = scan_sequential(&a_tv, &b, l, p);
                let mut got = b.clone();
                scan_parallel_tv_inplace(&a_tv, &mut got, l, p, t);
                close(&want, &got, 1e-4)
                    .unwrap_or_else(|e| panic!("TV t={t} l={l}: {e}"));
            }
        }
    }

    /// The planar parallel kernels hit the same chunk boundaries as the
    /// interleaved ones and must agree with the interleaved results
    /// **exactly** (identical FP ops in identical order), including at
    /// L = 1, chunk±1 and non-divisible remainders.
    #[test]
    fn planar_parallel_chunk_boundaries_match_interleaved_exactly() {
        let mut g = Rng::new(17);
        for &t in &[2usize, 3, 5, 8] {
            for &l in &[1usize, 2, t - 1, t, t + 1, 4 * t - 1, 4 * t, 4 * t + 1, 10 * t + 3] {
                let l = l.max(1);
                let p = 3;
                let a = rand_c32(&mut g, p, 0.6);
                let b = rand_c32(&mut g, l * p, 1.0);
                let (ar, ai) = planes(&a);
                let (br, bi) = planes(&b);
                let mut want = b.clone();
                scan_parallel_ti_inplace(&a, &mut want, l, p, t);
                let (mut xr, mut xi) = (br.clone(), bi.clone());
                let mut s = vec![0.0f32; planar_scratch_len(p, t)];
                scan_parallel_ti_planar_inplace(
                    &ar,
                    &ai,
                    &mut xr,
                    &mut xi,
                    l,
                    p,
                    t,
                    &mut s,
                    Executor::Scoped,
                );
                for (i, w) in want.iter().enumerate() {
                    assert!(
                        xr[i] == w.re && xi[i] == w.im,
                        "TI t={t} l={l} idx {i}: {w:?} != {}+{}i",
                        xr[i],
                        xi[i]
                    );
                }

                let a_tv = rand_c32(&mut g, l * p, 0.6);
                let (atr, ati) = planes(&a_tv);
                let mut want = b.clone();
                scan_parallel_tv_inplace(&a_tv, &mut want, l, p, t);
                let (mut xr, mut xi) = (br.clone(), bi.clone());
                scan_parallel_tv_planar_inplace(
                    &atr,
                    &ati,
                    &mut xr,
                    &mut xi,
                    l,
                    p,
                    t,
                    &mut s,
                    Executor::Scoped,
                );
                for (i, w) in want.iter().enumerate() {
                    assert!(
                        xr[i] == w.re && xi[i] == w.im,
                        "TV t={t} l={l} idx {i}: {w:?} != {}+{}i",
                        xr[i],
                        xi[i]
                    );
                }
            }
        }
    }

    /// Split an interleaved C32 buffer into planar re/im planes.
    fn planes(z: &[C32]) -> (Vec<f32>, Vec<f32>) {
        (z.iter().map(|v| v.re).collect(), z.iter().map(|v| v.im).collect())
    }

    /// Compare planar planes against an interleaved reference.
    fn close_planar(want: &[C32], xr: &[f32], xi: &[f32], tol: f32) -> prop::PropResult {
        for (i, w) in want.iter().enumerate() {
            let s = 1.0 + w.abs();
            if (xr[i] - w.re).abs() > tol * s || (xi[i] - w.im).abs() > tol * s {
                return Err(format!(
                    "idx {i}: {:?} !~ {}+{}i",
                    w, xr[i], xi[i]
                ));
            }
        }
        Ok(())
    }

    /// Every backend agrees with the sequential ground truth on single
    /// sequences, for TI and TV multipliers — in both layouts, including
    /// the `Interleaved` oracle wrapper.
    #[test]
    fn prop_backends_agree_single_sequence() {
        let backends: Vec<Box<dyn ScanBackend>> = vec![
            Box::new(SequentialBackend),
            Box::new(ParallelBackend::new(2)),
            Box::new(ParallelBackend::new(3)),
            Box::new(ParallelBackend::new(8)),
            Box::new(Interleaved(ParallelBackend::new(3))),
        ];
        prop::check("ScanBackend single-seq equivalence", 25, |g| {
            let l = 1 + g.below(300);
            let p = 1 + g.below(8);
            let a = rand_c32(g, p, 0.6);
            let a_tv = rand_c32(g, l * p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let want_ti = scan_sequential_ti(&a, &b, l, p);
            let want_tv = scan_sequential(&a_tv, &b, l, p);
            let (ar, ai) = planes(&a);
            let (atr, ati) = planes(&a_tv);
            let (br, bi) = planes(&b);
            let mut scratch = ScanScratch::new();
            for be in &backends {
                let mut got = b.clone();
                be.scan_ti(&a, &mut got, l, p, &mut scratch);
                close(&want_ti, &got, 1e-4)
                    .map_err(|e| format!("{} TI: {e}", be.name()))?;
                let mut got = b.clone();
                be.scan_tv(&a_tv, &mut got, l, p, &mut scratch);
                close(&want_tv, &got, 1e-4)
                    .map_err(|e| format!("{} TV: {e}", be.name()))?;
                let (mut xr, mut xi) = (br.clone(), bi.clone());
                be.scan_ti_planar(&ar, &ai, &mut xr, &mut xi, l, p, &mut scratch);
                close_planar(&want_ti, &xr, &xi, 1e-4)
                    .map_err(|e| format!("{} planar TI: {e}", be.name()))?;
                let (mut xr, mut xi) = (br.clone(), bi.clone());
                be.scan_tv_planar(&atr, &ati, &mut xr, &mut xi, l, p, &mut scratch);
                close_planar(&want_tv, &xr, &xi, 1e-4)
                    .map_err(|e| format!("{} planar TV: {e}", be.name()))?;
            }
            Ok(())
        });
    }

    /// Batched scans equal per-sequence scans for every backend, across
    /// B < threads, B = threads and B > threads regimes.
    #[test]
    fn prop_scan_batch_matches_per_sequence() {
        let backends: Vec<Box<dyn ScanBackend>> = vec![
            Box::new(SequentialBackend),
            Box::new(ParallelBackend::new(2)),
            Box::new(ParallelBackend::new(4)),
        ];
        prop::check("scan_batch ≡ per-sequence", 20, |g| {
            let batch = 1 + g.below(7);
            let l = 1 + g.below(120);
            let p = 1 + g.below(6);
            let a = rand_c32(g, p, 0.6);
            let a_tv = rand_c32(g, batch * l * p, 0.6);
            let b = rand_c32(g, batch * l * p, 1.0);

            let mut want_ti = b.clone();
            let mut want_tv = b.clone();
            for bi in 0..batch {
                let s = bi * l * p;
                scan_sequential_ti_inplace(&a, &mut want_ti[s..s + l * p], l, p);
                scan_sequential_tv_inplace(
                    &a_tv[s..s + l * p],
                    &mut want_tv[s..s + l * p],
                    l,
                    p,
                );
            }
            let (ar, ai) = planes(&a);
            let (atr, ati) = planes(&a_tv);
            let (br, bi) = planes(&b);
            let mut scratch = ScanScratch::new();
            for be in &backends {
                let mut got = b.clone();
                be.scan_batch_ti(&a, &mut got, batch, l, p, &mut scratch);
                close(&want_ti, &got, 1e-4)
                    .map_err(|e| format!("{} batch TI (B={batch}): {e}", be.name()))?;
                let mut got = b.clone();
                be.scan_batch_tv(&a_tv, &mut got, batch, l, p, &mut scratch);
                close(&want_tv, &got, 1e-4)
                    .map_err(|e| format!("{} batch TV (B={batch}): {e}", be.name()))?;
                let (mut xr, mut xi) = (br.clone(), bi.clone());
                be.scan_batch_ti_planar(&ar, &ai, &mut xr, &mut xi, batch, l, p, &mut scratch);
                close_planar(&want_ti, &xr, &xi, 1e-4)
                    .map_err(|e| format!("{} planar batch TI (B={batch}): {e}", be.name()))?;
                let (mut xr, mut xi) = (br.clone(), bi.clone());
                be.scan_batch_tv_planar(&atr, &ati, &mut xr, &mut xi, batch, l, p, &mut scratch);
                close_planar(&want_tv, &xr, &xi, 1e-4)
                    .map_err(|e| format!("{} planar batch TV (B={batch}): {e}", be.name()))?;
            }
            Ok(())
        });
    }

    /// The streaming step kernel replayed over a sequence equals the
    /// offline TI scan — the online/offline shared-code-path guarantee.
    #[test]
    fn scan_step_replay_equals_offline() {
        let mut g = Rng::new(21);
        let (l, p) = (64, 5);
        let a = rand_c32(&mut g, p, 0.6);
        let b = rand_c32(&mut g, l * p, 1.0);
        let offline = scan_sequential_ti(&a, &b, l, p);
        let be = SequentialBackend;
        let mut state = vec![C32::ZERO; p];
        for k in 0..l {
            be.scan_step(&a, &mut state, &b[k * p..(k + 1) * p]);
            close(&offline[k * p..(k + 1) * p], &state, 1e-6)
                .unwrap_or_else(|e| panic!("k={k}: {e}"));
        }
    }

    #[test]
    fn parallel_exact_on_cumsum() {
        // a = 1: scan is a cumulative sum, easy closed form.
        let (l, p) = (1000, 2);
        let a = vec![C32::ONE; p];
        let b = vec![C32::new(1.0, 0.0); l * p];
        let xs = scan_parallel_ti(&a, &b, l, p, 4);
        for k in 0..l {
            assert!((xs[k * p].re - (k as f32 + 1.0)).abs() < 1e-3);
        }
    }

    #[test]
    fn dense_scan_matches_diagonal_when_a_is_diagonal() {
        let mut g = Rng::new(3);
        let (l, p) = (40, 5);
        let diag: Vec<C64> = (0..p).map(|_| C64::new(g.normal() * 0.4, g.normal() * 0.4)).collect();
        let mut a_dense = vec![C64::ZERO; p * p];
        for j in 0..p {
            a_dense[j * p + j] = diag[j];
        }
        let b: Vec<C64> = (0..l * p).map(|_| C64::new(g.normal(), g.normal())).collect();
        let dense = scan_dense_sequential(&a_dense, &b, l, p);

        let a32: Vec<C32> = diag.iter().map(|z| z.to_c32()).collect();
        let b32: Vec<C32> = b.iter().map(|z| z.to_c32()).collect();
        let diag_xs = scan_sequential_ti(&a32, &b32, l, p);
        for (x, y) in dense.iter().zip(diag_xs.iter()) {
            assert!((x.to_c32() - *y).abs() < 1e-3);
        }
    }

    #[test]
    fn prop_planar_matches_interleaved() {
        prop::check("planar scan ≡ interleaved", 30, |g| {
            let l = 1 + g.below(300);
            let p = 1 + g.below(16);
            let a = rand_c32(g, p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let ar: Vec<f32> = a.iter().map(|z| z.re).collect();
            let ai: Vec<f32> = a.iter().map(|z| z.im).collect();
            let br: Vec<f32> = b.iter().map(|z| z.re).collect();
            let bi: Vec<f32> = b.iter().map(|z| z.im).collect();
            let want = scan_sequential_ti(&a, &b, l, p);
            let (xr, xi) = scan_sequential_ti_planar(&ar, &ai, &br, &bi, l, p);
            for (i, w) in want.iter().enumerate() {
                let s = 1.0 + w.abs();
                if (xr[i] - w.re).abs() > 1e-4 * s || (xi[i] - w.im).abs() > 1e-4 * s {
                    return Err(format!("idx {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_and_single_element() {
        let a = vec![C32::new(0.5, 0.0)];
        assert!(scan_sequential_ti(&a, &[], 0, 1).is_empty());
        let b = vec![C32::new(2.0, -1.0)];
        let xs = scan_parallel_ti(&a, &b, 1, 1, 8);
        assert_eq!(xs[0], b[0]); // x_1 = b_1
    }

    /// The planar streaming step replayed over a sequence equals the
    /// offline planar TI scan — and the interleaved step — exactly.
    #[test]
    fn scan_step_planar_replay_equals_offline() {
        let mut g = Rng::new(23);
        let (l, p) = (64, 5);
        let a = rand_c32(&mut g, p, 0.6);
        let b = rand_c32(&mut g, l * p, 1.0);
        let offline = scan_sequential_ti(&a, &b, l, p);
        let (ar, ai) = planes(&a);
        let (br, bi) = planes(&b);
        let be = SequentialBackend;
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        let mut state = vec![C32::ZERO; p];
        for k in 0..l {
            let row = k * p;
            be.scan_step_planar(&ar, &ai, &mut sr, &mut si, &br[row..row + p], &bi[row..row + p]);
            be.scan_step(&a, &mut state, &b[row..row + p]);
            for j in 0..p {
                let w = offline[row + j];
                assert!(
                    (sr[j] - w.re).abs() < 1e-6 * (1.0 + w.abs())
                        && (si[j] - w.im).abs() < 1e-6 * (1.0 + w.abs()),
                    "k={k} j={j}"
                );
                assert_eq!(sr[j], state[j].re, "planar/interleaved step diverged k={k} j={j}");
                assert_eq!(si[j], state[j].im, "planar/interleaved step diverged k={k} j={j}");
            }
        }
    }

    /// Degenerate shapes — L = 0, P = 0, L < threads, L = 1, single-chunk
    /// remainders — are accepted panic-free by every kernel and every
    /// backend entry point, in both layouts.
    #[test]
    fn degenerate_shapes_are_panic_free() {
        let mut g = Rng::new(29);
        let backends: Vec<Box<dyn ScanBackend>> = vec![
            Box::new(SequentialBackend),
            Box::new(ParallelBackend::new(4)),
            Box::new(Interleaved(ParallelBackend::new(4))),
        ];
        let mut scratch = ScanScratch::new();
        for &(l, p, t) in &[
            (0usize, 3usize, 4usize), // empty sequence
            (5, 0, 4),                // empty state
            (0, 0, 4),                // both empty
            (1, 3, 8),                // L < threads (clamps to 1 chunk)
            (2, 3, 8),                // L < threads, 2 chunks
            (3, 1, 2),                // single-column state
            (9, 3, 4),                // non-divisible remainder (chunk 3, last 3)
            (7, 2, 3),                // remainder chunk shorter than the rest
        ] {
            let a = rand_c32(&mut g, p, 0.6);
            let a_tv = rand_c32(&mut g, l * p, 0.6);
            let b = rand_c32(&mut g, l * p, 1.0);
            let (ar, ai) = planes(&a);
            let (atr, ati) = planes(&a_tv);
            let (br, bi) = planes(&b);

            // free kernels (in-place, pooled and allocating forms)
            let mut x = b.clone();
            scan_sequential_ti_inplace(&a, &mut x, l, p);
            let mut x = b.clone();
            scan_sequential_tv_inplace(&a_tv, &mut x, l, p);
            let mut x = b.clone();
            scan_parallel_ti_inplace(&a, &mut x, l, p, t);
            let mut x = b.clone();
            scan_parallel_tv_inplace(&a_tv, &mut x, l, p, t);
            let _ = scan_parallel_ti(&a, &b, l, p, t);
            let _ = scan_parallel_tv(&a_tv, &b, l, p, t);
            let (mut xr, mut xi) = (br.clone(), bi.clone());
            scan_sequential_ti_planar_inplace(&ar, &ai, &mut xr, &mut xi, l, p);
            let (mut xr, mut xi) = (br.clone(), bi.clone());
            scan_sequential_tv_planar_inplace(&atr, &ati, &mut xr, &mut xi, l, p);
            let mut s = vec![0.0f32; planar_scratch_len(p, t)];
            let (mut xr, mut xi) = (br.clone(), bi.clone());
            scan_parallel_ti_planar_inplace(
                &ar,
                &ai,
                &mut xr,
                &mut xi,
                l,
                p,
                t,
                &mut s,
                Executor::Scoped,
            );
            let (mut xr, mut xi) = (br.clone(), bi.clone());
            scan_parallel_tv_planar_inplace(
                &atr,
                &ati,
                &mut xr,
                &mut xi,
                l,
                p,
                t,
                &mut s,
                Executor::Scoped,
            );

            // backend entry points, single and batched (B = 0 included)
            for be in &backends {
                for batch in [0usize, 1, 3] {
                    let ab = rand_c32(&mut g, batch * l * p, 0.6);
                    let bb = rand_c32(&mut g, batch * l * p, 1.0);
                    let (abr, abi) = planes(&ab);
                    let (bbr, bbi) = planes(&bb);
                    let mut x = bb.clone();
                    be.scan_batch_ti(&a, &mut x, batch, l, p, &mut scratch);
                    let mut x = bb.clone();
                    be.scan_batch_tv(&ab, &mut x, batch, l, p, &mut scratch);
                    let (mut xr, mut xi) = (bbr.clone(), bbi.clone());
                    be.scan_batch_ti_planar(&ar, &ai, &mut xr, &mut xi, batch, l, p, &mut scratch);
                    let (mut xr, mut xi) = (bbr, bbi);
                    be.scan_batch_tv_planar(
                        &abr,
                        &abi,
                        &mut xr,
                        &mut xi,
                        batch,
                        l,
                        p,
                        &mut scratch,
                    );
                }
                let mut x = b.clone();
                be.scan_ti(&a, &mut x, l, p, &mut scratch);
                let mut x = b.clone();
                be.scan_tv(&a_tv, &mut x, l, p, &mut scratch);
            }
        }
    }

    /// The pooled chunk summaries stop allocating after the first call:
    /// capacity is stable across repeat scans and across every batch
    /// sharding branch (B = 1 chunked, B < T, B ≥ T).
    #[test]
    fn scan_scratch_capacity_is_stable_after_warmup() {
        let mut g = Rng::new(31);
        let be = ParallelBackend::new(4);
        let (l, p) = (64, 6);
        let a = rand_c32(&mut g, p, 0.6);
        let mut scratch = ScanScratch::new();
        // warm up with the single-sequence chunked branch
        let mut b = rand_c32(&mut g, l * p, 1.0);
        be.scan_ti(&a, &mut b, l, p, &mut scratch);
        let high_water = scratch.capacity_bytes();
        assert!(high_water > 0);
        // every other branch must fit inside the reserved envelope
        for batch in [1usize, 2, 3, 4, 9] {
            let mut bb = rand_c32(&mut g, batch * l * p, 1.0);
            be.scan_batch_ti(&a, &mut bb, batch, l, p, &mut scratch);
            let (ar, ai) = planes(&a);
            let (mut xr, mut xi) = {
                let bb = rand_c32(&mut g, batch * l * p, 1.0);
                planes(&bb)
            };
            be.scan_batch_ti_planar(&ar, &ai, &mut xr, &mut xi, batch, l, p, &mut scratch);
        }
        // planar planes were reserved on first planar use; after that the
        // envelope must hold for good
        let planar_water = scratch.capacity_bytes();
        for batch in [1usize, 3, 9] {
            let mut bb = rand_c32(&mut g, batch * l * p, 1.0);
            be.scan_batch_ti(&a, &mut bb, batch, l, p, &mut scratch);
            let (ar, ai) = planes(&a);
            let bb = rand_c32(&mut g, batch * l * p, 1.0);
            let (mut xr, mut xi) = planes(&bb);
            be.scan_batch_ti_planar(&ar, &ai, &mut xr, &mut xi, batch, l, p, &mut scratch);
            let bb = rand_c32(&mut g, batch * l * p, 1.0);
            let (atr, ati) = planes(&rand_c32(&mut g, batch * l * p, 0.6));
            let (mut xr, mut xi) = planes(&bb);
            be.scan_batch_tv_planar(&atr, &ati, &mut xr, &mut xi, batch, l, p, &mut scratch);
            assert_eq!(
                scratch.capacity_bytes(),
                planar_water,
                "scratch grew at B={batch} after warmup"
            );
        }
    }

    #[test]
    fn backend_for_resolves_layouts() {
        assert_eq!(backend_for_threads(1).layout(), ScanLayout::Planar);
        assert_eq!(backend_for_threads(4).layout(), ScanLayout::Planar);
        let il = backend_for(4, ScanLayout::Interleaved);
        assert_eq!(il.layout(), ScanLayout::Interleaved);
        assert_eq!(il.threads(), 4);
        assert_eq!(backend_for(1, ScanLayout::Interleaved).layout(), ScanLayout::Interleaved);
    }

    /// Pooled dispatch is the default for every multi-threaded resolver
    /// (the acceptance criterion of the worker-pool PR); sequential
    /// strategies run inline; the opt-outs resolve as asked.
    #[test]
    fn backend_for_resolves_executors() {
        assert!(backend_for_threads(4).executor().is_pool());
        assert!(backend_for(4, ScanLayout::Interleaved).executor().is_pool());
        assert_eq!(backend_for_threads(1).executor().kind(), "inline");
        assert_eq!(
            backend_for_exec(4, ScanLayout::Planar, ScanExec::Scoped).executor().kind(),
            "scoped"
        );
        assert_eq!(
            backend_for_exec(4, ScanLayout::Planar, ScanExec::Inline).executor().kind(),
            "inline"
        );
        let own = Arc::new(WorkerPool::new(2));
        let be = ParallelBackend::with_exec(4, ScanExec::Pool(own.clone()));
        assert!(be.executor().is_pool());
        assert_eq!(be.threads(), 4, "thread budget is independent of pool size");
    }

    /// The tile-resumable kernels reproduce the whole-sequence sequential
    /// scans bit-for-bit under arbitrary tile decompositions — including
    /// T = 1 (step-sized tiles), tiles that don't divide L, and a single
    /// tile covering everything — in both layouts, TI and TV.
    #[test]
    fn resume_kernels_match_whole_sequence_over_any_tiling() {
        let mut g = Rng::new(41);
        for &(l, p) in &[(1usize, 3usize), (7, 2), (40, 5), (64, 1)] {
            let a = rand_c32(&mut g, p, 0.6);
            let a_tv = rand_c32(&mut g, l * p, 0.6);
            let b = rand_c32(&mut g, l * p, 1.0);
            let (ar, ai) = planes(&a);
            let (atr, ati) = planes(&a_tv);
            let (br, bi) = planes(&b);
            let mut want_ti = b.clone();
            scan_sequential_ti_inplace(&a, &mut want_ti, l, p);
            let mut want_tv = b.clone();
            scan_sequential_tv_inplace(&a_tv, &mut want_tv, l, p);
            for &tile in &[1usize, 2, 3, l.saturating_sub(1).max(1), l, l + 5] {
                // interleaved resume: first tile scanned plain (row 0 =
                // b_0, the staged op order), later tiles resumed from the
                // carried state — exactly how the fused driver tiles.
                for (want, tv) in [(&want_ti, false), (&want_tv, true)] {
                    let mut got = b.clone();
                    let mut state = vec![C32::ZERO; p];
                    let mut t0 = 0usize;
                    while t0 < l {
                        let tl = tile.min(l - t0);
                        let rows = &mut got[t0 * p..(t0 + tl) * p];
                        if t0 == 0 {
                            if tv {
                                scan_sequential_tv_inplace(&a_tv[..tl * p], rows, tl, p);
                            } else {
                                scan_sequential_ti_inplace(&a, rows, tl, p);
                            }
                            state.copy_from_slice(&rows[(tl - 1) * p..]);
                        } else if tv {
                            scan_resume_tv_inplace(
                                &a_tv[t0 * p..(t0 + tl) * p],
                                &mut state,
                                rows,
                                tl,
                                p,
                            );
                        } else {
                            scan_resume_ti_inplace(&a, &mut state, rows, tl, p);
                        }
                        t0 += tl;
                    }
                    for (i, w) in want.iter().enumerate() {
                        assert_eq!(
                            (got[i].re, got[i].im),
                            (w.re, w.im),
                            "interleaved tv={tv} l={l} p={p} tile={tile} idx {i}"
                        );
                    }
                }
                // planar resume, via the backend entry points, resuming
                // from zero state for every tile including the first (the
                // chunked-prefill contract: ≡ scan_step replay).
                for tv in [false, true] {
                    let (mut xr, mut xi) = (br.clone(), bi.clone());
                    let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
                    let be = SequentialBackend;
                    let mut t0 = 0usize;
                    while t0 < l {
                        let tl = tile.min(l - t0);
                        let (rr, ri) = (
                            &mut xr[t0 * p..(t0 + tl) * p],
                            &mut xi[t0 * p..(t0 + tl) * p],
                        );
                        if tv {
                            be.scan_tv_planar_resume(
                                &atr[t0 * p..(t0 + tl) * p],
                                &ati[t0 * p..(t0 + tl) * p],
                                &mut sr,
                                &mut si,
                                rr,
                                ri,
                                tl,
                                p,
                            );
                        } else {
                            be.scan_ti_planar_resume(&ar, &ai, &mut sr, &mut si, rr, ri, tl, p);
                        }
                        t0 += tl;
                    }
                    // reference: the planar streaming step replayed row by
                    // row (the online path) — must agree bit-for-bit
                    let (mut wr, mut wi) = (vec![0.0f32; p], vec![0.0f32; p]);
                    for k in 0..l {
                        let row = k * p;
                        if tv {
                            // TV step: same per-element op with row multipliers
                            for j in 0..p {
                                let nr = atr[row + j] * wr[j] - ati[row + j] * wi[j]
                                    + br[row + j];
                                let ni = atr[row + j] * wi[j] + ati[row + j] * wr[j]
                                    + bi[row + j];
                                wr[j] = nr;
                                wi[j] = ni;
                            }
                        } else {
                            be.scan_step_planar(
                                &ar,
                                &ai,
                                &mut wr,
                                &mut wi,
                                &br[row..row + p],
                                &bi[row..row + p],
                            );
                        }
                        for j in 0..p {
                            assert_eq!(
                                (xr[row + j], xi[row + j]),
                                (wr[j], wi[j]),
                                "planar tv={tv} l={l} p={p} tile={tile} k={k} j={j}"
                            );
                        }
                    }
                    // the carried state ends at the final state row
                    if l > 0 && p > 0 {
                        assert_eq!(&sr[..], &xr[(l - 1) * p..]);
                        assert_eq!(&si[..], &xi[(l - 1) * p..]);
                    }
                }
            }
        }
    }

    /// The f64-state kernels are tile-decomposition invariant bit-for-bit
    /// (the carry never round-trips through f32), for TI and TV.
    #[test]
    fn f64_resume_is_tile_invariant() {
        let mut g = Rng::new(43);
        let (l, p) = (57usize, 4usize);
        let a = rand_c32(&mut g, p, 0.6);
        let a_tv = rand_c32(&mut g, l * p, 0.6);
        let b = rand_c32(&mut g, l * p, 1.0);
        let (ar, ai) = planes(&a);
        let (atr, ati) = planes(&a_tv);
        let (br, bi) = planes(&b);
        for tv in [false, true] {
            let mut reference: Option<(Vec<f32>, Vec<f32>)> = None;
            for &tile in &[1usize, 5, 16, l, l + 9] {
                let (mut xr, mut xi) = (br.clone(), bi.clone());
                let (mut sr, mut si) = (vec![0.0f64; p], vec![0.0f64; p]);
                let mut t0 = 0usize;
                while t0 < l {
                    let tl = tile.min(l - t0);
                    let (rr, ri) = (
                        &mut xr[t0 * p..(t0 + tl) * p],
                        &mut xi[t0 * p..(t0 + tl) * p],
                    );
                    if tv {
                        scan_resume_tv_planar_f64_inplace(
                            &atr[t0 * p..(t0 + tl) * p],
                            &ati[t0 * p..(t0 + tl) * p],
                            &mut sr,
                            &mut si,
                            rr,
                            ri,
                            tl,
                            p,
                        );
                    } else {
                        scan_resume_ti_planar_f64_inplace(
                            &ar, &ai, &mut sr, &mut si, rr, ri, tl, p,
                        );
                    }
                    t0 += tl;
                }
                match &reference {
                    None => reference = Some((xr, xi)),
                    Some((wr, wi)) => {
                        assert_eq!(&xr, wr, "tv={tv} tile={tile} re plane diverged");
                        assert_eq!(&xi, wi, "tv={tv} tile={tile} im plane diverged");
                    }
                }
            }
        }
    }

    /// The f64 state option exists for long-L drift (open ROADMAP item):
    /// with ā = 1 the TI scan is a running sum, where the f32 carry loses
    /// low bits as the magnitude grows. At L = 64k the f64-state rows
    /// must track the exact (f64) running sum strictly better than the
    /// f32-state rows.
    #[test]
    fn f64_state_reduces_long_l_drift() {
        let l = 65536usize;
        let p = 2usize;
        let mut g = Rng::new(77);
        let ar = vec![1.0f32; p];
        let ai = vec![0.0f32; p];
        let br: Vec<f32> = (0..l * p).map(|_| g.normal() as f32).collect();
        let bi = vec![0.0f32; l * p];

        let (mut xr32, mut xi32) = (br.clone(), bi.clone());
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        scan_resume_ti_planar_inplace(&ar, &ai, &mut sr, &mut si, &mut xr32, &mut xi32, l, p);

        let (mut xr64, mut xi64) = (br.clone(), bi);
        let (mut s64r, mut s64i) = (vec![0.0f64; p], vec![0.0f64; p]);
        scan_resume_ti_planar_f64_inplace(
            &ar, &ai, &mut s64r, &mut s64i, &mut xr64, &mut xi64, l, p,
        );

        let mut acc = vec![0.0f64; p];
        let (mut err32, mut err64) = (0.0f64, 0.0f64);
        for k in 0..l {
            for j in 0..p {
                acc[j] += br[k * p + j] as f64;
                err32 = err32.max((xr32[k * p + j] as f64 - acc[j]).abs());
                err64 = err64.max((xr64[k * p + j] as f64 - acc[j]).abs());
            }
        }
        assert!(
            err64 < err32,
            "f64 state must drift less than f32 at L={l}: err64={err64:e} err32={err32:e}"
        );
        // the f64 rows are exact sums rounded once to f32 — error bounded
        // by one ulp of the running magnitude (~sqrt(L)·σ), far below the
        // accumulated f32 drift
        assert!(err64 < 5e-3, "f64-state error unexpectedly large: {err64:e}");
    }

    fn assert_rel_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let denom = w.abs().max(1.0);
            assert!(
                (g - w).abs() / denom <= tol,
                "{what}: idx {i} got {g} want {w}"
            );
        }
    }

    /// The seeded chunked-parallel resume kernels agree with the
    /// sequential resume kernel to rounding tolerance for every chunking,
    /// are bitwise identical across executors (the decomposition is fixed
    /// by `threads`, not by who runs it), fall back to the sequential
    /// kernel exactly at `threads == 1`, and leave the carry equal to the
    /// emitted final row bit-for-bit.
    #[test]
    fn resume_par_matches_sequential_resume_over_any_chunking() {
        let pool = WorkerPool::new(4);
        let mut g = Rng::new(91);
        for &(l, p) in &[(1usize, 3usize), (7, 2), (40, 5), (64, 1), (129, 8)] {
            let a = rand_c32(&mut g, p, 0.6);
            let a_tv = rand_c32(&mut g, l * p, 0.6);
            let b = rand_c32(&mut g, l * p, 1.0);
            let (ar, ai) = planes(&a);
            let (atr, ati) = planes(&a_tv);
            let (br, bi) = planes(&b);
            let carry = rand_c32(&mut g, p, 1.0);
            let (cr, ci) = planes(&carry);
            for tv in [false, true] {
                // Oracle: the sequential resume from the same carry.
                let (mut wxr, mut wxi) = (br.clone(), bi.clone());
                let (mut wsr, mut wsi) = (cr.clone(), ci.clone());
                if tv {
                    scan_resume_tv_planar_inplace(
                        &atr, &ati, &mut wsr, &mut wsi, &mut wxr, &mut wxi, l, p,
                    );
                } else {
                    scan_resume_ti_planar_inplace(
                        &ar, &ai, &mut wsr, &mut wsi, &mut wxr, &mut wxi, l, p,
                    );
                }
                for threads in [1usize, 2, 3, 8] {
                    let mut ref_run: Option<(Vec<f32>, Vec<f32>)> = None;
                    for exec in [Executor::Inline, Executor::Scoped, Executor::Pool(&pool)] {
                        let (mut xr, mut xi) = (br.clone(), bi.clone());
                        let (mut sr, mut si) = (cr.clone(), ci.clone());
                        let mut scratch = vec![0.0f32; planar_scratch_len(p, threads)];
                        if tv {
                            scan_resume_tv_planar_par_inplace(
                                &atr,
                                &ati,
                                &mut sr,
                                &mut si,
                                &mut xr,
                                &mut xi,
                                l,
                                p,
                                threads,
                                &mut scratch,
                                exec,
                            );
                        } else {
                            scan_resume_ti_planar_par_inplace(
                                &ar,
                                &ai,
                                &mut sr,
                                &mut si,
                                &mut xr,
                                &mut xi,
                                l,
                                p,
                                threads,
                                &mut scratch,
                                exec,
                            );
                        }
                        let what = format!("tv={tv} l={l} p={p} threads={threads}");
                        assert_rel_close(&xr, &wxr, 1e-4, &format!("{what} re"));
                        assert_rel_close(&xi, &wxi, 1e-4, &format!("{what} im"));
                        if threads == 1 {
                            assert_eq!((&xr, &xi), (&wxr, &wxi), "{what}: t=1 must be bitwise");
                        }
                        // carry contract: state ≡ emitted final row, bitwise
                        assert_eq!(&sr[..], &xr[(l - 1) * p..], "{what}: carry re");
                        assert_eq!(&si[..], &xi[(l - 1) * p..], "{what}: carry im");
                        // executor invariance: identical decomposition ⇒
                        // identical bits, regardless of who runs it
                        match &ref_run {
                            None => ref_run = Some((xr, xi)),
                            Some((rr, ri)) => {
                                assert_eq!((&xr, &xi), (rr, ri), "{what}: executor variance");
                            }
                        }
                    }
                }
            }
        }
    }

    /// Tiling composition: driving the chunked-parallel resume tile by
    /// tile (the fused wide path's usage: carry in, carry out) tracks the
    /// whole-sequence sequential scan within rounding tolerance, for
    /// tile sizes that do and don't divide L.
    #[test]
    fn resume_par_tiled_composition_tracks_whole_sequence() {
        let mut g = Rng::new(93);
        let (l, p) = (101usize, 6usize);
        let a = rand_c32(&mut g, p, 0.6);
        let b = rand_c32(&mut g, l * p, 1.0);
        let (ar, ai) = planes(&a);
        let (br, bi) = planes(&b);
        let (mut wxr, mut wxi) = (br.clone(), bi.clone());
        scan_sequential_ti_planar_inplace(&ar, &ai, &mut wxr, &mut wxi, l, p);
        for &tile in &[4usize, 17, 50, l, l + 3] {
            let (mut xr, mut xi) = (br.clone(), bi.clone());
            let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
            let mut scratch = vec![0.0f32; planar_scratch_len(p, 3)];
            let mut t0 = 0usize;
            while t0 < l {
                let tl = tile.min(l - t0);
                let (rr, ri) = (
                    &mut xr[t0 * p..(t0 + tl) * p],
                    &mut xi[t0 * p..(t0 + tl) * p],
                );
                scan_resume_ti_planar_par_inplace(
                    &ar,
                    &ai,
                    &mut sr,
                    &mut si,
                    rr,
                    ri,
                    tl,
                    p,
                    3,
                    &mut scratch,
                    Executor::Scoped,
                );
                t0 += tl;
            }
            assert_rel_close(&xr, &wxr, 1e-4, &format!("tile={tile} re"));
            assert_rel_close(&xi, &wxi, 1e-4, &format!("tile={tile} im"));
        }
    }

    /// The backend entry point honors its contract: sequential fallback
    /// for a budget of 1 (bitwise) and for short tiles, chunked execution
    /// otherwise, with the scratch vector grown on demand.
    #[test]
    fn backend_resume_par_entry_points() {
        let mut g = Rng::new(95);
        let (l, p) = (64usize, 4usize);
        let a = rand_c32(&mut g, p, 0.6);
        let b = rand_c32(&mut g, l * p, 1.0);
        let (ar, ai) = planes(&a);
        let (br, bi) = planes(&b);
        let (mut wxr, mut wxi) = (br.clone(), bi.clone());
        let (mut wsr, mut wsi) = (vec![0.0f32; p], vec![0.0f32; p]);
        scan_resume_ti_planar_inplace(&ar, &ai, &mut wsr, &mut wsi, &mut wxr, &mut wxi, l, p);

        // SequentialBackend's default: ignores the budget, stays bitwise.
        let (mut xr, mut xi) = (br.clone(), bi.clone());
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        let mut scratch = Vec::new();
        SequentialBackend.scan_ti_planar_resume_par(
            &ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p, 8, &mut scratch,
        );
        assert_eq!((&xr, &xi), (&wxr, &wxi));
        assert!(scratch.is_empty(), "default must not touch scratch");

        // ParallelBackend: budget 1 → bitwise sequential; budget > 1 →
        // tolerance, scratch grown once and reused.
        let be = ParallelBackend::with_exec(4, ScanExec::Scoped);
        let (mut xr, mut xi) = (br.clone(), bi.clone());
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        be.scan_ti_planar_resume_par(
            &ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p, 1, &mut scratch,
        );
        assert_eq!((&xr, &xi), (&wxr, &wxi), "budget 1 must be bitwise");
        assert!(scratch.is_empty());

        let (mut xr, mut xi) = (br.clone(), bi.clone());
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        be.scan_ti_planar_resume_par(
            &ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p, 4, &mut scratch,
        );
        assert_rel_close(&xr, &wxr, 1e-4, "budget 4 re");
        assert_rel_close(&xi, &wxi, 1e-4, "budget 4 im");
        let cap = scratch.len();
        assert!(cap >= planar_scratch_len(p, 4));
        // a second call must not need more scratch (steady state)
        let (mut xr, mut xi) = (br.clone(), bi.clone());
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        be.scan_ti_planar_resume_par(
            &ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p, 4, &mut scratch,
        );
        assert_eq!(scratch.len(), cap);
    }

    fn widen(x: &[Bf16]) -> Vec<f32> {
        x.iter().map(|&v| bf16_to_f32(v)).collect()
    }

    fn narrow(x: &[f32]) -> Vec<Bf16> {
        x.iter().map(|&v| f32_to_bf16(v)).collect()
    }

    /// The bf16 sequential resume kernels carry f32 state across any tile
    /// decomposition (bitwise), and every emitted row equals a streaming
    /// step replay — the f32 recurrence step on the widened stored drive
    /// followed by one storage rounding. This is the contract the online
    /// bf16 path reproduces without materializing bf16 planes.
    #[test]
    fn bf16_resume_is_tile_invariant_and_matches_step_replay() {
        let mut g = Rng::new(101);
        for &(l, p) in &[(1usize, 3usize), (7, 2), (40, 5), (129, 8)] {
            let a = rand_c32(&mut g, p, 0.6);
            let a_tv = rand_c32(&mut g, l * p, 0.6);
            let b = rand_c32(&mut g, l * p, 1.0);
            let (ar, ai) = planes(&a);
            let (atr, ati) = planes(&a_tv);
            let (br, bi) = planes(&b);
            let (dr, di) = (narrow(&br), narrow(&bi));
            for tv in [false, true] {
                // Whole-sequence kernel run from a zero carry.
                let (mut xr, mut xi) = (dr.clone(), di.clone());
                let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
                if tv {
                    scan_resume_tv_planar_bf16_inplace(
                        &atr, &ati, &mut sr, &mut si, &mut xr, &mut xi, l, p,
                    );
                } else {
                    scan_resume_ti_planar_bf16_inplace(
                        &ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p,
                    );
                }

                // Step replay: per-row f32 step on the widened stored
                // drive, narrowed once per emitted row.
                let (mut rsr, mut rsi) = (vec![0.0f32; p], vec![0.0f32; p]);
                for k in 0..l {
                    let row = k * p;
                    let (mr, mi) = if tv {
                        (&atr[row..row + p], &ati[row..row + p])
                    } else {
                        (&ar[..], &ai[..])
                    };
                    let bkr = widen(&dr[row..row + p]);
                    let bki = widen(&di[row..row + p]);
                    scan_step_planar_inplace(mr, mi, &mut rsr, &mut rsi, &bkr, &bki);
                    for j in 0..p {
                        assert_eq!(xr[row + j], f32_to_bf16(rsr[j]), "tv={tv} row {k} re {j}");
                        assert_eq!(xi[row + j], f32_to_bf16(rsi[j]), "tv={tv} row {k} im {j}");
                    }
                }
                // The carry never narrows: it equals the replay f32 state.
                assert_eq!((&sr, &si), (&rsr, &rsi), "tv={tv} l={l} p={p} carry");

                // Tile invariance: any decomposition reproduces the bits.
                for &tile in &[1usize, 3, 8, 50] {
                    let (mut txr, mut txi) = (dr.clone(), di.clone());
                    let (mut tsr, mut tsi) = (vec![0.0f32; p], vec![0.0f32; p]);
                    let mut t0 = 0usize;
                    while t0 < l {
                        let tl = tile.min(l - t0);
                        let rows = t0 * p..(t0 + tl) * p;
                        if tv {
                            scan_resume_tv_planar_bf16_inplace(
                                &atr[rows.clone()],
                                &ati[rows.clone()],
                                &mut tsr,
                                &mut tsi,
                                &mut txr[rows.clone()],
                                &mut txi[rows],
                                tl,
                                p,
                            );
                        } else {
                            scan_resume_ti_planar_bf16_inplace(
                                &ar,
                                &ai,
                                &mut tsr,
                                &mut tsi,
                                &mut txr[rows.clone()],
                                &mut txi[rows],
                                tl,
                                p,
                            );
                        }
                        t0 += tl;
                    }
                    assert_eq!((&txr, &txi), (&xr, &xi), "tv={tv} tile={tile} rows");
                    assert_eq!((&tsr, &tsi), (&sr, &si), "tv={tv} tile={tile} carry");
                }
            }
        }
    }

    /// The chunked-parallel bf16 resume kernels agree with the sequential
    /// bf16 kernel to a storage-scale tolerance for every chunking, are
    /// bitwise executor-invariant, and fall back to the sequential kernel
    /// exactly at `threads == 1`. Unlike the f32 kernels there is **no**
    /// carry ≡ final-row assertion: the bf16 carry-out is the f32 combine
    /// state, deliberately not the widened narrowed row.
    #[test]
    fn bf16_resume_par_matches_sequential_over_any_chunking() {
        let pool = WorkerPool::new(4);
        let mut g = Rng::new(103);
        for &(l, p) in &[(1usize, 3usize), (7, 2), (40, 5), (64, 1), (129, 8)] {
            let a = rand_c32(&mut g, p, 0.6);
            let a_tv = rand_c32(&mut g, l * p, 0.6);
            let b = rand_c32(&mut g, l * p, 1.0);
            let (ar, ai) = planes(&a);
            let (atr, ati) = planes(&a_tv);
            let (br, bi) = planes(&b);
            let (dr, di) = (narrow(&br), narrow(&bi));
            let carry = rand_c32(&mut g, p, 1.0);
            let (cr, ci) = planes(&carry);
            for tv in [false, true] {
                // Oracle: the sequential bf16 resume from the same carry.
                let (mut wxr, mut wxi) = (dr.clone(), di.clone());
                let (mut wsr, mut wsi) = (cr.clone(), ci.clone());
                if tv {
                    scan_resume_tv_planar_bf16_inplace(
                        &atr, &ati, &mut wsr, &mut wsi, &mut wxr, &mut wxi, l, p,
                    );
                } else {
                    scan_resume_ti_planar_bf16_inplace(
                        &ar, &ai, &mut wsr, &mut wsi, &mut wxr, &mut wxi, l, p,
                    );
                }
                for threads in [1usize, 2, 3, 8] {
                    let mut ref_run: Option<(Vec<Bf16>, Vec<Bf16>)> = None;
                    for exec in [Executor::Inline, Executor::Scoped, Executor::Pool(&pool)] {
                        let (mut xr, mut xi) = (dr.clone(), di.clone());
                        let (mut sr, mut si) = (cr.clone(), ci.clone());
                        let mut scratch = vec![0.0f32; planar_scratch_len(p, threads)];
                        if tv {
                            scan_resume_tv_planar_par_bf16_inplace(
                                &atr,
                                &ati,
                                &mut sr,
                                &mut si,
                                &mut xr,
                                &mut xi,
                                l,
                                p,
                                threads,
                                &mut scratch,
                                exec,
                            );
                        } else {
                            scan_resume_ti_planar_par_bf16_inplace(
                                &ar,
                                &ai,
                                &mut sr,
                                &mut si,
                                &mut xr,
                                &mut xi,
                                l,
                                p,
                                threads,
                                &mut scratch,
                                exec,
                            );
                        }
                        let what = format!("tv={tv} l={l} p={p} threads={threads}");
                        // Storage-scale tolerance: the chunked form
                        // narrows twice per fixed-up row (2⁻⁸ each).
                        assert_rel_close(&widen(&xr), &widen(&wxr), 2e-2, &format!("{what} re"));
                        assert_rel_close(&widen(&xi), &widen(&wxi), 2e-2, &format!("{what} im"));
                        assert_rel_close(&sr, &wsr, 2e-2, &format!("{what} carry re"));
                        assert_rel_close(&si, &wsi, 2e-2, &format!("{what} carry im"));
                        if threads == 1 {
                            assert_eq!((&xr, &xi), (&wxr, &wxi), "{what}: t=1 rows bitwise");
                            assert_eq!((&sr, &si), (&wsr, &wsi), "{what}: t=1 carry bitwise");
                        }
                        match &ref_run {
                            None => ref_run = Some((xr, xi)),
                            Some((rr, ri)) => {
                                assert_eq!((&xr, &xi), (rr, ri), "{what}: executor variance");
                            }
                        }
                    }
                }
            }
        }
    }

    /// The backend plumbing for bf16 storage: the trait defaults stay
    /// sequential-bitwise on every backend, the parallel override honors
    /// the budget-1 fallback and grows its scratch once, and the
    /// `Interleaved` oracle wrapper forwards rather than re-deriving.
    #[test]
    fn backend_bf16_entry_points() {
        let mut g = Rng::new(105);
        let (l, p) = (64usize, 4usize);
        let a = rand_c32(&mut g, p, 0.6);
        let b = rand_c32(&mut g, l * p, 1.0);
        let (ar, ai) = planes(&a);
        let (br, bi) = planes(&b);
        let (dr, di) = (narrow(&br), narrow(&bi));
        let (mut wxr, mut wxi) = (dr.clone(), di.clone());
        let (mut wsr, mut wsi) = (vec![0.0f32; p], vec![0.0f32; p]);
        scan_resume_ti_planar_bf16_inplace(&ar, &ai, &mut wsr, &mut wsi, &mut wxr, &mut wxi, l, p);

        // Sequential resume entry: backend-invariant bitwise.
        for be in [
            Box::new(SequentialBackend) as Box<dyn ScanBackend>,
            Box::new(ParallelBackend::with_exec(4, ScanExec::Scoped)),
            Box::new(Interleaved(ParallelBackend::with_exec(4, ScanExec::Scoped))),
        ] {
            let (mut xr, mut xi) = (dr.clone(), di.clone());
            let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
            be.scan_ti_planar_resume_bf16(&ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p);
            assert_eq!((&xr, &xi), (&wxr, &wxi), "{} rows", be.name());
            assert_eq!((&sr, &si), (&wsr, &wsi), "{} carry", be.name());
        }

        // The wide entry: default ignores the budget (bitwise, scratch
        // untouched); the parallel override chunks under tolerance.
        let (mut xr, mut xi) = (dr.clone(), di.clone());
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        let mut scratch = Vec::new();
        SequentialBackend.scan_ti_planar_resume_par_bf16(
            &ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p, 8, &mut scratch,
        );
        assert_eq!((&xr, &xi), (&wxr, &wxi));
        assert!(scratch.is_empty(), "default must not touch scratch");

        let be = ParallelBackend::with_exec(4, ScanExec::Scoped);
        let (mut xr, mut xi) = (dr.clone(), di.clone());
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        be.scan_ti_planar_resume_par_bf16(
            &ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p, 1, &mut scratch,
        );
        assert_eq!((&xr, &xi), (&wxr, &wxi), "budget 1 must be bitwise");
        assert!(scratch.is_empty());

        let (mut xr, mut xi) = (dr.clone(), di.clone());
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        be.scan_ti_planar_resume_par_bf16(
            &ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p, 4, &mut scratch,
        );
        assert_rel_close(&widen(&xr), &widen(&wxr), 2e-2, "budget 4 re");
        assert_rel_close(&widen(&xi), &widen(&wxi), 2e-2, "budget 4 im");
        let cap = scratch.len();
        assert!(cap >= planar_scratch_len(p, 4));
        let (mut xr, mut xi) = (dr.clone(), di.clone());
        let (mut sr, mut si) = (vec![0.0f32; p], vec![0.0f32; p]);
        be.scan_ti_planar_resume_par_bf16(
            &ar, &ai, &mut sr, &mut si, &mut xr, &mut xi, l, p, 4, &mut scratch,
        );
        assert_eq!(scratch.len(), cap);
    }
}
