//! Scans for first-order linear recurrences (paper §2.2, Appendix H).
//!
//! The recurrence x_k = ā_k ∘ x_{k−1} + b_k over ℂ^P is provided at three
//! altitudes:
//!
//! 1. **In-place kernels** — [`scan_sequential_ti_inplace`] /
//!    [`scan_sequential_tv_inplace`] overwrite the drive buffer with the
//!    states using the previous output row as the carried state (no scratch
//!    at all); [`scan_parallel_ti_inplace`] / [`scan_parallel_tv_inplace`]
//!    are the multi-threaded chunked form (local scan → chunk-summary
//!    combine → fixup, the CPU analogue of the work-efficient Blelloch scan
//!    the paper leans on). The parallel kernels honor the requested chunking
//!    exactly — heuristics live in the backends — so tests can pin
//!    chunk-boundary behavior.
//! 2. **The [`ScanBackend`] trait** — the object-safe strategy interface the
//!    batched engine ([`crate::ssm::engine`]) threads through the S5 stack.
//!    It unifies sequential and parallel, time-invariant (TI) and
//!    time-varying (TV) scans, adds batched entry points over (B, L, P)
//!    row-major buffers (parallelized across B × chunks), and exposes the
//!    single-step recurrence ([`ScanBackend::scan_step`]) that online
//!    generation (§3.3) shares with the offline path.
//! 3. **Allocating wrappers** — [`scan_sequential`], [`scan_sequential_ti`],
//!    [`scan_parallel_ti`], [`scan_parallel_tv`] keep the original
//!    copy-out signatures for benches and exploratory code.
//!
//! [`scan_dense_sequential`] is the O(L·P²)/O(L·P³) *dense*-A strawman of
//! §2.2, kept as a baseline to demonstrate why diagonalization is load-
//! bearing for S5. [`scan_sequential_ti_planar`] is the struct-of-arrays
//! layout experiment matching the L1 kernel's planar f32 streams.

use crate::num::{C32, C64};

// ---------------------------------------------------------------------------
// In-place kernels
// ---------------------------------------------------------------------------

/// One streaming recurrence step: `state ← a ∘ state + b` (elementwise).
///
/// This is the shared inner step of the sequential kernels and of online
/// generation ([`crate::ssm::online`]), so the two modes cannot drift.
#[inline]
pub fn scan_step_inplace(a: &[C32], state: &mut [C32], b: &[C32]) {
    debug_assert_eq!(a.len(), state.len());
    debug_assert_eq!(b.len(), state.len());
    for j in 0..state.len() {
        state[j] = a[j] * state[j] + b[j];
    }
}

/// Sequential time-invariant scan, in place: on entry `bu` holds the drive
/// b (row-major (L, P)); on exit it holds the states x. `a` has length P.
///
/// Uses the previous output row as the carried state — zero scratch.
pub fn scan_sequential_ti_inplace(a: &[C32], bu: &mut [C32], l: usize, p: usize) {
    assert_eq!(a.len(), p);
    assert_eq!(bu.len(), l * p);
    for k in 1..l {
        let (prev, cur) = bu.split_at_mut(k * p);
        let prev = &prev[(k - 1) * p..];
        for j in 0..p {
            cur[j] = a[j] * prev[j] + cur[j];
        }
    }
}

/// Sequential time-varying scan, in place: `a` and `bu` are (L, P).
pub fn scan_sequential_tv_inplace(a: &[C32], bu: &mut [C32], l: usize, p: usize) {
    assert_eq!(a.len(), l * p);
    assert_eq!(bu.len(), l * p);
    for k in 1..l {
        let row = k * p;
        let (prev, cur) = bu.split_at_mut(row);
        let prev = &prev[(k - 1) * p..];
        for j in 0..p {
            cur[j] = a[row + j] * prev[j] + cur[j];
        }
    }
}

/// Parallel chunked TI scan, in place, over exactly `threads` chunks
/// (clamped to L). Three phases (classic two-pass prefix scan, Blelloch
/// §1.4 at CPU chunk granularity):
///
///  1. each worker scans its chunk locally from x=0 in place and records
///     the chunk's composition (ā^len, local final state);
///  2. chunk summaries combine sequentially (T ≪ L elements);
///  3. each worker adds `ā^{k−start+1} ∘ x_enter` to its local states.
///
/// No small-L fallback: callers get the chunking they ask for (the
/// [`ParallelBackend`] applies the "sequential is faster below 4·T rows"
/// heuristic). Transient allocation is O(T·P) for the summaries.
pub fn scan_parallel_ti_inplace(a: &[C32], bu: &mut [C32], l: usize, p: usize, threads: usize) {
    assert_eq!(a.len(), p);
    assert_eq!(bu.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_sequential_ti_inplace(a, bu, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);

    let mut a_pow = vec![C32::ZERO; n_chunks * p];
    let mut last = vec![C32::ZERO; n_chunks * p];

    // Phase 1: local in-place scans (parallel).
    {
        let xs_chunks: Vec<&mut [C32]> = bu.chunks_mut(chunk * p).collect();
        let apow_chunks: Vec<&mut [C32]> = a_pow.chunks_mut(p).collect();
        let last_chunks: Vec<&mut [C32]> = last.chunks_mut(p).collect();
        std::thread::scope(|s| {
            for (c, ((xc, ac), lc)) in xs_chunks
                .into_iter()
                .zip(apow_chunks)
                .zip(last_chunks)
                .enumerate()
            {
                s.spawn(move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 1..len {
                        let (prev, cur) = xc.split_at_mut(k * p);
                        let prev = &prev[(k - 1) * p..];
                        for j in 0..p {
                            cur[j] = a[j] * prev[j] + cur[j];
                        }
                    }
                    for j in 0..p {
                        ac[j] = a[j].powi(len as u32);
                        lc[j] = xc[(len - 1) * p + j];
                    }
                });
            }
        });
    }

    // Phase 2: combine chunk summaries sequentially → state entering chunk c.
    let mut enter = vec![C32::ZERO; n_chunks * p];
    {
        let mut state = vec![C32::ZERO; p];
        for c in 0..n_chunks {
            enter[c * p..(c + 1) * p].copy_from_slice(&state);
            for j in 0..p {
                state[j] = a_pow[c * p + j] * state[j] + last[c * p + j];
            }
        }
    }

    // Phase 3: fixup (parallel): x_k += ā^{k−start+1} ∘ x_enter. The enter
    // rows double as the carry accumulators.
    {
        let xs_chunks: Vec<&mut [C32]> = bu.chunks_mut(chunk * p).collect();
        let enter_chunks: Vec<&mut [C32]> = enter.chunks_mut(p).collect();
        std::thread::scope(|s| {
            for (c, (xc, carry)) in xs_chunks.into_iter().zip(enter_chunks).enumerate() {
                if c == 0 {
                    continue; // enters at zero: nothing to add
                }
                s.spawn(move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 0..len {
                        let row = k * p;
                        for j in 0..p {
                            carry[j] = carry[j] * a[j];
                            xc[row + j] += carry[j];
                        }
                    }
                });
            }
        });
    }
}

/// Parallel chunked TV scan, in place (irregular sampling): `a`, `bu` are
/// (L, P). Same three phases as [`scan_parallel_ti_inplace`] with per-step
/// multiplier products as the chunk summaries.
pub fn scan_parallel_tv_inplace(a: &[C32], bu: &mut [C32], l: usize, p: usize, threads: usize) {
    assert_eq!(a.len(), l * p);
    assert_eq!(bu.len(), l * p);
    if l == 0 || p == 0 {
        return;
    }
    let threads = threads.max(1).min(l);
    if threads == 1 {
        return scan_sequential_tv_inplace(a, bu, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);

    let mut a_prod = vec![C32::ZERO; n_chunks * p];
    let mut last = vec![C32::ZERO; n_chunks * p];

    {
        let xs_chunks: Vec<&mut [C32]> = bu.chunks_mut(chunk * p).collect();
        let aprod_chunks: Vec<&mut [C32]> = a_prod.chunks_mut(p).collect();
        let last_chunks: Vec<&mut [C32]> = last.chunks_mut(p).collect();
        std::thread::scope(|s| {
            for (c, ((xc, ac), lc)) in xs_chunks
                .into_iter()
                .zip(aprod_chunks)
                .zip(last_chunks)
                .enumerate()
            {
                s.spawn(move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    ac.fill(C32::ONE);
                    for k in 0..len {
                        let g = (start + k) * p;
                        if k > 0 {
                            let (prev, cur) = xc.split_at_mut(k * p);
                            let prev = &prev[(k - 1) * p..];
                            for j in 0..p {
                                cur[j] = a[g + j] * prev[j] + cur[j];
                            }
                        }
                        for j in 0..p {
                            ac[j] = a[g + j] * ac[j];
                        }
                    }
                    lc.copy_from_slice(&xc[(len - 1) * p..len * p]);
                });
            }
        });
    }

    let mut enter = vec![C32::ZERO; n_chunks * p];
    {
        let mut state = vec![C32::ZERO; p];
        for c in 0..n_chunks {
            enter[c * p..(c + 1) * p].copy_from_slice(&state);
            for j in 0..p {
                state[j] = a_prod[c * p + j] * state[j] + last[c * p + j];
            }
        }
    }

    {
        let xs_chunks: Vec<&mut [C32]> = bu.chunks_mut(chunk * p).collect();
        let enter_chunks: Vec<&mut [C32]> = enter.chunks_mut(p).collect();
        std::thread::scope(|s| {
            for (c, (xc, carry)) in xs_chunks.into_iter().zip(enter_chunks).enumerate() {
                if c == 0 {
                    continue;
                }
                s.spawn(move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    for k in 0..len {
                        let g = (start + k) * p;
                        let row = k * p;
                        for j in 0..p {
                            carry[j] = a[g + j] * carry[j];
                            xc[row + j] += carry[j];
                        }
                    }
                });
            }
        });
    }
}

// ---------------------------------------------------------------------------
// ScanBackend: the pluggable strategy the engine threads through the stack
// ---------------------------------------------------------------------------

/// Object-safe scan strategy.
///
/// One backend object serves every scan shape in the native stack:
///
/// * `scan_ti` / `scan_tv` — one sequence, in place over the drive buffer;
/// * `scan_batch_ti` / `scan_batch_tv` — a packed (B, L, P) row-major batch,
///   each sequence scanned independently (backends parallelize across
///   B sequences × in-sequence chunks);
/// * `scan_step` — the single-step recurrence online generation uses, so
///   streaming and offline scans share one inner kernel.
///
/// All entry points overwrite the drive with the states and allocate no
/// per-element scratch; parallel strategies allocate O(threads·P) chunk
/// summaries per call.
pub trait ScanBackend: Send + Sync {
    /// Short human-readable strategy name (for benches/telemetry).
    fn name(&self) -> &'static str;

    /// Worker-thread budget this backend schedules onto (1 = sequential).
    fn threads(&self) -> usize;

    /// Time-invariant scan of one sequence: `a` (P), `bu` (L, P) in/out.
    fn scan_ti(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize);

    /// Time-varying scan of one sequence: `a`, `bu` (L, P) in/out.
    fn scan_tv(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize);

    /// Batched TI scan: `a` (P) shared, `bu` (B, L, P) in/out.
    fn scan_batch_ti(&self, a: &[C32], bu: &mut [C32], batch: usize, l: usize, p: usize) {
        assert_eq!(bu.len(), batch * l * p);
        if l == 0 || p == 0 {
            return;
        }
        for seq in bu.chunks_mut(l * p) {
            self.scan_ti(a, seq, l, p);
        }
    }

    /// Batched TV scan: `a`, `bu` both (B, L, P), `bu` in/out.
    fn scan_batch_tv(&self, a: &[C32], bu: &mut [C32], batch: usize, l: usize, p: usize) {
        assert_eq!(a.len(), batch * l * p);
        assert_eq!(bu.len(), batch * l * p);
        if l == 0 || p == 0 {
            return;
        }
        for (aseq, seq) in a.chunks(l * p).zip(bu.chunks_mut(l * p)) {
            self.scan_tv(aseq, seq, l, p);
        }
    }

    /// One streaming step `state ← a ∘ state + b` (online generation §3.3).
    fn scan_step(&self, a: &[C32], state: &mut [C32], b: &[C32]) {
        scan_step_inplace(a, state, b);
    }
}

/// The literal O(L·P) loop (ground truth; also the online-generation mode
/// of §3.3 at L = 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct SequentialBackend;

impl ScanBackend for SequentialBackend {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn threads(&self) -> usize {
        1
    }

    fn scan_ti(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize) {
        scan_sequential_ti_inplace(a, bu, l, p);
    }

    fn scan_tv(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize) {
        scan_sequential_tv_inplace(a, bu, l, p);
    }
}

/// Multi-threaded backend: chunked Blelloch scan within a sequence,
/// sequence-sharding across a batch.
///
/// Heuristics: a single sequence falls back to the sequential kernel below
/// 4·T rows (chunk bookkeeping would dominate); a batch with B ≥ T shards
/// whole sequences across workers (embarrassingly parallel, no fixup
/// phase); a batch with B < T gives each sequence ⌊T/B⌋ chunk-workers.
#[derive(Clone, Copy, Debug)]
pub struct ParallelBackend {
    threads: usize,
}

impl ParallelBackend {
    /// `threads = 0` auto-detects via `std::thread::available_parallelism`.
    pub fn new(threads: usize) -> ParallelBackend {
        ParallelBackend { threads: crate::ssm::engine::auto_threads(threads) }
    }
}

impl ScanBackend for ParallelBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn scan_ti(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize) {
        if self.threads <= 1 || l < 4 * self.threads {
            scan_sequential_ti_inplace(a, bu, l, p);
        } else {
            scan_parallel_ti_inplace(a, bu, l, p, self.threads);
        }
    }

    fn scan_tv(&self, a: &[C32], bu: &mut [C32], l: usize, p: usize) {
        if self.threads <= 1 || l < 4 * self.threads {
            scan_sequential_tv_inplace(a, bu, l, p);
        } else {
            scan_parallel_tv_inplace(a, bu, l, p, self.threads);
        }
    }

    fn scan_batch_ti(&self, a: &[C32], bu: &mut [C32], batch: usize, l: usize, p: usize) {
        assert_eq!(bu.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        let rows = l * p;
        let t = self.threads.max(1);
        if batch == 1 {
            return self.scan_ti(a, bu, l, p);
        }
        if t <= 1 {
            for seq in bu.chunks_mut(rows) {
                scan_sequential_ti_inplace(a, seq, l, p);
            }
        } else if batch >= t {
            let per = batch.div_ceil(t);
            std::thread::scope(|s| {
                for shard in bu.chunks_mut(per * rows) {
                    s.spawn(move || {
                        for seq in shard.chunks_mut(rows) {
                            scan_sequential_ti_inplace(a, seq, l, p);
                        }
                    });
                }
            });
        } else {
            let per_seq = t / batch;
            std::thread::scope(|s| {
                for seq in bu.chunks_mut(rows) {
                    s.spawn(move || {
                        if per_seq <= 1 || l < 4 * per_seq {
                            scan_sequential_ti_inplace(a, seq, l, p);
                        } else {
                            scan_parallel_ti_inplace(a, seq, l, p, per_seq);
                        }
                    });
                }
            });
        }
    }

    fn scan_batch_tv(&self, a: &[C32], bu: &mut [C32], batch: usize, l: usize, p: usize) {
        assert_eq!(a.len(), batch * l * p);
        assert_eq!(bu.len(), batch * l * p);
        if batch == 0 || l == 0 || p == 0 {
            return;
        }
        let rows = l * p;
        let t = self.threads.max(1);
        if batch == 1 {
            return self.scan_tv(a, bu, l, p);
        }
        if t <= 1 {
            for (aseq, seq) in a.chunks(rows).zip(bu.chunks_mut(rows)) {
                scan_sequential_tv_inplace(aseq, seq, l, p);
            }
        } else if batch >= t {
            let per = batch.div_ceil(t);
            std::thread::scope(|s| {
                for (ashard, shard) in a.chunks(per * rows).zip(bu.chunks_mut(per * rows)) {
                    s.spawn(move || {
                        for (aseq, seq) in ashard.chunks(rows).zip(shard.chunks_mut(rows)) {
                            scan_sequential_tv_inplace(aseq, seq, l, p);
                        }
                    });
                }
            });
        } else {
            let per_seq = t / batch;
            std::thread::scope(|s| {
                for (aseq, seq) in a.chunks(rows).zip(bu.chunks_mut(rows)) {
                    s.spawn(move || {
                        if per_seq <= 1 || l < 4 * per_seq {
                            scan_sequential_tv_inplace(aseq, seq, l, p);
                        } else {
                            scan_parallel_tv_inplace(aseq, seq, l, p, per_seq);
                        }
                    });
                }
            });
        }
    }
}

/// Pick a backend for a thread budget: ≤ 1 worker → [`SequentialBackend`],
/// otherwise [`ParallelBackend`]; `threads = 0` auto-detects.
///
/// This is the resolver behind the `threads` knob everywhere — the CLI,
/// the native server, and
/// [`ForwardOptions::with_threads`](crate::ssm::api::ForwardOptions::with_threads)
/// in the unified inference API all funnel through it.
pub fn backend_for_threads(threads: usize) -> Box<dyn ScanBackend> {
    let t = crate::ssm::engine::auto_threads(threads);
    if t <= 1 {
        Box::new(SequentialBackend)
    } else {
        Box::new(ParallelBackend::new(t))
    }
}

// ---------------------------------------------------------------------------
// Allocating wrappers (original signatures)
// ---------------------------------------------------------------------------

/// Sequential scan, time-varying multipliers.
///
/// `a`, `b`: row-major (L, P). Returns states (L, P).
pub fn scan_sequential(a: &[C32], b: &[C32], l: usize, p: usize) -> Vec<C32> {
    assert_eq!(a.len(), l * p);
    assert_eq!(b.len(), l * p);
    let mut xs = b.to_vec();
    scan_sequential_tv_inplace(a, &mut xs, l, p);
    xs
}

/// Sequential scan with a *time-invariant* diagonal (the common S5 case):
/// `a` has length P.
pub fn scan_sequential_ti(a: &[C32], b: &[C32], l: usize, p: usize) -> Vec<C32> {
    assert_eq!(a.len(), p);
    assert_eq!(b.len(), l * p);
    let mut xs = b.to_vec();
    scan_sequential_ti_inplace(a, &mut xs, l, p);
    xs
}

/// Parallel chunked scan over `threads` workers (time-invariant diagonal).
/// Falls back to the sequential kernel when the chunk bookkeeping would
/// dominate (L < 4·threads).
pub fn scan_parallel_ti(a: &[C32], b: &[C32], l: usize, p: usize, threads: usize) -> Vec<C32> {
    assert_eq!(a.len(), p);
    assert_eq!(b.len(), l * p);
    let threads = threads.max(1).min(l.max(1));
    let mut xs = b.to_vec();
    if threads == 1 || l < 4 * threads {
        scan_sequential_ti_inplace(a, &mut xs, l, p);
    } else {
        scan_parallel_ti_inplace(a, &mut xs, l, p, threads);
    }
    xs
}

/// Parallel chunked scan with time-varying multipliers (irregular sampling).
pub fn scan_parallel_tv(a: &[C32], b: &[C32], l: usize, p: usize, threads: usize) -> Vec<C32> {
    assert_eq!(a.len(), l * p);
    assert_eq!(b.len(), l * p);
    let threads = threads.max(1).min(l.max(1));
    let mut xs = b.to_vec();
    if threads == 1 || l < 4 * threads {
        scan_sequential_tv_inplace(a, &mut xs, l, p);
    } else {
        scan_parallel_tv_inplace(a, &mut xs, l, p, threads);
    }
    xs
}

/// Planar (struct-of-arrays) sequential scan: separate re/im f32 streams,
/// matching the L1 kernel's memory layout.
///
/// §Perf experiment (EXPERIMENTS.md): the interleaved `C32` loop carries a
/// real↔imag data dependence per element that blocks autovectorization;
/// planar streams let LLVM emit SIMD mul/fma over the P lanes. Same math,
/// same O(L·P) work.
pub fn scan_sequential_ti_planar(
    ar: &[f32],
    ai: &[f32],
    br: &[f32],
    bi: &[f32],
    l: usize,
    p: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(ar.len(), p);
    assert_eq!(br.len(), l * p);
    let mut xr = vec![0.0f32; l * p];
    let mut xi = vec![0.0f32; l * p];
    let mut sr = vec![0.0f32; p];
    let mut si = vec![0.0f32; p];
    for k in 0..l {
        let row = k * p;
        let (brk, bik) = (&br[row..row + p], &bi[row..row + p]);
        let (xrk, xik) = (&mut xr[row..row + p], &mut xi[row..row + p]);
        for j in 0..p {
            let nr = ar[j] * sr[j] - ai[j] * si[j] + brk[j];
            let ni = ar[j] * si[j] + ai[j] * sr[j] + bik[j];
            sr[j] = nr;
            si[j] = ni;
            xrk[j] = nr;
            xik[j] = ni;
        }
    }
    (xr, xi)
}

/// Dense-state-matrix sequential recurrence x_k = Ā x_{k−1} + b_k — the
/// O(L·P²) strawman of §2.2 (its *parallel* form would need O(P³) matrix
/// products per combine, which is the cost the diagonalization removes).
///
/// `a_dense`: row-major (P, P) in C64 for accuracy; `b`: (L, P).
pub fn scan_dense_sequential(a_dense: &[C64], b: &[C64], l: usize, p: usize) -> Vec<C64> {
    assert_eq!(a_dense.len(), p * p);
    assert_eq!(b.len(), l * p);
    let mut xs = vec![C64::ZERO; l * p];
    let mut state = vec![C64::ZERO; p];
    let mut next = vec![C64::ZERO; p];
    for k in 0..l {
        for i in 0..p {
            let mut acc = b[k * p + i];
            for j in 0..p {
                acc += a_dense[i * p + j] * state[j];
            }
            next[i] = acc;
        }
        std::mem::swap(&mut state, &mut next);
        xs[k * p..(k + 1) * p].copy_from_slice(&state);
    }
    xs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::prop;

    fn rand_c32(g: &mut Rng, n: usize, scale: f32) -> Vec<C32> {
        (0..n)
            .map(|_| C32::new(g.normal() as f32 * scale, g.normal() as f32 * scale))
            .collect()
    }

    fn close(a: &[C32], b: &[C32], tol: f32) -> prop::PropResult {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            let d = (*x - *y).abs();
            let s = 1.0 + x.abs().max(y.abs());
            if d > tol * s {
                return Err(format!("idx {i}: {x:?} !~ {y:?}"));
            }
        }
        Ok(())
    }

    #[test]
    fn sequential_ti_matches_tv() {
        let mut g = Rng::new(0);
        let (l, p) = (50, 4);
        let a = rand_c32(&mut g, p, 0.5);
        let b = rand_c32(&mut g, l * p, 1.0);
        let mut a_full = Vec::with_capacity(l * p);
        for _ in 0..l {
            a_full.extend_from_slice(&a);
        }
        let x1 = scan_sequential_ti(&a, &b, l, p);
        let x2 = scan_sequential(&a_full, &b, l, p);
        close(&x1, &x2, 1e-6).unwrap();
    }

    #[test]
    fn prop_parallel_ti_matches_sequential() {
        prop::check("parallel TI scan ≡ sequential", 40, |g| {
            let l = 1 + g.below(500);
            let p = 1 + g.below(12);
            let threads = 1 + g.below(8);
            let a = rand_c32(g, p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let seq = scan_sequential_ti(&a, &b, l, p);
            let par = scan_parallel_ti(&a, &b, l, p, threads);
            close(&seq, &par, 1e-4)
        });
    }

    #[test]
    fn prop_parallel_tv_matches_sequential() {
        prop::check("parallel TV scan ≡ sequential", 40, |g| {
            let l = 1 + g.below(400);
            let p = 1 + g.below(10);
            let threads = 1 + g.below(8);
            let a = rand_c32(g, l * p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let seq = scan_sequential(&a, &b, l, p);
            let par = scan_parallel_tv(&a, &b, l, p, threads);
            close(&seq, &par, 1e-4)
        });
    }

    /// Chunk-boundary sweep: the in-place parallel kernels (no fallback)
    /// must match the sequential kernels at L = 1, chunk−1, chunk, chunk+1
    /// and non-divisible L, for several thread counts.
    #[test]
    fn parallel_inplace_chunk_boundaries() {
        let mut g = Rng::new(11);
        for &t in &[2usize, 3, 5, 8] {
            // with threads = t, chunk = ceil(l / t): exercise the lengths
            // around every boundary the sharding can produce
            for &l in &[1usize, 2, t - 1, t, t + 1, 4 * t - 1, 4 * t, 4 * t + 1, 10 * t + 3] {
                let l = l.max(1);
                let p = 3;
                let a = rand_c32(&mut g, p, 0.6);
                let b = rand_c32(&mut g, l * p, 1.0);
                let want = scan_sequential_ti(&a, &b, l, p);
                let mut got = b.clone();
                scan_parallel_ti_inplace(&a, &mut got, l, p, t);
                close(&want, &got, 1e-4)
                    .unwrap_or_else(|e| panic!("TI t={t} l={l}: {e}"));

                let a_tv = rand_c32(&mut g, l * p, 0.6);
                let want = scan_sequential(&a_tv, &b, l, p);
                let mut got = b.clone();
                scan_parallel_tv_inplace(&a_tv, &mut got, l, p, t);
                close(&want, &got, 1e-4)
                    .unwrap_or_else(|e| panic!("TV t={t} l={l}: {e}"));
            }
        }
    }

    /// Every backend agrees with the sequential ground truth on single
    /// sequences, for TI and TV multipliers.
    #[test]
    fn prop_backends_agree_single_sequence() {
        let backends: Vec<Box<dyn ScanBackend>> = vec![
            Box::new(SequentialBackend),
            Box::new(ParallelBackend::new(2)),
            Box::new(ParallelBackend::new(3)),
            Box::new(ParallelBackend::new(8)),
        ];
        prop::check("ScanBackend single-seq equivalence", 25, |g| {
            let l = 1 + g.below(300);
            let p = 1 + g.below(8);
            let a = rand_c32(g, p, 0.6);
            let a_tv = rand_c32(g, l * p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let want_ti = scan_sequential_ti(&a, &b, l, p);
            let want_tv = scan_sequential(&a_tv, &b, l, p);
            for be in &backends {
                let mut got = b.clone();
                be.scan_ti(&a, &mut got, l, p);
                close(&want_ti, &got, 1e-4)
                    .map_err(|e| format!("{} TI: {e}", be.name()))?;
                let mut got = b.clone();
                be.scan_tv(&a_tv, &mut got, l, p);
                close(&want_tv, &got, 1e-4)
                    .map_err(|e| format!("{} TV: {e}", be.name()))?;
            }
            Ok(())
        });
    }

    /// Batched scans equal per-sequence scans for every backend, across
    /// B < threads, B = threads and B > threads regimes.
    #[test]
    fn prop_scan_batch_matches_per_sequence() {
        let backends: Vec<Box<dyn ScanBackend>> = vec![
            Box::new(SequentialBackend),
            Box::new(ParallelBackend::new(2)),
            Box::new(ParallelBackend::new(4)),
        ];
        prop::check("scan_batch ≡ per-sequence", 20, |g| {
            let batch = 1 + g.below(7);
            let l = 1 + g.below(120);
            let p = 1 + g.below(6);
            let a = rand_c32(g, p, 0.6);
            let a_tv = rand_c32(g, batch * l * p, 0.6);
            let b = rand_c32(g, batch * l * p, 1.0);

            let mut want_ti = b.clone();
            let mut want_tv = b.clone();
            for bi in 0..batch {
                let s = bi * l * p;
                scan_sequential_ti_inplace(&a, &mut want_ti[s..s + l * p], l, p);
                scan_sequential_tv_inplace(
                    &a_tv[s..s + l * p],
                    &mut want_tv[s..s + l * p],
                    l,
                    p,
                );
            }
            for be in &backends {
                let mut got = b.clone();
                be.scan_batch_ti(&a, &mut got, batch, l, p);
                close(&want_ti, &got, 1e-4)
                    .map_err(|e| format!("{} batch TI (B={batch}): {e}", be.name()))?;
                let mut got = b.clone();
                be.scan_batch_tv(&a_tv, &mut got, batch, l, p);
                close(&want_tv, &got, 1e-4)
                    .map_err(|e| format!("{} batch TV (B={batch}): {e}", be.name()))?;
            }
            Ok(())
        });
    }

    /// The streaming step kernel replayed over a sequence equals the
    /// offline TI scan — the online/offline shared-code-path guarantee.
    #[test]
    fn scan_step_replay_equals_offline() {
        let mut g = Rng::new(21);
        let (l, p) = (64, 5);
        let a = rand_c32(&mut g, p, 0.6);
        let b = rand_c32(&mut g, l * p, 1.0);
        let offline = scan_sequential_ti(&a, &b, l, p);
        let be = SequentialBackend;
        let mut state = vec![C32::ZERO; p];
        for k in 0..l {
            be.scan_step(&a, &mut state, &b[k * p..(k + 1) * p]);
            close(&offline[k * p..(k + 1) * p], &state, 1e-6)
                .unwrap_or_else(|e| panic!("k={k}: {e}"));
        }
    }

    #[test]
    fn parallel_exact_on_cumsum() {
        // a = 1: scan is a cumulative sum, easy closed form.
        let (l, p) = (1000, 2);
        let a = vec![C32::ONE; p];
        let b = vec![C32::new(1.0, 0.0); l * p];
        let xs = scan_parallel_ti(&a, &b, l, p, 4);
        for k in 0..l {
            assert!((xs[k * p].re - (k as f32 + 1.0)).abs() < 1e-3);
        }
    }

    #[test]
    fn dense_scan_matches_diagonal_when_a_is_diagonal() {
        let mut g = Rng::new(3);
        let (l, p) = (40, 5);
        let diag: Vec<C64> = (0..p).map(|_| C64::new(g.normal() * 0.4, g.normal() * 0.4)).collect();
        let mut a_dense = vec![C64::ZERO; p * p];
        for j in 0..p {
            a_dense[j * p + j] = diag[j];
        }
        let b: Vec<C64> = (0..l * p).map(|_| C64::new(g.normal(), g.normal())).collect();
        let dense = scan_dense_sequential(&a_dense, &b, l, p);

        let a32: Vec<C32> = diag.iter().map(|z| z.to_c32()).collect();
        let b32: Vec<C32> = b.iter().map(|z| z.to_c32()).collect();
        let diag_xs = scan_sequential_ti(&a32, &b32, l, p);
        for (x, y) in dense.iter().zip(diag_xs.iter()) {
            assert!((x.to_c32() - *y).abs() < 1e-3);
        }
    }

    #[test]
    fn prop_planar_matches_interleaved() {
        prop::check("planar scan ≡ interleaved", 30, |g| {
            let l = 1 + g.below(300);
            let p = 1 + g.below(16);
            let a = rand_c32(g, p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let ar: Vec<f32> = a.iter().map(|z| z.re).collect();
            let ai: Vec<f32> = a.iter().map(|z| z.im).collect();
            let br: Vec<f32> = b.iter().map(|z| z.re).collect();
            let bi: Vec<f32> = b.iter().map(|z| z.im).collect();
            let want = scan_sequential_ti(&a, &b, l, p);
            let (xr, xi) = scan_sequential_ti_planar(&ar, &ai, &br, &bi, l, p);
            for (i, w) in want.iter().enumerate() {
                let s = 1.0 + w.abs();
                if (xr[i] - w.re).abs() > 1e-4 * s || (xi[i] - w.im).abs() > 1e-4 * s {
                    return Err(format!("idx {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_and_single_element() {
        let a = vec![C32::new(0.5, 0.0)];
        assert!(scan_sequential_ti(&a, &[], 0, 1).is_empty());
        let b = vec![C32::new(2.0, -1.0)];
        let xs = scan_parallel_ti(&a, &b, 1, 1, 8);
        assert_eq!(xs[0], b[0]); // x_1 = b_1
    }
}
