//! Scans for first-order linear recurrences (paper §2.2, Appendix H).
//!
//! The recurrence x_k = ā_k ∘ x_{k−1} + b_k over ℂ^P is computed three ways:
//!
//! * [`scan_sequential`] — the literal O(L·P) loop (ground truth; also the
//!   online-generation mode of §3.3);
//! * [`scan_parallel`] — multi-threaded chunked scan (local scan → chunk-
//!   summary combine → fixup), the CPU analogue of the work-efficient
//!   Blelloch scan the paper leans on. Wall-clock scales with cores while
//!   total work stays O(L·P) — this is the subject of
//!   `bench_scan_scaling`;
//! * [`scan_dense_sequential`] — the O(L·P²)/O(L·P³) *dense*-A strawman of
//!   §2.2, kept as a baseline to demonstrate why diagonalization is load-
//!   bearing for S5.
//!
//! Element layout is planar-free here: `C32` pairs in row-major (L, P)
//! buffers, matching the L1 kernel's numerics (f32).

use crate::num::{C32, C64};

/// Sequential scan, time-varying multipliers.
///
/// `a`, `b`: row-major (L, P). Returns states (L, P).
pub fn scan_sequential(a: &[C32], b: &[C32], l: usize, p: usize) -> Vec<C32> {
    assert_eq!(a.len(), l * p);
    assert_eq!(b.len(), l * p);
    let mut xs = vec![C32::ZERO; l * p];
    let mut state = vec![C32::ZERO; p];
    for k in 0..l {
        let row = k * p;
        for j in 0..p {
            let x = a[row + j] * state[j] + b[row + j];
            state[j] = x;
            xs[row + j] = x;
        }
    }
    xs
}

/// Sequential scan with a *time-invariant* diagonal (the common S5 case):
/// `a` has length P.
pub fn scan_sequential_ti(a: &[C32], b: &[C32], l: usize, p: usize) -> Vec<C32> {
    assert_eq!(a.len(), p);
    assert_eq!(b.len(), l * p);
    let mut xs = vec![C32::ZERO; l * p];
    let mut state = vec![C32::ZERO; p];
    for k in 0..l {
        let row = k * p;
        for j in 0..p {
            let x = a[j] * state[j] + b[row + j];
            state[j] = x;
            xs[row + j] = x;
        }
    }
    xs
}

/// Parallel chunked scan over `threads` workers (time-invariant diagonal).
///
/// Three phases (classic two-pass prefix scan, Blelloch §1.4 adapted to a
/// chunk granularity that fits CPUs):
///  1. each worker scans its chunk locally from x=0 and records the chunk's
///     composition (ā^{len}, local final state);
///  2. the chunk summaries are combined sequentially (T ≪ L elements);
///  3. each worker adds `ā^{k+1-start} ∘ x_enter` to its local states.
pub fn scan_parallel_ti(
    a: &[C32],
    b: &[C32],
    l: usize,
    p: usize,
    threads: usize,
) -> Vec<C32> {
    assert_eq!(a.len(), p);
    assert_eq!(b.len(), l * p);
    let threads = threads.max(1).min(l.max(1));
    if threads == 1 || l < 4 * threads {
        return scan_sequential_ti(a, b, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);

    let mut xs = vec![C32::ZERO; l * p];
    // chunk summaries: a_pow[c] = ā^{len_c}, last[c] = local final state
    let mut a_pow = vec![C32::ZERO; n_chunks * p];
    let mut last = vec![C32::ZERO; n_chunks * p];

    // Phase 1: local scans (parallel).
    {
        let xs_chunks: Vec<&mut [C32]> = xs.chunks_mut(chunk * p).collect();
        let apow_chunks: Vec<&mut [C32]> = a_pow.chunks_mut(p).collect();
        let last_chunks: Vec<&mut [C32]> = last.chunks_mut(p).collect();
        std::thread::scope(|s| {
            for (c, ((xc, ac), lc)) in xs_chunks
                .into_iter()
                .zip(apow_chunks)
                .zip(last_chunks)
                .enumerate()
            {
                s.spawn(move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    let mut state = vec![C32::ZERO; p];
                    let mut pow = vec![C32::ONE; p];
                    for k in 0..len {
                        let g = (start + k) * p;
                        let row = k * p;
                        for j in 0..p {
                            let x = a[j] * state[j] + b[g + j];
                            state[j] = x;
                            xc[row + j] = x;
                            pow[j] = a[j] * pow[j];
                        }
                    }
                    ac.copy_from_slice(&pow);
                    lc.copy_from_slice(&state);
                });
            }
        });
    }

    // Phase 2: combine chunk summaries sequentially → state entering chunk c.
    let mut enter = vec![C32::ZERO; n_chunks * p];
    {
        let mut state = vec![C32::ZERO; p];
        for c in 0..n_chunks {
            enter[c * p..(c + 1) * p].copy_from_slice(&state);
            for j in 0..p {
                state[j] = a_pow[c * p + j] * state[j] + last[c * p + j];
            }
        }
    }

    // Phase 3: fixup (parallel): x_k += ā^{k−start+1} ∘ x_enter.
    {
        let xs_chunks: Vec<&mut [C32]> = xs.chunks_mut(chunk * p).collect();
        std::thread::scope(|s| {
            for (c, xc) in xs_chunks.into_iter().enumerate() {
                let enter_c = &enter[c * p..(c + 1) * p];
                s.spawn(move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    let mut carry: Vec<C32> = enter_c.to_vec();
                    if carry.iter().all(|z| *z == C32::ZERO) {
                        return; // first chunk: nothing to add
                    }
                    for k in 0..len {
                        let row = k * p;
                        for j in 0..p {
                            carry[j] = carry[j] * a[j];
                            xc[row + j] += carry[j];
                        }
                    }
                });
            }
        });
    }

    xs
}

/// Parallel chunked scan with time-varying multipliers (irregular sampling).
pub fn scan_parallel_tv(
    a: &[C32],
    b: &[C32],
    l: usize,
    p: usize,
    threads: usize,
) -> Vec<C32> {
    assert_eq!(a.len(), l * p);
    assert_eq!(b.len(), l * p);
    let threads = threads.max(1).min(l.max(1));
    if threads == 1 || l < 4 * threads {
        return scan_sequential(a, b, l, p);
    }
    let chunk = l.div_ceil(threads);
    let n_chunks = l.div_ceil(chunk);

    let mut xs = vec![C32::ZERO; l * p];
    let mut a_prod = vec![C32::ZERO; n_chunks * p];
    let mut last = vec![C32::ZERO; n_chunks * p];

    {
        let xs_chunks: Vec<&mut [C32]> = xs.chunks_mut(chunk * p).collect();
        let aprod_chunks: Vec<&mut [C32]> = a_prod.chunks_mut(p).collect();
        let last_chunks: Vec<&mut [C32]> = last.chunks_mut(p).collect();
        std::thread::scope(|s| {
            for (c, ((xc, ac), lc)) in xs_chunks
                .into_iter()
                .zip(aprod_chunks)
                .zip(last_chunks)
                .enumerate()
            {
                s.spawn(move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    let mut state = vec![C32::ZERO; p];
                    let mut prod = vec![C32::ONE; p];
                    for k in 0..len {
                        let g = (start + k) * p;
                        let row = k * p;
                        for j in 0..p {
                            let x = a[g + j] * state[j] + b[g + j];
                            state[j] = x;
                            xc[row + j] = x;
                            prod[j] = a[g + j] * prod[j];
                        }
                    }
                    ac.copy_from_slice(&prod);
                    lc.copy_from_slice(&state);
                });
            }
        });
    }

    let mut enter = vec![C32::ZERO; n_chunks * p];
    {
        let mut state = vec![C32::ZERO; p];
        for c in 0..n_chunks {
            enter[c * p..(c + 1) * p].copy_from_slice(&state);
            for j in 0..p {
                state[j] = a_prod[c * p + j] * state[j] + last[c * p + j];
            }
        }
    }

    {
        let xs_chunks: Vec<&mut [C32]> = xs.chunks_mut(chunk * p).collect();
        std::thread::scope(|s| {
            for (c, xc) in xs_chunks.into_iter().enumerate() {
                let enter_c = &enter[c * p..(c + 1) * p];
                s.spawn(move || {
                    let start = c * chunk;
                    let len = chunk.min(l - start);
                    let mut carry: Vec<C32> = enter_c.to_vec();
                    if carry.iter().all(|z| *z == C32::ZERO) {
                        return;
                    }
                    for k in 0..len {
                        let g = (start + k) * p;
                        let row = k * p;
                        for j in 0..p {
                            carry[j] = a[g + j] * carry[j];
                            xc[row + j] += carry[j];
                        }
                    }
                });
            }
        });
    }

    xs
}

/// Planar (struct-of-arrays) sequential scan: separate re/im f32 streams,
/// matching the L1 kernel's memory layout.
///
/// §Perf experiment (EXPERIMENTS.md): the interleaved `C32` loop carries a
/// real↔imag data dependence per element that blocks autovectorization;
/// planar streams let LLVM emit SIMD mul/fma over the P lanes. Same math,
/// same O(L·P) work.
pub fn scan_sequential_ti_planar(
    ar: &[f32],
    ai: &[f32],
    br: &[f32],
    bi: &[f32],
    l: usize,
    p: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(ar.len(), p);
    assert_eq!(br.len(), l * p);
    let mut xr = vec![0.0f32; l * p];
    let mut xi = vec![0.0f32; l * p];
    let mut sr = vec![0.0f32; p];
    let mut si = vec![0.0f32; p];
    for k in 0..l {
        let row = k * p;
        let (brk, bik) = (&br[row..row + p], &bi[row..row + p]);
        let (xrk, xik) = (&mut xr[row..row + p], &mut xi[row..row + p]);
        for j in 0..p {
            let nr = ar[j] * sr[j] - ai[j] * si[j] + brk[j];
            let ni = ar[j] * si[j] + ai[j] * sr[j] + bik[j];
            sr[j] = nr;
            si[j] = ni;
            xrk[j] = nr;
            xik[j] = ni;
        }
    }
    (xr, xi)
}

/// Dense-state-matrix sequential recurrence x_k = Ā x_{k−1} + b_k — the
/// O(L·P²) strawman of §2.2 (its *parallel* form would need O(P³) matrix
/// products per combine, which is the cost the diagonalization removes).
///
/// `a_dense`: row-major (P, P) in C64 for accuracy; `b`: (L, P).
pub fn scan_dense_sequential(a_dense: &[C64], b: &[C64], l: usize, p: usize) -> Vec<C64> {
    assert_eq!(a_dense.len(), p * p);
    assert_eq!(b.len(), l * p);
    let mut xs = vec![C64::ZERO; l * p];
    let mut state = vec![C64::ZERO; p];
    let mut next = vec![C64::ZERO; p];
    for k in 0..l {
        for i in 0..p {
            let mut acc = b[k * p + i];
            for j in 0..p {
                acc += a_dense[i * p + j] * state[j];
            }
            next[i] = acc;
        }
        std::mem::swap(&mut state, &mut next);
        xs[k * p..(k + 1) * p].copy_from_slice(&state);
    }
    xs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::prop;

    fn rand_c32(g: &mut Rng, n: usize, scale: f32) -> Vec<C32> {
        (0..n)
            .map(|_| C32::new(g.normal() as f32 * scale, g.normal() as f32 * scale))
            .collect()
    }

    fn close(a: &[C32], b: &[C32], tol: f32) -> prop::PropResult {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            let d = (*x - *y).abs();
            let s = 1.0 + x.abs().max(y.abs());
            if d > tol * s {
                return Err(format!("idx {i}: {x:?} !~ {y:?}"));
            }
        }
        Ok(())
    }

    #[test]
    fn sequential_ti_matches_tv() {
        let mut g = Rng::new(0);
        let (l, p) = (50, 4);
        let a = rand_c32(&mut g, p, 0.5);
        let b = rand_c32(&mut g, l * p, 1.0);
        let mut a_full = Vec::with_capacity(l * p);
        for _ in 0..l {
            a_full.extend_from_slice(&a);
        }
        let x1 = scan_sequential_ti(&a, &b, l, p);
        let x2 = scan_sequential(&a_full, &b, l, p);
        close(&x1, &x2, 1e-6).unwrap();
    }

    #[test]
    fn prop_parallel_ti_matches_sequential() {
        prop::check("parallel TI scan ≡ sequential", 40, |g| {
            let l = 1 + g.below(500);
            let p = 1 + g.below(12);
            let threads = 1 + g.below(8);
            let a = rand_c32(g, p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let seq = scan_sequential_ti(&a, &b, l, p);
            let par = scan_parallel_ti(&a, &b, l, p, threads);
            close(&seq, &par, 1e-4)
        });
    }

    #[test]
    fn prop_parallel_tv_matches_sequential() {
        prop::check("parallel TV scan ≡ sequential", 40, |g| {
            let l = 1 + g.below(400);
            let p = 1 + g.below(10);
            let threads = 1 + g.below(8);
            let a = rand_c32(g, l * p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let seq = scan_sequential(&a, &b, l, p);
            let par = scan_parallel_tv(&a, &b, l, p, threads);
            close(&seq, &par, 1e-4)
        });
    }

    #[test]
    fn parallel_exact_on_cumsum() {
        // a = 1: scan is a cumulative sum, easy closed form.
        let (l, p) = (1000, 2);
        let a = vec![C32::ONE; p];
        let b = vec![C32::new(1.0, 0.0); l * p];
        let xs = scan_parallel_ti(&a, &b, l, p, 4);
        for k in 0..l {
            assert!((xs[k * p].re - (k as f32 + 1.0)).abs() < 1e-3);
        }
    }

    #[test]
    fn dense_scan_matches_diagonal_when_a_is_diagonal() {
        let mut g = Rng::new(3);
        let (l, p) = (40, 5);
        let diag: Vec<C64> = (0..p).map(|_| C64::new(g.normal() * 0.4, g.normal() * 0.4)).collect();
        let mut a_dense = vec![C64::ZERO; p * p];
        for j in 0..p {
            a_dense[j * p + j] = diag[j];
        }
        let b: Vec<C64> = (0..l * p).map(|_| C64::new(g.normal(), g.normal())).collect();
        let dense = scan_dense_sequential(&a_dense, &b, l, p);

        let a32: Vec<C32> = diag.iter().map(|z| z.to_c32()).collect();
        let b32: Vec<C32> = b.iter().map(|z| z.to_c32()).collect();
        let diag_xs = scan_sequential_ti(&a32, &b32, l, p);
        for (x, y) in dense.iter().zip(diag_xs.iter()) {
            assert!((x.to_c32() - *y).abs() < 1e-3);
        }
    }

    #[test]
    fn prop_planar_matches_interleaved() {
        prop::check("planar scan ≡ interleaved", 30, |g| {
            let l = 1 + g.below(300);
            let p = 1 + g.below(16);
            let a = rand_c32(g, p, 0.6);
            let b = rand_c32(g, l * p, 1.0);
            let ar: Vec<f32> = a.iter().map(|z| z.re).collect();
            let ai: Vec<f32> = a.iter().map(|z| z.im).collect();
            let br: Vec<f32> = b.iter().map(|z| z.re).collect();
            let bi: Vec<f32> = b.iter().map(|z| z.im).collect();
            let want = scan_sequential_ti(&a, &b, l, p);
            let (xr, xi) = scan_sequential_ti_planar(&ar, &ai, &br, &bi, l, p);
            for (i, w) in want.iter().enumerate() {
                let s = 1.0 + w.abs();
                if (xr[i] - w.re).abs() > 1e-4 * s || (xi[i] - w.im).abs() > 1e-4 * s {
                    return Err(format!("idx {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_and_single_element() {
        let a = vec![C32::new(0.5, 0.0)];
        assert!(scan_sequential_ti(&a, &[], 0, 1).is_empty());
        let b = vec![C32::new(2.0, -1.0)];
        let xs = scan_parallel_ti(&a, &b, 1, 1, 8);
        assert_eq!(xs[0], b[0]); // x_1 = b_1
    }
}
