//! Online (autoregressive) generation mode for S5 (paper §3.3, and the
//! "online generation" case of Proposition 1 / Appendix C.1).
//!
//! When observations arrive one at a time, the S5 SSM runs as a stateful
//! recurrence at O(P·H + P) per step — the same asymptotics as S4's
//! recurrent mode at P = O(H). This module provides that stepping API on
//! top of [`crate::ssm::s5::S5Layer`], plus an [`OnlineModel`] that keeps
//! per-layer states for a whole stacked network (what a streaming
//! deployment of the inference server would hold per session).
//!
//! Correctness is pinned by equivalence tests against the offline scan —
//! and structurally: the per-step recurrence goes through the same
//! [`ScanBackend::scan_step`] kernel
//! ([`crate::ssm::scan::scan_step_inplace`]) that the offline sequential
//! scans are built on, so streaming generation and batched offline scans
//! share one code path by construction.

use crate::num::{C32, C64};
use crate::ssm::discretize::{discretize_diag, discretize_one, Method};
use crate::ssm::s5::{gelu, layer_norm_row, sigmoid, S5Layer, S5Model};
use crate::ssm::scan::{ScanBackend, SequentialBackend};

/// Streaming state of one S5 layer: the complex latent x_k plus the
/// precomputed discretization (recomputed only if Δt changes) and the
/// step's drive scratch (owned here so steady-state streaming allocates
/// only the per-step output rows).
pub struct LayerState {
    x: Vec<C32>,
    lam_bar: Vec<C32>,
    in_scale: Vec<C32>,
    /// per-step drive b = f ∘ B̃u (P2 scratch)
    drive: Vec<C32>,
    /// Δt this discretization was built for (None = time-invariant default)
    dt_scale: Option<f32>,
}

impl LayerState {
    /// Fresh state with the layer's default (time-invariant) discretization.
    pub fn new(layer: &S5Layer, timescale: f64) -> LayerState {
        let dt: Vec<f64> = layer
            .log_dt
            .iter()
            .map(|&ld| (ld as f64).exp() * timescale)
            .collect();
        let (lam_bar, scale) = discretize_diag(&layer.lambda, &dt, Method::Zoh);
        LayerState {
            x: vec![C32::ZERO; layer.p2],
            lam_bar: lam_bar.iter().map(|z| z.to_c32()).collect(),
            in_scale: scale.iter().map(|z| z.to_c32()).collect(),
            drive: vec![C32::ZERO; layer.p2],
            dt_scale: None,
        }
    }

    /// Re-discretize for an irregular step of length `dt_k` (×base Δ).
    fn rediscretize(&mut self, layer: &S5Layer, timescale: f64, dt_k: f32) {
        if self.dt_scale == Some(dt_k) {
            return;
        }
        for (r, &lam) in layer.lambda.iter().enumerate() {
            let dt = (layer.log_dt[r] as f64).exp() * timescale * dt_k as f64;
            let (lb, sc) = discretize_one(lam, dt, Method::Zoh);
            self.lam_bar[r] = lb.to_c32();
            self.in_scale[r] = sc.to_c32();
        }
        self.dt_scale = Some(dt_k);
    }

    /// Reset the latent to zero (new sequence).
    pub fn reset(&mut self) {
        self.x.iter_mut().for_each(|z| *z = C32::ZERO);
    }
}

impl S5Layer {
    /// One online SSM step: consumes u_k (H), returns y_k (H).
    /// O(P·H) work — the Proposition-1 online bound.
    ///
    /// Only unidirectional layers support streaming (a bidirectional layer
    /// needs the future by construction).
    pub fn step_ssm(
        &self,
        state: &mut LayerState,
        u: &[f32],
        timescale: f64,
        dt_k: Option<f32>,
    ) -> Vec<f32> {
        assert_eq!(u.len(), self.h);
        assert_eq!(self.c_tilde.len(), 1, "bidirectional layers cannot stream");
        if let Some(dt) = dt_k {
            state.rediscretize(self, timescale, dt);
        }
        // x ← Λ̄∘x + f∘(B̃u), through the shared step kernel: build the
        // drive b = f∘(B̃u) then advance with ScanBackend::scan_step
        for r in 0..self.p2 {
            let mut bu = C64::ZERO;
            for c in 0..self.h {
                bu += self.b_tilde[r * self.h + c].scale(u[c] as f64);
            }
            state.drive[r] = state.in_scale[r] * bu.to_c32();
        }
        SequentialBackend.scan_step(&state.lam_bar, &mut state.x, &state.drive);
        // y = 2·Re(C̃x) + D∘u
        let ct = &self.c_tilde[0];
        let mut y = vec![0.0f32; self.h];
        for r in 0..self.h {
            let mut acc = 0.0f32;
            for c in 0..self.p2 {
                let cv = ct[r * self.p2 + c];
                acc += cv.re as f32 * state.x[c].re - cv.im as f32 * state.x[c].im;
            }
            y[r] = 2.0 * acc + self.d[r] * u[r];
        }
        y
    }

    /// One online *layer* step: pre-norm → SSM step → activation → residual.
    pub fn step(
        &self,
        state: &mut LayerState,
        u: &[f32],
        timescale: f64,
        dt_k: Option<f32>,
    ) -> Vec<f32> {
        let mut v = vec![0.0f32; self.h];
        layer_norm_row(u, &self.norm_scale, &self.norm_bias, &mut v);
        let y = self.step_ssm(state, &v, timescale, dt_k);
        let mut out = vec![0.0f32; self.h];
        let g: Vec<f32> = y.iter().map(|&x| gelu(x)).collect();
        for r in 0..self.h {
            let mut lin = 0.0f32;
            for c in 0..self.h {
                lin += self.gate_w[r * self.h + c] * g[c];
            }
            out[r] = u[r] + g[r] * sigmoid(lin);
        }
        out
    }
}

/// Streaming state for a whole deep model (one LayerState per layer plus a
/// running mean-pool accumulator for classification-on-close).
pub struct OnlineModel<'a> {
    model: &'a S5Model,
    states: Vec<LayerState>,
    pool: Vec<f32>,
    steps: usize,
}

impl<'a> OnlineModel<'a> {
    pub fn new(model: &'a S5Model, timescale: f64) -> OnlineModel<'a> {
        OnlineModel {
            model,
            states: model.layers.iter().map(|l| LayerState::new(l, timescale)).collect(),
            pool: vec![0.0; model.h],
            steps: 0,
        }
    }

    /// Feed one observation (d_in); updates all layer states.
    pub fn push(&mut self, u: &[f32], timescale: f64) {
        let m = self.model;
        let mut x = vec![0.0f32; m.h];
        for r in 0..m.h {
            let mut acc = m.enc_b[r];
            for c in 0..m.d_in {
                acc += m.enc_w[r * m.d_in + c] * u[c];
            }
            x[r] = acc;
        }
        for (layer, state) in m.layers.iter().zip(self.states.iter_mut()) {
            x = layer.step(state, &x, timescale, None);
        }
        for r in 0..m.h {
            self.pool[r] += x[r];
        }
        self.steps += 1;
    }

    /// Current logits from the running mean-pool.
    pub fn logits(&self) -> Vec<f32> {
        let m = self.model;
        let denom = self.steps.max(1) as f32;
        let mut out = vec![0.0f32; m.classes];
        for r in 0..m.classes {
            let mut acc = m.dec_b[r];
            for c in 0..m.h {
                acc += m.dec_w[r * m.h + c] * (self.pool[c] / denom);
            }
            out[r] = acc;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::ssm::s5::S5Config;
    use crate::testing::prop;

    fn layer(h: usize, p: usize) -> S5Layer {
        S5Layer::init(&S5Config { h, p, j: 1, ..Default::default() }, &mut Rng::new(1))
    }

    #[test]
    fn online_ssm_equals_offline_scan() {
        let lp = layer(6, 8);
        let l = 40;
        let mut rng = Rng::new(2);
        let u = rng.normal_vec_f32(l * 6);
        let offline = lp.apply_ssm(&u, l, 1.0, None, 1);
        let mut st = LayerState::new(&lp, 1.0);
        for k in 0..l {
            let y = lp.step_ssm(&mut st, &u[k * 6..(k + 1) * 6], 1.0, None);
            for c in 0..6 {
                let (a, b) = (offline[k * 6 + c], y[c]);
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                    "k={k} c={c}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn online_layer_equals_offline_layer() {
        let lp = layer(4, 8);
        let l = 30;
        let mut rng = Rng::new(3);
        let u = rng.normal_vec_f32(l * 4);
        let offline = lp.apply(&u, l, 1.0, None, 1);
        let mut st = LayerState::new(&lp, 1.0);
        for k in 0..l {
            let y = lp.step(&mut st, &u[k * 4..(k + 1) * 4], 1.0, None);
            prop::close_slice_f32(&offline[k * 4..(k + 1) * 4], &y, 2e-3)
                .unwrap_or_else(|e| panic!("k={k}: {e}"));
        }
    }

    #[test]
    fn online_variable_dt_equals_offline_variable_dt() {
        let lp = layer(4, 8);
        let l = 25;
        let mut rng = Rng::new(4);
        let u = rng.normal_vec_f32(l * 4);
        let dts: Vec<f32> = rng.uniform_vec_f32(l, 0.3, 2.5);
        let offline = lp.apply_ssm(&u, l, 1.0, Some(&dts), 1);
        let mut st = LayerState::new(&lp, 1.0);
        for k in 0..l {
            let y = lp.step_ssm(&mut st, &u[k * 4..(k + 1) * 4], 1.0, Some(dts[k]));
            prop::close_slice_f32(&offline[k * 4..(k + 1) * 4], &y, 2e-3)
                .unwrap_or_else(|e| panic!("k={k}: {e}"));
        }
    }

    #[test]
    fn state_reset_restarts_sequence() {
        let lp = layer(4, 8);
        let mut rng = Rng::new(5);
        let u = rng.normal_vec_f32(4);
        let mut st = LayerState::new(&lp, 1.0);
        let y1 = lp.step_ssm(&mut st, &u, 1.0, None);
        let _ = lp.step_ssm(&mut st, &u, 1.0, None);
        st.reset();
        let y3 = lp.step_ssm(&mut st, &u, 1.0, None);
        prop::close_slice_f32(&y1, &y3, 1e-6).unwrap();
    }

    #[test]
    fn online_model_matches_offline_forward() {
        let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
        let model = crate::ssm::s5::S5Model::init(2, 5, 2, &cfg, &mut Rng::new(6));
        let l = 20;
        let mut rng = Rng::new(7);
        let u = rng.normal_vec_f32(l * 2);
        let offline = model.forward(&u, l, 1.0, 1);
        let mut online = OnlineModel::new(&model, 1.0);
        for k in 0..l {
            online.push(&u[k * 2..(k + 1) * 2], 1.0);
        }
        prop::close_slice_f32(&offline, &online.logits(), 2e-3).unwrap();
    }

    #[test]
    #[should_panic(expected = "bidirectional")]
    fn bidirectional_layer_cannot_stream() {
        let lp = S5Layer::init(
            &S5Config { h: 4, p: 8, j: 1, bidir: true, ..Default::default() },
            &mut Rng::new(8),
        );
        let mut st = LayerState::new(&lp, 1.0);
        lp.step_ssm(&mut st, &[0.0; 4], 1.0, None);
    }
}
