//! Online (autoregressive) generation mode for S5 (paper §3.3, and the
//! "online generation" case of Proposition 1 / Appendix C.1).
//!
//! When observations arrive one at a time, the S5 SSM runs as a stateful
//! recurrence at O(P·H + P) per step — the same asymptotics as S4's
//! recurrent mode at P = O(H). This module provides that stepping API on
//! top of [`crate::ssm::s5::S5Layer`], plus an [`OnlineModel`] that keeps
//! per-layer states for a whole stacked network (what a streaming
//! deployment of the inference server would hold per session).
//!
//! Correctness is pinned by equivalence tests against the offline scan —
//! and structurally: the per-step recurrence goes through the same planar
//! [`ScanBackend::scan_step_planar`] kernel
//! ([`crate::ssm::scan::scan_step_planar_inplace`]) that the offline
//! planar sequential scans are built on (the layer state lives as
//! struct-of-arrays re/im planes, matching the engine's default
//! [`ScanLayout::Planar`](crate::ssm::scan::ScanLayout) hot path), and the
//! projection accumulates in f64 exactly like the offline `project_seq`,
//! so streaming generation reproduces the sequential offline scan
//! **bit-for-bit** — in either layout, since the planar and interleaved
//! kernels execute identical FP ops in identical order.
//!
//! The public streaming surface is [`crate::ssm::api::Session`] over the
//! [`crate::ssm::api::SequenceModel`] trait; this module provides the
//! S5-specific state it drives ([`LayerState`], [`S5StreamState`]). The
//! old S5-only [`OnlineModel`] remains as a deprecated wrapper.
//!
//! Threading: a streaming step is O(P·H) — latency-bound, not
//! throughput-bound — so it always runs inline on the caller's thread
//! and never touches the worker pool; only the batched prefill path
//! dispatches shards (see [`crate::runtime::pool`]). Many concurrent
//! sessions therefore stream independently while sharing the
//! process-wide pool with the batch worker for their prefills.

use crate::num::C64;
use crate::ssm::api::ForwardOptions;
use crate::ssm::discretize::{discretize_diag, discretize_one, Method};
use crate::ssm::dtype::{bf16_round_trip, Dtype};
use crate::ssm::engine::{grow, EngineWorkspace, SsmBuffers};
use crate::ssm::s5::{gelu, layer_norm_row, sigmoid, FusedUnit, S5Layer, S5Model};
use crate::ssm::scan::{ScanBackend, SequentialBackend};

/// Streaming state of one S5 layer: the complex latent x_k plus the
/// precomputed discretization (recomputed only if Δt changes) and the
/// step's drive scratch (owned here so steady-state streaming allocates
/// only the per-step output rows).
///
/// Everything complex is stored as **planar re/im `f32` planes** — the
/// same struct-of-arrays layout the engine's default scan path uses — so
/// the per-step recurrence runs through
/// [`ScanBackend::scan_step_planar`] with no layout conversion.
pub struct LayerState {
    /// latent x (planar planes, length P2 each)
    xr: Vec<f32>,
    xi: Vec<f32>,
    /// live discretization Λ̄ and input scaling (planar planes)
    lam_re: Vec<f32>,
    lam_im: Vec<f32>,
    scale_re: Vec<f32>,
    scale_im: Vec<f32>,
    /// default (regular-step) discretization cache, restored when a
    /// regular step follows irregular ones and on stream reset
    lam_re0: Vec<f32>,
    lam_im0: Vec<f32>,
    scale_re0: Vec<f32>,
    scale_im0: Vec<f32>,
    /// per-step drive b = f ∘ B̃u (planar P2 scratch)
    drive_re: Vec<f32>,
    drive_im: Vec<f32>,
    /// Δt multiplier the live discretization was built for (None = regular)
    dt_scale: Option<f32>,
    /// timescale the live discretization was built for
    cur_timescale: f64,
    /// timescale the cached default discretization was built for
    base_timescale: f64,
    /// storage dtype this stream mirrors ([`ScanPolicy::dtype`]): the
    /// latent itself stays f32 compute precision, but under bf16 each
    /// step round-trips the drive and the projection read through bf16 —
    /// exactly the narrow-store/widen-load a fused bf16 tile row performs
    /// — so chunked prefill ≡ step replay stays bit-for-bit per dtype.
    ///
    /// [`ScanPolicy::dtype`]: crate::ssm::engine::ScanPolicy
    dtype: Dtype,
}

impl LayerState {
    /// Fresh state with the layer's default (time-invariant)
    /// discretization and f32 storage semantics.
    pub fn new(layer: &S5Layer, timescale: f64) -> LayerState {
        LayerState::with_dtype(layer, timescale, Dtype::F32)
    }

    /// [`LayerState::new`] with an explicit storage dtype for the
    /// stream's step/prefill arithmetic.
    pub fn with_dtype(layer: &S5Layer, timescale: f64, dtype: Dtype) -> LayerState {
        let dt: Vec<f64> = layer
            .log_dt
            .iter()
            .map(|&ld| (ld as f64).exp() * timescale)
            .collect();
        let (lam_bar, scale) = discretize_diag(&layer.lambda, &dt, Method::Zoh);
        let lam_re: Vec<f32> = lam_bar.iter().map(|z| z.to_c32().re).collect();
        let lam_im: Vec<f32> = lam_bar.iter().map(|z| z.to_c32().im).collect();
        let scale_re: Vec<f32> = scale.iter().map(|z| z.to_c32().re).collect();
        let scale_im: Vec<f32> = scale.iter().map(|z| z.to_c32().im).collect();
        LayerState {
            xr: vec![0.0; layer.p2],
            xi: vec![0.0; layer.p2],
            lam_re0: lam_re.clone(),
            lam_im0: lam_im.clone(),
            scale_re0: scale_re.clone(),
            scale_im0: scale_im.clone(),
            lam_re,
            lam_im,
            scale_re,
            scale_im,
            drive_re: vec![0.0; layer.p2],
            drive_im: vec![0.0; layer.p2],
            dt_scale: None,
            cur_timescale: timescale,
            base_timescale: timescale,
            dtype,
        }
    }

    /// Re-discretize for an irregular step of length `dt_k` (×base Δ).
    /// Keyed on **both** dt_k and the step's timescale, so a caller that
    /// changes timescale mid-stream never reuses a stale Λ̄.
    fn rediscretize(&mut self, layer: &S5Layer, timescale: f64, dt_k: f32) {
        if self.dt_scale == Some(dt_k) && self.cur_timescale == timescale {
            return;
        }
        for (r, &lam) in layer.lambda.iter().enumerate() {
            let dt = (layer.log_dt[r] as f64).exp() * timescale * dt_k as f64;
            let (lb, sc) = discretize_one(lam, dt, Method::Zoh);
            let (lb, sc) = (lb.to_c32(), sc.to_c32());
            self.lam_re[r] = lb.re;
            self.lam_im[r] = lb.im;
            self.scale_re[r] = sc.re;
            self.scale_im[r] = sc.im;
        }
        self.dt_scale = Some(dt_k);
        self.cur_timescale = timescale;
    }

    /// Make the live discretization the regular-step default for
    /// `timescale` (a regular step after irregular ones, or a timescale
    /// change). Rebuilds the cached default when the timescale moved.
    fn restore_default_dt(&mut self, layer: &S5Layer, timescale: f64) {
        if self.dt_scale.is_none() && self.cur_timescale == timescale {
            return;
        }
        if self.base_timescale != timescale {
            let dt: Vec<f64> = layer
                .log_dt
                .iter()
                .map(|&ld| (ld as f64).exp() * timescale)
                .collect();
            let (lam_bar, scale) = discretize_diag(&layer.lambda, &dt, Method::Zoh);
            for (r, z) in lam_bar.iter().enumerate() {
                let z = z.to_c32();
                self.lam_re0[r] = z.re;
                self.lam_im0[r] = z.im;
            }
            for (r, z) in scale.iter().enumerate() {
                let z = z.to_c32();
                self.scale_re0[r] = z.re;
                self.scale_im0[r] = z.im;
            }
            self.base_timescale = timescale;
        }
        self.lam_re.copy_from_slice(&self.lam_re0);
        self.lam_im.copy_from_slice(&self.lam_im0);
        self.scale_re.copy_from_slice(&self.scale_re0);
        self.scale_im.copy_from_slice(&self.scale_im0);
        self.dt_scale = None;
        self.cur_timescale = timescale;
    }

    /// Reset to the start of a new sequence: zero the latent and restore
    /// the cached default discretization.
    pub fn reset(&mut self) {
        self.xr.iter_mut().for_each(|v| *v = 0.0);
        self.xi.iter_mut().for_each(|v| *v = 0.0);
        self.lam_re.copy_from_slice(&self.lam_re0);
        self.lam_im.copy_from_slice(&self.lam_im0);
        self.scale_re.copy_from_slice(&self.scale_re0);
        self.scale_im.copy_from_slice(&self.scale_im0);
        self.dt_scale = None;
        self.cur_timescale = self.base_timescale;
    }
}

impl S5Layer {
    /// One online SSM step: consumes u_k (H), returns y_k (H).
    /// O(P·H) work — the Proposition-1 online bound.
    ///
    /// Only unidirectional layers support streaming (a bidirectional layer
    /// needs the future by construction).
    pub fn step_ssm(
        &self,
        state: &mut LayerState,
        u: &[f32],
        timescale: f64,
        dt_k: Option<f32>,
    ) -> Vec<f32> {
        let mut y = vec![0.0f32; self.h];
        self.step_ssm_into(state, u, timescale, dt_k, &mut y);
        y
    }

    /// [`step_ssm`](S5Layer::step_ssm) into a caller-provided output row —
    /// the allocation-free form the steady-state streaming path uses (the
    /// counting-allocator harness in `tests/alloc_guard.rs` pins it).
    pub fn step_ssm_into(
        &self,
        state: &mut LayerState,
        u: &[f32],
        timescale: f64,
        dt_k: Option<f32>,
        y: &mut [f32],
    ) {
        assert_eq!(u.len(), self.h);
        assert_eq!(y.len(), self.h);
        assert_eq!(self.c_tilde.len(), 1, "bidirectional layers cannot stream");
        // dt_k = None means a *regular* step (Δt multiplier 1), matching the
        // offline convention where omitted dts ≡ all-ones — so a regular
        // step after an irregular one restores the default discretization
        // rather than silently reusing the last irregular Λ̄ (and both
        // paths honor a timescale change between steps).
        match dt_k {
            Some(dt) => state.rediscretize(self, timescale, dt),
            None => state.restore_default_dt(self, timescale),
        }
        // x ← Λ̄∘x + f∘(B̃u), through the shared planar step kernel: build
        // the drive b = f∘(B̃u) as planes then advance with
        // ScanBackend::scan_step_planar (same op order as the interleaved
        // `in_scale * bu`, so nothing drifts vs. the old layout)
        if state.dtype == Dtype::Bf16 {
            // bf16 storage twin: round-trip the drive through bf16 before
            // and after the scale multiply — the narrow-store → widen-load
            // a fused bf16 tile applies at the same two points (drive
            // store, Δt-scale store) — so a step replay stays bit-for-bit
            // with the chunked bf16 prefill
            for r in 0..self.p2 {
                let mut bu = C64::ZERO;
                for c in 0..self.h {
                    bu += self.b_tilde[r * self.h + c].scale(u[c] as f64);
                }
                let b = bu.to_c32();
                let (br, bi) = (bf16_round_trip(b.re), bf16_round_trip(b.im));
                let dre = state.scale_re[r] * br - state.scale_im[r] * bi;
                let dim = state.scale_re[r] * bi + state.scale_im[r] * br;
                state.drive_re[r] = bf16_round_trip(dre);
                state.drive_im[r] = bf16_round_trip(dim);
            }
        } else {
            for r in 0..self.p2 {
                let mut bu = C64::ZERO;
                for c in 0..self.h {
                    bu += self.b_tilde[r * self.h + c].scale(u[c] as f64);
                }
                let b = bu.to_c32();
                state.drive_re[r] = state.scale_re[r] * b.re - state.scale_im[r] * b.im;
                state.drive_im[r] = state.scale_re[r] * b.im + state.scale_im[r] * b.re;
            }
        }
        SequentialBackend.scan_step_planar(
            &state.lam_re,
            &state.lam_im,
            &mut state.xr,
            &mut state.xi,
            &state.drive_re,
            &state.drive_im,
        );
        // y = 2·Re(C̃x) + D∘u — f64 accumulation with the exact op order of
        // the offline `project_seq` + `feedthrough_seq`, so one online step
        // equals one row of the offline sequential scan bit-for-bit. The
        // latent carry stays f32 at every dtype (the fused kernels carry
        // f32 across rows the same way); under bf16 the projection reads
        // the state through a bf16 round trip — the widen-load of the
        // narrowed tile row a fused projection consumes.
        let ct = &self.c_tilde[0];
        if state.dtype == Dtype::Bf16 {
            for r in 0..self.h {
                let mut acc = 0.0f64;
                for c in 0..self.p2 {
                    let cv = ct[r * self.p2 + c];
                    acc += cv.re * bf16_round_trip(state.xr[c]) as f64
                        - cv.im * bf16_round_trip(state.xi[c]) as f64;
                }
                y[r] = 2.0 * acc as f32 + self.d[r] * u[r];
            }
        } else {
            for r in 0..self.h {
                let mut acc = 0.0f64;
                for c in 0..self.p2 {
                    let cv = ct[r * self.p2 + c];
                    acc += cv.re * state.xr[c] as f64 - cv.im * state.xi[c] as f64;
                }
                y[r] = 2.0 * acc as f32 + self.d[r] * u[r];
            }
        }
    }

    /// One online *layer* step: pre-norm → SSM step → activation → residual.
    pub fn step(
        &self,
        state: &mut LayerState,
        u: &[f32],
        timescale: f64,
        dt_k: Option<f32>,
    ) -> Vec<f32> {
        let mut x = u.to_vec();
        let mut v = vec![0.0f32; self.h];
        let mut y = vec![0.0f32; self.h];
        self.step_into(state, &mut x, timescale, dt_k, &mut v, &mut y);
        x
    }

    /// [`step`](S5Layer::step) in place: `x` holds the layer input on entry
    /// and the layer output (residual applied) on exit; `v` and `y` are
    /// H-length scratch rows lent by the caller. Identical FP op order to
    /// the allocating wrapper — the gelu runs in place on `y` and the gate
    /// reads the already-activated row, exactly like the old `g` vector.
    pub fn step_into(
        &self,
        state: &mut LayerState,
        x: &mut [f32],
        timescale: f64,
        dt_k: Option<f32>,
        v: &mut [f32],
        y: &mut [f32],
    ) {
        layer_norm_row(x, &self.norm_scale, &self.norm_bias, v);
        self.step_ssm_into(state, v, timescale, dt_k, y);
        for g in y.iter_mut() {
            *g = gelu(*g);
        }
        for r in 0..self.h {
            let mut lin = 0.0f32;
            for c in 0..self.h {
                lin += self.gate_w[r * self.h + c] * y[c];
            }
            x[r] += y[r] * sigmoid(lin);
        }
    }
}

/// Streaming state for a whole deep S5 model: one [`LayerState`] per layer
/// plus a running mean-pool accumulator for classification-on-close. This
/// is what [`crate::ssm::api::Session`] holds (opaquely) for an
/// [`S5Model`]; it does not borrow the model, so sessions can share one
/// `Arc`'d model across connections.
pub struct S5StreamState {
    states: Vec<LayerState>,
    pool: Vec<f32>,
    steps: usize,
    /// Storage dtype shared by every layer's stream (see
    /// [`LayerState::with_dtype`]); selects which drive-plane family the
    /// chunked prefill borrows from the workspace.
    dtype: Dtype,
    /// Scratch shared by the chunked-prefill fast path ([`push_chunk`])
    /// and the per-token path ([`push`], which only uses the H-length
    /// activation rows): reused across calls so steady-state streaming
    /// and prefills allocate nothing. Dropped on [`reset`] so pooled idle
    /// sessions don't retain the high-water planes of their largest past
    /// prefill.
    ///
    /// [`push_chunk`]: S5StreamState::push_chunk
    /// [`reset`]: S5StreamState::reset
    ws: EngineWorkspace,
}

impl S5StreamState {
    pub fn new(model: &S5Model, timescale: f64) -> S5StreamState {
        S5StreamState::with_dtype(model, timescale, Dtype::F32)
    }

    /// [`S5StreamState::new`] with an explicit storage dtype, mirrored
    /// into every per-layer stream ([`LayerState::with_dtype`]).
    pub fn with_dtype(model: &S5Model, timescale: f64, dtype: Dtype) -> S5StreamState {
        S5StreamState {
            states: model
                .layers
                .iter()
                .map(|l| LayerState::with_dtype(l, timescale, dtype))
                .collect(),
            pool: vec![0.0; model.h],
            steps: 0,
            dtype,
            ws: EngineWorkspace::new(),
        }
    }

    /// Restart the stream without reallocating the per-layer states.
    ///
    /// The chunked-prefill scratch is dropped here: reset marks a
    /// connection boundary (session pooling), and an idle pooled session
    /// must not retain the O(L·H) activation planes of its largest past
    /// prefill. Within one stream's life repeated prefills still reuse
    /// the scratch allocation-free.
    pub fn reset(&mut self) {
        for st in &mut self.states {
            st.reset();
        }
        self.pool.iter_mut().for_each(|v| *v = 0.0);
        self.steps = 0;
        self.ws = EngineWorkspace::new();
    }

    /// Feed one observation (d_in); updates all layer states. `dt` is the
    /// per-step Δt multiplier for irregular sampling (§6.3).
    ///
    /// Runs through the workspace's activation rows via
    /// [`S5Layer::step_into`], so steady-state streaming performs no
    /// allocation (pinned by `tests/alloc_guard.rs`).
    pub fn push(&mut self, m: &S5Model, u: &[f32], timescale: f64, dt: Option<f32>) {
        assert_eq!(u.len(), m.d_in);
        let h = m.h;
        let S5StreamState { states, pool, ws, steps } = self;
        let EngineWorkspace { x, v, y, .. } = ws;
        grow(x, h);
        grow(v, h);
        grow(y, h);
        let (x, v, y) = (&mut x[..h], &mut v[..h], &mut y[..h]);
        for r in 0..h {
            let mut acc = m.enc_b[r];
            for c in 0..m.d_in {
                acc += m.enc_w[r * m.d_in + c] * u[c];
            }
            x[r] = acc;
        }
        for (layer, state) in m.layers.iter().zip(states.iter_mut()) {
            layer.step_into(state, x, timescale, dt, v, y);
        }
        for r in 0..h {
            pool[r] += x[r];
        }
        *steps += 1;
    }

    /// Chunked prefill: swallow `l` regular (Δt = 1) observations through
    /// the fused tile pipeline instead of `l` per-token [`push`] calls —
    /// per layer one drive → scale → tile-resumable scan → projection →
    /// gate pipeline over the whole chunk, resuming from (and writing
    /// back, in place) this stream's per-layer latent. The tile length
    /// follows the [`ForwardOptions`] tiling policy (staged runs as one
    /// tile — the carry is live either way).
    ///
    /// Equivalence: the pipeline runs the same planar kernels in the same
    /// per-element order as the per-token path — the scan resumes through
    /// `scan_ti_planar_resume`, whose row op is exactly
    /// [`ScanBackend::scan_step_planar`]; drive/scale/projection/gate
    /// match `step_ssm`/`step` op-for-op — so a chunked prefill equals
    /// the step-by-step replay **bit-for-bit** (pinned in
    /// `tests/sequence_api.rs`). The stream state's f32 latent is the
    /// carry, so the f64-state offline option does not apply here.
    ///
    /// The equivalence holds **per storage dtype**: a bf16 stream's
    /// per-token path round-trips the drive and the projection read
    /// through bf16 at exactly the points the fused bf16 tile
    /// narrow-stores, so bf16 chunked prefill ≡ bf16 step replay stays
    /// bit-for-bit too (same test, bf16 twin).
    ///
    /// [`push`]: S5StreamState::push
    pub fn push_chunk(&mut self, m: &S5Model, tokens: &[f32], l: usize, opts: &ForwardOptions) {
        assert_eq!(tokens.len(), l * m.d_in);
        assert!(m.streamable(), "bidirectional layers cannot stream");
        if l == 0 {
            return;
        }
        let timescale = opts.timescale;
        let dtype = self.dtype;
        let h = m.h;
        let n = l * h;
        let backend = opts.scan_backend();
        let ws = &mut self.ws;
        let EngineWorkspace { x, v, y, ssm, .. } = ws;
        grow(x, n);
        grow(v, n);
        grow(y, n);
        m.encode_seq(tokens, l, &mut x[..n]);
        for (layer, lstate) in m.layers.iter().zip(self.states.iter_mut()) {
            // a chunk of regular steps: restore the default discretization
            // exactly like each per-token regular step would
            lstate.restore_default_dt(layer, timescale);
            let p2 = layer.p2;
            let tile = opts
                .scan_policy()
                .tiling
                .resolve(p2, h, false)
                .unwrap_or(l)
                .min(l)
                .max(1);
            let SsmBuffers { bu_re, bu_im, bu_re16, bu_im16, scan, .. } = ssm;
            layer.norm_seq(&x[..n], l, &mut v[..n]);
            match dtype {
                Dtype::F32 => {
                    grow(bu_re, tile * p2);
                    grow(bu_im, tile * p2);
                    let mut unit = FusedUnit {
                        dir: 0,
                        useq: &v[..n],
                        dseq: None,
                        yseq: &mut y[..n],
                        dr: &mut bu_re[..tile * p2],
                        di: &mut bu_im[..tile * p2],
                        tv: None,
                        sr: &mut lstate.xr[..],
                        si: &mut lstate.xi[..],
                        s64: None,
                    };
                    layer.fused_unit(
                        &mut unit,
                        l,
                        tile,
                        &lstate.lam_re,
                        &lstate.lam_im,
                        &lstate.scale_re,
                        &lstate.scale_im,
                        &[],
                        &[],
                        backend,
                        true, // resume from (and write back) the live stream state
                        true, // unidirectional: fold the feedthrough per tile
                        1,    // in-tile width 1: keep the bit-for-bit step-replay pin
                        &mut scan.f_workers(1)[0],
                    );
                }
                Dtype::Bf16 => {
                    grow(bu_re16, tile * p2);
                    grow(bu_im16, tile * p2);
                    let mut unit = FusedUnit {
                        dir: 0,
                        useq: &v[..n],
                        dseq: None,
                        yseq: &mut y[..n],
                        dr: &mut bu_re16[..tile * p2],
                        di: &mut bu_im16[..tile * p2],
                        tv: None,
                        sr: &mut lstate.xr[..],
                        si: &mut lstate.xi[..],
                        s64: None,
                    };
                    layer.fused_unit(
                        &mut unit,
                        l,
                        tile,
                        &lstate.lam_re,
                        &lstate.lam_im,
                        &lstate.scale_re,
                        &lstate.scale_im,
                        &[],
                        &[],
                        backend,
                        true, // resume from (and write back) the live stream state
                        true, // unidirectional: fold the feedthrough per tile
                        1,    // in-tile width 1: keep the bit-for-bit step-replay pin
                        &mut scan.f_workers(1)[0],
                    );
                }
            }
            layer.gate_residual_seq(&y[..n], &mut x[..n], l, &mut v[..h]);
        }
        for k in 0..l {
            for r in 0..h {
                self.pool[r] += x[k * h + r];
            }
        }
        self.steps += l;
    }

    /// Current logits from the running mean-pool. The inline
    /// `pool[c] / denom` is the exact division `pool_decode_seq` applies
    /// before projecting (same single f32 op per element, just not
    /// materialized), so a stream of L pushes reproduces the batched
    /// forward bit-for-bit on the sequential scan path — with no per-call
    /// pool clone on the streaming hot path.
    pub fn logits(&self, m: &S5Model) -> Vec<f32> {
        let mut out = vec![0.0f32; m.classes];
        self.logits_into(m, &mut out);
        out
    }

    /// [`logits`](S5StreamState::logits) into a caller-provided row — the
    /// allocation-free form [`crate::ssm::api::Session::step_into`] drives.
    pub fn logits_into(&self, m: &S5Model, out: &mut [f32]) {
        assert_eq!(out.len(), m.classes);
        let denom = self.steps.max(1) as f32;
        for r in 0..m.classes {
            let mut acc = m.dec_b[r];
            for c in 0..m.h {
                acc += m.dec_w[r * m.h + c] * (self.pool[c] / denom);
            }
            out[r] = acc;
        }
    }

    /// Observations consumed since the last reset.
    pub fn steps(&self) -> usize {
        self.steps
    }
}

/// Legacy S5-only streaming wrapper (borrows the model).
#[deprecated(
    since = "0.3.0",
    note = "use `ssm::api::Session` over the `SequenceModel` trait"
)]
pub struct OnlineModel<'a> {
    model: &'a S5Model,
    state: S5StreamState,
}

#[allow(deprecated)]
impl<'a> OnlineModel<'a> {
    pub fn new(model: &'a S5Model, timescale: f64) -> OnlineModel<'a> {
        OnlineModel { model, state: S5StreamState::new(model, timescale) }
    }

    /// Feed one observation (d_in); updates all layer states.
    pub fn push(&mut self, u: &[f32], timescale: f64) {
        self.state.push(self.model, u, timescale, None);
    }

    /// Current logits from the running mean-pool.
    pub fn logits(&self) -> Vec<f32> {
        self.state.logits(self.model)
    }
}

#[cfg(test)]
#[allow(deprecated)] // exercises the legacy wrappers against the new path
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::ssm::s5::S5Config;
    use crate::testing::prop;

    fn layer(h: usize, p: usize) -> S5Layer {
        S5Layer::init(&S5Config { h, p, j: 1, ..Default::default() }, &mut Rng::new(1))
    }

    #[test]
    fn online_ssm_equals_offline_scan() {
        let lp = layer(6, 8);
        let l = 40;
        let mut rng = Rng::new(2);
        let u = rng.normal_vec_f32(l * 6);
        let offline = lp.apply_ssm(&u, l, 1.0, None, 1);
        let mut st = LayerState::new(&lp, 1.0);
        for k in 0..l {
            let y = lp.step_ssm(&mut st, &u[k * 6..(k + 1) * 6], 1.0, None);
            for c in 0..6 {
                let (a, b) = (offline[k * 6 + c], y[c]);
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                    "k={k} c={c}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn online_layer_equals_offline_layer() {
        let lp = layer(4, 8);
        let l = 30;
        let mut rng = Rng::new(3);
        let u = rng.normal_vec_f32(l * 4);
        let offline = lp.apply(&u, l, 1.0, None, 1);
        let mut st = LayerState::new(&lp, 1.0);
        for k in 0..l {
            let y = lp.step(&mut st, &u[k * 4..(k + 1) * 4], 1.0, None);
            prop::close_slice_f32(&offline[k * 4..(k + 1) * 4], &y, 2e-3)
                .unwrap_or_else(|e| panic!("k={k}: {e}"));
        }
    }

    #[test]
    fn online_variable_dt_equals_offline_variable_dt() {
        let lp = layer(4, 8);
        let l = 25;
        let mut rng = Rng::new(4);
        let u = rng.normal_vec_f32(l * 4);
        let dts: Vec<f32> = rng.uniform_vec_f32(l, 0.3, 2.5);
        let offline = lp.apply_ssm(&u, l, 1.0, Some(&dts), 1);
        let mut st = LayerState::new(&lp, 1.0);
        for k in 0..l {
            let y = lp.step_ssm(&mut st, &u[k * 4..(k + 1) * 4], 1.0, Some(dts[k]));
            prop::close_slice_f32(&offline[k * 4..(k + 1) * 4], &y, 2e-3)
                .unwrap_or_else(|e| panic!("k={k}: {e}"));
        }
    }

    /// dt = None is a *regular* step: after an irregular step, streaming
    /// must fall back to the default discretization (multiplier 1), not
    /// keep integrating with the last irregular Λ̄ — matching the offline
    /// TV scan where omitted dts ≡ all-ones.
    #[test]
    fn regular_step_after_irregular_restores_default_dt() {
        let lp = layer(4, 8);
        let l = 12;
        let mut rng = Rng::new(9);
        let u = rng.normal_vec_f32(l * 4);
        let mut dts = vec![1.0f32; l];
        dts[3] = 2.5; // one long gap mid-stream
        let offline = lp.apply_ssm(&u, l, 1.0, Some(&dts), 1);
        let mut st = LayerState::new(&lp, 1.0);
        for k in 0..l {
            let dt = if dts[k] != 1.0 { Some(dts[k]) } else { None };
            let y = lp.step_ssm(&mut st, &u[k * 4..(k + 1) * 4], 1.0, dt);
            prop::close_slice_f32(&offline[k * 4..(k + 1) * 4], &y, 2e-3)
                .unwrap_or_else(|e| panic!("k={k}: {e}"));
        }
    }

    /// A per-call timescale change must re-discretize, not reuse the Λ̄
    /// built for the construction-time timescale — for both the regular
    /// (dt = None) and irregular (dt = Some) paths.
    #[test]
    fn timescale_change_mid_stream_rediscretizes() {
        let lp = layer(4, 8);
        let mut rng = Rng::new(12);
        let u = rng.normal_vec_f32(4);
        // state built for timescale 1.0 but stepped at 2.0 must equal a
        // state built for 2.0 from the start
        let mut st_a = LayerState::new(&lp, 1.0);
        let mut st_b = LayerState::new(&lp, 2.0);
        let ya = lp.step_ssm(&mut st_a, &u, 2.0, None);
        let yb = lp.step_ssm(&mut st_b, &u, 2.0, None);
        prop::close_slice_f32(&ya, &yb, 1e-6).unwrap();
        // same for the irregular path: cached dt key must not mask a
        // timescale change
        let mut st_c = LayerState::new(&lp, 1.0);
        let mut st_d = LayerState::new(&lp, 1.0);
        let _ = lp.step_ssm(&mut st_c, &u, 1.0, Some(1.5));
        let _ = lp.step_ssm(&mut st_d, &u, 1.0, Some(1.5));
        let yc = lp.step_ssm(&mut st_c, &u, 3.0, Some(1.5));
        let mut st_e = LayerState::new(&lp, 1.0);
        let _ = lp.step_ssm(&mut st_e, &u, 1.0, Some(1.5));
        let ye = lp.step_ssm(&mut st_e, &u, 3.0, Some(1.5));
        prop::close_slice_f32(&yc, &ye, 1e-6).unwrap();
        // and the changed-timescale result must actually differ from the
        // stale-cache result (which st_d reproduces by construction)
        let yd_stale = lp.step_ssm(&mut st_d, &u, 1.0, Some(1.5));
        let diff: f32 = yc.iter().zip(&yd_stale).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-6, "timescale change had no effect");
    }

    #[test]
    fn state_reset_restarts_sequence() {
        let lp = layer(4, 8);
        let mut rng = Rng::new(5);
        let u = rng.normal_vec_f32(4);
        let mut st = LayerState::new(&lp, 1.0);
        let y1 = lp.step_ssm(&mut st, &u, 1.0, None);
        let _ = lp.step_ssm(&mut st, &u, 1.0, None);
        st.reset();
        let y3 = lp.step_ssm(&mut st, &u, 1.0, None);
        prop::close_slice_f32(&y1, &y3, 1e-6).unwrap();
    }

    #[test]
    fn online_model_matches_offline_forward() {
        let cfg = S5Config { h: 8, p: 8, j: 1, ..Default::default() };
        let model = crate::ssm::s5::S5Model::init(2, 5, 2, &cfg, &mut Rng::new(6));
        let l = 20;
        let mut rng = Rng::new(7);
        let u = rng.normal_vec_f32(l * 2);
        let offline = model.forward(&u, l, 1.0, 1);
        let mut online = OnlineModel::new(&model, 1.0);
        for k in 0..l {
            online.push(&u[k * 2..(k + 1) * 2], 1.0);
        }
        prop::close_slice_f32(&offline, &online.logits(), 2e-3).unwrap();
    }

    #[test]
    #[should_panic(expected = "bidirectional")]
    fn bidirectional_layer_cannot_stream() {
        let lp = S5Layer::init(
            &S5Config { h: 4, p: 8, j: 1, bidir: true, ..Default::default() },
            &mut Rng::new(8),
        );
        let mut st = LayerState::new(&lp, 1.0);
        lp.step_ssm(&mut st, &[0.0; 4], 1.0, None);
    }
}
