//! Storage dtypes for the planar engine: the storage/compute split.
//!
//! The fused planar pipeline is memory-bound (see `BENCH_scan.json`'s
//! ssm-bytes-per-token rows), so the *storage* element type of the drive
//! planes is a first-class parameter: [`ScanElem`] abstracts over what the
//! workspace planes hold, while every recurrence, chunk summary and
//! projection accumulator stays `f32` (or `f64` under the f64-state
//! option) — kernels load-widen, compute in full precision, and
//! narrow-store.
//!
//! Two storage types exist today:
//!
//! * `f32` — the identity instantiation. `from_f32`/`to_f32` are the
//!   identity function, so the monomorphized kernels are the exact
//!   pre-refactor code and stay **bit-for-bit** with the scalar/staged
//!   oracles (pinned by `tests/scan_matrix.rs`).
//! * [`Bf16`] — a hand-rolled software bfloat16 (the container is
//!   hermetic; no external half-float crate). bfloat16 is the top 16 bits
//!   of an IEEE-754 binary32: same 8-bit exponent, 7-bit mantissa, so
//!   widening is exact (a shift) and narrowing is a round-to-nearest-even
//!   on the low 16 bits. Relative precision is 2⁻⁸ per stored element;
//!   the end-to-end forward error budget is documented in the crate-level
//!   "Precision model" section and pinned by the L = 64k drift test in
//!   `tests/scan_matrix.rs`.
//!
//! The trait is **sealed**: the planar kernels in `ssm/scan.rs` and
//! `ssm/simd.rs` pattern-match storage behavior per type (e.g. the f32
//! first-tile fast path), so an out-of-crate element type could not be
//! given a correct kernel set anyway. int8 drive planes would slot in
//! here as a third implementation.

/// The storage dtype of the planar drive planes, as a runtime value —
/// what [`ScanPolicy`](crate::ssm::engine::ScanPolicy) carries and the
/// `S5_DTYPE` environment knob selects.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Dtype {
    /// 4-byte IEEE binary32 storage (the default; bit-for-bit with the
    /// pre-dtype engine).
    #[default]
    F32,
    /// 2-byte bfloat16 storage with f32 accumulate (half the plane
    /// traffic; tolerance-pinned).
    Bf16,
}

impl Dtype {
    /// Bytes per stored element (what the workspace capacity accounting
    /// and the bench's bytes-per-token metric charge per plane slot).
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 => 2,
        }
    }

    /// Canonical lowercase name (`"f32"` / `"bf16"`), matching the
    /// accepted `S5_DTYPE` values.
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
        }
    }
}

/// A software bfloat16: the top 16 bits of an IEEE-754 binary32.
///
/// Stored as the raw bit pattern. Arithmetic never happens in this type —
/// kernels widen to `f32` ([`Bf16::to_f32`], exact), compute, and narrow
/// back ([`Bf16::from_f32`], round-to-nearest-even).
#[repr(transparent)]
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Bf16(pub u16);

/// Narrow an `f32` to bfloat16 with IEEE round-to-nearest-even.
///
/// The non-NaN path is the classic bias trick: adding
/// `0x7FFF + lsb(upper half)` to the f32 bits carries into the kept half
/// exactly when the discarded half is above the tie, or at the tie with
/// an odd kept half — i.e. round-to-nearest, ties-to-even. This also
/// rounds values past `bf16` max to ±inf and handles subnormals and ±0
/// with no special cases. NaN is handled separately because the bias
/// could carry a NaN payload up into an infinity bit pattern: the result
/// keeps the sign and high payload bits and forces the quiet bit.
#[inline]
pub fn f32_to_bf16(x: f32) -> Bf16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return Bf16(((bits >> 16) as u16) | 0x0040);
    }
    Bf16((bits.wrapping_add(0x7FFF + ((bits >> 16) & 1)) >> 16) as u16)
}

/// Widen a bfloat16 to `f32`. Exact for every bit pattern (bfloat16 is a
/// bit-prefix of binary32).
#[inline]
pub fn bf16_to_f32(b: Bf16) -> f32 {
    f32::from_bits((b.0 as u32) << 16)
}

/// One f32 → bf16 → f32 round trip: the value actually stored when a
/// computed f32 lands in a bfloat16 plane. The streaming step path uses
/// this to reproduce the prefill path's storage rounding bit-for-bit
/// without materializing bf16 planes.
#[inline]
pub fn bf16_round_trip(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for super::Bf16 {}
}

/// A storage element of the planar drive planes. Sealed — see the module
/// docs for why.
///
/// The contract kernels rely on:
/// * `to_f32(from_f32(x))` is a *pure rounding* of `x` (identity for
///   `f32`, round-to-nearest-even for [`Bf16`]), and
/// * `from_f32(to_f32(e)) == e` for every non-NaN stored element
///   (narrow∘widen is the identity), so re-storing a widened element is
///   lossless and tile boundaries cannot introduce double-rounding drift.
pub trait ScanElem:
    sealed::Sealed + Copy + Default + Send + Sync + PartialEq + std::fmt::Debug + 'static
{
    /// The runtime tag for this storage type.
    const DTYPE: Dtype;

    /// Narrow a computed f32 into storage (rounding for narrow types).
    fn from_f32(x: f32) -> Self;

    /// Widen a stored element to f32 (always exact).
    fn to_f32(self) -> f32;
}

impl ScanElem for f32 {
    const DTYPE: Dtype = Dtype::F32;

    #[inline]
    fn from_f32(x: f32) -> Self {
        x
    }

    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
}

impl ScanElem for Bf16 {
    const DTYPE: Dtype = Dtype::Bf16;

    #[inline]
    fn from_f32(x: f32) -> Self {
        f32_to_bf16(x)
    }

    #[inline]
    fn to_f32(self) -> f32 {
        bf16_to_f32(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn narrow_bits(bits: u32) -> u16 {
        f32_to_bf16(f32::from_bits(bits)).0
    }

    /// Reference bit patterns for the round-to-nearest-even narrowing:
    /// below the tie truncates, above the tie rounds up, and exact ties
    /// go to the even (lsb-0) kept half in both directions.
    #[test]
    fn narrowing_rounds_to_nearest_even() {
        // 1.0: exact in bf16.
        assert_eq!(narrow_bits(0x3F80_0000), 0x3F80);
        // Just below the tie between 0x3F80 and 0x3F81: truncates.
        assert_eq!(narrow_bits(0x3F80_7FFF), 0x3F80);
        // Exact tie with even kept half: stays even (down).
        assert_eq!(narrow_bits(0x3F80_8000), 0x3F80);
        // Just above the tie: rounds up.
        assert_eq!(narrow_bits(0x3F80_8001), 0x3F81);
        // Exact tie with odd kept half: rounds up to even.
        assert_eq!(narrow_bits(0x3F81_8000), 0x3F82);
        // Just below that tie: truncates to the odd half.
        assert_eq!(narrow_bits(0x3F81_7FFF), 0x3F81);
        // Carry propagation across the mantissa into the exponent:
        // 0x3FFF_8000 is the tie between 0x3FFF (1.9921875) and the next
        // representable, which is 2.0 = 0x4000 — even, so the tie lands
        // there via a full mantissa carry.
        assert_eq!(narrow_bits(0x3FFF_8000), 0x4000);
        // Sign is preserved through the same paths.
        assert_eq!(narrow_bits(0xBF80_8001), 0xBF81);
    }

    #[test]
    fn special_values_survive() {
        // ±0 keep their sign bit.
        assert_eq!(narrow_bits(0x0000_0000), 0x0000);
        assert_eq!(narrow_bits(0x8000_0000), 0x8000);
        assert_eq!(bf16_to_f32(Bf16(0x8000)).to_bits(), 0x8000_0000);
        // ±inf round-trip exactly.
        assert_eq!(f32_to_bf16(f32::INFINITY).0, 0x7F80);
        assert_eq!(f32_to_bf16(f32::NEG_INFINITY).0, 0xFF80);
        assert_eq!(bf16_to_f32(Bf16(0x7F80)), f32::INFINITY);
        // Values past bf16 max (but finite in f32) round to inf…
        assert_eq!(f32_to_bf16(f32::MAX).0, 0x7F80);
        // …while bf16 max itself is representable and round-trips.
        assert_eq!(narrow_bits(0x7F7F_0000), 0x7F7F);
        // NaN stays NaN (quiet bit forced, sign + high payload kept),
        // and never collapses into an infinity bit pattern.
        let q = f32_to_bf16(f32::NAN);
        assert!(bf16_to_f32(q).is_nan());
        let signaling = f32::from_bits(0xFF80_0001); // -NaN, payload only in low bits
        let n = f32_to_bf16(signaling);
        assert!(bf16_to_f32(n).is_nan(), "payload below bit 16 must not vanish");
        assert_eq!(n.0 & 0x8000, 0x8000, "NaN sign preserved");
        // f32 subnormals: the smallest ones round to (signed) zero…
        assert_eq!(narrow_bits(0x0000_0001), 0x0000);
        assert_eq!(narrow_bits(0x8000_0001), 0x8000);
        // …and bf16's own subnormals are exactly representable f32
        // subnormals, rounding to nearest like everything else.
        assert_eq!(narrow_bits(0x0001_0000), 0x0001);
        assert_eq!(narrow_bits(0x0000_8000), 0x0000, "tie at half the smallest: to even");
        assert_eq!(narrow_bits(0x0000_8001), 0x0001, "just above: rounds up");
    }

    /// Every one of the 65536 bf16 bit patterns widens and re-narrows to
    /// itself (NaNs: to *a* NaN — the quiet bit is forced). This is the
    /// narrow∘widen = identity half of the [`ScanElem`] contract, and it
    /// makes f32→bf16→f32 idempotent by construction.
    #[test]
    fn widen_then_narrow_is_identity_for_all_bit_patterns() {
        for bits in 0..=u16::MAX {
            let b = Bf16(bits);
            let wide = bf16_to_f32(b);
            let back = f32_to_bf16(wide);
            if wide.is_nan() {
                assert!(bf16_to_f32(back).is_nan(), "{bits:#06x} lost NaN-ness");
                assert_eq!(back.0 & 0xFF80, bits & 0xFF80, "{bits:#06x} sign/exponent");
            } else {
                assert_eq!(back.0, bits, "{bits:#06x} failed to round-trip");
            }
        }
    }

    /// f32 → bf16 → f32 is idempotent: rounding an already-rounded value
    /// changes nothing. Property-tested over an LCG stream of raw f32
    /// bit patterns (covering normals, subnormals, huge values and NaNs).
    #[test]
    fn round_trip_is_idempotent() {
        let mut seed = 0x9E37_79B9_7F4A_7C15u64;
        for _ in 0..100_000 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let x = f32::from_bits((seed >> 32) as u32);
            let once = bf16_round_trip(x);
            let twice = bf16_round_trip(once);
            if once.is_nan() {
                assert!(twice.is_nan());
            } else {
                assert_eq!(twice.to_bits(), once.to_bits(), "x={:#010x}", x.to_bits());
            }
        }
    }

    /// The f32 instantiation of [`ScanElem`] is the identity at the bit
    /// level — the guarantee behind "f32 storage is bit-for-bit with the
    /// pre-dtype engine".
    #[test]
    fn f32_elem_is_bitwise_identity() {
        for bits in [0u32, 0x8000_0000, 0x3F80_0001, 0x7F80_0000, 0x0000_0001] {
            let x = f32::from_bits(bits);
            assert_eq!(<f32 as ScanElem>::from_f32(x).to_bits(), bits);
            assert_eq!(ScanElem::to_f32(x).to_bits(), bits);
        }
        assert_eq!(<f32 as ScanElem>::DTYPE, Dtype::F32);
        assert_eq!(<Bf16 as ScanElem>::DTYPE, Dtype::Bf16);
        assert_eq!(Dtype::F32.bytes(), 4);
        assert_eq!(Dtype::Bf16.bytes(), 2);
        assert_eq!(Dtype::F32.name(), "f32");
        assert_eq!(Dtype::Bf16.name(), "bf16");
        assert_eq!(Dtype::default(), Dtype::F32);
    }

    /// bf16 relative precision: one round trip perturbs a normal value by
    /// at most 2⁻⁸ relative (half-ulp of a 7-bit mantissa) — the
    /// per-element term the end-to-end drift budget is built from.
    #[test]
    fn relative_error_within_half_ulp() {
        let mut seed = 1u64;
        for _ in 0..10_000 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // Map to (-8, 8), away from zero-crossing denormal noise.
            let x = ((seed >> 40) as f32 / (1u64 << 24) as f32 - 0.5) * 16.0;
            if x.abs() < 1e-3 {
                continue;
            }
            let r = bf16_round_trip(x);
            assert!((r - x).abs() <= x.abs() * (1.0 / 256.0), "x={x} r={r}");
        }
    }
}
