//! The unified sequence-model inference API.
//!
//! S5's pitch is that one MIMO SSM plus a parallel scan subsumes a bank of
//! SISO SSMs; this module makes the *serving surface* match that claim: one
//! typed API that every sequence model in the crate plugs into, so the
//! dynamic-batching server, streaming sessions and checkpoint import
//! compose instead of being re-implemented per model.
//!
//! * [`Batch`] — a typed view over a packed row-major (B, L, d) buffer,
//!   replacing raw `&[f32]` plus positional size arguments.
//! * [`ForwardOptions`] — the execution knobs (timescale as `f64`
//!   everywhere, scan strategy / thread budget) as a builder, replacing the
//!   positional `(timescale, threads)` tail of the legacy signatures.
//! * [`SequenceModel`] — the object-safe trait: `spec()` describes the
//!   model, `prefill_into` consumes a packed batch (the offline scan path),
//!   `make_state`/`step` run incremental decoding (the §3.3 online mode).
//!   Implemented by [`S5Model`](crate::ssm::s5::S5Model),
//!   [`GruCell`](crate::ssm::rnn::GruCell) and
//!   [`CruLike`](crate::ssm::rnn::CruLike).
//! * [`Session`] — prefill-then-step stateful streaming over any
//!   `SequenceModel` (absorbing the old S5-only
//!   `online::OnlineModel`), and [`SessionPool`] — the per-connection
//!   session reuse the native server hands out.
//!
//! Streaming and batched execution share kernels by construction, so for
//! the sequential scan strategy `Session::step` driven over L tokens
//! reproduces `prefill` outputs bit-for-bit (see `tests/sequence_api.rs`).

use std::any::Any;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::ssm::dtype::Dtype;
use crate::ssm::engine::{EngineWorkspace, ScanPolicy, Tiling};
use crate::ssm::scan::{
    backend_for, backend_for_exec, backend_for_threads, ScanBackend, ScanExec, ScanLayout,
    SequentialBackend,
};

// ---------------------------------------------------------------------------
// Typed batch view
// ---------------------------------------------------------------------------

/// A typed, validated view of a packed row-major (B, L, width) buffer.
///
/// Constructing a `Batch` checks the dimension product once, so every
/// consumer downstream can slice without re-deriving sizes from positional
/// arguments.
#[derive(Clone, Copy, Debug)]
pub struct Batch<'a> {
    data: &'a [f32],
    batch: usize,
    len: usize,
    width: usize,
}

impl<'a> Batch<'a> {
    /// View `data` as (batch, len, width). Panics if the product does not
    /// match `data.len()` or any dimension is zero.
    pub fn new(data: &'a [f32], batch: usize, len: usize, width: usize) -> Batch<'a> {
        assert!(batch > 0 && len > 0 && width > 0, "empty batch/sequence");
        assert_eq!(
            data.len(),
            batch * len * width,
            "batch data length {} != {batch}x{len}x{width}",
            data.len()
        );
        Batch { data, batch, len, width }
    }

    /// View one sequence as a batch of 1.
    pub fn single(data: &'a [f32], len: usize, width: usize) -> Batch<'a> {
        Batch::new(data, 1, len, width)
    }

    /// Number of sequences B.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Sequence length L.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the view holds no timesteps (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Feature width per step.
    pub fn width(&self) -> usize {
        self.width
    }

    /// The underlying packed buffer.
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// One sequence's (L × width) rows.
    pub fn seq(&self, i: usize) -> &'a [f32] {
        let stride = self.len * self.width;
        &self.data[i * stride..(i + 1) * stride]
    }
}

// ---------------------------------------------------------------------------
// Forward options
// ---------------------------------------------------------------------------

/// Execution knobs for a forward pass, as a builder.
///
/// Replaces the positional `(timescale, threads)` argument tails: the
/// timescale is `f64` everywhere (no more f32/f64 mismatch between server
/// and model), and the scan strategy is an explicit shared object rather
/// than a thread count re-resolved at every layer.
///
/// ```
/// use s5::ssm::api::ForwardOptions;
/// let opts = ForwardOptions::new().with_timescale(2.0).with_threads(4);
/// assert_eq!(opts.timescale, 2.0);
/// assert_eq!(opts.scan_backend().threads(), 4);
/// ```
#[derive(Clone)]
pub struct ForwardOptions {
    /// Zero-shot Δ-rescale factor (§6.2); 1.0 = the trained sampling rate.
    pub timescale: f64,
    backend: Arc<dyn ScanBackend>,
    policy: ScanPolicy,
}

impl Default for ForwardOptions {
    /// Sequential scan, timescale 1.0, fused auto-tiled forward — the
    /// deterministic reference configuration (streaming ≡ batched
    /// bit-for-bit).
    fn default() -> Self {
        ForwardOptions {
            timescale: 1.0,
            backend: Arc::new(SequentialBackend),
            policy: ScanPolicy::default(),
        }
    }
}

impl ForwardOptions {
    pub fn new() -> ForwardOptions {
        ForwardOptions::default()
    }

    /// Set the Δ-rescale factor.
    pub fn with_timescale(mut self, timescale: f64) -> ForwardOptions {
        self.timescale = timescale;
        self
    }

    /// Pick a scan strategy for a thread budget (0 = auto-detect, ≤ 1 =
    /// sequential, else parallel) — mirrors the legacy `threads` knob.
    /// The resolved backend drives the default **planar** (SIMD-friendly)
    /// layout and dispatches shards on the process-wide persistent worker
    /// pool; use [`ForwardOptions::with_scan`] to pin the interleaved
    /// reference oracle, or [`ForwardOptions::with_exec`] to opt out of
    /// the pool.
    pub fn with_threads(mut self, threads: usize) -> ForwardOptions {
        self.backend = Arc::from(backend_for_threads(threads));
        self
    }

    /// Pick a scan strategy with an explicit buffer layout — the A/B knob
    /// for validating the planar default against the interleaved oracle.
    ///
    /// Re-resolves the whole backend: a dispatch mode previously pinned
    /// with [`ForwardOptions::with_exec`] resets to the pooled default
    /// (call `with_scan` first, `with_exec` last — `with_exec` preserves
    /// the layout).
    pub fn with_scan(mut self, threads: usize, layout: ScanLayout) -> ForwardOptions {
        self.backend = Arc::from(backend_for(threads, layout));
        self
    }

    /// Pick a scan strategy with an explicit dispatch mode — the opt-out
    /// knob for the persistent worker pool. [`ScanExec::Scoped`] restores
    /// the pre-pool spawn-per-call threads, [`ScanExec::Inline`] runs the
    /// same chunked decomposition single-threaded, and
    /// [`ScanExec::Pool`] pins a dedicated pool instance. Results are
    /// bit-for-bit identical across modes; only dispatch overhead
    /// changes. The currently selected [`ScanLayout`] is preserved, so
    /// `with_scan(...).with_exec(...)` composes.
    pub fn with_exec(mut self, threads: usize, exec: ScanExec) -> ForwardOptions {
        let layout = self.backend.layout();
        self.backend = Arc::from(backend_for_exec(threads, layout, exec));
        self
    }

    /// Install an explicit scan strategy object.
    pub fn with_backend(mut self, backend: Arc<dyn ScanBackend>) -> ForwardOptions {
        self.backend = backend;
        self
    }

    /// Pin an explicit L-tile length for the fused cache-blocked forward
    /// (`0` disables tiling — the staged reference pipeline). The default
    /// is [`Tiling::Auto`]: a tile auto-sized to the L2 budget
    /// ([`crate::ssm::engine::auto_tile_l`]), overridable process-wide
    /// with the `S5_TILE_L` environment variable. The tile never changes
    /// the result — fused forwards equal the staged sequential pipeline
    /// bit-for-bit for any tile — only the memory-traffic profile.
    pub fn with_tile(mut self, tile_l: usize) -> ForwardOptions {
        self.policy.tiling = if tile_l == 0 { Tiling::Staged } else { Tiling::Fixed(tile_l) };
        self
    }

    /// Select the forward blocking policy explicitly — [`Tiling::Staged`]
    /// pins the untiled full-plane reference pipeline the fused default
    /// is validated against.
    pub fn with_tiling(mut self, tiling: Tiling) -> ForwardOptions {
        self.policy.tiling = tiling;
        self
    }

    /// Carry the scan state in f64 across the sequence (long-L drift
    /// studies): the recurrence accumulates in f64 while the emitted
    /// state rows stay f32, so results are tile- and thread-invariant
    /// bit-for-bit. Planar layout only (the interleaved oracle is
    /// f32-only, and streaming sessions always carry f32 state); with
    /// [`Tiling::Staged`] the sequence runs as a single fused tile.
    pub fn with_f64_state(mut self) -> ForwardOptions {
        self.policy.f64_state = true;
        self
    }

    /// Opt into **in-tile** parallelism for the fused forward
    /// ([`ScanPolicy::wide`]): when a pass has fewer (sequence ×
    /// direction) pipelines than the backend's thread budget — the
    /// single-stream / low-batch regime — the leftover workers split each
    /// tile's rows instead of idling. Drive, Δt-scale and projection
    /// row-splits are bit-exact; the tile scan runs the seeded
    /// chunked-parallel kernels, whose carry reassociation makes the wide
    /// path **tolerance-equal** (≤ 1e-4 relative) to the sequential
    /// reference rather than bit-for-bit — which is why this is opt-in
    /// and the default stays exactly reproducible. Results remain
    /// deterministic for a fixed thread budget and executor-invariant.
    /// Ignored by [`ForwardOptions::with_f64_state`] (the f64 carry
    /// contract is sequential) and by streaming sessions.
    pub fn with_wide(mut self) -> ForwardOptions {
        self.policy.wide = true;
        self
    }

    /// Pin the storage dtype for the SSM drive planes
    /// ([`ScanPolicy::dtype`]): [`Dtype::Bf16`] halves the dominant
    /// memory traffic of the fused forward (and of streaming sessions)
    /// by narrow-storing the drive planes, while every accumulation —
    /// scan recurrence, chunk carries, projection — stays f32. Unset
    /// (the default), the process-wide `S5_DTYPE` environment knob
    /// decides, falling back to [`Dtype::F32`] — which is bit-for-bit
    /// the pre-dtype pipeline. bf16 runs fused (a staged policy
    /// executes as one tile) and composes with streaming: a bf16
    /// session's step replay equals its chunked prefill bit-for-bit.
    /// [`ForwardOptions::with_f64_state`] overrides this back to f32
    /// storage (its tile-invariance contract is the precision story).
    pub fn with_dtype(mut self, dtype: Dtype) -> ForwardOptions {
        self.policy.dtype = Some(dtype);
        self
    }

    /// The engine-level scan policy (tiling + state precision + in-tile
    /// width) this forward will run under.
    pub fn scan_policy(&self) -> ScanPolicy {
        self.policy
    }

    /// The scan strategy this forward will run with.
    pub fn scan_backend(&self) -> &dyn ScanBackend {
        self.backend.as_ref()
    }

    /// The buffer layout the forward will drive ([`ScanLayout::Planar`]
    /// unless an interleaved oracle backend was installed).
    pub fn scan_layout(&self) -> ScanLayout {
        self.backend.layout()
    }
}

// ---------------------------------------------------------------------------
// The SequenceModel trait
// ---------------------------------------------------------------------------

/// What a model consumes and produces, plus its capabilities.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    /// Short model-family name (telemetry, logs).
    pub name: &'static str,
    /// Input feature width per step.
    pub d_input: usize,
    /// Output row width per sequence (classifier logits, hidden state, …).
    pub d_output: usize,
    /// Whether [`SequenceModel::make_state`]/[`SequenceModel::step`] are
    /// supported (bidirectional S5 stacks cannot stream by construction).
    pub streamable: bool,
}

/// Opaque per-session streaming state of some [`SequenceModel`].
///
/// Models downcast to their concrete state type inside `step`; callers
/// treat it as a token owned by a [`Session`].
pub struct SessionState(Box<dyn Any + Send>);

impl SessionState {
    pub fn new<T: Any + Send>(state: T) -> SessionState {
        SessionState(Box::new(state))
    }

    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        self.0.downcast_ref::<T>()
    }

    pub fn downcast_mut<T: Any>(&mut self) -> Option<&mut T> {
        self.0.downcast_mut::<T>()
    }
}

/// The one typed inference interface every sequence model implements.
///
/// Object-safe: the native server holds `Arc<dyn SequenceModel>` and one
/// dynamic-batching loop serves S5 and the RNN baselines alike.
pub trait SequenceModel: Send + Sync {
    /// Static shape/capability description.
    fn spec(&self) -> ModelSpec;

    /// Forward a packed batch, writing one `d_output` row per sequence
    /// into `out` (must be exactly `batch.batch() * d_output` long).
    fn prefill_into(
        &self,
        batch: Batch<'_>,
        opts: &ForwardOptions,
        ws: &mut EngineWorkspace,
        out: &mut [f32],
    );

    /// Forward a packed batch into a fresh output vector.
    fn prefill(
        &self,
        batch: Batch<'_>,
        opts: &ForwardOptions,
        ws: &mut EngineWorkspace,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; batch.batch() * self.spec().d_output];
        self.prefill_into(batch, opts, ws, &mut out);
        out
    }

    /// Fresh streaming state (one decode stream). Panics if
    /// `spec().streamable` is false.
    fn make_state(&self, opts: &ForwardOptions) -> SessionState;

    /// Reset a streaming state to the start-of-sequence point without
    /// reallocating (session reuse across connections).
    fn reset_state(&self, state: &mut SessionState);

    /// Consume one input row (`d_input`), advance the state, and return
    /// the current output row (`d_output`). `dt` is the per-step Δt
    /// multiplier for irregular sampling (§6.3); models without a Δt
    /// notion ignore it.
    fn step(
        &self,
        state: &mut SessionState,
        u: &[f32],
        dt: Option<f32>,
        opts: &ForwardOptions,
    ) -> Vec<f32>;

    /// [`step`](SequenceModel::step) into a caller-provided output row
    /// (`d_output`). Default: the allocating `step` copied into `out`;
    /// models override to make the steady-state streaming path
    /// allocation-free (S5 does — pinned by `tests/alloc_guard.rs`).
    fn step_into(
        &self,
        state: &mut SessionState,
        u: &[f32],
        dt: Option<f32>,
        opts: &ForwardOptions,
        out: &mut [f32],
    ) {
        out.copy_from_slice(&self.step(state, u, dt, opts));
    }

    /// Advance the state without materializing an output row — the
    /// prefill fast path (a classifier head projection per swallowed
    /// token would be pure waste). Default: `step` with the output
    /// discarded; models override to skip the output entirely.
    fn advance(&self, state: &mut SessionState, u: &[f32], dt: Option<f32>, opts: &ForwardOptions) {
        let _ = self.step(state, u, dt, opts);
    }

    /// Advance the state over a whole packed (L, d_input) chunk of
    /// regular-Δt observations without materializing outputs — the
    /// chunked-prefill fast path. Must be observably equivalent to `l`
    /// calls to [`SequenceModel::advance`] (the default does exactly
    /// that); models override to run their batched/tiled kernels instead
    /// — S5 runs the fused cache-blocked tile pipeline, resuming from the
    /// live stream state, with bit-for-bit identical results.
    fn advance_batch(
        &self,
        state: &mut SessionState,
        tokens: &[f32],
        l: usize,
        opts: &ForwardOptions,
    ) {
        let d = self.spec().d_input;
        assert_eq!(tokens.len(), l * d);
        for k in 0..l {
            self.advance(state, &tokens[k * d..(k + 1) * d], None, opts);
        }
    }
}

// ---------------------------------------------------------------------------
// Sessions
// ---------------------------------------------------------------------------

/// Stateful prefill-then-step streaming over any [`SequenceModel`]
/// (what a streaming deployment holds per connection).
///
/// `prefill` feeds a whole prefix; `step` feeds one observation at a time.
/// Both drive the same per-step kernels as the offline scans, so a session
/// replayed over a sequence agrees with the batched forward.
pub struct Session {
    model: Arc<dyn SequenceModel>,
    opts: ForwardOptions,
    state: SessionState,
    steps: usize,
}

impl Session {
    /// Open a session over `model`. Panics if the model cannot stream.
    pub fn new(model: Arc<dyn SequenceModel>, opts: ForwardOptions) -> Session {
        assert!(model.spec().streamable, "model {:?} cannot stream", model.spec().name);
        let state = model.make_state(&opts);
        Session { model, opts, state, steps: 0 }
    }

    /// Feed one observation; returns the current output row.
    pub fn step(&mut self, u: &[f32]) -> Vec<f32> {
        self.steps += 1;
        self.model.step(&mut self.state, u, None, &self.opts)
    }

    /// Feed one observation, writing the output row into `out`
    /// (`d_output`). The allocation-free form of [`step`](Session::step):
    /// for models that override [`SequenceModel::step_into`] (S5 does), a
    /// warmed-up session performs zero heap allocations per step.
    pub fn step_into(&mut self, u: &[f32], out: &mut [f32]) {
        self.steps += 1;
        self.model.step_into(&mut self.state, u, None, &self.opts, out);
    }

    /// Feed one irregularly-sampled observation (Δt multiplier `dt`).
    pub fn step_dt(&mut self, u: &[f32], dt: f32) -> Vec<f32> {
        self.steps += 1;
        self.model.step(&mut self.state, u, Some(dt), &self.opts)
    }

    /// Feed a whole (L × d_input) prefix through the streaming path;
    /// returns the output row after the last token. Only the final token
    /// materializes an output; the swallowed prefix goes through the
    /// chunked [`SequenceModel::advance_batch`] fast path (for S5, the
    /// fused tile pipeline — same results as per-token stepping,
    /// bit-for-bit, at batch-kernel throughput).
    pub fn prefill(&mut self, tokens: &[f32], l: usize) -> Vec<f32> {
        let d = self.model.spec().d_input;
        let tokens = Batch::single(tokens, l, d);
        if l > 1 {
            self.model.advance_batch(
                &mut self.state,
                &tokens.data()[..(l - 1) * d],
                l - 1,
                &self.opts,
            );
            self.steps += l - 1;
        }
        self.step(&tokens.data()[(l - 1) * d..l * d])
    }

    /// Restart the stream (new sequence, same connection).
    pub fn reset(&mut self) {
        self.model.reset_state(&mut self.state);
        self.steps = 0;
    }

    /// Observations consumed since the last reset.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// The model this session streams over.
    pub fn spec(&self) -> ModelSpec {
        self.model.spec()
    }

    fn into_state(self) -> SessionState {
        self.state
    }
}

/// A pool of reusable streaming sessions over one shared model — the
/// native server checks one out per connection and returns it on close,
/// so steady-state streaming allocates no per-connection state.
///
/// Robustness properties:
///
/// * **Never poisoned.** The free list's mutex recovers from a panicking
///   holder ([`Mutex::into_inner`] on poison) — a client thread that dies
///   mid-release must not take the whole pool down with it.
/// * **No stale state.** [`SessionPool::release`] resets the state before
///   pooling it, so a session whose stream panicked mid-step can be
///   returned and the *next* `acquire` still starts from a zeroed state —
///   pinned by `tests/server_robustness.rs` (f32 and bf16 rows).
/// * **Idle-TTL eviction.** With [`SessionPool::with_ttl`], returned
///   states that nobody reclaims within `ttl` are dropped (buffers
///   freed) on the next pool operation or an explicit
///   [`SessionPool::evict_idle`] — so a burst of connections does not pin
///   peak-size state memory forever.
pub struct SessionPool {
    model: Arc<dyn SequenceModel>,
    opts: ForwardOptions,
    /// idle states, oldest first, each stamped with its return time
    free: Mutex<Vec<(SessionState, Instant)>>,
    ttl: Option<Duration>,
}

impl SessionPool {
    pub fn new(model: Arc<dyn SequenceModel>, opts: ForwardOptions) -> SessionPool {
        SessionPool { model, opts, free: Mutex::new(Vec::new()), ttl: None }
    }

    /// A pool that drops idle states `ttl` after they were returned.
    pub fn with_ttl(
        model: Arc<dyn SequenceModel>,
        opts: ForwardOptions,
        ttl: Duration,
    ) -> SessionPool {
        SessionPool { model, opts, free: Mutex::new(Vec::new()), ttl: Some(ttl) }
    }

    /// Lock the free list, recovering from a poisoned mutex: the list is
    /// a plain `Vec` of owned states, valid at every await-free point, so
    /// a panicking holder cannot leave it mid-invariant.
    fn lock_free(&self) -> std::sync::MutexGuard<'_, Vec<(SessionState, Instant)>> {
        self.free.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Drop entries older than `ttl` from the locked list. Entries are in
    /// return order, so expired ones form a prefix.
    fn evict_locked(free: &mut Vec<(SessionState, Instant)>, ttl: Duration) -> usize {
        let keep_from =
            free.iter().position(|(_, returned)| returned.elapsed() < ttl).unwrap_or(free.len());
        free.drain(..keep_from).count()
    }

    /// Check out a session (reusing a returned state when available).
    pub fn acquire(&self) -> Session {
        let state = {
            let mut free = self.lock_free();
            if let Some(ttl) = self.ttl {
                Self::evict_locked(&mut free, ttl);
            }
            free.pop()
        };
        match state {
            Some((state, _returned)) => {
                Session { model: self.model.clone(), opts: self.opts.clone(), state, steps: 0 }
            }
            None => Session::new(self.model.clone(), self.opts.clone()),
        }
    }

    /// Return a session's state to the pool (reset for the next caller).
    ///
    /// Panics if `session` was opened over a different model instance —
    /// pooling a foreign state would hand a wrong-dimensioned state to the
    /// next `acquire`, deferring the failure to an opaque out-of-bounds
    /// panic mid-stream. A session opened with different
    /// [`ForwardOptions`] (e.g. another timescale) is dropped instead of
    /// pooled: its state may bake those options in (S5 discretization),
    /// and recycling it would silently stream with the wrong dynamics.
    pub fn release(&self, mut session: Session) {
        // compare data addresses only (not vtable parts, which are not
        // stable across codegen units)
        let same_model = std::ptr::eq(
            Arc::as_ptr(&self.model) as *const u8,
            Arc::as_ptr(&session.model) as *const u8,
        );
        assert!(same_model, "session released to a pool over a different model");
        if session.opts.timescale != self.opts.timescale {
            return; // foreign-opts state: drop rather than poison the pool
        }
        // Reset *before* pooling: even if the session's stream panicked
        // mid-step, the next acquire starts from a zeroed state.
        session.reset();
        let mut free = self.lock_free();
        if let Some(ttl) = self.ttl {
            Self::evict_locked(&mut free, ttl);
        }
        free.push((session.into_state(), Instant::now()));
    }

    /// Drop idle states older than the pool's TTL (no-op for a pool built
    /// without one). Returns how many states were evicted.
    pub fn evict_idle(&self) -> usize {
        match self.ttl {
            Some(ttl) => Self::evict_locked(&mut self.lock_free(), ttl),
            None => 0,
        }
    }

    /// Number of idle pooled states.
    pub fn idle(&self) -> usize {
        self.lock_free().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::ssm::rnn::GruCell;
    use crate::ssm::s5::{S5Config, S5Model};

    #[test]
    fn batch_view_checks_dims() {
        let data = vec![0.0f32; 2 * 3 * 4];
        let b = Batch::new(&data, 2, 3, 4);
        assert_eq!((b.batch(), b.len(), b.width()), (2, 3, 4));
        assert_eq!(b.seq(1).len(), 12);
    }

    #[test]
    #[should_panic(expected = "batch data length")]
    fn batch_view_rejects_bad_dims() {
        let data = vec![0.0f32; 7];
        let _ = Batch::new(&data, 2, 3, 4);
    }

    #[test]
    fn options_builder_resolves_backend() {
        let o = ForwardOptions::new();
        assert_eq!(o.timescale, 1.0);
        assert_eq!(o.scan_backend().threads(), 1);
        assert_eq!(o.scan_layout(), ScanLayout::Planar);
        let o = o.with_threads(3).with_timescale(0.5);
        assert_eq!(o.scan_backend().threads(), 3);
        assert_eq!(o.scan_layout(), ScanLayout::Planar, "planar is the default strategy");
        assert_eq!(o.timescale, 0.5);
        assert!(ForwardOptions::new().with_threads(0).scan_backend().threads() >= 1);
        let o = ForwardOptions::new().with_scan(2, ScanLayout::Interleaved);
        assert_eq!(o.scan_layout(), ScanLayout::Interleaved);
        assert_eq!(o.scan_backend().threads(), 2);
        // pooled dispatch is the default; with_exec is the opt-out
        assert!(ForwardOptions::new().with_threads(3).scan_backend().executor().is_pool());
        let o = ForwardOptions::new().with_exec(3, ScanExec::Scoped);
        assert_eq!(o.scan_backend().executor().kind(), "scoped");
        assert_eq!(o.scan_backend().threads(), 3);
        // with_exec composes with a previously pinned layout
        let o = ForwardOptions::new()
            .with_scan(3, ScanLayout::Interleaved)
            .with_exec(3, ScanExec::Scoped);
        assert_eq!(o.scan_layout(), ScanLayout::Interleaved);
        assert_eq!(o.scan_backend().executor().kind(), "scoped");
    }

    /// The tiling/state policy defaults to (fused Auto, f32), the knobs
    /// set it, and re-resolving the backend never resets it.
    #[test]
    fn options_builder_carries_scan_policy() {
        let o = ForwardOptions::new();
        assert_eq!(o.scan_policy().tiling, Tiling::Auto);
        assert!(!o.scan_policy().f64_state);
        assert!(!o.scan_policy().wide, "wide must be opt-in: the default path is bit-for-bit");
        let o = ForwardOptions::new().with_tile(128).with_threads(3);
        assert_eq!(o.scan_policy().tiling, Tiling::Fixed(128), "with_threads reset the tiling");
        assert_eq!(ForwardOptions::new().with_tile(0).scan_policy().tiling, Tiling::Staged);
        let o = ForwardOptions::new()
            .with_tiling(Tiling::Staged)
            .with_f64_state()
            .with_scan(2, ScanLayout::Planar)
            .with_exec(2, ScanExec::Scoped);
        assert_eq!(o.scan_policy().tiling, Tiling::Staged);
        assert!(o.scan_policy().f64_state, "with_scan/with_exec reset f64_state");
        let o = ForwardOptions::new().with_wide().with_threads(4).with_tile(64);
        assert!(o.scan_policy().wide, "with_threads/with_tile reset wide");
        assert!(!o.scan_policy().f64_state);
        // storage dtype: unset defers to the env knob (f32 unless
        // S5_DTYPE says otherwise); an explicit pin wins and survives
        // backend/tiling re-resolution
        assert_eq!(ForwardOptions::new().scan_policy().dtype, None);
        let o = ForwardOptions::new().with_dtype(Dtype::Bf16).with_threads(3).with_tile(64);
        assert_eq!(o.scan_policy().dtype, Some(Dtype::Bf16), "with_threads/with_tile reset it");
        assert_eq!(o.scan_policy().storage_dtype(), Dtype::Bf16);
        let o = ForwardOptions::new().with_dtype(Dtype::F32);
        assert_eq!(o.scan_policy().storage_dtype(), Dtype::F32);
    }

    #[test]
    fn session_pool_reuses_states() {
        let model: Arc<dyn SequenceModel> = Arc::new(GruCell::init(2, 4, &mut Rng::new(1)));
        let pool = SessionPool::new(model, ForwardOptions::new());
        let mut s = pool.acquire();
        let y1 = s.step(&[1.0, -0.5]);
        pool.release(s);
        assert_eq!(pool.idle(), 1);
        // a re-acquired session starts from a reset state
        let mut s2 = pool.acquire();
        assert_eq!(pool.idle(), 0);
        assert_eq!(s2.steps(), 0);
        let y2 = s2.step(&[1.0, -0.5]);
        assert_eq!(y1, y2);
    }

    #[test]
    #[should_panic(expected = "different model")]
    fn release_to_foreign_pool_rejected() {
        let m1: Arc<dyn SequenceModel> = Arc::new(GruCell::init(2, 4, &mut Rng::new(1)));
        let m2: Arc<dyn SequenceModel> = Arc::new(GruCell::init(2, 8, &mut Rng::new(2)));
        let pool = SessionPool::new(m1, ForwardOptions::new());
        let foreign = Session::new(m2, ForwardOptions::new());
        pool.release(foreign); // would poison the pool with a 8-wide state
    }

    #[test]
    #[should_panic(expected = "cannot stream")]
    fn bidirectional_s5_session_rejected() {
        let cfg = S5Config { h: 4, p: 8, j: 1, bidir: true, ..Default::default() };
        let model: Arc<dyn SequenceModel> =
            Arc::new(S5Model::init(2, 3, 1, &cfg, &mut Rng::new(2)));
        let _ = Session::new(model, ForwardOptions::new());
    }
}
