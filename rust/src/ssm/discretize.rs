//! Discretization of diagonal continuous-time SSMs (paper §2.1, eq. 6).
//!
//! For the diagonalized system dx/dt = Λx + B̃u the three classic rules give
//! per-eigenvalue scalar maps; the S5 layer uses ZOH:
//!
//!   ZOH:       Λ̄ = exp(ΛΔ),          B̄ = Λ⁻¹(Λ̄ − I)B̃
//!   Bilinear:  Λ̄ = (1+ΛΔ/2)/(1−ΛΔ/2), B̄ = (1−ΛΔ/2)⁻¹ Δ B̃
//!   Euler:     Λ̄ = 1 + ΛΔ,            B̄ = Δ B̃
//!
//! Since everything is diagonal we return, for each state p, the pair
//! `(lam_bar_p, input_scale_p)` where the discretized drive is
//! `input_scale_p · (B̃u)_p`.

use crate::num::C64;

/// Discretization rule selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Zoh,
    Bilinear,
    Euler,
}

/// Discretize one eigenvalue with timestep `dt`.
///
/// Returns `(lam_bar, input_scale)`.
#[inline]
pub fn discretize_one(lam: C64, dt: f64, method: Method) -> (C64, C64) {
    match method {
        Method::Zoh => {
            let lam_bar = lam.scale(dt).exp();
            // Λ⁻¹(Λ̄ − 1); for |ΛΔ| → 0 this limits to Δ, handled by the
            // series when the eigenvalue is tiny.
            let scale = if lam.abs() < 1e-12 {
                C64::from_re(dt)
            } else {
                (lam_bar - C64::ONE) * lam.inv()
            };
            (lam_bar, scale)
        }
        Method::Bilinear => {
            let half = lam.scale(dt / 2.0);
            let denom_inv = (C64::ONE - half).inv();
            let lam_bar = (C64::ONE + half) * denom_inv;
            (lam_bar, denom_inv.scale(dt))
        }
        Method::Euler => (C64::ONE + lam.scale(dt), C64::from_re(dt)),
    }
}

/// Discretize a diagonal spectrum with per-state timesteps (vector Δ∈ℝᴾ,
/// paper §4.3/D.5). `dts.len()` must be 1 (scalar Δ) or `lam.len()`.
pub fn discretize_diag(
    lam: &[C64],
    dts: &[f64],
    method: Method,
) -> (Vec<C64>, Vec<C64>) {
    assert!(dts.len() == 1 || dts.len() == lam.len());
    let mut lam_bar = Vec::with_capacity(lam.len());
    let mut scale = Vec::with_capacity(lam.len());
    for (p, &l) in lam.iter().enumerate() {
        let dt = dts[if dts.len() == 1 { 0 } else { p }];
        let (lb, sc) = discretize_one(l, dt, method);
        lam_bar.push(lb);
        scale.push(sc);
    }
    (lam_bar, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn zoh_of_zero_eigenvalue_is_integrator() {
        let (lb, sc) = discretize_one(C64::ZERO, 0.25, Method::Zoh);
        assert!((lb - C64::ONE).abs() < 1e-12);
        assert!((sc - C64::from_re(0.25)).abs() < 1e-12);
    }

    #[test]
    fn zoh_is_exact_for_lti_step() {
        // For constant input u, ZOH reproduces the exact solution of
        // dx/dt = λx + u at multiples of Δ.
        let lam = C64::new(-0.7, 1.3);
        let dt = 0.05;
        let (lb, sc) = discretize_one(lam, dt, Method::Zoh);
        let u = C64::from_re(1.0);
        let mut x = C64::ZERO;
        let steps = 40;
        for _ in 0..steps {
            x = lb * x + sc * u;
        }
        // exact: x(t) = (e^{λt} − 1)/λ · u
        let t = dt * steps as f64;
        let exact = (lam.scale(t).exp() - C64::ONE) * lam.inv() * u;
        assert!((x - exact).abs() < 1e-9, "{x:?} vs {exact:?}");
    }

    #[test]
    fn prop_methods_agree_to_first_order() {
        prop::check("discretizations agree as Δ→0", 60, |g| {
            let lam = C64::new(-g.uniform_in(0.1, 2.0), g.uniform_in(-3.0, 3.0));
            let dt = 1e-4;
            let (z, _) = discretize_one(lam, dt, Method::Zoh);
            let (b, _) = discretize_one(lam, dt, Method::Bilinear);
            let (e, _) = discretize_one(lam, dt, Method::Euler);
            prop::close_f64(z.re, b.re, 1e-6)?;
            prop::close_f64(z.im, b.im, 1e-6)?;
            prop::close_f64(z.re, e.re, 1e-6)?;
            prop::close_f64(z.im, e.im, 1e-6)
        });
    }

    #[test]
    fn prop_zoh_stability_preserved() {
        // Re(λ) < 0 ⇒ |Λ̄| < 1: ZOH maps the stable half-plane into the
        // unit disk for any Δ > 0.
        prop::check("zoh stability", 100, |g| {
            let lam = C64::new(-g.uniform_in(1e-3, 5.0), g.uniform_in(-20.0, 20.0));
            let dt = g.uniform_in(1e-4, 1.0);
            let (lb, _) = discretize_one(lam, dt, Method::Zoh);
            prop::ensure_msg(lb.abs() < 1.0, format!("|lam_bar|={}", lb.abs()))
        });
    }

    #[test]
    fn prop_bilinear_stability_preserved() {
        prop::check("bilinear stability", 100, |g| {
            let lam = C64::new(-g.uniform_in(1e-3, 5.0), g.uniform_in(-20.0, 20.0));
            let dt = g.uniform_in(1e-4, 1.0);
            let (lb, _) = discretize_one(lam, dt, Method::Bilinear);
            prop::ensure(lb.abs() < 1.0)
        });
    }

    #[test]
    fn euler_can_be_unstable() {
        // The counterexample motivating ZOH: oscillatory λ with Euler.
        let (lb, _) = discretize_one(C64::new(-0.5, 40.0), 0.1, Method::Euler);
        assert!(lb.abs() > 1.0);
    }

    #[test]
    fn vector_dt_applies_per_state() {
        let lam = vec![C64::new(-1.0, 0.0), C64::new(-1.0, 0.0)];
        let (lb, _) = discretize_diag(&lam, &[0.1, 0.2], Method::Zoh);
        assert!((lb[0].re - (-0.1f64).exp()).abs() < 1e-12);
        assert!((lb[1].re - (-0.2f64).exp()).abs() < 1e-12);
    }
}
