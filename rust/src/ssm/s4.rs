//! S4 / S4D baselines (paper §2.3, Table 4, Appendix C.2).
//!
//! An S4 layer is a bank of H independent SISO SSMs with N-dimensional
//! state, followed by a position-wise mixing layer. We implement the
//! *diagonal* variant (S4D — the stronger baseline the paper benchmarks
//! against) in both of its modes:
//!
//! * **convolution mode** ([`S4DLayer::apply_conv`]): materialize the length-L
//!   kernel k_ℓ = Σ_n C̄_n Λ̄_nᶫ B̄_n (a Vandermonde contraction), then apply
//!   it with the FFT — O(H·L·log L), the offline path of Figure 4a;
//! * **recurrent mode** ([`S4DLayer::apply_recurrent`]): step the diagonal
//!   recurrence — O(H·N) per step, the online-generation path.
//!
//! The relative cost of these against the S5 scan is exactly what paper
//! Table 4 measures; `bench_table4_runtime` regenerates it.

use crate::fft;
use crate::num::{C32, C64};
use crate::rng::Rng;
use crate::ssm::discretize::{discretize_one, Method};
use crate::ssm::hippo;
use crate::ssm::scan;

/// One SISO diagonal SSM (state size N) of the S4D bank.
#[derive(Clone, Debug)]
pub struct SisoSsm {
    /// Λ (N/2 under conjugate symmetry).
    pub lambda: Vec<C64>,
    /// B (N/2), input column.
    pub b: Vec<C64>,
    /// C (N/2), output row.
    pub c: Vec<C64>,
    /// Feedthrough scalar.
    pub d: f32,
    /// log Δ (scalar per SSM, as in S4).
    pub log_dt: f32,
}

/// The S4D layer: H independent SISO SSMs + dense mixing layer (H × H).
#[derive(Clone, Debug)]
pub struct S4DLayer {
    pub ssms: Vec<SisoSsm>,
    /// Position-wise mixing layer applied after the nonlinearity (§2.3).
    pub mix_w: Vec<f32>,
    pub h: usize,
    pub n2: usize,
}

impl S4DLayer {
    /// HiPPO-N initialized bank with per-SSM timescales.
    pub fn init(h: usize, n: usize, rng: &mut Rng) -> S4DLayer {
        let (lam_full, _, _) = hippo::block_diag_hippo_init(n, 1, true);
        let n2 = lam_full.len();
        let ssms = (0..h)
            .map(|_| {
                let scale = (0.5 / n as f64).sqrt();
                SisoSsm {
                    lambda: lam_full.clone(),
                    b: (0..n2).map(|_| C64::new(rng.normal(), rng.normal()).scale(scale)).collect(),
                    c: (0..n2).map(|_| C64::new(rng.normal(), rng.normal()).scale(scale)).collect(),
                    d: rng.normal() as f32,
                    log_dt: rng.uniform_in((1e-3f64).ln(), (1e-1f64).ln()) as f32,
                }
            })
            .collect();
        S4DLayer {
            ssms,
            mix_w: (0..h * h).map(|_| (rng.normal() / (h as f64).sqrt()) as f32).collect(),
            h,
            n2,
        }
    }

    /// Materialize the length-L convolution kernel of one SISO SSM:
    /// k_ℓ = 2·Re(Σ_n C_n Λ̄_nᶫ B̄_n)  (Vandermonde contraction).
    pub fn kernel(&self, ssm: &SisoSsm, l: usize) -> Vec<f64> {
        let dt = (ssm.log_dt as f64).exp();
        let mut k = vec![0.0f64; l];
        for n in 0..self.n2 {
            let (lam_bar, f) = discretize_one(ssm.lambda[n], dt, Method::Zoh);
            let cb = ssm.c[n] * f * ssm.b[n];
            let mut pow = C64::ONE;
            for item in k.iter_mut().take(l) {
                *item += 2.0 * (cb * pow).re;
                pow = pow * lam_bar;
            }
        }
        k
    }

    /// Convolution (offline) mode: SSM outputs before mixing, (L × H).
    pub fn apply_conv_ssm(&self, u: &[f32], l: usize) -> Vec<f32> {
        let h = self.h;
        assert_eq!(u.len(), l * h);
        let mut y = vec![0.0f32; l * h];
        for (ch, ssm) in self.ssms.iter().enumerate() {
            let k = self.kernel(ssm, l);
            let sig: Vec<f64> = (0..l).map(|t| u[t * h + ch] as f64).collect();
            let conv = fft::conv_real(&k, &sig, l);
            for t in 0..l {
                y[t * h + ch] = conv[t] as f32 + ssm.d * u[t * h + ch];
            }
        }
        y
    }

    /// Recurrent (online) mode: identical math via per-step stepping.
    pub fn apply_recurrent_ssm(&self, u: &[f32], l: usize) -> Vec<f32> {
        let h = self.h;
        let mut y = vec![0.0f32; l * h];
        for (ch, ssm) in self.ssms.iter().enumerate() {
            let dt = (ssm.log_dt as f64).exp();
            let n2 = self.n2;
            let mut lam_bar = Vec::with_capacity(n2);
            let mut b_bar = Vec::with_capacity(n2);
            for n in 0..n2 {
                let (lb, f) = discretize_one(ssm.lambda[n], dt, Method::Zoh);
                lam_bar.push(lb.to_c32());
                b_bar.push((f * ssm.b[n]).to_c32());
            }
            let c32: Vec<C32> = ssm.c.iter().map(|z| z.to_c32()).collect();
            let mut state = vec![C32::ZERO; n2];
            for t in 0..l {
                let ut = u[t * h + ch];
                let mut acc = 0.0f32;
                for n in 0..n2 {
                    state[n] = lam_bar[n] * state[n] + b_bar[n].scale(ut);
                    let cv = c32[n];
                    acc += cv.re * state[n].re - cv.im * state[n].im;
                }
                y[t * h + ch] = 2.0 * acc + ssm.d * ut;
            }
        }
        y
    }

    /// Scan (offline) mode for the *bank* of SISO SSMs — what §2.3 notes
    /// would cost O(H·N·L) work: the block-diagonal system has effective
    /// state H·N, versus S5's P.
    pub fn apply_scan_ssm(&self, u: &[f32], l: usize, threads: usize) -> Vec<f32> {
        let h = self.h;
        let n2 = self.n2;
        let p = h * n2; // block-diagonal effective state
        let mut a = vec![C32::ZERO; p];
        let mut drive = vec![C32::ZERO; l * p];
        let mut c_all = vec![C32::ZERO; p];
        for (ch, ssm) in self.ssms.iter().enumerate() {
            let dt = (ssm.log_dt as f64).exp();
            for n in 0..n2 {
                let (lb, f) = discretize_one(ssm.lambda[n], dt, Method::Zoh);
                let idx = ch * n2 + n;
                a[idx] = lb.to_c32();
                c_all[idx] = ssm.c[n].to_c32();
                let bb = (f * ssm.b[n]).to_c32();
                for t in 0..l {
                    drive[t * p + idx] = bb.scale(u[t * h + ch]);
                }
            }
        }
        let xs = if threads <= 1 {
            scan::scan_sequential_ti(&a, &drive, l, p)
        } else {
            scan::scan_parallel_ti(&a, &drive, l, p, threads)
        };
        let mut y = vec![0.0f32; l * h];
        for t in 0..l {
            for ch in 0..h {
                let mut acc = 0.0f32;
                for n in 0..n2 {
                    let idx = ch * n2 + n;
                    let cv = c_all[idx];
                    let x = xs[t * p + idx];
                    acc += cv.re * x.re - cv.im * x.im;
                }
                y[t * h + ch] = 2.0 * acc + self.ssms[ch].d * u[t * h + ch];
            }
        }
        y
    }

    /// GELU + position-wise mixing layer (the part S5 folds into its MIMO C).
    pub fn mix(&self, y: &[f32], l: usize) -> Vec<f32> {
        let h = self.h;
        let mut out = vec![0.0f32; l * h];
        let mut g = vec![0.0f32; h];
        for t in 0..l {
            for c in 0..h {
                g[c] = super::s5::gelu(y[t * h + c]);
            }
            for r in 0..h {
                let mut acc = 0.0f32;
                for c in 0..h {
                    acc += self.mix_w[r * h + c] * g[c];
                }
                out[t * h + r] = acc;
            }
        }
        out
    }

    /// Full layer, convolution mode (the paper's offline S4 path).
    pub fn apply_conv(&self, u: &[f32], l: usize) -> Vec<f32> {
        let y = self.apply_conv_ssm(u, l);
        self.mix(&y, l)
    }

    /// Full layer, recurrent mode.
    pub fn apply_recurrent(&self, u: &[f32], l: usize) -> Vec<f32> {
        let y = self.apply_recurrent_ssm(u, l);
        self.mix(&y, l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    fn mk(h: usize, n: usize, seed: u64) -> S4DLayer {
        S4DLayer::init(h, n, &mut Rng::new(seed))
    }

    #[test]
    fn conv_matches_recurrent() {
        // The two S4 modes are two implementations of the same LTI system.
        let layer = mk(4, 8, 1);
        let l = 64;
        let mut rng = Rng::new(2);
        let u = rng.normal_vec_f32(l * 4);
        let yc = layer.apply_conv_ssm(&u, l);
        let yr = layer.apply_recurrent_ssm(&u, l);
        prop::close_slice_f32(&yc, &yr, 2e-3).unwrap();
    }

    #[test]
    fn scan_mode_matches_recurrent() {
        let layer = mk(3, 8, 3);
        let l = 50;
        let mut rng = Rng::new(4);
        let u = rng.normal_vec_f32(l * 3);
        let ys = layer.apply_scan_ssm(&u, l, 4);
        let yr = layer.apply_recurrent_ssm(&u, l);
        prop::close_slice_f32(&ys, &yr, 2e-3).unwrap();
    }

    #[test]
    fn prop_all_three_modes_agree() {
        prop::check("s4d conv ≡ recurrent ≡ scan", 10, |g| {
            let h = 1 + g.below(4);
            let n = 2 * (1 + g.below(4));
            let l = 8 + g.below(100);
            let layer = mk(h, n, g.next_u64());
            let u: Vec<f32> = (0..l * h).map(|_| g.normal() as f32).collect();
            let yc = layer.apply_conv_ssm(&u, l);
            let yr = layer.apply_recurrent_ssm(&u, l);
            let ys = layer.apply_scan_ssm(&u, l, 2);
            prop::close_slice_f32(&yc, &yr, 5e-3)?;
            prop::close_slice_f32(&ys, &yr, 5e-3)
        });
    }

    #[test]
    fn kernel_decays_for_stable_spectrum() {
        let layer = mk(1, 16, 5);
        let k = layer.kernel(&layer.ssms[0], 4096);
        let head: f64 = k[..64].iter().map(|v| v.abs()).sum();
        let tail: f64 = k[4032..].iter().map(|v| v.abs()).sum();
        assert!(tail < head, "kernel must decay: head={head} tail={tail}");
    }

    #[test]
    fn impulse_response_equals_kernel() {
        let layer = mk(1, 8, 6);
        let l = 32;
        let mut u = vec![0.0f32; l];
        u[0] = 1.0;
        let y = layer.apply_conv_ssm(&u, l);
        let k = layer.kernel(&layer.ssms[0], l);
        for t in 0..l {
            let want = k[t] as f32 + if t == 0 { layer.ssms[0].d } else { 0.0 };
            assert!((y[t] - want).abs() < 1e-3, "t={t}: {} vs {want}", y[t]);
        }
    }

    #[test]
    fn mixing_layer_shapes() {
        let layer = mk(5, 4, 7);
        let l = 10;
        let mut rng = Rng::new(8);
        let u = rng.normal_vec_f32(l * 5);
        let out = layer.apply_conv(&u, l);
        assert_eq!(out.len(), l * 5);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
