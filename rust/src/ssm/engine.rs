//! The batched native inference engine: workspace-reusing (B, L, H)
//! forwards across the SSM stack.
//!
//! The paper gets batching for free from `jax.vmap`; the native Rust path
//! historically scanned one sequence at a time with fresh `Vec`s per call.
//! This module supplies the pieces that thread a batch dimension and a
//! pluggable scan strategy through every layer of the native stack:
//!
//! * [`EngineWorkspace`] — owns every per-forward scratch buffer
//!   (activations, pre-norm, SSM drive/states, time-varying multipliers).
//!   Buffers grow to the high-water mark of the shapes seen and are then
//!   reused, so steady-state inference performs **zero O(B·L··) heap
//!   allocation**; the only transient allocations left are the
//!   O(threads·P) chunk summaries inside the parallel scan (see ROADMAP
//!   open items for pooling those too).
//! * A per-layer **time-invariant discretization cache** (`TiDisc`,
//!   keyed by layer slot and validated against (Λ, log Δ, timescale)) so
//!   repeated same-timescale batches skip the exp-heavy re-discretization
//!   entirely.
//!
//! The object-safe "packed batch in, rows out" interface the server and
//! benches drive models through is
//! [`SequenceModel`](crate::ssm::api::SequenceModel) (it superseded the
//! old `BatchForward` trait).
//!
//! Parallelism enters at two levels, both steered by the same
//! [`ScanBackend`](crate::ssm::scan::ScanBackend) object: dense stages
//! (encoder, norm, B̃u, C̃x, gate) shard *sequences* across workers via
//! `par_zip`; the scan stage goes through `scan_batch_*`, which shards
//! across B sequences × in-sequence chunks. A batch of 1 degrades to the
//! classic single-sequence path with in-sequence chunking only.

use crate::num::{C32, C64};
use crate::ssm::discretize::{discretize_diag, Method};

/// Resolve a thread-count knob: `0` auto-detects the machine's parallelism
/// (`std::thread::available_parallelism`), any other value is taken as-is.
pub fn auto_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Shard `n` strided items across up to `threads` workers: calls
/// `f(item_index, &src[i·ss..], &mut dst[i·ds..])` for every item, with
/// disjoint mutable destination slices. `src` and `dst` may be longer than
/// `n` items (workspace buffers keep their high-water capacity); the tail
/// is ignored. With `threads ≤ 1` or `n == 1` the loop runs inline —
/// no spawn overhead on the single-sequence path.
pub(crate) fn par_zip<T, U, F>(
    threads: usize,
    src: &[T],
    ss: usize,
    dst: &mut [U],
    ds: usize,
    n: usize,
    f: F,
) where
    T: Sync,
    U: Send,
    F: Fn(usize, &[T], &mut [U]) + Sync,
{
    if n == 0 {
        return;
    }
    let src = &src[..n * ss];
    let dst = &mut dst[..n * ds];
    let shards = threads.max(1).min(n);
    if shards <= 1 {
        for (i, (sc, dc)) in src.chunks(ss).zip(dst.chunks_mut(ds)).enumerate() {
            f(i, sc, dc);
        }
        return;
    }
    let per = n.div_ceil(shards);
    std::thread::scope(|s| {
        for (ci, (sc, dc)) in src
            .chunks(per * ss)
            .zip(dst.chunks_mut(per * ds))
            .enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (j, (ss_, ds_)) in sc.chunks(ss).zip(dc.chunks_mut(ds)).enumerate() {
                    f(ci * per + j, ss_, ds_);
                }
            });
        }
    });
}

/// Like [`par_zip`] but with two destination buffers per item (used by the
/// time-varying path, which writes both the per-step multipliers and the
/// scaled drive).
#[allow(clippy::too_many_arguments)]
pub(crate) fn par_zip2<T, U, V, F>(
    threads: usize,
    src: &[T],
    ss: usize,
    d1: &mut [U],
    s1: usize,
    d2: &mut [V],
    s2: usize,
    n: usize,
    f: F,
) where
    T: Sync,
    U: Send,
    V: Send,
    F: Fn(usize, &[T], &mut [U], &mut [V]) + Sync,
{
    if n == 0 {
        return;
    }
    let src = &src[..n * ss];
    let d1 = &mut d1[..n * s1];
    let d2 = &mut d2[..n * s2];
    let shards = threads.max(1).min(n);
    if shards <= 1 {
        for (i, ((sc, c1), c2)) in src
            .chunks(ss)
            .zip(d1.chunks_mut(s1))
            .zip(d2.chunks_mut(s2))
            .enumerate()
        {
            f(i, sc, c1, c2);
        }
        return;
    }
    let per = n.div_ceil(shards);
    std::thread::scope(|s| {
        for (ci, ((sc, c1), c2)) in src
            .chunks(per * ss)
            .zip(d1.chunks_mut(per * s1))
            .zip(d2.chunks_mut(per * s2))
            .enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (j, ((ss_, d1_), d2_)) in sc
                    .chunks(ss)
                    .zip(c1.chunks_mut(s1))
                    .zip(c2.chunks_mut(s2))
                    .enumerate()
                {
                    f(ci * per + j, ss_, d1_, d2_);
                }
            });
        }
    });
}

/// Grow (never shrink) a buffer to at least `n` elements.
pub(crate) fn grow<T: Clone + Default>(buf: &mut Vec<T>, n: usize) {
    if buf.len() < n {
        buf.resize(n, T::default());
    }
}

/// All per-forward scratch buffers of the native engine, reused across
/// calls. One workspace belongs to one driving thread (the server worker,
/// a bench loop); the parallel *inside* a forward comes from the scan
/// backend, not from sharing workspaces.
///
/// Buffer shapes (row-major, `B` = batch, `L` = sequence length, `H` =
/// model width, `P2` = conjugate-symmetric state size):
///
/// | field    | shape      | role                                   |
/// |----------|------------|----------------------------------------|
/// | `x`      | (B, L, H)  | running activations (layer in/out)     |
/// | `v`      | (B, L, H)  | pre-norm output / gate scratch         |
/// | `y`      | (B, L, H)  | SSM output before activation           |
/// | `bu`     | (B, L, P2) | scan drive, overwritten with states    |
/// | `bu_rev` | (B, L, P2) | reversed drive for bidirectional layers|
/// | `a_tv`   | (B, L, P2) | time-varying multipliers (§6.3 path)   |
/// | `disc`   | per layer  | cached TI discretization (`TiDisc`)    |
#[derive(Default)]
pub struct EngineWorkspace {
    pub(crate) x: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) y: Vec<f32>,
    pub(crate) bu: Vec<C32>,
    pub(crate) bu_rev: Vec<C32>,
    pub(crate) a_tv: Vec<C32>,
    pub(crate) disc: Vec<Vec<TiDisc>>,
}

impl EngineWorkspace {
    pub fn new() -> EngineWorkspace {
        EngineWorkspace::default()
    }

    /// Current heap footprint of the owned buffers, in bytes (capacity,
    /// not length — what the workspace actually holds onto).
    pub fn capacity_bytes(&self) -> usize {
        self.x.capacity() * 4
            + self.v.capacity() * 4
            + self.y.capacity() * 4
            + (self.bu.capacity() + self.bu_rev.capacity() + self.a_tv.capacity()) * 8
            + self
                .disc
                .iter()
                .flat_map(|slot| slot.iter())
                .map(TiDisc::capacity_bytes)
                .sum::<usize>()
    }
}

/// One cached time-invariant ZOH discretization: Λ̄ and the input scaling
/// for a given (Λ, log Δ, timescale) triple, in both the C32 form the hot
/// loops consume and the C64 form the bidirectional reversed drive needs.
///
/// Cache entries live in the [`EngineWorkspace`]: each layer slot holds up
/// to [`TI_DISC_SLOT_CAP`] entries in most-recently-used order, so
/// interleaved timescales (the zero-shot-resampling serving mix) all stay
/// cached instead of thrashing one entry. Entries are validated by *value*
/// against the layer's Λ and log Δ — a workspace reused across models (or
/// a layer whose parameters changed) recomputes instead of serving stale
/// multipliers.
pub(crate) struct TiDisc {
    timescale: f64,
    lambda: Vec<C64>,
    log_dt: Vec<f32>,
    /// Λ̄ as C32 (scan multipliers).
    pub(crate) a32: Vec<C32>,
    /// Input scaling as C32 (forward drive).
    pub(crate) f32s: Vec<C32>,
    /// Input scaling as C64 (reversed drive of bidirectional layers,
    /// which folds the scaling in before the C32 conversion).
    pub(crate) f64s: Vec<C64>,
}

/// Max cached discretizations per layer slot (distinct timescales in
/// flight); beyond this the least-recently-used entry is evicted.
pub(crate) const TI_DISC_SLOT_CAP: usize = 4;

impl TiDisc {
    fn matches(&self, lambda: &[C64], log_dt: &[f32], timescale: f64) -> bool {
        self.timescale == timescale
            && self.lambda.as_slice() == lambda
            && self.log_dt.as_slice() == log_dt
    }

    fn capacity_bytes(&self) -> usize {
        self.lambda.capacity() * 16
            + self.log_dt.capacity() * 4
            + (self.a32.capacity() + self.f32s.capacity()) * 8
            + self.f64s.capacity() * 16
    }
}

/// Fetch (or recompute) the cached TI discretization for layer `slot`.
///
/// Entries are keyed by value on `(lambda, log_dt, timescale)`: an O(P)
/// comparison against the cached key replaces the O(P) `exp`/complex-`exp`
/// work on every hit. The slot keeps its entries in MRU order and caps
/// them at [`TI_DISC_SLOT_CAP`].
pub(crate) fn ti_disc<'a>(
    cache: &'a mut Vec<Vec<TiDisc>>,
    slot: usize,
    lambda: &[C64],
    log_dt: &[f32],
    timescale: f64,
) -> &'a TiDisc {
    while cache.len() <= slot {
        cache.push(Vec::new());
    }
    let entries = &mut cache[slot];
    if let Some(i) = entries.iter().position(|e| e.matches(lambda, log_dt, timescale)) {
        entries[..=i].rotate_right(1); // move hit to MRU position
        return &entries[0];
    }
    let dt: Vec<f64> = log_dt.iter().map(|&ld| (ld as f64).exp() * timescale).collect();
    let (lam_bar, scale) = discretize_diag(lambda, &dt, Method::Zoh);
    let fresh = TiDisc {
        timescale,
        lambda: lambda.to_vec(),
        log_dt: log_dt.to_vec(),
        a32: lam_bar.iter().map(|z| z.to_c32()).collect(),
        f32s: scale.iter().map(|z| z.to_c32()).collect(),
        f64s: scale,
    };
    entries.insert(0, fresh);
    entries.truncate(TI_DISC_SLOT_CAP);
    &entries[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_threads_resolves() {
        assert!(auto_threads(0) >= 1);
        assert_eq!(auto_threads(3), 3);
        assert_eq!(auto_threads(1), 1);
    }

    #[test]
    fn par_zip_matches_serial() {
        for &threads in &[1usize, 2, 3, 8] {
            for &n in &[0usize, 1, 2, 5, 16, 17] {
                let ss = 3;
                let ds = 2;
                let src: Vec<f32> = (0..n * ss).map(|i| i as f32).collect();
                let mut dst = vec![0.0f32; n * ds];
                par_zip(threads, &src, ss, &mut dst, ds, n, |i, s, d| {
                    d[0] = s.iter().sum::<f32>();
                    d[1] = i as f32;
                });
                for i in 0..n {
                    let want: f32 = (0..ss).map(|j| (i * ss + j) as f32).sum();
                    assert_eq!(dst[i * ds], want, "threads={threads} n={n} i={i}");
                    assert_eq!(dst[i * ds + 1], i as f32);
                }
            }
        }
    }

    #[test]
    fn par_zip_tolerates_oversized_buffers() {
        // workspace buffers keep high-water capacity; par_zip must slice
        let src: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let mut dst = vec![-1.0f32; 50];
        par_zip(2, &src, 2, &mut dst, 1, 4, |_, s, d| d[0] = s[0] + s[1]);
        assert_eq!(&dst[..4], &[1.0, 5.0, 9.0, 13.0]);
        assert_eq!(dst[4], -1.0, "tail untouched");
    }

    #[test]
    fn par_zip2_matches_serial() {
        let n = 7;
        let src: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut d1 = vec![0.0f32; n];
        let mut d2 = vec![0.0f32; 2 * n];
        par_zip2(3, &src, 1, &mut d1, 1, &mut d2, 2, n, |i, s, a, b| {
            a[0] = s[0] * 2.0;
            b[0] = i as f32;
            b[1] = s[0];
        });
        for i in 0..n {
            assert_eq!(d1[i], 2.0 * i as f32);
            assert_eq!(d2[2 * i], i as f32);
            assert_eq!(d2[2 * i + 1], i as f32);
        }
    }

    #[test]
    fn workspace_starts_empty_and_reports_bytes() {
        let mut ws = EngineWorkspace::new();
        assert_eq!(ws.capacity_bytes(), 0);
        grow(&mut ws.x, 128);
        assert!(ws.capacity_bytes() >= 128 * 4);
    }

    /// The discretization cache must hit on identical keys and recompute
    /// on any changed component (timescale, Λ, log Δ) — stale multipliers
    /// would silently corrupt every scan downstream.
    #[test]
    fn ti_disc_cache_hits_and_invalidates() {
        let lambda = vec![C64::new(-0.5, 1.0), C64::new(-0.1, -2.0)];
        let log_dt = vec![-3.0f32, -2.0];
        let mut cache = Vec::new();
        let a_first = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0).a32.clone();
        // hit: same key, same values, same allocation
        let ptr = cache[0][0].a32.as_ptr();
        let again = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0);
        assert_eq!(again.a32, a_first);
        assert_eq!(again.a32.as_ptr(), ptr);
        // a different timescale gets its own (different) entry
        let rescaled = ti_disc(&mut cache, 0, &lambda, &log_dt, 2.0).a32.clone();
        assert_ne!(rescaled, a_first);
        // Λ change misses even at the same slot + timescale
        let lambda2 = vec![C64::new(-0.9, 0.3), C64::new(-0.2, 0.7)];
        let other = ti_disc(&mut cache, 0, &lambda2, &log_dt, 2.0).a32.clone();
        assert_ne!(other, rescaled);
        // and flipping back reproduces the original values
        let back = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0);
        assert_eq!(back.a32, a_first);
    }

    /// Interleaved timescales (the zero-shot-resampling serving mix) must
    /// all stay resident: alternating between two timescales hits cached
    /// entries (stable allocations), and the slot is bounded.
    #[test]
    fn ti_disc_cache_holds_interleaved_timescales() {
        let lambda = vec![C64::new(-0.4, 0.8)];
        let log_dt = vec![-2.5f32];
        let mut cache = Vec::new();
        let _ = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0);
        let _ = ti_disc(&mut cache, 0, &lambda, &log_dt, 2.0);
        assert_eq!(cache[0].len(), 2);
        let p1 = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0).a32.as_ptr();
        let p2 = ti_disc(&mut cache, 0, &lambda, &log_dt, 2.0).a32.as_ptr();
        // alternating again reuses the same allocations (cache hits)
        assert_eq!(ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0).a32.as_ptr(), p1);
        assert_eq!(ti_disc(&mut cache, 0, &lambda, &log_dt, 2.0).a32.as_ptr(), p2);
        assert_eq!(cache[0].len(), 2);
        // the slot never grows past its cap
        for i in 0..10 {
            let _ = ti_disc(&mut cache, 0, &lambda, &log_dt, 3.0 + i as f64);
        }
        assert!(cache[0].len() <= TI_DISC_SLOT_CAP);
    }
}
