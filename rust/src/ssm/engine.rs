//! The batched native inference engine: workspace-reusing (B, L, H)
//! forwards across the SSM stack.
//!
//! The paper gets batching for free from `jax.vmap`; the native Rust path
//! historically scanned one sequence at a time with fresh `Vec`s per call.
//! This module supplies the pieces that thread a batch dimension and a
//! pluggable scan strategy through every layer of the native stack:
//!
//! * [`EngineWorkspace`] — owns every per-forward scratch buffer
//!   (activations, pre-norm, SSM drive/states in both scan layouts,
//!   time-varying multipliers, and the pooled O(threads·P) chunk
//!   summaries of the parallel scan via
//!   [`ScanScratch`](crate::ssm::scan::ScanScratch)). Buffers grow to the
//!   high-water mark of the shapes seen and are then reused, so
//!   steady-state inference performs **zero heap allocation on the data
//!   buffers** — including inside the parallel scan (previously an open
//!   ROADMAP item). (Pooled dispatch itself costs O(shards) small boxed
//!   closures per parallel stage — see
//!   [`crate::runtime::pool::WorkerPool::run_tasks`] — which replaced the
//!   far costlier per-stage thread spawn/join.)
//! * A per-layer **time-invariant discretization cache** (`TiDisc`,
//!   keyed by layer slot and validated against (Λ, log Δ, timescale)) so
//!   repeated same-timescale batches skip the exp-heavy re-discretization
//!   entirely — in both interleaved and planar forms, plus the base-Δt
//!   vector the irregular-sampling (TV) path previously rebuilt per batch.
//!
//! The object-safe "packed batch in, rows out" interface the server and
//! benches drive models through is
//! [`SequenceModel`](crate::ssm::api::SequenceModel) (it superseded the
//! old `BatchForward` trait).
//!
//! Parallelism enters at two levels, both steered by the same
//! [`ScanBackend`](crate::ssm::scan::ScanBackend) object: dense stages
//! (encoder, norm, B̃u, C̃x, gate) shard *sequences* across workers via
//! `par_zip`; the scan stage goes through `scan_batch_*`, which shards
//! across B sequences × in-sequence chunks. A batch of 1 degrades to the
//! classic single-sequence path with in-sequence chunking only.
//!
//! Since the worker-pool refactor, neither level spawns: every stage
//! dispatches its shard closures on the backend's
//! [`Executor`](crate::runtime::pool::Executor) — the process-wide
//! persistent pool for the default pooled backends, scoped threads or
//! inline execution for the opt-outs — with bit-for-bit identical
//! results either way (the shard decomposition depends only on the
//! thread budget).

use crate::num::{C32, C64};
use crate::runtime::pool::Executor;
use crate::ssm::discretize::{discretize_diag, Method};
use crate::ssm::dtype::{Bf16, Dtype};
use crate::ssm::scan::ScanScratch;

/// Resolve a thread-count knob: `0` auto-detects the machine's parallelism
/// (`std::thread::available_parallelism`), any other value is taken as-is.
pub fn auto_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        requested
    }
}

// s5:hot-begin — the par_zip shard dispatchers run once per layer per
// forward on the serving path; they must never allocate (lint L3, and the
// alloc_guard steady-state tests in tests/alloc_guard.rs).

/// Shard `n` strided items across up to `threads` workers: calls
/// `f(item_index, &src[i·ss..], &mut dst[i·ds..])` for every item, with
/// disjoint mutable destination slices. `src` and `dst` may be longer than
/// `n` items (workspace buffers keep their high-water capacity); the tail
/// is ignored. With `threads ≤ 1` or `n == 1` the loop runs inline — no
/// dispatch overhead on the single-sequence path; otherwise the shards
/// run on `exec` (the backend's persistent pool on the serving path —
/// results are bit-for-bit executor-invariant since the item
/// decomposition is fixed by `threads`, never by the executor).
#[allow(clippy::too_many_arguments)]
pub(crate) fn par_zip<T, U, F>(
    exec: Executor<'_>,
    threads: usize,
    src: &[T],
    ss: usize,
    dst: &mut [U],
    ds: usize,
    n: usize,
    f: F,
) where
    T: Sync,
    U: Send,
    F: Fn(usize, &[T], &mut [U]) + Sync,
{
    if n == 0 {
        return;
    }
    let src = &src[..n * ss];
    let dst = &mut dst[..n * ds];
    let shards = threads.max(1).min(n);
    if shards <= 1 {
        for (i, (sc, dc)) in src.chunks(ss).zip(dst.chunks_mut(ds)).enumerate() {
            f(i, sc, dc);
        }
        return;
    }
    let per = n.div_ceil(shards);
    let fr = &f;
    exec.run_tasks(
        src.chunks(per * ss)
            .zip(dst.chunks_mut(per * ds))
            .enumerate()
            .map(|(ci, (sc, dc))| {
                move || {
                    for (j, (ss_, ds_)) in sc.chunks(ss).zip(dc.chunks_mut(ds)).enumerate() {
                        fr(ci * per + j, ss_, ds_);
                    }
                }
            }),
    );
}

/// Like [`par_zip`] but with four destination buffers per item — the
/// planar time-varying path writes the multiplier re/im planes and scales
/// the drive re/im planes in one pass over the Δt rows.
#[allow(clippy::too_many_arguments)]
pub(crate) fn par_zip4<T, U1, U2, U3, U4, F>(
    exec: Executor<'_>,
    threads: usize,
    src: &[T],
    ss: usize,
    d1: &mut [U1],
    s1: usize,
    d2: &mut [U2],
    s2: usize,
    d3: &mut [U3],
    s3: usize,
    d4: &mut [U4],
    s4: usize,
    n: usize,
    f: F,
) where
    T: Sync,
    U1: Send,
    U2: Send,
    U3: Send,
    U4: Send,
    F: Fn(usize, &[T], &mut [U1], &mut [U2], &mut [U3], &mut [U4]) + Sync,
{
    if n == 0 {
        return;
    }
    let src = &src[..n * ss];
    let d1 = &mut d1[..n * s1];
    let d2 = &mut d2[..n * s2];
    let d3 = &mut d3[..n * s3];
    let d4 = &mut d4[..n * s4];
    let shards = threads.max(1).min(n);
    if shards <= 1 {
        for (i, ((((sc, c1), c2), c3), c4)) in src
            .chunks(ss)
            .zip(d1.chunks_mut(s1))
            .zip(d2.chunks_mut(s2))
            .zip(d3.chunks_mut(s3))
            .zip(d4.chunks_mut(s4))
            .enumerate()
        {
            f(i, sc, c1, c2, c3, c4);
        }
        return;
    }
    let per = n.div_ceil(shards);
    let fr = &f;
    exec.run_tasks(
        src.chunks(per * ss)
            .zip(d1.chunks_mut(per * s1))
            .zip(d2.chunks_mut(per * s2))
            .zip(d3.chunks_mut(per * s3))
            .zip(d4.chunks_mut(per * s4))
            .enumerate()
            .map(|(ci, ((((sc, c1), c2), c3), c4))| {
                move || {
                    for (j, ((((ss_, e1), e2), e3), e4)) in sc
                        .chunks(ss)
                        .zip(c1.chunks_mut(s1))
                        .zip(c2.chunks_mut(s2))
                        .zip(c3.chunks_mut(s3))
                        .zip(c4.chunks_mut(s4))
                        .enumerate()
                    {
                        fr(ci * per + j, ss_, e1, e2, e3, e4);
                    }
                }
            }),
    );
}

/// Like [`par_zip`] but with two destination buffers per item (used by the
/// time-varying path, which writes both the per-step multipliers and the
/// scaled drive).
#[allow(clippy::too_many_arguments)]
pub(crate) fn par_zip2<T, U, V, F>(
    exec: Executor<'_>,
    threads: usize,
    src: &[T],
    ss: usize,
    d1: &mut [U],
    s1: usize,
    d2: &mut [V],
    s2: usize,
    n: usize,
    f: F,
) where
    T: Sync,
    U: Send,
    V: Send,
    F: Fn(usize, &[T], &mut [U], &mut [V]) + Sync,
{
    if n == 0 {
        return;
    }
    let src = &src[..n * ss];
    let d1 = &mut d1[..n * s1];
    let d2 = &mut d2[..n * s2];
    let shards = threads.max(1).min(n);
    if shards <= 1 {
        for (i, ((sc, c1), c2)) in src
            .chunks(ss)
            .zip(d1.chunks_mut(s1))
            .zip(d2.chunks_mut(s2))
            .enumerate()
        {
            f(i, sc, c1, c2);
        }
        return;
    }
    let per = n.div_ceil(shards);
    let fr = &f;
    exec.run_tasks(
        src.chunks(per * ss)
            .zip(d1.chunks_mut(per * s1))
            .zip(d2.chunks_mut(per * s2))
            .enumerate()
            .map(|(ci, ((sc, c1), c2))| {
                move || {
                    for (j, ((ss_, d1_), d2_)) in sc
                        .chunks(ss)
                        .zip(c1.chunks_mut(s1))
                        .zip(c2.chunks_mut(s2))
                        .enumerate()
                    {
                        fr(ci * per + j, ss_, d1_, d2_);
                    }
                }
            }),
    );
}

// s5:hot-end

/// Grow (never shrink) a buffer to at least `n` elements.
pub(crate) fn grow<T: Clone + Default>(buf: &mut Vec<T>, n: usize) {
    if buf.len() < n {
        buf.resize(n, T::default());
    }
}

/// How the S5 forward materializes and scans the per-layer drive.
///
/// The default is the **fused cache-blocked** path: each (sequence,
/// direction) runs as an independent pipeline of L-tiles — drive → Δt
/// scale → tile-resumable scan → projection per tile — so the drive
/// working set stays O(tile·P2) per pipeline and the workspace's
/// [`SsmBuffers`] hold O(B·T·P2) total instead of full (B, L, P2) planes.
/// [`Tiling::Staged`] selects the untiled reference pipeline (separate
/// full-sequence drive/scale/scan/projection passes), retained as the
/// oracle the fused path is validated against.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Tiling {
    /// Fused path with a tile auto-sized to the L2 budget (see
    /// [`auto_tile_l`]). The `S5_TILE_L` environment variable overrides
    /// the auto size (`S5_TILE_L=0` selects the staged path) — the CI
    /// tile sweep drives the equivalence matrix through it.
    #[default]
    Auto,
    /// Fused path with an explicit tile length (`Fixed(0)` degrades to
    /// [`Tiling::Staged`]).
    Fixed(usize),
    /// The untiled staged reference pipeline (full-plane materialization;
    /// the pre-tiling behavior). The interleaved oracle layout always
    /// runs staged regardless of this knob.
    Staged,
}

impl Tiling {
    /// Resolve to a concrete tile length (`None` = staged). `Auto`
    /// consults `S5_TILE_L` first, then sizes to the L2 budget.
    pub(crate) fn resolve(self, p2: usize, h: usize, tv: bool) -> Option<usize> {
        match self {
            Tiling::Staged => None,
            Tiling::Fixed(0) => None,
            Tiling::Fixed(t) => Some(t),
            Tiling::Auto => match tile_env_override() {
                Some(0) => None,
                Some(t) => Some(t),
                None => Some(auto_tile_l(p2, h, tv)),
            },
        }
    }
}

/// The `S5_TILE_L` override, parsed once per process — `resolve` runs per
/// layer per forward, and `std::env::var` takes the env lock and
/// allocates, which has no place on the serving hot path. A set-but-
/// unparsable value warns once and falls back to the auto size (a sweep
/// that silently tested nothing would be worse than the noise); the
/// strict parse lives in [`crate::runtime::envcfg`].
fn tile_env_override() -> Option<usize> {
    static TILE_ENV: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    crate::runtime::envcfg::env_usize_once(
        &TILE_ENV,
        "S5_TILE_L",
        "a tile length (rows; 0 = staged)",
    )
}

/// Fallback per-pipeline cache budget: roughly half a typical per-core
/// L2 slice, leaving room for the layer parameters the drive/projection
/// loops stream. Used when the calibration probe can't produce a sane
/// measurement; the live budget is [`tile_target_bytes`].
pub const TILE_TARGET_BYTES: usize = 256 * 1024;

/// Bounds on the calibrated budget: even a tiny-L2 part gets a tile big
/// enough to amortize the per-tile fixed costs, and a huge-L3 part must
/// not size tiles past the point where the (64, 8192)-row clamp of
/// [`auto_tile_l`] stops binding the shapes the tests pin.
const TILE_BUDGET_MIN_BYTES: usize = 128 * 1024;
const TILE_BUDGET_MAX_BYTES: usize = 4 * 1024 * 1024;

/// The measured per-pipeline cache budget, calibrated once per process.
///
/// Resolution order: a strict `S5_CACHE_KB` override (the *effective
/// cache size* in KiB; the budget is half of it, mirroring the probe
/// rule), else a one-shot timing probe ([`probe_effective_cache_bytes`]),
/// else [`TILE_TARGET_BYTES`]. Clamped to [128 KiB, 4 MiB]. The result
/// feeds both [`auto_tile_l`] (`Tiling::Auto`) and the fused path's
/// in-tile chunk split (`ScanPolicy::wide` widens the tile to one budget
/// per chunk worker).
///
/// [`crate::runtime::pool::global_pool`] forces this calibration before
/// its workers spin up, so the probe times a quiet process.
pub fn tile_target_bytes() -> usize {
    static BUDGET: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *BUDGET.get_or_init(|| {
        static CACHE_KB: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
        let cache_bytes = crate::runtime::envcfg::env_usize_once(
            &CACHE_KB,
            "S5_CACHE_KB",
            "an effective cache size in KiB",
        )
        .map(|kb| kb.saturating_mul(1024))
        .unwrap_or_else(probe_effective_cache_bytes);
        (cache_bytes / 2).clamp(TILE_BUDGET_MIN_BYTES, TILE_BUDGET_MAX_BYTES)
    })
}

/// One-shot effective-cache probe: dependent-load (pointer-chase) timing
/// sweep over power-of-two working sets from 64 KiB to 8 MiB.
///
/// Each working set is a cyclic single-cycle permutation of cache lines
/// (Sattolo's algorithm over one u32 index per 64-byte line), chased for
/// a fixed number of steps so every step is one serialized cache-line
/// load — the access pattern a hardware stride prefetcher cannot hide,
/// which keeps the latency knees sharp where a plain strided traversal
/// would flatten them. The effective cache size is the largest working
/// set whose per-step latency stays within 4× of the smallest set's
/// (L1/L2-resident) latency — i.e. everything cheaper than the
/// L3/memory cliff. Runs in a few tens of milliseconds, once per
/// process. Returns `2 × TILE_TARGET_BYTES` (≡ the historical 256 KiB
/// budget) if the timings are degenerate (e.g. a coarse clock).
fn probe_effective_cache_bytes() -> usize {
    use std::time::Instant;
    const LINE_ELEMS: usize = 16; // one 64-byte line of u32 indices
    const SIZES: [usize; 8] = [
        64 << 10,
        128 << 10,
        256 << 10,
        512 << 10,
        1 << 20,
        2 << 20,
        4 << 20,
        8 << 20,
    ];
    const STEPS: usize = 1 << 16;

    // The chase buffer doubles as the working set: one index per line.
    let max_lines = SIZES[SIZES.len() - 1] / 64;
    let mut next = vec![0u32; max_lines * LINE_ELEMS];
    let mut perm: Vec<u32> = Vec::with_capacity(max_lines);
    let mut ns_per_step = [0.0f64; SIZES.len()];

    for (s, &bytes) in SIZES.iter().enumerate() {
        let lines = bytes / 64;
        // Sattolo shuffle of the identity → a single-cycle permutation,
        // seeded deterministically (an LCG, not the crate Rng, to keep
        // this module free of test-only deps).
        perm.clear();
        perm.extend(0..lines as u32);
        let mut seed = 0x9E3779B97F4A7C15u64 ^ bytes as u64;
        for i in (1..lines).rev() {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let j = ((seed >> 33) as usize) % i;
            perm.swap(i, j);
        }
        for i in 0..lines {
            next[i * LINE_ELEMS] = perm[i];
        }
        // Warm the set, then time the chase.
        let mut idx = 0u32;
        for _ in 0..lines {
            idx = next[idx as usize * LINE_ELEMS];
        }
        let start = Instant::now();
        for _ in 0..STEPS {
            idx = next[idx as usize * LINE_ELEMS];
        }
        let elapsed = start.elapsed();
        // The chase result feeds the timing decision, so the loop cannot
        // be optimized away even without a black_box.
        if idx as usize >= lines {
            return 2 * TILE_TARGET_BYTES;
        }
        ns_per_step[s] = elapsed.as_nanos() as f64 / STEPS as f64;
    }

    let base = ns_per_step[0].min(ns_per_step[1]);
    if !(base.is_finite() && base > 0.0) {
        return 2 * TILE_TARGET_BYTES;
    }
    let mut effective = SIZES[0];
    for (s, &bytes) in SIZES.iter().enumerate() {
        if ns_per_step[s] <= 4.0 * base {
            effective = bytes;
        } else {
            break;
        }
    }
    effective
}

/// Auto-size the fused path's L-tile so one pipeline's per-tile working
/// set — the re/im drive planes (plus TV multiplier planes under
/// irregular sampling) and the touched input/output rows — fits the
/// calibrated [`tile_target_bytes`] budget. Clamped to [64, 8192] rows so
/// degenerate widths neither thrash (tiny tiles) nor defeat the blocking.
pub fn auto_tile_l(p2: usize, h: usize, tv: bool) -> usize {
    let planes = if tv { 4 } else { 2 };
    let bytes_per_row = 4 * (planes * p2 + 2 * h);
    (tile_target_bytes() / bytes_per_row.max(1)).clamp(64, 8192)
}

/// Engine-level execution policy that rides alongside the
/// [`ScanBackend`](crate::ssm::scan::ScanBackend): where the backend
/// picks the scan *strategy* (sequential/parallel, layout, executor),
/// the policy picks how the forward is *blocked* ([`Tiling`]) and what
/// precision the scan state carries.
///
/// Plumbed from [`ForwardOptions`](crate::ssm::api::ForwardOptions)
/// (`with_tile` / `with_tiling` / `with_f64_state` / `with_wide`); the
/// positional layer/model entry points use the default (fused
/// auto-tiled, f32, sequential in-tile).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScanPolicy {
    /// Forward blocking: fused cache-blocked tiles (default) or the
    /// staged full-plane reference pipeline.
    pub tiling: Tiling,
    /// Carry the scan state in f64 across the sequence (long-L drift
    /// studies — an open ROADMAP item). Planar layout only; the state
    /// rows are still emitted as f32. With [`Tiling::Staged`] the
    /// sequence runs as a single tile of the fused pipeline.
    pub f64_state: bool,
    /// Let the fused pipeline go wide *inside* a tile when there are
    /// fewer (sequence × direction) units than workers: the drive,
    /// Δt-scale and projection rows split across the idle workers
    /// (bit-exact — rows are independent), and the tile scan runs the
    /// seeded chunked-parallel resume kernels
    /// ([`scan_resume_ti_planar_par_inplace`](crate::ssm::scan::scan_resume_ti_planar_par_inplace)).
    /// The tile itself widens to one [`tile_target_bytes`] budget per
    /// chunk worker, so each chunk keeps the cache locality a lone
    /// pipeline would have had.
    ///
    /// **Off by default** because the chunked scan reassociates the carry
    /// propagation: the default fused forward stays bit-for-bit equal to
    /// the staged/sequential oracles, while the wide path is
    /// tolerance-pinned (≤ 1e-4 relative; executor-invariant and
    /// deterministic for a fixed thread budget). Ignored by the f64-state
    /// path, whose tile-invariance contract requires a continuous carry.
    pub wide: bool,
    /// Storage dtype of the planar drive planes (the storage/compute
    /// split — see the crate-level "Precision model" docs and
    /// [`Dtype`]). `None` (the default) defers to the `S5_DTYPE`
    /// environment knob, then f32. Scan state, chunk summaries and all
    /// accumulation stay f32 regardless; `f64_state` takes precedence
    /// (its tile-invariance contract needs full-precision planes) and
    /// the interleaved oracle layout is f32-only.
    pub dtype: Option<Dtype>,
}

impl ScanPolicy {
    /// Resolve the effective storage dtype: an explicit
    /// [`with_dtype`](crate::ssm::api::ForwardOptions::with_dtype) choice
    /// wins, else the strictly-parsed `S5_DTYPE` environment knob
    /// (`f32`/`bf16`, warn-once on anything else), else [`Dtype::F32`].
    pub fn storage_dtype(&self) -> Dtype {
        self.dtype.unwrap_or_else(dtype_env_override)
    }
}

/// The `S5_DTYPE` override, parsed once per process — same rationale and
/// strictness contract as [`tile_env_override`]: a set-but-unrecognized
/// value warns once and serves the f32 default rather than silently
/// running a different A/B arm than the sweep asked for.
fn dtype_env_override() -> Dtype {
    static DTYPE_ENV: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    match crate::runtime::envcfg::env_choice_once(&DTYPE_ENV, "S5_DTYPE", &["f32", "bf16"]) {
        Some(1) => Dtype::Bf16,
        _ => Dtype::F32,
    }
}

/// Scan-facing scratch of the engine: drive/state buffers in both layouts
/// plus the pooled chunk summaries of the parallel scan. Grouped so the S5
/// forward path can borrow all of it with one `&mut` while the activation
/// buffers (`x`/`v`/`y`) of the enclosing [`EngineWorkspace`] stay
/// independently borrowable.
///
/// Shapes (`B` = batch, `L` = sequence length, `P2` = conjugate-symmetric
/// state size); only the family matching the backend's
/// [`ScanLayout`](crate::ssm::scan::ScanLayout) is ever grown:
///
/// Shapes under the **staged** reference pipeline (`U` = B·n_dir units,
/// `T` = tile length under the default **fused** cache-blocked path —
/// fused forwards reuse the same planar fields at the far smaller
/// O(U·T·P2) footprint and never touch the full-plane shapes):
///
/// | field                    | staged     | fused      | role                        |
/// |--------------------------|------------|------------|-----------------------------|
/// | `bu`                     | (B, L, P2) | —          | interleaved drive → states  |
/// | `bu_rev`                 | (B, L, P2) | —          | interleaved reversed drive  |
/// | `a_tv`                   | (B, L, P2) | —          | interleaved TV multipliers  |
/// | `bu_re`/`bu_im`          | (B, L, P2) | (U, T, P2) | planar drive → states       |
/// | `bu_re16`/`bu_im16`      | —          | (U, T, P2) | planar drive, bf16 storage  |
/// | `bu_rev_re`/`bu_rev_im`  | (B, L, P2) | —          | planar reversed drive       |
/// | `a_tv_re`/`a_tv_im`      | (B, L, P2) | (U, T, P2) | planar TV multipliers       |
/// | `dts_rev`                | (B, L)     | (B, L)     | reversed Δt (bidir TV)      |
/// | `state_re`/`state_im`    | —          | (U, P2)    | fused carry states (f32)    |
/// | `state64_re`/`state64_im`| —          | (U, P2)    | fused carry states (f64)    |
/// | `scan`                   | O(T·P2)    | —          | pooled chunk summaries      |
///
/// On the fused path the high-water footprint is therefore independent
/// of L — it grows only with the tile length and B (the workspace
/// capacity tests pin this).
#[derive(Default)]
pub struct SsmBuffers {
    pub(crate) bu: Vec<C32>,
    pub(crate) bu_rev: Vec<C32>,
    pub(crate) a_tv: Vec<C32>,
    pub(crate) bu_re: Vec<f32>,
    pub(crate) bu_im: Vec<f32>,
    pub(crate) bu_re16: Vec<Bf16>,
    pub(crate) bu_im16: Vec<Bf16>,
    pub(crate) bu_rev_re: Vec<f32>,
    pub(crate) bu_rev_im: Vec<f32>,
    pub(crate) a_tv_re: Vec<f32>,
    pub(crate) a_tv_im: Vec<f32>,
    pub(crate) dts_rev: Vec<f32>,
    pub(crate) state_re: Vec<f32>,
    pub(crate) state_im: Vec<f32>,
    pub(crate) state64_re: Vec<f64>,
    pub(crate) state64_im: Vec<f64>,
    pub(crate) scan: ScanScratch,
}

impl SsmBuffers {
    fn capacity_bytes(&self) -> usize {
        (self.bu.capacity() + self.bu_rev.capacity() + self.a_tv.capacity()) * 8
            + (self.bu_re.capacity()
                + self.bu_im.capacity()
                + self.bu_rev_re.capacity()
                + self.bu_rev_im.capacity()
                + self.a_tv_re.capacity()
                + self.a_tv_im.capacity()
                + self.dts_rev.capacity()
                + self.state_re.capacity()
                + self.state_im.capacity())
                * 4
            + (self.bu_re16.capacity() + self.bu_im16.capacity()) * 2
            + (self.state64_re.capacity() + self.state64_im.capacity()) * 8
            + self.scan.capacity_bytes()
    }
}

/// All per-forward scratch buffers of the native engine, reused across
/// calls. One workspace belongs to one driving thread (the server worker,
/// a bench loop); the parallel *inside* a forward comes from the scan
/// backend, not from sharing workspaces.
///
/// Buffer shapes (row-major, `B` = batch, `L` = sequence length, `H` =
/// model width):
///
/// | field    | shape      | role                                   |
/// |----------|------------|----------------------------------------|
/// | `x`      | (B, L, H)  | running activations (layer in/out)     |
/// | `v`      | (B, L, H)  | pre-norm output / gate scratch         |
/// | `y`      | (B, L, H)  | SSM output before activation           |
/// | `y2`     | (B, L, H)  | backward-direction projection plane of the fused bidirectional path |
/// | `ssm`    | see [`SsmBuffers`] | scan drives + carry states + pooled summaries |
/// | `disc`   | per layer  | cached TI discretization (`TiDisc`)    |
#[derive(Default)]
pub struct EngineWorkspace {
    pub(crate) x: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) y: Vec<f32>,
    pub(crate) y2: Vec<f32>,
    pub(crate) ssm: SsmBuffers,
    pub(crate) disc: Vec<Vec<TiDisc>>,
}

impl EngineWorkspace {
    pub fn new() -> EngineWorkspace {
        EngineWorkspace::default()
    }

    /// Current heap footprint of the owned buffers, in bytes (capacity,
    /// not length — what the workspace actually holds onto). Includes the
    /// pooled parallel-scan chunk summaries, so the steady-state
    /// zero-allocation tests cover them too.
    pub fn capacity_bytes(&self) -> usize {
        self.x.capacity() * 4
            + self.v.capacity() * 4
            + self.y.capacity() * 4
            + self.y2.capacity() * 4
            + self.ssm.capacity_bytes()
            + self
                .disc
                .iter()
                .flat_map(|slot| slot.iter())
                .map(TiDisc::capacity_bytes)
                .sum::<usize>()
    }

    /// Heap footprint of the scan-facing buffers ([`SsmBuffers`]) alone,
    /// in bytes. On the fused cache-blocked path this is the quantity
    /// that must stay **independent of L** — it bounds the drive/state
    /// working set at O(B·T·P2) — while the activation planes (`x`, `v`,
    /// `y`, `y2`) necessarily scale with the batch content.
    pub fn ssm_capacity_bytes(&self) -> usize {
        self.ssm.capacity_bytes()
    }
}

/// One cached time-invariant ZOH discretization: Λ̄ and the input scaling
/// for a given (Λ, log Δ, timescale) triple, in both the C32 form the hot
/// loops consume and the C64 form the bidirectional reversed drive needs.
///
/// Cache entries live in the [`EngineWorkspace`]: each layer slot holds up
/// to [`TI_DISC_SLOT_CAP`] entries in most-recently-used order, so
/// interleaved timescales (the zero-shot-resampling serving mix) all stay
/// cached instead of thrashing one entry. Entries are validated by *value*
/// against the layer's Λ and log Δ — a workspace reused across models (or
/// a layer whose parameters changed) recomputes instead of serving stale
/// multipliers.
pub(crate) struct TiDisc {
    timescale: f64,
    lambda: Vec<C64>,
    log_dt: Vec<f32>,
    /// Λ̄ as C32 (interleaved scan multipliers).
    pub(crate) a32: Vec<C32>,
    /// Input scaling as C32 (interleaved forward drive).
    pub(crate) f32s: Vec<C32>,
    /// Input scaling as C64 (reversed drive of bidirectional layers,
    /// which folds the scaling in before the C32 conversion).
    pub(crate) f64s: Vec<C64>,
    /// Λ̄ as planar re/im planes (planar scan multipliers; identical
    /// values to `a32`, transposed once at discretization time so the hot
    /// path never pays an interleave↔planar transpose).
    pub(crate) a_re: Vec<f32>,
    pub(crate) a_im: Vec<f32>,
    /// Input scaling as planar re/im planes.
    pub(crate) f_re: Vec<f32>,
    pub(crate) f_im: Vec<f32>,
    /// Base per-state Δt (exp(log Δ)·timescale), cached so the
    /// time-varying (irregular-Δt) path stops rebuilding it per batch —
    /// it shares this entry's (Λ, log Δ, timescale) value validation.
    pub(crate) base_dt: Vec<f64>,
}

/// Max cached discretizations per layer slot (distinct timescales in
/// flight); beyond this the least-recently-used entry is evicted.
pub(crate) const TI_DISC_SLOT_CAP: usize = 4;

impl TiDisc {
    fn matches(&self, lambda: &[C64], log_dt: &[f32], timescale: f64) -> bool {
        self.timescale == timescale
            && self.lambda.as_slice() == lambda
            && self.log_dt.as_slice() == log_dt
    }

    fn capacity_bytes(&self) -> usize {
        self.lambda.capacity() * 16
            + self.log_dt.capacity() * 4
            + (self.a32.capacity() + self.f32s.capacity()) * 8
            + self.f64s.capacity() * 16
            + self.base_dt.capacity() * 8
            + (self.a_re.capacity()
                + self.a_im.capacity()
                + self.f_re.capacity()
                + self.f_im.capacity())
                * 4
    }
}

/// Fetch (or recompute) the cached TI discretization for layer `slot`.
///
/// Entries are keyed by value on `(lambda, log_dt, timescale)`: an O(P)
/// comparison against the cached key replaces the O(P) `exp`/complex-`exp`
/// work on every hit. The slot keeps its entries in MRU order and caps
/// them at [`TI_DISC_SLOT_CAP`].
pub(crate) fn ti_disc<'a>(
    cache: &'a mut Vec<Vec<TiDisc>>,
    slot: usize,
    lambda: &[C64],
    log_dt: &[f32],
    timescale: f64,
) -> &'a TiDisc {
    while cache.len() <= slot {
        cache.push(Vec::new());
    }
    let entries = &mut cache[slot];
    if let Some(i) = entries.iter().position(|e| e.matches(lambda, log_dt, timescale)) {
        entries[..=i].rotate_right(1); // move hit to MRU position
        return &entries[0];
    }
    let dt: Vec<f64> = log_dt.iter().map(|&ld| (ld as f64).exp() * timescale).collect();
    let (lam_bar, scale) = discretize_diag(lambda, &dt, Method::Zoh);
    let a32: Vec<C32> = lam_bar.iter().map(|z| z.to_c32()).collect();
    let f32s: Vec<C32> = scale.iter().map(|z| z.to_c32()).collect();
    let fresh = TiDisc {
        timescale,
        lambda: lambda.to_vec(),
        log_dt: log_dt.to_vec(),
        a_re: a32.iter().map(|z| z.re).collect(),
        a_im: a32.iter().map(|z| z.im).collect(),
        f_re: f32s.iter().map(|z| z.re).collect(),
        f_im: f32s.iter().map(|z| z.im).collect(),
        a32,
        f32s,
        f64s: scale,
        base_dt: dt,
    };
    entries.insert(0, fresh);
    entries.truncate(TI_DISC_SLOT_CAP);
    &entries[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_threads_resolves() {
        assert!(auto_threads(0) >= 1);
        assert_eq!(auto_threads(3), 3);
        assert_eq!(auto_threads(1), 1);
    }

    #[test]
    fn par_zip_matches_serial() {
        let pool = crate::runtime::pool::WorkerPool::new(2);
        for exec in [Executor::Inline, Executor::Scoped, Executor::Pool(&pool)] {
            for &threads in &[1usize, 2, 3, 8] {
                for &n in &[0usize, 1, 2, 5, 16, 17] {
                    let ss = 3;
                    let ds = 2;
                    let src: Vec<f32> = (0..n * ss).map(|i| i as f32).collect();
                    let mut dst = vec![0.0f32; n * ds];
                    par_zip(exec, threads, &src, ss, &mut dst, ds, n, |i, s, d| {
                        d[0] = s.iter().sum::<f32>();
                        d[1] = i as f32;
                    });
                    for i in 0..n {
                        let want: f32 = (0..ss).map(|j| (i * ss + j) as f32).sum();
                        assert_eq!(
                            dst[i * ds],
                            want,
                            "exec={} threads={threads} n={n} i={i}",
                            exec.kind()
                        );
                        assert_eq!(dst[i * ds + 1], i as f32);
                    }
                }
            }
        }
    }

    #[test]
    fn par_zip_tolerates_oversized_buffers() {
        // workspace buffers keep high-water capacity; par_zip must slice
        let src: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let mut dst = vec![-1.0f32; 50];
        par_zip(Executor::Scoped, 2, &src, 2, &mut dst, 1, 4, |_, s, d| d[0] = s[0] + s[1]);
        assert_eq!(&dst[..4], &[1.0, 5.0, 9.0, 13.0]);
        assert_eq!(dst[4], -1.0, "tail untouched");
    }

    #[test]
    fn par_zip2_matches_serial() {
        let n = 7;
        let src: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut d1 = vec![0.0f32; n];
        let mut d2 = vec![0.0f32; 2 * n];
        par_zip2(Executor::Scoped, 3, &src, 1, &mut d1, 1, &mut d2, 2, n, |i, s, a, b| {
            a[0] = s[0] * 2.0;
            b[0] = i as f32;
            b[1] = s[0];
        });
        for i in 0..n {
            assert_eq!(d1[i], 2.0 * i as f32);
            assert_eq!(d2[2 * i], i as f32);
            assert_eq!(d2[2 * i + 1], i as f32);
        }
    }

    #[test]
    fn par_zip4_matches_serial() {
        let pool = crate::runtime::pool::WorkerPool::new(2);
        for exec in [Executor::Inline, Executor::Scoped, Executor::Pool(&pool)] {
            for &threads in &[1usize, 3] {
                let n = 7;
                let src: Vec<f32> = (0..n).map(|i| i as f32).collect();
                let mut d1 = vec![0.0f32; n];
                let mut d2 = vec![0.0f32; n];
                let mut d3 = vec![0.0f32; 2 * n];
                let mut d4 = vec![0.0f32; 2 * n];
                par_zip4(
                    exec, threads, &src, 1, &mut d1, 1, &mut d2, 1, &mut d3, 2, &mut d4, 2, n,
                    |i, s, a, b, c, d| {
                        a[0] = s[0] * 2.0;
                        b[0] = s[0] + 1.0;
                        c[0] = i as f32;
                        c[1] = s[0];
                        d[0] = -s[0];
                        d[1] = i as f32 * 10.0;
                    },
                );
                for i in 0..n {
                    assert_eq!(d1[i], 2.0 * i as f32, "exec={} t={threads}", exec.kind());
                    assert_eq!(d2[i], i as f32 + 1.0);
                    assert_eq!(d3[2 * i], i as f32);
                    assert_eq!(d3[2 * i + 1], i as f32);
                    assert_eq!(d4[2 * i], -(i as f32));
                    assert_eq!(d4[2 * i + 1], i as f32 * 10.0);
                }
            }
        }
    }

    #[test]
    fn workspace_starts_empty_and_reports_bytes() {
        let mut ws = EngineWorkspace::new();
        assert_eq!(ws.capacity_bytes(), 0);
        assert_eq!(ws.ssm_capacity_bytes(), 0);
        grow(&mut ws.x, 128);
        assert!(ws.capacity_bytes() >= 128 * 4);
        // activation planes are not scan-facing
        assert_eq!(ws.ssm_capacity_bytes(), 0);
        grow(&mut ws.ssm.state_re, 16);
        assert!(ws.ssm_capacity_bytes() >= 16 * 4);
    }

    /// The auto tile targets the L2 budget: wider states get shorter
    /// tiles, the result is clamped to [64, 8192], and the TV path (two
    /// extra multiplier planes) tiles tighter than the TI path.
    #[test]
    fn auto_tile_tracks_row_width() {
        assert!(auto_tile_l(256, 256, false) >= 64);
        assert!(auto_tile_l(256, 256, false) <= auto_tile_l(64, 64, false));
        assert!(auto_tile_l(256, 256, true) <= auto_tile_l(256, 256, false));
        assert_eq!(auto_tile_l(1 << 20, 1 << 20, false), 64, "clamped below");
        assert_eq!(auto_tile_l(1, 1, false), 8192, "clamped above");
    }

    /// Tiling resolution: Staged and Fixed(0) disable tiling, Fixed(t)
    /// passes through; Auto falls back to the auto size (the `S5_TILE_L`
    /// environment override is exercised by the CI tile sweep, not here —
    /// mutating the process environment would race other tests).
    #[test]
    fn tiling_resolves() {
        assert_eq!(Tiling::Staged.resolve(8, 8, false), None);
        assert_eq!(Tiling::Fixed(0).resolve(8, 8, false), None);
        assert_eq!(Tiling::Fixed(17).resolve(8, 8, false), Some(17));
        if !crate::runtime::envcfg::is_set("S5_TILE_L") {
            assert_eq!(Tiling::Auto.resolve(8, 8, false), Some(auto_tile_l(8, 8, false)));
        }
    }

    /// Storage-dtype resolution: an explicit policy choice wins in both
    /// directions; the built-in default (no choice, `S5_DTYPE` unset) is
    /// f32 storage. The env-knob arm itself is exercised by the CI
    /// `S5_DTYPE=bf16` run, not here — mutating the process environment
    /// would race other tests.
    #[test]
    fn scan_policy_resolves_storage_dtype() {
        let mut p = ScanPolicy::default();
        assert_eq!(p.dtype, None);
        if !crate::runtime::envcfg::is_set("S5_DTYPE") {
            assert_eq!(p.storage_dtype(), Dtype::F32);
        }
        p.dtype = Some(Dtype::Bf16);
        assert_eq!(p.storage_dtype(), Dtype::Bf16);
        p.dtype = Some(Dtype::F32);
        assert_eq!(p.storage_dtype(), Dtype::F32);
    }

    /// The discretization cache must hit on identical keys and recompute
    /// on any changed component (timescale, Λ, log Δ) — stale multipliers
    /// would silently corrupt every scan downstream.
    #[test]
    fn ti_disc_cache_hits_and_invalidates() {
        let lambda = vec![C64::new(-0.5, 1.0), C64::new(-0.1, -2.0)];
        let log_dt = vec![-3.0f32, -2.0];
        let mut cache = Vec::new();
        let a_first = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0).a32.clone();
        // hit: same key, same values, same allocation
        let ptr = cache[0][0].a32.as_ptr();
        let again = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0);
        assert_eq!(again.a32, a_first);
        assert_eq!(again.a32.as_ptr(), ptr);
        // a different timescale gets its own (different) entry
        let rescaled = ti_disc(&mut cache, 0, &lambda, &log_dt, 2.0).a32.clone();
        assert_ne!(rescaled, a_first);
        // Λ change misses even at the same slot + timescale
        let lambda2 = vec![C64::new(-0.9, 0.3), C64::new(-0.2, 0.7)];
        let other = ti_disc(&mut cache, 0, &lambda2, &log_dt, 2.0).a32.clone();
        assert_ne!(other, rescaled);
        // and flipping back reproduces the original values
        let back = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0);
        assert_eq!(back.a32, a_first);
    }

    /// The planar planes of a cached discretization are the exact re/im
    /// transpose of the interleaved form (same `to_c32` rounding), and the
    /// base-Δt vector the TV path consumes is cached with the entry.
    #[test]
    fn ti_disc_planar_planes_match_interleaved_and_cache_base_dt() {
        let lambda = vec![C64::new(-0.5, 1.0), C64::new(-0.1, -2.0)];
        let log_dt = vec![-3.0f32, -2.0];
        let mut cache = Vec::new();
        let d = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.5);
        for (j, z) in d.a32.iter().enumerate() {
            assert_eq!(d.a_re[j], z.re);
            assert_eq!(d.a_im[j], z.im);
        }
        for (j, z) in d.f32s.iter().enumerate() {
            assert_eq!(d.f_re[j], z.re);
            assert_eq!(d.f_im[j], z.im);
        }
        for (j, &ld) in log_dt.iter().enumerate() {
            assert_eq!(d.base_dt[j], (ld as f64).exp() * 1.5);
        }
        // the TV path's repeated-batch recompute is gone: a hit serves the
        // same base_dt allocation
        let ptr = cache[0][0].base_dt.as_ptr();
        assert_eq!(ti_disc(&mut cache, 0, &lambda, &log_dt, 1.5).base_dt.as_ptr(), ptr);
    }

    /// Interleaved timescales (the zero-shot-resampling serving mix) must
    /// all stay resident: alternating between two timescales hits cached
    /// entries (stable allocations), and the slot is bounded.
    #[test]
    fn ti_disc_cache_holds_interleaved_timescales() {
        let lambda = vec![C64::new(-0.4, 0.8)];
        let log_dt = vec![-2.5f32];
        let mut cache = Vec::new();
        let _ = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0);
        let _ = ti_disc(&mut cache, 0, &lambda, &log_dt, 2.0);
        assert_eq!(cache[0].len(), 2);
        let p1 = ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0).a32.as_ptr();
        let p2 = ti_disc(&mut cache, 0, &lambda, &log_dt, 2.0).a32.as_ptr();
        // alternating again reuses the same allocations (cache hits)
        assert_eq!(ti_disc(&mut cache, 0, &lambda, &log_dt, 1.0).a32.as_ptr(), p1);
        assert_eq!(ti_disc(&mut cache, 0, &lambda, &log_dt, 2.0).a32.as_ptr(), p2);
        assert_eq!(cache[0].len(), 2);
        // the slot never grows past its cap
        for i in 0..10 {
            let _ = ti_disc(&mut cache, 0, &lambda, &log_dt, 3.0 + i as f64);
        }
        assert!(cache[0].len() <= TI_DISC_SLOT_CAP);
    }
}
