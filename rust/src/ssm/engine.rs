//! The batched native inference engine: workspace-reusing (B, L, H)
//! forwards across the SSM stack.
//!
//! The paper gets batching for free from `jax.vmap`; the native Rust path
//! historically scanned one sequence at a time with fresh `Vec`s per call.
//! This module supplies the two pieces that thread a batch dimension and a
//! pluggable scan strategy through every layer of the native stack:
//!
//! * [`EngineWorkspace`] — owns every per-forward scratch buffer
//!   (activations, pre-norm, SSM drive/states, time-varying multipliers).
//!   Buffers grow to the high-water mark of the shapes seen and are then
//!   reused, so steady-state inference performs **zero O(B·L··) heap
//!   allocation**; the only transient allocations left are O(layers·P)
//!   discretization scalars and O(threads·P) chunk summaries inside the
//!   parallel scan (see ROADMAP open items for hoisting those too).
//! * [`BatchForward`] — the object-safe "packed batch in, rows out"
//!   interface implemented by the S5 stack (logits per sequence) and the
//!   RNN baselines (final hidden state per sequence), so the server,
//!   benches and tests drive any sequence model uniformly.
//!
//! Parallelism enters at two levels, both steered by the same
//! [`ScanBackend`](crate::ssm::scan::ScanBackend) object: dense stages
//! (encoder, norm, B̃u, C̃x, gate) shard *sequences* across workers via
//! [`par_zip`]; the scan stage goes through `scan_batch_*`, which shards
//! across B sequences × in-sequence chunks. A batch of 1 degrades to the
//! classic single-sequence path with in-sequence chunking only.

use crate::num::C32;
use crate::ssm::s5::S5Model;
use crate::ssm::scan::ScanBackend;

/// Resolve a thread-count knob: `0` auto-detects the machine's parallelism
/// (`std::thread::available_parallelism`), any other value is taken as-is.
pub fn auto_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Shard `n` strided items across up to `threads` workers: calls
/// `f(item_index, &src[i·ss..], &mut dst[i·ds..])` for every item, with
/// disjoint mutable destination slices. `src` and `dst` may be longer than
/// `n` items (workspace buffers keep their high-water capacity); the tail
/// is ignored. With `threads ≤ 1` or `n == 1` the loop runs inline —
/// no spawn overhead on the single-sequence path.
pub(crate) fn par_zip<T, U, F>(
    threads: usize,
    src: &[T],
    ss: usize,
    dst: &mut [U],
    ds: usize,
    n: usize,
    f: F,
) where
    T: Sync,
    U: Send,
    F: Fn(usize, &[T], &mut [U]) + Sync,
{
    if n == 0 {
        return;
    }
    let src = &src[..n * ss];
    let dst = &mut dst[..n * ds];
    let shards = threads.max(1).min(n);
    if shards <= 1 {
        for (i, (sc, dc)) in src.chunks(ss).zip(dst.chunks_mut(ds)).enumerate() {
            f(i, sc, dc);
        }
        return;
    }
    let per = n.div_ceil(shards);
    std::thread::scope(|s| {
        for (ci, (sc, dc)) in src
            .chunks(per * ss)
            .zip(dst.chunks_mut(per * ds))
            .enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (j, (ss_, ds_)) in sc.chunks(ss).zip(dc.chunks_mut(ds)).enumerate() {
                    f(ci * per + j, ss_, ds_);
                }
            });
        }
    });
}

/// Like [`par_zip`] but with two destination buffers per item (used by the
/// time-varying path, which writes both the per-step multipliers and the
/// scaled drive).
#[allow(clippy::too_many_arguments)]
pub(crate) fn par_zip2<T, U, V, F>(
    threads: usize,
    src: &[T],
    ss: usize,
    d1: &mut [U],
    s1: usize,
    d2: &mut [V],
    s2: usize,
    n: usize,
    f: F,
) where
    T: Sync,
    U: Send,
    V: Send,
    F: Fn(usize, &[T], &mut [U], &mut [V]) + Sync,
{
    if n == 0 {
        return;
    }
    let src = &src[..n * ss];
    let d1 = &mut d1[..n * s1];
    let d2 = &mut d2[..n * s2];
    let shards = threads.max(1).min(n);
    if shards <= 1 {
        for (i, ((sc, c1), c2)) in src
            .chunks(ss)
            .zip(d1.chunks_mut(s1))
            .zip(d2.chunks_mut(s2))
            .enumerate()
        {
            f(i, sc, c1, c2);
        }
        return;
    }
    let per = n.div_ceil(shards);
    std::thread::scope(|s| {
        for (ci, ((sc, c1), c2)) in src
            .chunks(per * ss)
            .zip(d1.chunks_mut(per * s1))
            .zip(d2.chunks_mut(per * s2))
            .enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (j, ((ss_, d1_), d2_)) in sc
                    .chunks(ss)
                    .zip(c1.chunks_mut(s1))
                    .zip(c2.chunks_mut(s2))
                    .enumerate()
                {
                    f(ci * per + j, ss_, d1_, d2_);
                }
            });
        }
    });
}

/// Grow (never shrink) a buffer to at least `n` elements.
pub(crate) fn grow<T: Clone + Default>(buf: &mut Vec<T>, n: usize) {
    if buf.len() < n {
        buf.resize(n, T::default());
    }
}

/// All per-forward scratch buffers of the native engine, reused across
/// calls. One workspace belongs to one driving thread (the server worker,
/// a bench loop); the parallel *inside* a forward comes from the scan
/// backend, not from sharing workspaces.
///
/// Buffer shapes (row-major, `B` = batch, `L` = sequence length, `H` =
/// model width, `P2` = conjugate-symmetric state size):
///
/// | field    | shape      | role                                   |
/// |----------|------------|----------------------------------------|
/// | `x`      | (B, L, H)  | running activations (layer in/out)     |
/// | `v`      | (B, L, H)  | pre-norm output / gate scratch         |
/// | `y`      | (B, L, H)  | SSM output before activation           |
/// | `bu`     | (B, L, P2) | scan drive, overwritten with states    |
/// | `bu_rev` | (B, L, P2) | reversed drive for bidirectional layers|
/// | `a_tv`   | (B, L, P2) | time-varying multipliers (§6.3 path)   |
#[derive(Default)]
pub struct EngineWorkspace {
    pub(crate) x: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) y: Vec<f32>,
    pub(crate) bu: Vec<C32>,
    pub(crate) bu_rev: Vec<C32>,
    pub(crate) a_tv: Vec<C32>,
}

impl EngineWorkspace {
    pub fn new() -> EngineWorkspace {
        EngineWorkspace::default()
    }

    /// Current heap footprint of the owned buffers, in bytes (capacity,
    /// not length — what the workspace actually holds onto).
    pub fn capacity_bytes(&self) -> usize {
        self.x.capacity() * 4
            + self.v.capacity() * 4
            + self.y.capacity() * 4
            + (self.bu.capacity() + self.bu_rev.capacity() + self.a_tv.capacity()) * 8
    }
}

/// Object-safe batched forward: consume a packed row-major (B, L, d_input)
/// buffer, produce one `d_output` row per sequence.
///
/// Implementors: [`S5Model`] (logits), the RNN baselines in
/// [`crate::ssm::rnn`] (final hidden state). The native inference server
/// and the throughput benches drive models exclusively through this.
pub trait BatchForward: Send + Sync {
    /// Input feature width per step.
    fn d_input(&self) -> usize;
    /// Output row width per sequence.
    fn d_output(&self) -> usize;
    /// Forward a packed batch; `out` must hold `batch · d_output()` floats.
    #[allow(clippy::too_many_arguments)]
    fn forward_batch_into(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        backend: &dyn ScanBackend,
        ws: &mut EngineWorkspace,
        out: &mut [f32],
    );
}

impl BatchForward for S5Model {
    fn d_input(&self) -> usize {
        self.d_in
    }

    fn d_output(&self) -> usize {
        self.classes
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_batch_into(
        &self,
        u: &[f32],
        batch: usize,
        l: usize,
        timescale: f64,
        backend: &dyn ScanBackend,
        ws: &mut EngineWorkspace,
        out: &mut [f32],
    ) {
        S5Model::forward_batch_into(self, u, batch, l, timescale, backend, ws, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_threads_resolves() {
        assert!(auto_threads(0) >= 1);
        assert_eq!(auto_threads(3), 3);
        assert_eq!(auto_threads(1), 1);
    }

    #[test]
    fn par_zip_matches_serial() {
        for &threads in &[1usize, 2, 3, 8] {
            for &n in &[0usize, 1, 2, 5, 16, 17] {
                let ss = 3;
                let ds = 2;
                let src: Vec<f32> = (0..n * ss).map(|i| i as f32).collect();
                let mut dst = vec![0.0f32; n * ds];
                par_zip(threads, &src, ss, &mut dst, ds, n, |i, s, d| {
                    d[0] = s.iter().sum::<f32>();
                    d[1] = i as f32;
                });
                for i in 0..n {
                    let want: f32 = (0..ss).map(|j| (i * ss + j) as f32).sum();
                    assert_eq!(dst[i * ds], want, "threads={threads} n={n} i={i}");
                    assert_eq!(dst[i * ds + 1], i as f32);
                }
            }
        }
    }

    #[test]
    fn par_zip_tolerates_oversized_buffers() {
        // workspace buffers keep high-water capacity; par_zip must slice
        let src: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let mut dst = vec![-1.0f32; 50];
        par_zip(2, &src, 2, &mut dst, 1, 4, |_, s, d| d[0] = s[0] + s[1]);
        assert_eq!(&dst[..4], &[1.0, 5.0, 9.0, 13.0]);
        assert_eq!(dst[4], -1.0, "tail untouched");
    }

    #[test]
    fn par_zip2_matches_serial() {
        let n = 7;
        let src: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut d1 = vec![0.0f32; n];
        let mut d2 = vec![0.0f32; 2 * n];
        par_zip2(3, &src, 1, &mut d1, 1, &mut d2, 2, n, |i, s, a, b| {
            a[0] = s[0] * 2.0;
            b[0] = i as f32;
            b[1] = s[0];
        });
        for i in 0..n {
            assert_eq!(d1[i], 2.0 * i as f32);
            assert_eq!(d2[2 * i], i as f32);
            assert_eq!(d2[2 * i + 1], i as f32);
        }
    }

    #[test]
    fn workspace_starts_empty_and_reports_bytes() {
        let mut ws = EngineWorkspace::new();
        assert_eq!(ws.capacity_bytes(), 0);
        grow(&mut ws.x, 128);
        assert!(ws.capacity_bytes() >= 128 * 4);
    }
}
