//! Sequential RNN baselines (paper Tables 3/9).
//!
//! The pendulum experiment compares S5 against per-step recurrent models
//! (CRU, RKN, GRU, ODE-RNN). Their defining cost property is the one the
//! paper's speed column measures: **O(L) sequential steps with dense
//! matrix work per step**, impossible to parallelize across time. This
//! module provides a GRU cell and a CRU-like variant (GRU + per-step
//! matrix "uncertainty" update, mimicking the Kalman-style propagation
//! that makes CRU slow) as honest baselines for the relative-speed
//! reproduction.
//!
//! Both baselines implement the unified inference trait
//! ([`crate::ssm::api::SequenceModel`]): `prefill_into` consumes a packed
//! (B, L, d) [`Batch`] and shards sequences across the scan backend's
//! thread budget, and `make_state`/`step` stream one observation at a
//! time — so the server and the throughput benches drive the recurrent
//! baselines and S5 through the identical harness. The defining O(L)
//! sequential-step property is untouched: only the batch dimension
//! parallelizes, never time.

use crate::rng::Rng;
use crate::runtime::pool::Executor;
use crate::ssm::api::{Batch, ForwardOptions, ModelSpec, SequenceModel, SessionState};
use crate::ssm::engine::{par_zip, EngineWorkspace};

/// A GRU cell: h' = (1−z)∘h + z∘tanh(W_h x + U_h (r∘h)).
#[derive(Clone, Debug)]
pub struct GruCell {
    pub h: usize,
    pub d_in: usize,
    // gates weights: (3H × d_in) input and (3H × H) recurrent, 3H bias
    pub w: Vec<f32>,
    pub u: Vec<f32>,
    pub b: Vec<f32>,
}

impl GruCell {
    pub fn init(d_in: usize, h: usize, rng: &mut Rng) -> GruCell {
        let si = 1.0 / (d_in as f64).sqrt();
        let sh = 1.0 / (h as f64).sqrt();
        GruCell {
            h,
            d_in,
            w: (0..3 * h * d_in).map(|_| (rng.normal() * si) as f32).collect(),
            u: (0..3 * h * h).map(|_| (rng.normal() * sh) as f32).collect(),
            b: vec![0.0; 3 * h],
        }
    }

    /// One step: updates `state` in place given input row `x`.
    pub fn step(&self, state: &mut [f32], x: &[f32], scratch: &mut [f32]) {
        let h = self.h;
        debug_assert_eq!(state.len(), h);
        debug_assert_eq!(x.len(), self.d_in);
        debug_assert_eq!(scratch.len(), 3 * h);
        // gates = W x + U h + b
        for g in 0..3 * h {
            let mut acc = self.b[g];
            for c in 0..self.d_in {
                acc += self.w[g * self.d_in + c] * x[c];
            }
            scratch[g] = acc;
        }
        for g in 0..2 * h {
            let mut acc = 0.0f32;
            for c in 0..h {
                acc += self.u[g * h + c] * state[c];
            }
            scratch[g] += acc;
        }
        let sigmoid = |v: f32| 1.0 / (1.0 + (-v).exp());
        // z, r gates then candidate with reset-gated recurrence
        for i in 0..h {
            let z = sigmoid(scratch[i]);
            let r = sigmoid(scratch[h + i]);
            let mut cand = scratch[2 * h + i];
            for c in 0..h {
                cand += self.u[(2 * h + i) * h + c] * (r * state[c]);
            }
            let cand = cand.tanh();
            state[i] = (1.0 - z) * state[i] + z * cand;
        }
    }

    /// Run one sequence into a caller-provided (L × H) buffer.
    pub fn run_into(&self, xs: &[f32], l: usize, out: &mut [f32]) {
        let h = self.h;
        let mut state = vec![0.0f32; h];
        let mut scratch = vec![0.0f32; 3 * h];
        for k in 0..l {
            self.step(&mut state, &xs[k * self.d_in..(k + 1) * self.d_in], &mut scratch);
            out[k * h..(k + 1) * h].copy_from_slice(&state);
        }
    }

    /// Run the full sequence, returning all hidden states (L × H).
    pub fn run(&self, xs: &[f32], l: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; l * self.h];
        self.run_into(xs, l, &mut out);
        out
    }

    /// Packed-batch run: xs (B, L, d_in) → hidden states (B, L, H),
    /// sequences sharded across `threads` workers (time stays sequential).
    #[deprecated(
        since = "0.3.0",
        note = "positional legacy signature; use `SequenceModel::prefill` \
                with a `Batch` view (see `ssm::api`)"
    )]
    pub fn run_batch(&self, xs: &[f32], batch: usize, l: usize, threads: usize) -> Vec<f32> {
        assert_eq!(xs.len(), batch * l * self.d_in);
        let mut out = vec![0.0f32; batch * l * self.h];
        let (ss, ds) = (l * self.d_in, l * self.h);
        // deprecated positional API: keeps the historical spawn-per-call
        // dispatch (results are executor-invariant; migrated callers get
        // the pooled default through SequenceModel::prefill)
        par_zip(Executor::Scoped, threads, xs, ss, &mut out, ds, batch, |_, xseq, oseq| {
            self.run_into(xseq, l, oseq);
        });
        out
    }
}

/// Streaming state of one GRU decode stream.
pub struct GruStreamState {
    state: Vec<f32>,
    scratch: Vec<f32>,
}

impl SequenceModel for GruCell {
    /// Per-sequence prefill output: the final hidden state (the summary a
    /// classifier head would consume).
    fn spec(&self) -> ModelSpec {
        ModelSpec { name: "gru", d_input: self.d_in, d_output: self.h, streamable: true }
    }

    fn prefill_into(
        &self,
        batch: Batch<'_>,
        opts: &ForwardOptions,
        _ws: &mut EngineWorkspace,
        out: &mut [f32],
    ) {
        assert_eq!(batch.width(), self.d_in, "batch width != model d_input");
        assert_eq!(out.len(), batch.batch() * self.h);
        let (h, l, d_in) = (self.h, batch.len(), self.d_in);
        let be = opts.scan_backend();
        let (threads, ex) = (be.threads(), be.executor());
        // only the final hidden state leaves this function, so step with
        // O(H) state+scratch instead of materializing all L rows
        par_zip(ex, threads, batch.data(), l * d_in, out, h, batch.batch(), |_, xseq, oseq| {
            let mut scratch = vec![0.0f32; 3 * h];
            oseq.fill(0.0);
            for k in 0..l {
                self.step(oseq, &xseq[k * d_in..(k + 1) * d_in], &mut scratch);
            }
        });
    }

    fn make_state(&self, _opts: &ForwardOptions) -> SessionState {
        SessionState::new(GruStreamState {
            state: vec![0.0; self.h],
            scratch: vec![0.0; 3 * self.h],
        })
    }

    fn reset_state(&self, state: &mut SessionState) {
        let st = state
            .downcast_mut::<GruStreamState>()
            .expect("state is not a GruStreamState");
        st.state.iter_mut().for_each(|v| *v = 0.0);
    }

    fn step(
        &self,
        state: &mut SessionState,
        u: &[f32],
        _dt: Option<f32>,
        _opts: &ForwardOptions,
    ) -> Vec<f32> {
        let st = state
            .downcast_mut::<GruStreamState>()
            .expect("state is not a GruStreamState");
        GruCell::step(self, &mut st.state, u, &mut st.scratch);
        st.state.clone()
    }

    /// Prefill fast path: no output-row clone per swallowed token.
    fn advance(
        &self,
        state: &mut SessionState,
        u: &[f32],
        _dt: Option<f32>,
        _opts: &ForwardOptions,
    ) {
        let st = state
            .downcast_mut::<GruStreamState>()
            .expect("state is not a GruStreamState");
        GruCell::step(self, &mut st.state, u, &mut st.scratch);
    }
}

/// CRU-like baseline: a GRU whose step additionally propagates an H×H
/// covariance-style matrix (the Kalman-filter bookkeeping that dominates
/// CRU's runtime: O(H³)-ish per observation in the original, O(H²) here
/// with a diagonal-plus-rank-1 update — deliberately the cheaper end, so
/// the measured S5 speedup is a *lower* bound on the paper's).
#[derive(Clone, Debug)]
pub struct CruLike {
    pub gru: GruCell,
    /// process-noise style mixing matrix (H × H)
    pub a: Vec<f32>,
}

impl CruLike {
    pub fn init(d_in: usize, h: usize, rng: &mut Rng) -> CruLike {
        let sh = 1.0 / (h as f64).sqrt();
        CruLike {
            gru: GruCell::init(d_in, h, rng),
            a: (0..h * h).map(|_| (rng.normal() * sh) as f32).collect(),
        }
    }

    /// Packed-batch run: xs (B, L, d_in), dts (B, L) → outputs (B, L, H),
    /// sequences sharded across `threads` workers.
    #[deprecated(
        since = "0.3.0",
        note = "positional legacy signature; use `SequenceModel::prefill` \
                with a `Batch` view (see `ssm::api`)"
    )]
    pub fn run_batch(
        &self,
        xs: &[f32],
        dts: &[f32],
        batch: usize,
        l: usize,
        threads: usize,
    ) -> Vec<f32> {
        let (h, d_in) = (self.gru.h, self.gru.d_in);
        assert_eq!(xs.len(), batch * l * d_in);
        assert_eq!(dts.len(), batch * l);
        let mut out = vec![0.0f32; batch * l * h];
        // deprecated positional API: historical spawn-per-call dispatch
        // (see GruCell::run_batch)
        let ex = Executor::Scoped;
        par_zip(ex, threads, xs, l * d_in, &mut out, l * h, batch, |i, xseq, oseq| {
            let got = self.run(xseq, &dts[i * l..(i + 1) * l], l);
            oseq.copy_from_slice(&got);
        });
        out
    }

    /// One CRU-like step over an explicit state: GRU step, covariance
    /// propagation, covariance-gated output row written into `out` (H).
    /// This is the single kernel the full-sequence run, the batched
    /// prefill and streaming `step` all share.
    pub fn step(&self, st: &mut CruStreamState, x: &[f32], dt: f32, out: &mut [f32]) {
        let h = self.gru.h;
        self.gru.step(&mut st.state, x, &mut st.scratch);
        // cov ← A cov Aᵀ · dt + I  (the sequential matrix work)
        for i in 0..h {
            for j in 0..h {
                let mut acc = 0.0f32;
                for c in 0..h {
                    acc += self.a[i * h + c] * st.cov[c * h + j];
                }
                st.next_cov[i * h + j] = acc;
            }
        }
        for i in 0..h {
            for j in 0..h {
                let mut acc = 0.0f32;
                for c in 0..h {
                    acc += st.next_cov[i * h + c] * self.a[j * h + c];
                }
                st.cov[i * h + j] = acc * dt * 0.01 + if i == j { 1.0 } else { 0.0 };
            }
        }
        // gate the state by the covariance diagonal (keeps it load-bearing)
        for i in 0..h {
            out[i] = st.state[i] / (1.0 + st.cov[i * h + i].abs().sqrt() * 0.01);
        }
    }

    /// Full-sequence run with per-step Δt modulation of the covariance.
    pub fn run(&self, xs: &[f32], dts: &[f32], l: usize) -> Vec<f32> {
        let h = self.gru.h;
        let mut st = CruStreamState::new(h);
        let mut out = vec![0.0f32; l * h];
        for k in 0..l {
            let row = &mut out[k * h..(k + 1) * h];
            self.step(&mut st, &xs[k * self.gru.d_in..(k + 1) * self.gru.d_in], dts[k], row);
        }
        out
    }
}

/// Streaming state of one CRU-like decode stream: GRU hidden state plus
/// the propagated covariance.
pub struct CruStreamState {
    state: Vec<f32>,
    scratch: Vec<f32>,
    cov: Vec<f32>,
    next_cov: Vec<f32>,
    /// discarded-output scratch for the `advance` prefill fast path
    out: Vec<f32>,
}

impl CruStreamState {
    fn new(h: usize) -> CruStreamState {
        let mut cov = vec![0.0f32; h * h];
        for i in 0..h {
            cov[i * h + i] = 1.0;
        }
        CruStreamState {
            state: vec![0.0; h],
            scratch: vec![0.0; 3 * h],
            cov,
            next_cov: vec![0.0; h * h],
            out: vec![0.0; h],
        }
    }

    fn reset(&mut self) {
        let h = self.state.len();
        self.state.iter_mut().for_each(|v| *v = 0.0);
        self.cov.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..h {
            self.cov[i * h + i] = 1.0;
        }
    }
}

impl SequenceModel for CruLike {
    /// Per-sequence prefill output: the last covariance-gated output row.
    /// Prefill assumes regular sampling (Δt ≡ 1); the irregular path is
    /// streaming `step` with `dt` or [`CruLike::run`].
    fn spec(&self) -> ModelSpec {
        ModelSpec {
            name: "cru-like",
            d_input: self.gru.d_in,
            d_output: self.gru.h,
            streamable: true,
        }
    }

    fn prefill_into(
        &self,
        batch: Batch<'_>,
        opts: &ForwardOptions,
        _ws: &mut EngineWorkspace,
        out: &mut [f32],
    ) {
        let (h, l) = (self.gru.h, batch.len());
        assert_eq!(batch.width(), self.gru.d_in, "batch width != model d_input");
        assert_eq!(out.len(), batch.batch() * h);
        let be = opts.scan_backend();
        let (threads, ex) = (be.threads(), be.executor());
        let d_in = self.gru.d_in;
        // only the final gated row leaves this function: step a state
        // through the shared kernel, writing each row over `oseq`, instead
        // of materializing all L×H rows (and a Δt vector) per call
        par_zip(ex, threads, batch.data(), l * d_in, out, h, batch.batch(), |_, xseq, oseq| {
            let mut st = CruStreamState::new(h);
            for k in 0..l {
                self.step(&mut st, &xseq[k * d_in..(k + 1) * d_in], 1.0, oseq);
            }
        });
    }

    fn make_state(&self, _opts: &ForwardOptions) -> SessionState {
        SessionState::new(CruStreamState::new(self.gru.h))
    }

    fn reset_state(&self, state: &mut SessionState) {
        state
            .downcast_mut::<CruStreamState>()
            .expect("state is not a CruStreamState")
            .reset();
    }

    fn step(
        &self,
        state: &mut SessionState,
        u: &[f32],
        dt: Option<f32>,
        _opts: &ForwardOptions,
    ) -> Vec<f32> {
        let st = state
            .downcast_mut::<CruStreamState>()
            .expect("state is not a CruStreamState");
        let mut out = vec![0.0f32; self.gru.h];
        CruLike::step(self, st, u, dt.unwrap_or(1.0), &mut out);
        out
    }

    /// Prefill fast path: reuse the state-owned output scratch instead of
    /// allocating a discarded row per swallowed token.
    fn advance(
        &self,
        state: &mut SessionState,
        u: &[f32],
        dt: Option<f32>,
        _opts: &ForwardOptions,
    ) {
        let st = state
            .downcast_mut::<CruStreamState>()
            .expect("state is not a CruStreamState");
        let mut out = std::mem::take(&mut st.out);
        CruLike::step(self, st, u, dt.unwrap_or(1.0), &mut out);
        st.out = out;
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy batch wrappers are exercised as oracles
mod tests {
    use super::*;

    #[test]
    fn gru_run_batch_matches_per_sequence() {
        let mut rng = Rng::new(5);
        let cell = GruCell::init(3, 5, &mut rng);
        let (batch, l) = (5usize, 20usize);
        let xs = rng.normal_vec_f32(batch * l * 3);
        for threads in [1usize, 2, 4] {
            let got = cell.run_batch(&xs, batch, l, threads);
            for bi in 0..batch {
                let want = cell.run(&xs[bi * l * 3..(bi + 1) * l * 3], l);
                assert_eq!(&got[bi * l * 5..(bi + 1) * l * 5], &want[..], "t={threads} seq {bi}");
            }
        }
    }

    #[test]
    fn cru_run_batch_matches_per_sequence() {
        let mut rng = Rng::new(6);
        let cru = CruLike::init(2, 4, &mut rng);
        let (batch, l) = (3usize, 15usize);
        let xs = rng.normal_vec_f32(batch * l * 2);
        let dts = rng.uniform_vec_f32(batch * l, 0.5, 2.0);
        let got = cru.run_batch(&xs, &dts, batch, l, 2);
        for bi in 0..batch {
            let want = cru.run(
                &xs[bi * l * 2..(bi + 1) * l * 2],
                &dts[bi * l..(bi + 1) * l],
                l,
            );
            assert_eq!(&got[bi * l * 4..(bi + 1) * l * 4], &want[..], "seq {bi}");
        }
    }

    #[test]
    fn gru_state_bounded() {
        let mut rng = Rng::new(0);
        let cell = GruCell::init(4, 8, &mut rng);
        let xs = rng.normal_vec_f32(100 * 4);
        let hs = cell.run(&xs, 100);
        assert_eq!(hs.len(), 800);
        // tanh candidate + convex gate keeps |h| ≤ 1
        assert!(hs.iter().all(|v| v.abs() <= 1.0 + 1e-5));
    }

    #[test]
    fn gru_is_causal_and_stateful() {
        let mut rng = Rng::new(1);
        let cell = GruCell::init(2, 4, &mut rng);
        let mut xs = rng.normal_vec_f32(50 * 2);
        let h1 = cell.run(&xs, 50);
        // perturb an input a few steps before the end: GRU forget gates can
        // wash a step-0 perturbation below f32 noise over 50 steps, but it
        // must still be visible a short horizon later (recurrence works)...
        xs[45 * 2] += 1.0;
        let h2 = cell.run(&xs, 50);
        let late: f32 = (0..4).map(|c| (h1[49 * 4 + c] - h2[49 * 4 + c]).abs()).sum();
        assert!(late > 1e-6, "state does not carry information");
        // ...and must NOT affect anything before it (causality)
        let early: f32 = (0..45 * 4).map(|i| (h1[i] - h2[i]).abs()).sum();
        assert!(early == 0.0, "future input leaked into the past: {early}");
    }

    #[test]
    fn cru_like_runs_and_uses_dt() {
        let mut rng = Rng::new(2);
        let cru = CruLike::init(3, 6, &mut rng);
        let xs = rng.normal_vec_f32(30 * 3);
        let y1 = cru.run(&xs, &vec![1.0; 30], 30);
        let y2 = cru.run(&xs, &vec![3.0; 30], 30);
        assert_eq!(y1.len(), 180);
        let d: f32 = y1.iter().zip(&y2).map(|(a, b)| (a - b).abs()).sum();
        assert!(d > 1e-6, "Δt must influence the CRU-like output");
    }
}
